//! The Matrices Processing Engine — Section III-A.
//!
//! `P_m` linear arrays of `P` PEs with multiplexers between adjacent
//! arrays. In *Independent* mode each array executes tasks alone; in
//! *Cooperation* mode a multiplexer chains two neighbours into one longer
//! array that shares a single memory interface and supports block sizes
//! up to the combined PE count (Eq. 9's coupling of `N_p` and `S_i`).
//!
//! Two levels of fidelity, cross-validated in tests:
//! * [`pe`] — a cycle-stepped simulation of one (possibly chained) array
//!   executing one sub-block task: per-PE `R_a` double buffering, `M_c`
//!   accumulation, PSU stall insertion when `S_i != S_j`, `f_c` drain.
//!   Produces both the numerical result and the exact cycle count.
//! * [`timing`] — the closed-form per-task cycle model (the Eq. 6
//!   components); asserted equal to the stepped simulation across the
//!   parameter space, then used by the fast event-driven simulator in
//!   [`crate::accelerator`].

pub mod pe;
pub mod timing;

pub use pe::{LinearArray, TaskExecution};
pub use timing::TaskTiming;

use crate::config::{HardwareConfig, RunConfig};

/// How the muxes are programmed for a run: `pm / np` base arrays chain
/// into each of the `np` logical arrays.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArrayGeometry {
    /// Logical (post-chaining) arrays working in parallel (`N_p`).
    pub np: usize,
    /// Base arrays chained per logical array (`pm / np`).
    pub chain: usize,
    /// PEs per logical array (`chain * P`).
    pub pes: usize,
}

impl ArrayGeometry {
    pub fn for_run(hw: &HardwareConfig, run: &RunConfig) -> anyhow::Result<Self> {
        run.validate(hw)?;
        let chain = hw.pm / run.np;
        Ok(Self { np: run.np, chain, pes: chain * hw.p })
    }

    /// Operation mode of the inter-array multiplexers.
    pub fn mode(&self) -> OperatingMode {
        if self.chain == 1 {
            OperatingMode::Independent
        } else {
            OperatingMode::Cooperation
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OperatingMode {
    /// All muxes disabled; arrays run separate tasks.
    Independent,
    /// Muxes enabled; chained arrays act as one longer array.
    Cooperation,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry_chains_by_power_of_two() {
        let hw = HardwareConfig::paper();
        let g = ArrayGeometry::for_run(&hw, &RunConfig::square(4, 64)).unwrap();
        assert_eq!((g.chain, g.pes), (1, 64));
        assert_eq!(g.mode(), OperatingMode::Independent);

        let g = ArrayGeometry::for_run(&hw, &RunConfig::square(2, 128)).unwrap();
        assert_eq!((g.chain, g.pes), (2, 128));
        assert_eq!(g.mode(), OperatingMode::Cooperation);

        let g = ArrayGeometry::for_run(&hw, &RunConfig::square(1, 256)).unwrap();
        assert_eq!((g.chain, g.pes), (4, 256));
    }

    #[test]
    fn geometry_rejects_eq9_violations() {
        let hw = HardwareConfig::paper();
        assert!(ArrayGeometry::for_run(&hw, &RunConfig::square(4, 128)).is_err());
        assert!(ArrayGeometry::for_run(&hw, &RunConfig::square(3, 16)).is_err());
    }
}
