//! Closed-form per-task cycle model — Eq. 6's numerator, validated
//! cycle-for-cycle against the stepped simulation in [`super::pe`].


/// Cycle breakdown of one sub-block task on one logical array.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TaskTiming {
    /// V_1 prefetch: `S_i` cycles.
    pub prefetch: u64,
    /// K iterations of `max(S_i, S_j)` cycles each.
    pub compute: u64,
    /// FMAC pipeline drain: `Stage_fmac` cycles.
    pub drain: u64,
    /// Result stream-out through `f_c`: `S_i * S_j + S_i` cycles
    /// (overlapped with the next task in the full accelerator; *not*
    /// part of Eq. 6's compute time).
    pub writeback: u64,
}

impl TaskTiming {
    /// Eq. 6 numerator for one task: `S_i + max(S_i,S_j) * K + Stage_fmac`.
    pub fn per_task(si: usize, sj: usize, k: usize, fmac_stages: usize) -> Self {
        Self {
            prefetch: si as u64,
            compute: si.max(sj) as u64 * k as u64,
            drain: fmac_stages as u64,
            writeback: (si * sj + si) as u64,
        }
    }

    /// Compute-pipeline cycles (what Eq. 6 counts).
    pub fn total(&self) -> u64 {
        self.prefetch + self.compute + self.drain
    }

    /// Seconds at the accelerator clock.
    pub fn seconds(&self, freq_mhz: f64) -> f64 {
        self.total() as f64 / (freq_mhz * 1e6)
    }
}

/// Eq. 6 in full: compute time (seconds) for `n_work` tasks on one array.
pub fn t_compute(
    n_work: usize,
    si: usize,
    sj: usize,
    k: usize,
    fmac_stages: usize,
    freq_mhz: f64,
) -> f64 {
    n_work as f64 * TaskTiming::per_task(si, sj, k, fmac_stages).total() as f64
        / (freq_mhz * 1e6)
}

/// Sustained-throughput ceiling of one array running back-to-back tasks:
/// useful FLOPs per task over cycles per task, at `freq_mhz`.
pub fn array_gflops(si: usize, sj: usize, k: usize, fmac_stages: usize, freq_mhz: f64) -> f64 {
    let t = TaskTiming::per_task(si, sj, k, fmac_stages);
    let flops = 2.0 * si as f64 * sj as f64 * k as f64;
    flops / (t.total() as f64 / (freq_mhz * 1e6)) / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq6_components() {
        let t = TaskTiming::per_task(128, 128, 1200, 14);
        assert_eq!(t.prefetch, 128);
        assert_eq!(t.compute, 128 * 1200);
        assert_eq!(t.drain, 14);
        assert_eq!(t.total(), 128 + 128 * 1200 + 14);
    }

    #[test]
    fn asymmetric_uses_max() {
        let t = TaskTiming::per_task(64, 96, 10, 8);
        assert_eq!(t.compute, 96 * 10);
    }

    #[test]
    fn t_compute_scales_with_n_work() {
        let one = t_compute(1, 128, 128, 1200, 14, 200.0);
        let three = t_compute(3, 128, 128, 1200, 14, 200.0);
        assert!((three - 3.0 * one).abs() < 1e-12);
    }

    #[test]
    fn array_gflops_approaches_2si_freq() {
        // With S_i = S_j and K large, cycles/task -> S_i * K, so the array
        // sustains ~2 * S_i FLOP/cycle = 2 * S_i * F GFLOPS: each of the
        // S_i PEs retires one FMAC per cycle.
        let g = array_gflops(128, 128, 100_000, 14, 200.0);
        let peak = 2.0 * 128.0 * 200e6 / 1e9; // 51.2
        assert!(g > 0.99 * peak && g <= peak, "{g} vs {peak}");
    }

    #[test]
    fn seconds_at_200mhz() {
        let t = TaskTiming::per_task(2, 2, 1, 0);
        // 2 + 2 + 0 = 4 cycles at 200 MHz = 20 ns.
        assert!((t.seconds(200.0) - 20e-9).abs() < 1e-18);
    }
}
