//! Cycle-stepped simulation of one linear PE array executing one
//! sub-block task `C_ij = SA_i x SB_j` (the dataflow of Fig. 1, right).
//!
//! Per PE state, exactly as the paper describes:
//! * `r_a` — double-buffered registers holding this PE's element of the
//!   current column `V_k` (front) while the next column `V_{k+1}` streams
//!   in (back);
//! * `m_c` — local memory accumulating this PE's row of `C_ij`;
//! * the PSU — when `S_i != S_j` the two streams finish an iteration at
//!   different times; the PSU stalls the faster stream so every PE sees
//!   the `k`-th column of SA and the `k`-th row of SB aligned.
//!
//! One element of each stream enters the array per cycle (the linear
//! array's single memory interface delivers one `a` and one `b` word per
//! cycle — its low-bandwidth virtue). An iteration therefore takes
//! `max(S_i, S_j)` cycles, the prefetch of `V_1` takes `S_i`, and the
//! FMAC pipeline drains in `Stage_fmac`: the stepped total reproduces
//! Eq. 6's `S_i + max(S_i, S_j) * K + Stage_fmac` per task, which
//! [`super::timing`] then uses in closed form.

use crate::gemm::Matrix;

/// What one task execution produced.
#[derive(Debug, Clone)]
pub struct TaskExecution {
    /// The `rows x cols` result block.
    pub result: Matrix,
    /// Cycles spent in each phase.
    pub prefetch_cycles: u64,
    pub compute_cycles: u64,
    pub drain_cycles: u64,
    /// PSU stalls inserted (cycles the shorter stream waited).
    pub psu_stalls: u64,
    /// Cycles to stream the result block out through `f_c` (overlapped
    /// with the next task's load in the full accelerator; reported for
    /// the write-back path model).
    pub writeback_cycles: u64,
}

impl TaskExecution {
    /// Total compute-pipeline cycles (what Eq. 6 counts).
    pub fn total_cycles(&self) -> u64 {
        self.prefetch_cycles + self.compute_cycles + self.drain_cycles
    }
}

/// One logical (possibly mux-chained) linear array of `pes` PEs.
#[derive(Debug, Clone)]
pub struct LinearArray {
    pub pes: usize,
    pub fmac_stages: usize,
}

struct PeState {
    /// Double-buffered R_a: [front (in use), back (being loaded)].
    r_a: [f32; 2],
    /// Local memory M_c: this PE's row of the accumulator block.
    m_c: Vec<f32>,
}

impl LinearArray {
    pub fn new(pes: usize, fmac_stages: usize) -> Self {
        assert!(pes >= 1);
        Self { pes, fmac_stages }
    }

    /// Execute one sub-block task. `sa` is the `rows x k` slice of A
    /// (`rows <= S_i`), `sb` the `k x cols` slice of B (`cols <= S_j`);
    /// `si`/`sj` are the *programmed* block sizes (BZ in the buffer
    /// descriptor) — the pipeline walks the padded extent, which is how
    /// the zero-padding of Section IV spends real cycles.
    pub fn execute_task(
        &self,
        sa: &Matrix,
        sb: &Matrix,
        si: usize,
        sj: usize,
    ) -> TaskExecution {
        assert_eq!(sa.cols, sb.rows, "contraction mismatch");
        assert!(sa.rows <= si && sb.cols <= sj, "block overflow");
        assert!(
            si <= self.pes,
            "S_i = {si} exceeds array length {} (Eq. 9)",
            self.pes
        );
        let k_iters = sa.cols;
        let iter_len = si.max(sj) as u64;

        let mut pes: Vec<PeState> = (0..si)
            .map(|_| PeState { r_a: [0.0; 2], m_c: vec![0.0; sj] })
            .collect();

        // --- Prefetch: V_1 streams in, PE `i` latches element `i`.
        // One element per cycle => S_i cycles.
        let mut cycles_prefetch = 0u64;
        for (i, pe) in pes.iter_mut().enumerate() {
            pe.r_a[0] = if i < sa.rows { sa.get(i, 0) } else { 0.0 };
            cycles_prefetch += 1;
        }

        // --- Compute: K iterations. In iteration k (1-based), U_k streams
        // across all PEs while V_{k+1} streams into the back buffers.
        let mut cycles_compute = 0u64;
        let mut psu_stalls = 0u64;
        for k in 0..k_iters {
            // The b-stream delivers U_k in S_j cycles and the a-stream
            // delivers V_{k+1} in S_i cycles, concurrently; the iteration
            // slot closes when the longer stream finishes, so the PSU
            // holds the compute (b) stream for max(S_i,S_j) - S_j cycles
            // whenever S_i > S_j (and idles the a-stream in the converse
            // case, which costs nothing — the FMAC keeps consuming b).
            cycles_compute += iter_len;
            psu_stalls += iter_len - sj as u64;

            for (i, pe) in pes.iter_mut().enumerate() {
                // FMAC: R_a (front) times every element of U_k, accumulated
                // into M_c — the R_a value is reused S_j times.
                let a = pe.r_a[0];
                for j in 0..sj {
                    let b = if i < sa.rows && j < sb.cols {
                        sb.get(k, j)
                    } else {
                        0.0
                    };
                    pe.m_c[j] += a * b;
                }
                // Back buffer fills with V_{k+1} in the same iteration.
                if k + 1 < k_iters {
                    pe.r_a[1] = if i < sa.rows { sa.get(i, k + 1) } else { 0.0 };
                }
            }
            // Double-buffer swap at the iteration boundary.
            for pe in pes.iter_mut() {
                pe.r_a[0] = pe.r_a[1];
            }
        }

        // --- Drain: the FMAC pipeline empties.
        let cycles_drain = self.fmac_stages as u64;

        // --- Write-back: the last iteration writes into f_c instead of
        // M_c; the block then streams PE-to-PE to PE_0 and out to the MAC:
        // S_i * S_j elements at one per cycle (+ array traversal latency).
        let writeback_cycles = (si * sj) as u64 + si as u64;

        // Collect the un-padded result.
        let rows = sa.rows;
        let cols = sb.cols;
        let mut result = Matrix::zeros(rows, cols);
        for i in 0..rows {
            result.data[i * cols..(i + 1) * cols]
                .copy_from_slice(&pes[i].m_c[..cols]);
        }

        TaskExecution {
            result,
            prefetch_cycles: cycles_prefetch,
            compute_cycles: cycles_compute,
            drain_cycles: cycles_drain,
            psu_stalls,
            writeback_cycles,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpe::timing::TaskTiming;
    use crate::util::check;

    fn array(pes: usize) -> LinearArray {
        LinearArray::new(pes, 14)
    }

    #[test]
    fn numerics_match_oracle() {
        let sa = Matrix::random(8, 5, 1);
        let sb = Matrix::random(5, 8, 2);
        let exec = array(8).execute_task(&sa, &sb, 8, 8);
        assert!(exec.result.allclose(&sa.matmul(&sb), 1e-5));
    }

    #[test]
    fn padded_task_numerics_unchanged() {
        // rows < S_i, cols < S_j: padding lanes must not pollute results.
        let sa = Matrix::random(5, 7, 3);
        let sb = Matrix::random(7, 3, 4);
        let exec = array(8).execute_task(&sa, &sb, 8, 8);
        assert_eq!((exec.result.rows, exec.result.cols), (5, 3));
        assert!(exec.result.allclose(&sa.matmul(&sb), 1e-5));
    }

    #[test]
    fn cycle_count_matches_eq6_square() {
        let si = 8;
        let k = 12;
        let sa = Matrix::random(si, k, 5);
        let sb = Matrix::random(k, si, 6);
        let exec = array(8).execute_task(&sa, &sb, si, si);
        let want = TaskTiming::per_task(si, si, k, 14);
        assert_eq!(exec.total_cycles(), want.total());
    }

    #[test]
    fn psu_stalls_zero_when_square() {
        let sa = Matrix::random(8, 6, 7);
        let sb = Matrix::random(6, 8, 8);
        let exec = array(8).execute_task(&sa, &sb, 8, 8);
        assert_eq!(exec.psu_stalls, 0);
    }

    #[test]
    fn psu_stalls_when_si_exceeds_sj() {
        // a-stream (S_i = 8) longer than b-stream (S_j = 4): the PSU
        // holds the compute stream (8 - 4) cycles every iteration.
        let k = 5;
        let sa = Matrix::random(8, k, 9);
        let sb = Matrix::random(k, 4, 10);
        let exec = array(8).execute_task(&sa, &sb, 8, 4);
        assert_eq!(exec.psu_stalls, (8 - 4) * k as u64);
        assert!(exec.result.allclose(&sa.matmul(&sb), 1e-5));
    }

    #[test]
    fn no_fmac_stall_when_sj_exceeds_si() {
        let sa = Matrix::random(4, 3, 15);
        let sb = Matrix::random(3, 8, 16);
        let exec = array(8).execute_task(&sa, &sb, 4, 8);
        assert_eq!(exec.psu_stalls, 0);
        assert!(exec.result.allclose(&sa.matmul(&sb), 1e-5));
    }

    #[test]
    fn writeback_streams_block_plus_latency() {
        let sa = Matrix::random(4, 3, 11);
        let sb = Matrix::random(3, 6, 12);
        let exec = array(8).execute_task(&sa, &sb, 4, 6);
        assert_eq!(exec.writeback_cycles, 4 * 6 + 4);
    }

    #[test]
    #[should_panic(expected = "Eq. 9")]
    fn block_larger_than_array_panics() {
        let sa = Matrix::random(9, 2, 13);
        let sb = Matrix::random(2, 9, 14);
        array(8).execute_task(&sa, &sb, 9, 9);
    }

    /// The stepped simulation always agrees with the closed form the
    /// fast simulator uses — the key cross-validation of the crate.
    #[test]
    fn prop_cycles_equal_closed_form() {
        check::cases(48, |rng| {
            let (si, sj, k) = (rng.range(1, 24), rng.range(1, 24), rng.range(1, 16));
            let seed = rng.next_u64();
            let sa = Matrix::random(si, k, seed);
            let sb = Matrix::random(k, sj, seed + 1);
            let exec = LinearArray::new(32, 14).execute_task(&sa, &sb, si, sj);
            let want = TaskTiming::per_task(si, sj, k, 14);
            assert_eq!(exec.total_cycles(), want.total());
        });
    }

    #[test]
    fn cooperation_mode_supports_blocks_beyond_base_array() {
        // Two chained 64-PE arrays act as one 128-PE array (Cooperation
        // mode): an S_i = 128 task is only executable on the chain.
        let chained = LinearArray::new(128, 14);
        let sa = Matrix::random(128, 6, 21);
        let sb = Matrix::random(6, 128, 22);
        let exec = chained.execute_task(&sa, &sb, 128, 128);
        assert!(exec.result.allclose(&sa.matmul(&sb), 1e-4));
        assert_eq!(
            exec.total_cycles(),
            TaskTiming::per_task(128, 128, 6, 14).total()
        );
    }

    #[test]
    fn single_pe_array_degenerates_to_dot_products() {
        // P = 1, S_i = 1: the array is one PE computing a row of C.
        let arr = LinearArray::new(1, 2);
        let sa = Matrix::random(1, 9, 23);
        let sb = Matrix::random(9, 5, 24);
        let exec = arr.execute_task(&sa, &sb, 1, 5);
        assert!(exec.result.allclose(&sa.matmul(&sb), 1e-5));
    }

    #[test]
    fn k_equals_one_single_rank1_update() {
        let arr = array(8);
        let sa = Matrix::random(4, 1, 25);
        let sb = Matrix::random(1, 4, 26);
        let exec = arr.execute_task(&sa, &sb, 4, 4);
        assert!(exec.result.allclose(&sa.matmul(&sb), 1e-6));
        // One iteration: prefetch 4 + compute 4 + drain 14.
        assert_eq!(exec.total_cycles(), 4 + 4 + 14);
    }

    /// Numerics always match the oracle, padded or not.
    #[test]
    fn prop_numerics() {
        check::cases(48, |rng| {
            let (rows, cols, k) = (rng.range(1, 16), rng.range(1, 16), rng.range(1, 10));
            let (pad_r, pad_c) = (rng.range(0, 4), rng.range(0, 4));
            let seed = rng.next_u64();
            let sa = Matrix::random(rows, k, seed);
            let sb = Matrix::random(k, cols, seed + 1);
            let exec = LinearArray::new(32, 8)
                .execute_task(&sa, &sb, rows + pad_r, cols + pad_c);
            assert!(exec.result.allclose(&sa.matmul(&sb), 1e-4));
        });
    }
}
