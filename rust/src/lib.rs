//! # multi-array-gemm
//!
//! A full-stack reproduction of *“Towards a Multi-array Architecture for
//! Accelerating Large-scale Matrix Multiplication on FPGAs”* (Shen, Qiao,
//! Huang, Wen, Zhang — NUDT, 2018).
//!
//! The paper extends the classic linear systolic array for blocked dense
//! GEMM into a configurable **multi-array** design with work stealing and
//! an analytical performance model. This crate rebuilds the whole system
//! with a cycle-level simulator standing in for the VC709 FPGA:
//!
//! * [`config`] — bitstream (`P_m`, `P`) and run-time (`N_p`, `S_i`) knobs;
//! * [`gemm`] — dense-matrix substrate in three layers: the oracle
//!   [`Matrix`], the functional blocked algorithm, and the zero-copy
//!   panel pipeline (borrowed `MatrixView`s → refcounted packed halves
//!   `PackedA`/`PackedB` composed per job as `PackedPanels` — packed
//!   once per job, shareable across jobs → register-blocked
//!   microkernel → lock-free `DisjointBlocks` writes into C) — the
//!   whole pipeline parameterized over a job-level [`Dtype`]
//!   (f64/f32/f16/bf16): panels convert at pack time, half-width
//!   panels widen on load and accumulate in f32, and the operand
//!   registry caches one pack per `(handle, side, S, dtype)`;
//! * [`blocking`] — the blocked algorithm's task grid (`BlockPlan`,
//!   whose exact tiling of C is what makes the disjoint writes sound);
//! * [`ddr`] — DDR3 bank/row timing model (the Fig. 3 substrate);
//! * [`mac`] — buffer descriptors, transpose-of-A, burst scheduling;
//! * [`wqm`] — workload queues + the work-stealing controller: the
//!   steppable `Wqm` for the simulators, the lock-free `AtomicWqm`
//!   (one CAS per pop/steal) for the coordinator's workers, and the
//!   epoch-tagged `JobRegistry` that widens the stealing scope from
//!   arrays to live jobs;
//! * [`mpe`] — PE / linear-array / multi-array cycle model (PSU, FIFOs,
//!   Independent vs Cooperation mux modes);
//! * [`accelerator`] — the integrated event-driven simulation;
//! * [`analytical`] — Eqs. 3–9 and the `BW = f(N_p, S_i)` surface;
//! * [`dse`] — design-space exploration for optimal `⟨N_p, S_i⟩`;
//! * [`resources`] — Table I's post-synthesis resource model;
//! * [`cnn`] — AlexNet-as-GEMM workloads (Table II) plus the im2col
//!   streaming front-end: conv layers lower to patch-row GEMMs whose
//!   shared filter matrix is packed once per batch;
//! * [`runtime`] — PJRT client executing the AOT-compiled JAX/Pallas
//!   kernels (`artifacts/*.hlo.txt`) for the real numerics;
//! * [`coordinator`] — the serving layer: GEMM jobs in, panels packed
//!   once per job, workers draining lock-free WQMs and writing disjoint
//!   C blocks in place, timing via the simulator. Two shapes: the
//!   one-job-at-a-time `Coordinator`, and the multi-job `JobServer` —
//!   a persistent pool behind a traffic-shaped admission front end
//!   (one typed `Submission` builder with `submit_async` →
//!   awaitable `JobFuture`, per-tenant quotas + weighted
//!   deficit-round-robin fairness, deadline-slack dispatch with
//!   misses surfaced in `stats()`, N admission shards) with cross-job
//!   work stealing, small-job batching, shared-operand batches
//!   (`Submission::batched`: one B packed once, fanned out to N
//!   sub-jobs as a `JobGroup`, bit-identical to individual runs), and
//!   a server-resident operand registry symmetric over both sides
//!   (`register_b` → `WeightHandle`, `register_a` →
//!   `ActivationHandle`: operands packed at most once per
//!   `(handle, side, S)` for the whole process, resolved from cache by
//!   every submission carrying a handle, one shared byte budget with
//!   refcount-pinned cross-side LRU eviction) plus registry-aware
//!   planning (a pinned or DSE'd config is steered to an
//!   already-resident block-size variant within a cost slack), and a
//!   bounded lock-free flight recorder (`coordinator::trace`: a
//!   seqlock ring stamping every job's submit → admit → pop → plan →
//!   publish → task → finalize lifecycle, folded into per-job
//!   queue/plan/pack/execute/finalize breakdowns, per-worker steal
//!   provenance, predicted-vs-measured drift, and JSONL / Chrome
//!   `trace_event` export), the production serving runtime;
//! * [`attention`] — the flagship registered-operand workload: a
//!   transformer block (Q/K/V/O projections, QKᵀ, softmax, AV) served
//!   entirely through registered operands — activations registered
//!   once per batch on the A side, weights held as `WeightHandle`s —
//!   so repeated runs over one batch pack nothing;
//! * [`strassen`] — the algorithmic layer above the serving runtime:
//!   recursive Strassen decomposition (7 sub-products per quadrant
//!   split instead of 8) whose leaf fan-out is submitted to the
//!   `JobServer` as a job group and load-balanced by cross-job
//!   stealing; the 7-product algebra is table-driven
//!   (`strassen::StrassenAlgo` — default Winograd schedule at 15
//!   combine ops per node vs the classic 18), leaf operand
//!   combinations are fused into the packer (`FusedOperand`: the
//!   panel packer streams `X ± Y` straight from parent quadrant views,
//!   no materialized temps), sibling sub-trees above the leaf walk in
//!   parallel on scoped threads (bit-identical to the sequential
//!   walk), the recursion cutoff is chosen by the analytical model
//!   (`analytical::strassen_crossover_with`, combine term priced per
//!   schedule and fusion mode) and temporaries recycle through a
//!   scratch arena; `strassen::multiply_batched` runs a whole
//!   shared-B batch through one recursion, materializing and packing
//!   each B-side quadrant combination once for the batch.

pub mod accelerator;
pub mod analytical;
pub mod attention;
pub mod blocking;
pub mod cnn;
pub mod config;
pub mod coordinator;
pub mod ddr;
pub mod dse;
pub mod gemm;
pub mod mac;
pub mod mpe;
pub mod resources;
pub mod runtime;
pub mod strassen;
pub mod util;
pub mod wqm;

pub use config::{HardwareConfig, RunConfig};
pub use coordinator::{
    ActivationHandle, AOperand, BOperand, GemmJob, JobFuture, JobServer, ServerConfig,
    SubmitError, Submission, TenantConfig, TenantId, WeightHandle,
};
pub use gemm::{Dtype, Matrix};
