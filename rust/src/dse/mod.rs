//! Design-space exploration: pick the optimal `⟨N_p, S_i⟩` for a problem.
//!
//! Section IV's procedure: fix the PE budget `P_m * P`, enumerate the
//! `(N_p, S_i)` pairs Eq. 9 admits, evaluate the analytical model for
//! each, and keep the pair that minimizes the (range of) `T_total`. The
//! explorer ranks by the overlap estimate `max(T_compute, T_trans)` with
//! the Eq. 7 upper bound as tie-break — the candidate that is fastest
//! when double buffering works and degrades least when it doesn't.
//!
//! The serving layer adds one refinement on top of this search: when a
//! job's operands are registered with the
//! [`crate::coordinator::OperandRegistry`], the `JobServer` may steer
//! the DSE'd (or pinned) config toward an `(S_i, S_j)` variant whose
//! packs are already resident, whenever this model prices the variant
//! within `ServerConfig::plan_residency_slack` of the baseline — see
//! `refine_run_for_residency` in the coordinator.


use crate::analytical::{self, BandwidthSurface, Prediction};
use crate::blocking::BlockPlan;
use crate::config::{HardwareConfig, RunConfig};
use crate::gemm::Dtype;

/// One evaluated design point.
#[derive(Debug, Clone)]
pub struct DesignPoint {
    pub run: RunConfig,
    pub prediction: Prediction,
    pub est_gflops: f64,
}

/// Result of exploring one problem.
#[derive(Debug, Clone)]
pub struct Exploration {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub best: DesignPoint,
    /// All feasible points, sorted best-first.
    pub points: Vec<DesignPoint>,
}

/// Candidate block sizes: multiples of 16 up to the full PE budget (the
/// paper's sweep granularity in Fig. 4), clipped to the problem's M.
pub fn candidate_sis(hw: &HardwareConfig, m: usize) -> Vec<usize> {
    let max = hw.total_pes();
    let mut sis: Vec<usize> = (1..=max / 16).map(|i| i * 16).collect();
    // Block sizes beyond M only waste pipeline slots on padding, but keep
    // the next multiple above M so ragged problems can use one row block.
    sis.retain(|&si| si <= m.next_multiple_of(16).max(16));
    if sis.is_empty() {
        sis.push(16);
    }
    sis
}

/// Evaluate every Eq. 9-feasible `(N_p, S_i)` for `(m, k, n)`.
pub fn explore(
    hw: &HardwareConfig,
    m: usize,
    k: usize,
    n: usize,
    surface: &BandwidthSurface,
) -> anyhow::Result<Exploration> {
    explore_dtype(hw, m, k, n, surface, Dtype::F32)
}

/// [`explore`] with every candidate priced at `dtype`
/// ([`analytical::predict_dtype`]): narrower operands move less data
/// and cost cheaper MACs, so the optimum can shift toward smaller
/// blocks or more arrays. Identical to [`explore`] at `F32` (which
/// delegates here).
pub fn explore_dtype(
    hw: &HardwareConfig,
    m: usize,
    k: usize,
    n: usize,
    surface: &BandwidthSurface,
    dtype: Dtype,
) -> anyhow::Result<Exploration> {
    let flops = BlockPlan::new(m, k, n, 16, 16).effective_flops();
    let mut points = Vec::new();
    for si in candidate_sis(hw, m) {
        for np in analytical::feasible_nps(hw, si) {
            let run = RunConfig::square(np, si);
            let prediction = analytical::predict_dtype(hw, &run, m, k, n, surface, dtype)?;
            let est_gflops = prediction.gflops_from(flops);
            points.push(DesignPoint { run, prediction, est_gflops });
        }
    }
    anyhow::ensure!(!points.is_empty(), "no feasible design point");
    points.sort_by(|a, b| {
        a.prediction
            .t_overlap()
            .partial_cmp(&b.prediction.t_overlap())
            .unwrap()
            .then(a.prediction.upper.partial_cmp(&b.prediction.upper).unwrap())
    });
    Ok(Exploration { m, k, n, best: points[0].clone(), points })
}

/// A precision-aware exploration verdict: the chosen dtype and the full
/// design-point ranking at that precision.
#[derive(Debug, Clone)]
pub struct PrecisionChoice {
    pub dtype: Dtype,
    pub exploration: Exploration,
}

/// Precision-aware DSE: among the precisions whose unit roundoff is at
/// most `accuracy_floor`, return the one whose best design point is
/// fastest. f16 and bf16 price identically (same width, same MAC
/// cost); the tie resolves toward bf16, whose f32-width exponent keeps
/// long accumulations out of overflow. Errors when no precision meets
/// the floor (ask for better than f64 and nothing qualifies).
pub fn explore_precision(
    hw: &HardwareConfig,
    m: usize,
    k: usize,
    n: usize,
    surface: &BandwidthSurface,
    accuracy_floor: f64,
) -> anyhow::Result<PrecisionChoice> {
    // Preference order under ties: widest exponent range per byte
    // first. Strict `<` below means earlier entries win exact ties.
    let mut best: Option<PrecisionChoice> = None;
    for dtype in [Dtype::Bf16, Dtype::F16, Dtype::F32, Dtype::F64] {
        if dtype.unit_roundoff() > accuracy_floor {
            continue;
        }
        let exploration = explore_dtype(hw, m, k, n, surface, dtype)?;
        let t = exploration.best.prediction.t_overlap();
        if best
            .as_ref()
            .map(|b| t < b.exploration.best.prediction.t_overlap())
            .unwrap_or(true)
        {
            best = Some(PrecisionChoice { dtype, exploration });
        }
    }
    best.ok_or_else(|| {
        anyhow::anyhow!("no precision meets accuracy floor {accuracy_floor:e} (f64 is the best available)")
    })
}

/// Direct exploration plus the Strassen recursion verdict — the cutoff
/// is a first-class DSE output alongside the optimal `⟨N_p, S_i⟩`.
#[derive(Debug, Clone)]
pub struct StrassenExploration {
    /// The classic per-problem exploration (best direct design point).
    pub direct: Exploration,
    /// The recursion-cutoff trace for the same problem.
    pub crossover: crate::analytical::CrossoverPlan,
}

/// Explore `(m, k, n)` both ways: the best direct `⟨N_p, S_i⟩` and the
/// model-chosen Strassen depth on top of it
/// ([`crate::analytical::strassen_crossover`]).
pub fn explore_strassen(
    hw: &HardwareConfig,
    m: usize,
    k: usize,
    n: usize,
    surface: &BandwidthSurface,
) -> anyhow::Result<StrassenExploration> {
    Ok(StrassenExploration {
        direct: explore(hw, m, k, n, surface)?,
        crossover: analytical::strassen_crossover(hw, m, k, n, surface)?,
    })
}

/// The fixed-extension baselines Table II compares against: all arrays
/// independent (`N_p = P_m`) and one fully-chained array (`N_p = 1`),
/// each at its best feasible S_i.
pub fn baseline(
    hw: &HardwareConfig,
    np: usize,
    m: usize,
    k: usize,
    n: usize,
    surface: &BandwidthSurface,
) -> anyhow::Result<DesignPoint> {
    let flops = BlockPlan::new(m, k, n, 16, 16).effective_flops();
    let mut best: Option<DesignPoint> = None;
    for si in candidate_sis(hw, m) {
        if !analytical::feasible_nps(hw, si).contains(&np) {
            continue;
        }
        let run = RunConfig::square(np, si);
        let prediction = analytical::predict(hw, &run, m, k, n, surface)?;
        let point = DesignPoint {
            run,
            prediction,
            est_gflops: prediction.gflops_from(flops),
        };
        if best
            .as_ref()
            .map(|b| point.prediction.t_overlap() < b.prediction.t_overlap())
            .unwrap_or(true)
        {
            best = Some(point);
        }
    }
    best.ok_or_else(|| anyhow::anyhow!("no feasible point for np={np}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (HardwareConfig, BandwidthSurface) {
        let hw = HardwareConfig::paper();
        let s = BandwidthSurface::calibrate(&hw.ddr);
        (hw, s)
    }

    #[test]
    fn explore_returns_sorted_feasible_points() {
        let (hw, s) = setup();
        let e = explore(&hw, 128, 1200, 729, &s).unwrap();
        assert!(!e.points.is_empty());
        for w in e.points.windows(2) {
            assert!(
                w[0].prediction.t_overlap() <= w[1].prediction.t_overlap() + 1e-12
            );
        }
        for p in &e.points {
            assert!(p.run.validate(&hw).is_ok());
        }
    }

    #[test]
    fn best_beats_baselines_on_alexnet_layers() {
        // The Table II headline: the optimal mixed extension is at least
        // as fast as both pure extensions on every layer.
        let (hw, s) = setup();
        for l in crate::cnn::alexnet_layers() {
            let e = explore(&hw, l.m, l.k, l.n, &s).unwrap();
            let b4 = baseline(&hw, 4, l.m, l.k, l.n, &s).unwrap();
            let b1 = baseline(&hw, 1, l.m, l.k, l.n, &s).unwrap();
            assert!(
                e.best.est_gflops >= b4.est_gflops - 1e-9,
                "{}: best {} < np=4 {}",
                l.name,
                e.best.est_gflops,
                b4.est_gflops
            );
            assert!(
                e.best.est_gflops >= b1.est_gflops - 1e-9,
                "{}: best {} < np=1 {}",
                l.name,
                e.best.est_gflops,
                b1.est_gflops
            );
        }
    }

    #[test]
    fn optimal_uses_multiple_arrays_on_conv2() {
        // Paper Table II: conv-2 optimum is (2, 128) — multi-array with
        // chaining, not a pure extension.
        let (hw, s) = setup();
        let e = explore(&hw, 128, 1200, 729, &s).unwrap();
        assert!(e.best.run.np >= 2, "got {}", e.best.run);
        assert!(e.best.run.si >= 64, "got {}", e.best.run);
    }

    #[test]
    fn candidate_sis_respects_budget_and_m() {
        let hw = HardwareConfig::paper();
        let sis = candidate_sis(&hw, 10_000);
        assert_eq!(*sis.last().unwrap(), 256);
        let sis = candidate_sis(&hw, 96);
        assert!(*sis.last().unwrap() <= 96);
        let sis = candidate_sis(&hw, 1);
        assert_eq!(sis, vec![16]);
    }

    #[test]
    fn explore_dtype_f32_is_the_base_sweep() {
        let (hw, s) = setup();
        let base = explore(&hw, 128, 1200, 729, &s).unwrap();
        let f32d = explore_dtype(&hw, 128, 1200, 729, &s, Dtype::F32).unwrap();
        assert_eq!(base.best.run, f32d.best.run);
        assert_eq!(
            base.best.prediction.t_overlap().to_bits(),
            f32d.best.prediction.t_overlap().to_bits()
        );
    }

    #[test]
    fn explore_precision_selects_cheapest_dtype_meeting_the_floor() {
        // The acceptance pin for precision-aware DSE, against the
        // per-precision cost tables: a loose floor admits the half
        // types (bf16 wins the f16 tie on exponent range), a 1e-6
        // floor excludes both halves and falls back to f32, a floor
        // only f64 meets returns f64, and an impossible floor errors.
        let (hw, s) = setup();
        let loose = explore_precision(&hw, 128, 1200, 729, &s, 5e-3).unwrap();
        assert_eq!(loose.dtype, Dtype::Bf16);
        let f16_only = explore_precision(&hw, 128, 1200, 729, &s, 1e-3).unwrap();
        assert_eq!(f16_only.dtype, Dtype::F16, "bf16 fails a 1e-3 floor, f16 meets it");
        let tight = explore_precision(&hw, 128, 1200, 729, &s, 1e-6).unwrap();
        assert_eq!(tight.dtype, Dtype::F32);
        let double = explore_precision(&hw, 128, 1200, 729, &s, 2e-16).unwrap();
        assert_eq!(double.dtype, Dtype::F64);
        assert!(explore_precision(&hw, 128, 1200, 729, &s, 1e-17).is_err());
        // The cheaper precision is genuinely predicted faster: that is
        // WHY the loose floor picks it.
        assert!(
            loose.exploration.best.prediction.t_overlap()
                < tight.exploration.best.prediction.t_overlap()
        );
    }

    #[test]
    fn baseline_infeasible_np_errors() {
        let (hw, s) = setup();
        assert!(baseline(&hw, 8, 128, 128, 128, &s).is_err());
    }

    #[test]
    fn explore_works_on_tiny_hardware() {
        let hw = HardwareConfig::tiny(); // Pm=2, P=8 -> 16 PEs
        let s = BandwidthSurface::calibrate_for(&hw.ddr, &[1, 2]);
        let e = explore(&hw, 50, 30, 50, &s).unwrap();
        assert!(e.best.run.si <= 16);
        assert!(e.best.run.np <= 2);
    }

    #[test]
    fn fc_layers_prefer_chained_big_blocks() {
        // The paper's fc rows all land on (2, 128): K is huge, so big
        // blocks amortize transfers and chaining supplies the PEs.
        let (hw, s) = setup();
        for name in ["fc6", "fc7", "fc8"] {
            let l = crate::cnn::layer(name).unwrap();
            let e = explore(&hw, l.m, l.k, l.n, &s).unwrap();
            assert_eq!(
                (e.best.run.np, e.best.run.si),
                (2, 128),
                "{name} chose {}",
                e.best.run
            );
        }
    }

    #[test]
    fn strassen_exploration_agrees_with_direct_sweep() {
        // analytical::strassen::best_direct_secs mirrors explore()'s
        // candidate sweep; the crossover's level-0 direct time must be
        // exactly the best explored overlap estimate.
        let (hw, s) = setup();
        for (m, k, n) in [(128, 1200, 729), (128, 9216, 4096), (50, 30, 50), (1000, 1000, 1000)] {
            let e = explore_strassen(&hw, m, k, n, &s).unwrap();
            let direct = e.direct.best.prediction.t_overlap();
            let model = e.crossover.t_direct;
            assert!(
                (direct - model).abs() <= 1e-12 * direct.max(1.0),
                "{m}x{k}x{n}: explore {direct} vs crossover {model}"
            );
        }
    }

    #[test]
    fn strassen_exploration_recurses_only_at_scale() {
        let (hw, s) = setup();
        assert_eq!(explore_strassen(&hw, 128, 128, 128, &s).unwrap().crossover.depth, 0);
        assert!(explore_strassen(&hw, 8192, 8192, 8192, &s).unwrap().crossover.depth >= 1);
    }

    #[test]
    fn baseline_np1_uses_full_chain() {
        let (hw, s) = setup();
        let b = baseline(&hw, 1, 128, 9216, 4096, &s).unwrap();
        assert_eq!(b.run.np, 1);
        assert!(b.run.si > 64, "chained baseline should use big blocks");
    }
}
