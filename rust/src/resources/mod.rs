//! FPGA resource model — the substitute for Vivado post-synthesis reports.
//!
//! Table I gives one data point: the full design at `(P_m, P) = (4, 64)`
//! on a XC7VX690T uses 1032 DSP48Es, 560.5 BRAM36s, 292016 FFs and 192493
//! LUTs (all < 50% of the device, which is what lets it close timing at
//! 200 MHz). We decompose that into per-PE, per-array and base
//! (MAC + WQM + DDR controllers + PCIe) costs so the model (a) reproduces
//! Table I exactly at the paper's design point and (b) extrapolates
//! plausibly across the design space the DSE explores.
//!
//! Decomposition rationale:
//! * DSP: a Virtex-7 FP32 FMAC maps to 4 DSP48Es (3 for the multiplier in
//!   "full" mode + 1 for the adder's mantissa datapath) -> 1024 for 256
//!   PEs; the remaining 8 sit in the MAC's address generators.
//! * BRAM: each PE holds `M_c` (accumulator block rows) + FIFOs `f_a/f_b/
//!   f_c` ~ 2 BRAM36; per-array workload queues + width converters ~ 8;
//!   the MAC/DDR infrastructure uses the odd 16.5 (the .5 is an 18Kb
//!   half-block, as Vivado reports them).
//! * FF/LUT: pipeline registers dominate and scale with PE count.


use crate::config::HardwareConfig;

/// One resource vector in device units.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ResourceVector {
    pub dsp: f64,
    pub bram36: f64,
    pub ff: f64,
    pub lut: f64,
}

impl ResourceVector {
    pub fn scale(&self, by: f64) -> Self {
        Self {
            dsp: self.dsp * by,
            bram36: self.bram36 * by,
            ff: self.ff * by,
            lut: self.lut * by,
        }
    }

    pub fn add(&self, other: &Self) -> Self {
        Self {
            dsp: self.dsp + other.dsp,
            bram36: self.bram36 + other.bram36,
            ff: self.ff + other.ff,
            lut: self.lut + other.lut,
        }
    }

    /// Element-wise utilization fraction against a device.
    pub fn utilization(&self, device: &Self) -> Self {
        Self {
            dsp: self.dsp / device.dsp,
            bram36: self.bram36 / device.bram36,
            ff: self.ff / device.ff,
            lut: self.lut / device.lut,
        }
    }

    pub fn fits(&self, device: &Self) -> bool {
        self.dsp <= device.dsp
            && self.bram36 <= device.bram36
            && self.ff <= device.ff
            && self.lut <= device.lut
    }

    pub fn max_fraction(&self, device: &Self) -> f64 {
        let u = self.utilization(device);
        u.dsp.max(u.bram36).max(u.ff).max(u.lut)
    }
}

/// The XC7VX690T device capacity (Virtex-7 datasheet).
pub fn xc7vx690t() -> ResourceVector {
    ResourceVector { dsp: 3600.0, bram36: 1470.0, ff: 866_400.0, lut: 433_200.0 }
}

/// Calibrated cost model: `total = per_pe * (Pm*P) + per_array * Pm + base`.
#[derive(Debug, Clone, PartialEq)]
pub struct ResourceModel {
    pub per_pe: ResourceVector,
    pub per_array: ResourceVector,
    pub base: ResourceVector,
}

impl Default for ResourceModel {
    fn default() -> Self {
        Self::calibrated()
    }
}

impl ResourceModel {
    /// Calibrated to reproduce Table I at `(Pm, P) = (4, 64)`.
    pub fn calibrated() -> Self {
        Self {
            per_pe: ResourceVector { dsp: 4.0, bram36: 2.0, ff: 1000.0, lut: 600.0 },
            per_array: ResourceVector {
                dsp: 0.0,
                bram36: 8.0,
                ff: 6000.0,
                lut: 7000.0,
            },
            base: ResourceVector {
                dsp: 8.0,
                bram36: 16.5,
                ff: 12016.0,
                lut: 10893.0,
            },
        }
    }

    /// Estimated usage for a `(Pm, P)` design.
    pub fn estimate(&self, pm: usize, p: usize) -> ResourceVector {
        self.per_pe
            .scale((pm * p) as f64)
            .add(&self.per_array.scale(pm as f64))
            .add(&self.base)
    }

    pub fn estimate_for(&self, hw: &HardwareConfig) -> ResourceVector {
        self.estimate(hw.pm, hw.p)
    }

    /// Largest `P` (PEs per array) that fits the device for a given `Pm`.
    pub fn max_p(&self, pm: usize, device: &ResourceVector) -> usize {
        let mut lo = 0usize;
        let mut hi = 8192usize;
        while lo < hi {
            let mid = (lo + hi + 1) / 2;
            if self.estimate(pm, mid).fits(device) {
                lo = mid;
            } else {
                hi = mid - 1;
            }
        }
        lo
    }
}

/// A Table I-style report row.
#[derive(Debug, Clone)]
pub struct UtilizationReport {
    pub usage: ResourceVector,
    pub percent: ResourceVector,
}

pub fn report(hw: &HardwareConfig) -> UtilizationReport {
    let model = ResourceModel::calibrated();
    let usage = model.estimate_for(hw);
    let percent = usage.utilization(&xc7vx690t()).scale(100.0);
    UtilizationReport { usage, percent }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check;

    #[test]
    fn reproduces_table1_exactly() {
        let r = report(&HardwareConfig::paper());
        assert_eq!(r.usage.dsp, 1032.0);
        assert_eq!(r.usage.bram36, 560.5);
        assert_eq!(r.usage.ff, 292_016.0);
        assert_eq!(r.usage.lut, 192_493.0);
    }

    #[test]
    fn reproduces_table1_percentages() {
        // Paper: 28.67 / 38.13 / 33.70 / 44.44 %.
        let r = report(&HardwareConfig::paper());
        assert!((r.percent.dsp - 28.67).abs() < 0.01, "{}", r.percent.dsp);
        assert!((r.percent.bram36 - 38.13).abs() < 0.01, "{}", r.percent.bram36);
        assert!((r.percent.ff - 33.70).abs() < 0.01, "{}", r.percent.ff);
        assert!((r.percent.lut - 44.44).abs() < 0.01, "{}", r.percent.lut);
    }

    #[test]
    fn paper_design_fits_device() {
        let m = ResourceModel::calibrated();
        assert!(m.estimate(4, 64).fits(&xc7vx690t()));
    }

    #[test]
    fn max_p_is_monotone_in_pm() {
        let m = ResourceModel::calibrated();
        let d = xc7vx690t();
        assert!(m.max_p(1, &d) >= m.max_p(2, &d));
        assert!(m.max_p(2, &d) >= m.max_p(4, &d));
        // The device can hold a much larger design than the paper's 50%.
        assert!(m.max_p(4, &d) > 64);
    }

    #[test]
    fn prop_estimate_monotone() {
        check::cases(32, |rng| {
            let (pm, p) = (rng.range(1, 8), rng.range(1, 256));
            let m = ResourceModel::calibrated();
            let a = m.estimate(pm, p);
            let b = m.estimate(pm, p + 1);
            assert!(b.dsp >= a.dsp && b.bram36 >= a.bram36);
            assert!(b.ff >= a.ff && b.lut >= a.lut);
        });
    }

    #[test]
    fn prop_utilization_consistent() {
        check::cases(32, |rng| {
            let (pm, p) = (rng.range(1, 8), rng.range(1, 128));
            let m = ResourceModel::calibrated();
            let d = xc7vx690t();
            let e = m.estimate(pm, p);
            assert_eq!(e.fits(&d), e.max_fraction(&d) <= 1.0);
        });
    }
}
