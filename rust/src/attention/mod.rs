//! Transformer-block attention served through registered operands —
//! the flagship cache-hot workload for the symmetric operand registry.
//!
//! A decoder block's GEMM traffic has two stable halves:
//!
//! * **weights** (`W_q`, `W_k`, `W_v`, `W_o`) are fixed across every
//!   request — the classic B-side registry case
//!   ([`AttentionWeights`], one [`WeightHandle`] per projection);
//! * **activations** (the token batch `X`) are fixed across the many
//!   GEMMs *inside* one serving step — `X` feeds the Q, K and V
//!   projections, so an inline path re-packs the very same matrix
//!   three times per member per run. [`ActivationBatch`] registers
//!   each member once on the A side ([`ActivationHandle`]) and every
//!   projection resolves it from the pack cache.
//!
//! [`attention_block_registered`] runs the whole block — batched
//! Q/K/V projections (shared-B groups over registered activations),
//! per-member scaled `Q·Kᵀ`, a numerically stable host-side softmax,
//! per-member `P·V`, and a batched O-projection — with **zero operand
//! packing after warmup**: N repeated runs over one registered batch
//! perform exactly one A-pack per `(member, S_i)` variant and one
//! B-pack per weight variant, where the inline path
//! ([`attention_block_inline`]) packs every operand on every run.
//! Both paths drive identical kernels over identical packed layouts,
//! so their outputs are **bit-identical**; [`attention_block_oracle`]
//! is the scalar reference for end-to-end `allclose` checks
//! (`marr attention --check`).

use crate::config::RunConfig;
use crate::coordinator::{
    ActivationHandle, AOperand, BOperand, GemmJob, JobServer, SpanKind, Submission,
    WeightHandle,
};
use crate::gemm::{Dtype, Matrix};

/// One attention block's projection weights as server-resident state:
/// `W_q`, `W_k`, `W_v`, `W_o`, each `d_model x d_model`, registered
/// once and resolved from the registry by every serving step.
pub struct AttentionWeights {
    wq: WeightHandle,
    wk: WeightHandle,
    wv: WeightHandle,
    wo: WeightHandle,
    d_model: usize,
}

impl AttentionWeights {
    /// Register the four projection matrices (the model-load step).
    /// All must be square `d_model x d_model`. On a partial failure the
    /// already-registered handles are released before the error
    /// surfaces, so a half-loaded block never leaks into the server.
    pub fn register(
        server: &JobServer,
        wq: Matrix,
        wk: Matrix,
        wv: Matrix,
        wo: Matrix,
    ) -> anyhow::Result<Self> {
        let d_model = wq.rows;
        anyhow::ensure!(d_model > 0, "degenerate d_model 0");
        for (name, w) in [("W_q", &wq), ("W_k", &wk), ("W_v", &wv), ("W_o", &wo)] {
            anyhow::ensure!(
                (w.rows, w.cols) == (d_model, d_model),
                "{name} is {}x{}, expected {d_model}x{d_model}",
                w.rows,
                w.cols
            );
        }
        let mut handles = Vec::with_capacity(4);
        for (name, w) in [("W_q", wq), ("W_k", wk), ("W_v", wv), ("W_o", wo)] {
            match server.register_b(w) {
                Ok(h) => handles.push(h),
                Err(e) => {
                    let e = e.context(format!("registering {name}"));
                    return Err(match server.unregister_all(handles) {
                        Ok(()) => e,
                        Err(cleanup) => e.context(format!(
                            "cleanup of partially registered block also failed: {cleanup:#}"
                        )),
                    });
                }
            }
        }
        let (wq, wk, wv, wo) = (handles[0], handles[1], handles[2], handles[3]);
        Ok(Self { wq, wk, wv, wo, d_model })
    }

    /// Deterministic random weights — the demo/bench model.
    pub fn random(server: &JobServer, d_model: usize, seed: u64) -> anyhow::Result<Self> {
        Self::register(
            server,
            Matrix::random(d_model, d_model, seed),
            Matrix::random(d_model, d_model, seed + 1),
            Matrix::random(d_model, d_model, seed + 2),
            Matrix::random(d_model, d_model, seed + 3),
        )
    }

    /// The block's model width.
    pub fn d_model(&self) -> usize {
        self.d_model
    }

    /// The four registered handles, in `[W_q, W_k, W_v, W_o]` order.
    pub fn handles(&self) -> [WeightHandle; 4] {
        [self.wq, self.wk, self.wv, self.wo]
    }

    /// Drop all four registered weights (cached packs freed). Sweeps
    /// the whole set even when one handle fails.
    pub fn unregister(self, server: &JobServer) -> anyhow::Result<()> {
        server.unregister_all([self.wq, self.wk, self.wv, self.wo])
    }
}

/// A token batch registered on the A side: each member (one sequence's
/// `seq x d_model` activation matrix) held under an
/// [`ActivationHandle`], packed at most once per `(member, S_i)`
/// variant however many projections and serving steps consume it.
pub struct ActivationBatch {
    handles: Vec<ActivationHandle>,
    seq: usize,
    d_model: usize,
}

impl ActivationBatch {
    /// Register every member of the batch. All members must share one
    /// `seq x d_model` shape; a partial failure releases what was
    /// registered before surfacing.
    pub fn register(server: &JobServer, xs: &[Matrix]) -> anyhow::Result<Self> {
        anyhow::ensure!(!xs.is_empty(), "empty batch");
        let (seq, d_model) = (xs[0].rows, xs[0].cols);
        anyhow::ensure!(seq > 0 && d_model > 0, "degenerate member {seq}x{d_model}");
        anyhow::ensure!(
            xs.iter().all(|x| (x.rows, x.cols) == (seq, d_model)),
            "batch members must share one shape"
        );
        let mut handles = Vec::with_capacity(xs.len());
        for (i, x) in xs.iter().enumerate() {
            match server.register_a(x.clone()) {
                Ok(h) => handles.push(h),
                Err(e) => {
                    let e = e.context(format!("registering batch member {i}"));
                    return Err(match server.unregister_all_a(handles) {
                        Ok(()) => e,
                        Err(cleanup) => e.context(format!(
                            "cleanup of partially registered batch also failed: {cleanup:#}"
                        )),
                    });
                }
            }
        }
        Ok(Self { handles, seq, d_model })
    }

    /// Batch size.
    pub fn len(&self) -> usize {
        self.handles.len()
    }

    /// True iff the batch has no members (unreachable via
    /// [`ActivationBatch::register`], which rejects empty input).
    pub fn is_empty(&self) -> bool {
        self.handles.is_empty()
    }

    /// Tokens per member.
    pub fn seq(&self) -> usize {
        self.seq
    }

    /// Model width.
    pub fn d_model(&self) -> usize {
        self.d_model
    }

    /// The per-member handles, in batch order.
    pub fn handles(&self) -> &[ActivationHandle] {
        &self.handles
    }

    /// Drop every member's registration (cached packs freed). Sweeps
    /// the whole list even when one handle fails.
    pub fn unregister(self, server: &JobServer) -> anyhow::Result<()> {
        server.unregister_all_a(self.handles)
    }
}

/// Run one attention block over a **registered** batch: every
/// projection resolves both sides from the operand registry. Returns
/// the per-member `seq x d_model` block outputs, in batch order.
pub fn attention_block_registered(
    server: &JobServer,
    batch: &ActivationBatch,
    weights: &AttentionWeights,
    run: Option<RunConfig>,
) -> anyhow::Result<Vec<Matrix>> {
    attention_block_registered_dtype(server, batch, weights, run, Dtype::F32)
}

/// [`attention_block_registered`] at a serving precision: every GEMM of
/// the block submits at `dtype`, so one registered batch and weight set
/// serve several precisions side by side — the registry caches one pack
/// per `(handle, S, dtype)` variant. `F32` is exactly the base entry
/// point (which delegates here).
pub fn attention_block_registered_dtype(
    server: &JobServer,
    batch: &ActivationBatch,
    weights: &AttentionWeights,
    run: Option<RunConfig>,
    dtype: Dtype,
) -> anyhow::Result<Vec<Matrix>> {
    anyhow::ensure!(
        batch.d_model == weights.d_model,
        "width mismatch: batch d_model = {}, weights d_model = {}",
        batch.d_model,
        weights.d_model
    );
    let xs =
        || -> Vec<AOperand> { batch.handles.iter().map(|&h| AOperand::from(h)).collect() };
    block_core(server, &xs, weights.handles().map(BOperand::from), batch.d_model, run, dtype)
}

/// The inline baseline: the same block over raw matrices — every
/// operand is re-packed on every call. Bit-identical to
/// [`attention_block_registered`] over the same inputs (identical
/// kernels over identical packed layouts; residency never changes
/// numerics).
pub fn attention_block_inline(
    server: &JobServer,
    xs: &[Matrix],
    wq: &Matrix,
    wk: &Matrix,
    wv: &Matrix,
    wo: &Matrix,
    run: Option<RunConfig>,
) -> anyhow::Result<Vec<Matrix>> {
    attention_block_inline_dtype(server, xs, wq, wk, wv, wo, run, Dtype::F32)
}

/// [`attention_block_inline`] at a serving precision (see
/// [`attention_block_registered_dtype`]).
#[allow(clippy::too_many_arguments)]
pub fn attention_block_inline_dtype(
    server: &JobServer,
    xs: &[Matrix],
    wq: &Matrix,
    wk: &Matrix,
    wv: &Matrix,
    wo: &Matrix,
    run: Option<RunConfig>,
    dtype: Dtype,
) -> anyhow::Result<Vec<Matrix>> {
    anyhow::ensure!(!xs.is_empty(), "empty batch");
    let (seq, d_model) = (xs[0].rows, xs[0].cols);
    anyhow::ensure!(seq > 0 && d_model > 0, "degenerate member {seq}x{d_model}");
    anyhow::ensure!(
        xs.iter().all(|x| (x.rows, x.cols) == (seq, d_model)),
        "batch members must share one shape"
    );
    for (name, w) in [("W_q", wq), ("W_k", wk), ("W_v", wv), ("W_o", wo)] {
        anyhow::ensure!(
            (w.rows, w.cols) == (d_model, d_model),
            "{name} is {}x{}, expected {d_model}x{d_model}",
            w.rows,
            w.cols
        );
    }
    let make_xs =
        || -> Vec<AOperand> { xs.iter().map(|x| AOperand::from(x.clone())).collect() };
    let ws = [wq, wk, wv, wo].map(|w| BOperand::from(w.clone()));
    block_core(server, &make_xs, ws, d_model, run, dtype)
}

/// The shared block body: batched Q/K/V projections, per-member scaled
/// `Q·Kᵀ`, host softmax, per-member `P·V`, batched O-projection.
/// `ws` is `[W_q, W_k, W_v, W_o]`, inline or registered.
fn block_core(
    server: &JobServer,
    make_xs: &dyn Fn() -> Vec<AOperand>,
    ws: [BOperand; 4],
    d_model: usize,
    run: Option<RunConfig>,
    dtype: Dtype,
) -> anyhow::Result<Vec<Matrix>> {
    let [wq, wk, wv, wo] = ws;

    // Q/K/V: three shared-B groups over the same activation batch,
    // all in flight before the first wait so the pool sees the whole
    // fan-out at once.
    server.trace_span_begin(SpanKind::AttentionPhase, 0);
    let gq = server.submit_async(Submission::batched(wq, make_xs()).run(run).dtype(dtype))?;
    let gk = server.submit_async(Submission::batched(wk, make_xs()).run(run).dtype(dtype))?;
    let gv = server.submit_async(Submission::batched(wv, make_xs()).run(run).dtype(dtype))?;
    let qs: Vec<Matrix> = gq.wait()?.into_iter().map(|r| r.c).collect();
    let ks: Vec<Matrix> = gk.wait()?.into_iter().map(|r| r.c).collect();
    let vs: Vec<Matrix> = gv.wait()?.into_iter().map(|r| r.c).collect();
    server.trace_span_end(SpanKind::AttentionPhase, 0);

    // Scores: one Q·Kᵀ job per member, submitted as a single group
    // (K differs per member, so there is no shared side to register).
    server.trace_span_begin(SpanKind::AttentionPhase, 1);
    let score_jobs: Vec<GemmJob> = qs
        .iter()
        .zip(&ks)
        .enumerate()
        .map(|(i, (q, k))| GemmJob {
            id: i as u64,
            a: q.clone().into(),
            b: k.transpose().into(),
            run,
        })
        .collect();
    let scores: Vec<Matrix> = server
        .submit_blocking(Submission::group(score_jobs).dtype(dtype))?
        .into_iter()
        .map(|r| r.c)
        .collect();

    // Attention probabilities: numerically stable scaled softmax on
    // the host (elementwise, O(seq²) — not GEMM traffic).
    let probs: Vec<Matrix> =
        scores.into_iter().map(|s| scaled_softmax_rows(s, d_model)).collect();

    // Context: one P·V job per member.
    let ctx_jobs: Vec<GemmJob> = probs
        .into_iter()
        .zip(vs)
        .enumerate()
        .map(|(i, (p, v))| GemmJob { id: i as u64, a: p.into(), b: v.into(), run })
        .collect();
    let ctxs: Vec<Matrix> = server
        .submit_blocking(Submission::group(ctx_jobs).dtype(dtype))?
        .into_iter()
        .map(|r| r.c)
        .collect();
    server.trace_span_end(SpanKind::AttentionPhase, 1);

    // Output projection: one shared-B group over the fresh contexts.
    server.trace_span_begin(SpanKind::AttentionPhase, 2);
    let go = server.submit_async(Submission::batched(wo, ctxs).run(run).dtype(dtype))?;
    let out = go.wait()?.into_iter().map(|r| r.c).collect();
    server.trace_span_end(SpanKind::AttentionPhase, 2);
    Ok(out)
}

/// Row-wise softmax of `scores / sqrt(d_model)`, max-subtracted for
/// stability (the standard online-safe formulation; every row sums to
/// 1 even when logits are large).
fn scaled_softmax_rows(mut scores: Matrix, d_model: usize) -> Matrix {
    let scale = 1.0 / (d_model as f32).sqrt();
    let cols = scores.cols;
    for row in scores.data.chunks_mut(cols) {
        let mut max = f32::NEG_INFINITY;
        for v in row.iter_mut() {
            *v *= scale;
            max = max.max(*v);
        }
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
    scores
}

/// Scalar reference for the whole block (host [`Matrix::matmul`] plus
/// the same softmax) — the `--check` oracle. Panics on shape mismatch;
/// validate through the serving entry points first.
pub fn attention_block_oracle(
    xs: &[Matrix],
    wq: &Matrix,
    wk: &Matrix,
    wv: &Matrix,
    wo: &Matrix,
) -> Vec<Matrix> {
    xs.iter()
        .map(|x| {
            let q = x.matmul(wq);
            let k = x.matmul(wk);
            let v = x.matmul(wv);
            let p = scaled_softmax_rows(q.matmul(&k.transpose()), wq.rows);
            p.matmul(&v).matmul(wo)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareConfig;
    use crate::coordinator::{NumericsEngine, ServerConfig};

    fn server() -> JobServer {
        let cfg = ServerConfig {
            workers: 4,
            queue_capacity: 16,
            batch_max_tasks: 4,
            batch_window: 4,
            cross_job_stealing: true,
            default_run: Some(RunConfig::square(2, 16)),
            ..ServerConfig::default()
        };
        JobServer::new(HardwareConfig::paper(), NumericsEngine::golden(), cfg).unwrap()
    }

    fn token_batch(batch: usize, seq: usize, d_model: usize, seed: u64) -> Vec<Matrix> {
        (0..batch as u64).map(|i| Matrix::random(seq, d_model, seed + i)).collect()
    }

    #[test]
    fn registered_block_is_bit_identical_to_inline_and_oracle_close() {
        let srv = server();
        let (d, seq) = (16, 13);
        let xs = token_batch(2, seq, d, 700);
        let wq = Matrix::random(d, d, 710);
        let wk = Matrix::random(d, d, 711);
        let wv = Matrix::random(d, d, 712);
        let wo = Matrix::random(d, d, 713);
        let run = Some(RunConfig::square(2, 16));
        let inline =
            attention_block_inline(&srv, &xs, &wq, &wk, &wv, &wo, run).unwrap();
        let weights = AttentionWeights::register(
            &srv,
            wq.clone(),
            wk.clone(),
            wv.clone(),
            wo.clone(),
        )
        .unwrap();
        let batch = ActivationBatch::register(&srv, &xs).unwrap();
        let reg = attention_block_registered(&srv, &batch, &weights, run).unwrap();
        assert_eq!(inline.len(), reg.len());
        for (a, b) in inline.iter().zip(&reg) {
            assert_eq!((b.rows, b.cols), (seq, d));
            assert_eq!(a.data, b.data, "residency must not change numerics");
        }
        let oracle = attention_block_oracle(&xs, &wq, &wk, &wv, &wo);
        for (o, b) in oracle.iter().zip(&reg) {
            assert!(o.allclose(b, 1e-3), "served block must match the scalar oracle");
        }
        batch.unregister(&srv).unwrap();
        weights.unregister(&srv).unwrap();
    }

    #[test]
    fn half_precision_block_tracks_oracle_and_packs_per_dtype_variant() {
        let srv = server();
        let (d, seq, members) = (16, 13, 2);
        let xs = token_batch(members, seq, d, 740);
        let wq = Matrix::random(d, d, 750);
        let wk = Matrix::random(d, d, 751);
        let wv = Matrix::random(d, d, 752);
        let wo = Matrix::random(d, d, 753);
        let run = Some(RunConfig::square(2, 16));
        let oracle = attention_block_oracle(&xs, &wq, &wk, &wv, &wo);
        let weights = AttentionWeights::register(
            &srv,
            wq.clone(),
            wk.clone(),
            wv.clone(),
            wo.clone(),
        )
        .unwrap();
        let batch = ActivationBatch::register(&srv, &xs).unwrap();
        // The explicit-F32 variant is the base entry point, bitwise.
        let base = attention_block_registered(&srv, &batch, &weights, run).unwrap();
        let f32v =
            attention_block_registered_dtype(&srv, &batch, &weights, run, Dtype::F32)
                .unwrap();
        for (a, b) in base.iter().zip(&f32v) {
            assert_eq!(a.data, b.data, "explicit F32 must be the default path");
        }
        // Half-precision serving of the same registered operands stays
        // close to the scalar oracle: five chained GEMMs, with the
        // softmax renormalizing between them, so the loss is a few
        // units of the per-GEMM bound (k·u ≈ 8e-3 f16 / 6e-2 bf16).
        for (dtype, tol) in [(Dtype::F16, 5e-2), (Dtype::Bf16, 3e-1)] {
            let out =
                attention_block_registered_dtype(&srv, &batch, &weights, run, dtype)
                    .unwrap();
            for (o, b) in oracle.iter().zip(&out) {
                assert!(o.allclose(b, tol), "{dtype} block must track the oracle");
            }
        }
        // Registered operands pack once per (handle, S, dtype) variant:
        // three serving dtypes touched the same members and weights.
        let m = srv.metrics();
        assert_eq!(m.registry_a_misses(), 3 * members as u64);
        assert_eq!(m.registry_misses(), (3 * (members + 4)) as u64);
        batch.unregister(&srv).unwrap();
        weights.unregister(&srv).unwrap();
    }

    #[test]
    fn repeated_registered_runs_pack_each_operand_exactly_once() {
        // The ISSUE's acceptance criterion: N runs over one registered
        // batch = 1 A-pack per (member, S_i) variant and 1 B-pack per
        // weight variant, while the inline baseline re-packs every
        // operand every run.
        let srv = server();
        let (d, seq, members) = (16, 12, 3);
        let xs = token_batch(members, seq, d, 720);
        let weights = AttentionWeights::random(&srv, d, 730).unwrap();
        let batch = ActivationBatch::register(&srv, &xs).unwrap();
        let run = Some(RunConfig::square(2, 16));
        let n_runs = 3;
        let mut outs = Vec::new();
        for _ in 0..n_runs {
            outs.push(attention_block_registered(&srv, &batch, &weights, run).unwrap());
        }
        for later in &outs[1..] {
            for (a, b) in outs[0].iter().zip(later) {
                assert_eq!(a.data, b.data, "repeat runs must be bit-identical");
            }
        }
        let m = srv.metrics();
        // A side: each member packs once for the X·W projections (all
        // three resolve the same (handle, S_i) pack). The per-run
        // Q·Kᵀ / P·V / O-projection A operands are fresh matrices and
        // pack privately: 3 members x 3 ephemeral GEMM stages x runs.
        assert_eq!(m.registry_a_misses(), members as u64, "one A-pack per member, ever");
        assert_eq!(
            m.registry_a_hits(),
            (3 * n_runs - 1) as u64 * members as u64,
            "every later projection is a cache hit"
        );
        assert_eq!(
            m.a_panel_packs(),
            (members + members * 3 * n_runs) as u64,
            "registered packs + per-run ephemeral (scores/ctx/O) packs only"
        );
        // B side: the four weights pack once ever; the per-run Kᵀ and
        // V leaf operands are fresh each run.
        assert_eq!(m.registry_misses(), (members + 4) as u64);
        let stats = srv.stats();
        assert_eq!(stats.registered_weights, 4);
        assert_eq!(stats.registered_activations, members);
        assert!(stats.registry_a_resident_bytes > 0);
        batch.unregister(&srv).unwrap();
        weights.unregister(&srv).unwrap();
        let after = srv.stats();
        assert_eq!((after.registered_weights, after.registered_activations), (0, 0));
        assert_eq!(after.registry_a_resident_bytes, 0);
    }

    #[test]
    fn shape_validation_rejects_mismatches() {
        let srv = server();
        // Non-square / mismatched weights.
        assert!(AttentionWeights::register(
            &srv,
            Matrix::random(8, 8, 1),
            Matrix::random(8, 8, 2),
            Matrix::random(8, 4, 3),
            Matrix::random(8, 8, 4),
        )
        .is_err());
        assert_eq!(srv.stats().registered_weights, 0, "partial failure must not leak");
        // Ragged / empty activation batches.
        assert!(ActivationBatch::register(&srv, &[]).is_err());
        let ragged = vec![Matrix::random(4, 8, 5), Matrix::random(5, 8, 6)];
        assert!(ActivationBatch::register(&srv, &ragged).is_err());
        // Width mismatch between a valid batch and valid weights.
        let weights = AttentionWeights::random(&srv, 8, 7).unwrap();
        let batch =
            ActivationBatch::register(&srv, &token_batch(1, 4, 16, 8)).unwrap();
        assert!(attention_block_registered(&srv, &batch, &weights, None).is_err());
        batch.unregister(&srv).unwrap();
        weights.unregister(&srv).unwrap();
        // Inline path validates too.
        let w = Matrix::random(8, 8, 9);
        assert!(attention_block_inline(&srv, &[], &w, &w, &w, &w, None).is_err());
    }
}
