//! Bank/row-state DDR simulation and the effective-bandwidth measurement
//! used to calibrate the analytical model's `BW = f(N_p, S_i)` surface.

use super::DdrConfig;

/// How a master's addresses advance between chunks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamPattern {
    /// Fully sequential stream (transposed A, rows of B): each chunk
    /// continues where the previous one ended.
    Sequential,
    /// Strided stream (untransposed A, column-major access of a row-major
    /// matrix): each chunk starts `stride_bytes` past the previous chunk's
    /// start. This is the access pattern the MAC's transpose eliminates.
    Strided { stride_bytes: usize },
}

/// Result of a bandwidth measurement run.
#[derive(Debug, Clone, Copy)]
pub struct BandwidthPoint {
    /// Per-master effective bandwidth, bytes/second.
    pub per_master: f64,
    /// Aggregate effective bandwidth across all masters, bytes/second.
    pub aggregate: f64,
    /// Fraction of clocks spent moving data (bus utilization).
    pub utilization: f64,
    /// Row-buffer hit rate over all bursts.
    pub row_hit_rate: f64,
}

impl BandwidthPoint {
    pub fn per_master_gbps(&self) -> f64 {
        self.per_master / 1e9
    }
    pub fn aggregate_gbps(&self) -> f64 {
        self.aggregate / 1e9
    }
}

/// Cycle-cost DDR model: per-channel, per-bank open-row state with
/// burst-granular timing. Channels have independent buses and timelines;
/// elapsed time is the busiest channel's clock.
#[derive(Debug, Clone)]
pub struct DdrSim {
    cfg: DdrConfig,
    /// Open row per (channel, bank) (`None` = precharged/idle).
    open_rows: Vec<Option<u64>>,
    /// Controller clocks elapsed per channel.
    channel_clocks: Vec<u64>,
    /// Clocks spent on data beats (for utilization accounting).
    data_clocks: u64,
    bursts: u64,
    row_hits: u64,
}

impl DdrSim {
    pub fn new(cfg: DdrConfig) -> Self {
        let slots = cfg.banks * cfg.channels;
        let channels = cfg.channels;
        Self {
            cfg,
            open_rows: vec![None; slots],
            channel_clocks: vec![0; channels],
            data_clocks: 0,
            bursts: 0,
            row_hits: 0,
        }
    }

    pub fn config(&self) -> &DdrConfig {
        &self.cfg
    }

    /// Busiest channel's clock — the wall-clock of the memory system.
    pub fn clocks(&self) -> u64 {
        self.channel_clocks.iter().copied().max().unwrap_or(0)
    }

    fn channel_bank_row(&self, addr: u64) -> (usize, usize, u64) {
        // Sequential addresses fill a row, stripe to the next channel,
        // then move to the next bank (bank/channel-interleaved mapping,
        // the MIG default for streams).
        let row_unit = addr / self.cfg.row_bytes as u64;
        let channel = (row_unit % self.cfg.channels as u64) as usize;
        let bank_unit = row_unit / self.cfg.channels as u64;
        let bank = (bank_unit % self.cfg.banks as u64) as usize;
        let row = bank_unit / self.cfg.banks as u64;
        (channel, bank, row)
    }

    /// Issue one burst at `addr`; returns clocks consumed on its channel.
    fn burst(&mut self, addr: u64) -> u64 {
        let (channel, bank, row) = self.channel_bank_row(addr);
        let slot = channel * self.cfg.banks + bank;
        self.bursts += 1;
        let mut cost = self.cfg.burst_clocks();
        match self.open_rows[slot] {
            Some(open) if open == row => {
                // Row hit: data beats only (CAS pipelined with the
                // previous burst in a stream).
                self.row_hits += 1;
            }
            Some(_) => {
                // Conflict: precharge the open row, activate, CAS.
                cost += self.cfg.t_rp + self.cfg.t_rcd + self.cfg.t_cl;
                self.open_rows[slot] = Some(row);
            }
            None => {
                // Page empty: activate + CAS.
                cost += self.cfg.t_rcd + self.cfg.t_cl;
                self.open_rows[slot] = Some(row);
            }
        }
        self.data_clocks += self.cfg.burst_clocks();
        self.channel_clocks[channel] += cost;
        cost
    }

    /// Transfer `bytes` starting at `addr` as a run of bursts; returns
    /// clocks consumed (including the per-request controller overhead).
    pub fn transfer(&mut self, addr: u64, bytes: usize) -> u64 {
        let bb = self.cfg.burst_bytes() as u64;
        // Align down; partial leading/trailing bursts still move a full
        // burst on the bus (the over-fetch the paper's MAC avoids by
        // sizing BZ to burst multiples).
        let start = addr / bb * bb;
        let end = addr + bytes as u64;
        let (first_ch, _, _) = self.channel_bank_row(start);
        self.channel_clocks[first_ch] += self.cfg.req_overhead;
        let mut cost = self.cfg.req_overhead;
        let mut a = start;
        while a < end {
            cost += self.burst(a);
            a += bb;
        }
        cost
    }

    pub fn row_hit_rate(&self) -> f64 {
        if self.bursts == 0 {
            return 0.0;
        }
        self.row_hits as f64 / self.bursts as f64
    }

    pub fn utilization(&self) -> f64 {
        let total: u64 = self.channel_clocks.iter().sum();
        if total == 0 {
            return 0.0;
        }
        self.data_clocks as f64 / total as f64
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.clocks() as f64 * self.cfg.clock_period()
    }

    /// Measure steady-state effective bandwidth for `np` masters that each
    /// stream `chunks_per_master` chunks of `chunk_bytes`, arbitrated
    /// round-robin at chunk granularity — the Fig. 3 experiment.
    pub fn measure_stream(
        cfg: &DdrConfig,
        np: usize,
        chunk_bytes: usize,
        chunks_per_master: usize,
        pattern: StreamPattern,
    ) -> BandwidthPoint {
        assert!(np >= 1 && chunk_bytes > 0 && chunks_per_master > 0);
        let mut sim = DdrSim::new(cfg.clone());
        // Masters stream from disjoint 256 MiB regions, as the MAC
        // allocates one matrix region per array.
        let region = 256u64 << 20;
        let mut cursors: Vec<u64> = (0..np).map(|m| m as u64 * region).collect();
        for _ in 0..chunks_per_master {
            for cursor in cursors.iter_mut() {
                sim.transfer(*cursor, chunk_bytes);
                match pattern {
                    StreamPattern::Sequential => *cursor += chunk_bytes as u64,
                    StreamPattern::Strided { stride_bytes } => {
                        *cursor += stride_bytes as u64
                    }
                }
            }
        }
        let total_bytes = (np * chunk_bytes * chunks_per_master) as f64;
        let secs = sim.elapsed_secs();
        let aggregate = total_bytes / secs;
        BandwidthPoint {
            per_master: aggregate / np as f64,
            aggregate,
            utilization: sim.utilization(),
            row_hit_rate: sim.row_hit_rate(),
        }
    }

    /// Effective per-array bandwidth (bytes/s) for a block size `si` with
    /// `np` active arrays — the `BW = f(N_p, S_i)` of Eq. 8. The chunk is
    /// one block-row/column: `si` FP32 elements, contiguous thanks to the
    /// MAC's transpose of A.
    pub fn block_bandwidth(cfg: &DdrConfig, np: usize, si: usize) -> BandwidthPoint {
        let chunk = si * 4;
        // Enough chunks to reach steady state and wrap several rows.
        let chunks = (64 * cfg.row_bytes / chunk.max(1)).clamp(256, 65_536);
        Self::measure_stream(cfg, np, chunk, chunks, StreamPattern::Sequential)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> DdrConfig {
        DdrConfig::vc709()
    }

    #[test]
    fn single_burst_costs_activate_cas_data() {
        let mut sim = DdrSim::new(cfg());
        let c = sim.transfer(0, 64);
        assert_eq!(c, 4 + 11 + 11 + 4); // overhead + tRCD + tCL + data
    }

    #[test]
    fn open_row_streaming_costs_data_only() {
        let mut sim = DdrSim::new(cfg());
        sim.transfer(0, 64);
        let before = sim.clocks();
        sim.transfer(64, 64);
        // Second burst in the same row: req overhead + data beats.
        assert_eq!(sim.clocks() - before, 4 + 4);
        assert!(sim.row_hit_rate() > 0.4);
    }

    #[test]
    fn row_conflict_pays_precharge() {
        let c = cfg();
        let row_span = (c.row_bytes * c.banks) as u64; // same bank, next row
        let mut sim = DdrSim::new(c);
        sim.transfer(0, 64);
        let before = sim.clocks();
        sim.transfer(row_span, 64);
        assert_eq!(sim.clocks() - before, 4 + 11 + 11 + 11 + 4);
    }

    #[test]
    fn bandwidth_rises_with_block_size() {
        // Fig. 3, observation 1.
        let c = cfg();
        let small = DdrSim::block_bandwidth(&c, 2, 16).per_master;
        let mid = DdrSim::block_bandwidth(&c, 2, 64).per_master;
        let large = DdrSim::block_bandwidth(&c, 2, 256).per_master;
        assert!(small < mid, "{small} !< {mid}");
        assert!(mid < large, "{mid} !< {large}");
    }

    #[test]
    fn bandwidth_falls_with_more_arrays() {
        // Fig. 3, observation 2.
        let c = cfg();
        for si in [16usize, 64, 256] {
            let b1 = DdrSim::block_bandwidth(&c, 1, si).per_master;
            let b2 = DdrSim::block_bandwidth(&c, 2, si).per_master;
            let b4 = DdrSim::block_bandwidth(&c, 4, si).per_master;
            assert!(b1 > b2, "si={si}: {b1} !> {b2}");
            assert!(b2 > b4, "si={si}: {b2} !> {b4}");
        }
    }

    #[test]
    fn aggregate_never_exceeds_peak() {
        let c = cfg();
        for np in [1, 2, 4] {
            for si in [16, 32, 128, 512] {
                let p = DdrSim::block_bandwidth(&c, np, si);
                assert!(p.aggregate <= c.peak_bytes_per_sec() * 1.0001);
            }
        }
    }

    #[test]
    fn strided_slower_than_sequential() {
        // The transpose-of-A rationale (Section III-C): column-major
        // access of row-major A touches a new region every element run.
        let c = cfg();
        let seq =
            DdrSim::measure_stream(&c, 1, 64, 4096, StreamPattern::Sequential);
        let strided = DdrSim::measure_stream(
            &c,
            1,
            64,
            4096,
            StreamPattern::Strided { stride_bytes: 4096 * 4 },
        );
        assert!(seq.per_master > 1.5 * strided.per_master);
    }

    #[test]
    fn utilization_bounded() {
        let c = cfg();
        let p = DdrSim::block_bandwidth(&c, 1, 256);
        assert!(p.utilization > 0.0 && p.utilization <= 1.0);
        assert!(p.row_hit_rate >= 0.0 && p.row_hit_rate <= 1.0);
    }

    #[test]
    fn dual_channel_raises_single_master_bandwidth() {
        let single = DdrSim::block_bandwidth(&DdrConfig::vc709(), 1, 256);
        let dual = DdrSim::block_bandwidth(&DdrConfig::vc709_dual(), 1, 256);
        assert!(
            dual.per_master > 1.5 * single.per_master,
            "dual {} vs single {}",
            dual.per_master,
            single.per_master
        );
        assert!(dual.aggregate <= DdrConfig::vc709_dual().peak_bytes_per_sec() * 1.0001);
    }

    #[test]
    fn dual_channel_preserves_contention_ratio() {
        // With row-striped mapping every master streams through every
        // channel, so adding a channel scales bandwidth ~uniformly and
        // the N_p contention *ratio* is preserved (to soften it one
        // would assign masters to channels instead — a different MAC).
        let penalty = |c: &DdrConfig| {
            DdrSim::block_bandwidth(c, 1, 128).per_master
                / DdrSim::block_bandwidth(c, 4, 128).per_master
        };
        let single = penalty(&DdrConfig::vc709());
        let dual = penalty(&DdrConfig::vc709_dual());
        assert!(
            (dual - single).abs() / single < 0.05,
            "ratio changed: dual {dual} vs single {single}"
        );
    }

    #[test]
    fn dual_channel_preserves_fig3_shape() {
        let c = DdrConfig::vc709_dual();
        for np in [1, 2, 4] {
            assert!(
                DdrSim::block_bandwidth(&c, np, 32).per_master
                    < DdrSim::block_bandwidth(&c, np, 256).per_master
            );
        }
        for si in [32, 256] {
            assert!(
                DdrSim::block_bandwidth(&c, 1, si).per_master
                    > DdrSim::block_bandwidth(&c, 4, si).per_master
            );
        }
    }
}
