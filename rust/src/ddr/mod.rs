//! Off-chip DDR3 timing model — the substrate behind Fig. 3.
//!
//! The paper measures the *effective* memory bandwidth a PE array sees as
//! a function of block size (`S_i`, which sets the burst length of each
//! transfer) and the number of arrays sharing the memory interface
//! (`N_p`, which sets how often streams from different address regions
//! interleave and evict each other's open DRAM rows). We reproduce that
//! surface with a bank/row-state DDR3 model:
//!
//! * data moves in fixed BL8 bursts (`burst_bytes` per `burst_clocks`);
//! * a burst that hits the open row of its bank costs only data beats;
//! * a burst to a different row pays precharge + activate + CAS
//!   (`t_rp + t_rcd + t_cl`);
//! * `N_p` masters stream from disjoint regions and are arbitrated
//!   round-robin at *chunk* granularity (one chunk = one contiguous
//!   block-row/column of `S_i` elements — the unit a buffer descriptor
//!   transfers), so small blocks force a row miss on nearly every
//!   arbitration turn while large blocks amortize it.
//!
//! The two observations of Fig. 3 fall out: effective bandwidth rises
//! with block size and falls as arrays are added.

pub mod sim;

pub use sim::{BandwidthPoint, DdrSim, StreamPattern};


/// DDR3 channel parameters (defaults model the VC709's DDR3-1600 SODIMM).
#[derive(Debug, Clone, PartialEq)]
pub struct DdrConfig {
    /// Memory controller clock in MHz (DDR3-1600: 800 MHz, 2 transfers/clk).
    pub mem_clock_mhz: f64,
    /// Data bus width in bytes (64-bit DIMM = 8).
    pub bus_bytes: usize,
    /// Banks per rank.
    pub banks: usize,
    /// Row (page) size in bytes.
    pub row_bytes: usize,
    /// Row-to-column delay (activate), controller clocks.
    pub t_rcd: u64,
    /// Row precharge, controller clocks.
    pub t_rp: u64,
    /// CAS latency, controller clocks.
    pub t_cl: u64,
    /// Burst length in bus transfers (BL8).
    pub burst_transfers: usize,
    /// Fixed controller/arbitration overhead per chunk request, clocks.
    pub req_overhead: u64,
    /// Independent DDR channels (the VC709 carries two SODIMMs). Rows
    /// stripe across channels; transfers on different channels overlap
    /// in time, so peak bandwidth scales with this.
    pub channels: usize,
}

impl Default for DdrConfig {
    fn default() -> Self {
        Self::vc709()
    }
}

impl DdrConfig {
    /// One DDR3-1600 channel of the VC709 (MIG defaults, 11-11-11).
    /// Single-channel is the calibration default — it reproduces the
    /// Fig. 3 *shape* most clearly; see [`Self::vc709_dual`].
    pub fn vc709() -> Self {
        Self {
            mem_clock_mhz: 800.0,
            bus_bytes: 8,
            banks: 8,
            row_bytes: 8192,
            t_rcd: 11,
            t_rp: 11,
            t_cl: 11,
            burst_transfers: 8,
            req_overhead: 4,
            channels: 1,
        }
    }

    /// Both VC709 SODIMMs: rows stripe across two independent channels,
    /// doubling peak bandwidth. The N_p contention *ratio* is preserved
    /// under striping (every master touches every channel); see the
    /// channel ablation bench.
    pub fn vc709_dual() -> Self {
        Self { channels: 2, ..Self::vc709() }
    }

    /// Bytes moved by one burst (BL8 x bus width).
    pub fn burst_bytes(&self) -> usize {
        self.bus_bytes * self.burst_transfers
    }

    /// Controller clocks of pure data transfer per burst (2 transfers/clk).
    pub fn burst_clocks(&self) -> u64 {
        (self.burst_transfers / 2).max(1) as u64
    }

    /// Theoretical peak bandwidth in bytes/second (all channels).
    pub fn peak_bytes_per_sec(&self) -> f64 {
        self.mem_clock_mhz * 1e6 * 2.0 * self.bus_bytes as f64 * self.channels as f64
    }

    /// Theoretical peak in GB/s.
    pub fn peak_gbps(&self) -> f64 {
        self.peak_bytes_per_sec() / 1e9
    }

    /// Seconds per controller clock.
    pub fn clock_period(&self) -> f64 {
        1.0 / (self.mem_clock_mhz * 1e6)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.mem_clock_mhz > 0.0, "mem clock must be positive");
        anyhow::ensure!(self.bus_bytes > 0, "bus width must be positive");
        anyhow::ensure!(self.banks.is_power_of_two(), "banks must be 2^k");
        anyhow::ensure!(
            self.row_bytes >= self.burst_bytes(),
            "row must hold at least one burst"
        );
        anyhow::ensure!(self.burst_transfers >= 2, "burst must be >= 2 transfers");
        anyhow::ensure!(self.channels >= 1, "need at least one channel");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vc709_peak_is_12_8_gbps() {
        let c = DdrConfig::vc709();
        assert!((c.peak_gbps() - 12.8).abs() < 1e-9);
        c.validate().unwrap();
    }

    #[test]
    fn burst_geometry() {
        let c = DdrConfig::vc709();
        assert_eq!(c.burst_bytes(), 64);
        assert_eq!(c.burst_clocks(), 4);
        assert_eq!(c.row_bytes / c.burst_bytes(), 128);
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = DdrConfig::vc709();
        c.banks = 3;
        assert!(c.validate().is_err());
        let mut c = DdrConfig::vc709();
        c.row_bytes = 16;
        assert!(c.validate().is_err());
    }
}
