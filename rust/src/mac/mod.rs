//! Memory Access Controller — Section III-C.
//!
//! The MAC turns each sub-block task into three buffer-descriptor-driven
//! DMA transfers against the DDR model: load `SA_i` (from the transposed
//! copy of A, so columns are contiguous), load `SB_j`, and write back
//! `C_ij`. A descriptor carries exactly the fields the paper lists:
//! `ADDR` (base of the sub-matrix), `STR` (stride between consecutive
//! block rows), `BZ` (block size) and `ITER_K` (the contraction depth).


use crate::blocking::BlockTask;
use crate::ddr::{DdrConfig, DdrSim};

/// The paper's self-defined workload descriptor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BufferDescriptor {
    /// Byte address of the first element of the sub-matrix.
    pub addr: u64,
    /// Byte stride between consecutive rows of the transfer.
    pub stride: u64,
    /// Bytes per contiguous row of the transfer (derived from BZ).
    pub row_bytes: usize,
    /// Number of rows (ITER_K for the input panels, S_i for C).
    pub rows: usize,
}

impl BufferDescriptor {
    pub fn total_bytes(&self) -> u64 {
        self.row_bytes as u64 * self.rows as u64
    }
}

/// Memory layout of one GEMM problem in DDR address space.
#[derive(Debug, Clone, Copy)]
pub struct ProblemLayout {
    /// Base of the transposed A (K x M, row-major — so a *column* of the
    /// original A is a contiguous run).
    pub a_t_base: u64,
    /// Base of B (K x N, row-major).
    pub b_base: u64,
    /// Base of C (M x N, row-major).
    pub c_base: u64,
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// Bytes per element (FP32 = 4).
    pub elem: usize,
}

impl ProblemLayout {
    /// Pack A^T, B, C back-to-back from `base`, each region row-aligned
    /// to the DDR burst so descriptors start on burst boundaries.
    pub fn contiguous(base: u64, m: usize, k: usize, n: usize, elem: usize) -> Self {
        let align = |x: u64| x.div_ceil(4096) * 4096;
        let a_t_base = align(base);
        let b_base = align(a_t_base + (k * m * elem) as u64);
        let c_base = align(b_base + (k * n * elem) as u64);
        Self { a_t_base, b_base, c_base, m, k, n, elem }
    }

    /// Descriptor for loading `SA_i` of `task`: K rows (one per k) of
    /// `S_i` contiguous elements out of A^T — burst-friendly *because of*
    /// the transpose. Without it this would be `S_i * K` single-element
    /// strided reads (see [`Mac::untransposed_a_descriptor`]).
    pub fn sa_descriptor(&self, task: &BlockTask) -> BufferDescriptor {
        BufferDescriptor {
            addr: self.a_t_base + (task.row0 * self.elem) as u64,
            stride: (self.m * self.elem) as u64,
            row_bytes: task.si * self.elem,
            rows: self.k,
        }
    }

    /// Descriptor for loading `SB_j`: K rows of `S_j` contiguous elements.
    pub fn sb_descriptor(&self, task: &BlockTask) -> BufferDescriptor {
        BufferDescriptor {
            addr: self.b_base + (task.col0 * self.elem) as u64,
            stride: (self.n * self.elem) as u64,
            row_bytes: task.sj * self.elem,
            rows: self.k,
        }
    }

    /// Descriptor for writing back `C_ij`: S_i rows of S_j elements.
    pub fn c_descriptor(&self, task: &BlockTask) -> BufferDescriptor {
        BufferDescriptor {
            addr: self.c_base + ((task.row0 * self.n + task.col0) * self.elem) as u64,
            stride: (self.n * self.elem) as u64,
            row_bytes: task.sj * self.elem,
            rows: task.si,
        }
    }

    /// The access pattern the transpose *avoids*: fetching a column of
    /// row-major A = `S_i * K` reads of one element, each `N` elements
    /// apart. Exposed for the ablation bench.
    pub fn untransposed_a_descriptor(&self, task: &BlockTask) -> BufferDescriptor {
        BufferDescriptor {
            addr: self.a_t_base + (task.row0 * self.k * self.elem) as u64,
            stride: (self.k * self.elem) as u64,
            row_bytes: self.elem, // one element per "row" of the transfer
            rows: task.si * self.k,
        }
    }
}

/// Timing result of moving one task's data.
#[derive(Debug, Clone, Copy)]
pub struct TaskTransfer {
    pub load_clocks: u64,
    pub store_clocks: u64,
    pub bytes: u64,
}

impl TaskTransfer {
    pub fn total_clocks(&self) -> u64 {
        self.load_clocks + self.store_clocks
    }
    pub fn seconds(&self, ddr: &DdrConfig) -> f64 {
        self.total_clocks() as f64 * ddr.clock_period()
    }
}

/// The MAC engine: executes descriptors against a DDR simulation.
#[derive(Debug)]
pub struct Mac {
    sim: DdrSim,
}

impl Mac {
    pub fn new(cfg: DdrConfig) -> Self {
        Self { sim: DdrSim::new(cfg) }
    }

    pub fn ddr(&self) -> &DdrSim {
        &self.sim
    }

    /// Run one descriptor: `rows` transfers of `row_bytes` at `stride`.
    pub fn run_descriptor(&mut self, d: &BufferDescriptor) -> u64 {
        let mut clocks = 0;
        let mut addr = d.addr;
        for _ in 0..d.rows {
            clocks += self.sim.transfer(addr, d.row_bytes);
            addr += d.stride;
        }
        clocks
    }

    /// Move one task's data (Eq. 4's byte count, timed by the DDR model):
    /// load SA_i and SB_j, then write back C_ij.
    pub fn transfer_task(&mut self, layout: &ProblemLayout, task: &BlockTask) -> TaskTransfer {
        let sa = layout.sa_descriptor(task);
        let sb = layout.sb_descriptor(task);
        let c = layout.c_descriptor(task);
        let load_clocks = self.run_descriptor(&sa) + self.run_descriptor(&sb);
        let store_clocks = self.run_descriptor(&c);
        TaskTransfer {
            load_clocks,
            store_clocks,
            bytes: sa.total_bytes() + sb.total_bytes() + c.total_bytes(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocking::BlockPlan;

    fn layout() -> ProblemLayout {
        ProblemLayout::contiguous(0, 128, 1200, 729, 4)
    }

    fn task0() -> BlockTask {
        BlockPlan::new(128, 1200, 729, 128, 128).task(0)
    }

    #[test]
    fn descriptor_bytes_match_eq4() {
        let l = layout();
        let t = task0();
        let total = l.sa_descriptor(&t).total_bytes()
            + l.sb_descriptor(&t).total_bytes()
            + l.c_descriptor(&t).total_bytes();
        assert_eq!(total, t.bytes_moved());
    }

    #[test]
    fn regions_do_not_overlap() {
        let l = layout();
        assert!(l.a_t_base + (l.k * l.m * l.elem) as u64 <= l.b_base);
        assert!(l.b_base + (l.k * l.n * l.elem) as u64 <= l.c_base);
    }

    #[test]
    fn sa_descriptor_is_burst_friendly() {
        let l = layout();
        let d = l.sa_descriptor(&task0());
        assert_eq!(d.row_bytes, 128 * 4); // a full block-column, contiguous
        assert_eq!(d.rows, 1200);
    }

    #[test]
    fn transposed_load_beats_untransposed() {
        // The Section III-C claim: transposing A significantly improves
        // effective bandwidth.
        let l = layout();
        let t = task0();
        let mut mac = Mac::new(DdrConfig::vc709());
        let good = mac.run_descriptor(&l.sa_descriptor(&t));
        let mut mac = Mac::new(DdrConfig::vc709());
        let bad = mac.run_descriptor(&l.untransposed_a_descriptor(&t));
        assert!(
            bad > 4 * good,
            "untransposed ({bad} clk) should be >4x transposed ({good} clk)"
        );
    }

    #[test]
    fn transfer_task_accounts_all_bytes() {
        let l = layout();
        let t = task0();
        let mut mac = Mac::new(DdrConfig::vc709());
        let tr = mac.transfer_task(&l, &t);
        assert_eq!(tr.bytes, t.bytes_moved());
        assert!(tr.load_clocks > 0 && tr.store_clocks > 0);
    }

    #[test]
    fn full_problem_transfer_matches_plan_bytes() {
        // Moving every task moves exactly the plan's Eq. 4/5 total.
        let plan = BlockPlan::new(64, 100, 96, 32, 32);
        let l = ProblemLayout::contiguous(0, 64, 100, 96, 4);
        let mut mac = Mac::new(DdrConfig::vc709());
        let total: u64 = plan.tasks().map(|t| mac.transfer_task(&l, &t).bytes).sum();
        assert_eq!(total, plan.total_bytes());
    }

    #[test]
    fn sb_descriptor_walks_rows_of_b() {
        let l = layout();
        let t = BlockPlan::new(128, 1200, 729, 128, 128).task(1); // bj = 1
        let d = l.sb_descriptor(&t);
        assert_eq!(d.addr, l.b_base + 128 * 4); // col0 = 128
        assert_eq!(d.stride, (729 * 4) as u64);
        assert_eq!(d.rows, 1200);
    }

    #[test]
    fn larger_blocks_transfer_more_efficiently() {
        // Clocks per byte falls with block size — Fig. 3 at the MAC level.
        let eff = |si: usize| {
            let plan = BlockPlan::new(256, 512, 256, si, si);
            let l = ProblemLayout::contiguous(0, 256, 512, 256, 4);
            let t = plan.task(0);
            let mut mac = Mac::new(DdrConfig::vc709());
            let tr = mac.transfer_task(&l, &t);
            tr.total_clocks() as f64 / tr.bytes as f64
        };
        assert!(eff(128) < eff(32));
        assert!(eff(32) < eff(8));
    }

    #[test]
    fn edge_task_descriptors_stay_in_region() {
        let plan = BlockPlan::new(100, 50, 90, 64, 64);
        let l = ProblemLayout::contiguous(1 << 20, 100, 50, 90, 4);
        let t = plan.task(plan.num_tasks() - 1);
        let d = l.c_descriptor(&t);
        // Padded block extends past N in elements but descriptor bounds
        // are computed from the padded BZ; the store region is sized for
        // padded C in the simulator's address map.
        assert!(d.addr >= l.c_base);
    }
}
