//! Minimal criterion-style benchmark harness for `harness = false`
//! benches: warmup, timed iterations, mean / median / p95 / min, and an
//! optional throughput line. Honors `MARR_BENCH_QUICK=1` for CI-speed
//! runs.

use std::time::{Duration, Instant};

/// One benchmark group/runner.
pub struct Bench {
    name: String,
    warmup_iters: usize,
    samples: usize,
}

/// Summary statistics of one benchmark.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub mean: Duration,
    pub median: Duration,
    pub p95: Duration,
    pub min: Duration,
    pub samples: usize,
}

impl Bench {
    pub fn new(name: impl Into<String>) -> Self {
        let quick = std::env::var("MARR_BENCH_QUICK").is_ok();
        Self {
            name: name.into(),
            warmup_iters: if quick { 1 } else { 3 },
            samples: if quick { 5 } else { 30 },
        }
    }

    pub fn samples(mut self, n: usize) -> Self {
        self.samples = n;
        self
    }

    /// Time `f`, print a report line, return the stats.
    pub fn run<T>(&self, label: &str, mut f: impl FnMut() -> T) -> Stats {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut times: Vec<Duration> = (0..self.samples)
            .map(|_| {
                let t0 = Instant::now();
                std::hint::black_box(f());
                t0.elapsed()
            })
            .collect();
        times.sort_unstable();
        let total: Duration = times.iter().sum();
        let stats = Stats {
            mean: total / times.len() as u32,
            median: times[times.len() / 2],
            p95: times[(times.len() * 95 / 100).min(times.len() - 1)],
            min: times[0],
            samples: times.len(),
        };
        println!(
            "bench {}/{label:<32} mean {:>12} median {:>12} p95 {:>12} min {:>12} (n={})",
            self.name,
            fmt(stats.mean),
            fmt(stats.median),
            fmt(stats.p95),
            fmt(stats.min),
            stats.samples
        );
        stats
    }

    /// Like [`run`], also printing elements/second throughput.
    pub fn run_throughput<T>(
        &self,
        label: &str,
        elements: u64,
        f: impl FnMut() -> T,
    ) -> Stats {
        let stats = self.run(label, f);
        let per_sec = elements as f64 / stats.median.as_secs_f64();
        println!(
            "bench {}/{label:<32} throughput {:.3e} elem/s",
            self.name, per_sec
        );
        stats
    }
}

fn fmt(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let b = Bench::new("test").samples(10);
        let s = b.run("noop", || 1 + 1);
        assert!(s.min <= s.median && s.median <= s.p95);
        assert_eq!(s.samples, 10);
    }

    #[test]
    fn fmt_scales() {
        assert!(fmt(Duration::from_nanos(12)).contains("ns"));
        assert!(fmt(Duration::from_micros(12)).contains("µs"));
        assert!(fmt(Duration::from_millis(12)).contains("ms"));
        assert!(fmt(Duration::from_secs(2)).contains(" s"));
    }
}
