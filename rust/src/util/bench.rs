//! Minimal criterion-style benchmark harness for `harness = false`
//! benches: warmup, timed iterations, mean / median / p95 / min, an
//! optional throughput line, and a JSON report ([`Bench::write_json`])
//! so runs leave a machine-readable artifact (`BENCH_hotpath.json`).
//! Honors `MARR_BENCH_QUICK=1` for CI-speed runs.

use std::cell::RefCell;
use std::path::Path;
use std::time::{Duration, Instant};

/// One benchmark group/runner. Results accumulate internally so a bench
/// binary can dump everything it measured as JSON at exit.
pub struct Bench {
    name: String,
    warmup_iters: usize,
    samples: usize,
    records: RefCell<Vec<Record>>,
}

/// Summary statistics of one benchmark.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub mean: Duration,
    pub median: Duration,
    pub p95: Duration,
    pub min: Duration,
    pub samples: usize,
}

struct Record {
    label: String,
    stats: Stats,
    /// Elements per iteration, when the caller declared a throughput.
    elements: Option<u64>,
    /// Caller-attached named metrics (e.g. a measured idle fraction),
    /// emitted as extra JSON fields on the record.
    extras: Vec<(String, f64)>,
    /// Caller-attached named string tags (e.g. the serving `dtype`),
    /// emitted as quoted JSON fields on the record.
    extras_str: Vec<(String, String)>,
}

impl Bench {
    pub fn new(name: impl Into<String>) -> Self {
        let quick = std::env::var("MARR_BENCH_QUICK").is_ok();
        Self {
            name: name.into(),
            warmup_iters: if quick { 1 } else { 3 },
            samples: if quick { 5 } else { 30 },
            records: RefCell::new(Vec::new()),
        }
    }

    pub fn samples(mut self, n: usize) -> Self {
        self.samples = n;
        self
    }

    /// Time `f`, print a report line, return the stats.
    pub fn run<T>(&self, label: &str, f: impl FnMut() -> T) -> Stats {
        self.run_recorded(label, None, f)
    }

    /// Like [`Bench::run`], also printing elements/second throughput.
    pub fn run_throughput<T>(&self, label: &str, elements: u64, f: impl FnMut() -> T) -> Stats {
        let stats = self.run_recorded(label, Some(elements), f);
        println!(
            "bench {}/{label:<32} throughput {:.3e} elem/s",
            self.name,
            rate(elements, stats.median)
        );
        stats
    }

    fn run_recorded<T>(
        &self,
        label: &str,
        elements: Option<u64>,
        mut f: impl FnMut() -> T,
    ) -> Stats {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut times: Vec<Duration> = (0..self.samples)
            .map(|_| {
                let t0 = Instant::now();
                std::hint::black_box(f());
                t0.elapsed()
            })
            .collect();
        times.sort_unstable();
        let total: Duration = times.iter().sum();
        let stats = Stats {
            mean: total / times.len() as u32,
            median: times[times.len() / 2],
            p95: times[(times.len() * 95 / 100).min(times.len() - 1)],
            min: times[0],
            samples: times.len(),
        };
        println!(
            "bench {}/{label:<32} mean {:>12} median {:>12} p95 {:>12} min {:>12} (n={})",
            self.name,
            fmt(stats.mean),
            fmt(stats.median),
            fmt(stats.p95),
            fmt(stats.min),
            stats.samples
        );
        self.records.borrow_mut().push(Record {
            label: label.to_string(),
            stats,
            elements,
            extras: Vec::new(),
            extras_str: Vec::new(),
        });
        stats
    }

    /// Attach a named numeric metric to the most recently recorded
    /// benchmark (no-op before the first `run`). Keys should not collide
    /// with the schema's own field names.
    pub fn annotate(&self, key: &str, value: f64) {
        if let Some(r) = self.records.borrow_mut().last_mut() {
            println!("bench {}/{:<32} {key} = {value:.6}", self.name, r.label);
            r.extras.push((key.to_string(), value));
        }
    }

    /// Attach a named string tag to the most recently recorded
    /// benchmark (no-op before the first `run`) — e.g.
    /// `annotate_str("dtype", "bf16")` so the regression gate can pair
    /// baseline and fresh records per precision.
    pub fn annotate_str(&self, key: &str, value: &str) {
        if let Some(r) = self.records.borrow_mut().last_mut() {
            println!("bench {}/{:<32} {key} = {value}", self.name, r.label);
            r.extras_str.push((key.to_string(), value.to_string()));
        }
    }

    /// Dump everything measured so far as a JSON report. Schema:
    /// `{bench, quick, results: [{label, samples, mean_ns, median_ns,
    /// p95_ns, min_ns, elements?, elements_per_sec?}]}`.
    pub fn write_json(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let records = self.records.borrow();
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"bench\": \"{}\",\n", escape_json(&self.name)));
        out.push_str(&format!(
            "  \"quick\": {},\n",
            std::env::var("MARR_BENCH_QUICK").is_ok()
        ));
        out.push_str("  \"results\": [\n");
        for (i, r) in records.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"label\": \"{}\", \"samples\": {}, \"mean_ns\": {}, \
                 \"median_ns\": {}, \"p95_ns\": {}, \"min_ns\": {}",
                escape_json(&r.label),
                r.stats.samples,
                r.stats.mean.as_nanos(),
                r.stats.median.as_nanos(),
                r.stats.p95.as_nanos(),
                r.stats.min.as_nanos()
            ));
            if let Some(elements) = r.elements {
                out.push_str(&format!(
                    ", \"elements\": {}, \"elements_per_sec\": {:.6e}",
                    elements,
                    rate(elements, r.stats.median)
                ));
            }
            for (key, value) in &r.extras {
                let value = if value.is_finite() { *value } else { 0.0 };
                out.push_str(&format!(", \"{}\": {:.6e}", escape_json(key), value));
            }
            for (key, value) in &r.extras_str {
                out.push_str(&format!(
                    ", \"{}\": \"{}\"",
                    escape_json(key),
                    escape_json(value)
                ));
            }
            out.push('}');
            if i + 1 < records.len() {
                out.push(',');
            }
            out.push('\n');
        }
        out.push_str("  ]\n}\n");
        std::fs::write(path, out)
    }
}

/// Escape a string for embedding in a JSON string literal.
fn escape_json(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn rate(elements: u64, median: Duration) -> f64 {
    let secs = median.as_secs_f64();
    if secs > 0.0 {
        elements as f64 / secs
    } else {
        0.0
    }
}

fn fmt(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let b = Bench::new("test").samples(10);
        let s = b.run("noop", || 1 + 1);
        assert!(s.min <= s.median && s.median <= s.p95);
        assert_eq!(s.samples, 10);
    }

    #[test]
    fn fmt_scales() {
        assert!(fmt(Duration::from_nanos(12)).contains("ns"));
        assert!(fmt(Duration::from_micros(12)).contains("µs"));
        assert!(fmt(Duration::from_millis(12)).contains("ms"));
        assert!(fmt(Duration::from_secs(2)).contains(" s"));
    }

    #[test]
    fn json_report_lists_all_labels() {
        let b = Bench::new("jsontest").samples(3);
        b.run("alpha", || 1 + 1);
        b.run_throughput("beta", 1_000_000, || std::hint::black_box(0u64));
        let path = std::env::temp_dir().join("marr_bench_test.json");
        b.write_json(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert!(text.contains("\"bench\": \"jsontest\""));
        assert!(text.contains("\"label\": \"alpha\""));
        assert!(text.contains("\"label\": \"beta\""));
        assert!(text.contains("elements_per_sec"));
        // Exactly one comma between the two result objects, none trailing.
        assert_eq!(text.matches("},\n").count(), 1);
    }

    #[test]
    fn rate_handles_zero_duration() {
        assert_eq!(rate(100, Duration::ZERO), 0.0);
    }

    #[test]
    fn annotations_land_on_last_record() {
        let b = Bench::new("annot").samples(3);
        b.annotate("ignored_before_first_run", 1.0); // no record yet: no-op
        b.run("one", || 0u8);
        b.annotate("idle_frac", 0.25);
        b.run("two", || 0u8);
        b.annotate("jobs", 64.0);
        b.annotate("bad", f64::NAN); // sanitized: JSON has no NaN
        let path = std::env::temp_dir().join("marr_bench_annotate_test.json");
        b.write_json(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert!(!text.contains("ignored_before_first_run"));
        assert!(text.contains("\"idle_frac\": 2.500000e-1"));
        assert!(text.contains("\"jobs\": 6.400000e1"));
        assert!(text.contains("\"bad\": 0.000000e0"));
        assert!(!text.contains("NaN"));
    }

    #[test]
    fn string_annotations_emit_quoted_fields() {
        let b = Bench::new("annot_str").samples(3);
        b.annotate_str("ignored_before_first_run", "x"); // no record yet
        b.run("one", || 0u8);
        b.annotate_str("dtype", "bf16");
        b.annotate("jobs", 4.0); // numeric and string extras coexist
        let path = std::env::temp_dir().join("marr_bench_annotate_str_test.json");
        b.write_json(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert!(!text.contains("ignored_before_first_run"));
        assert!(text.contains("\"dtype\": \"bf16\""));
        assert!(text.contains("\"jobs\": 4.000000e0"));
    }

    #[test]
    fn json_metacharacters_in_labels_are_escaped() {
        assert_eq!(escape_json(r#"a "b" \c"#), r#"a \"b\" \\c"#);
        assert_eq!(escape_json("tab\there"), "tab\\u0009here");
        let b = Bench::new("esc\"name").samples(3);
        b.run("label \"quoted\"", || 0u8);
        let path = std::env::temp_dir().join("marr_bench_escape_test.json");
        b.write_json(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert!(text.contains(r#""bench": "esc\"name""#));
        assert!(text.contains(r#"label \"quoted\""#));
    }
}
