//! Flat `key = value` config parser with `[section]` headers — the TOML
//! subset the hardware config files use. Values are numbers or bare
//! strings; `#` starts a comment.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed file: `section -> key -> raw value` (the root section is "").
#[derive(Debug, Clone, Default, PartialEq)]
pub struct KvFile {
    sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl KvFile {
    pub fn parse(text: &str) -> Result<Self> {
        let mut out = Self::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .with_context(|| format!("line {}: unterminated section", lineno + 1))?;
                section = name.trim().to_string();
                out.sections.entry(section.clone()).or_default();
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("line {}: expected `key = value`, got {line:?}", lineno + 1);
            };
            out.sections
                .entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), v.trim().trim_matches('"').to_string());
        }
        Ok(out)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(String::as_str)
    }

    pub fn get_f64(&self, section: &str, key: &str) -> Result<Option<f64>> {
        self.get(section, key)
            .map(|v| v.parse().with_context(|| format!("{section}.{key} = {v:?} is not a number")))
            .transpose()
    }

    pub fn get_usize(&self, section: &str, key: &str) -> Result<Option<usize>> {
        self.get(section, key)
            .map(|v| v.parse().with_context(|| format!("{section}.{key} = {v:?} is not an integer")))
            .transpose()
    }

    pub fn get_u64(&self, section: &str, key: &str) -> Result<Option<u64>> {
        self.get(section, key)
            .map(|v| v.parse().with_context(|| format!("{section}.{key} = {v:?} is not an integer")))
            .transpose()
    }

    /// Keys present in a section (for unknown-key validation).
    pub fn keys(&self, section: &str) -> Vec<&str> {
        self.sections
            .get(section)
            .map(|m| m.keys().map(String::as_str).collect())
            .unwrap_or_default()
    }

    pub fn sections(&self) -> Vec<&str> {
        self.sections.keys().map(String::as_str).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_comments() {
        let f = KvFile::parse(
            "pm = 4 # arrays\nfreq_mhz = 200.0\n[ddr]\nbanks = 8\nname = \"vc709\"\n",
        )
        .unwrap();
        assert_eq!(f.get_usize("", "pm").unwrap(), Some(4));
        assert_eq!(f.get_f64("", "freq_mhz").unwrap(), Some(200.0));
        assert_eq!(f.get_usize("ddr", "banks").unwrap(), Some(8));
        assert_eq!(f.get("ddr", "name"), Some("vc709"));
        assert_eq!(f.get("ddr", "missing"), None);
    }

    #[test]
    fn rejects_garbage() {
        assert!(KvFile::parse("this is not kv").is_err());
        assert!(KvFile::parse("[unterminated\n").is_err());
    }

    #[test]
    fn type_errors_reported() {
        let f = KvFile::parse("pm = four").unwrap();
        assert!(f.get_usize("", "pm").is_err());
    }

    #[test]
    fn empty_file_ok() {
        let f = KvFile::parse("\n# just a comment\n").unwrap();
        assert_eq!(f.get("", "x"), None);
    }
}
