//! Seeded randomized property testing — the proptest replacement.
//!
//! [`cases`] drives a closure over `n` deterministic PRNG streams; a
//! failure reports the seed so the case replays exactly. Shrinking is
//! not implemented (cases are generated small instead).

use super::rng::Rng;

/// Run `f` over `n` seeded cases; panic with the failing seed on error.
pub fn cases(n: usize, mut f: impl FnMut(&mut Rng)) {
    for seed in 0..n as u64 {
        let mut rng = Rng::new(0xC0FFEE ^ (seed.wrapping_mul(0x9E3779B97F4A7C15)));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng)
        }));
        if let Err(e) = result {
            eprintln!("property failed at case #{seed} (replay with this index)");
            std::panic::resume_unwind(e);
        }
    }
}

/// Default case count, overridable via `MARR_CHECK_CASES`.
pub fn default_cases() -> usize {
    std::env::var("MARR_CHECK_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(48)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let count = std::cell::Cell::new(0);
        cases(10, |_| count.set(count.get() + 1));
        assert_eq!(count.get(), 10);
    }

    #[test]
    fn cases_see_distinct_streams() {
        let mut first = Vec::new();
        cases(5, |rng| first.push(rng.next_u64()));
        let uniq: std::collections::HashSet<_> = first.iter().collect();
        assert_eq!(uniq.len(), 5);
    }

    #[test]
    #[should_panic]
    fn failure_propagates() {
        cases(3, |rng| assert!(rng.next_f64() < -1.0));
    }
}
