//! Std-only infrastructure the offline build environment demands.
//!
//! This workspace compiles against a vendored crate set containing only
//! the `xla` closure + `anyhow`, so the usual ecosystem crates are
//! replaced by small, tested, purpose-built equivalents:
//!
//! * [`rng`] — SplitMix64 PRNG (replaces `rand`/`rand_chacha`);
//! * [`bench`] — a criterion-style timing harness for `harness = false`
//!   benches (replaces `criterion`);
//! * [`check`] — seeded randomized property-test driver (replaces
//!   `proptest`);
//! * [`kv`] — flat `key = value` config-file parser with `[section]`
//!   support, the TOML subset [`crate::config`] needs (replaces `toml`).

pub mod bench;
pub mod check;
pub mod kv;
pub mod rng;

pub use bench::Bench;
pub use rng::Rng;
