//! SplitMix64 — a tiny, fast, well-distributed PRNG for test data and
//! workload generation. Deterministic per seed; not cryptographic.
//! (Vose, Steele et al., "Fast splittable pseudorandom number
//! generators", OOPSLA 2014.)

#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in `[-1, 1)` — matrix test data.
    #[inline]
    pub fn next_f32_signed(&mut self) -> f32 {
        (self.next_f64() * 2.0 - 1.0) as f32
    }

    /// Uniform f32 on the grid `k / 256` for `k` in `[-256, 256)` —
    /// every value is exactly representable in f16 (11-bit significand)
    /// and bf16 (8-bit significand), so half-precision bit-identity
    /// tests built on this data don't depend on rounding luck.
    #[inline]
    pub fn next_f32_grid(&mut self) -> f32 {
        ((self.next_u64() % 512) as i64 - 256) as f32 / 256.0
    }

    /// Uniform in `[lo, hi)` (integer).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range");
        lo + (self.next_u64() % (hi - lo) as u64) as usize
    }

    /// Uniform choice from a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn grid_values_are_on_the_256_grid_and_in_range() {
        let mut r = Rng::new(13);
        for _ in 0..10_000 {
            let x = r.next_f32_grid();
            assert!((-1.0..1.0).contains(&x));
            let k = x * 256.0;
            assert_eq!(k, k.trunc(), "off-grid value {x}");
        }
    }

    #[test]
    fn range_bounds_respected() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.range(3, 17);
            assert!((3..17).contains(&x));
        }
    }

    #[test]
    fn roughly_uniform() {
        let mut r = Rng::new(11);
        let mut buckets = [0usize; 10];
        let n = 100_000;
        for _ in 0..n {
            buckets[(r.next_f64() * 10.0) as usize] += 1;
        }
        for &b in &buckets {
            let expected = n / 10;
            assert!(
                (b as i64 - expected as i64).unsigned_abs() < (expected / 10) as u64,
                "bucket {b} far from {expected}"
            );
        }
    }
}
