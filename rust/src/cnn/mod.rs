//! AlexNet-as-GEMM: the paper's case-study workload (Section V, Table II).
//!
//! Each conv layer is lowered to one GEMM via im2col (Cong & Xiao, ref.
//! [14]): `M` = output channels, `K` = in_channels x kh x kw, `N` =
//! output pixels. Fully-connected layers are GEMMs with the paper's batch
//! of 128. The derived `(M, K, N)` triples are asserted against Table II
//! and against the Python model's `ALEXNET_GEMM_SHAPES` (via the artifact
//! manifest) so all three layers of the stack agree on the workload.
//!
//! [`im2col`] does the actual lowering (patch-row im2col, direct-conv
//! oracle, shared-filter batch operands) and [`schedule`] extends the
//! per-layer view to whole-network scheduling with reconfiguration
//! costs and batched serving through the `JobServer`.

pub mod im2col;
pub mod schedule;


/// Convolution geometry of one CNN layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvShape {
    pub in_channels: usize,
    pub in_hw: usize, // square feature maps
    pub out_channels: usize,
    pub kernel: usize,
    pub stride: usize,
    pub pad: usize,
    /// Grouped convolution factor (AlexNet's two-GPU split).
    pub groups: usize,
}

impl ConvShape {
    pub fn out_hw(&self) -> usize {
        (self.in_hw + 2 * self.pad - self.kernel) / self.stride + 1
    }

    /// im2col GEMM dims for ONE group: (M, K, N).
    pub fn gemm_dims(&self) -> (usize, usize, usize) {
        let m = self.out_channels / self.groups;
        let k = (self.in_channels / self.groups) * self.kernel * self.kernel;
        let n = self.out_hw() * self.out_hw();
        (m, k, n)
    }
}

/// One workload row of Table II.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GemmLayer {
    pub name: &'static str,
    pub m: usize,
    pub k: usize,
    pub n: usize,
}

impl GemmLayer {
    pub fn flops(&self) -> u64 {
        2 * self.m as u64 * self.k as u64 * self.n as u64
    }

    /// Is this a convolution layer (im2col-lowered, batched serving
    /// shares the packed filter across images)? Table II's convention:
    /// conv layers are named `conv*`, fully-connected ones `fc*` (the
    /// FC batch is already folded into `M`).
    pub fn is_conv(&self) -> bool {
        self.name.starts_with("conv")
    }
}

/// The eight AlexNet layers exactly as Table II lists them (`M*K*N`).
///
/// Notes on the derivation, to keep the provenance auditable:
/// * conv-1: 96 filters of 3x11x11 on 227x227 stride 4 -> 96 * 363 * 55^2.
/// * conv-2/4/5 are grouped (2 GPUs in the original net); the paper lists
///   the per-group GEMM (e.g. conv-2: 256/2=128 filters, K=48*5*5=1200).
/// * fc layers: batch 128 -> M=128, K=in features, N=out features.
pub fn alexnet_layers() -> Vec<GemmLayer> {
    vec![
        GemmLayer { name: "conv1", m: 96, k: 363, n: 3025 },
        GemmLayer { name: "conv2", m: 128, k: 1200, n: 729 },
        GemmLayer { name: "conv3", m: 384, k: 2304, n: 169 },
        GemmLayer { name: "conv4", m: 192, k: 1728, n: 169 },
        GemmLayer { name: "conv5", m: 128, k: 1728, n: 169 },
        GemmLayer { name: "fc6", m: 128, k: 9216, n: 4096 },
        GemmLayer { name: "fc7", m: 128, k: 4096, n: 4096 },
        GemmLayer { name: "fc8", m: 128, k: 4096, n: 1000 },
    ]
}

pub fn layer(name: &str) -> Option<GemmLayer> {
    alexnet_layers().into_iter().find(|l| l.name == name)
}

/// The conv geometry behind a Table II layer name, if it is one of the
/// known AlexNet conv layers (the serving scheduler uses this to lower
/// a conv layer through real im2col instead of synthetic operands).
pub fn conv_shape(name: &str) -> Option<ConvShape> {
    alexnet_conv_shapes().into_iter().find(|(n, _)| *n == name).map(|(_, s)| s)
}

/// The conv geometries the Table II GEMMs derive from.
pub fn alexnet_conv_shapes() -> Vec<(&'static str, ConvShape)> {
    vec![
        (
            "conv1",
            ConvShape {
                in_channels: 3,
                in_hw: 227,
                out_channels: 96,
                kernel: 11,
                stride: 4,
                pad: 0,
                groups: 1,
            },
        ),
        (
            "conv2",
            ConvShape {
                in_channels: 96,
                in_hw: 27,
                out_channels: 256,
                kernel: 5,
                stride: 1,
                pad: 2,
                groups: 2,
            },
        ),
        (
            "conv3",
            ConvShape {
                in_channels: 256,
                in_hw: 13,
                out_channels: 384,
                kernel: 3,
                stride: 1,
                pad: 1,
                groups: 1,
            },
        ),
        (
            "conv4",
            ConvShape {
                in_channels: 384,
                in_hw: 13,
                out_channels: 384,
                kernel: 3,
                stride: 1,
                pad: 1,
                groups: 2,
            },
        ),
        (
            "conv5",
            ConvShape {
                in_channels: 384,
                in_hw: 13,
                out_channels: 256,
                kernel: 3,
                stride: 1,
                pad: 1,
                groups: 2,
            },
        ),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_has_eight_layers() {
        assert_eq!(alexnet_layers().len(), 8);
    }

    #[test]
    fn conv_geometries_derive_table2_gemms() {
        for (name, shape) in alexnet_conv_shapes() {
            let (m, k, n) = shape.gemm_dims();
            let l = layer(name).unwrap();
            assert_eq!((m, k, n), (l.m, l.k, l.n), "layer {name}");
        }
    }

    #[test]
    fn conv1_output_is_55() {
        let (_, c1) = alexnet_conv_shapes().into_iter().next().unwrap();
        assert_eq!(c1.out_hw(), 55);
    }

    #[test]
    fn fc6_flops() {
        // fc-6: 2 * 128 * 9216 * 4096 ~= 9.66 GFLOP.
        assert_eq!(layer("fc6").unwrap().flops(), 9_663_676_416);
    }

    #[test]
    fn unknown_layer_is_none() {
        assert!(layer("conv9").is_none());
    }

    #[test]
    fn conv_and_fc_layers_classified() {
        assert!(layer("conv2").unwrap().is_conv());
        assert!(!layer("fc6").unwrap().is_conv());
        assert!(conv_shape("conv3").is_some());
        assert!(conv_shape("fc6").is_none());
        assert!(conv_shape("conv9").is_none());
    }
}
