//! Network-level scheduling — the deployment question Table II implies
//! but never asks: the host CPU *can* reprogram the multiplexers and
//! buffer descriptors between layers (Section III-A: "the multiplexers
//! are initialized by the host CPU"), so should a whole network run with
//! per-layer optimal `⟨N_p, S_i⟩` (paying a reconfiguration stall per
//! switch) or one fixed configuration?
//!
//! This extends the paper's per-layer analysis into an end-to-end
//! schedule: `schedule_network` evaluates both policies on the simulator
//! and reports the break-even reconfiguration cost, and
//! [`schedule_network_served`] routes the same layer sequence through
//! the serving runtime ([`crate::coordinator::JobServer`]) so a
//! whole-network run is just another job stream — real numerics per
//! layer, same schedule accounting.

use crate::accelerator::{Accelerator, SimOptions};
use crate::config::{HardwareConfig, RunConfig};
use crate::coordinator::{GemmJob, JobServer};
use crate::dse;
use crate::gemm::Matrix;

use super::GemmLayer;

/// How to configure the accelerator across a layer sequence.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Policy {
    /// DSE-optimal config per layer; costs `reconfig_secs` whenever the
    /// config changes between consecutive layers.
    PerLayerOptimal,
    /// One configuration for the whole network.
    Fixed(RunConfig),
}

/// One scheduled layer.
#[derive(Debug, Clone)]
pub struct ScheduledLayer {
    pub name: &'static str,
    pub run: RunConfig,
    pub secs: f64,
    pub gflops: f64,
    pub reconfigured: bool,
}

/// A whole-network schedule.
#[derive(Debug, Clone)]
pub struct NetworkSchedule {
    pub layers: Vec<ScheduledLayer>,
    pub reconfigs: usize,
    /// Compute time + reconfiguration stalls.
    pub total_secs: f64,
    pub total_gflops: f64,
}

/// Evaluate `policy` over `layers` on the simulated accelerator.
/// `reconfig_secs` is the host-side stall to rewrite muxes + descriptors
/// (PCIe config writes; tens of microseconds on the VC709 class).
pub fn schedule_network(
    hw: &HardwareConfig,
    acc: &Accelerator,
    layers: &[GemmLayer],
    policy: Policy,
    reconfig_secs: f64,
) -> anyhow::Result<NetworkSchedule> {
    let mut out = Vec::with_capacity(layers.len());
    let mut prev: Option<RunConfig> = None;
    let mut total = 0.0;
    let mut reconfigs = 0;
    let mut flops = 0u64;
    for l in layers {
        let run = match policy {
            Policy::PerLayerOptimal => {
                dse::explore(hw, l.m, l.k, l.n, acc.surface())?.best.run
            }
            Policy::Fixed(run) => run,
        };
        let sim = acc.simulate(&run, l.m, l.k, l.n, &SimOptions::default())?;
        let reconfigured = prev.is_some_and(|p| p != run);
        if reconfigured {
            reconfigs += 1;
            total += reconfig_secs;
        }
        total += sim.total_secs;
        flops += l.flops();
        out.push(ScheduledLayer {
            name: l.name,
            run,
            secs: sim.total_secs,
            gflops: sim.gflops,
            reconfigured,
        });
        prev = Some(run);
    }
    Ok(NetworkSchedule {
        layers: out,
        reconfigs,
        total_secs: total,
        total_gflops: flops as f64 / total / 1e9,
    })
}

/// Run a whole network through the serving runtime: one [`GemmJob`] per
/// layer (deterministic random operands seeded by layer index),
/// submitted as a stream and folded into the same [`NetworkSchedule`]
/// shape as [`schedule_network`] — compute times come from each job's
/// simulation report, reconfiguration stalls from consecutive config
/// changes in layer order.
///
/// `Policy::PerLayerOptimal` leaves jobs unpinned, so the server picks
/// per-layer configs (its `default_run` if set, else the DSE optimum —
/// pass a server without a default to reproduce the DSE schedule).
pub fn schedule_network_served(
    server: &JobServer,
    layers: &[GemmLayer],
    policy: Policy,
    reconfig_secs: f64,
) -> anyhow::Result<NetworkSchedule> {
    anyhow::ensure!(!layers.is_empty(), "empty layer sequence");
    let mut tickets = Vec::with_capacity(layers.len());
    for (i, l) in layers.iter().enumerate() {
        let run = match policy {
            Policy::PerLayerOptimal => None,
            Policy::Fixed(run) => Some(run),
        };
        let seed = 0x5EED ^ ((i as u64) << 8);
        let a = Matrix::random(l.m, l.k, seed);
        let b = Matrix::random(l.k, l.n, seed + 1);
        tickets.push(server.submit(GemmJob { id: i as u64, a, b, run })?);
    }
    let mut out = Vec::with_capacity(layers.len());
    let mut prev: Option<RunConfig> = None;
    let mut total = 0.0;
    let mut reconfigs = 0;
    let mut flops = 0u64;
    for (l, t) in layers.iter().zip(tickets) {
        let r = t.wait()?;
        let reconfigured = prev.is_some_and(|p| p != r.run);
        if reconfigured {
            reconfigs += 1;
            total += reconfig_secs;
        }
        total += r.sim.total_secs;
        flops += l.flops();
        out.push(ScheduledLayer {
            name: l.name,
            run: r.run,
            secs: r.sim.total_secs,
            gflops: r.sim.gflops,
            reconfigured,
        });
        prev = Some(r.run);
    }
    Ok(NetworkSchedule {
        layers: out,
        reconfigs,
        total_secs: total,
        total_gflops: flops as f64 / total / 1e9,
    })
}

/// The best single configuration for the whole network: evaluate every
/// Eq. 9-feasible `⟨N_p, S_i⟩` as a `Fixed` policy and keep the fastest.
pub fn best_fixed(
    hw: &HardwareConfig,
    acc: &Accelerator,
    layers: &[GemmLayer],
) -> anyhow::Result<NetworkSchedule> {
    let max_m = layers.iter().map(|l| l.m).max().unwrap_or(16);
    let mut best: Option<NetworkSchedule> = None;
    for si in dse::candidate_sis(hw, max_m) {
        for np in crate::analytical::feasible_nps(hw, si) {
            let s = schedule_network(
                hw,
                acc,
                layers,
                Policy::Fixed(RunConfig::square(np, si)),
                0.0,
            )?;
            if best.as_ref().map(|b| s.total_secs < b.total_secs).unwrap_or(true) {
                best = Some(s);
            }
        }
    }
    best.ok_or_else(|| anyhow::anyhow!("no feasible fixed configuration"))
}

/// Reconfiguration cost at which per-layer-optimal and best-fixed tie.
pub fn break_even_reconfig_secs(
    hw: &HardwareConfig,
    acc: &Accelerator,
    layers: &[GemmLayer],
) -> anyhow::Result<f64> {
    let per_layer = schedule_network(hw, acc, layers, Policy::PerLayerOptimal, 0.0)?;
    let fixed = best_fixed(hw, acc, layers)?;
    if per_layer.reconfigs == 0 {
        return Ok(f64::INFINITY);
    }
    Ok((fixed.total_secs - per_layer.total_secs) / per_layer.reconfigs as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::alexnet_layers;

    fn setup() -> (HardwareConfig, Accelerator) {
        let hw = HardwareConfig::paper();
        let acc = Accelerator::new(hw.clone());
        (hw, acc)
    }

    #[test]
    fn per_layer_optimal_beats_fixed_at_zero_cost() {
        let (hw, acc) = setup();
        let layers = alexnet_layers();
        let opt =
            schedule_network(&hw, &acc, &layers, Policy::PerLayerOptimal, 0.0).unwrap();
        let fixed = best_fixed(&hw, &acc, &layers).unwrap();
        assert!(opt.total_secs <= fixed.total_secs * 1.0001);
        assert_eq!(opt.layers.len(), 8);
    }

    #[test]
    fn reconfig_cost_charged_per_switch() {
        let (hw, acc) = setup();
        let layers = alexnet_layers();
        let free =
            schedule_network(&hw, &acc, &layers, Policy::PerLayerOptimal, 0.0).unwrap();
        let costly =
            schedule_network(&hw, &acc, &layers, Policy::PerLayerOptimal, 1e-3).unwrap();
        assert_eq!(free.reconfigs, costly.reconfigs);
        let want = free.total_secs + free.reconfigs as f64 * 1e-3;
        assert!((costly.total_secs - want).abs() < 1e-12);
    }

    #[test]
    fn fixed_policy_never_reconfigures() {
        let (hw, acc) = setup();
        let layers = alexnet_layers();
        let s = schedule_network(
            &hw,
            &acc,
            &layers,
            Policy::Fixed(RunConfig::square(2, 128)),
            1.0, // would be catastrophic if charged
        )
        .unwrap();
        assert_eq!(s.reconfigs, 0);
        assert!(s.layers.iter().all(|l| l.run == RunConfig::square(2, 128)));
    }

    #[test]
    fn break_even_is_positive_for_alexnet() {
        // Per-layer optimal saves real time, so some nonzero reconfig
        // budget is affordable.
        let (hw, acc) = setup();
        let be = break_even_reconfig_secs(&hw, &acc, &alexnet_layers()).unwrap();
        assert!(be > 0.0, "break-even {be}");
    }

    #[test]
    fn served_fixed_policy_matches_simulated_totals() {
        // The served path and the simulate-only path agree exactly on a
        // fixed schedule: same sim model, same accounting.
        use crate::coordinator::{NumericsEngine, ServerConfig};
        let (hw, acc) = setup();
        let srv = JobServer::new(
            hw.clone(),
            NumericsEngine::golden(),
            ServerConfig {
                workers: 4,
                queue_capacity: 8,
                batch_max_tasks: 0,
                batch_window: 1,
                cross_job_stealing: true,
                default_run: None,
            },
        )
        .unwrap();
        let layers = vec![
            GemmLayer { name: "l0", m: 64, k: 32, n: 64 },
            GemmLayer { name: "l1", m: 48, k: 24, n: 40 },
        ];
        let run = RunConfig::square(2, 32);
        let served =
            schedule_network_served(&srv, &layers, Policy::Fixed(run), 1.0).unwrap();
        let simulated =
            schedule_network(&hw, &acc, &layers, Policy::Fixed(run), 1.0).unwrap();
        assert_eq!(served.reconfigs, 0);
        assert_eq!(served.layers.len(), 2);
        assert!((served.total_secs - simulated.total_secs).abs() < 1e-12);
        assert!(served.layers.iter().all(|l| l.run == run));
    }

    #[test]
    fn served_empty_network_rejected() {
        use crate::coordinator::{NumericsEngine, ServerConfig};
        let (hw, _) = setup();
        let srv = JobServer::new(
            hw,
            NumericsEngine::golden(),
            ServerConfig { workers: 2, ..ServerConfig::default() },
        )
        .unwrap();
        assert!(schedule_network_served(&srv, &[], Policy::PerLayerOptimal, 0.0).is_err());
    }

    #[test]
    fn single_layer_network_never_reconfigures() {
        let (hw, acc) = setup();
        let layers = vec![crate::cnn::layer("fc6").unwrap()];
        let s =
            schedule_network(&hw, &acc, &layers, Policy::PerLayerOptimal, 1.0).unwrap();
        assert_eq!(s.reconfigs, 0);
    }
}
