//! Network-level scheduling — the deployment question Table II implies
//! but never asks: the host CPU *can* reprogram the multiplexers and
//! buffer descriptors between layers (Section III-A: "the multiplexers
//! are initialized by the host CPU"), so should a whole network run with
//! per-layer optimal `⟨N_p, S_i⟩` (paying a reconfiguration stall per
//! switch) or one fixed configuration?
//!
//! This extends the paper's per-layer analysis into an end-to-end
//! schedule: `schedule_network` evaluates both policies on the simulator
//! and reports the break-even reconfiguration cost, and
//! [`schedule_network_served`] routes the same layer sequence through
//! the serving runtime ([`crate::coordinator::JobServer`]) so a
//! whole-network run is just another job stream — real numerics per
//! layer, same schedule accounting. Weights are **registered state**,
//! not per-call traffic: [`NetworkWeights::register`] loads every
//! layer's B operand (conv filters via im2col's transposed
//! [`super::im2col::filter_operand`], FC weight matrices as-is) into
//! the server's operand registry once, and every batch/epoch streamed
//! through [`schedule_network_served_with`] submits by
//! [`crate::coordinator::WeightHandle`] — a filter reused by N batches
//! packs exactly once per process, with repeat runs resolving the
//! cached pack (registry hits) instead of repacking. Conv layers still
//! ride the shared-B group shape
//! ([`crate::coordinator::Submission::batched`]) so the
//! within-call sharing composes with the cross-call cache.

use crate::accelerator::{Accelerator, SimOptions};
use crate::config::{HardwareConfig, RunConfig};
use crate::coordinator::{JobServer, SpanKind, Submission, WeightHandle};
use crate::dse;
use crate::gemm::{Dtype, Matrix};

use super::GemmLayer;

/// How to configure the accelerator across a layer sequence.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Policy {
    /// DSE-optimal config per layer; costs `reconfig_secs` whenever the
    /// config changes between consecutive layers.
    PerLayerOptimal,
    /// One configuration for the whole network.
    Fixed(RunConfig),
}

/// One scheduled layer.
#[derive(Debug, Clone)]
pub struct ScheduledLayer {
    pub name: &'static str,
    pub run: RunConfig,
    pub secs: f64,
    pub gflops: f64,
    pub reconfigured: bool,
}

/// A whole-network schedule.
#[derive(Debug, Clone)]
pub struct NetworkSchedule {
    pub layers: Vec<ScheduledLayer>,
    pub reconfigs: usize,
    /// Compute time + reconfiguration stalls.
    pub total_secs: f64,
    pub total_gflops: f64,
}

/// Evaluate `policy` over `layers` on the simulated accelerator.
/// `reconfig_secs` is the host-side stall to rewrite muxes + descriptors
/// (PCIe config writes; tens of microseconds on the VC709 class).
pub fn schedule_network(
    hw: &HardwareConfig,
    acc: &Accelerator,
    layers: &[GemmLayer],
    policy: Policy,
    reconfig_secs: f64,
) -> anyhow::Result<NetworkSchedule> {
    let mut out = Vec::with_capacity(layers.len());
    let mut prev: Option<RunConfig> = None;
    let mut total = 0.0;
    let mut reconfigs = 0;
    let mut flops = 0u64;
    for l in layers {
        let run = match policy {
            Policy::PerLayerOptimal => {
                dse::explore(hw, l.m, l.k, l.n, acc.surface())?.best.run
            }
            Policy::Fixed(run) => run,
        };
        let sim = acc.simulate(&run, l.m, l.k, l.n, &SimOptions::default())?;
        let reconfigured = prev.is_some_and(|p| p != run);
        if reconfigured {
            reconfigs += 1;
            total += reconfig_secs;
        }
        total += sim.total_secs;
        flops += l.flops();
        out.push(ScheduledLayer {
            name: l.name,
            run,
            secs: sim.total_secs,
            gflops: sim.gflops,
            reconfigured,
        });
        prev = Some(run);
    }
    Ok(NetworkSchedule {
        layers: out,
        reconfigs,
        total_secs: total,
        total_gflops: flops as f64 / total / 1e9,
    })
}

/// How one served layer is in flight: a lone future (FC / dense
/// layers) or a shared-B batch future (conv layers — one packed
/// filter, `batch` im2col'd images).
enum LayerHandle {
    Single(crate::coordinator::JobFuture),
    Batched(crate::coordinator::JobFuture),
}

/// A network's weights as server-resident state: one registered
/// [`WeightHandle`] per layer. Built once
/// ([`NetworkWeights::register`]), streamed through any number of
/// [`schedule_network_served_with`] runs — each layer's operand packs
/// at most once per process however many batches and epochs reuse it.
pub struct NetworkWeights {
    handles: Vec<WeightHandle>,
}

impl NetworkWeights {
    /// Register every layer's B operand with `server` (the model-load
    /// step): conv filters as the transposed
    /// [`super::im2col::filter_operand`] (`K x M`), synthetic `K x M`
    /// operands for conv layers without a known Table II geometry, and
    /// `K x N` weight matrices for FC layers. Deterministic per-layer
    /// seeds, so repeated registrations reproduce the same network.
    pub fn register(server: &JobServer, layers: &[GemmLayer]) -> anyhow::Result<Self> {
        let mut handles = Vec::with_capacity(layers.len());
        for (i, l) in layers.iter().enumerate() {
            match server.register_b(layer_weight(l, layer_seed(i))) {
                Ok(h) => handles.push(h),
                Err(e) => {
                    // A half-registered network must not leak into a
                    // long-lived server: release what was registered
                    // before surfacing the failure. A cleanup failure
                    // is counted by the server (`unregister_failures`)
                    // and chained onto the primary error instead of
                    // being dropped.
                    let e = e.context(format!("registering weight for layer {}", l.name));
                    return Err(match server.unregister_all(handles) {
                        Ok(()) => e,
                        Err(cleanup) => e.context(format!(
                            "cleanup of partially registered network also failed: {cleanup:#}"
                        )),
                    });
                }
            }
        }
        Ok(Self { handles })
    }

    /// The per-layer handles, in layer order.
    pub fn handles(&self) -> &[WeightHandle] {
        &self.handles
    }

    /// Drop every registered weight (cached packs freed; in-flight
    /// work is unaffected). Sweeps the whole list even when one handle
    /// fails (e.g. already unregistered directly), so a partial failure
    /// never leaks the remaining weights.
    pub fn unregister(self, server: &JobServer) -> anyhow::Result<()> {
        server.unregister_all(self.handles)
    }
}

/// Deterministic per-layer operand seed (stable across registration
/// and activation building).
fn layer_seed(i: usize) -> u64 {
    0x5EED ^ ((i as u64) << 8)
}

/// One layer's deterministic B operand — what
/// [`NetworkWeights::register`] loads into the server.
fn layer_weight(l: &GemmLayer, seed: u64) -> Matrix {
    if l.is_conv() {
        match crate::cnn::conv_shape(l.name) {
            Some(_) => super::im2col::filter_operand(&Matrix::random(l.m, l.k, seed + 1)),
            None => Matrix::random(l.k, l.m, seed + 1),
        }
    } else {
        Matrix::random(l.k, l.n, seed + 1)
    }
}

/// One conv layer's batch of A operands: real im2col patch rows over
/// deterministic random images when the geometry is known (Table II's
/// conv1..conv5, per-group), synthetic patch matrices of the same
/// `(N, K)` shape otherwise.
fn conv_activations(l: &GemmLayer, batch: usize, seed: u64) -> Vec<Matrix> {
    match crate::cnn::conv_shape(l.name) {
        Some(shape) => {
            let channels = shape.in_channels / shape.groups;
            (0..batch)
                .map(|i| {
                    let img =
                        Matrix::random(channels, shape.in_hw * shape.in_hw, seed + 2 + i as u64);
                    super::im2col::im2col_patches(&img, &shape)
                })
                .collect()
        }
        None => (0..batch).map(|i| Matrix::random(l.n, l.k, seed + 2 + i as u64)).collect(),
    }
}

/// [`schedule_network_served_with`] plus the weight lifecycle: register
/// every layer's operand, stream one run, unregister. For repeated
/// inference over the same network — where the registry's cross-call
/// reuse pays off — register once with [`NetworkWeights::register`] and
/// call [`schedule_network_served_with`] per batch/epoch instead.
pub fn schedule_network_served(
    server: &JobServer,
    layers: &[GemmLayer],
    policy: Policy,
    reconfig_secs: f64,
    batch: usize,
) -> anyhow::Result<NetworkSchedule> {
    anyhow::ensure!(!layers.is_empty(), "empty layer sequence");
    anyhow::ensure!(batch >= 1, "batch must be >= 1");
    let weights = NetworkWeights::register(server, layers)?;
    // Unregister before surfacing any run failure (a failed schedule
    // must not leak the layer weights), and let a run error outrank an
    // unregister error.
    let schedule =
        schedule_network_served_with(server, layers, &weights, policy, reconfig_secs, batch);
    let unregistered = weights.unregister(server);
    let schedule = schedule?;
    unregistered?;
    Ok(schedule)
}

/// Run a whole network through the serving runtime against
/// pre-registered weights and fold the results into the same
/// [`NetworkSchedule`] shape as [`schedule_network`] — compute times
/// come from each job's simulation report, reconfiguration stalls from
/// consecutive config changes in layer order.
///
/// **Every layer streams through its registered handle.** Conv layers
/// are lowered via im2col ([`super::im2col`]) to `batch` patch-row
/// GEMMs submitted as one shared-B group
/// ([`Submission::batched`]) under the layer's
/// [`WeightHandle`]: the packed filter is resolved from the operand
/// registry — packed on first use, a cache hit ever after — so a
/// filter reused by N batches across any number of calls packs exactly
/// once per process. A conv layer's `secs` is the summed simulated
/// time of its whole batch. Fully-connected layers keep Table II's
/// convention (the FC batch is already folded into `M`) and run as one
/// handle-carrying job each.
///
/// `Policy::PerLayerOptimal` leaves jobs unpinned, so the server picks
/// per-layer configs (its `default_run` if set, else the DSE optimum —
/// pass a server without a default to reproduce the DSE schedule);
/// every image of a conv batch runs under one config by construction.
pub fn schedule_network_served_with(
    server: &JobServer,
    layers: &[GemmLayer],
    weights: &NetworkWeights,
    policy: Policy,
    reconfig_secs: f64,
    batch: usize,
) -> anyhow::Result<NetworkSchedule> {
    schedule_network_served_with_dtype(
        server,
        layers,
        weights,
        policy,
        reconfig_secs,
        batch,
        Dtype::F32,
    )
}

/// [`schedule_network_served_with`] at a serving precision: every
/// layer's GEMMs submit at `dtype`, and the registry caches each
/// weight's pack once per `(handle, S, dtype)` variant — one registered
/// network serves several precisions side by side. `F32` is exactly the
/// base entry point (which delegates here).
#[allow(clippy::too_many_arguments)]
pub fn schedule_network_served_with_dtype(
    server: &JobServer,
    layers: &[GemmLayer],
    weights: &NetworkWeights,
    policy: Policy,
    reconfig_secs: f64,
    batch: usize,
    dtype: Dtype,
) -> anyhow::Result<NetworkSchedule> {
    anyhow::ensure!(!layers.is_empty(), "empty layer sequence");
    anyhow::ensure!(batch >= 1, "batch must be >= 1");
    anyhow::ensure!(
        weights.handles.len() == layers.len(),
        "weights registered for {} layers, schedule has {}",
        weights.handles.len(),
        layers.len()
    );
    let mut handles = Vec::with_capacity(layers.len());
    for (i, l) in layers.iter().enumerate() {
        let run = match policy {
            Policy::PerLayerOptimal => None,
            Policy::Fixed(run) => Some(run),
        };
        let seed = layer_seed(i);
        let weight = weights.handles[i];
        server.trace_span_begin(SpanKind::CnnLayer, i as u64);
        if l.is_conv() {
            let many_a = conv_activations(l, batch, seed);
            handles.push(LayerHandle::Batched(
                server.submit_async(
                    Submission::batched(weight, many_a).run(run).dtype(dtype),
                )?,
            ));
        } else {
            let a = Matrix::random(l.m, l.k, seed);
            handles.push(LayerHandle::Single(server.submit_async(
                Submission::gemm(a, weight).id(i as u64).run(run).dtype(dtype),
            )?));
        }
    }
    let mut out = Vec::with_capacity(layers.len());
    let mut prev: Option<RunConfig> = None;
    let mut total = 0.0;
    let mut reconfigs = 0;
    let mut flops = 0u64;
    for (i, (l, h)) in layers.iter().zip(handles).enumerate() {
        // (config, layer compute seconds, layer FLOPs).
        let (run, secs, layer_flops) = match h {
            LayerHandle::Single(t) => {
                let r = t.wait_one()?;
                (r.run, r.sim.total_secs, l.flops())
            }
            LayerHandle::Batched(g) => {
                let results = g.wait()?;
                let run = results[0].run;
                debug_assert!(results.iter().all(|r| r.run == run));
                let secs: f64 = results.iter().map(|r| r.sim.total_secs).sum();
                (run, secs, l.flops() * results.len() as u64)
            }
        };
        server.trace_span_end(SpanKind::CnnLayer, i as u64);
        let reconfigured = prev.is_some_and(|p| p != run);
        if reconfigured {
            reconfigs += 1;
            total += reconfig_secs;
        }
        total += secs;
        flops += layer_flops;
        out.push(ScheduledLayer {
            name: l.name,
            run,
            secs,
            gflops: layer_flops as f64 / secs / 1e9,
            reconfigured,
        });
        prev = Some(run);
    }
    Ok(NetworkSchedule {
        layers: out,
        reconfigs,
        total_secs: total,
        total_gflops: flops as f64 / total / 1e9,
    })
}

/// The best single configuration for the whole network: evaluate every
/// Eq. 9-feasible `⟨N_p, S_i⟩` as a `Fixed` policy and keep the fastest.
pub fn best_fixed(
    hw: &HardwareConfig,
    acc: &Accelerator,
    layers: &[GemmLayer],
) -> anyhow::Result<NetworkSchedule> {
    let max_m = layers.iter().map(|l| l.m).max().unwrap_or(16);
    let mut best: Option<NetworkSchedule> = None;
    for si in dse::candidate_sis(hw, max_m) {
        for np in crate::analytical::feasible_nps(hw, si) {
            let s = schedule_network(
                hw,
                acc,
                layers,
                Policy::Fixed(RunConfig::square(np, si)),
                0.0,
            )?;
            if best.as_ref().map(|b| s.total_secs < b.total_secs).unwrap_or(true) {
                best = Some(s);
            }
        }
    }
    best.ok_or_else(|| anyhow::anyhow!("no feasible fixed configuration"))
}

/// Reconfiguration cost at which per-layer-optimal and best-fixed tie.
pub fn break_even_reconfig_secs(
    hw: &HardwareConfig,
    acc: &Accelerator,
    layers: &[GemmLayer],
) -> anyhow::Result<f64> {
    let per_layer = schedule_network(hw, acc, layers, Policy::PerLayerOptimal, 0.0)?;
    let fixed = best_fixed(hw, acc, layers)?;
    if per_layer.reconfigs == 0 {
        return Ok(f64::INFINITY);
    }
    Ok((fixed.total_secs - per_layer.total_secs) / per_layer.reconfigs as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::alexnet_layers;

    fn setup() -> (HardwareConfig, Accelerator) {
        let hw = HardwareConfig::paper();
        let acc = Accelerator::new(hw.clone());
        (hw, acc)
    }

    #[test]
    fn per_layer_optimal_beats_fixed_at_zero_cost() {
        let (hw, acc) = setup();
        let layers = alexnet_layers();
        let opt =
            schedule_network(&hw, &acc, &layers, Policy::PerLayerOptimal, 0.0).unwrap();
        let fixed = best_fixed(&hw, &acc, &layers).unwrap();
        assert!(opt.total_secs <= fixed.total_secs * 1.0001);
        assert_eq!(opt.layers.len(), 8);
    }

    #[test]
    fn reconfig_cost_charged_per_switch() {
        let (hw, acc) = setup();
        let layers = alexnet_layers();
        let free =
            schedule_network(&hw, &acc, &layers, Policy::PerLayerOptimal, 0.0).unwrap();
        let costly =
            schedule_network(&hw, &acc, &layers, Policy::PerLayerOptimal, 1e-3).unwrap();
        assert_eq!(free.reconfigs, costly.reconfigs);
        let want = free.total_secs + free.reconfigs as f64 * 1e-3;
        assert!((costly.total_secs - want).abs() < 1e-12);
    }

    #[test]
    fn fixed_policy_never_reconfigures() {
        let (hw, acc) = setup();
        let layers = alexnet_layers();
        let s = schedule_network(
            &hw,
            &acc,
            &layers,
            Policy::Fixed(RunConfig::square(2, 128)),
            1.0, // would be catastrophic if charged
        )
        .unwrap();
        assert_eq!(s.reconfigs, 0);
        assert!(s.layers.iter().all(|l| l.run == RunConfig::square(2, 128)));
    }

    #[test]
    fn stale_network_unregister_fails_loudly_and_is_counted() {
        // A handle dropped out from under a NetworkWeights sweep must
        // surface as an error AND a counted `unregister_failures` —
        // never a silent `let _ =` drop.
        use crate::coordinator::{NumericsEngine, ServerConfig};
        let hw = HardwareConfig::paper();
        let srv = JobServer::new(
            hw,
            NumericsEngine::golden(),
            ServerConfig { workers: 2, queue_capacity: 4, ..ServerConfig::default() },
        )
        .unwrap();
        let layers: Vec<GemmLayer> = alexnet_layers().into_iter().take(2).collect();
        let weights = NetworkWeights::register(&srv, &layers).unwrap();
        srv.unregister_b(weights.handles()[0]).unwrap();
        assert!(weights.unregister(&srv).is_err());
        let stats = srv.stats();
        assert_eq!(stats.unregister_failures, 1);
        assert_eq!(stats.registered_weights, 0, "sweep still released the rest");
    }

    #[test]
    fn break_even_is_positive_for_alexnet() {
        // Per-layer optimal saves real time, so some nonzero reconfig
        // budget is affordable.
        let (hw, acc) = setup();
        let be = break_even_reconfig_secs(&hw, &acc, &alexnet_layers()).unwrap();
        assert!(be > 0.0, "break-even {be}");
    }

    #[test]
    fn served_fixed_policy_matches_simulated_totals() {
        // The served path and the simulate-only path agree exactly on a
        // fixed schedule: same sim model, same accounting.
        use crate::coordinator::{NumericsEngine, ServerConfig};
        let (hw, acc) = setup();
        let srv = JobServer::new(
            hw.clone(),
            NumericsEngine::golden(),
            ServerConfig {
                workers: 4,
                queue_capacity: 8,
                batch_max_tasks: 0,
                batch_window: 1,
                cross_job_stealing: true,
                default_run: None,
                ..ServerConfig::default()
            },
        )
        .unwrap();
        let layers = vec![
            GemmLayer { name: "l0", m: 64, k: 32, n: 64 },
            GemmLayer { name: "l1", m: 48, k: 24, n: 40 },
        ];
        let run = RunConfig::square(2, 32);
        let served =
            schedule_network_served(&srv, &layers, Policy::Fixed(run), 1.0, 1).unwrap();
        let simulated =
            schedule_network(&hw, &acc, &layers, Policy::Fixed(run), 1.0).unwrap();
        assert_eq!(served.reconfigs, 0);
        assert_eq!(served.layers.len(), 2);
        assert!((served.total_secs - simulated.total_secs).abs() < 1e-12);
        assert!(served.layers.iter().all(|l| l.run == run));
    }

    #[test]
    fn served_empty_network_rejected() {
        use crate::coordinator::{NumericsEngine, ServerConfig};
        let (hw, _) = setup();
        let srv = JobServer::new(
            hw,
            NumericsEngine::golden(),
            ServerConfig { workers: 2, ..ServerConfig::default() },
        )
        .unwrap();
        assert!(
            schedule_network_served(&srv, &[], Policy::PerLayerOptimal, 0.0, 1).is_err()
        );
        let one = vec![GemmLayer { name: "l0", m: 16, k: 8, n: 16 }];
        assert!(
            schedule_network_served(&srv, &one, Policy::PerLayerOptimal, 0.0, 0).is_err(),
            "batch 0 is degenerate"
        );
    }

    #[test]
    fn served_conv_batch_packs_filter_once() {
        // A small conv net with an unknown-geometry conv layer (synthetic
        // patches) and a known one would be AlexNet-sized; use a dense
        // follower to exercise the mixed conv/FC fold. The conv layer's
        // shared B must be packed exactly once for the whole batch.
        use crate::coordinator::{NumericsEngine, ServerConfig};
        let (hw, _) = setup();
        let srv = JobServer::new(
            hw,
            NumericsEngine::golden(),
            ServerConfig {
                workers: 4,
                queue_capacity: 16,
                batch_max_tasks: 0,
                batch_window: 1,
                cross_job_stealing: true,
                default_run: None,
                ..ServerConfig::default()
            },
        )
        .unwrap();
        let layers = vec![
            GemmLayer { name: "convX", m: 12, k: 18, n: 36 },
            GemmLayer { name: "fcX", m: 16, k: 12, n: 20 },
        ];
        let run = RunConfig::square(2, 16);
        let batch = 4;
        let s =
            schedule_network_served(&srv, &layers, Policy::Fixed(run), 0.0, batch).unwrap();
        assert_eq!(s.layers.len(), 2);
        assert!(s.layers.iter().all(|l| l.run == run));
        let m = srv.metrics();
        // Layer 0: one shared-B group, B packed once, batch-1 packs
        // avoided. Layer 1: a lone dense job (one more A and B pack).
        assert_eq!(m.shared_b_groups(), 1);
        assert_eq!(m.b_panel_packs(), 2, "conv batch must pack its filter exactly once");
        assert_eq!(m.panels_shared(), batch as u64 - 1);
        assert_eq!(m.a_panel_packs(), batch as u64 + 1);
        assert_eq!(m.jobs(), batch as u64 + 1);
    }

    #[test]
    fn served_known_conv_layer_runs_real_im2col() {
        // conv3 (the smallest Table II conv GEMM) through the served
        // path with real im2col lowering: the layer completes, carries
        // the batch's summed time, and shares one packed filter.
        use crate::coordinator::{NumericsEngine, ServerConfig};
        let (hw, _) = setup();
        let srv = JobServer::new(
            hw,
            NumericsEngine::golden(),
            ServerConfig {
                workers: 4,
                queue_capacity: 8,
                batch_max_tasks: 0,
                batch_window: 1,
                cross_job_stealing: true,
                default_run: None,
                ..ServerConfig::default()
            },
        )
        .unwrap();
        let layers = vec![crate::cnn::layer("conv3").unwrap()];
        let run = RunConfig::square(4, 64);
        let s = schedule_network_served(&srv, &layers, Policy::Fixed(run), 0.0, 2).unwrap();
        assert_eq!(s.reconfigs, 0);
        assert!(s.layers[0].secs > 0.0);
        let m = srv.metrics();
        assert_eq!(m.b_panel_packs(), 1);
        assert_eq!(m.panels_shared(), 1);
        assert_eq!(m.jobs(), 2);
    }

    #[test]
    fn repeated_runs_reuse_registered_weights() {
        // The cross-call guarantee the registry exists for: register
        // once, stream several batches — each layer's operand packs
        // exactly once per process, later runs hit the cached pack.
        use crate::coordinator::{NumericsEngine, ServerConfig};
        let (hw, _) = setup();
        let srv = JobServer::new(
            hw,
            NumericsEngine::golden(),
            ServerConfig {
                workers: 4,
                queue_capacity: 16,
                batch_max_tasks: 0,
                batch_window: 1,
                cross_job_stealing: true,
                default_run: None,
                ..ServerConfig::default()
            },
        )
        .unwrap();
        let layers = vec![
            GemmLayer { name: "convX", m: 12, k: 18, n: 36 },
            GemmLayer { name: "fcX", m: 16, k: 12, n: 20 },
        ];
        let run = RunConfig::square(2, 16);
        let weights = NetworkWeights::register(&srv, &layers).unwrap();
        assert_eq!(weights.handles().len(), 2);
        let batch = 3;
        for _ in 0..3 {
            let s = schedule_network_served_with(
                &srv,
                &layers,
                &weights,
                Policy::Fixed(run),
                0.0,
                batch,
            )
            .unwrap();
            assert_eq!(s.layers.len(), 2);
        }
        let m = srv.metrics();
        // 2 operands x 3 runs: packed once apiece, hit twice apiece.
        assert_eq!(m.b_panel_packs(), 2, "weights pack once per process, not per run");
        assert_eq!(m.registry_misses(), 2);
        assert_eq!(m.registry_hits(), 4);
        assert_eq!(m.jobs(), 3 * (batch as u64 + 1));
        weights.unregister(&srv).unwrap();
        assert_eq!(srv.stats().registered_weights, 0);
    }

    #[test]
    fn served_network_at_two_dtypes_packs_per_variant() {
        // One registered network streamed at f32 and then bf16: each
        // layer's weight packs once per (handle, S, dtype) variant —
        // two layers x two precisions — with no cross-dtype hits.
        use crate::coordinator::{NumericsEngine, ServerConfig};
        let (hw, _) = setup();
        let srv = JobServer::new(
            hw,
            NumericsEngine::golden(),
            ServerConfig {
                workers: 4,
                queue_capacity: 16,
                batch_max_tasks: 0,
                batch_window: 1,
                cross_job_stealing: true,
                default_run: None,
                ..ServerConfig::default()
            },
        )
        .unwrap();
        let layers = vec![
            GemmLayer { name: "convX", m: 12, k: 18, n: 36 },
            GemmLayer { name: "fcX", m: 16, k: 12, n: 20 },
        ];
        let run = RunConfig::square(2, 16);
        let weights = NetworkWeights::register(&srv, &layers).unwrap();
        for dtype in [Dtype::F32, Dtype::Bf16] {
            let s = schedule_network_served_with_dtype(
                &srv,
                &layers,
                &weights,
                Policy::Fixed(run),
                0.0,
                2,
                dtype,
            )
            .unwrap();
            assert_eq!(s.layers.len(), 2);
            assert!(s.total_secs > 0.0);
        }
        let m = srv.metrics();
        assert_eq!(m.b_panel_packs(), 4, "one pack per (weight, dtype) variant");
        assert_eq!(m.registry_misses(), 4);
        assert_eq!(m.registry_hits(), 0, "dtype variants must not alias");
        weights.unregister(&srv).unwrap();
        assert_eq!(srv.stats().registered_weights, 0);
    }

    #[test]
    fn partial_registration_failure_leaks_nothing() {
        // A layer whose operand cannot register (degenerate K) must
        // roll back the layers registered before it.
        use crate::coordinator::{NumericsEngine, ServerConfig};
        let (hw, _) = setup();
        let srv =
            JobServer::new(hw, NumericsEngine::golden(), ServerConfig::default()).unwrap();
        let layers = vec![
            GemmLayer { name: "fc_ok", m: 16, k: 8, n: 16 },
            GemmLayer { name: "fc_bad", m: 16, k: 0, n: 16 },
        ];
        assert!(NetworkWeights::register(&srv, &layers).is_err());
        assert_eq!(srv.stats().registered_weights, 0, "failed registration must not leak");
    }

    #[test]
    fn single_layer_network_never_reconfigures() {
        let (hw, acc) = setup();
        let layers = vec![crate::cnn::layer("fc6").unwrap()];
        let s =
            schedule_network(&hw, &acc, &layers, Policy::PerLayerOptimal, 1.0).unwrap();
        assert_eq!(s.reconfigs, 0);
    }
}
