//! im2col streaming front-end: lower convolution layers to the
//! shared-operand GEMMs the serving runtime batches.
//!
//! The paper's Table II treats each conv layer as one GEMM via im2col
//! (Cong & Xiao, ref. 14) with `M` = output channels, `K` =
//! `in_channels x kh x kw`, `N` = output pixels. Under batched
//! inference every image of the batch multiplies the *same* filter
//! matrix, so the natural serving shape is the shared-B batch of
//! [`crate::coordinator::Submission::batched`]: one shared
//! B, many A. This module does the lowering in that orientation:
//!
//! * an input feature map is a [`Matrix`] of `in_channels` rows x
//!   `in_hw^2` columns (channel-major, row-major pixels within a
//!   channel);
//! * [`im2col_patches`] turns one image into the **patch-row matrix**
//!   `A = N x K`: row `n` is output pixel `n`'s receptive field,
//!   flattened `(channel, ky, kx)`-major — the transpose of the
//!   column-per-pixel im2col, chosen so the *filter* lands on the B
//!   side;
//! * the shared operand is `B = filters^T` (`K x M`, from the Table II
//!   `M x K` filter matrix), packed **once** per layer per batch by the
//!   server; each sub-result `C_i = A_i x B` is `N x M` (pixel-major
//!   feature map, one column per output channel).
//!
//! [`conv_direct`] is the audit-grade sliding-window oracle the GEMM
//! lowering is tested against, and [`conv_batch_operands`] bundles a
//! whole batch into the `(b, many_a)` pair the server consumes.
//! Grouped convolutions (AlexNet's two-GPU split) call this per group
//! with the group's channel slices, exactly like Table II lists the
//! per-group GEMM.

use crate::gemm::Matrix;

use super::ConvShape;

/// Flattened patch index of `(channel, ky, kx)` in a `K`-vector.
#[inline]
fn patch_idx(shape: &ConvShape, c: usize, ky: usize, kx: usize) -> usize {
    (c * shape.kernel + ky) * shape.kernel + kx
}

/// im2col in patch-row orientation: `input` is one image
/// (`in_channels x in_hw^2`, channel rows, pixels row-major); the
/// result is `N x K` with `N = out_hw^2` output pixels and
/// `K = in_channels * kernel^2`. Padding contributes exact zeros.
///
/// For grouped convolution pass the per-group channel slice and a
/// `ConvShape` whose `in_channels`/`groups` describe that group (i.e.
/// `groups = 1` on an already-sliced input).
pub fn im2col_patches(input: &Matrix, shape: &ConvShape) -> Matrix {
    let channels = shape.in_channels / shape.groups;
    let hw = shape.in_hw;
    assert_eq!(input.rows, channels, "input channel count mismatch");
    assert_eq!(input.cols, hw * hw, "input spatial size mismatch");
    let out = shape.out_hw();
    let k = channels * shape.kernel * shape.kernel;
    let mut patches = Matrix::zeros(out * out, k);
    for oy in 0..out {
        for ox in 0..out {
            let row = oy * out + ox;
            let base = row * k;
            for c in 0..channels {
                let chan = input.row(c);
                for ky in 0..shape.kernel {
                    // Input y of this kernel row; skip rows in the pad.
                    let iy = (oy * shape.stride + ky) as isize - shape.pad as isize;
                    if iy < 0 || iy as usize >= hw {
                        continue;
                    }
                    for kx in 0..shape.kernel {
                        let ix = (ox * shape.stride + kx) as isize - shape.pad as isize;
                        if ix < 0 || ix as usize >= hw {
                            continue;
                        }
                        patches.data[base + patch_idx(shape, c, ky, kx)] =
                            chan[iy as usize * hw + ix as usize];
                    }
                }
            }
        }
    }
    patches
}

/// Direct sliding-window convolution — the oracle the im2col lowering
/// is verified against. `filters` is the Table II `M x K` matrix
/// (`M` output channels, rows flattened `(channel, ky, kx)`-major);
/// the result is `M x N` (channel-major output feature map).
pub fn conv_direct(input: &Matrix, filters: &Matrix, shape: &ConvShape) -> Matrix {
    let channels = shape.in_channels / shape.groups;
    let hw = shape.in_hw;
    assert_eq!(input.rows, channels, "input channel count mismatch");
    assert_eq!(input.cols, hw * hw, "input spatial size mismatch");
    let k = channels * shape.kernel * shape.kernel;
    assert_eq!(filters.cols, k, "filter K mismatch");
    let out = shape.out_hw();
    let mut result = Matrix::zeros(filters.rows, out * out);
    for m in 0..filters.rows {
        let w = filters.row(m);
        for oy in 0..out {
            for ox in 0..out {
                let mut acc = 0.0f32;
                for c in 0..channels {
                    let chan = input.row(c);
                    for ky in 0..shape.kernel {
                        let iy = (oy * shape.stride + ky) as isize - shape.pad as isize;
                        if iy < 0 || iy as usize >= hw {
                            continue;
                        }
                        for kx in 0..shape.kernel {
                            let ix = (ox * shape.stride + kx) as isize - shape.pad as isize;
                            if ix < 0 || ix as usize >= hw {
                                continue;
                            }
                            acc += w[patch_idx(shape, c, ky, kx)]
                                * chan[iy as usize * hw + ix as usize];
                        }
                    }
                }
                result.data[m * out * out + oy * out + ox] = acc;
            }
        }
    }
    result
}

/// The shared B operand of one conv layer: `filters^T` (`K x M`, from
/// the Table II `M x K` filter matrix). This is the matrix a serving
/// deployment registers **once** with the job server's operand registry
/// ([`crate::coordinator::JobServer::register_b`]) so every batch of
/// every epoch resolves the same cached pack instead of repacking.
pub fn filter_operand(filters: &Matrix) -> Matrix {
    filters.transpose()
}

/// Lower a whole batch through one conv layer to the server's shared-B
/// shape: `(b, many_a)` with `b` = [`filter_operand`] (`K x M`, packed
/// once) and `many_a[i]` = image `i`'s patch rows (`N x K`). Each
/// sub-result `C_i = A_i x b` is the `N x M` pixel-major output feature
/// map — `C_i^T` is what [`conv_direct`] returns for the same image.
pub fn conv_batch_operands(
    inputs: &[Matrix],
    filters: &Matrix,
    shape: &ConvShape,
) -> (Matrix, Vec<Matrix>) {
    let b = filter_operand(filters);
    let many_a = inputs.iter().map(|img| im2col_patches(img, shape)).collect();
    (b, many_a)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::alexnet_conv_shapes;

    /// A small conv layer exercising stride, padding, and multiple
    /// channels at test-friendly sizes.
    fn small_shape() -> ConvShape {
        ConvShape {
            in_channels: 3,
            in_hw: 7,
            out_channels: 4,
            kernel: 3,
            stride: 2,
            pad: 1,
            groups: 1,
        }
    }

    #[test]
    fn patch_matrix_has_table2_dims() {
        let shape = small_shape();
        let (m, k, n) = shape.gemm_dims();
        let img = Matrix::random(shape.in_channels, shape.in_hw * shape.in_hw, 1);
        let p = im2col_patches(&img, &shape);
        assert_eq!((p.rows, p.cols), (n, k));
        let filters = Matrix::random(shape.out_channels, k, 2);
        assert_eq!(filters.rows, m);
    }

    #[test]
    fn im2col_gemm_equals_direct_convolution() {
        for (shape, seed) in [
            (small_shape(), 10u64),
            // No padding, stride 1: pure sliding window.
            (
                ConvShape {
                    in_channels: 2,
                    in_hw: 6,
                    out_channels: 3,
                    kernel: 3,
                    stride: 1,
                    pad: 0,
                    groups: 1,
                },
                11,
            ),
            // Kernel 1 degenerates to a per-pixel channel mix.
            (
                ConvShape {
                    in_channels: 4,
                    in_hw: 5,
                    out_channels: 2,
                    kernel: 1,
                    stride: 1,
                    pad: 0,
                    groups: 1,
                },
                12,
            ),
        ] {
            let (m, k, n) = shape.gemm_dims();
            let img = Matrix::random(shape.in_channels, shape.in_hw * shape.in_hw, seed);
            let filters = Matrix::random(shape.out_channels, k, seed + 100);
            let direct = conv_direct(&img, &filters, &shape);
            assert_eq!((direct.rows, direct.cols), (m, n));
            // Pixel-major GEMM orientation: patches x filters^T.
            let gemm = im2col_patches(&img, &shape).matmul(&filters.transpose());
            assert!(
                gemm.transpose().allclose(&direct, 1e-4),
                "lowering diverged for {shape:?}"
            );
        }
    }

    #[test]
    fn padded_border_patches_are_zero() {
        let shape = ConvShape {
            in_channels: 1,
            in_hw: 3,
            out_channels: 1,
            kernel: 3,
            stride: 1,
            pad: 1,
            groups: 1,
        };
        let img = Matrix::from_vec(1, 9, (1..=9).map(|v| v as f32).collect());
        let p = im2col_patches(&img, &shape);
        // Output pixel (0,0): the top row and left column of its patch
        // hang into the pad and must be exact zeros.
        let row = p.row(0);
        assert_eq!(&row[0..3], &[0.0, 0.0, 0.0]);
        assert_eq!(row[3], 0.0);
        assert_eq!(row[4], 1.0); // image (0,0)
        assert_eq!(row[8], 5.0); // image (1,1)
    }

    #[test]
    fn grouped_conv_runs_per_group_slice() {
        // A 2-group conv: each group sees half the input channels and
        // produces half the output channels, exactly Table II's
        // per-group GEMM.
        let shape = ConvShape {
            in_channels: 4,
            in_hw: 5,
            out_channels: 6,
            kernel: 3,
            stride: 1,
            pad: 1,
            groups: 2,
        };
        let (m, k, n) = shape.gemm_dims();
        assert_eq!((m, k), (3, 2 * 9));
        for g in 0..shape.groups {
            let img = Matrix::random(shape.in_channels / shape.groups, 25, 30 + g as u64);
            let filters = Matrix::random(m, k, 40 + g as u64);
            let direct = conv_direct(&img, &filters, &shape);
            let gemm = im2col_patches(&img, &shape).matmul(&filters.transpose());
            assert_eq!((gemm.rows, gemm.cols), (n, m));
            assert!(gemm.transpose().allclose(&direct, 1e-4));
        }
    }

    #[test]
    fn batch_operands_share_one_b() {
        let shape = small_shape();
        let (m, k, n) = shape.gemm_dims();
        let imgs: Vec<Matrix> = (0..3)
            .map(|i| Matrix::random(shape.in_channels, 49, 50 + i))
            .collect();
        let filters = Matrix::random(m, k, 60);
        let (b, many_a) = conv_batch_operands(&imgs, &filters, &shape);
        assert_eq!((b.rows, b.cols), (k, m));
        assert_eq!(many_a.len(), 3);
        for (img, a) in imgs.iter().zip(&many_a) {
            assert_eq!((a.rows, a.cols), (n, k));
            let direct = conv_direct(img, &filters, &shape);
            assert!(a.matmul(&b).transpose().allclose(&direct, 1e-4));
        }
    }

    #[test]
    fn alexnet_conv_shapes_lower_to_table2_patch_dims() {
        // The real workload's geometry: every Table II conv layer's
        // per-group patch matrix has (N, K) matching the listed GEMM.
        for (name, shape) in alexnet_conv_shapes() {
            let l = crate::cnn::layer(name).unwrap();
            let (m, k, n) = shape.gemm_dims();
            assert_eq!((m, k, n), (l.m, l.k, l.n), "{name}");
        }
    }
}
