//! Lock-free WQM for the coordinator's worker threads.
//!
//! The hardware WQM's per-queue counter lives in one place and every
//! pop/steal is a counter compare plus a FIFO op. The first software
//! twin serialized all of that behind one `Mutex<Wqm>` — every pop from
//! every worker contended one lock. [`AtomicWqm`] removes the lock: each
//! queue is a frozen task array plus a single packed `head|tail` word,
//! and a pop (front) or steal (back) is one CAS on that word.
//!
//! Linearizability argument: both endpoints live in the *same* atomic,
//! so a successful `compare_exchange` claims index `head` (pop) or
//! `tail - 1` (steal) with the emptiness check (`head < tail`) in the
//! same atomic step. Head only grows, tail only shrinks, and claimed
//! indices are therefore unique — every task is handed out exactly once
//! (the conservation invariant the threaded tests hammer). The task
//! array itself is never mutated after construction, so reading the
//! claimed slot needs no synchronization beyond the acquire on the CAS.
//!
//! Stealing policy matches the paper and [`super::Wqm`]: an empty queue
//! steals one task from the back of the *fullest* other queue. The
//! fullest-victim scan reads racy lengths (like the hardware's counter
//! snapshot), which can momentarily pick a second-fullest victim — the
//! policy is a heuristic; correctness never depends on it.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

use super::QueueStats;

/// Pack `(head, tail)` into one CAS-able word.
#[inline]
fn pack(head: u32, tail: u32) -> u64 {
    (u64::from(head) << 32) | u64::from(tail)
}

#[inline]
fn unpack(bounds: u64) -> (u32, u32) {
    ((bounds >> 32) as u32, bounds as u32)
}

#[derive(Debug)]
struct Queue<T> {
    /// Frozen at construction; slots are claimed via `bounds`, never
    /// overwritten.
    tasks: Vec<T>,
    /// `head << 32 | tail`: live tasks are `tasks[head..tail]`.
    bounds: AtomicU64,
    executed: AtomicU64,
    stolen_in: AtomicU64,
    stolen_out: AtomicU64,
}

impl<T: Copy> Queue<T> {
    fn new(tasks: Vec<T>) -> Self {
        assert!(u32::try_from(tasks.len()).is_ok(), "queue exceeds u32 tasks");
        let bounds = AtomicU64::new(pack(0, tasks.len() as u32));
        Self {
            tasks,
            bounds,
            executed: AtomicU64::new(0),
            stolen_in: AtomicU64::new(0),
            stolen_out: AtomicU64::new(0),
        }
    }

    fn len(&self) -> usize {
        let (head, tail) = unpack(self.bounds.load(Ordering::Relaxed));
        (tail - head) as usize
    }

    /// Claim the front task (FIFO local pop).
    fn pop_front(&self) -> Option<T> {
        let mut cur = self.bounds.load(Ordering::Acquire);
        loop {
            let (head, tail) = unpack(cur);
            if head >= tail {
                return None;
            }
            match self.bounds.compare_exchange_weak(
                cur,
                pack(head + 1, tail),
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => return Some(self.tasks[head as usize]),
                Err(now) => cur = now,
            }
        }
    }

    /// Claim the back task (steal — the tasks the owner would reach
    /// last, minimizing disruption of its stream).
    fn steal_back(&self) -> Option<T> {
        let mut cur = self.bounds.load(Ordering::Acquire);
        loop {
            let (head, tail) = unpack(cur);
            if head >= tail {
                return None;
            }
            match self.bounds.compare_exchange_weak(
                cur,
                pack(head, tail - 1),
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => return Some(self.tasks[(tail - 1) as usize]),
                Err(now) => cur = now,
            }
        }
    }
}

/// Lock-free work-stealing queue set: `N_p` frozen queues, atomic
/// endpoint words, shared by reference across workers (`pop` takes
/// `&self`).
#[derive(Debug)]
pub struct AtomicWqm<T> {
    queues: Vec<Queue<T>>,
    stealing: AtomicBool,
}

impl<T: Copy> AtomicWqm<T> {
    /// Build from an initial static partition (one Vec per array).
    pub fn from_partition(partition: Vec<Vec<T>>) -> Self {
        assert!(!partition.is_empty(), "need at least one queue");
        Self {
            queues: partition.into_iter().map(Queue::new).collect(),
            stealing: AtomicBool::new(true),
        }
    }

    /// Global switch — `false` models the no-stealing baseline ablation.
    pub fn set_stealing(&self, enabled: bool) {
        self.stealing.store(enabled, Ordering::Relaxed);
    }

    pub fn num_queues(&self) -> usize {
        self.queues.len()
    }

    /// Per-queue live counts (the WQM counters), as a racy snapshot.
    pub fn counters(&self) -> Vec<usize> {
        self.queues.iter().map(Queue::len).collect()
    }

    pub fn remaining(&self) -> usize {
        self.queues.iter().map(Queue::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    /// Snapshot of the per-queue statistics (same shape as
    /// [`super::Wqm::stats`]; `enqueued` is the initial load).
    pub fn stats(&self) -> Vec<QueueStats> {
        self.queues
            .iter()
            .map(|q| QueueStats {
                enqueued: q.tasks.len() as u64,
                executed: q.executed.load(Ordering::Relaxed),
                stolen_in: q.stolen_in.load(Ordering::Relaxed),
                stolen_out: q.stolen_out.load(Ordering::Relaxed),
            })
            .collect()
    }

    /// Pop for array `queue`; if its queue is empty and stealing is
    /// enabled, steal one task from the fullest non-empty queue.
    /// Returns `None` only once every reachable queue is empty.
    pub fn pop(&self, queue: usize) -> Option<T> {
        self.pop_with_source(queue).map(|(task, _)| task)
    }

    /// [`AtomicWqm::pop`] that also reports *which* queue the task was
    /// claimed from — the steal-provenance signal the serving layer's
    /// flight recorder stamps onto each task (`source != queue` means
    /// the task was stolen off another array's queue).
    pub fn pop_with_source(&self, queue: usize) -> Option<(T, usize)> {
        if let Some(task) = self.queues[queue].pop_front() {
            self.queues[queue].executed.fetch_add(1, Ordering::Relaxed);
            return Some((task, queue));
        }
        if !self.stealing.load(Ordering::Relaxed) {
            return None;
        }
        loop {
            let victim = self.fullest_other(queue)?;
            if let Some(task) = self.queues[victim].steal_back() {
                self.queues[victim].stolen_out.fetch_add(1, Ordering::Relaxed);
                self.queues[queue].stolen_in.fetch_add(1, Ordering::Relaxed);
                self.queues[queue].executed.fetch_add(1, Ordering::Relaxed);
                return Some((task, victim));
            }
            // Victim drained between the scan and the CAS — rescan. The
            // loop terminates: total remaining work is finite and
            // strictly shrinks under claims, and when every other queue
            // reads empty the scan returns None.
        }
    }

    /// Victim selection: fullest non-empty other queue, ties toward the
    /// lowest index (the paper's "queue with the most workloads").
    fn fullest_other(&self, requester: usize) -> Option<usize> {
        let mut best: Option<(usize, usize)> = None;
        for (q, queue) in self.queues.iter().enumerate() {
            if q == requester {
                continue;
            }
            let len = queue.len();
            if len == 0 {
                continue;
            }
            let better = match best {
                None => true,
                Some((_, best_len)) => len > best_len,
            };
            if better {
                best = Some((q, len));
            }
        }
        best.map(|(q, _)| q)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check;

    fn loaded(counts: &[usize]) -> AtomicWqm<usize> {
        let mut id = 0;
        let partition = counts
            .iter()
            .map(|&c| {
                (0..c)
                    .map(|_| {
                        id += 1;
                        id - 1
                    })
                    .collect()
            })
            .collect();
        AtomicWqm::from_partition(partition)
    }

    #[test]
    fn local_pop_is_fifo() {
        let w = loaded(&[3, 0]);
        assert_eq!(w.pop(0), Some(0));
        assert_eq!(w.pop(0), Some(1));
        assert_eq!(w.pop(0), Some(2));
    }

    #[test]
    fn empty_queue_steals_from_fullest_back() {
        let w = loaded(&[2, 0, 5]); // queue 1 empty; fullest is 2 (ids 2..7)
        assert_eq!(w.pop(1), Some(6));
        let stats = w.stats();
        assert_eq!(stats[1].stolen_in, 1);
        assert_eq!(stats[2].stolen_out, 1);
    }

    #[test]
    fn pop_with_source_reports_provenance() {
        let w = loaded(&[2, 0, 5]);
        // Local pop: source is the popper's own queue.
        assert_eq!(w.pop_with_source(0), Some((0, 0)));
        // Steal: source is the victim queue.
        assert_eq!(w.pop_with_source(1), Some((6, 2)));
        // Drained: None either way.
        let w2 = loaded(&[0]);
        assert_eq!(w2.pop_with_source(0), None);
    }

    #[test]
    fn stealing_disabled_returns_none() {
        let w = loaded(&[0, 5]);
        w.set_stealing(false);
        assert_eq!(w.pop(0), None);
        assert_eq!(w.remaining(), 5);
    }

    #[test]
    fn counters_track_claims() {
        let w = loaded(&[0, 3, 7, 5]);
        w.pop(0).unwrap();
        assert_eq!(w.counters(), vec![0, 3, 6, 5]);
    }

    #[test]
    fn drain_executes_everything_exactly_once() {
        let w = loaded(&[4, 0, 9, 1]);
        let mut seen = Vec::new();
        for q in 0..4 {
            while let Some(t) = w.pop(q) {
                seen.push(t);
            }
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..14).collect::<Vec<_>>());
        assert!(w.is_empty());
        assert_eq!(w.stats().iter().map(|s| s.executed).sum::<u64>(), 14);
    }

    #[test]
    fn prop_sequential_conservation_matches_locked_wqm_semantics() {
        check::cases(96, |rng| {
            let np = rng.range(1, 6);
            let counts: Vec<usize> = (0..np).map(|_| rng.range(0, 12)).collect();
            let total: usize = counts.iter().sum();
            let w = loaded(&counts);
            w.set_stealing(rng.bool());
            let mut seen = Vec::new();
            for _ in 0..rng.range(0, 200) {
                if let Some(t) = w.pop(rng.range(0, np)) {
                    seen.push(t);
                }
            }
            for q in 0..np {
                while let Some(t) = w.pop(q) {
                    seen.push(t);
                }
            }
            seen.sort_unstable();
            assert_eq!(seen, (0..total).collect::<Vec<_>>());
        });
    }

    #[test]
    fn threaded_drain_no_loss_no_duplication() {
        // The invariant the lock-free claim rests on, hammered from
        // many threads: every task claimed exactly once.
        let nthreads = 8;
        let per_queue = 2000;
        let w = loaded(&[per_queue; 4]);
        let total = 4 * per_queue;
        let mut all: Vec<usize> = Vec::with_capacity(total);
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for t in 0..nthreads {
                let w = &w;
                handles.push(s.spawn(move || {
                    let mut mine = Vec::new();
                    let mut q = t % 4;
                    while let Some(task) = w.pop(q) {
                        mine.push(task);
                        q = (q + 1) % 4;
                    }
                    mine
                }));
            }
            for h in handles {
                all.extend(h.join().unwrap());
            }
        });
        all.sort_unstable();
        assert_eq!(all, (0..total).collect::<Vec<_>>());
        let stats = w.stats();
        assert_eq!(stats.iter().map(|s| s.executed).sum::<u64>(), total as u64);
        assert_eq!(
            stats.iter().map(|s| s.stolen_in).sum::<u64>(),
            stats.iter().map(|s| s.stolen_out).sum::<u64>()
        );
    }

    #[test]
    fn threaded_single_queue_contention() {
        // All threads fight over one queue's packed word.
        let w = loaded(&[10_000]);
        let mut all: Vec<usize> = Vec::new();
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for _ in 0..8 {
                let w = &w;
                handles.push(s.spawn(move || {
                    let mut mine = Vec::new();
                    while let Some(task) = w.pop(0) {
                        mine.push(task);
                    }
                    mine
                }));
            }
            for h in handles {
                all.extend(h.join().unwrap());
            }
        });
        all.sort_unstable();
        assert_eq!(all, (0..10_000).collect::<Vec<_>>());
    }

    #[test]
    fn pack_unpack_roundtrip() {
        for (h, t) in [(0u32, 0u32), (1, 5), (u32::MAX, u32::MAX), (7, u32::MAX)] {
            assert_eq!(unpack(pack(h, t)), (h, t));
        }
    }
}
