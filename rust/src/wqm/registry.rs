//! Epoch-tagged job table — the membership layer under cross-job work
//! stealing.
//!
//! The paper's WQM equalizes load *between arrays* of one job; the
//! serving runtime must also equalize load *between jobs*. The registry
//! is the shared table the server's persistent workers scan for live
//! jobs: each entry is an `Arc` to a job (in practice a job's
//! [`super::AtomicWqm`] plus its execution context — operands and the
//! refcounted packed-panel halves its sub-jobs share) tagged with the
//! epoch at which it was registered.
//!
//! Concurrency design: membership changes (register/unregister) are rare
//! compared to pops, so they take a plain mutex and bump a global epoch
//! counter. Registration is multi-producer by construction: the server's
//! N admission shards plan and pack independently and publish into this
//! one table concurrently, so cross-job stealing still sees a single
//! pool — sharding the front never partitions the work. Workers keep a private snapshot of the table and revalidate
//! it with a single relaxed-cost atomic load per scan
//! ([`JobRegistry::epoch`]); only when the epoch moved do they pay the
//! lock for a fresh [`JobRegistry::snapshot`]. The hot path (popping
//! tasks from a job already in
//! the snapshot) never touches the registry at all — it goes straight to
//! the job's lock-free WQM. A worker's stale snapshot can briefly pin a
//! finished job's `Arc` (bounded by its next epoch check) and can
//! briefly miss a new job (bounded the same way); neither affects the
//! conservation invariant, because tasks live in the per-job WQMs, not
//! here.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Shared table of live jobs, epoch-tagged for cheap staleness checks.
///
/// `J` is the per-job state (the server uses its `ActiveJob`); the
/// registry only needs to refcount it.
#[derive(Debug)]
pub struct JobRegistry<J> {
    /// Bumped on every membership change; never decreases. Registration
    /// tags are drawn from this counter, so tags are unique per table.
    epoch: AtomicU64,
    /// Live jobs in registration (FIFO) order.
    jobs: Mutex<Vec<(u64, Arc<J>)>>,
}

impl<J> JobRegistry<J> {
    pub fn new() -> Self {
        Self { epoch: AtomicU64::new(0), jobs: Mutex::new(Vec::new()) }
    }

    /// Current epoch. A worker whose cached snapshot was taken at an
    /// older epoch must refresh before trusting membership.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// Add a job; returns its unique tag. Bumps the epoch.
    pub fn register(&self, job: Arc<J>) -> u64 {
        let mut jobs = self.jobs.lock().unwrap();
        let tag = self.epoch.fetch_add(1, Ordering::AcqRel) + 1;
        jobs.push((tag, job));
        tag
    }

    /// Remove the job with `tag`. Returns whether it was present. Bumps
    /// the epoch when it was.
    pub fn unregister(&self, tag: u64) -> bool {
        let mut jobs = self.jobs.lock().unwrap();
        let before = jobs.len();
        jobs.retain(|(t, _)| *t != tag);
        let removed = jobs.len() != before;
        if removed {
            self.epoch.fetch_add(1, Ordering::AcqRel);
        }
        removed
    }

    /// Consistent `(epoch, live jobs)` snapshot, FIFO order. The epoch is
    /// read under the membership lock, so it matches the returned list
    /// exactly.
    pub fn snapshot(&self) -> (u64, Vec<(u64, Arc<J>)>) {
        let jobs = self.jobs.lock().unwrap();
        (self.epoch.load(Ordering::Acquire), jobs.clone())
    }

    pub fn len(&self) -> usize {
        self.jobs.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<J> Default for JobRegistry<J> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_returns_unique_tags() {
        let reg: JobRegistry<usize> = JobRegistry::new();
        let a = reg.register(Arc::new(1));
        let b = reg.register(Arc::new(2));
        assert_ne!(a, b);
        assert_eq!(reg.len(), 2);
    }

    #[test]
    fn unregister_removes_and_reports() {
        let reg: JobRegistry<usize> = JobRegistry::new();
        let tag = reg.register(Arc::new(7));
        assert!(reg.unregister(tag));
        assert!(!reg.unregister(tag));
        assert!(reg.is_empty());
    }

    #[test]
    fn epoch_moves_on_every_membership_change() {
        let reg: JobRegistry<usize> = JobRegistry::new();
        let e0 = reg.epoch();
        let tag = reg.register(Arc::new(0));
        let e1 = reg.epoch();
        assert!(e1 > e0);
        reg.unregister(tag);
        assert!(reg.epoch() > e1);
        // Unregistering a missing tag is not a membership change.
        let e2 = reg.epoch();
        reg.unregister(tag);
        assert_eq!(reg.epoch(), e2);
    }

    #[test]
    fn snapshot_is_fifo_and_matches_epoch() {
        let reg: JobRegistry<&'static str> = JobRegistry::new();
        reg.register(Arc::new("first"));
        reg.register(Arc::new("second"));
        let (epoch, jobs) = reg.snapshot();
        assert_eq!(epoch, reg.epoch());
        let order: Vec<&str> = jobs.iter().map(|(_, j)| **j).collect();
        assert_eq!(order, vec!["first", "second"]);
    }

    #[test]
    fn stale_snapshot_detected_by_epoch_check() {
        let reg: JobRegistry<usize> = JobRegistry::new();
        let (seen, _) = reg.snapshot();
        reg.register(Arc::new(1));
        assert_ne!(reg.epoch(), seen);
        let (seen, _) = reg.snapshot();
        assert_eq!(reg.epoch(), seen);
    }

    #[test]
    fn concurrent_shard_registration_yields_one_pool() {
        // The sharded-dispatcher contract: several "shards" registering
        // concurrently produce unique tags and one coherent table — a
        // reader snapshot sees every published job exactly once.
        let reg = Arc::new(JobRegistry::<u64>::new());
        std::thread::scope(|s| {
            for shard in 0..4u64 {
                let reg = &reg;
                s.spawn(move || {
                    for i in 0..25 {
                        reg.register(Arc::new(shard * 100 + i));
                    }
                });
            }
        });
        let (_, jobs) = reg.snapshot();
        assert_eq!(jobs.len(), 100);
        let mut tags: Vec<u64> = jobs.iter().map(|(t, _)| *t).collect();
        tags.sort_unstable();
        tags.dedup();
        assert_eq!(tags.len(), 100, "tags unique across shards");
        let mut vals: Vec<u64> = jobs.iter().map(|(_, j)| **j).collect();
        vals.sort_unstable();
        vals.dedup();
        assert_eq!(vals.len(), 100, "every shard's jobs all present");
    }

    #[test]
    fn threaded_register_unregister_keeps_table_consistent() {
        let reg = Arc::new(JobRegistry::<u64>::new());
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let reg = &reg;
                s.spawn(move || {
                    for i in 0..50 {
                        let tag = reg.register(Arc::new(t * 1000 + i));
                        assert!(reg.unregister(tag));
                    }
                });
            }
        });
        assert!(reg.is_empty());
        // 4 threads x 50 iterations x 2 membership changes each.
        assert_eq!(reg.epoch(), 400);
    }
}
