//! Workload Queue Management — Section III-B.
//!
//! One FIFO workload queue per PE array, a counter per queue, and a
//! stealing controller: when a queue runs empty, the controller takes one
//! task from the *fullest* non-empty queue (comparing counters) and loads
//! it into the empty queue. Concurrent steal requests are serialized by a
//! round-robin arbiter so no array starves.
//!
//! The module is generic over the task type so both the cycle simulator
//! (over [`crate::blocking::BlockTask`]) and the async coordinator (over
//! job handles) reuse the exact same policy, and so the proptests pin the
//! conservation invariants once for everyone.
//!
//! Two implementations share the policy and the [`QueueStats`] shape:
//!
//! * [`Wqm`] — the single-owner (`&mut self`) deque version the
//!   simulators step; supports pushes and the round-robin arbiter;
//! * [`atomic::AtomicWqm`] — the lock-free (`&self`) version the
//!   coordinator's worker threads share: frozen queues with one packed
//!   `head|tail` CAS word each, no `Mutex` on the pop/steal fast path.
//!
//! [`registry::JobRegistry`] extends the stealing scope from arrays to
//! *jobs*: an epoch-tagged table of live per-job `AtomicWqm`s that the
//! serving runtime's persistent workers scan, so an idle worker can
//! steal from the fullest queue of any live job, not just its own. The
//! registered job state carries each sub-job's packed operands as
//! refcounted halves (`Arc<PackedA>` / `Arc<PackedB>`): a worker's
//! table snapshot pins at most one `Arc` per live job, and a shared-B
//! batch publishes one packed B across its whole task fan-out instead
//! of one per sub-job.

pub mod atomic;
pub mod registry;

pub use atomic::AtomicWqm;
pub use registry::JobRegistry;

use std::collections::VecDeque;

/// Per-queue statistics the WQM exposes to the metrics layer.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct QueueStats {
    /// Tasks that entered this queue (initial load + stolen in).
    pub enqueued: u64,
    /// Tasks popped by this queue's array.
    pub executed: u64,
    /// Tasks this queue stole from others.
    pub stolen_in: u64,
    /// Tasks other queues stole from this one.
    pub stolen_out: u64,
}

/// The WQM: `N_p` workload queues + counters + stealing controller.
#[derive(Debug, Clone)]
pub struct Wqm<T> {
    queues: Vec<VecDeque<T>>,
    stats: Vec<QueueStats>,
    /// Round-robin arbiter cursor for concurrent steal requests.
    arbiter: usize,
    /// Global switch — `false` models the no-stealing baseline ablation.
    stealing_enabled: bool,
}

impl<T> Wqm<T> {
    pub fn new(np: usize) -> Self {
        assert!(np >= 1, "need at least one queue");
        Self {
            queues: (0..np).map(|_| VecDeque::new()).collect(),
            stats: vec![QueueStats::default(); np],
            arbiter: 0,
            stealing_enabled: true,
        }
    }

    /// Build from an initial static partition (one Vec per array).
    pub fn from_partition(partition: Vec<Vec<T>>) -> Self {
        let mut wqm = Self::new(partition.len());
        for (q, tasks) in partition.into_iter().enumerate() {
            for t in tasks {
                wqm.push(q, t);
            }
        }
        wqm
    }

    pub fn set_stealing(&mut self, enabled: bool) {
        self.stealing_enabled = enabled;
    }

    pub fn num_queues(&self) -> usize {
        self.queues.len()
    }

    /// The per-queue counters the stealing controller compares.
    pub fn counters(&self) -> Vec<usize> {
        self.queues.iter().map(VecDeque::len).collect()
    }

    pub fn remaining(&self) -> usize {
        self.queues.iter().map(VecDeque::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    pub fn stats(&self) -> &[QueueStats] {
        &self.stats
    }

    pub fn push(&mut self, queue: usize, task: T) {
        self.stats[queue].enqueued += 1;
        self.queues[queue].push_back(task);
    }

    /// Pop for array `queue` *without* stealing (baseline behaviour).
    pub fn pop_local(&mut self, queue: usize) -> Option<T> {
        let t = self.queues[queue].pop_front();
        if t.is_some() {
            self.stats[queue].executed += 1;
        }
        t
    }

    /// Pop for array `queue`; if its queue is empty and stealing is
    /// enabled, steal one task from the fullest non-empty queue.
    pub fn pop(&mut self, queue: usize) -> Option<T> {
        if let Some(t) = self.pop_local(queue) {
            return Some(t);
        }
        if !self.stealing_enabled {
            return None;
        }
        let victim = self.fullest_other(queue)?;
        // Steal from the *back* of the victim: those are the tasks its
        // array would reach last, minimizing disruption of its stream.
        let t = self.queues[victim].pop_back()?;
        self.stats[victim].stolen_out += 1;
        self.stats[queue].stolen_in += 1;
        self.stats[queue].executed += 1;
        Some(t)
    }

    /// The victim-selection rule: fullest non-empty queue (by counter),
    /// ties broken toward the lowest index — matching "select the
    /// workload queue with the most workloads as target".
    fn fullest_other(&self, requester: usize) -> Option<usize> {
        self.queues
            .iter()
            .enumerate()
            .filter(|(q, dq)| *q != requester && !dq.is_empty())
            .max_by(|(qa, a), (qb, b)| a.len().cmp(&b.len()).then(qb.cmp(qa)))
            .map(|(q, _)| q)
    }

    /// Serve a set of concurrent steal/pop requests in round-robin order
    /// starting at the arbiter cursor — one grant per requester, cursor
    /// advances past the first requester served (Section III-B's arbiter).
    pub fn arbitrate(&mut self, requesters: &[usize]) -> Vec<(usize, Option<T>)> {
        let np = self.num_queues();
        let mut order: Vec<usize> = Vec::with_capacity(requesters.len());
        for off in 0..np {
            let q = (self.arbiter + off) % np;
            if requesters.contains(&q) {
                order.push(q);
            }
        }
        if let Some(&first) = order.first() {
            self.arbiter = (first + 1) % np;
        }
        order.into_iter().map(|q| (q, self.pop(q))).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check;

    fn loaded(counts: &[usize]) -> Wqm<usize> {
        let mut id = 0;
        let partition = counts
            .iter()
            .map(|&c| {
                (0..c)
                    .map(|_| {
                        id += 1;
                        id - 1
                    })
                    .collect()
            })
            .collect();
        Wqm::from_partition(partition)
    }

    #[test]
    fn local_pop_is_fifo() {
        let mut w = loaded(&[3, 0]);
        assert_eq!(w.pop(0), Some(0));
        assert_eq!(w.pop(0), Some(1));
        assert_eq!(w.pop(0), Some(2));
        assert_eq!(w.pop_local(0), None);
    }

    #[test]
    fn empty_queue_steals_from_fullest() {
        let mut w = loaded(&[2, 0, 5]); // queue 1 is empty; fullest is 2
        let t = w.pop(1).unwrap();
        // Stolen from the back of queue 2 (ids 2..7 -> back is 6).
        assert_eq!(t, 6);
        assert_eq!(w.stats()[1].stolen_in, 1);
        assert_eq!(w.stats()[2].stolen_out, 1);
    }

    #[test]
    fn stealing_disabled_returns_none() {
        let mut w = loaded(&[0, 5]);
        w.set_stealing(false);
        assert_eq!(w.pop(0), None);
        assert_eq!(w.remaining(), 5);
    }

    #[test]
    fn steal_victim_is_max_counter() {
        let mut w = loaded(&[0, 3, 7, 5]);
        w.pop(0).unwrap();
        assert_eq!(w.counters(), vec![0, 3, 6, 5]);
    }

    #[test]
    fn arbiter_round_robins() {
        let mut w = loaded(&[0, 0, 8, 8]);
        // Both 0 and 1 request concurrently; arbiter starts at 0.
        let grants = w.arbitrate(&[0, 1]);
        assert_eq!(grants.len(), 2);
        assert_eq!(grants[0].0, 0); // served first this round
        let grants = w.arbitrate(&[0, 1]);
        assert_eq!(grants[0].0, 1); // cursor advanced: 1 served first now
    }

    #[test]
    fn drain_executes_everything_exactly_once() {
        let mut w = loaded(&[4, 0, 9, 1]);
        let mut seen = Vec::new();
        let mut q = 0;
        while let Some(t) = w.pop(q % 4) {
            seen.push(t);
            q += 1;
        }
        // A single pop stream from one queue can stall while others hold
        // work; rotate until fully drained.
        for qq in 0..4 {
            while let Some(t) = w.pop(qq) {
                seen.push(t);
            }
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..14).collect::<Vec<_>>());
    }

    #[test]
    fn arbiter_skips_non_requesters() {
        let mut w = loaded(&[5, 5, 5, 5]);
        let grants = w.arbitrate(&[2]);
        assert_eq!(grants.len(), 1);
        assert_eq!(grants[0].0, 2);
        // Cursor advanced past 2: next tie starts at 3.
        let grants = w.arbitrate(&[1, 3]);
        assert_eq!(grants[0].0, 3);
    }

    #[test]
    fn arbitrate_empty_request_set() {
        let mut w: Wqm<usize> = loaded(&[2, 2]);
        assert!(w.arbitrate(&[]).is_empty());
    }

    #[test]
    fn steal_chain_drains_everything_through_one_queue() {
        // One array can finish the whole problem alone via stealing —
        // the degenerate case of the paper's "idle array acquires tasks".
        let mut w = loaded(&[0, 7, 3, 2]);
        let mut n = 0;
        while w.pop(0).is_some() {
            n += 1;
        }
        assert_eq!(n, 12);
        assert_eq!(w.stats()[0].stolen_in, 12);
    }

    #[test]
    fn push_after_drain_reactivates_queue() {
        let mut w = loaded(&[1]);
        assert_eq!(w.pop(0), Some(0));
        assert_eq!(w.pop(0), None);
        w.push(0, 99);
        assert_eq!(w.pop(0), Some(99));
    }

    /// Conservation: with any interleaving of pops across queues, every
    /// task is executed exactly once and none is lost.
    #[test]
    fn prop_no_loss_no_duplication() {
        check::cases(128, |rng| {
            let np = rng.range(1, 6);
            let counts: Vec<usize> = (0..np).map(|_| rng.range(0, 12)).collect();
            let total: usize = counts.iter().sum();
            let steal = rng.bool();
            let mut w = loaded(&counts);
            w.set_stealing(steal);
            let mut seen = Vec::new();
            for _ in 0..rng.range(0, 200) {
                let q = rng.range(0, np);
                if let Some(t) = w.pop(q) {
                    seen.push(t);
                }
            }
            // Drain the rest deterministically.
            for q in 0..np {
                while let Some(t) = w.pop(q) {
                    seen.push(t);
                }
            }
            seen.sort_unstable();
            assert_eq!(seen, (0..total).collect::<Vec<_>>());
        });
    }

    /// With stealing on, a requester never comes back empty while any
    /// queue still holds work.
    #[test]
    fn prop_no_idle_while_work_remains() {
        check::cases(128, |rng| {
            let np = rng.range(2, 6);
            let counts: Vec<usize> = (0..np).map(|_| rng.range(0, 12)).collect();
            if counts.iter().sum::<usize>() == 0 {
                return;
            }
            let q = rng.range(0, np);
            let mut w = loaded(&counts);
            assert!(w.pop(q).is_some());
        });
    }

    /// Counters always equal actual queue lengths (the WQM hardware
    /// invariant the controller's comparisons rely on).
    #[test]
    fn prop_counters_consistent() {
        check::cases(128, |rng| {
            let np = rng.range(1, 5);
            let counts: Vec<usize> = (0..np).map(|_| rng.range(0, 10)).collect();
            let mut w = loaded(&counts);
            for _ in 0..rng.range(0, 50) {
                let q = rng.range(0, np);
                w.pop(q);
                assert_eq!(w.remaining(), w.counters().iter().sum::<usize>());
            }
        });
    }
}
