//! `artifacts/manifest.tsv` — the contract between `python/compile/aot.py`
//! and the rust runtime. Python writes both a human-friendly
//! `manifest.json` and this TSV twin; rust reads the TSV (the offline
//! vendored crate set has no JSON parser, and the schema is three flat
//! record types — TSV is the honest format).
//!
//! Line format (tab-separated, `#` comments):
//! ```text
//! task\t<name>\t<file>\t<si>\t<kc>\t<sj>
//! full\t<name>\t<file>\t<n>
//! alexnet\t<layer>\t<m>\t<k>\t<n>
//! ```

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// One task-executable entry (`C' = C + A @ B` at fixed panel shape).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TaskShapeEntry {
    pub name: String,
    pub file: String,
    pub si: usize,
    pub kc: usize,
    pub sj: usize,
}

/// One self-contained `A @ B` entry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FullEntry {
    pub name: String,
    pub file: String,
    pub n: usize,
}

#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub tasks: Vec<TaskShapeEntry>,
    pub full: Vec<FullEntry>,
    /// Table II layer name -> [M, K, N]; asserted against `crate::cnn`.
    pub alexnet: BTreeMap<String, [usize; 3]>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!("read {} — run `make artifacts`", path.display())
        })?;
        let m = Self::parse(&text)?;
        m.validate()?;
        Ok(m)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let mut m = Self::default();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let f: Vec<&str> = line.split('\t').collect();
            let ctx = || format!("manifest.tsv line {}: {line:?}", lineno + 1);
            match f.as_slice() {
                ["task", name, file, si, kc, sj] => m.tasks.push(TaskShapeEntry {
                    name: name.to_string(),
                    file: file.to_string(),
                    si: si.parse().with_context(ctx)?,
                    kc: kc.parse().with_context(ctx)?,
                    sj: sj.parse().with_context(ctx)?,
                }),
                ["full", name, file, n] => m.full.push(FullEntry {
                    name: name.to_string(),
                    file: file.to_string(),
                    n: n.parse().with_context(ctx)?,
                }),
                ["alexnet", layer, mm, kk, nn] => {
                    m.alexnet.insert(
                        layer.to_string(),
                        [
                            mm.parse().with_context(ctx)?,
                            kk.parse().with_context(ctx)?,
                            nn.parse().with_context(ctx)?,
                        ],
                    );
                }
                _ => bail!("{}: unknown record", ctx()),
            }
        }
        Ok(m)
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(!self.tasks.is_empty(), "manifest lists no task shapes");
        for t in &self.tasks {
            anyhow::ensure!(
                t.si > 0 && t.kc > 0 && t.sj > 0,
                "degenerate task shape {}",
                t.name
            );
        }
        // The Python model and the rust cnn module must agree on Table II.
        for (name, &[m, k, n]) in &self.alexnet {
            if let Some(layer) = crate::cnn::layer(name) {
                anyhow::ensure!(
                    (layer.m, layer.k, layer.n) == (m, k, n),
                    "layer {name}: python says {m}x{k}x{n}, rust says {}x{}x{}",
                    layer.m,
                    layer.k,
                    layer.n
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_and_cross_checks() {
        let text = "# comment\n\
                    task\tt\tt.hlo.txt\t32\t128\t32\n\
                    full\tg\tg.hlo.txt\t256\n\
                    alexnet\tconv2\t128\t1200\t729\n";
        let m = Manifest::parse(text).unwrap();
        m.validate().unwrap();
        assert_eq!(m.tasks[0].si, 32);
        assert_eq!(m.full[0].n, 256);
        assert_eq!(m.alexnet["conv2"], [128, 1200, 729]);
    }

    #[test]
    fn mismatched_alexnet_shape_rejected() {
        let text = "task\tt\tt.hlo.txt\t32\t128\t32\n\
                    alexnet\tconv2\t128\t1200\t999\n";
        let m = Manifest::parse(text).unwrap();
        assert!(m.validate().is_err());
    }

    #[test]
    fn empty_tasks_rejected() {
        let m = Manifest::parse("full\tg\tg.hlo.txt\t256\n").unwrap();
        assert!(m.validate().is_err());
    }

    #[test]
    fn malformed_lines_rejected() {
        assert!(Manifest::parse("task\tonly\ttwo\n").is_err());
        assert!(Manifest::parse("task\tt\tf\tx\t128\t32\n").is_err());
        assert!(Manifest::parse("what\tis\tthis\n").is_err());
    }
}
