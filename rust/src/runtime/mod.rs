//! PJRT runtime — loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`, built once by `make artifacts`) and executes
//! them on the CPU PJRT client. This is the only place numerics leave
//! rust; Python is never on this path.
//!
//! The workload unit mirrors the accelerator's: a *task executable*
//! computes `C' = C + A_panel @ B_panel` for a fixed `(S_i, KC, S_j)`
//! panel shape (the L1 Pallas kernel under the hood). Arbitrary block
//! products are built by tiling rows/columns to an available shape and
//! threading `C` through K-chunks — exactly how the PE array's `M_c`
//! accumulates across the K loop.

mod manifest;

pub use manifest::{FullEntry, Manifest, TaskShapeEntry};

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::gemm::Matrix;

/// A compiled task executable and its panel geometry.
struct TaskExe {
    si: usize,
    kc: usize,
    sj: usize,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT-backed GEMM engine.
pub struct Runtime {
    tasks: Vec<TaskExe>,
    full: HashMap<usize, xla::PjRtLoadedExecutable>,
    pub dir: PathBuf,
}

fn xerr(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("xla: {e}")
}

impl Runtime {
    /// Load and compile every artifact listed in `manifest.json`.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(xerr)?;

        let mut tasks = Vec::new();
        for entry in &manifest.tasks {
            let exe = Self::compile(&client, &dir.join(&entry.file))?;
            tasks.push(TaskExe { si: entry.si, kc: entry.kc, sj: entry.sj, exe });
        }
        // Largest panels first: the chunking loop prefers them.
        tasks.sort_by(|a, b| (b.si, b.kc).cmp(&(a.si, a.kc)));

        let mut full = HashMap::new();
        for entry in &manifest.full {
            full.insert(entry.n, Self::compile(&client, &dir.join(&entry.file))?);
        }
        Ok(Self { tasks, full, dir })
    }

    fn compile(
        client: &xla::PjRtClient,
        path: &Path,
    ) -> anyhow::Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(xerr)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        client.compile(&comp).map_err(xerr)
    }

    /// Convenience: load from `$MARR_ARTIFACTS` or `./artifacts`.
    pub fn load_default() -> anyhow::Result<Self> {
        let dir = std::env::var("MARR_ARTIFACTS")
            .unwrap_or_else(|_| "artifacts".to_string());
        Self::load(dir)
    }

    /// Panel shapes available, largest first — `(si, kc, sj)`.
    pub fn task_shapes(&self) -> Vec<(usize, usize, usize)> {
        self.tasks.iter().map(|t| (t.si, t.kc, t.sj)).collect()
    }

    /// Pick the largest square tile `<= want` (artifacts ship 16..128),
    /// falling back to the smallest available for tiny blocks.
    fn tile_for(&self, want: usize) -> anyhow::Result<usize> {
        self.tasks
            .iter()
            .filter(|t| t.si == t.sj && t.si <= want)
            .map(|t| t.si)
            .max()
            .or_else(|| {
                self.tasks.iter().filter(|t| t.si == t.sj).map(|t| t.si).min()
            })
            .ok_or_else(|| anyhow::anyhow!("no square task artifacts loaded"))
    }

    fn literal(m: &Matrix) -> anyhow::Result<xla::Literal> {
        xla::Literal::vec1(&m.data)
            .reshape(&[m.rows as i64, m.cols as i64])
            .map_err(xerr)
    }

    fn unpack(result: xla::Literal, rows: usize, cols: usize) -> anyhow::Result<Matrix> {
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1().map_err(xerr)?;
        Ok(Matrix::from_vec(rows, cols, out.to_vec::<f32>().map_err(xerr)?))
    }

    /// One accumulation step `C' = C + A @ B` on task executable
    /// `exe_idx`. Operands must already have the exact panel shape.
    fn run_task_exe(
        &self,
        exe_idx: usize,
        a: &Matrix,
        b: &Matrix,
        c: &Matrix,
    ) -> anyhow::Result<Matrix> {
        let t = &self.tasks[exe_idx];
        debug_assert_eq!((a.rows, a.cols), (t.si, t.kc));
        debug_assert_eq!((b.rows, b.cols), (t.kc, t.sj));
        let out = t
            .exe
            .execute::<xla::Literal>(&[
                Self::literal(a)?,
                Self::literal(b)?,
                Self::literal(c)?,
            ])
            .map_err(xerr)?[0][0]
            .to_literal_sync()
            .map_err(xerr)?;
        Self::unpack(out, t.si, t.sj)
    }

    /// Compute one sub-block product `SA x SB` (`rows x k` times
    /// `k x cols`, any sizes) by tiling to the available panel shapes:
    /// the runtime analogue of one WQM task.
    pub fn block_product(&self, sa: &Matrix, sb: &Matrix) -> anyhow::Result<Matrix> {
        anyhow::ensure!(sa.cols == sb.rows, "contraction mismatch");
        let tile = self.tile_for(sa.rows.max(sb.cols))?;
        let k = sa.cols;
        let mut c = Matrix::zeros(sa.rows, sb.cols);
        let mut r0 = 0;
        while r0 < sa.rows {
            let mut c0 = 0;
            while c0 < sb.cols {
                let block = self.tile_product(sa, sb, r0, c0, tile, k)?;
                let rows = tile.min(sa.rows - r0);
                let cols = tile.min(sb.cols - c0);
                c.set_block(r0, c0, &block.block(0, 0, rows, cols));
                c0 += tile;
            }
            r0 += tile;
        }
        Ok(c)
    }

    /// One `tile x tile` output block, accumulated over K chunks chosen
    /// greedily from the available `kc` variants (largest first), with
    /// the ragged tail zero-padded — Section IV's padding, applied at
    /// the artifact boundary.
    fn tile_product(
        &self,
        sa: &Matrix,
        sb: &Matrix,
        r0: usize,
        c0: usize,
        tile: usize,
        k: usize,
    ) -> anyhow::Result<Matrix> {
        let min_kc = self.min_kc(tile);
        let mut c = Matrix::zeros(tile, tile);
        let mut k0 = 0;
        while k0 < k {
            // Largest kc that still fits the remaining depth; the
            // smallest kc otherwise (its tail will be zero-padded).
            let exe_idx = self
                .tasks
                .iter()
                .position(|t| {
                    t.si == tile
                        && t.sj == tile
                        && (k0 + t.kc <= k || t.kc == min_kc)
                })
                .ok_or_else(|| anyhow::anyhow!("no task exe for tile {tile}"))?;
            let kc = self.tasks[exe_idx].kc;
            // Gather the (padded) A and B panels for this chunk. Row-wise
            // memcpy, not per-element loops — this gather sits on the
            // coordinator's hot path (see EXPERIMENTS.md §Perf).
            let valid_k = kc.min(k - k0);
            let valid_rows = tile.min(sa.rows.saturating_sub(r0));
            let valid_cols = tile.min(sb.cols.saturating_sub(c0));
            let mut a = Matrix::zeros(tile, kc);
            for i in 0..valid_rows {
                let src = (r0 + i) * sa.cols + k0;
                a.data[i * kc..i * kc + valid_k]
                    .copy_from_slice(&sa.data[src..src + valid_k]);
            }
            let mut b = Matrix::zeros(kc, tile);
            for kk in 0..valid_k {
                let src = (k0 + kk) * sb.cols + c0;
                b.data[kk * tile..kk * tile + valid_cols]
                    .copy_from_slice(&sb.data[src..src + valid_cols]);
            }
            c = self.run_task_exe(exe_idx, &a, &b, &c)?;
            k0 += kc;
        }
        Ok(c)
    }

    fn min_kc(&self, tile: usize) -> usize {
        self.tasks
            .iter()
            .filter(|t| t.si == tile && t.sj == tile)
            .map(|t| t.kc)
            .min()
            .unwrap_or(usize::MAX)
    }

    /// Full GEMM through the task executables (blocked at the largest
    /// available tile). The numerics path of the coordinator.
    pub fn gemm(&self, a: &Matrix, b: &Matrix) -> anyhow::Result<Matrix> {
        self.block_product(a, b)
    }

    /// Run a `gemm_full_{n}` artifact (quickstart/smoke path).
    pub fn gemm_full(&self, a: &Matrix, b: &Matrix) -> anyhow::Result<Matrix> {
        let n = a.rows;
        let exe = self
            .full
            .get(&n)
            .ok_or_else(|| anyhow::anyhow!("no gemm_full_{n} artifact"))?;
        anyhow::ensure!(
            a.cols == n && b.rows == n && b.cols == n,
            "gemm_full_{n} needs {n}x{n} operands"
        );
        let out = exe
            .execute::<xla::Literal>(&[Self::literal(a)?, Self::literal(b)?])
            .map_err(xerr)?[0][0]
            .to_literal_sync()
            .map_err(xerr)?;
        Self::unpack(out, n, n)
    }
}

#[cfg(test)]
mod tests {
    //! Tests needing compiled artifacts live in `rust/tests/runtime.rs`
    //! (they skip when `artifacts/` is absent); here only pure logic.

    use super::*;

    #[test]
    fn missing_dir_errors() {
        assert!(Runtime::load("/nonexistent/path").is_err());
    }
}
