//! Accelerator configuration — the knobs the paper's host CPU programs.
//!
//! A [`HardwareConfig`] fixes what would be baked into the bitstream
//! (`P_m`, `P`, pipeline depths, DDR timing); a [`RunConfig`] holds the
//! per-problem knobs the host writes into the multiplexers and buffer
//! descriptors at run time (`N_p`, `S_i`, `S_j`).


use crate::ddr::DdrConfig;

/// Bitstream-time parameters of the accelerator (Section V defaults).
#[derive(Debug, Clone, PartialEq)]
pub struct HardwareConfig {
    /// Maximum number of independent PE arrays (`P_m`), all muxes open.
    pub pm: usize,
    /// PEs per base array (`P`).
    pub p: usize,
    /// Accelerator clock in MHz (`F_acc`; paper: 200 MHz post-synthesis).
    pub freq_mhz: f64,
    /// Depth of the FMAC pipeline (`Stage_fmac` in Eq. 6).
    pub fmac_stages: usize,
    /// Bytes per matrix element (FP32 = 4, the paper's word size).
    pub elem_bytes: usize,
    /// Off-chip memory model parameters.
    pub ddr: DdrConfig,
}

impl Default for HardwareConfig {
    fn default() -> Self {
        Self::paper()
    }
}

impl HardwareConfig {
    /// The experimental setup of Section V: `P_m = 4`, `P = 64`,
    /// `F_acc = 200 MHz` on a VC709 (two DDR3 DIMMs).
    pub fn paper() -> Self {
        Self {
            pm: 4,
            p: 64,
            freq_mhz: 200.0,
            fmac_stages: 14, // Virtex-7 FP32 mul (8) + add (6) class depth
            elem_bytes: 4,
            ddr: DdrConfig::vc709(),
        }
    }

    /// A small config for fast tests: `P_m = 2`, `P = 8`.
    pub fn tiny() -> Self {
        Self {
            pm: 2,
            p: 8,
            freq_mhz: 200.0,
            fmac_stages: 4,
            elem_bytes: 4,
            ddr: DdrConfig::vc709(),
        }
    }

    /// Total PE budget `P_m * P` — fixed across all run configs.
    pub fn total_pes(&self) -> usize {
        self.pm * self.p
    }

    /// Theoretical peak in GFLOPS: `2 * F_acc * P_m * P` (Section V).
    pub fn peak_gflops(&self) -> f64 {
        2.0 * self.freq_mhz * 1e6 * self.total_pes() as f64 / 1e9
    }

    /// Accelerator clock period in seconds.
    pub fn clock_period(&self) -> f64 {
        1.0 / (self.freq_mhz * 1e6)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.pm >= 1, "pm must be >= 1");
        anyhow::ensure!(
            self.pm.is_power_of_two(),
            "pm must be a power of two (mux chaining halves array count)"
        );
        anyhow::ensure!(self.p >= 1, "p must be >= 1");
        anyhow::ensure!(self.freq_mhz > 0.0, "freq must be positive");
        anyhow::ensure!(self.elem_bytes > 0, "elem_bytes must be positive");
        self.ddr.validate()?;
        Ok(())
    }

    /// Parse a config file (flat `key = value` with an optional `[ddr]`
    /// section — see `configs/paper.toml`). Unset keys keep the paper's
    /// defaults; unknown keys are an error so typos fail loudly.
    pub fn from_toml(text: &str) -> anyhow::Result<Self> {
        let kv = crate::util::kv::KvFile::parse(text)?;
        let mut cfg = Self::paper();
        for key in kv.keys("") {
            match key {
                "pm" => cfg.pm = kv.get_usize("", "pm")?.unwrap(),
                "p" => cfg.p = kv.get_usize("", "p")?.unwrap(),
                "freq_mhz" => cfg.freq_mhz = kv.get_f64("", "freq_mhz")?.unwrap(),
                "fmac_stages" => {
                    cfg.fmac_stages = kv.get_usize("", "fmac_stages")?.unwrap()
                }
                "elem_bytes" => {
                    cfg.elem_bytes = kv.get_usize("", "elem_bytes")?.unwrap()
                }
                other => anyhow::bail!("unknown config key {other:?}"),
            }
        }
        for key in kv.keys("ddr") {
            let d = &mut cfg.ddr;
            match key {
                "mem_clock_mhz" => {
                    d.mem_clock_mhz = kv.get_f64("ddr", key)?.unwrap()
                }
                "bus_bytes" => d.bus_bytes = kv.get_usize("ddr", key)?.unwrap(),
                "banks" => d.banks = kv.get_usize("ddr", key)?.unwrap(),
                "row_bytes" => d.row_bytes = kv.get_usize("ddr", key)?.unwrap(),
                "t_rcd" => d.t_rcd = kv.get_u64("ddr", key)?.unwrap(),
                "t_rp" => d.t_rp = kv.get_u64("ddr", key)?.unwrap(),
                "t_cl" => d.t_cl = kv.get_u64("ddr", key)?.unwrap(),
                "burst_transfers" => {
                    d.burst_transfers = kv.get_usize("ddr", key)?.unwrap()
                }
                "req_overhead" => d.req_overhead = kv.get_u64("ddr", key)?.unwrap(),
                other => anyhow::bail!("unknown [ddr] key {other:?}"),
            }
        }
        if let Some(section) = kv.sections().iter().find(|&&s| !s.is_empty() && s != "ddr")
        {
            anyhow::bail!("unknown config section [{section}]");
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Serialize to the same `key = value` format `from_toml` accepts.
    pub fn to_toml(&self) -> String {
        format!(
            "pm = {}\np = {}\nfreq_mhz = {}\nfmac_stages = {}\nelem_bytes = {}\n\n\
             [ddr]\nmem_clock_mhz = {}\nbus_bytes = {}\nbanks = {}\nrow_bytes = {}\n\
             t_rcd = {}\nt_rp = {}\nt_cl = {}\nburst_transfers = {}\nreq_overhead = {}\n",
            self.pm,
            self.p,
            self.freq_mhz,
            self.fmac_stages,
            self.elem_bytes,
            self.ddr.mem_clock_mhz,
            self.ddr.bus_bytes,
            self.ddr.banks,
            self.ddr.row_bytes,
            self.ddr.t_rcd,
            self.ddr.t_rp,
            self.ddr.t_cl,
            self.ddr.burst_transfers,
            self.ddr.req_overhead,
        )
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<Self> {
        Self::from_toml(&std::fs::read_to_string(path)?)
    }
}

/// Run-time configuration: the `<N_p, S_i>` the host programs per problem.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct RunConfig {
    /// Number of PE arrays working in parallel (`N_p`).
    pub np: usize,
    /// Block size on rows of A (`S_i`).
    pub si: usize,
    /// Block size on columns of B (`S_j`).
    pub sj: usize,
}

impl RunConfig {
    pub fn new(np: usize, si: usize, sj: usize) -> Self {
        Self { np, si, sj }
    }

    /// Square-block config (`S_i = S_j`), the Section IV simplification.
    pub fn square(np: usize, si: usize) -> Self {
        Self { np, si, sj: si }
    }

    /// PEs available to each (possibly chained) array: `P_m * P / N_p`.
    pub fn pes_per_array(&self, hw: &HardwareConfig) -> usize {
        hw.total_pes() / self.np
    }

    /// Validity under Eq. 9: `N_p` arrays exist after chaining, each
    /// chained array must hold at least `S_i` PEs (one PE per result row),
    /// and `S_j` must not starve the pipeline.
    pub fn validate(&self, hw: &HardwareConfig) -> anyhow::Result<()> {
        anyhow::ensure!(self.np >= 1 && self.np <= hw.pm, "np out of range [1, pm]");
        anyhow::ensure!(
            hw.pm % self.np == 0,
            "np must divide pm (arrays chain in powers of two)"
        );
        anyhow::ensure!(self.si >= 1 && self.sj >= 1, "block sizes must be >= 1");
        let pes = self.pes_per_array(hw);
        anyhow::ensure!(
            self.si <= pes,
            "S_i = {} exceeds the {} PEs of a chained array (Eq. 9)",
            self.si,
            pes
        );
        Ok(())
    }
}

impl std::fmt::Display for RunConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.si == self.sj {
            write!(f, "(Np={}, Si={})", self.np, self.si)
        } else {
            write!(f, "(Np={}, Si={}, Sj={})", self.np, self.si, self.sj)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_config_matches_section5() {
        let hw = HardwareConfig::paper();
        assert_eq!(hw.total_pes(), 256);
        assert!((hw.peak_gflops() - 102.4).abs() < 1e-9);
        hw.validate().unwrap();
    }

    #[test]
    fn clock_period() {
        let hw = HardwareConfig::paper();
        assert!((hw.clock_period() - 5e-9).abs() < 1e-18);
    }

    #[test]
    fn eq9_constraint_enforced() {
        let hw = HardwareConfig::paper();
        // Np=4 -> 64 PEs/array -> Si <= 64.
        assert!(RunConfig::square(4, 64).validate(&hw).is_ok());
        assert!(RunConfig::square(4, 65).validate(&hw).is_err());
        // Np=2 -> 128 PEs/array.
        assert!(RunConfig::square(2, 128).validate(&hw).is_ok());
        assert!(RunConfig::square(2, 129).validate(&hw).is_err());
        // Np=1 -> 256 PEs.
        assert!(RunConfig::square(1, 256).validate(&hw).is_ok());
        assert!(RunConfig::square(1, 257).validate(&hw).is_err());
    }

    #[test]
    fn np_must_divide_pm() {
        let hw = HardwareConfig::paper();
        assert!(RunConfig::square(3, 16).validate(&hw).is_err());
        assert!(RunConfig::square(0, 16).validate(&hw).is_err());
        assert!(RunConfig::square(5, 16).validate(&hw).is_err());
    }

    #[test]
    fn toml_roundtrip() {
        let hw = HardwareConfig::paper();
        let back = HardwareConfig::from_toml(&hw.to_toml()).unwrap();
        assert_eq!(hw, back);
    }

    #[test]
    fn partial_config_keeps_defaults() {
        let hw = HardwareConfig::from_toml("p = 32\n").unwrap();
        assert_eq!(hw.p, 32);
        assert_eq!(hw.pm, 4); // default preserved
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(HardwareConfig::from_toml("pe_count = 3\n").is_err());
        assert!(HardwareConfig::from_toml("[dddr]\nbanks = 8\n").is_err());
    }

    #[test]
    fn invalid_toml_rejected() {
        assert!(HardwareConfig::from_toml("pm = 3").is_err());
    }

    #[test]
    fn display_formats() {
        assert_eq!(RunConfig::square(2, 128).to_string(), "(Np=2, Si=128)");
        assert_eq!(
            RunConfig::new(2, 64, 32).to_string(),
            "(Np=2, Si=64, Sj=32)"
        );
    }
}
