//! Blocked element-wise add/sub kernels over borrowed views — the
//! combine substrate of the Strassen subsystem.
//!
//! Strassen forms its 7 operand combinations (`A11 + A22`, `B12 - B22`,
//! ...) and recombines the 7 sub-products into C's quadrants with pure
//! element-wise adds and subtracts. These kernels do that work through
//! [`MatrixView`] / [`MatrixViewMut`] windows, so quadrants are read and
//! written in place — no quadrant is ever materialized just to be added.
//!
//! Blocking structure: a view's rows are contiguous runs of the parent's
//! storage, so the kernels stream row-by-row — each row is one
//! sequential burst for all three operands (the same access shape the
//! DDR model rewards in Fig. 3), and the inner loops are plain slice
//! zips LLVM autovectorizes. Shapes are asserted equal up front; there
//! is no edge handling inside the loops.

use super::view::{MatrixView, MatrixViewMut};

/// The two element-wise combine primitives Strassen's operand formation
/// is built from. A first-class value so a combination can be *carried*
/// (into the fused packers, [`crate::gemm::PackedA::from_sum_of_views`])
/// instead of eagerly applied into a materialized temp.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CombineOp {
    Add,
    Sub,
}

impl CombineOp {
    /// Apply the op to one element pair — a single f32 rounding, exactly
    /// what [`add_into`] / [`sub_into`] perform per element, so fused
    /// and materialized formation are bit-identical.
    #[inline]
    pub fn apply(self, x: f32, y: f32) -> f32 {
        match self {
            CombineOp::Add => x + y,
            CombineOp::Sub => x - y,
        }
    }
}

/// `out = x + y`, element-wise. All three shapes must match.
pub fn add_into(x: MatrixView<'_>, y: MatrixView<'_>, out: &mut MatrixViewMut<'_>) {
    assert_shapes(x.rows(), x.cols(), y.rows(), y.cols(), out.rows(), out.cols());
    for r in 0..out.rows() {
        let (xr, yr) = (x.row(r), y.row(r));
        for ((o, &a), &b) in out.row_mut(r).iter_mut().zip(xr).zip(yr) {
            *o = a + b;
        }
    }
}

/// `out = x - y`, element-wise. All three shapes must match.
pub fn sub_into(x: MatrixView<'_>, y: MatrixView<'_>, out: &mut MatrixViewMut<'_>) {
    assert_shapes(x.rows(), x.cols(), y.rows(), y.cols(), out.rows(), out.cols());
    for r in 0..out.rows() {
        let (xr, yr) = (x.row(r), y.row(r));
        for ((o, &a), &b) in out.row_mut(r).iter_mut().zip(xr).zip(yr) {
            *o = a - b;
        }
    }
}

/// `out += x`, element-wise accumulate.
pub fn acc_add(out: &mut MatrixViewMut<'_>, x: MatrixView<'_>) {
    assert_eq!((out.rows(), out.cols()), (x.rows(), x.cols()), "shape mismatch");
    for r in 0..out.rows() {
        let xr = x.row(r);
        for (o, &a) in out.row_mut(r).iter_mut().zip(xr) {
            *o += a;
        }
    }
}

/// `out -= x`, element-wise accumulate-subtract.
pub fn acc_sub(out: &mut MatrixViewMut<'_>, x: MatrixView<'_>) {
    assert_eq!((out.rows(), out.cols()), (x.rows(), x.cols()), "shape mismatch");
    for r in 0..out.rows() {
        let xr = x.row(r);
        for (o, &a) in out.row_mut(r).iter_mut().zip(xr) {
            *o -= a;
        }
    }
}

/// `out = x`, row-streamed copy between views.
pub fn copy_into(x: MatrixView<'_>, out: &mut MatrixViewMut<'_>) {
    assert_eq!((out.rows(), out.cols()), (x.rows(), x.cols()), "shape mismatch");
    for r in 0..out.rows() {
        out.row_mut(r).copy_from_slice(x.row(r));
    }
}

fn assert_shapes(xr: usize, xc: usize, yr: usize, yc: usize, or: usize, oc: usize) {
    assert_eq!((xr, xc), (yr, yc), "operand shape mismatch");
    assert_eq!((xr, xc), (or, oc), "output shape mismatch");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::Matrix;
    use crate::util::check;

    #[test]
    fn add_and_sub_whole_matrices() {
        let x = Matrix::random(5, 7, 1);
        let y = Matrix::random(5, 7, 2);
        let mut sum = Matrix::zeros(5, 7);
        let mut diff = Matrix::zeros(5, 7);
        add_into(x.view(), y.view(), &mut sum.view_mut());
        sub_into(x.view(), y.view(), &mut diff.view_mut());
        for i in 0..5 * 7 {
            assert_eq!(sum.data[i], x.data[i] + y.data[i]);
            assert_eq!(diff.data[i], x.data[i] - y.data[i]);
        }
    }

    #[test]
    fn accumulate_variants() {
        let x = Matrix::random(4, 4, 3);
        let mut out = Matrix::random(4, 4, 4);
        let before = out.clone();
        acc_add(&mut out.view_mut(), x.view());
        for i in 0..16 {
            assert_eq!(out.data[i], before.data[i] + x.data[i]);
        }
        acc_sub(&mut out.view_mut(), x.view());
        for i in 0..16 {
            assert_eq!(out.data[i], before.data[i]);
        }
    }

    #[test]
    fn copy_between_views() {
        let x = Matrix::random(3, 9, 5);
        let mut out = Matrix::zeros(3, 9);
        copy_into(x.view(), &mut out.view_mut());
        assert_eq!(out, x);
    }

    #[test]
    fn strided_quadrant_views_add_in_place() {
        // Add the top-left quadrant of one 6x6 into the bottom-right
        // quadrant of another — both sides are strided sub-views.
        let src = Matrix::random(6, 6, 6);
        let mut dst = Matrix::zeros(6, 6);
        {
            let mut dv = dst.view_mut();
            let mut q = dv.block_mut(3, 3, 3, 3);
            let sv = src.view();
            add_into(sv.block(0, 0, 3, 3), sv.block(0, 3, 3, 3), &mut q);
        }
        for r in 0..3 {
            for c in 0..3 {
                assert_eq!(dst.get(3 + r, 3 + c), src.get(r, c) + src.get(r, 3 + c));
                assert_eq!(dst.get(r, c), 0.0, "outside the target quadrant");
            }
        }
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn mismatched_shapes_panic() {
        let x = Matrix::zeros(2, 3);
        let y = Matrix::zeros(3, 2);
        let mut out = Matrix::zeros(2, 3);
        add_into(x.view(), y.view(), &mut out.view_mut());
    }

    #[test]
    fn combine_op_matches_kernels() {
        let x = Matrix::random(4, 6, 7);
        let y = Matrix::random(4, 6, 8);
        let mut sum = Matrix::zeros(4, 6);
        let mut diff = Matrix::zeros(4, 6);
        add_into(x.view(), y.view(), &mut sum.view_mut());
        sub_into(x.view(), y.view(), &mut diff.view_mut());
        for i in 0..24 {
            assert_eq!(CombineOp::Add.apply(x.data[i], y.data[i]), sum.data[i]);
            assert_eq!(CombineOp::Sub.apply(x.data[i], y.data[i]), diff.data[i]);
        }
    }

    #[test]
    fn prop_add_sub_roundtrip() {
        check::cases(32, |rng| {
            let (m, n) = (rng.range(1, 20), rng.range(1, 20));
            let seed = rng.next_u64();
            let x = Matrix::random(m, n, seed);
            let y = Matrix::random(m, n, seed + 1);
            let mut sum = Matrix::zeros(m, n);
            add_into(x.view(), y.view(), &mut sum.view_mut());
            let mut back = Matrix::zeros(m, n);
            sub_into(sum.view(), y.view(), &mut back.view_mut());
            assert!(back.allclose(&x, 1e-6));
        });
    }
}
