//! Panel packing — each operand element is touched once per *job*, not
//! once per task, and a packed operand is a refcounted unit that can be
//! shared across jobs.
//!
//! The old hot path re-copied a full `S_i x K` slice of A and a
//! `K x S_j` slice of B out of the operands for every WQM task (so a
//! `bi` row-panel was copied `blocks_j` times and a `bj` column-panel
//! `blocks_i` times). [`PackedA`] / [`PackedB`] do the copy exactly once
//! per panel, into the layout the register-blocked microkernel streams:
//!
//! * A's row-panel `bi` is stored as `ceil(rows/MR)` strips; within a
//!   strip the layout is k-major with `MR` row-adjacent values per k —
//!   i.e. *transposed*, so a column of `SA_i` is contiguous, the same
//!   layout fix the MAC applies to A for burst-friendly DDR reads
//!   (Section III-C);
//! * B's column-panel `bj` is `ceil(cols/NR)` strips, k-major with `NR`
//!   column-adjacent values per k.
//!
//! Ragged strips are zero-padded to the full `MR`/`NR` width so the
//! microkernel never branches on edges; the padding contributes exact
//! `+0.0` terms and the writer clips them on the way out.
//!
//! The two halves are deliberately *independent* types behind `Arc`s:
//! a batched workload (same B, many A — CNN inference's shape) packs B
//! once into an `Arc<PackedB>` and pairs it with a fresh [`PackedA`]
//! per sub-job via [`PackedPanels::from_parts`]. Because the packed
//! layout of an operand depends only on its own shape and block size —
//! not on the other operand — a shared half is bit-identical to one
//! packed privately, so batched results match individual runs exactly.
//! The server's operand registry
//! ([`crate::coordinator::OperandRegistry`]) stretches the same
//! guarantee across *calls*, on both sides: a registered weight's
//! `Arc<PackedB>` is cached per `S_j` and a registered activation's
//! `Arc<PackedA>` per `S_i`, so successive submissions reusing either
//! handle never repack.

use std::sync::Arc;

use crate::blocking::BlockPlan;

use super::dtype::Dtype;
use super::microkernel::{MR, NR};
use super::ops::CombineOp;
use super::view::MatrixView;

/// A borrowed view of one packed panel's strips in whatever storage
/// precision the panel was packed at. The microkernel dispatches on this
/// to pick the matching per-dtype inner loop; f16/bf16 strips are `u16`
/// bit patterns decoded on load.
#[derive(Debug, Clone, Copy)]
pub enum PanelRef<'a> {
    /// f32 strips — the legacy layout, served from the same storage as
    /// [`PackedA::panel`] / [`PackedB::panel`].
    F32(&'a [f32]),
    /// f64 strips (exact widenings of the f32 source elements).
    F64(&'a [f64]),
    /// f16 or bf16 bit patterns; which one is named by the owner's
    /// [`Dtype`].
    Half(&'a [u16]),
}

/// The packed row-panels of one A operand (`M x K` at block size `si`):
/// strip-major `[strip][k][MR]` per panel. Refcounted and immutable
/// after packing; shareable across jobs that multiply the same A.
///
/// Panels are stored in the dtype the job asked for ([`Dtype`], default
/// `F32`): exactly one of the three panel stores is populated. The `F32`
/// store and its constructors are byte-for-byte the pre-multi-precision
/// code path.
#[derive(Debug, Clone)]
pub struct PackedA {
    k: usize,
    /// Storage precision of the populated panel store.
    dtype: Dtype,
    /// Per block-row of A: strip-major `[strip][k][MR]` packing (`F32`).
    panels: Vec<Vec<f32>>,
    /// `F64` storage: same slot arithmetic, exact widenings.
    wide_panels: Vec<Vec<f64>>,
    /// `F16`/`Bf16` storage: same slot arithmetic, RNE-converted bits.
    half_panels: Vec<Vec<u16>>,
    /// Effective (unpadded) rows per panel.
    rows: Vec<usize>,
}

impl PackedA {
    /// Pack `a` (`M x K`) into `ceil(M / si)` row-panels.
    pub fn pack(a: MatrixView<'_>, si: usize) -> Self {
        assert!(si > 0, "degenerate block size");
        let (m, k) = (a.rows(), a.cols());
        let blocks = m.div_ceil(si);
        let mut panels = Vec::with_capacity(blocks);
        let mut rows_eff = Vec::with_capacity(blocks);
        for bi in 0..blocks {
            let row0 = bi * si;
            let rows = si.min(m - row0);
            panels.push(pack_a_panel(&a, row0, rows, k));
            rows_eff.push(rows);
        }
        Self {
            k,
            dtype: Dtype::F32,
            panels,
            wide_panels: Vec::new(),
            half_panels: Vec::new(),
            rows: rows_eff,
        }
    }

    /// [`PackedA::pack`] with the storage precision as a parameter:
    /// `F32` is exactly `pack` (same storage, bit for bit); other dtypes
    /// convert each element once on the way into the panel (`F64` widens
    /// exactly, the half types round to nearest even).
    pub fn pack_dtype(a: MatrixView<'_>, si: usize, dtype: Dtype) -> Self {
        if dtype == Dtype::F32 {
            return Self::pack(a, si);
        }
        assert!(si > 0, "degenerate block size");
        let (m, k) = (a.rows(), a.cols());
        let blocks = m.div_ceil(si);
        let mut out = Self {
            k,
            dtype,
            panels: Vec::new(),
            wide_panels: Vec::new(),
            half_panels: Vec::new(),
            rows: Vec::with_capacity(blocks),
        };
        for bi in 0..blocks {
            let row0 = bi * si;
            let rows = si.min(m - row0);
            match dtype {
                Dtype::F64 => out
                    .wide_panels
                    .push(pack_a_panel_conv(&a, row0, rows, k, 0.0f64, |v| v as f64)),
                _ => {
                    let enc = dtype.half_encoder().expect("half dtype has an encoder");
                    out.half_panels.push(pack_a_panel_conv(&a, row0, rows, k, 0u16, enc));
                }
            }
            out.rows.push(rows);
        }
        out
    }

    /// Pack `x op y` (element-wise, or plain `x` when `y` is `None`)
    /// without ever materializing the combined operand: each packed slot
    /// is written as `op.apply(x[i], y[i])` — one f32 rounding, exactly
    /// what a materialize-then-pack pipeline produces, so the result is
    /// bit-identical to `PackedA::pack(&materialized, si)`. This is the
    /// Strassen fused combine-packing path: a leaf's `A11 + A22` is
    /// formed *inside* the pack pass, saving one full temp write + read
    /// per operand.
    pub fn from_sum_of_views(
        x: MatrixView<'_>,
        y: Option<(MatrixView<'_>, CombineOp)>,
        si: usize,
    ) -> Self {
        assert!(si > 0, "degenerate block size");
        if let Some((yv, _)) = &y {
            assert_eq!(
                (x.rows(), x.cols()),
                (yv.rows(), yv.cols()),
                "fused operand shape mismatch"
            );
        }
        let (m, k) = (x.rows(), x.cols());
        let blocks = m.div_ceil(si);
        let mut panels = Vec::with_capacity(blocks);
        let mut rows_eff = Vec::with_capacity(blocks);
        for bi in 0..blocks {
            let row0 = bi * si;
            let rows = si.min(m - row0);
            panels.push(pack_a_panel_fused(&x, y.as_ref(), row0, rows, k));
            rows_eff.push(rows);
        }
        Self {
            k,
            dtype: Dtype::F32,
            panels,
            wide_panels: Vec::new(),
            half_panels: Vec::new(),
            rows: rows_eff,
        }
    }

    /// [`PackedA::from_sum_of_views`] with the storage precision as a
    /// parameter. The combination `x op y` is always formed in f32 first
    /// (one f32 rounding, exactly like the materialize-then-pack
    /// pipeline) and then converted into the storage dtype — so a fused
    /// half-precision pack is bit-identical to materializing the f32
    /// combination and `pack_dtype`-ing it.
    pub fn from_sum_of_views_dtype(
        x: MatrixView<'_>,
        y: Option<(MatrixView<'_>, CombineOp)>,
        si: usize,
        dtype: Dtype,
    ) -> Self {
        if dtype == Dtype::F32 {
            return Self::from_sum_of_views(x, y, si);
        }
        assert!(si > 0, "degenerate block size");
        if let Some((yv, _)) = &y {
            assert_eq!(
                (x.rows(), x.cols()),
                (yv.rows(), yv.cols()),
                "fused operand shape mismatch"
            );
        }
        let (m, k) = (x.rows(), x.cols());
        let blocks = m.div_ceil(si);
        let mut out = Self {
            k,
            dtype,
            panels: Vec::new(),
            wide_panels: Vec::new(),
            half_panels: Vec::new(),
            rows: Vec::with_capacity(blocks),
        };
        for bi in 0..blocks {
            let row0 = bi * si;
            let rows = si.min(m - row0);
            match dtype {
                Dtype::F64 => out.wide_panels.push(pack_a_panel_fused_conv(
                    &x,
                    y.as_ref(),
                    row0,
                    rows,
                    k,
                    0.0f64,
                    |v| v as f64,
                )),
                _ => {
                    let enc = dtype.half_encoder().expect("half dtype has an encoder");
                    out.half_panels
                        .push(pack_a_panel_fused_conv(&x, y.as_ref(), row0, rows, k, 0u16, enc));
                }
            }
            out.rows.push(rows);
        }
        out
    }

    /// Contraction depth K this operand was packed for.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Storage precision of the packed panels.
    pub fn dtype(&self) -> Dtype {
        self.dtype
    }

    /// Number of packed row-panels (`ceil(M / si)`).
    pub fn num_panels(&self) -> usize {
        self.rows.len()
    }

    /// Packed strips of row-panel `bi` and its effective row count.
    /// The f32 accessor — for other dtypes use [`PackedA::panel_ref`].
    pub fn panel(&self, bi: usize) -> (&[f32], usize) {
        debug_assert_eq!(self.dtype, Dtype::F32, "panel() reads the f32 store");
        (&self.panels[bi], self.rows[bi])
    }

    /// Packed strips of row-panel `bi` in the panel's own storage
    /// precision, plus its effective row count.
    pub fn panel_ref(&self, bi: usize) -> (PanelRef<'_>, usize) {
        let p = match self.dtype {
            Dtype::F32 => PanelRef::F32(&self.panels[bi]),
            Dtype::F64 => PanelRef::F64(&self.wide_panels[bi]),
            Dtype::F16 | Dtype::Bf16 => PanelRef::Half(&self.half_panels[bi]),
        };
        (p, self.rows[bi])
    }

    /// Total packed elements (diagnostics: equals the padded operand
    /// size, whatever the storage precision).
    pub fn packed_len(&self) -> usize {
        match self.dtype {
            Dtype::F32 => self.panels.iter().map(Vec::len).sum(),
            Dtype::F64 => self.wide_panels.iter().map(Vec::len).sum(),
            Dtype::F16 | Dtype::Bf16 => self.half_panels.iter().map(Vec::len).sum(),
        }
    }

    /// Packed payload size in bytes — what a cached pack costs the
    /// operand registry's byte budget. Scales with the storage dtype
    /// (a bf16 pack of the same operand costs half an f32 pack).
    pub fn packed_bytes(&self) -> u64 {
        (self.packed_len() * self.dtype.bytes()) as u64
    }
}

/// The packed column-panels of one B operand (`K x N` at block size
/// `sj`): strip-major `[strip][k][NR]` per panel. Refcounted and
/// immutable after packing — the shared half of a batched GEMM (one B,
/// many A), where a single pack feeds every sub-job.
#[derive(Debug, Clone)]
pub struct PackedB {
    k: usize,
    /// Storage precision of the populated panel store.
    dtype: Dtype,
    /// Per block-column of B: strip-major `[strip][k][NR]` packing (`F32`).
    panels: Vec<Vec<f32>>,
    /// `F64` storage: same slot arithmetic, exact widenings.
    wide_panels: Vec<Vec<f64>>,
    /// `F16`/`Bf16` storage: same slot arithmetic, RNE-converted bits.
    half_panels: Vec<Vec<u16>>,
    /// Effective (unpadded) columns per panel.
    cols: Vec<usize>,
}

impl PackedB {
    /// Pack `b` (`K x N`) into `ceil(N / sj)` column-panels.
    pub fn pack(b: MatrixView<'_>, sj: usize) -> Self {
        assert!(sj > 0, "degenerate block size");
        let (k, n) = (b.rows(), b.cols());
        let blocks = n.div_ceil(sj);
        let mut panels = Vec::with_capacity(blocks);
        let mut cols_eff = Vec::with_capacity(blocks);
        for bj in 0..blocks {
            let col0 = bj * sj;
            let cols = sj.min(n - col0);
            panels.push(pack_b_panel(&b, col0, cols, k));
            cols_eff.push(cols);
        }
        Self {
            k,
            dtype: Dtype::F32,
            panels,
            wide_panels: Vec::new(),
            half_panels: Vec::new(),
            cols: cols_eff,
        }
    }

    /// [`PackedB::pack`] with the storage precision as a parameter:
    /// `F32` is exactly `pack`; other dtypes convert each element once
    /// on the way into the panel.
    pub fn pack_dtype(b: MatrixView<'_>, sj: usize, dtype: Dtype) -> Self {
        if dtype == Dtype::F32 {
            return Self::pack(b, sj);
        }
        assert!(sj > 0, "degenerate block size");
        let (k, n) = (b.rows(), b.cols());
        let blocks = n.div_ceil(sj);
        let mut out = Self {
            k,
            dtype,
            panels: Vec::new(),
            wide_panels: Vec::new(),
            half_panels: Vec::new(),
            cols: Vec::with_capacity(blocks),
        };
        for bj in 0..blocks {
            let col0 = bj * sj;
            let cols = sj.min(n - col0);
            match dtype {
                Dtype::F64 => out
                    .wide_panels
                    .push(pack_b_panel_conv(&b, col0, cols, k, 0.0f64, |v| v as f64)),
                _ => {
                    let enc = dtype.half_encoder().expect("half dtype has an encoder");
                    out.half_panels.push(pack_b_panel_conv(&b, col0, cols, k, 0u16, enc));
                }
            }
            out.cols.push(cols);
        }
        out
    }

    /// Pack `x op y` (element-wise, or plain `x` when `y` is `None`)
    /// without materializing the combined operand — the B-side twin of
    /// [`PackedA::from_sum_of_views`], bit-identical to
    /// materialize-then-`pack`.
    pub fn from_sum_of_views(
        x: MatrixView<'_>,
        y: Option<(MatrixView<'_>, CombineOp)>,
        sj: usize,
    ) -> Self {
        assert!(sj > 0, "degenerate block size");
        if let Some((yv, _)) = &y {
            assert_eq!(
                (x.rows(), x.cols()),
                (yv.rows(), yv.cols()),
                "fused operand shape mismatch"
            );
        }
        let (k, n) = (x.rows(), x.cols());
        let blocks = n.div_ceil(sj);
        let mut panels = Vec::with_capacity(blocks);
        let mut cols_eff = Vec::with_capacity(blocks);
        for bj in 0..blocks {
            let col0 = bj * sj;
            let cols = sj.min(n - col0);
            panels.push(pack_b_panel_fused(&x, y.as_ref(), col0, cols, k));
            cols_eff.push(cols);
        }
        Self {
            k,
            dtype: Dtype::F32,
            panels,
            wide_panels: Vec::new(),
            half_panels: Vec::new(),
            cols: cols_eff,
        }
    }

    /// [`PackedB::from_sum_of_views`] with the storage precision as a
    /// parameter — the B-side twin of
    /// [`PackedA::from_sum_of_views_dtype`]: the combination is formed in
    /// f32, then converted into the storage dtype.
    pub fn from_sum_of_views_dtype(
        x: MatrixView<'_>,
        y: Option<(MatrixView<'_>, CombineOp)>,
        sj: usize,
        dtype: Dtype,
    ) -> Self {
        if dtype == Dtype::F32 {
            return Self::from_sum_of_views(x, y, sj);
        }
        assert!(sj > 0, "degenerate block size");
        if let Some((yv, _)) = &y {
            assert_eq!(
                (x.rows(), x.cols()),
                (yv.rows(), yv.cols()),
                "fused operand shape mismatch"
            );
        }
        let (k, n) = (x.rows(), x.cols());
        let blocks = n.div_ceil(sj);
        let mut out = Self {
            k,
            dtype,
            panels: Vec::new(),
            wide_panels: Vec::new(),
            half_panels: Vec::new(),
            cols: Vec::with_capacity(blocks),
        };
        for bj in 0..blocks {
            let col0 = bj * sj;
            let cols = sj.min(n - col0);
            match dtype {
                Dtype::F64 => out.wide_panels.push(pack_b_panel_fused_conv(
                    &x,
                    y.as_ref(),
                    col0,
                    cols,
                    k,
                    0.0f64,
                    |v| v as f64,
                )),
                _ => {
                    let enc = dtype.half_encoder().expect("half dtype has an encoder");
                    out.half_panels
                        .push(pack_b_panel_fused_conv(&x, y.as_ref(), col0, cols, k, 0u16, enc));
                }
            }
            out.cols.push(cols);
        }
        out
    }

    /// Contraction depth K this operand was packed for.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Storage precision of the packed panels.
    pub fn dtype(&self) -> Dtype {
        self.dtype
    }

    /// Number of packed column-panels (`ceil(N / sj)`).
    pub fn num_panels(&self) -> usize {
        self.cols.len()
    }

    /// Packed strips of column-panel `bj` and its effective column count.
    /// The f32 accessor — for other dtypes use [`PackedB::panel_ref`].
    pub fn panel(&self, bj: usize) -> (&[f32], usize) {
        debug_assert_eq!(self.dtype, Dtype::F32, "panel() reads the f32 store");
        (&self.panels[bj], self.cols[bj])
    }

    /// Packed strips of column-panel `bj` in the panel's own storage
    /// precision, plus its effective column count.
    pub fn panel_ref(&self, bj: usize) -> (PanelRef<'_>, usize) {
        let p = match self.dtype {
            Dtype::F32 => PanelRef::F32(&self.panels[bj]),
            Dtype::F64 => PanelRef::F64(&self.wide_panels[bj]),
            Dtype::F16 | Dtype::Bf16 => PanelRef::Half(&self.half_panels[bj]),
        };
        (p, self.cols[bj])
    }

    /// Total packed elements (diagnostics: equals the padded operand
    /// size, whatever the storage precision).
    pub fn packed_len(&self) -> usize {
        match self.dtype {
            Dtype::F32 => self.panels.iter().map(Vec::len).sum(),
            Dtype::F64 => self.wide_panels.iter().map(Vec::len).sum(),
            Dtype::F16 | Dtype::Bf16 => self.half_panels.iter().map(Vec::len).sum(),
        }
    }

    /// Packed payload size in bytes — what a cached pack costs the
    /// operand registry's byte budget. Scales with the storage dtype.
    pub fn packed_bytes(&self) -> u64 {
        (self.packed_len() * self.dtype.bytes()) as u64
    }
}

/// Both operands of one GEMM job, as refcounted packed halves. Built by
/// the coordinator (or [`super::packed_matmul`]); shared read-only
/// across all workers. Cloning is shallow — two clones share the same
/// packed storage — and [`PackedPanels::from_parts`] composes a job
/// from pre-packed halves, which is how a shared-B batch hands one
/// `Arc<PackedB>` to every sub-job.
#[derive(Debug, Clone)]
pub struct PackedPanels {
    a: Arc<PackedA>,
    b: Arc<PackedB>,
}

impl PackedPanels {
    /// Pack `a` (`M x K`) and `b` (`K x N`) for `plan`'s block grid.
    pub fn pack(a: MatrixView<'_>, b: MatrixView<'_>, plan: &BlockPlan) -> Self {
        assert_eq!((a.rows(), a.cols()), (plan.m, plan.k), "A shape mismatch");
        assert_eq!((b.rows(), b.cols()), (plan.k, plan.n), "B shape mismatch");
        Self::from_parts(
            Arc::new(PackedA::pack(a, plan.si)),
            Arc::new(PackedB::pack(b, plan.sj)),
        )
    }

    /// [`PackedPanels::pack`] with the storage precision as a parameter.
    pub fn pack_dtype(
        a: MatrixView<'_>,
        b: MatrixView<'_>,
        plan: &BlockPlan,
        dtype: Dtype,
    ) -> Self {
        assert_eq!((a.rows(), a.cols()), (plan.m, plan.k), "A shape mismatch");
        assert_eq!((b.rows(), b.cols()), (plan.k, plan.n), "B shape mismatch");
        Self::from_parts(
            Arc::new(PackedA::pack_dtype(a, plan.si, dtype)),
            Arc::new(PackedB::pack_dtype(b, plan.sj, dtype)),
        )
    }

    /// Compose a job's panels from pre-packed (possibly shared) halves.
    /// The halves must agree on K — they came from conformable operands —
    /// and on storage dtype, so the microkernel sees one precision.
    pub fn from_parts(a: Arc<PackedA>, b: Arc<PackedB>) -> Self {
        assert_eq!(a.k(), b.k(), "packed halves disagree on contraction depth");
        assert_eq!(a.dtype(), b.dtype(), "packed halves disagree on dtype");
        Self { a, b }
    }

    /// Shared contraction depth K.
    pub fn k(&self) -> usize {
        self.a.k()
    }

    /// Shared storage precision of both halves.
    pub fn dtype(&self) -> Dtype {
        self.a.dtype()
    }

    /// The refcounted A half.
    pub fn a_half(&self) -> &Arc<PackedA> {
        &self.a
    }

    /// The refcounted B half (what a shared-B batch clones per sub-job;
    /// `Arc::ptr_eq` on two jobs' halves observes the sharing).
    pub fn b_half(&self) -> &Arc<PackedB> {
        &self.b
    }

    /// Packed strips of A's row-panel `bi` and its effective row count.
    pub fn a_panel(&self, bi: usize) -> (&[f32], usize) {
        self.a.panel(bi)
    }

    /// Packed strips of B's column-panel `bj` and its effective column
    /// count.
    pub fn b_panel(&self, bj: usize) -> (&[f32], usize) {
        self.b.panel(bj)
    }

    /// Dtype-generic access to A's row-panel `bi`.
    pub fn a_panel_ref(&self, bi: usize) -> (PanelRef<'_>, usize) {
        self.a.panel_ref(bi)
    }

    /// Dtype-generic access to B's column-panel `bj`.
    pub fn b_panel_ref(&self, bj: usize) -> (PanelRef<'_>, usize) {
        self.b.panel_ref(bj)
    }

    /// Total packed floats (diagnostics: equals padded operand sizes).
    pub fn packed_len(&self) -> usize {
        self.a.packed_len() + self.b.packed_len()
    }
}

/// Pack `rows` rows of A starting at `row0` into MR-strips, k-major.
/// Element `(row0 + s*MR + r, p)` of A lands at `s*k*MR + p*MR + r`.
fn pack_a_panel(a: &MatrixView<'_>, row0: usize, rows: usize, k: usize) -> Vec<f32> {
    let strips = rows.div_ceil(MR);
    let mut out = vec![0.0f32; strips * k * MR];
    for s in 0..strips {
        let base = s * k * MR;
        for r in 0..MR.min(rows - s * MR) {
            let src = a.row(row0 + s * MR + r);
            for (p, &v) in src.iter().enumerate() {
                out[base + p * MR + r] = v;
            }
        }
    }
    out
}

/// [`pack_a_panel`] with the element source replaced by `x op y`; the
/// slot arithmetic is identical so the layout cannot drift from the
/// plain packer's.
fn pack_a_panel_fused(
    x: &MatrixView<'_>,
    y: Option<&(MatrixView<'_>, CombineOp)>,
    row0: usize,
    rows: usize,
    k: usize,
) -> Vec<f32> {
    let strips = rows.div_ceil(MR);
    let mut out = vec![0.0f32; strips * k * MR];
    for s in 0..strips {
        let base = s * k * MR;
        for r in 0..MR.min(rows - s * MR) {
            let row = row0 + s * MR + r;
            let src = x.row(row);
            match y {
                None => {
                    for (p, &v) in src.iter().enumerate() {
                        out[base + p * MR + r] = v;
                    }
                }
                Some((yv, op)) => {
                    let ysrc = yv.row(row);
                    for (p, (&xv, &yv)) in src.iter().zip(ysrc).enumerate() {
                        out[base + p * MR + r] = op.apply(xv, yv);
                    }
                }
            }
        }
    }
    out
}

/// [`pack_b_panel`] with the element source replaced by `x op y`. The
/// combined variant goes element-wise where the plain packer uses
/// `copy_from_slice`, but writes the same slots.
fn pack_b_panel_fused(
    x: &MatrixView<'_>,
    y: Option<&(MatrixView<'_>, CombineOp)>,
    col0: usize,
    cols: usize,
    k: usize,
) -> Vec<f32> {
    let strips = cols.div_ceil(NR);
    let mut out = vec![0.0f32; strips * k * NR];
    for s in 0..strips {
        let base = s * k * NR;
        let c0 = col0 + s * NR;
        let width = NR.min(cols - s * NR);
        for p in 0..k {
            let src = &x.row(p)[c0..c0 + width];
            match y {
                None => out[base + p * NR..base + p * NR + width].copy_from_slice(src),
                Some((yv, op)) => {
                    let ysrc = &yv.row(p)[c0..c0 + width];
                    for (c, (&xv, &yv)) in src.iter().zip(ysrc).enumerate() {
                        out[base + p * NR + c] = op.apply(xv, yv);
                    }
                }
            }
        }
    }
    out
}

/// [`pack_a_panel`] generalized over the storage element: identical slot
/// arithmetic, each source element passed through `conv` on the way in.
/// The f32 packers above stay as dedicated functions so the legacy path
/// is untouched; this handles every other dtype.
fn pack_a_panel_conv<T: Copy>(
    a: &MatrixView<'_>,
    row0: usize,
    rows: usize,
    k: usize,
    zero: T,
    conv: impl Fn(f32) -> T,
) -> Vec<T> {
    let strips = rows.div_ceil(MR);
    let mut out = vec![zero; strips * k * MR];
    for s in 0..strips {
        let base = s * k * MR;
        for r in 0..MR.min(rows - s * MR) {
            let src = a.row(row0 + s * MR + r);
            for (p, &v) in src.iter().enumerate() {
                out[base + p * MR + r] = conv(v);
            }
        }
    }
    out
}

/// [`pack_a_panel_fused`] generalized over the storage element: the
/// combination is formed in f32 (`op.apply`), then converted.
fn pack_a_panel_fused_conv<T: Copy>(
    x: &MatrixView<'_>,
    y: Option<&(MatrixView<'_>, CombineOp)>,
    row0: usize,
    rows: usize,
    k: usize,
    zero: T,
    conv: impl Fn(f32) -> T,
) -> Vec<T> {
    let strips = rows.div_ceil(MR);
    let mut out = vec![zero; strips * k * MR];
    for s in 0..strips {
        let base = s * k * MR;
        for r in 0..MR.min(rows - s * MR) {
            let row = row0 + s * MR + r;
            let src = x.row(row);
            match y {
                None => {
                    for (p, &v) in src.iter().enumerate() {
                        out[base + p * MR + r] = conv(v);
                    }
                }
                Some((yv, op)) => {
                    let ysrc = yv.row(row);
                    for (p, (&xv, &yv)) in src.iter().zip(ysrc).enumerate() {
                        out[base + p * MR + r] = conv(op.apply(xv, yv));
                    }
                }
            }
        }
    }
    out
}

/// [`pack_b_panel`] generalized over the storage element.
fn pack_b_panel_conv<T: Copy>(
    b: &MatrixView<'_>,
    col0: usize,
    cols: usize,
    k: usize,
    zero: T,
    conv: impl Fn(f32) -> T,
) -> Vec<T> {
    let strips = cols.div_ceil(NR);
    let mut out = vec![zero; strips * k * NR];
    for s in 0..strips {
        let base = s * k * NR;
        let c0 = col0 + s * NR;
        let width = NR.min(cols - s * NR);
        for p in 0..k {
            let src = &b.row(p)[c0..c0 + width];
            for (c, &v) in src.iter().enumerate() {
                out[base + p * NR + c] = conv(v);
            }
        }
    }
    out
}

/// [`pack_b_panel_fused`] generalized over the storage element.
fn pack_b_panel_fused_conv<T: Copy>(
    x: &MatrixView<'_>,
    y: Option<&(MatrixView<'_>, CombineOp)>,
    col0: usize,
    cols: usize,
    k: usize,
    zero: T,
    conv: impl Fn(f32) -> T,
) -> Vec<T> {
    let strips = cols.div_ceil(NR);
    let mut out = vec![zero; strips * k * NR];
    for s in 0..strips {
        let base = s * k * NR;
        let c0 = col0 + s * NR;
        let width = NR.min(cols - s * NR);
        for p in 0..k {
            let src = &x.row(p)[c0..c0 + width];
            match y {
                None => {
                    for (c, &v) in src.iter().enumerate() {
                        out[base + p * NR + c] = conv(v);
                    }
                }
                Some((yv, op)) => {
                    let ysrc = &yv.row(p)[c0..c0 + width];
                    for (c, (&xv, &yv)) in src.iter().zip(ysrc).enumerate() {
                        out[base + p * NR + c] = conv(op.apply(xv, yv));
                    }
                }
            }
        }
    }
    out
}

/// Pack `cols` columns of B starting at `col0` into NR-strips, k-major.
/// Element `(p, col0 + s*NR + c)` of B lands at `s*k*NR + p*NR + c`.
fn pack_b_panel(b: &MatrixView<'_>, col0: usize, cols: usize, k: usize) -> Vec<f32> {
    let strips = cols.div_ceil(NR);
    let mut out = vec![0.0f32; strips * k * NR];
    for s in 0..strips {
        let base = s * k * NR;
        let c0 = col0 + s * NR;
        let width = NR.min(cols - s * NR);
        for p in 0..k {
            let src = b.row(p);
            out[base + p * NR..base + p * NR + width].copy_from_slice(&src[c0..c0 + width]);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::Matrix;
    use crate::util::check;

    #[test]
    fn a_panel_layout_is_transposed_strips() {
        // 6x3 A, si = 6: one panel, two strips (4 + 2 rows).
        let a = Matrix::from_vec(
            6,
            3,
            (0..18).map(|v| v as f32).collect::<Vec<_>>(),
        );
        let plan = BlockPlan::new(6, 3, 8, 6, 8);
        let p = PackedPanels::pack(a.view(), Matrix::zeros(3, 8).view(), &plan);
        let (ap, rows) = p.a_panel(0);
        assert_eq!(rows, 6);
        assert_eq!(ap.len(), 2 * 3 * MR);
        // Strip 0, k = 0 holds column 0 of rows 0..4: [0, 3, 6, 9].
        assert_eq!(&ap[0..4], &[0.0, 3.0, 6.0, 9.0]);
        // Strip 0, k = 2 holds column 2 of rows 0..4: [2, 5, 8, 11].
        assert_eq!(&ap[2 * MR..2 * MR + 4], &[2.0, 5.0, 8.0, 11.0]);
        // Strip 1, k = 0: rows 4..6 then zero padding.
        assert_eq!(&ap[3 * MR..3 * MR + 4], &[12.0, 15.0, 0.0, 0.0]);
    }

    #[test]
    fn b_panel_layout_is_row_strips() {
        // 2x10 B, sj = 10: one panel, two strips (8 + 2 cols).
        let b = Matrix::from_vec(
            2,
            10,
            (0..20).map(|v| v as f32).collect::<Vec<_>>(),
        );
        let plan = BlockPlan::new(4, 2, 10, 4, 10);
        let p = PackedPanels::pack(Matrix::zeros(4, 2).view(), b.view(), &plan);
        let (bp, cols) = p.b_panel(0);
        assert_eq!(cols, 10);
        assert_eq!(bp.len(), 2 * 2 * NR);
        // Strip 0, k = 0: columns 0..8 of row 0.
        assert_eq!(&bp[0..NR], &[0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0]);
        // Strip 1, k = 1: columns 8..10 of row 1, zero-padded.
        assert_eq!(&bp[2 * NR + NR..2 * NR + NR + 4], &[18.0, 19.0, 0.0, 0.0]);
    }

    #[test]
    fn panels_cover_whole_operands() {
        let a = Matrix::random(50, 13, 7);
        let b = Matrix::random(13, 41, 8);
        let plan = BlockPlan::new(50, 13, 41, 16, 16);
        let p = PackedPanels::pack(a.view(), b.view(), &plan);
        assert_eq!(p.a_half().num_panels(), plan.blocks_i());
        assert_eq!(p.b_half().num_panels(), plan.blocks_j());
        assert_eq!(p.a_half().rows.iter().sum::<usize>(), 50);
        assert_eq!(p.b_half().cols.iter().sum::<usize>(), 41);
    }

    #[test]
    fn shared_b_half_is_bit_identical_to_private_pack() {
        // The sharing guarantee the batched server path rests on: a B
        // packed once and composed with any A's half equals (bit for
        // bit) the B half of a private per-job pack.
        let b = Matrix::random(23, 37, 9);
        let shared = Arc::new(PackedB::pack(b.view(), 12));
        for (m, seed) in [(17usize, 10u64), (40, 11), (3, 12)] {
            let a = Matrix::random(m, 23, seed);
            let plan = BlockPlan::new(m, 23, 37, 16, 12);
            let private = PackedPanels::pack(a.view(), b.view(), &plan);
            let composed = PackedPanels::from_parts(
                Arc::new(PackedA::pack(a.view(), 16)),
                shared.clone(),
            );
            for bj in 0..plan.blocks_j() {
                assert_eq!(private.b_panel(bj), composed.b_panel(bj));
            }
            for bi in 0..plan.blocks_i() {
                assert_eq!(private.a_panel(bi), composed.a_panel(bi));
            }
            assert_eq!(private.packed_len(), composed.packed_len());
        }
    }

    #[test]
    fn clones_share_storage_and_sharing_is_observable() {
        let a = Matrix::random(8, 6, 20);
        let b = Matrix::random(6, 10, 21);
        let plan = BlockPlan::new(8, 6, 10, 4, 8);
        let p = PackedPanels::pack(a.view(), b.view(), &plan);
        let q = p.clone();
        assert!(Arc::ptr_eq(p.b_half(), q.b_half()), "clone must share the packed B");
        let r = PackedPanels::pack(a.view(), b.view(), &plan);
        assert!(!Arc::ptr_eq(p.b_half(), r.b_half()), "independent packs must not alias");
    }

    #[test]
    #[should_panic(expected = "disagree on contraction depth")]
    fn from_parts_rejects_mismatched_k() {
        let a = Arc::new(PackedA::pack(Matrix::zeros(4, 5).view(), 4));
        let b = Arc::new(PackedB::pack(Matrix::zeros(6, 4).view(), 4));
        PackedPanels::from_parts(a, b);
    }

    #[test]
    fn fused_pack_equals_materialize_then_pack() {
        // The fused-combine guarantee Strassen's leaf packing rests on:
        // packing `x op y` straight from two views is bit-identical to
        // materializing the combination first and packing that.
        for op in [CombineOp::Add, CombineOp::Sub] {
            for (rows, cols, s) in [(13usize, 9usize, 5usize), (16, 16, 16), (7, 21, 4)] {
                let x = Matrix::random(rows, cols, 31);
                let y = Matrix::random(rows, cols, 32);
                let mut mat = Matrix::zeros(rows, cols);
                for i in 0..rows * cols {
                    mat.data[i] = op.apply(x.data[i], y.data[i]);
                }
                let fused_a = PackedA::from_sum_of_views(x.view(), Some((y.view(), op)), s);
                let plain_a = PackedA::pack(mat.view(), s);
                assert_eq!(fused_a.panels, plain_a.panels, "A {op:?} {rows}x{cols}/{s}");
                assert_eq!(fused_a.rows, plain_a.rows);
                let fused_b = PackedB::from_sum_of_views(x.view(), Some((y.view(), op)), s);
                let plain_b = PackedB::pack(mat.view(), s);
                assert_eq!(fused_b.panels, plain_b.panels, "B {op:?} {rows}x{cols}/{s}");
                assert_eq!(fused_b.cols, plain_b.cols);
            }
        }
    }

    #[test]
    fn fused_pack_single_view_equals_plain_pack() {
        let x = Matrix::random(11, 14, 33);
        let fa = PackedA::from_sum_of_views(x.view(), None, 6);
        let pa = PackedA::pack(x.view(), 6);
        assert_eq!(fa.panels, pa.panels);
        let fb = PackedB::from_sum_of_views(x.view(), None, 6);
        let pb = PackedB::pack(x.view(), 6);
        assert_eq!(fb.panels, pb.panels);
    }

    #[test]
    fn fused_pack_from_quadrant_views() {
        // Strassen's actual call shape: both views are strided quadrant
        // windows of one parent.
        let parent = Matrix::random(10, 12, 34);
        let v = parent.view();
        let q11 = v.block(0, 0, 5, 6);
        let q22 = v.block(5, 6, 5, 6);
        let mut sum = Matrix::zeros(5, 6);
        crate::gemm::ops::add_into(q11, q22, &mut sum.view_mut());
        let fused = PackedA::from_sum_of_views(
            v.block(0, 0, 5, 6),
            Some((v.block(5, 6, 5, 6), CombineOp::Add)),
            4,
        );
        let plain = PackedA::pack(sum.view(), 4);
        assert_eq!(fused.panels, plain.panels);
    }

    #[test]
    #[should_panic(expected = "fused operand shape mismatch")]
    fn fused_pack_rejects_shape_mismatch() {
        let x = Matrix::zeros(4, 4);
        let y = Matrix::zeros(4, 5);
        PackedA::from_sum_of_views(x.view(), Some((y.view(), CombineOp::Add)), 4);
    }

    #[test]
    fn dtype_f32_pack_is_bit_identical_to_plain_pack() {
        // The tentpole's bit-identity guarantee at the pack layer:
        // requesting F32 through the dtype entry points runs the exact
        // legacy packers.
        let a = Matrix::random(29, 17, 40);
        let pa = PackedA::pack(a.view(), 12);
        let da = PackedA::pack_dtype(a.view(), 12, Dtype::F32);
        assert_eq!(da.dtype(), Dtype::F32);
        assert_eq!(pa.panels, da.panels);
        assert_eq!(pa.rows, da.rows);
        let b = Matrix::random(17, 23, 41);
        let pb = PackedB::pack(b.view(), 10);
        let db = PackedB::pack_dtype(b.view(), 10, Dtype::F32);
        assert_eq!(pb.panels, db.panels);
        assert_eq!(pb.packed_bytes(), db.packed_bytes());
    }

    #[test]
    fn dtype_packs_store_converted_elements() {
        use crate::gemm::dtype::{f32_to_bf16_bits, f32_to_f16_bits};
        let a = Matrix::random(13, 7, 42);
        let f32p = PackedA::pack(a.view(), 8);
        for dtype in [Dtype::F64, Dtype::F16, Dtype::Bf16] {
            let p = PackedA::pack_dtype(a.view(), 8, dtype);
            assert_eq!(p.dtype(), dtype);
            assert_eq!(p.packed_len(), f32p.packed_len());
            assert_eq!(p.packed_bytes(), (p.packed_len() * dtype.bytes()) as u64);
            for bi in 0..p.num_panels() {
                let (f32strip, _) = f32p.panel(bi);
                match p.panel_ref(bi).0 {
                    PanelRef::F64(w) => {
                        for (x, &v) in w.iter().zip(f32strip) {
                            assert_eq!(*x, v as f64); // widening is exact
                        }
                    }
                    PanelRef::Half(h) => {
                        let enc = match dtype {
                            Dtype::F16 => f32_to_f16_bits,
                            _ => f32_to_bf16_bits,
                        };
                        for (x, &v) in h.iter().zip(f32strip) {
                            assert_eq!(*x, enc(v), "slot mismatch at {dtype}");
                        }
                    }
                    PanelRef::F32(_) => panic!("expected non-f32 store"),
                }
            }
        }
    }

    #[test]
    fn fused_dtype_pack_equals_materialize_then_pack_dtype() {
        let x = Matrix::random(11, 9, 43);
        let y = Matrix::random(11, 9, 44);
        let mut mat = Matrix::zeros(11, 9);
        for i in 0..11 * 9 {
            mat.data[i] = CombineOp::Sub.apply(x.data[i], y.data[i]);
        }
        for dtype in [Dtype::F64, Dtype::F16, Dtype::Bf16] {
            let fused = PackedA::from_sum_of_views_dtype(
                x.view(),
                Some((y.view(), CombineOp::Sub)),
                4,
                dtype,
            );
            let plain = PackedA::pack_dtype(mat.view(), 4, dtype);
            assert_eq!(fused.wide_panels, plain.wide_panels, "{dtype}");
            assert_eq!(fused.half_panels, plain.half_panels, "{dtype}");
            let fused_b = PackedB::from_sum_of_views_dtype(
                x.view(),
                Some((y.view(), CombineOp::Sub)),
                4,
                dtype,
            );
            let plain_b = PackedB::pack_dtype(mat.view(), 4, dtype);
            assert_eq!(fused_b.wide_panels, plain_b.wide_panels, "{dtype}");
            assert_eq!(fused_b.half_panels, plain_b.half_panels, "{dtype}");
        }
    }

    #[test]
    #[should_panic(expected = "disagree on dtype")]
    fn from_parts_rejects_mismatched_dtype() {
        let a = Arc::new(PackedA::pack_dtype(Matrix::zeros(4, 5).view(), 4, Dtype::Bf16));
        let b = Arc::new(PackedB::pack(Matrix::zeros(5, 4).view(), 4));
        PackedPanels::from_parts(a, b);
    }

    #[test]
    fn prop_pack_preserves_every_element() {
        check::cases(48, |rng| {
            let (m, k, n) = (rng.range(1, 30), rng.range(1, 20), rng.range(1, 30));
            let (si, sj) = (rng.range(1, 16), rng.range(1, 16));
            let seed = rng.next_u64();
            let a = Matrix::random(m, k, seed);
            let b = Matrix::random(k, n, seed + 1);
            let plan = BlockPlan::new(m, k, n, si, sj);
            let p = PackedPanels::pack(a.view(), b.view(), &plan);
            // Every A element is recoverable from its packed slot.
            for bi in 0..plan.blocks_i() {
                let (ap, rows) = p.a_panel(bi);
                for r in 0..rows {
                    let (s, rr) = (r / MR, r % MR);
                    for p_idx in 0..k {
                        let got = ap[s * k * MR + p_idx * MR + rr];
                        assert_eq!(got, a.get(bi * si + r, p_idx));
                    }
                }
            }
            // Every B element likewise.
            for bj in 0..plan.blocks_j() {
                let (bp, cols) = p.b_panel(bj);
                for c in 0..cols {
                    let (s, cc) = (c / NR, c % NR);
                    for p_idx in 0..k {
                        let got = bp[s * k * NR + p_idx * NR + cc];
                        assert_eq!(got, b.get(p_idx, bj * sj + c));
                    }
                }
            }
        });
    }
}
