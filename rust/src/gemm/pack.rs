//! Panel packing — each operand element is touched once per *job*, not
//! once per task.
//!
//! The old hot path re-copied a full `S_i x K` slice of A and a
//! `K x S_j` slice of B out of the operands for every WQM task (so a
//! `bi` row-panel was copied `blocks_j` times and a `bj` column-panel
//! `blocks_i` times). [`PackedPanels`] does the copy exactly once per
//! panel, into the layout the register-blocked microkernel streams:
//!
//! * A's row-panel `bi` is stored as `ceil(rows/MR)` strips; within a
//!   strip the layout is k-major with `MR` row-adjacent values per k —
//!   i.e. *transposed*, so a column of `SA_i` is contiguous, the same
//!   layout fix the MAC applies to A for burst-friendly DDR reads
//!   (Section III-C);
//! * B's column-panel `bj` is `ceil(cols/NR)` strips, k-major with `NR`
//!   column-adjacent values per k.
//!
//! Ragged strips are zero-padded to the full `MR`/`NR` width so the
//! microkernel never branches on edges; the padding contributes exact
//! `+0.0` terms and the writer clips them on the way out.

use crate::blocking::BlockPlan;

use super::microkernel::{MR, NR};
use super::view::MatrixView;

/// Both operands of one GEMM job, packed panel-by-panel for the
/// microkernel. Built once per job by the coordinator (or by
/// [`super::packed_matmul`]); shared read-only across all workers.
#[derive(Debug, Clone)]
pub struct PackedPanels {
    k: usize,
    /// Per block-row of A: strip-major `[strip][k][MR]` packing.
    a_panels: Vec<Vec<f32>>,
    /// Effective (unpadded) rows per A panel.
    a_rows: Vec<usize>,
    /// Per block-column of B: strip-major `[strip][k][NR]` packing.
    b_panels: Vec<Vec<f32>>,
    /// Effective (unpadded) columns per B panel.
    b_cols: Vec<usize>,
}

impl PackedPanels {
    /// Pack `a` (`M x K`) and `b` (`K x N`) for `plan`'s block grid.
    pub fn pack(a: MatrixView<'_>, b: MatrixView<'_>, plan: &BlockPlan) -> Self {
        assert_eq!((a.rows(), a.cols()), (plan.m, plan.k), "A shape mismatch");
        assert_eq!((b.rows(), b.cols()), (plan.k, plan.n), "B shape mismatch");
        let k = plan.k;
        let mut a_panels = Vec::with_capacity(plan.blocks_i());
        let mut a_rows = Vec::with_capacity(plan.blocks_i());
        for bi in 0..plan.blocks_i() {
            let row0 = bi * plan.si;
            let rows = plan.si.min(plan.m - row0);
            a_panels.push(pack_a_panel(&a, row0, rows, k));
            a_rows.push(rows);
        }
        let mut b_panels = Vec::with_capacity(plan.blocks_j());
        let mut b_cols = Vec::with_capacity(plan.blocks_j());
        for bj in 0..plan.blocks_j() {
            let col0 = bj * plan.sj;
            let cols = plan.sj.min(plan.n - col0);
            b_panels.push(pack_b_panel(&b, col0, cols, k));
            b_cols.push(cols);
        }
        Self { k, a_panels, a_rows, b_panels, b_cols }
    }

    /// Shared contraction depth K.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Packed strips of A's row-panel `bi` and its effective row count.
    pub fn a_panel(&self, bi: usize) -> (&[f32], usize) {
        (&self.a_panels[bi], self.a_rows[bi])
    }

    /// Packed strips of B's column-panel `bj` and its effective column
    /// count.
    pub fn b_panel(&self, bj: usize) -> (&[f32], usize) {
        (&self.b_panels[bj], self.b_cols[bj])
    }

    /// Total packed floats (diagnostics: equals padded operand sizes).
    pub fn packed_len(&self) -> usize {
        self.a_panels.iter().map(Vec::len).sum::<usize>()
            + self.b_panels.iter().map(Vec::len).sum::<usize>()
    }
}

/// Pack `rows` rows of A starting at `row0` into MR-strips, k-major.
/// Element `(row0 + s*MR + r, p)` of A lands at `s*k*MR + p*MR + r`.
fn pack_a_panel(a: &MatrixView<'_>, row0: usize, rows: usize, k: usize) -> Vec<f32> {
    let strips = rows.div_ceil(MR);
    let mut out = vec![0.0f32; strips * k * MR];
    for s in 0..strips {
        let base = s * k * MR;
        for r in 0..MR.min(rows - s * MR) {
            let src = a.row(row0 + s * MR + r);
            for (p, &v) in src.iter().enumerate() {
                out[base + p * MR + r] = v;
            }
        }
    }
    out
}

/// Pack `cols` columns of B starting at `col0` into NR-strips, k-major.
/// Element `(p, col0 + s*NR + c)` of B lands at `s*k*NR + p*NR + c`.
fn pack_b_panel(b: &MatrixView<'_>, col0: usize, cols: usize, k: usize) -> Vec<f32> {
    let strips = cols.div_ceil(NR);
    let mut out = vec![0.0f32; strips * k * NR];
    for s in 0..strips {
        let base = s * k * NR;
        let c0 = col0 + s * NR;
        let width = NR.min(cols - s * NR);
        for p in 0..k {
            let src = b.row(p);
            out[base + p * NR..base + p * NR + width].copy_from_slice(&src[c0..c0 + width]);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::Matrix;
    use crate::util::check;

    #[test]
    fn a_panel_layout_is_transposed_strips() {
        // 6x3 A, si = 6: one panel, two strips (4 + 2 rows).
        let a = Matrix::from_vec(
            6,
            3,
            (0..18).map(|v| v as f32).collect::<Vec<_>>(),
        );
        let plan = BlockPlan::new(6, 3, 8, 6, 8);
        let p = PackedPanels::pack(a.view(), Matrix::zeros(3, 8).view(), &plan);
        let (ap, rows) = p.a_panel(0);
        assert_eq!(rows, 6);
        assert_eq!(ap.len(), 2 * 3 * MR);
        // Strip 0, k = 0 holds column 0 of rows 0..4: [0, 3, 6, 9].
        assert_eq!(&ap[0..4], &[0.0, 3.0, 6.0, 9.0]);
        // Strip 0, k = 2 holds column 2 of rows 0..4: [2, 5, 8, 11].
        assert_eq!(&ap[2 * MR..2 * MR + 4], &[2.0, 5.0, 8.0, 11.0]);
        // Strip 1, k = 0: rows 4..6 then zero padding.
        assert_eq!(&ap[3 * MR..3 * MR + 4], &[12.0, 15.0, 0.0, 0.0]);
    }

    #[test]
    fn b_panel_layout_is_row_strips() {
        // 2x10 B, sj = 10: one panel, two strips (8 + 2 cols).
        let b = Matrix::from_vec(
            2,
            10,
            (0..20).map(|v| v as f32).collect::<Vec<_>>(),
        );
        let plan = BlockPlan::new(4, 2, 10, 4, 10);
        let p = PackedPanels::pack(Matrix::zeros(4, 2).view(), b.view(), &plan);
        let (bp, cols) = p.b_panel(0);
        assert_eq!(cols, 10);
        assert_eq!(bp.len(), 2 * 2 * NR);
        // Strip 0, k = 0: columns 0..8 of row 0.
        assert_eq!(&bp[0..NR], &[0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0]);
        // Strip 1, k = 1: columns 8..10 of row 1, zero-padded.
        assert_eq!(&bp[2 * NR + NR..2 * NR + NR + 4], &[18.0, 19.0, 0.0, 0.0]);
    }

    #[test]
    fn panels_cover_whole_operands() {
        let a = Matrix::random(50, 13, 7);
        let b = Matrix::random(13, 41, 8);
        let plan = BlockPlan::new(50, 13, 41, 16, 16);
        let p = PackedPanels::pack(a.view(), b.view(), &plan);
        assert_eq!(p.a_panels.len(), plan.blocks_i());
        assert_eq!(p.b_panels.len(), plan.blocks_j());
        assert_eq!(p.a_rows.iter().sum::<usize>(), 50);
        assert_eq!(p.b_cols.iter().sum::<usize>(), 41);
    }

    #[test]
    fn prop_pack_preserves_every_element() {
        check::cases(48, |rng| {
            let (m, k, n) = (rng.range(1, 30), rng.range(1, 20), rng.range(1, 30));
            let (si, sj) = (rng.range(1, 16), rng.range(1, 16));
            let seed = rng.next_u64();
            let a = Matrix::random(m, k, seed);
            let b = Matrix::random(k, n, seed + 1);
            let plan = BlockPlan::new(m, k, n, si, sj);
            let p = PackedPanels::pack(a.view(), b.view(), &plan);
            // Every A element is recoverable from its packed slot.
            for bi in 0..plan.blocks_i() {
                let (ap, rows) = p.a_panel(bi);
                for r in 0..rows {
                    let (s, rr) = (r / MR, r % MR);
                    for p_idx in 0..k {
                        let got = ap[s * k * MR + p_idx * MR + rr];
                        assert_eq!(got, a.get(bi * si + r, p_idx));
                    }
                }
            }
            // Every B element likewise.
            for bj in 0..plan.blocks_j() {
                let (bp, cols) = p.b_panel(bj);
                for c in 0..cols {
                    let (s, cc) = (c / NR, c % NR);
                    for p_idx in 0..k {
                        let got = bp[s * k * NR + p_idx * NR + cc];
                        assert_eq!(got, b.get(p_idx, bj * sj + c));
                    }
                }
            }
        });
    }
}
