//! Dense-matrix substrate: row-major FP32 matrices, the paper's blocked
//! algorithm, and the zero-copy panel pipeline the coordinator serves
//! from.
//!
//! Three numeric layers, slowest to fastest, each checked against the
//! one above it:
//!
//! * [`Matrix::matmul`] — naive triple loop, the audit-grade oracle
//!   (also cross-checked against the jnp oracle through the pytest
//!   suite at artifact-build time);
//! * [`block_task`] / [`blocked_matmul`] — the functional form of the
//!   PE array's k-i-j dataflow, bit-for-bit what the simulated arrays
//!   produce; kept as the readable reference the fast path is verified
//!   against;
//! * the packed pipeline — [`view`]'s borrowed [`MatrixView`] /
//!   [`MatrixViewMut`] windows feed [`pack`]'s refcounted halves
//!   ([`PackedA`] / [`PackedB`], composed per job as [`PackedPanels`]:
//!   each operand element packed once, A panels transposed exactly
//!   like the MAC's layout fix, and a half shareable across jobs —
//!   a batch with one B packs it once), [`microkernel`]'s register-blocked
//!   `MR x NR` kernel does the FLOPs, and [`DisjointBlocks`] streams
//!   finished blocks into C without locks. [`packed_matmul`] composes
//!   them single-threaded; the coordinator runs the same pieces across
//!   its work-stealing workers.
//!
//! [`ops`] adds the row-streamed element-wise add/sub kernels the
//! Strassen layer ([`crate::strassen`]) uses to form operand
//! combinations and recombine quadrants through borrowed views.
//!
//! [`dtype`] makes element precision a job parameter: panels can be
//! packed in f64/f32/f16/bf16 ([`Dtype`]), the microkernel widens half
//! types back to f32 on load (accumulating in f32, natively in f64 for
//! `F64`), and results always stream into the f32 `C` buffer. `F32` jobs
//! run the pre-existing code paths bit for bit.

pub mod dtype;
mod matrix;
pub mod microkernel;
pub mod ops;
pub mod pack;
pub mod view;

pub use dtype::Dtype;
pub use matrix::Matrix;
pub use microkernel::{micro_kernel, task_product, task_product_into, MR, NR};
pub use ops::CombineOp;
pub use pack::{PackedA, PackedB, PackedPanels, PanelRef};
pub use view::{DisjointBlocks, MatrixView, MatrixViewMut};

use crate::blocking::BlockPlan;

/// Functional execution of the paper's blocked algorithm (Eq. 2): compute
/// every sub-block task `C_ij = SA_i x SB_j` by rank-1 updates in the PE
/// array's accumulation order, then assemble C. Bit-for-bit identical to
/// what the simulated arrays produce, and allclose to the oracle.
pub fn blocked_matmul(a: &Matrix, b: &Matrix, si: usize, sj: usize) -> Matrix {
    assert_eq!(a.cols, b.rows, "contraction mismatch");
    let plan = BlockPlan::new(a.rows, a.cols, b.cols, si, sj);
    let mut c = Matrix::zeros(a.rows, b.cols);
    for task in plan.tasks() {
        let block = block_task(a, b, task.row0, task.col0, task.si, task.sj);
        c.set_block(task.row0, task.col0, &block);
    }
    c
}

/// One sub-block task in the PE dataflow order: for each k, the column
/// `V_k = SA_i[:, k]` is held in the R_a registers and the row
/// `U_k = SB_j[k, :]` streams through, accumulating `C += V_k (x) U_k`.
/// `row0/col0` locate the block; edge blocks are implicitly zero-padded.
pub fn block_task(
    a: &Matrix,
    b: &Matrix,
    row0: usize,
    col0: usize,
    si: usize,
    sj: usize,
) -> Matrix {
    let rows = si.min(a.rows - row0);
    let cols = sj.min(b.cols - col0);
    let mut c = Matrix::zeros(rows, cols);
    // Loop order k-i-j — the array's own schedule (rank-1 update per k).
    // §Perf: measured 12.9 GFLOP/s at 128x9216x128 vs 7.9 for i-k-j;
    // each B row is read once (streamed like the f_b FIFO) while the C
    // block (64 KB) stays cache-resident, exactly the reuse the paper's
    // M_c local memories exploit.
    for k in 0..a.cols {
        let brow = &b.data[k * b.cols + col0..k * b.cols + col0 + cols];
        for i in 0..rows {
            let v = a.get(row0 + i, k); // R_a, reused S_j times
            if v == 0.0 {
                continue; // zero-padded lane
            }
            let crow = &mut c.data[i * cols..(i + 1) * cols];
            for (cc, bb) in crow.iter_mut().zip(brow) {
                *cc += v * bb; // FMAC
            }
        }
    }
    c
}

/// Full GEMM through the packed panel pipeline: pack both operands once,
/// then run the register-blocked microkernel over every task of the
/// block grid, writing blocks in place. Single-threaded twin of the
/// coordinator's hot path; same task decomposition as [`blocked_matmul`]
/// but with panel reuse instead of per-task copies.
pub fn packed_matmul(a: &Matrix, b: &Matrix, si: usize, sj: usize) -> Matrix {
    assert_eq!(a.cols, b.rows, "contraction mismatch");
    let plan = BlockPlan::new(a.rows, a.cols, b.cols, si, sj);
    let panels = PackedPanels::pack(a.view(), b.view(), &plan);
    let mut c = Matrix::zeros(a.rows, b.cols);
    {
        let writer = DisjointBlocks::new(c.view_mut());
        for task in plan.tasks() {
            // SAFETY: `plan.tasks()` yields each task exactly once and
            // tasks tile C disjointly, so no block is written twice.
            unsafe { task_product_into(&panels, &task, &writer) };
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check;

    fn rand_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
        Matrix::random(rows, cols, seed)
    }

    #[test]
    fn blocked_equals_naive_exact_blocks() {
        let a = rand_matrix(32, 24, 1);
        let b = rand_matrix(24, 16, 2);
        let got = blocked_matmul(&a, &b, 8, 8);
        let want = a.matmul(&b);
        assert!(got.allclose(&want, 1e-4), "max err {}", got.max_abs_diff(&want));
    }

    #[test]
    fn blocked_equals_naive_ragged() {
        let a = rand_matrix(37, 53, 3);
        let b = rand_matrix(53, 41, 4);
        let got = blocked_matmul(&a, &b, 16, 16);
        assert!(got.allclose(&a.matmul(&b), 1e-4));
    }

    #[test]
    fn asymmetric_blocks() {
        let a = rand_matrix(20, 10, 5);
        let b = rand_matrix(10, 30, 6);
        let got = blocked_matmul(&a, &b, 8, 12);
        assert!(got.allclose(&a.matmul(&b), 1e-4));
    }

    #[test]
    fn single_block_task_is_whole_product() {
        let a = rand_matrix(8, 5, 7);
        let b = rand_matrix(5, 8, 8);
        let got = block_task(&a, &b, 0, 0, 8, 8);
        assert!(got.allclose(&a.matmul(&b), 1e-5));
    }

    #[test]
    fn prop_blocked_matches_naive() {
        check::cases(32, |rng| {
            let (m, k, n) = (rng.range(1, 40), rng.range(1, 40), rng.range(1, 40));
            let (si, sj) = (rng.range(1, 20), rng.range(1, 20));
            let seed = rng.next_u64();
            let a = rand_matrix(m, k, seed);
            let b = rand_matrix(k, n, seed + 1);
            let got = blocked_matmul(&a, &b, si, sj);
            assert!(got.allclose(&a.matmul(&b), 1e-3));
        });
    }

    #[test]
    fn packed_matmul_matches_oracle() {
        let a = rand_matrix(48, 36, 9);
        let b = rand_matrix(36, 56, 10);
        let got = packed_matmul(&a, &b, 16, 16);
        let want = a.matmul(&b);
        assert!(got.allclose(&want, 1e-4), "max err {}", got.max_abs_diff(&want));
    }

    #[test]
    fn packed_matmul_matches_blocked_on_ragged_shapes() {
        let a = rand_matrix(37, 53, 11);
        let b = rand_matrix(53, 41, 12);
        let got = packed_matmul(&a, &b, 16, 12);
        let want = blocked_matmul(&a, &b, 16, 12);
        assert!(got.allclose(&want, 1e-5));
    }

    #[test]
    fn prop_packed_matches_naive() {
        check::cases(32, |rng| {
            let (m, k, n) = (rng.range(1, 40), rng.range(1, 40), rng.range(1, 40));
            let (si, sj) = (rng.range(1, 20), rng.range(1, 20));
            let seed = rng.next_u64();
            let a = rand_matrix(m, k, seed);
            let b = rand_matrix(k, n, seed + 1);
            let got = packed_matmul(&a, &b, si, sj);
            assert!(got.allclose(&a.matmul(&b), 1e-3));
        });
    }

    #[test]
    fn prop_block_task_covers_edges() {
        // Every edge block has the clipped shape, never out of bounds.
        check::cases(32, |rng| {
            let (m, n) = (rng.range(1, 30), rng.range(1, 30));
            let (si, sj) = (rng.range(1, 16), rng.range(1, 16));
            let seed = rng.next_u64();
            let a = rand_matrix(m, 7, seed);
            let b = rand_matrix(7, n, seed + 1);
            let row0 = (m - 1) / si * si;
            let col0 = (n - 1) / sj * sj;
            let blk = block_task(&a, &b, row0, col0, si, sj);
            assert_eq!(blk.rows, m - row0);
            assert_eq!(blk.cols, n - col0);
        });
    }
}
