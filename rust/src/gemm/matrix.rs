//! Row-major FP32 matrix with the handful of operations the accelerator
//! stack needs: oracle matmul, cache-blocked transpose (the MAC's layout
//! fix for A), zero-padding (Section IV), block get/set, borrowed views,
//! and comparison helpers.

use crate::util::rng::Rng;

use super::view::{MatrixView, MatrixViewMut};

#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Self { rows, cols, data }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// Deterministic pseudo-random matrix in [-1, 1) — test/bench data.
    pub fn random(rows: usize, cols: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let data = (0..rows * cols).map(|_| rng.next_f32_signed()).collect();
        Self { rows, cols, data }
    }

    /// Deterministic pseudo-random matrix on the `k/256` grid — every
    /// element exactly representable in f16 and bf16, for half-precision
    /// bit-identity tests (see [`Rng::next_f32_grid`]).
    pub fn random_quantized(rows: usize, cols: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let data = (0..rows * cols).map(|_| rng.next_f32_grid()).collect();
        Self { rows, cols, data }
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Oracle GEMM: naive ikj triple loop, f32 accumulation.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "contraction mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self.get(i, k);
                if a == 0.0 {
                    continue;
                }
                let orow = &mut out.data[i * other.cols..(i + 1) * other.cols];
                let brow = other.row(k);
                for (o, b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// Reference GEMM with f64 accumulation (result narrowed to f32 at
    /// the end): the oracle the reduced-precision kernels are measured
    /// against — its own rounding error is negligible next to any
    /// f32/f16/bf16 path's.
    pub fn matmul_f64(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "contraction mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        let mut acc = vec![0.0f64; other.cols];
        for i in 0..self.rows {
            acc.fill(0.0);
            for k in 0..self.cols {
                let a = self.get(i, k) as f64;
                if a == 0.0 {
                    continue;
                }
                let brow = other.row(k);
                for (o, &b) in acc.iter_mut().zip(brow) {
                    *o += a * b as f64;
                }
            }
            for (o, &v) in out.data[i * other.cols..(i + 1) * other.cols]
                .iter_mut()
                .zip(&acc)
            {
                *o = v as f32;
            }
        }
        out
    }

    /// Borrowed read-only view of the whole matrix — the zero-copy entry
    /// point of the panel pipeline.
    pub fn view(&self) -> MatrixView<'_> {
        MatrixView::new(&self.data, self.rows, self.cols, self.cols)
    }

    /// Borrowed mutable view (dense stride), splittable into disjoint
    /// row bands and wrappable by [`super::DisjointBlocks`].
    pub fn view_mut(&mut self) -> MatrixViewMut<'_> {
        MatrixViewMut::new(&mut self.data, self.rows, self.cols, self.cols)
    }

    /// The MAC's transpose of A: makes column-of-SA fetches contiguous so
    /// both matrices stream in burst mode (Section III-C).
    ///
    /// Cache-blocked: walks `TILE x TILE` tiles so both the source reads
    /// and the (strided) destination writes stay within a tile that fits
    /// L1, instead of streaming one full strided column per output row.
    /// This routine feeds the MAC path and the panel packer, so it sits
    /// on the per-job setup path of every coordinator job.
    pub fn transpose(&self) -> Matrix {
        const TILE: usize = 32;
        let mut out = Matrix::zeros(self.cols, self.rows);
        for r0 in (0..self.rows).step_by(TILE) {
            let r1 = (r0 + TILE).min(self.rows);
            for c0 in (0..self.cols).step_by(TILE) {
                let c1 = (c0 + TILE).min(self.cols);
                for r in r0..r1 {
                    for c in c0..c1 {
                        out.data[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
            }
        }
        out
    }

    /// Zero-pad to (rows, cols) — Section IV's padding rule.
    pub fn pad_to(&self, rows: usize, cols: usize) -> Matrix {
        assert!(rows >= self.rows && cols >= self.cols, "pad must grow");
        let mut out = Matrix::zeros(rows, cols);
        for r in 0..self.rows {
            out.data[r * cols..r * cols + self.cols]
                .copy_from_slice(self.row(r));
        }
        out
    }

    /// Copy of the `rows x cols` block at (row0, col0), clipped to bounds.
    pub fn block(&self, row0: usize, col0: usize, rows: usize, cols: usize) -> Matrix {
        let r1 = (row0 + rows).min(self.rows);
        let c1 = (col0 + cols).min(self.cols);
        let mut out = Matrix::zeros(r1 - row0, c1 - col0);
        for (i, r) in (row0..r1).enumerate() {
            let src = &self.data[r * self.cols + col0..r * self.cols + c1];
            out.data[i * out.cols..(i + 1) * out.cols].copy_from_slice(src);
        }
        out
    }

    /// Write `block` into this matrix at (row0, col0).
    pub fn set_block(&mut self, row0: usize, col0: usize, block: &Matrix) {
        assert!(row0 + block.rows <= self.rows && col0 + block.cols <= self.cols);
        for i in 0..block.rows {
            let dst_off = (row0 + i) * self.cols + col0;
            self.data[dst_off..dst_off + block.cols]
                .copy_from_slice(block.row(i));
        }
    }

    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Mixed absolute/relative closeness, scaled to the magnitude range.
    pub fn allclose(&self, other: &Matrix, tol: f32) -> bool {
        if (self.rows, self.cols) != (other.rows, other.cols) {
            return false;
        }
        let scale = self
            .data
            .iter()
            .map(|v| v.abs())
            .fold(1.0f32, f32::max);
        self.max_abs_diff(other) <= tol * scale
    }

    pub fn flops_of_matmul(m: usize, k: usize, n: usize) -> u64 {
        2 * m as u64 * k as u64 * n as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check;

    #[test]
    fn identity_matmul() {
        let a = Matrix::random(5, 5, 42);
        let got = a.matmul(&Matrix::identity(5));
        assert!(got.allclose(&a, 1e-7));
    }

    #[test]
    fn known_product() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_f64_agrees_with_f32_oracle() {
        let a = Matrix::random(13, 29, 50);
        let b = Matrix::random(29, 11, 51);
        let got = a.matmul_f64(&b);
        assert!(got.allclose(&a.matmul(&b), 1e-5));
    }

    #[test]
    fn quantized_random_is_half_exact() {
        use crate::gemm::dtype::{f16_bits_to_f32, f32_to_f16_bits};
        let m = Matrix::random_quantized(9, 7, 52);
        for &v in &m.data {
            assert_eq!(f16_bits_to_f32(f32_to_f16_bits(v)), v);
        }
    }

    #[test]
    fn transpose_involution() {
        let a = Matrix::random(7, 3, 1);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn transpose_values() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let t = a.transpose();
        assert_eq!(t.rows, 3);
        assert_eq!(t.get(2, 1), 6.0);
        assert_eq!(t.get(0, 1), 4.0);
    }

    #[test]
    fn transpose_ragged_tiles() {
        // Shapes straddling the 32-tile boundary in both dimensions.
        for (rows, cols) in [(1, 1), (31, 33), (32, 32), (33, 31), (65, 97), (100, 3)] {
            let a = Matrix::random(rows, cols, (rows * 1000 + cols) as u64);
            let t = a.transpose();
            assert_eq!((t.rows, t.cols), (cols, rows));
            for r in 0..rows {
                for c in 0..cols {
                    assert_eq!(t.get(c, r), a.get(r, c), "({rows}x{cols}) at ({r},{c})");
                }
            }
        }
    }

    #[test]
    fn pad_preserves_and_zeros() {
        let a = Matrix::random(3, 5, 2);
        let p = a.pad_to(8, 8);
        assert_eq!(p.block(0, 0, 3, 5), a);
        assert!(p.data[3 * 8..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn block_roundtrip() {
        let a = Matrix::random(10, 10, 3);
        let blk = a.block(4, 6, 4, 4);
        let mut b = Matrix::zeros(10, 10);
        b.set_block(4, 6, &blk);
        assert_eq!(b.block(4, 6, 4, 4), blk);
    }

    #[test]
    fn block_clips_at_edges() {
        let a = Matrix::random(10, 10, 4);
        let blk = a.block(8, 8, 4, 4);
        assert_eq!((blk.rows, blk.cols), (2, 2));
    }

    #[test]
    #[should_panic(expected = "contraction mismatch")]
    fn mismatched_matmul_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 3);
        a.matmul(&b);
    }

    #[test]
    fn prop_transpose_matmul_identity() {
        // (A B)^T = B^T A^T
        check::cases(48, |rng| {
            let (m, k, n) = (rng.range(1, 12), rng.range(1, 12), rng.range(1, 12));
            let seed = rng.next_u64();
            let a = Matrix::random(m, k, seed);
            let b = Matrix::random(k, n, seed + 1);
            let lhs = a.matmul(&b).transpose();
            let rhs = b.transpose().matmul(&a.transpose());
            assert!(lhs.allclose(&rhs, 1e-4));
        });
    }

    #[test]
    fn prop_pad_does_not_change_product() {
        check::cases(48, |rng| {
            let (m, k, n) = (rng.range(1, 10), rng.range(1, 10), rng.range(1, 10));
            let seed = rng.next_u64();
            let a = Matrix::random(m, k, seed);
            let b = Matrix::random(k, n, seed + 1);
            let ap = a.pad_to(m + 3, k + 5);
            let bp = b.pad_to(k + 5, n + 2);
            let full = ap.matmul(&bp);
            assert!(full.block(0, 0, m, n).allclose(&a.matmul(&b), 1e-4));
        });
    }
}
