//! Element precision as a first-class job parameter.
//!
//! [`Dtype`] names the four storage precisions a job can request for its
//! packed panels. The host-side substrate ([`super::Matrix`]) stays `f32`
//! everywhere — dtype is applied **at pack time**: the packer converts each
//! element into the job's storage format, the per-dtype microkernels widen
//! half-precision elements back to `f32` on load and accumulate in `f32`
//! (natively in `f64` for [`Dtype::F64`]), and results stream back into the
//! `f32` `C` buffer exactly as before. `F32` jobs never touch a conversion:
//! they run the pre-existing pack functions and microkernel bit for bit.
//!
//! Stable Rust has no `f16`/`bf16` primitives, so the half types are stored
//! as IEEE bit patterns in `u16` and converted with the scalar kernels in
//! this module ([`f32_to_f16_bits`] & co. — round-to-nearest-even, with
//! subnormal, infinity, and NaN handling).

use std::fmt;
use std::str::FromStr;

/// Storage precision for a job's packed panels.
///
/// The default is [`Dtype::F32`], which reproduces the pre-multi-precision
/// behavior bit for bit.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Default)]
pub enum Dtype {
    /// IEEE double; packs widen `f32` inputs exactly, accumulates in `f64`.
    F64,
    /// IEEE single — the legacy path, byte- and bit-identical to before.
    #[default]
    F32,
    /// IEEE half (1-5-10); widen-on-load, accumulate in `f32`.
    F16,
    /// bfloat16 (1-8-7); widen-on-load, accumulate in `f32`.
    Bf16,
}

impl Dtype {
    /// Every dtype, in [`Dtype::index`] order (`F32` first so that index 0
    /// — and the dtype bits of trace payloads — stay zero for f32 traffic).
    pub const ALL: [Dtype; 4] = [Dtype::F32, Dtype::F64, Dtype::F16, Dtype::Bf16];

    /// Storage bytes per element (8 / 4 / 2 / 2).
    pub fn bytes(self) -> usize {
        match self {
            Dtype::F64 => 8,
            Dtype::F32 => 4,
            Dtype::F16 | Dtype::Bf16 => 2,
        }
    }

    /// Lower-case label used in CLI flags, bench annotations, and stats.
    pub fn label(self) -> &'static str {
        match self {
            Dtype::F64 => "f64",
            Dtype::F32 => "f32",
            Dtype::F16 => "f16",
            Dtype::Bf16 => "bf16",
        }
    }

    /// Dense index for per-dtype metric arrays and trace payloads.
    ///
    /// `F32` is index 0 so that encoding a dtype into previously-zero
    /// payload bits leaves every f32-only trace bitwise unchanged.
    pub fn index(self) -> usize {
        match self {
            Dtype::F32 => 0,
            Dtype::F64 => 1,
            Dtype::F16 => 2,
            Dtype::Bf16 => 3,
        }
    }

    /// Inverse of [`Dtype::index`].
    pub fn from_index(i: usize) -> Option<Dtype> {
        Dtype::ALL.get(i).copied()
    }

    /// Unit roundoff of the *storage* format — the worst-case relative
    /// error introduced by rounding one operand element into this dtype
    /// (`2^-(p)` for `p` stored significand bits plus the implicit one).
    /// Accumulation is always f32 or wider, so per-element storage error
    /// dominates the end-to-end GEMM error; DSE compares this against a
    /// caller-supplied accuracy floor.
    pub fn unit_roundoff(self) -> f64 {
        match self {
            Dtype::F64 => 1.1102230246251565e-16, // 2^-53
            Dtype::F32 => 5.960464477539063e-8,   // 2^-24
            Dtype::F16 => 4.8828125e-4,           // 2^-11
            Dtype::Bf16 => 3.90625e-3,            // 2^-8
        }
    }

    /// True for the two 16-bit formats.
    pub fn is_half(self) -> bool {
        matches!(self, Dtype::F16 | Dtype::Bf16)
    }

    /// `u16` bit-pattern encoder for the half formats (`None` otherwise).
    pub fn half_encoder(self) -> Option<fn(f32) -> u16> {
        match self {
            Dtype::F16 => Some(f32_to_f16_bits),
            Dtype::Bf16 => Some(f32_to_bf16_bits),
            _ => None,
        }
    }

    /// `u16` bit-pattern decoder for the half formats (`None` otherwise).
    pub fn half_decoder(self) -> Option<fn(u16) -> f32> {
        match self {
            Dtype::F16 => Some(f16_bits_to_f32),
            Dtype::Bf16 => Some(bf16_bits_to_f32),
            _ => None,
        }
    }

    /// Round-trip one element through this dtype's storage format: the
    /// value a packed panel actually holds for input `x`.
    pub fn quantize(self, x: f32) -> f32 {
        match self {
            Dtype::F64 | Dtype::F32 => x,
            Dtype::F16 => f16_bits_to_f32(f32_to_f16_bits(x)),
            Dtype::Bf16 => bf16_bits_to_f32(f32_to_bf16_bits(x)),
        }
    }
}

impl fmt::Display for Dtype {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.label())
    }
}

impl FromStr for Dtype {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "f64" => Ok(Dtype::F64),
            "f32" => Ok(Dtype::F32),
            "f16" => Ok(Dtype::F16),
            "bf16" => Ok(Dtype::Bf16),
            other => Err(format!(
                "unknown dtype {other:?} (expected f64, f32, f16, or bf16)"
            )),
        }
    }
}

/// Convert `f32` to IEEE half (1-5-10) bits, round-to-nearest-even.
///
/// Overflow saturates to infinity, values below the smallest half
/// subnormal round to signed zero, and NaN stays NaN (quiet bit forced so
/// a truncated payload can never turn into infinity).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp32 = ((bits >> 23) & 0xff) as i32;
    let man32 = bits & 0x007f_ffff;
    if exp32 == 0xff {
        let payload = if man32 == 0 {
            0 // infinity
        } else {
            0x0200 | ((man32 >> 13) as u16 & 0x03ff)
        };
        return sign | 0x7c00 | payload;
    }
    let exp_h = exp32 - 127 + 15;
    if exp_h >= 0x1f {
        return sign | 0x7c00; // overflow -> infinity
    }
    if exp_h <= 0 {
        // Subnormal target: shift the implicit-1 mantissa into place.
        // Below exp_h = -10 even the halfway point rounds to zero.
        if exp_h < -10 {
            return sign;
        }
        let m = man32 | 0x0080_0000;
        let shift = (14 - exp_h) as u32; // 14..=24
        let half = 1u32 << (shift - 1);
        let rem = m & ((1u32 << shift) - 1);
        let mut man_h = m >> shift;
        if rem > half || (rem == half && man_h & 1 == 1) {
            man_h += 1; // may carry into the smallest normal — still correct
        }
        return sign | man_h as u16;
    }
    // Normal target: round 23 mantissa bits to 10, RNE; a mantissa that
    // rounds up to 2.0 carries into the exponent (possibly to infinity).
    let round = 0x0fff + ((man32 >> 13) & 1);
    let h = ((exp_h as u32) << 10) + ((man32 + round) >> 13);
    sign | h as u16
}

/// Convert IEEE half (1-5-10) bits to `f32`. Exact for every input.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h as u32) & 0x8000) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x03ff) as u32;
    let bits = match (exp, man) {
        (0, 0) => sign,
        (0, _) => {
            // Subnormal: renormalize into f32's normal range.
            let mut e = -14i32;
            let mut m = man;
            while m & 0x0400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x03ff;
            sign | (((e + 127) as u32) << 23) | (m << 13)
        }
        (0x1f, 0) => sign | 0x7f80_0000,
        (0x1f, _) => sign | 0x7f80_0000 | (man << 13), // NaN, payload kept
        _ => sign | ((exp + 112) << 23) | (man << 13),
    };
    f32::from_bits(bits)
}

/// Convert `f32` to bfloat16 (1-8-7) bits, round-to-nearest-even.
///
/// bf16 shares f32's exponent range, so there is no overflow/underflow
/// special-casing beyond the rounding itself; NaN keeps its quiet bit.
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // Force the quiet bit so truncating the payload can't yield Inf.
        return ((bits >> 16) as u16) | 0x0040;
    }
    let round = ((bits >> 16) & 1) + 0x7fff;
    ((bits + round) >> 16) as u16
}

/// Convert bfloat16 (1-8-7) bits to `f32`. Exact for every input.
pub fn bf16_bits_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Scalar oracle: round an `f32` to `p` significand bits (RNE) via
    /// `f64` arithmetic, without reimplementing the bit tricks under test.
    fn round_to_precision(x: f32, p: i32, min_exp: i32) -> f64 {
        let v = x as f64;
        if v == 0.0 || !v.is_finite() {
            return v;
        }
        let e = v.abs().log2().floor() as i32;
        let e = e.max(min_exp); // subnormals round on a fixed grid
        let ulp = (e - (p - 1)).clamp(-1074, 1023);
        let scale = (ulp as f64).exp2();
        (v / scale).round_ties_even() * scale
    }

    #[test]
    fn f16_matches_scalar_oracle_on_sweep() {
        // Magnitudes from deep subnormal to overflow, both signs.
        let mut xs = vec![0.0f32, -0.0];
        for e in -30..=18 {
            for m in [1.0f32, 1.25, 1.5, 1.9990234375] {
                let v = m * (e as f32).exp2();
                xs.push(v);
                xs.push(-v);
            }
        }
        for &x in &xs {
            let rt = f16_bits_to_f32(f32_to_f16_bits(x));
            let oracle = round_to_precision(x, 11, -14);
            if oracle.abs() > 65504.0 {
                assert!(rt.is_infinite() && (rt > 0.0) == (x > 0.0), "x={x}");
            } else if oracle.abs() < (-149f64).exp2() {
                assert_eq!(rt, 0.0, "x={x} rt={rt}");
            } else {
                assert_eq!(rt as f64, oracle, "x={x}");
            }
        }
    }

    #[test]
    fn bf16_matches_scalar_oracle_on_sweep() {
        let mut xs = vec![0.0f32, -0.0];
        for e in -40..=38 {
            for m in [1.0f32, 1.2421875, 1.5, 1.984375] {
                let v = m * (e as f32).exp2();
                xs.push(v);
                xs.push(-v);
            }
        }
        for &x in &xs {
            let rt = bf16_bits_to_f32(f32_to_bf16_bits(x));
            let oracle = round_to_precision(x, 8, -126);
            assert_eq!(rt as f64, oracle, "x={x}");
        }
    }

    #[test]
    fn f16_special_values() {
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        assert_eq!(f32_to_f16_bits(1.0), 0x3c00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xc000);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7bff); // f16::MAX
        assert_eq!(f32_to_f16_bits(65536.0), 0x7c00); // overflow -> inf
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7c00);
        assert_eq!(f32_to_f16_bits(f32::NEG_INFINITY), 0xfc00);
        let nan = f32_to_f16_bits(f32::NAN);
        assert_eq!(nan & 0x7c00, 0x7c00);
        assert_ne!(nan & 0x03ff, 0);
        // Smallest f16 subnormal and the value just under half of it.
        assert_eq!(f16_bits_to_f32(0x0001), (-24f32).exp2());
        assert_eq!(f32_to_f16_bits((-24f32).exp2()), 0x0001);
        assert_eq!(f32_to_f16_bits((-26f32).exp2()), 0x0000);
        // Exact tie at half the smallest subnormal rounds to even (zero).
        assert_eq!(f32_to_f16_bits((-25f32).exp2()), 0x0000);
    }

    #[test]
    fn f16_rne_ties() {
        // 1 + 2^-11 is exactly between 1.0 and the next f16 (1 + 2^-10):
        // ties to even -> 1.0. One ulp32 above the tie rounds up.
        let tie = f32::from_bits(0x3f80_1000);
        assert_eq!(f32_to_f16_bits(tie), 0x3c00);
        let above = f32::from_bits(0x3f80_1001);
        assert_eq!(f32_to_f16_bits(above), 0x3c01);
        // 1 + 3*2^-11 ties between 1+2^-10 and 1+2^-9: to even -> 1+2^-9.
        let tie_odd = f32::from_bits(0x3f80_3000);
        assert_eq!(f32_to_f16_bits(tie_odd), 0x3c02);
    }

    #[test]
    fn bf16_special_values() {
        assert_eq!(f32_to_bf16_bits(0.0), 0x0000);
        assert_eq!(f32_to_bf16_bits(-0.0), 0x8000);
        assert_eq!(f32_to_bf16_bits(1.0), 0x3f80);
        assert_eq!(bf16_bits_to_f32(0x3f80), 1.0);
        assert_eq!(f32_to_bf16_bits(f32::INFINITY), 0x7f80);
        let nan = f32_to_bf16_bits(f32::NAN);
        assert_eq!(nan & 0x7f80, 0x7f80);
        assert_ne!(nan & 0x007f, 0);
        // RNE tie: 1 + 2^-8 is between 1.0 and 1 + 2^-7 -> even (1.0).
        assert_eq!(f32_to_bf16_bits(1.0 + (-8f32).exp2()), 0x3f80);
        assert_eq!(f32_to_bf16_bits(1.0 + 3.0 * (-8f32).exp2()), 0x3f82);
        // Rounding can push f32::MAX over the top: correct RNE -> inf.
        assert_eq!(f32_to_bf16_bits(f32::MAX), 0x7f80);
    }

    #[test]
    fn grid_values_round_trip_exactly_in_both_half_formats() {
        // k/256 for k in [-256, 256) is exactly representable in f16
        // (11-bit significand) and bf16 (8-bit significand): |k| <= 256
        // needs at most 8 significant bits after normalization.
        for k in -256i32..256 {
            let x = k as f32 / 256.0;
            assert_eq!(f16_bits_to_f32(f32_to_f16_bits(x)), x, "f16 k={k}");
            assert_eq!(bf16_bits_to_f32(f32_to_bf16_bits(x)), x, "bf16 k={k}");
        }
    }

    #[test]
    fn dtype_surface() {
        assert_eq!(Dtype::default(), Dtype::F32);
        for d in Dtype::ALL {
            assert_eq!(Dtype::from_index(d.index()), Some(d));
            assert_eq!(d.label().parse::<Dtype>(), Ok(d));
            assert_eq!(format!("{d}"), d.label());
        }
        assert_eq!(Dtype::F32.index(), 0); // trace payloads rely on this
        assert_eq!(Dtype::F64.bytes(), 8);
        assert_eq!(Dtype::F16.bytes(), 2);
        assert!(Dtype::Bf16.unit_roundoff() > Dtype::F16.unit_roundoff());
        assert!("f8".parse::<Dtype>().is_err());
        assert_eq!(Dtype::F32.quantize(0.1), 0.1);
        assert!((Dtype::Bf16.quantize(0.1) - 0.1).abs() < 1e-3);
    }
}
