//! Borrowed matrix views — the zero-copy substrate of the panel
//! pipeline.
//!
//! [`MatrixView`] / [`MatrixViewMut`] are `(rows, cols, row_stride)`
//! windows over borrowed FP32 storage: the panel packer reads operand
//! sub-blocks through them without materializing per-task copies, and
//! row-band splits of a mutable view are how C is partitioned across
//! workers. [`DisjointBlocks`] is the writer the coordinator hands its
//! workers: a `Sync` handle over C's storage whose block writes are data-
//! race-free because the blocks of one [`crate::blocking::BlockPlan`]
//! tile C exactly (see `prop_tasks_tile_c_exactly`) and the WQM hands
//! every task to exactly one worker (see the conservation proptests) —
//! disjointness by construction, no `Mutex<Matrix>` on the hot path.

use super::Matrix;

/// Immutable window over row-major FP32 storage.
#[derive(Debug, Clone, Copy)]
pub struct MatrixView<'a> {
    rows: usize,
    cols: usize,
    row_stride: usize,
    data: &'a [f32],
}

impl<'a> MatrixView<'a> {
    /// View over `data` with explicit geometry. `data` must hold the
    /// last element of the last row.
    pub fn new(data: &'a [f32], rows: usize, cols: usize, row_stride: usize) -> Self {
        assert!(row_stride >= cols, "row stride shorter than a row");
        if rows > 0 && cols > 0 {
            assert!(
                data.len() >= (rows - 1) * row_stride + cols,
                "view geometry exceeds storage"
            );
        }
        Self { rows, cols, row_stride, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        assert!(r < self.rows && c < self.cols, "view index out of bounds");
        self.data[r * self.row_stride + c]
    }

    /// Row `r` as a contiguous slice (borrows the underlying storage).
    #[inline]
    pub fn row(&self, r: usize) -> &'a [f32] {
        assert!(r < self.rows, "row out of bounds");
        &self.data[r * self.row_stride..r * self.row_stride + self.cols]
    }

    /// Sub-view of the `rows x cols` block at `(row0, col0)`, clipped to
    /// the parent bounds — the borrowed twin of [`Matrix::block`].
    pub fn block(&self, row0: usize, col0: usize, rows: usize, cols: usize) -> MatrixView<'a> {
        let r1 = (row0 + rows).min(self.rows);
        let c1 = (col0 + cols).min(self.cols);
        assert!(row0 <= r1 && col0 <= c1, "block origin out of bounds");
        let (nrows, ncols) = (r1 - row0, c1 - col0);
        if nrows == 0 || ncols == 0 {
            return MatrixView { rows: 0, cols: 0, row_stride: self.row_stride, data: &[] };
        }
        let start = row0 * self.row_stride + col0;
        let end = start + (nrows - 1) * self.row_stride + ncols;
        MatrixView {
            rows: nrows,
            cols: ncols,
            row_stride: self.row_stride,
            data: &self.data[start..end],
        }
    }

    /// Copy this view into an owned [`Matrix`] (test/diagnostic helper;
    /// the hot path never calls it).
    pub fn to_matrix(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            out.data[r * self.cols..(r + 1) * self.cols].copy_from_slice(self.row(r));
        }
        out
    }
}

/// Mutable window over row-major FP32 storage.
#[derive(Debug)]
pub struct MatrixViewMut<'a> {
    rows: usize,
    cols: usize,
    row_stride: usize,
    data: &'a mut [f32],
}

impl<'a> MatrixViewMut<'a> {
    pub fn new(data: &'a mut [f32], rows: usize, cols: usize, row_stride: usize) -> Self {
        assert!(row_stride >= cols, "row stride shorter than a row");
        if rows > 0 && cols > 0 {
            assert!(
                data.len() >= (rows - 1) * row_stride + cols,
                "view geometry exceeds storage"
            );
        }
        Self { rows, cols, row_stride, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Row `r` as a mutable contiguous slice.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        assert!(r < self.rows, "row out of bounds");
        &mut self.data[r * self.row_stride..r * self.row_stride + self.cols]
    }

    /// Reborrow immutably.
    pub fn as_view(&self) -> MatrixView<'_> {
        MatrixView::new(self.data, self.rows, self.cols, self.row_stride)
    }

    /// Exclusive sub-view of the `rows x cols` block at `(row0, col0)` —
    /// the mutable twin of [`MatrixView::block`], used by the Strassen
    /// combine step to write one quadrant of C at a time. Strict bounds
    /// (no clipping): writers must know exactly what they target.
    pub fn block_mut(
        &mut self,
        row0: usize,
        col0: usize,
        rows: usize,
        cols: usize,
    ) -> MatrixViewMut<'_> {
        assert!(row0 + rows <= self.rows && col0 + cols <= self.cols, "block out of bounds");
        if rows == 0 || cols == 0 {
            return MatrixViewMut { rows: 0, cols: 0, row_stride: self.row_stride, data: &mut [] };
        }
        let start = row0 * self.row_stride + col0;
        let end = start + (rows - 1) * self.row_stride + cols;
        MatrixViewMut {
            rows,
            cols,
            row_stride: self.row_stride,
            data: &mut self.data[start..end],
        }
    }

    /// Split into two disjoint row bands `[0, r)` and `[r, rows)` — the
    /// safe primitive behind partitioning C across owners.
    pub fn split_at_row(self, r: usize) -> (MatrixViewMut<'a>, MatrixViewMut<'a>) {
        assert!(r <= self.rows, "split row out of bounds");
        let (top, bottom) = self.data.split_at_mut(r * self.row_stride);
        (
            MatrixViewMut { rows: r, cols: self.cols, row_stride: self.row_stride, data: top },
            MatrixViewMut {
                rows: self.rows - r,
                cols: self.cols,
                row_stride: self.row_stride,
                data: bottom,
            },
        )
    }
}

/// Shared writer over a dense output matrix whose writes target
/// *disjoint* blocks.
///
/// This is the partitioned-C half of the lock-free coordinator: every
/// worker holds `&DisjointBlocks` and streams its finished `C_ij` blocks
/// straight into place. Soundness rests on the invariant named in the
/// constructor docs and discharged by the callers: concurrent
/// [`DisjointBlocks::write_block`] calls never overlap because (a) a
/// [`crate::blocking::BlockPlan`]'s tasks tile C exactly — every element
/// belongs to exactly one `(bi, bj)` block — and (b) the WQM pops each
/// task exactly once, so exactly one worker writes each block.
pub struct DisjointBlocks<'a> {
    ptr: *mut f32,
    rows: usize,
    cols: usize,
    _borrow: std::marker::PhantomData<&'a mut [f32]>,
}

// SAFETY: the writer only ever writes through `ptr`, and the contract of
// `write_block` (each block written by at most one thread) makes those
// writes disjoint; the PhantomData keeps the exclusive borrow of the
// underlying matrix alive for 'a, so no other safe code can observe the
// storage concurrently.
unsafe impl Send for DisjointBlocks<'_> {}
unsafe impl Sync for DisjointBlocks<'_> {}

impl<'a> DisjointBlocks<'a> {
    /// Wrap a dense (`row_stride == cols`) mutable view. The view's
    /// exclusive borrow is held for the writer's lifetime.
    pub fn new(view: MatrixViewMut<'a>) -> Self {
        assert_eq!(view.row_stride, view.cols, "writer needs a dense view");
        Self {
            ptr: view.data.as_mut_ptr(),
            rows: view.rows,
            cols: view.cols,
            _borrow: std::marker::PhantomData,
        }
    }

    /// Wrap raw dense row-major storage without a borrow — the
    /// `Arc`-owned twin of [`DisjointBlocks::new`] for writers whose
    /// output buffer lives in shared job state (the serving runtime)
    /// rather than on a caller's stack frame.
    ///
    /// # Safety
    ///
    /// `ptr` must point to at least `rows * cols` valid, writable `f32`s
    /// that stay allocated (and are not read or written by anyone else
    /// outside this writer's `write_block` contract) for as long as the
    /// returned writer is used. The usual disjointness contract of
    /// [`DisjointBlocks::write_block`] applies on top.
    pub unsafe fn from_raw(ptr: *mut f32, rows: usize, cols: usize) -> DisjointBlocks<'static> {
        DisjointBlocks { ptr, rows, cols, _borrow: std::marker::PhantomData }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Write a `rows x cols` tile (stored row-major at `src_stride`)
    /// at `(row0, col0)`.
    ///
    /// # Safety
    ///
    /// No two concurrent calls may target overlapping element ranges.
    /// The coordinator guarantees this by only writing the block of a
    /// [`crate::blocking::BlockTask`] it popped from the WQM: tasks tile
    /// C disjointly and each is popped once. Bounds are checked.
    pub unsafe fn write_block(
        &self,
        row0: usize,
        col0: usize,
        src: &[f32],
        src_stride: usize,
        rows: usize,
        cols: usize,
    ) {
        assert!(row0 + rows <= self.rows && col0 + cols <= self.cols, "block out of bounds");
        assert!(cols <= src_stride, "source stride shorter than a row");
        if rows == 0 || cols == 0 {
            return;
        }
        assert!(src.len() >= (rows - 1) * src_stride + cols, "source too short");
        for i in 0..rows {
            let dst = self.ptr.add((row0 + i) * self.cols + col0);
            std::ptr::copy_nonoverlapping(src.as_ptr().add(i * src_stride), dst, cols);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn view_matches_matrix() {
        let m = Matrix::random(7, 5, 1);
        let v = m.view();
        assert_eq!((v.rows(), v.cols()), (7, 5));
        for r in 0..7 {
            assert_eq!(v.row(r), m.row(r));
            for c in 0..5 {
                assert_eq!(v.get(r, c), m.get(r, c));
            }
        }
    }

    #[test]
    fn sub_view_equals_copied_block() {
        let m = Matrix::random(10, 8, 2);
        let v = m.view().block(3, 2, 4, 5);
        assert_eq!(v.to_matrix(), m.block(3, 2, 4, 5));
    }

    #[test]
    fn sub_view_clips_at_edges() {
        let m = Matrix::random(10, 10, 3);
        let v = m.view().block(8, 7, 4, 4);
        assert_eq!((v.rows(), v.cols()), (2, 3));
        assert_eq!(v.to_matrix(), m.block(8, 7, 4, 4));
    }

    #[test]
    fn nested_sub_views_compose() {
        let m = Matrix::random(12, 12, 4);
        let outer = m.view().block(2, 2, 8, 8);
        let inner = outer.block(1, 3, 4, 4);
        assert_eq!(inner.to_matrix(), m.block(3, 5, 4, 4));
    }

    #[test]
    fn mut_view_writes_through() {
        let mut m = Matrix::zeros(4, 4);
        {
            let mut v = m.view_mut();
            v.row_mut(2)[1] = 7.0;
        }
        assert_eq!(m.get(2, 1), 7.0);
    }

    #[test]
    fn block_mut_writes_only_its_window() {
        let mut m = Matrix::zeros(6, 5);
        {
            let mut v = m.view_mut();
            let mut q = v.block_mut(2, 1, 3, 2);
            assert_eq!((q.rows(), q.cols()), (3, 2));
            for r in 0..3 {
                q.row_mut(r).fill(1.0);
            }
        }
        let ones: f32 = m.data.iter().sum();
        assert_eq!(ones, 6.0);
        for r in 2..5 {
            for c in 1..3 {
                assert_eq!(m.get(r, c), 1.0);
            }
        }
        assert_eq!(m.get(1, 1), 0.0);
        assert_eq!(m.get(2, 0), 0.0);
        assert_eq!(m.get(5, 1), 0.0);
    }

    #[test]
    #[should_panic(expected = "block out of bounds")]
    fn block_mut_bounds_checked() {
        let mut m = Matrix::zeros(4, 4);
        let mut v = m.view_mut();
        v.block_mut(2, 2, 3, 3);
    }

    #[test]
    fn split_at_row_is_disjoint_and_complete() {
        let mut m = Matrix::zeros(6, 3);
        {
            let v = m.view_mut();
            let (mut top, mut bottom) = v.split_at_row(2);
            assert_eq!((top.rows(), bottom.rows()), (2, 4));
            top.row_mut(1)[0] = 1.0;
            bottom.row_mut(0)[2] = 2.0;
        }
        assert_eq!(m.get(1, 0), 1.0);
        assert_eq!(m.get(2, 2), 2.0);
    }

    #[test]
    fn disjoint_writer_places_blocks() {
        let mut m = Matrix::zeros(6, 6);
        {
            let w = DisjointBlocks::new(m.view_mut());
            let tile = [1.0f32, 2.0, 3.0, 4.0];
            // SAFETY: single-threaded, disjoint targets.
            unsafe {
                w.write_block(0, 0, &tile, 2, 2, 2);
                w.write_block(4, 4, &tile, 2, 2, 2);
            }
        }
        assert_eq!(m.get(0, 0), 1.0);
        assert_eq!(m.get(1, 1), 4.0);
        assert_eq!(m.get(4, 5), 2.0);
        assert_eq!(m.get(5, 4), 3.0);
        assert_eq!(m.get(3, 3), 0.0);
    }

    #[test]
    fn writer_respects_source_stride() {
        let mut m = Matrix::zeros(2, 4);
        {
            let w = DisjointBlocks::new(m.view_mut());
            // 2x2 tile embedded in a stride-3 scratch buffer.
            let scratch = [1.0f32, 2.0, 9.0, 3.0, 4.0, 9.0];
            unsafe { w.write_block(0, 1, &scratch, 3, 2, 2) };
        }
        assert_eq!(m.data, vec![0.0, 1.0, 2.0, 0.0, 0.0, 3.0, 4.0, 0.0]);
    }

    #[test]
    fn raw_writer_matches_borrowed_writer() {
        let mut m = Matrix::zeros(4, 4);
        {
            // SAFETY: the Vec outlives the writer; single-threaded use.
            let w = unsafe {
                DisjointBlocks::from_raw(m.data.as_mut_ptr(), m.rows, m.cols)
            };
            let tile = [5.0f32, 6.0, 7.0, 8.0];
            unsafe { w.write_block(1, 1, &tile, 2, 2, 2) };
        }
        assert_eq!(m.get(1, 1), 5.0);
        assert_eq!(m.get(2, 2), 8.0);
        assert_eq!(m.get(0, 0), 0.0);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn writer_bounds_checked() {
        let mut m = Matrix::zeros(4, 4);
        let w = DisjointBlocks::new(m.view_mut());
        let tile = [0.0f32; 16];
        unsafe { w.write_block(2, 2, &tile, 4, 4, 4) };
    }
}
