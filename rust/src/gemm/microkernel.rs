//! Register-blocked inner kernel of the packed panel pipeline.
//!
//! One call computes a full-K `MR x NR` tile of C with the accumulator
//! held in locals (LLVM keeps the 4x8 tile in registers and
//! autovectorizes the NR-wide update), reading A through an MR-strip and
//! B through an NR-strip of [`super::PackedPanels`]. Compared to the
//! scalar k-i-j loop in [`super::block_task`] this retires MR*NR FMAs
//! per (MR + NR)-element load instead of one FMA per load+store of C —
//! the register reuse a PE's `R_a`/`M_c` pair provides in hardware.
//!
//! Accumulation order over k is identical to [`super::block_task`] and
//! the PE array (ascending k, one rank-1 update per step), so results
//! agree with the oracle to the usual FP32 reassociation noise only from
//! padding zeros, which contribute exact `+0.0` terms.

use crate::blocking::BlockTask;

use super::pack::PackedPanels;
use super::view::DisjointBlocks;
use super::Matrix;

/// Rows of C per register tile (A-strip width).
pub const MR: usize = 4;
/// Columns of C per register tile (B-strip width).
pub const NR: usize = 8;

/// Multiply one packed A strip (`k * MR`, k-major) by one packed B strip
/// (`k * NR`, k-major), returning the `MR x NR` tile row-major. The tile
/// lives entirely in locals: no loads or stores of C inside the k loop.
#[inline]
pub fn micro_kernel(ap: &[f32], bp: &[f32], k: usize) -> [f32; MR * NR] {
    debug_assert!(ap.len() >= k * MR && bp.len() >= k * NR);
    let mut acc = [0.0f32; MR * NR];
    for (a_col, b_row) in ap.chunks_exact(MR).zip(bp.chunks_exact(NR)).take(k) {
        for (acc_row, &a) in acc.chunks_exact_mut(NR).zip(a_col) {
            for (c, &b) in acc_row.iter_mut().zip(b_row) {
                *c += a * b;
            }
        }
    }
    acc
}

/// Compute one sub-block task `C_ij = SA_i x SB_j` from pre-packed
/// panels, streaming the register tiles straight into the shared output
/// writer. Allocation-free: the only scratch is the `MR x NR` stack
/// tile.
///
/// # Safety
///
/// Inherits [`DisjointBlocks::write_block`]'s contract: `task`'s block
/// must not be written concurrently by anyone else. The coordinator
/// guarantees this because each task is popped from the WQM exactly once
/// and tasks tile C disjointly.
pub unsafe fn task_product_into(
    panels: &PackedPanels,
    task: &BlockTask,
    out: &DisjointBlocks<'_>,
) {
    write_task(panels, task, out, task.row0, task.col0);
}

/// Shared body of [`task_product_into`] (global C coordinates) and
/// [`task_product`] (block-local coordinates).
///
/// # Safety
///
/// Same contract as [`task_product_into`].
unsafe fn write_task(
    panels: &PackedPanels,
    task: &BlockTask,
    out: &DisjointBlocks<'_>,
    base_row: usize,
    base_col: usize,
) {
    let k = panels.k();
    let (ap, rows) = panels.a_panel(task.bi);
    let (bp, cols) = panels.b_panel(task.bj);
    assert_eq!(rows, task.rows, "panel/task row mismatch");
    assert_eq!(cols, task.cols, "panel/task col mismatch");
    let a_strips = rows.div_ceil(MR);
    let b_strips = cols.div_ceil(NR);
    for s in 0..a_strips {
        let ap_s = &ap[s * k * MR..(s + 1) * k * MR];
        let rows_here = MR.min(rows - s * MR);
        for t in 0..b_strips {
            let bp_t = &bp[t * k * NR..(t + 1) * k * NR];
            let cols_here = NR.min(cols - t * NR);
            let acc = micro_kernel(ap_s, bp_t, k);
            out.write_block(
                base_row + s * MR,
                base_col + t * NR,
                &acc,
                NR,
                rows_here,
                cols_here,
            );
        }
    }
}

/// Owned-result variant of [`task_product_into`]: compute one task's
/// `rows x cols` block into a fresh [`Matrix`]. Used by tests and by
/// callers that want a block without a shared writer.
pub fn task_product(panels: &PackedPanels, task: &BlockTask) -> Matrix {
    let mut c = Matrix::zeros(task.rows, task.cols);
    {
        let w = DisjointBlocks::new(c.view_mut());
        // SAFETY: `w` wraps an exclusive borrow of the local `c`, and
        // this is the only writer — no concurrent access is possible.
        unsafe { write_task(panels, task, &w, 0, 0) };
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocking::BlockPlan;
    use crate::util::check;

    fn packed(a: &Matrix, b: &Matrix, si: usize, sj: usize) -> (BlockPlan, PackedPanels) {
        let plan = BlockPlan::new(a.rows, a.cols, b.cols, si, sj);
        let panels = PackedPanels::pack(a.view(), b.view(), &plan);
        (plan, panels)
    }

    #[test]
    fn single_tile_matches_oracle() {
        let a = Matrix::random(MR, 17, 1);
        let b = Matrix::random(17, NR, 2);
        let (plan, panels) = packed(&a, &b, MR, NR);
        let got = task_product(&panels, &plan.task(0));
        assert!(got.allclose(&a.matmul(&b), 1e-5));
    }

    #[test]
    fn whole_block_matches_block_task() {
        let a = Matrix::random(32, 24, 3);
        let b = Matrix::random(24, 40, 4);
        let (plan, panels) = packed(&a, &b, 16, 16);
        for task in plan.tasks() {
            let got = task_product(&panels, &task);
            let want = crate::gemm::block_task(&a, &b, task.row0, task.col0, task.si, task.sj);
            assert!(got.allclose(&want, 1e-5), "task {}", task.id);
        }
    }

    #[test]
    fn ragged_edge_blocks_match() {
        // Shapes chosen so every edge case fires: rows % MR != 0,
        // cols % NR != 0, blocks clip at both matrix edges.
        let a = Matrix::random(37, 19, 5);
        let b = Matrix::random(19, 29, 6);
        let (plan, panels) = packed(&a, &b, 16, 12);
        for task in plan.tasks() {
            let got = task_product(&panels, &task);
            assert_eq!((got.rows, got.cols), (task.rows, task.cols));
            let want = crate::gemm::block_task(&a, &b, task.row0, task.col0, task.si, task.sj);
            assert!(got.allclose(&want, 1e-5), "task {}", task.id);
        }
    }

    #[test]
    fn prop_packed_task_equals_oracle() {
        check::cases(64, |rng| {
            let (m, k, n) = (rng.range(1, 40), rng.range(1, 40), rng.range(1, 40));
            let (si, sj) = (rng.range(1, 20), rng.range(1, 20));
            let seed = rng.next_u64();
            let a = Matrix::random(m, k, seed);
            let b = Matrix::random(k, n, seed + 1);
            let (plan, panels) = packed(&a, &b, si, sj);
            let oracle = a.matmul(&b);
            for task in plan.tasks() {
                let got = task_product(&panels, &task);
                let want = oracle.block(task.row0, task.col0, task.rows, task.cols);
                assert!(got.allclose(&want, 1e-3), "task {}", task.id);
            }
        });
    }

    #[test]
    fn micro_kernel_is_rank1_accumulation() {
        // k = 1: acc[i][j] = a[i] * b[j] exactly.
        let ap: Vec<f32> = (0..MR).map(|i| i as f32 + 1.0).collect();
        let bp: Vec<f32> = (0..NR).map(|j| j as f32 + 1.0).collect();
        let acc = micro_kernel(&ap, &bp, 1);
        for i in 0..MR {
            for j in 0..NR {
                assert_eq!(acc[i * NR + j], (i as f32 + 1.0) * (j as f32 + 1.0));
            }
        }
    }
}
