//! Register-blocked inner kernel of the packed panel pipeline.
//!
//! One call computes a full-K `MR x NR` tile of C with the accumulator
//! held in locals (LLVM keeps the 4x8 tile in registers and
//! autovectorizes the NR-wide update), reading A through an MR-strip and
//! B through an NR-strip of [`super::PackedPanels`]. Compared to the
//! scalar k-i-j loop in [`super::block_task`] this retires MR*NR FMAs
//! per (MR + NR)-element load instead of one FMA per load+store of C —
//! the register reuse a PE's `R_a`/`M_c` pair provides in hardware.
//!
//! Accumulation order over k is identical to [`super::block_task`] and
//! the PE array (ascending k, one rank-1 update per step), so results
//! agree with the oracle to the usual FP32 reassociation noise only from
//! padding zeros, which contribute exact `+0.0` terms.
//!
//! Multi-precision: the kernel has one variant per storage class.
//! [`micro_kernel`] is the legacy f32 path, untouched;
//! [`micro_kernel_f64`] accumulates natively in f64 and narrows the
//! finished tile once on write-out; [`micro_kernel_half`] widens each
//! f16/bf16 element to f32 on load and accumulates in f32 (the
//! accumulate-in-f32 scheme gemm_hls uses for half precision). All
//! variants stream into the same f32 `C` writer, so downstream stays
//! dtype-blind.

use crate::blocking::BlockTask;

use super::pack::{PackedPanels, PanelRef};
use super::view::DisjointBlocks;
use super::Matrix;

/// Rows of C per register tile (A-strip width).
pub const MR: usize = 4;
/// Columns of C per register tile (B-strip width).
pub const NR: usize = 8;

/// Multiply one packed A strip (`k * MR`, k-major) by one packed B strip
/// (`k * NR`, k-major), returning the `MR x NR` tile row-major. The tile
/// lives entirely in locals: no loads or stores of C inside the k loop.
#[inline]
pub fn micro_kernel(ap: &[f32], bp: &[f32], k: usize) -> [f32; MR * NR] {
    debug_assert!(ap.len() >= k * MR && bp.len() >= k * NR);
    let mut acc = [0.0f32; MR * NR];
    for (a_col, b_row) in ap.chunks_exact(MR).zip(bp.chunks_exact(NR)).take(k) {
        for (acc_row, &a) in acc.chunks_exact_mut(NR).zip(a_col) {
            for (c, &b) in acc_row.iter_mut().zip(b_row) {
                *c += a * b;
            }
        }
    }
    acc
}

/// [`micro_kernel`] over f64 strips: same dataflow, native f64
/// accumulation. The caller narrows the finished tile to f32 once, so a
/// full-K dot product suffers exactly one f32 rounding instead of one
/// per step.
#[inline]
pub fn micro_kernel_f64(ap: &[f64], bp: &[f64], k: usize) -> [f64; MR * NR] {
    debug_assert!(ap.len() >= k * MR && bp.len() >= k * NR);
    let mut acc = [0.0f64; MR * NR];
    for (a_col, b_row) in ap.chunks_exact(MR).zip(bp.chunks_exact(NR)).take(k) {
        for (acc_row, &a) in acc.chunks_exact_mut(NR).zip(a_col) {
            for (c, &b) in acc_row.iter_mut().zip(b_row) {
                *c += a * b;
            }
        }
    }
    acc
}

/// [`micro_kernel`] over f16/bf16 bit-pattern strips: each element is
/// widened to f32 through `decode` on load and the tile accumulates in
/// f32 — precision is lost only where the *storage* rounded, never in
/// the accumulation dataflow, which stays bit-compatible with the f32
/// kernel fed pre-quantized inputs.
#[inline]
pub fn micro_kernel_half(ap: &[u16], bp: &[u16], k: usize, decode: fn(u16) -> f32) -> [f32; MR * NR] {
    debug_assert!(ap.len() >= k * MR && bp.len() >= k * NR);
    let mut acc = [0.0f32; MR * NR];
    for (a_col, b_row) in ap.chunks_exact(MR).zip(bp.chunks_exact(NR)).take(k) {
        // Widen the NR-wide B row once per k step, not once per FMA.
        let mut brow = [0.0f32; NR];
        for (o, &b) in brow.iter_mut().zip(b_row) {
            *o = decode(b);
        }
        for (acc_row, &a) in acc.chunks_exact_mut(NR).zip(a_col) {
            let a = decode(a);
            for (c, &b) in acc_row.iter_mut().zip(&brow) {
                *c += a * b;
            }
        }
    }
    acc
}

/// Compute one sub-block task `C_ij = SA_i x SB_j` from pre-packed
/// panels, streaming the register tiles straight into the shared output
/// writer. Allocation-free: the only scratch is the `MR x NR` stack
/// tile.
///
/// # Safety
///
/// Inherits [`DisjointBlocks::write_block`]'s contract: `task`'s block
/// must not be written concurrently by anyone else. The coordinator
/// guarantees this because each task is popped from the WQM exactly once
/// and tasks tile C disjointly.
pub unsafe fn task_product_into(
    panels: &PackedPanels,
    task: &BlockTask,
    out: &DisjointBlocks<'_>,
) {
    write_task(panels, task, out, task.row0, task.col0);
}

/// Shared body of [`task_product_into`] (global C coordinates) and
/// [`task_product`] (block-local coordinates).
///
/// # Safety
///
/// Same contract as [`task_product_into`].
unsafe fn write_task(
    panels: &PackedPanels,
    task: &BlockTask,
    out: &DisjointBlocks<'_>,
    base_row: usize,
    base_col: usize,
) {
    let k = panels.k();
    let (apr, rows) = panels.a_panel_ref(task.bi);
    let (bpr, cols) = panels.b_panel_ref(task.bj);
    assert_eq!(rows, task.rows, "panel/task row mismatch");
    assert_eq!(cols, task.cols, "panel/task col mismatch");
    let a_strips = rows.div_ceil(MR);
    let b_strips = cols.div_ceil(NR);
    for s in 0..a_strips {
        let rows_here = MR.min(rows - s * MR);
        for t in 0..b_strips {
            let cols_here = NR.min(cols - t * NR);
            // Dispatch on the panels' storage dtype; `from_parts`
            // guarantees both halves agree, so mixed arms are
            // unreachable. The F32 arm is the untouched legacy kernel.
            let acc: [f32; MR * NR] = match (apr, bpr) {
                (PanelRef::F32(ap), PanelRef::F32(bp)) => micro_kernel(
                    &ap[s * k * MR..(s + 1) * k * MR],
                    &bp[t * k * NR..(t + 1) * k * NR],
                    k,
                ),
                (PanelRef::F64(ap), PanelRef::F64(bp)) => {
                    let wide = micro_kernel_f64(
                        &ap[s * k * MR..(s + 1) * k * MR],
                        &bp[t * k * NR..(t + 1) * k * NR],
                        k,
                    );
                    let mut acc = [0.0f32; MR * NR];
                    for (o, v) in acc.iter_mut().zip(wide) {
                        *o = v as f32;
                    }
                    acc
                }
                (PanelRef::Half(ap), PanelRef::Half(bp)) => {
                    let decode = panels
                        .dtype()
                        .half_decoder()
                        .expect("half panels carry a half dtype");
                    micro_kernel_half(
                        &ap[s * k * MR..(s + 1) * k * MR],
                        &bp[t * k * NR..(t + 1) * k * NR],
                        k,
                        decode,
                    )
                }
                _ => unreachable!("packed halves disagree on dtype"),
            };
            out.write_block(
                base_row + s * MR,
                base_col + t * NR,
                &acc,
                NR,
                rows_here,
                cols_here,
            );
        }
    }
}

/// Owned-result variant of [`task_product_into`]: compute one task's
/// `rows x cols` block into a fresh [`Matrix`]. Used by tests and by
/// callers that want a block without a shared writer.
pub fn task_product(panels: &PackedPanels, task: &BlockTask) -> Matrix {
    let mut c = Matrix::zeros(task.rows, task.cols);
    {
        let w = DisjointBlocks::new(c.view_mut());
        // SAFETY: `w` wraps an exclusive borrow of the local `c`, and
        // this is the only writer — no concurrent access is possible.
        unsafe { write_task(panels, task, &w, 0, 0) };
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocking::BlockPlan;
    use crate::util::check;

    fn packed(a: &Matrix, b: &Matrix, si: usize, sj: usize) -> (BlockPlan, PackedPanels) {
        let plan = BlockPlan::new(a.rows, a.cols, b.cols, si, sj);
        let panels = PackedPanels::pack(a.view(), b.view(), &plan);
        (plan, panels)
    }

    #[test]
    fn single_tile_matches_oracle() {
        let a = Matrix::random(MR, 17, 1);
        let b = Matrix::random(17, NR, 2);
        let (plan, panels) = packed(&a, &b, MR, NR);
        let got = task_product(&panels, &plan.task(0));
        assert!(got.allclose(&a.matmul(&b), 1e-5));
    }

    #[test]
    fn whole_block_matches_block_task() {
        let a = Matrix::random(32, 24, 3);
        let b = Matrix::random(24, 40, 4);
        let (plan, panels) = packed(&a, &b, 16, 16);
        for task in plan.tasks() {
            let got = task_product(&panels, &task);
            let want = crate::gemm::block_task(&a, &b, task.row0, task.col0, task.si, task.sj);
            assert!(got.allclose(&want, 1e-5), "task {}", task.id);
        }
    }

    #[test]
    fn ragged_edge_blocks_match() {
        // Shapes chosen so every edge case fires: rows % MR != 0,
        // cols % NR != 0, blocks clip at both matrix edges.
        let a = Matrix::random(37, 19, 5);
        let b = Matrix::random(19, 29, 6);
        let (plan, panels) = packed(&a, &b, 16, 12);
        for task in plan.tasks() {
            let got = task_product(&panels, &task);
            assert_eq!((got.rows, got.cols), (task.rows, task.cols));
            let want = crate::gemm::block_task(&a, &b, task.row0, task.col0, task.si, task.sj);
            assert!(got.allclose(&want, 1e-5), "task {}", task.id);
        }
    }

    #[test]
    fn prop_packed_task_equals_oracle() {
        check::cases(64, |rng| {
            let (m, k, n) = (rng.range(1, 40), rng.range(1, 40), rng.range(1, 40));
            let (si, sj) = (rng.range(1, 20), rng.range(1, 20));
            let seed = rng.next_u64();
            let a = Matrix::random(m, k, seed);
            let b = Matrix::random(k, n, seed + 1);
            let (plan, panels) = packed(&a, &b, si, sj);
            let oracle = a.matmul(&b);
            for task in plan.tasks() {
                let got = task_product(&panels, &task);
                let want = oracle.block(task.row0, task.col0, task.rows, task.cols);
                assert!(got.allclose(&want, 1e-3), "task {}", task.id);
            }
        });
    }

    #[test]
    fn dtype_f32_task_product_is_bit_identical() {
        // The dtype-parameterized pack at F32 must reproduce the legacy
        // path bit for bit, task by task.
        let a = Matrix::random(37, 19, 21);
        let b = Matrix::random(19, 29, 22);
        let plan = BlockPlan::new(37, 19, 29, 16, 12);
        let legacy = PackedPanels::pack(a.view(), b.view(), &plan);
        let typed = PackedPanels::pack_dtype(a.view(), b.view(), &plan, crate::gemm::Dtype::F32);
        for task in plan.tasks() {
            let x = task_product(&legacy, &task);
            let y = task_product(&typed, &task);
            assert_eq!(x.data, y.data, "task {}", task.id);
        }
    }

    #[test]
    fn f64_panels_match_f64_oracle_tightly() {
        use crate::gemm::Dtype;
        // Ragged prime shapes; the f64 kernel should sit within f32
        // output rounding of the f64 oracle.
        let a = Matrix::random(31, 53, 23);
        let b = Matrix::random(53, 37, 24);
        let plan = BlockPlan::new(31, 53, 37, 16, 12);
        let panels = PackedPanels::pack_dtype(a.view(), b.view(), &plan, Dtype::F64);
        let oracle = a.matmul_f64(&b);
        for task in plan.tasks() {
            let got = task_product(&panels, &task);
            let want = oracle.block(task.row0, task.col0, task.rows, task.cols);
            assert!(got.allclose(&want, 1e-6), "task {} err {}", task.id, got.max_abs_diff(&want));
        }
    }

    #[test]
    fn half_panels_match_f64_oracle_within_dtype_tolerance() {
        use crate::gemm::Dtype;
        // Storage rounding dominates: with values in [-1, 1) and k = 53,
        // per-element error is bounded by ~2*k*u_dtype against an f64
        // oracle (u_f16 = 2^-11, u_bf16 = 2^-8). The documented
        // tolerances below have ~4x headroom over the random-case error.
        let a = Matrix::random(29, 53, 25);
        let b = Matrix::random(53, 31, 26);
        let plan = BlockPlan::new(29, 53, 31, 16, 12);
        let oracle = a.matmul_f64(&b);
        for (dtype, tol) in [(Dtype::F16, 2e-2f32), (Dtype::Bf16, 1.5e-1)] {
            let panels = PackedPanels::pack_dtype(a.view(), b.view(), &plan, dtype);
            for task in plan.tasks() {
                let got = task_product(&panels, &task);
                let want = oracle.block(task.row0, task.col0, task.rows, task.cols);
                assert!(
                    got.allclose(&want, tol),
                    "{dtype} task {} err {}",
                    task.id,
                    got.max_abs_diff(&want)
                );
            }
        }
    }

    #[test]
    fn half_kernel_on_quantized_inputs_equals_f32_kernel() {
        use crate::gemm::Dtype;
        // Grid-quantized inputs are exactly representable in f16 and
        // bf16, so storage rounds nothing and the half kernels must
        // agree with the f32 kernel bit for bit (same accumulation
        // dataflow, same f32 arithmetic).
        let a = Matrix::random_quantized(23, 17, 27);
        let b = Matrix::random_quantized(17, 19, 28);
        let plan = BlockPlan::new(23, 17, 19, 8, 8);
        let f32p = PackedPanels::pack(a.view(), b.view(), &plan);
        for dtype in [Dtype::F16, Dtype::Bf16] {
            let panels = PackedPanels::pack_dtype(a.view(), b.view(), &plan, dtype);
            for task in plan.tasks() {
                let want = task_product(&f32p, &task);
                let got = task_product(&panels, &task);
                assert_eq!(got.data, want.data, "{dtype} task {}", task.id);
            }
        }
    }

    #[test]
    fn micro_kernel_is_rank1_accumulation() {
        // k = 1: acc[i][j] = a[i] * b[j] exactly.
        let ap: Vec<f32> = (0..MR).map(|i| i as f32 + 1.0).collect();
        let bp: Vec<f32> = (0..NR).map(|j| j as f32 + 1.0).collect();
        let acc = micro_kernel(&ap, &bp, 1);
        for i in 0..MR {
            for j in 0..NR {
                assert_eq!(acc[i * NR + j], (i as f32 + 1.0) * (j as f32 + 1.0));
            }
        }
    }
}
