//! The integrated accelerator: MAC + WQM + MPE composed into an
//! event-driven simulation — the "actual measurement" half of Fig. 4 and
//! Table II, with the VC709 replaced by the crate's timing models.
//!
//! Granularity: one event per (array, task). For each task an array pops
//! (stealing when its queue is dry), the simulator charges
//!
//! * a transfer time from Eq. 4 at the effective bandwidth of Eq. 8 —
//!   the `BW = f(N_p, S_i)` surface measured on the DDR model, with an
//!   optional per-array skew (asymmetric DDR routing — the inequality
//!   the paper's work stealing exists to counter);
//! * a compute time from the Eq. 6 closed form (validated against the
//!   cycle-stepped PE simulation in `mpe::pe`);
//!
//! and overlaps them under double buffering: steady-state cost per task
//! is `max(T_work, T_task_compute)`, plus a pipeline-fill charge of the
//! first task's transfer.
//!
//! Optionally the simulator also executes every task *functionally*
//! (through [`crate::gemm::block_task`]) so the result matrix is real and
//! checked against the oracle in tests, and records a per-task event
//! trace ([`trace`] renders Gantt/CSV).

pub mod cycle;
pub mod trace;

use crate::analytical::BandwidthSurface;
use crate::blocking::BlockPlan;
use crate::config::{HardwareConfig, RunConfig};
use crate::gemm::{self, Matrix};
use crate::mpe::{timing::TaskTiming, ArrayGeometry};
use crate::wqm::Wqm;

/// Simulation options.
#[derive(Debug, Clone)]
pub struct SimOptions {
    /// Work stealing on (the paper's WQM) or off (static partition).
    pub stealing: bool,
    /// Skew factors multiplying each array's effective bandwidth — models
    /// asymmetric DDR port routing; `None` = symmetric. Used by the
    /// work-stealing demo and ablation.
    pub bw_skew: Option<Vec<f64>>,
    /// Double buffering in `R_a`/the task pipeline (Section III-A). When
    /// off, transfer and compute serialize per task — the ablation that
    /// shows why the paper overlaps them.
    pub double_buffering: bool,
    /// Record a per-task event trace in the report (timeline analysis,
    /// Gantt rendering, CSV export). Off by default: traces cost an
    /// allocation per task.
    pub trace: bool,
}

impl Default for SimOptions {
    fn default() -> Self {
        Self { stealing: true, bw_skew: None, double_buffering: true, trace: false }
    }
}

/// One traced task execution.
#[derive(Debug, Clone, Copy)]
pub struct TraceEvent {
    pub array: usize,
    pub task_id: usize,
    pub start_secs: f64,
    pub end_secs: f64,
    /// Task came from another array's queue.
    pub stolen: bool,
}

/// Per-array outcome.
#[derive(Debug, Clone)]
pub struct ArrayStats {
    pub tasks: usize,
    pub busy_secs: f64,
    pub finish_secs: f64,
    pub stolen_in: u64,
    pub stolen_out: u64,
}

/// Whole-run outcome.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub run: RunConfig,
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub total_secs: f64,
    pub gflops: f64,
    pub arrays: Vec<ArrayStats>,
    pub total_tasks: usize,
    pub total_steals: u64,
    /// Fraction of tasks whose transfer outweighed compute.
    pub memory_bound_frac: f64,
    /// Per-task events (only when `SimOptions::trace` is set).
    pub trace: Vec<TraceEvent>,
}

impl SimReport {
    /// Sustained-to-peak ratio against `2 * F_acc * P_m * P`.
    pub fn efficiency(&self, hw: &HardwareConfig) -> f64 {
        self.gflops / hw.peak_gflops()
    }

    /// Load imbalance: max array finish time over mean busy time.
    pub fn imbalance(&self) -> f64 {
        let max = self.arrays.iter().map(|a| a.finish_secs).fold(0.0, f64::max);
        let mean = self.arrays.iter().map(|a| a.busy_secs).sum::<f64>()
            / self.arrays.len() as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }
}

/// The simulated accelerator.
pub struct Accelerator {
    pub hw: HardwareConfig,
    surface: BandwidthSurface,
}

impl Accelerator {
    pub fn new(hw: HardwareConfig) -> Self {
        let surface = BandwidthSurface::calibrate_for(
            &hw.ddr,
            &nps_of(hw.pm),
        );
        Self { hw, surface }
    }

    pub fn with_surface(hw: HardwareConfig, surface: BandwidthSurface) -> Self {
        Self { hw, surface }
    }

    pub fn surface(&self) -> &BandwidthSurface {
        &self.surface
    }

    /// Simulate one GEMM problem (timing only).
    pub fn simulate(
        &self,
        run: &RunConfig,
        m: usize,
        k: usize,
        n: usize,
        opts: &SimOptions,
    ) -> anyhow::Result<SimReport> {
        self.run_sim(run, m, k, n, opts, None).map(|(r, _)| r)
    }

    /// Simulate and also compute `C = A x B` functionally, task by task,
    /// in exactly the schedule order the arrays executed.
    pub fn execute(
        &self,
        run: &RunConfig,
        a: &Matrix,
        b: &Matrix,
        opts: &SimOptions,
    ) -> anyhow::Result<(SimReport, Matrix)> {
        let (report, c) = self.run_sim(
            run,
            a.rows,
            a.cols,
            b.cols,
            opts,
            Some((a, b)),
        )?;
        Ok((report, c.expect("functional mode returns C")))
    }

    fn run_sim(
        &self,
        run: &RunConfig,
        m: usize,
        k: usize,
        n: usize,
        opts: &SimOptions,
        operands: Option<(&Matrix, &Matrix)>,
    ) -> anyhow::Result<(SimReport, Option<Matrix>)> {
        let geom = ArrayGeometry::for_run(&self.hw, run)?;
        if let Some(skew) = &opts.bw_skew {
            anyhow::ensure!(skew.len() == geom.np, "skew length != np");
        }
        let plan = BlockPlan::new(m, k, n, run.si, run.sj);
        let mut wqm = Wqm::from_partition(plan.partition(geom.np));
        wqm.set_stealing(opts.stealing);

        let task_cycles =
            TaskTiming::per_task(run.si, run.sj, k, self.hw.fmac_stages).total();
        let t_task_compute = task_cycles as f64 / (self.hw.freq_mhz * 1e6);

        // Effective bandwidth: f(N_p, S_i) as the paper's Eq. 8 — the
        // *configured* array count sets the contention level (the MAC's
        // port arbitration is fixed at configure time), optionally skewed
        // per array to model asymmetric routing. Hoisted out of the task
        // loop: the surface lookup interpolates a BTreeMap and dominated
        // the per-task cost before (§Perf).
        let bw_base = self.surface.bw(geom.np, run.si);
        let bw_of: Vec<f64> = (0..geom.np)
            .map(|i| match &opts.bw_skew {
                Some(skew) => bw_base * skew[i],
                None => bw_base,
            })
            .collect();

        let mut c = operands.map(|(a, b)| Matrix::zeros(a.rows, b.cols));

        // Per-array clocks: when each array's *compute engine* frees, and
        // whether the first task (pipeline fill) is behind it.
        let mut clock = vec![0.0f64; geom.np];
        let mut busy = vec![0.0f64; geom.np];
        let mut tasks_done = vec![0usize; geom.np];
        let mut first = vec![true; geom.np];
        let mut active = vec![true; geom.np];
        let mut mem_bound_tasks = 0usize;
        let mut trace: Vec<TraceEvent> = Vec::new();
        let total_tasks = plan.num_tasks();

        // Event loop: always advance the array whose engine frees first;
        // that is the array whose pop (and possible steal) happens next.
        loop {
            let Some(a_idx) = (0..geom.np)
                .filter(|&i| active[i])
                .min_by(|&x, &y| clock[x].partial_cmp(&clock[y]).unwrap())
            else {
                break;
            };
            let stolen_before = wqm.stats()[a_idx].stolen_in;
            let Some(task) = wqm.pop(a_idx) else {
                active[a_idx] = false;
                continue;
            };
            let was_stolen = wqm.stats()[a_idx].stolen_in > stolen_before;

            let t_transfer = task.bytes_moved() as f64 / bw_of[a_idx];
            if t_transfer > t_task_compute {
                mem_bound_tasks += 1;
            }

            // Double buffering: the first task pays its full transfer
            // before compute; thereafter the engines overlap and the
            // slower one paces the pipeline. Without it (ablation) every
            // task serializes load + compute.
            let dt = if !opts.double_buffering {
                t_transfer + t_task_compute
            } else if first[a_idx] {
                first[a_idx] = false;
                t_transfer + t_task_compute
            } else {
                t_transfer.max(t_task_compute)
            };
            if opts.trace {
                trace.push(TraceEvent {
                    array: a_idx,
                    task_id: task.id,
                    start_secs: clock[a_idx],
                    end_secs: clock[a_idx] + dt,
                    stolen: was_stolen,
                });
            }
            clock[a_idx] += dt;
            busy[a_idx] += dt;
            tasks_done[a_idx] += 1;

            if let (Some(c), Some((a, b))) = (c.as_mut(), operands) {
                let block =
                    gemm::block_task(a, b, task.row0, task.col0, task.si, task.sj);
                c.set_block(task.row0, task.col0, &block);
            }
        }

        // The final write-back drains after the last compute: one block
        // stream-out at the current bandwidth (small; kept for fidelity).
        let total_secs = clock.iter().cloned().fold(0.0, f64::max);
        let stats = wqm.stats();
        let arrays = (0..geom.np)
            .map(|i| ArrayStats {
                tasks: tasks_done[i],
                busy_secs: busy[i],
                finish_secs: clock[i],
                stolen_in: stats[i].stolen_in,
                stolen_out: stats[i].stolen_out,
            })
            .collect::<Vec<_>>();
        let total_steals = stats.iter().map(|s| s.stolen_in).sum();

        let report = SimReport {
            run: *run,
            m,
            k,
            n,
            total_secs,
            gflops: plan.effective_flops() as f64 / total_secs / 1e9,
            arrays,
            total_tasks,
            total_steals,
            memory_bound_frac: mem_bound_tasks as f64 / total_tasks as f64,
            trace,
        };
        Ok((report, c))
    }
}

fn nps_of(pm: usize) -> Vec<usize> {
    (0..)
        .map(|e| 1usize << e)
        .take_while(|np| *np <= pm)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check;

    fn acc() -> Accelerator {
        Accelerator::new(HardwareConfig::paper())
    }

    #[test]
    fn all_tasks_execute() {
        let acc = acc();
        let r = acc
            .simulate(&RunConfig::square(4, 64), 300, 100, 300, &SimOptions::default())
            .unwrap();
        let done: usize = r.arrays.iter().map(|a| a.tasks).sum();
        assert_eq!(done, r.total_tasks);
        assert!(r.total_secs > 0.0);
    }

    #[test]
    fn functional_result_matches_oracle() {
        let acc = acc();
        let a = Matrix::random(100, 40, 1);
        let b = Matrix::random(40, 90, 2);
        let (_, c) = acc
            .execute(&RunConfig::square(2, 32), &a, &b, &SimOptions::default())
            .unwrap();
        assert!(c.allclose(&a.matmul(&b), 1e-4));
    }

    #[test]
    fn stealing_never_slower_with_skew() {
        let acc = acc();
        let skew = Some(vec![1.0, 0.4]);
        let on = acc
            .simulate(
                &RunConfig::square(2, 64),
                512,
                512,
                512,
                &SimOptions { stealing: true, bw_skew: skew.clone(), ..Default::default() },
            )
            .unwrap();
        let off = acc
            .simulate(
                &RunConfig::square(2, 64),
                512,
                512,
                512,
                &SimOptions { stealing: false, bw_skew: skew, ..Default::default() },
            )
            .unwrap();
        assert!(on.total_secs <= off.total_secs * 1.0001);
        assert!(on.total_steals > 0);
    }

    #[test]
    fn stealing_improves_imbalance_under_skew() {
        let acc = acc();
        let opts_on = SimOptions { stealing: true, bw_skew: Some(vec![1.0, 0.3]), ..Default::default() };
        let opts_off = SimOptions { stealing: false, bw_skew: Some(vec![1.0, 0.3]), ..Default::default() };
        let run = RunConfig::square(2, 32);
        let on = acc.simulate(&run, 1024, 256, 1024, &opts_on).unwrap();
        let off = acc.simulate(&run, 1024, 256, 1024, &opts_off).unwrap();
        assert!(on.imbalance() < off.imbalance());
        assert!(on.total_secs < off.total_secs);
    }

    #[test]
    fn gflops_below_peak() {
        let acc = acc();
        for (np, si) in [(1, 256), (2, 128), (4, 64)] {
            let r = acc
                .simulate(
                    &RunConfig::square(np, si),
                    128,
                    9216,
                    4096,
                    &SimOptions::default(),
                )
                .unwrap();
            assert!(r.gflops <= acc.hw.peak_gflops() * 1.001, "{}", r.gflops);
            assert!(r.gflops > 0.0);
        }
    }

    #[test]
    fn fc6_optimal_config_is_efficient() {
        // Paper: fc-6 at (2, 128) reaches 100.9 GFLOPS = 98.6% of peak.
        let acc = acc();
        let r = acc
            .simulate(
                &RunConfig::square(2, 128),
                128,
                9216,
                4096,
                &SimOptions::default(),
            )
            .unwrap();
        assert!(
            r.efficiency(&acc.hw) > 0.90,
            "efficiency {} too low",
            r.efficiency(&acc.hw)
        );
    }

    #[test]
    fn rejects_infeasible_config() {
        let acc = acc();
        assert!(acc
            .simulate(&RunConfig::square(4, 128), 128, 128, 128, &SimOptions::default())
            .is_err());
    }

    #[test]
    fn memory_bound_fraction_tracks_block_size() {
        // Small blocks starve the arrays (Fig. 4's memory-bound cases);
        // big blocks feed them.
        let acc = acc();
        let small = acc
            .simulate(&RunConfig::square(2, 16), 128, 1200, 729, &SimOptions::default())
            .unwrap();
        let large = acc
            .simulate(&RunConfig::square(2, 128), 128, 1200, 729, &SimOptions::default())
            .unwrap();
        assert!(small.memory_bound_frac > 0.9, "{}", small.memory_bound_frac);
        assert!(large.memory_bound_frac < 0.1, "{}", large.memory_bound_frac);
    }

    #[test]
    fn tiny_hardware_config_simulates() {
        let acc = Accelerator::new(HardwareConfig::tiny()); // Pm=2, P=8
        let r = acc
            .simulate(&RunConfig::square(2, 8), 40, 20, 40, &SimOptions::default())
            .unwrap();
        assert_eq!(r.total_tasks, 25);
        assert!(r.gflops <= acc.hw.peak_gflops());
    }

    #[test]
    fn double_buffering_never_slower() {
        let acc = acc();
        for (m, k, n) in [(128, 1200, 729), (128, 9216, 4096), (300, 100, 300)] {
            let run = RunConfig::square(2, 64);
            let on = acc.simulate(&run, m, k, n, &SimOptions::default()).unwrap();
            let off = acc
                .simulate(
                    &run,
                    m,
                    k,
                    n,
                    &SimOptions { double_buffering: false, ..Default::default() },
                )
                .unwrap();
            assert!(on.total_secs <= off.total_secs * 1.0001);
            // Serialized = sum of both phases exactly.
            assert!(off.total_secs > on.total_secs);
        }
    }

    #[test]
    fn skew_length_mismatch_rejected() {
        let acc = acc();
        let opts = SimOptions { stealing: true, bw_skew: Some(vec![1.0]), ..Default::default() };
        assert!(acc.simulate(&RunConfig::square(2, 64), 64, 64, 64, &opts).is_err());
    }

    #[test]
    fn report_identifies_run_and_problem() {
        let acc = acc();
        let run = RunConfig::square(2, 64);
        let r = acc.simulate(&run, 100, 50, 60, &SimOptions::default()).unwrap();
        assert_eq!(r.run, run);
        assert_eq!((r.m, r.k, r.n), (100, 50, 60));
        assert_eq!(r.arrays.len(), 2);
    }

    /// Conservation + numerics across the config space.
    #[test]
    fn prop_simulation_consistent() {
        let acc = acc();
        check::cases(24, |rng| {
            let np = 1usize << rng.range(0, 3);
            let si = 1usize << rng.range(4, 7);
            let (m, k, n) = (rng.range(1, 300), rng.range(1, 100), rng.range(1, 300));
            let run = RunConfig::square(np, si);
            let opts = SimOptions { stealing: rng.bool(), bw_skew: None, ..Default::default() };
            let r = acc.simulate(&run, m, k, n, &opts).unwrap();
            let done: usize = r.arrays.iter().map(|a| a.tasks).sum();
            assert_eq!(done, r.total_tasks);
            assert!(r.total_secs > 0.0);
            assert!(r.gflops <= acc.hw.peak_gflops() * 1.001);
        });
    }

    #[test]
    fn prop_functional_always_correct() {
        let acc = acc();
        check::cases(24, |rng| {
            let (m, k, n) = (rng.range(1, 80), rng.range(1, 40), rng.range(1, 80));
            let a = Matrix::random(m, k, rng.next_u64());
            let b = Matrix::random(k, n, rng.next_u64());
            let run = RunConfig::square(2, 1usize << rng.range(3, 6));
            let opts = SimOptions { stealing: rng.bool(), bw_skew: None, ..Default::default() };
            let (_, c) = acc.execute(&run, &a, &b, &opts).unwrap();
            assert!(c.allclose(&a.matmul(&b), 1e-3));
        });
    }
}
