//! Trace rendering: turn a [`super::SimReport`]'s event trace into an
//! ASCII Gantt chart or CSV for offline analysis. The Gantt makes the
//! work-stealing behaviour visible at a glance: stolen tasks render as
//! `s`, locally-queued ones as `#`, idle as `.`.

use super::SimReport;

/// ASCII Gantt: one row per array, `width` columns of wall-clock time.
/// Requires the report to carry a trace (`SimOptions::trace = true`).
pub fn gantt(report: &SimReport, width: usize) -> String {
    assert!(width >= 10, "gantt needs at least 10 columns");
    if report.trace.is_empty() {
        return String::from("(no trace recorded — set SimOptions::trace)\n");
    }
    let total = report.total_secs;
    let np = report.arrays.len();
    let mut rows = vec![vec!['.'; width]; np];
    for ev in &report.trace {
        let c0 = ((ev.start_secs / total) * width as f64) as usize;
        let c1 = (((ev.end_secs / total) * width as f64).ceil() as usize).min(width);
        let ch = if ev.stolen { 's' } else { '#' };
        for cell in rows[ev.array][c0.min(width - 1)..c1.max(c0 + 1).min(width)]
            .iter_mut()
        {
            *cell = ch;
        }
    }
    let mut out = String::new();
    for (i, row) in rows.iter().enumerate() {
        out.push_str(&format!("array {i} |"));
        out.extend(row.iter());
        out.push_str("|\n");
    }
    out.push_str(&format!(
        "         0{:>width$}\n",
        format!("{:.3} ms", total * 1e3),
        width = width
    ));
    out
}

/// CSV export: `array,task_id,start_secs,end_secs,stolen` per event.
pub fn to_csv(report: &SimReport) -> String {
    let mut out = String::from("array,task_id,start_secs,end_secs,stolen\n");
    for ev in &report.trace {
        out.push_str(&format!(
            "{},{},{:.9},{:.9},{}\n",
            ev.array, ev.task_id, ev.start_secs, ev.end_secs, ev.stolen
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accelerator::{Accelerator, SimOptions};
    use crate::config::{HardwareConfig, RunConfig};

    fn traced_report(stealing: bool) -> SimReport {
        let acc = Accelerator::new(HardwareConfig::paper());
        let opts = SimOptions {
            stealing,
            bw_skew: Some(vec![1.0, 0.25]),
            trace: true,
            ..Default::default()
        };
        acc.simulate(&RunConfig::square(2, 64), 512, 128, 512, &opts).unwrap()
    }

    #[test]
    fn trace_covers_every_task() {
        let r = traced_report(true);
        assert_eq!(r.trace.len(), r.total_tasks);
        // Events are well-formed and within the run window.
        for ev in &r.trace {
            assert!(ev.start_secs >= 0.0 && ev.end_secs <= r.total_secs * 1.0001);
            assert!(ev.end_secs > ev.start_secs);
        }
    }

    #[test]
    fn stolen_events_marked_only_with_stealing() {
        let on = traced_report(true);
        assert!(on.trace.iter().any(|e| e.stolen));
        let off = traced_report(false);
        assert!(off.trace.iter().all(|e| !e.stolen));
    }

    #[test]
    fn gantt_renders_rows_and_steals() {
        let r = traced_report(true);
        let g = gantt(&r, 60);
        assert_eq!(g.lines().count(), 3); // 2 arrays + time axis
        assert!(g.contains('#'));
        assert!(g.contains('s'));
    }

    #[test]
    fn gantt_without_trace_is_graceful() {
        let acc = Accelerator::new(HardwareConfig::paper());
        let r = acc
            .simulate(&RunConfig::square(2, 64), 128, 64, 128, &SimOptions::default())
            .unwrap();
        assert!(gantt(&r, 40).contains("no trace"));
    }

    #[test]
    fn csv_has_header_and_rows() {
        let r = traced_report(true);
        let csv = to_csv(&r);
        assert!(csv.starts_with("array,task_id"));
        assert_eq!(csv.lines().count(), r.total_tasks + 1);
    }
}
