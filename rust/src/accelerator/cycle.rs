//! Cycle-granular cross-validation of the event-driven simulator.
//!
//! The event simulator (`run_sim`) charges each task `max(T_work,
//! T_compute)` in steady state — the closed-form behaviour of a two-engine
//! (DMA + compute) pipeline with one prefetch buffer. This module
//! *derives* that behaviour instead of assuming it: each array is modeled
//! as two engines stepped at accelerator-clock granularity,
//!
//! * the **transfer engine** starts loading the next task as soon as it
//!   is idle and the prefetch buffer slot is free (double buffering in
//!   `R_a`/the input FIFOs);
//! * the **compute engine** starts when its input buffer is full, runs
//!   the Eq. 6 cycle count, then frees the slot;
//! * tasks are popped from the shared work-stealing WQM at *transfer
//!   start* (the moment the MAC fetches the buffer descriptor).
//!
//! Tests assert the two simulators agree within a fraction of a percent
//! across configurations, skews, and stealing modes — so the fast
//! simulator's Fig. 4 / Table II numbers rest on a mechanistic model,
//! not on the formula being assumed twice.

use crate::blocking::BlockPlan;
use crate::config::{HardwareConfig, RunConfig};
use crate::mpe::{timing::TaskTiming, ArrayGeometry};
use crate::wqm::Wqm;

use super::{Accelerator, SimOptions};

/// Outcome of the cycle-granular run.
#[derive(Debug, Clone)]
pub struct CycleReport {
    pub total_cycles: u64,
    pub total_secs: f64,
    pub tasks_per_array: Vec<usize>,
}

/// Per-array engine state.
struct ArrayState {
    /// Cycles left on the in-flight transfer (0 = idle).
    transfer_left: u64,
    /// Cycles left on the in-flight compute (0 = idle).
    compute_left: u64,
    /// Loaded-but-not-computed buffers (0..=1 waiting + 1 in compute).
    ready_buffers: usize,
    done: bool,
    tasks: usize,
}

impl Accelerator {
    /// Step the whole accelerator at clock granularity. Slower than
    /// [`Accelerator::simulate`] by orders of magnitude; used by tests
    /// and available for waveform-level debugging.
    pub fn simulate_cycles(
        &self,
        run: &RunConfig,
        m: usize,
        k: usize,
        n: usize,
        opts: &SimOptions,
    ) -> anyhow::Result<CycleReport> {
        let geom = ArrayGeometry::for_run(&self.hw, run)?;
        if let Some(skew) = &opts.bw_skew {
            anyhow::ensure!(skew.len() == geom.np, "skew length != np");
        }
        anyhow::ensure!(
            opts.double_buffering,
            "cycle model implements the double-buffered pipeline only"
        );
        let plan = BlockPlan::new(m, k, n, run.si, run.sj);
        let mut wqm = Wqm::from_partition(plan.partition(geom.np));
        wqm.set_stealing(opts.stealing);

        let freq = self.hw.freq_mhz * 1e6;
        let compute_cycles =
            TaskTiming::per_task(run.si, run.sj, k, self.hw.fmac_stages).total();
        let bw_base = self.surface().bw(geom.np, run.si);
        // Transfer cycles per task, at the array's effective bandwidth
        // expressed in accelerator clocks.
        let transfer_cycles: Vec<u64> = (0..geom.np)
            .map(|i| {
                let bw = match &opts.bw_skew {
                    Some(skew) => bw_base * skew[i],
                    None => bw_base,
                };
                let bytes = plan.task(0).bytes_moved() as f64;
                (bytes / bw * freq).ceil() as u64
            })
            .collect();

        let mut arrays: Vec<ArrayState> = (0..geom.np)
            .map(|_| ArrayState {
                transfer_left: 0,
                compute_left: 0,
                ready_buffers: 0,
                done: false,
                tasks: 0,
            })
            .collect();

        let mut cycle: u64 = 0;
        loop {
            // Advance by the smallest remaining engine time instead of 1
            // (event-stepped cycles: exact same trajectory, tractable
            // speed for multi-million-cycle runs).
            let mut all_done = true;
            let mut stride = u64::MAX;
            for a in arrays.iter() {
                if !a.done {
                    all_done = false;
                    if a.transfer_left > 0 {
                        stride = stride.min(a.transfer_left);
                    }
                    if a.compute_left > 0 {
                        stride = stride.min(a.compute_left);
                    }
                }
            }
            if all_done {
                break;
            }
            if stride == u64::MAX {
                stride = 0; // engines idle: act this cycle (pop/start)
            }
            cycle += stride;
            for a in arrays.iter_mut() {
                if a.done {
                    continue;
                }
                if a.transfer_left > 0 {
                    a.transfer_left -= stride;
                }
                if a.compute_left > 0 {
                    a.compute_left -= stride;
                    if a.compute_left == 0 {
                        a.tasks += 1;
                    }
                }
            }
            // Start engines (transfer completion -> buffer ready; compute
            // start consumes a buffer; transfer start pops the WQM).
            for (i, a) in arrays.iter_mut().enumerate() {
                if a.done {
                    continue;
                }
                // A finished transfer hands its buffer over.
                if a.transfer_left == 0 && a.ready_buffers > 0 {
                    // (buffer already accounted at transfer start)
                }
                // Compute starts when idle and a buffer is loaded.
                if a.compute_left == 0 && a.ready_buffers > 0 && a.transfer_left == 0
                {
                    a.ready_buffers -= 1;
                    a.compute_left = compute_cycles;
                }
                // Transfer starts when engine idle and prefetch slot free.
                if a.transfer_left == 0 && a.ready_buffers == 0 {
                    match wqm.pop(i) {
                        Some(_task) => {
                            a.transfer_left = transfer_cycles[i];
                            a.ready_buffers += 1;
                        }
                        None => {
                            if a.compute_left == 0 {
                                a.done = true;
                            }
                        }
                    }
                }
            }
        }

        Ok(CycleReport {
            total_cycles: cycle,
            total_secs: cycle as f64 / freq,
            tasks_per_array: arrays.iter().map(|a| a.tasks).collect(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accelerator::Accelerator;

    fn acc() -> Accelerator {
        Accelerator::new(HardwareConfig::paper())
    }

    fn agree(run: RunConfig, m: usize, k: usize, n: usize, opts: &SimOptions, tol: f64) {
        let acc = acc();
        let fast = acc.simulate(&run, m, k, n, opts).unwrap();
        let slow = acc.simulate_cycles(&run, m, k, n, opts).unwrap();
        let rel = (fast.total_secs - slow.total_secs).abs() / slow.total_secs;
        assert!(
            rel < tol,
            "{run} {m}x{k}x{n}: event {:.6e}s vs cycle {:.6e}s (rel {rel:.4})",
            fast.total_secs,
            slow.total_secs
        );
        let fast_tasks: usize = fast.arrays.iter().map(|a| a.tasks).sum();
        assert_eq!(fast_tasks, slow.tasks_per_array.iter().sum::<usize>());
    }

    #[test]
    fn agrees_compute_bound() {
        agree(RunConfig::square(2, 128), 128, 1200, 729, &SimOptions::default(), 0.01);
    }

    #[test]
    fn agrees_memory_bound() {
        agree(RunConfig::square(4, 16), 128, 1200, 729, &SimOptions::default(), 0.01);
    }

    #[test]
    fn agrees_single_array() {
        agree(RunConfig::square(1, 256), 512, 300, 512, &SimOptions::default(), 0.01);
    }

    #[test]
    fn agrees_with_skew_and_stealing() {
        let opts = SimOptions {
            stealing: true,
            bw_skew: Some(vec![1.0, 0.4]),
            ..Default::default()
        };
        agree(RunConfig::square(2, 64), 512, 256, 512, &opts, 0.02);
    }

    #[test]
    fn agrees_without_stealing() {
        let opts = SimOptions {
            stealing: false,
            bw_skew: Some(vec![1.0, 0.4]),
            ..Default::default()
        };
        agree(RunConfig::square(2, 64), 512, 256, 512, &opts, 0.02);
    }

    #[test]
    fn serialized_mode_rejected() {
        let acc = acc();
        let opts = SimOptions { double_buffering: false, ..Default::default() };
        assert!(acc
            .simulate_cycles(&RunConfig::square(2, 64), 64, 64, 64, &opts)
            .is_err());
    }
}
