//! `marr` — CLI for the multi-array GEMM accelerator.
//!
//! Subcommands map one-to-one onto the paper's artifacts:
//! * `resources` — Table I (post-synthesis utilization model);
//! * `sweep-bandwidth` — Fig. 3 (effective BW vs block size and N_p);
//! * `predict --layer conv2` — Fig. 4 (model bounds vs simulated time);
//! * `alexnet` — Table II (optimal ⟨N_p, S_i⟩ per layer vs baselines);
//! * `dse --m M --k K --n N` — design-space report for any problem;
//! * `run --m M --k K --n N [--np NP --si SI] [--golden]` — one GEMM
//!   through the full coordinator (numerics + simulation).
//!
//! Global: `--hw <file>` loads a hardware config (see `configs/`).

use std::collections::HashMap;

use multi_array::accelerator::{Accelerator, SimOptions};
use multi_array::analytical::{self, bandwidth::SI_GRID, BandwidthSurface};
use multi_array::cnn;
use multi_array::config::{HardwareConfig, RunConfig};
use multi_array::coordinator::{Coordinator, GemmJob, NumericsEngine, Submission};
use multi_array::dse;
use multi_array::gemm::{Dtype, Matrix};
use multi_array::resources;

const USAGE: &str = "\
marr — multi-array linear-systolic GEMM accelerator (Shen et al. 2018)

USAGE: marr [--hw <config-file>] <command> [options]

COMMANDS:
  resources                         Table I resource utilization
  sweep-bandwidth                   Fig. 3 bandwidth surface
  predict [--layer conv2]           Fig. 4 bounds vs simulation
  alexnet                           Table II optimal configs
  dse --m M --k K --n N             design-space exploration
  run --m M --k K --n N [--np NP --si SI] [--golden] [--artifacts DIR]
                                    run one GEMM end to end
  strassen --m M --k K --n N [--depth D] [--algo winograd|classic]
           [--sequential] [--np NP --si SI]
           [--workers W] [--check] [--golden] [--artifacts DIR]
                                    Strassen-decomposed GEMM through the
                                    job server (depth: forced levels;
                                    default: model-chosen cutoff).
                                    --algo picks the combine schedule
                                    (default winograd: 15 combine ops
                                    per node vs classic's 18); the
                                    report prints both schedules' op
                                    counts and the temps the fused leaf
                                    packing avoided. --sequential
                                    disables the parallel sibling walk
  batch --file JOBS [--shared-b | --register-weights [--repeat R]]
        [--dtype f64|f32|f16|bf16] [--workers W] [--golden] [--artifacts DIR]
                                    serve a job file (lines: M K N [NP SI]);
                                    '-' reads stdin. --shared-b runs the
                                    batch (uniform K N required) against ONE
                                    shared B both ways — individual submits
                                    vs one Submission::batched — and reports the
                                    pack-traffic win. --register-weights
                                    runs the batch R times (default 3)
                                    inline vs through one registered
                                    WeightHandle and reports the repacks
                                    avoided across runs. --dtype serves
                                    every job at that precision (panels
                                    packed at the dtype, f32 accumulate)
                                    and prints model-predicted vs
                                    simulated time per job
  serve-demo [--tenants N] [--jobs J] [--deadline-ms MS] [--workers W]
             [--golden]             multi-tenant admission demo: N tenants
                                    with DRR weights 1..=N submit skewed
                                    async streams under a per-job deadline;
                                    prints per-tenant service counters and
                                    the deadline-miss rate from stats()
  schedule [--reconfig-us US]       whole-AlexNet schedule: per-layer
                                    optimal (w/ reconfiguration cost) vs
                                    best fixed config
  attention [--d-model D --seq S --batch B] [--repeat R] [--np NP --si SI]
            [--dtype f64|f32|f16|bf16] [--check] [--workers W] [--golden]
            [--artifacts DIR]
                                    transformer attention block (Q/K/V/O
                                    projections, QK^T, softmax, AV) served
                                    R times inline vs through registered
                                    weights + a registered activation
                                    batch; prints the packs avoided.
                                    --dtype serves every GEMM of the block
                                    at that precision and prints the
                                    model-predicted projection time vs
                                    f32. --check verifies against the
                                    scalar oracle (per-dtype tolerance)
  trace [--tenants N] [--jobs J] [--workers W] [--capacity C]
        [--json] [--out PREFIX] [--golden]
                                    flight-recorder demo: run a mixed
                                    workload (plain GEMMs, a shared-B
                                    batch over a registered weight,
                                    deadlines) with tracing on, then
                                    print the per-job stage breakdown,
                                    per-worker task/steal provenance and
                                    predicted-vs-measured drift. --json
                                    emits the JSONL job traces to stdout;
                                    --out PREFIX writes PREFIX.jsonl and
                                    PREFIX.chrome.json (Perfetto-loadable)
  help                              this message
";

/// Tiny argv parser: positional command + `--key value` flags
/// (`--golden`-style booleans take no value).
struct Args {
    cmd: String,
    flags: HashMap<String, String>,
}

const BOOL_FLAGS: &[&str] =
    &["golden", "check", "shared-b", "register-weights", "json", "sequential"];

fn parse_args(argv: &[String]) -> anyhow::Result<Args> {
    let mut cmd = None;
    let mut flags = HashMap::new();
    let mut it = argv.iter().peekable();
    while let Some(arg) = it.next() {
        if let Some(key) = arg.strip_prefix("--") {
            if BOOL_FLAGS.contains(&key) {
                flags.insert(key.to_string(), "true".to_string());
            } else {
                let val = it
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("--{key} needs a value"))?;
                flags.insert(key.to_string(), val.clone());
            }
        } else if cmd.is_none() {
            cmd = Some(arg.clone());
        } else {
            anyhow::bail!("unexpected argument {arg:?}");
        }
    }
    Ok(Args { cmd: cmd.unwrap_or_else(|| "help".into()), flags })
}

impl Args {
    fn get_usize(&self, key: &str) -> anyhow::Result<Option<usize>> {
        self.flags
            .get(key)
            .map(|v| {
                v.parse()
                    .map_err(|_| anyhow::anyhow!("--{key} = {v:?} is not an integer"))
            })
            .transpose()
    }

    fn require_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get_usize(key)?
            .ok_or_else(|| anyhow::anyhow!("missing required --{key}"))
    }
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = parse_args(&argv)?;
    let hw = match args.flags.get("hw") {
        Some(path) => HardwareConfig::load(std::path::Path::new(path))?,
        None => HardwareConfig::paper(),
    };
    match args.cmd.as_str() {
        "resources" => cmd_resources(&hw),
        "sweep-bandwidth" => cmd_sweep(&hw),
        "predict" => cmd_predict(
            &hw,
            args.flags.get("layer").map(String::as_str).unwrap_or("conv2"),
        ),
        "alexnet" => cmd_alexnet(&hw),
        "dse" => cmd_dse(
            &hw,
            args.require_usize("m")?,
            args.require_usize("k")?,
            args.require_usize("n")?,
        ),
        "run" => cmd_run(&hw, &args),
        "strassen" => cmd_strassen(&hw, &args),
        "batch" => cmd_batch(&hw, &args),
        "serve-demo" => cmd_serve_demo(&hw, &args),
        "trace" => cmd_trace(&hw, &args),
        "schedule" => cmd_schedule(&hw, &args),
        "attention" => cmd_attention(&hw, &args),
        "help" | "-h" | "--help" => {
            print!("{USAGE}");
            Ok(())
        }
        other => {
            eprint!("unknown command {other:?}\n\n{USAGE}");
            std::process::exit(2);
        }
    }
}

/// Serving precision from the shared `--dtype` flag (default f32 — the
/// legacy path, bit for bit).
fn dtype_from(args: &Args) -> anyhow::Result<Dtype> {
    match args.flags.get("dtype") {
        Some(v) => v.parse().map_err(|e: String| anyhow::anyhow!(e)),
        None => Ok(Dtype::F32),
    }
}

/// Numerics backend from the shared `--golden` / `--artifacts` flags:
/// golden when forced, otherwise PJRT with golden fallback.
fn engine_from(args: &Args) -> NumericsEngine {
    let artifacts = args
        .flags
        .get("artifacts")
        .map(String::as_str)
        .unwrap_or("artifacts");
    if args.flags.contains_key("golden") {
        NumericsEngine::golden()
    } else {
        NumericsEngine::auto(artifacts)
    }
}

fn cmd_resources(hw: &HardwareConfig) -> anyhow::Result<()> {
    let r = resources::report(hw);
    println!("Post-synthesis resource utilization (Pm={}, P={}):", hw.pm, hw.p);
    println!("{:<12} {:>10} {:>12}", "Resource", "Used", "Percent");
    println!("{:<12} {:>10.0} {:>11.2}%", "DSP48Es", r.usage.dsp, r.percent.dsp);
    println!("{:<12} {:>10.1} {:>11.2}%", "BRAMs", r.usage.bram36, r.percent.bram36);
    println!("{:<12} {:>10.0} {:>11.2}%", "Flip-Flops", r.usage.ff, r.percent.ff);
    println!("{:<12} {:>10.0} {:>11.2}%", "LUTs", r.usage.lut, r.percent.lut);
    Ok(())
}

fn cmd_sweep(hw: &HardwareConfig) -> anyhow::Result<()> {
    println!("Effective per-array memory bandwidth (GB/s), Fig. 3:");
    print!("{:>8}", "Si");
    for np in [1usize, 2, 4] {
        print!("{:>10}", format!("Np={np}"));
    }
    println!();
    let surface = BandwidthSurface::calibrate(&hw.ddr);
    for &si in SI_GRID.iter().filter(|&&si| si <= 512) {
        print!("{si:>8}");
        for np in [1usize, 2, 4] {
            print!("{:>10.2}", surface.bw(np, si) / 1e9);
        }
        println!();
    }
    Ok(())
}

fn cmd_predict(hw: &HardwareConfig, layer: &str) -> anyhow::Result<()> {
    let l = cnn::layer(layer)
        .ok_or_else(|| anyhow::anyhow!("unknown layer {layer} (conv1..fc8)"))?;
    let acc = Accelerator::new(hw.clone());
    println!(
        "Layer {} (M*K*N = {}*{}*{}): predicted bounds vs simulated, Fig. 4:",
        l.name, l.m, l.k, l.n
    );
    println!(
        "{:>12} {:>12} {:>12} {:>12} {:>12} {:>8}",
        "(Np,Si)", "lower(ms)", "upper(ms)", "sim(ms)", "GFLOPS", "memB"
    );
    for si in [16usize, 32, 64, 128, 256] {
        for np in analytical::feasible_nps(hw, si) {
            let run = RunConfig::square(np, si);
            let p = analytical::predict(hw, &run, l.m, l.k, l.n, acc.surface())?;
            let sim = acc.simulate(&run, l.m, l.k, l.n, &SimOptions::default())?;
            println!(
                "{:>12} {:>12.3} {:>12.3} {:>12.3} {:>12.1} {:>8}",
                format!("({np},{si})"),
                p.lower * 1e3,
                p.upper * 1e3,
                sim.total_secs * 1e3,
                sim.gflops,
                if p.memory_bound() { "yes" } else { "no" }
            );
        }
    }
    Ok(())
}

fn cmd_alexnet(hw: &HardwareConfig) -> anyhow::Result<()> {
    let acc = Accelerator::new(hw.clone());
    println!("Optimal (Np, Si) per AlexNet layer, Table II (simulated GFLOPS):");
    println!(
        "{:>8} {:>16} {:>10} {:>10} {:>10} {:>10}",
        "Layer", "M*K*N", "Optimal", "GFLOPS", "Np=4", "Np=1"
    );
    for l in cnn::alexnet_layers() {
        let e = dse::explore(hw, l.m, l.k, l.n, acc.surface())?;
        let best = e.best.run;
        let opt = acc.simulate(&best, l.m, l.k, l.n, &SimOptions::default())?;
        let b4 = dse::baseline(hw, hw.pm, l.m, l.k, l.n, acc.surface())?;
        let s4 = acc.simulate(&b4.run, l.m, l.k, l.n, &SimOptions::default())?;
        let b1 = dse::baseline(hw, 1, l.m, l.k, l.n, acc.surface())?;
        let s1 = acc.simulate(&b1.run, l.m, l.k, l.n, &SimOptions::default())?;
        println!(
            "{:>8} {:>16} {:>10} {:>10.1} {:>10.1} {:>10.1}",
            l.name,
            format!("{}*{}*{}", l.m, l.k, l.n),
            format!("({},{})", best.np, best.si),
            opt.gflops,
            s4.gflops,
            s1.gflops
        );
    }
    println!("peak = {:.1} GFLOPS (2 * F_acc * Pm * P)", hw.peak_gflops());
    Ok(())
}

fn cmd_dse(hw: &HardwareConfig, m: usize, k: usize, n: usize) -> anyhow::Result<()> {
    let surface = BandwidthSurface::calibrate(&hw.ddr);
    let e = dse::explore(hw, m, k, n, &surface)?;
    println!("Design space for {m}x{k}x{n} (best first):");
    println!(
        "{:>12} {:>12} {:>12} {:>12} {:>10}",
        "(Np,Si)", "lower(ms)", "upper(ms)", "overlap(ms)", "GFLOPS"
    );
    for p in e.points.iter().take(12) {
        println!(
            "{:>12} {:>12.3} {:>12.3} {:>12.3} {:>10.1}",
            format!("({},{})", p.run.np, p.run.si),
            p.prediction.lower * 1e3,
            p.prediction.upper * 1e3,
            p.prediction.t_overlap() * 1e3,
            p.est_gflops
        );
    }
    println!("optimal: {}", e.best.run);
    Ok(())
}

fn cmd_run(hw: &HardwareConfig, args: &Args) -> anyhow::Result<()> {
    let (m, k, n) = (
        args.require_usize("m")?,
        args.require_usize("k")?,
        args.require_usize("n")?,
    );
    let engine = engine_from(args);
    println!("numerics backend: {}", engine.name);
    let co = Coordinator::new(hw.clone(), engine);
    let run = match (args.get_usize("np")?, args.get_usize("si")?) {
        (Some(np), Some(si)) => Some(RunConfig::square(np, si)),
        (None, None) => None,
        _ => anyhow::bail!("--np and --si must be given together"),
    };
    let a = Matrix::random(m, k, 42);
    let b = Matrix::random(k, n, 43);
    let want = a.matmul(&b);

    let result = co.run_job(GemmJob { id: 0, a: a.into(), b: b.into(), run })?;

    let err = result.c.max_abs_diff(&want);
    println!("config: {}", result.run);
    println!("max |err| vs oracle: {err:.3e}");
    println!(
        "simulated FPGA time: {:.3} ms ({:.1} GFLOPS, {:.1}% of peak)",
        result.sim.total_secs * 1e3,
        result.sim.gflops,
        100.0 * result.sim.efficiency(hw)
    );
    println!("host numerics latency: {:.3} s", result.host_latency_secs);
    println!("metrics: {}", co.metrics().summary());
    Ok(())
}

/// Strassen-decomposed GEMM through the job server: the model picks the
/// recursion depth (`--depth` forces it), each level fans 7 sub-products
/// into the pool as a job group, and the crossover trace is printed the
/// way `dse` prints design points.
fn cmd_strassen(hw: &HardwareConfig, args: &Args) -> anyhow::Result<()> {
    use multi_array::coordinator::{JobServer, ServerConfig};
    use multi_array::strassen::{self, Cutoff, StrassenAlgo, StrassenConfig, DIRECT_SPLIT_FANOUT};

    let (m, k, n) = (
        args.require_usize("m")?,
        args.require_usize("k")?,
        args.require_usize("n")?,
    );
    let run = match (args.get_usize("np")?, args.get_usize("si")?) {
        (Some(np), Some(si)) => Some(RunConfig::square(np, si)),
        (None, None) => None,
        _ => anyhow::bail!("--np and --si must be given together"),
    };
    let engine = engine_from(args);
    println!("numerics backend: {}", engine.name);
    let mut server_cfg = ServerConfig::default();
    if let Some(w) = args.get_usize("workers")? {
        server_cfg.workers = w;
    }
    server_cfg.default_run = run;
    let srv = JobServer::new(hw.clone(), engine, server_cfg)?;

    let cutoff = match args.get_usize("depth")? {
        Some(d) => Cutoff::Depth(d),
        None => Cutoff::Model,
    };
    let algo = match args.flags.get("algo").map(String::as_str) {
        None | Some("winograd") => StrassenAlgo::Winograd,
        Some("classic") => StrassenAlgo::Classic,
        Some(other) => anyhow::bail!("--algo must be 'winograd' or 'classic', got {other:?}"),
    };
    let parallel = !args.flags.contains_key("sequential");
    let a = Matrix::random(m, k, 42);
    let b = Matrix::random(k, n, 43);
    let want = if args.flags.contains_key("check") {
        Some(a.matmul(&b))
    } else {
        None
    };

    let t0 = std::time::Instant::now();
    let r = strassen::multiply(&srv, &a, &b, &StrassenConfig { cutoff, run, algo, parallel })?;
    let wall = t0.elapsed().as_secs_f64();

    // Model runs carry their plan in the report; forced-depth runs skip
    // the sweep, so evaluate it here (outside the timed region) for the
    // trace.
    let computed;
    let plan = match &r.model {
        Some(p) => p,
        None => {
            computed = multi_array::analytical::strassen_crossover(hw, m, k, n, srv.surface())?;
            &computed
        }
    };
    println!("\nmodel crossover trace (level: size, direct vs 7·child+combine):");
    println!(
        "{:>6} {:>18} {:>12} {:>12} {:>8}",
        "level", "M*K*N", "direct(ms)", "strassen(ms)", "recurse"
    );
    for (i, l) in plan.levels.iter().enumerate() {
        let ts = if l.t_strassen.is_finite() {
            format!("{:.3}", l.t_strassen * 1e3)
        } else {
            "-".to_string()
        };
        println!(
            "{:>6} {:>18} {:>12.3} {:>12} {:>8}",
            i,
            format!("{}*{}*{}", l.m, l.k, l.n),
            l.t_direct * 1e3,
            ts,
            if l.recurse { "yes" } else { "no" }
        );
    }
    println!("model-chosen depth: {}", plan.depth);
    println!(
        "executed depth: {} ({} leaf GEMMs; padded to {}x{}x{})",
        r.depth, r.leaf_gemms, r.padded.0, r.padded.1, r.padded.2
    );
    println!(
        "schedule: {} ({} tree walk)",
        r.algo.name(),
        if parallel { "parallel" } else { "sequential" }
    );
    for lvl in 0..r.depth {
        println!(
            "  level {lvl}: {} node(s), measured fan-out {} sub-multiplies (direct split: {})",
            r.level_nodes[lvl], r.fanout(lvl), DIRECT_SPLIT_FANOUT
        );
    }
    if r.depth > 0 {
        println!(
            "combine: {} ops over {} nodes ({:.1}/node; winograd schedules 15, classic 18)",
            r.combine.combine_ops,
            r.combine.nodes,
            r.combine.ops_per_node()
        );
        println!(
            "temps: {} materialized, {} avoided by fused leaf packing",
            r.combine.temps_materialized, r.combine.temps_avoided
        );
    }
    println!(
        "arena: {} fresh allocs ({:.1} MiB), {} reuses",
        r.arena.fresh_allocs, r.arena.fresh_bytes as f64 / (1 << 20) as f64, r.arena.reuses
    );
    if let Some(want) = want {
        println!("max |err| vs oracle: {:.3e}", r.c.max_abs_diff(&want));
    }
    println!("host wall time: {wall:.3} s");
    println!("server: {}", srv.stats());
    srv.shutdown();
    Ok(())
}

/// Whole-network scheduling: per-layer-optimal with reconfiguration
/// stalls vs the best single fixed configuration (cnn::schedule).
fn cmd_schedule(hw: &HardwareConfig, args: &Args) -> anyhow::Result<()> {
    use multi_array::cnn::schedule::{self, Policy};
    let reconfig_us = args.get_usize("reconfig-us")?.unwrap_or(50) as f64;
    let reconfig = reconfig_us * 1e-6;
    let acc = Accelerator::new(hw.clone());
    let layers = cnn::alexnet_layers();

    let opt = schedule::schedule_network(hw, &acc, &layers, Policy::PerLayerOptimal, reconfig)?;
    let fixed = schedule::best_fixed(hw, &acc, &layers)?;
    let be = schedule::break_even_reconfig_secs(hw, &acc, &layers)?;

    println!("AlexNet schedule (reconfig stall = {reconfig_us} µs):");
    println!("{:>8} {:>10} {:>12} {:>10} {:>8}", "Layer", "config", "time(ms)", "GFLOPS", "reconf");
    for l in &opt.layers {
        println!(
            "{:>8} {:>10} {:>12.3} {:>10.1} {:>8}",
            l.name,
            format!("({},{})", l.run.np, l.run.si),
            l.secs * 1e3,
            l.gflops,
            if l.reconfigured { "yes" } else { "" }
        );
    }
    println!(
        "\nper-layer optimal: {:.3} ms total ({} reconfigs) -> {:.1} GFLOPS",
        opt.total_secs * 1e3,
        opt.reconfigs,
        opt.total_gflops
    );
    println!(
        "best fixed {}: {:.3} ms total -> {:.1} GFLOPS",
        fixed.layers[0].run,
        fixed.total_secs * 1e3,
        fixed.total_gflops
    );
    println!(
        "break-even reconfiguration cost: {:.1} µs per switch",
        be * 1e6
    );
    Ok(())
}

/// Serve a file of jobs through the coordinator's queue, one line per
/// GEMM: `M K N [NP SI]`. Demonstrates the serving face: the client
/// thread enqueues, the coordinator drains, per-job replies come back on
/// per-job channels.
fn cmd_batch(hw: &HardwareConfig, args: &Args) -> anyhow::Result<()> {
    let file = args
        .flags
        .get("file")
        .ok_or_else(|| anyhow::anyhow!("missing required --file"))?;
    let text = if file == "-" {
        let mut s = String::new();
        std::io::Read::read_to_string(&mut std::io::stdin(), &mut s)?;
        s
    } else {
        std::fs::read_to_string(file)?
    };
    let mut jobs = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let nums: Vec<usize> = line
            .split_whitespace()
            .map(|t| {
                t.parse()
                    .map_err(|_| anyhow::anyhow!("line {}: bad number {t:?}", lineno + 1))
            })
            .collect::<anyhow::Result<_>>()?;
        let (mkn, run) = match nums.as_slice() {
            [m, k, n] => ((*m, *k, *n), None),
            [m, k, n, np, si] => ((*m, *k, *n), Some(RunConfig::square(*np, *si))),
            _ => anyhow::bail!("line {}: expected `M K N [NP SI]`", lineno + 1),
        };
        jobs.push((mkn, run));
    }
    anyhow::ensure!(!jobs.is_empty(), "no jobs in {file}");

    if args.flags.contains_key("shared-b") {
        return cmd_batch_shared_b(hw, args, &jobs);
    }
    if args.flags.contains_key("register-weights") {
        return cmd_batch_register_weights(hw, args, &jobs);
    }

    let dtype = dtype_from(args)?;
    let engine = engine_from(args);
    println!(
        "numerics backend: {} | {} jobs | serving dtype {dtype}",
        engine.name,
        jobs.len()
    );

    // f32 keeps the legacy Coordinator serve loop bit for bit; other
    // precisions carry the dtype on their Submissions, so they route
    // through the JobServer front end.
    let t0 = std::time::Instant::now();
    let (results, metrics_line) = if dtype == Dtype::F32 {
        let co = Coordinator::new(hw.clone(), engine);
        let (jtx, jrx) = std::sync::mpsc::channel();
        let replies: Vec<_> = jobs
            .iter()
            .enumerate()
            .map(|(id, ((m, k, n), run))| {
                let (rtx, rrx) = std::sync::mpsc::channel();
                let a = Matrix::random(*m, *k, id as u64 * 2);
                let b = Matrix::random(*k, *n, id as u64 * 2 + 1);
                jtx.send((GemmJob { id: id as u64, a: a.into(), b: b.into(), run: *run }, rtx))
                    .unwrap();
                rrx
            })
            .collect();
        drop(jtx);
        co.serve(jrx);
        let results = replies
            .into_iter()
            .map(|rrx| rrx.recv()?)
            .collect::<anyhow::Result<Vec<_>>>()?;
        (results, format!("metrics: {}", co.metrics().summary()))
    } else {
        let srv = batch_server(hw, args, jobs.len(), "serving")?;
        let futures: Vec<_> = jobs
            .iter()
            .enumerate()
            .map(|(id, ((m, k, n), run))| {
                let a = Matrix::random(*m, *k, id as u64 * 2);
                let b = Matrix::random(*k, *n, id as u64 * 2 + 1);
                let job = GemmJob { id: id as u64, a: a.into(), b: b.into(), run: *run };
                srv.submit_async(Submission::from(job).dtype(dtype))
            })
            .collect::<anyhow::Result<_>>()?;
        let results = futures
            .into_iter()
            .map(|f| f.wait_one())
            .collect::<anyhow::Result<Vec<_>>>()?;
        let line = format!("server: {}", srv.stats());
        srv.shutdown();
        (results, line)
    };
    let wall = t0.elapsed().as_secs_f64();

    let surface = BandwidthSurface::calibrate(&hw.ddr);
    println!(
        "{:>4} {:>16} {:>10} {:>12} {:>12} {:>10} {:>10}",
        "job", "M*K*N", "config", "pred(ms)", "sim(ms)", "GFLOPS", "host(s)"
    );
    let mut total_flops = 0u64;
    let mut total_sim = 0.0;
    for ((id, ((m, k, n), _)), r) in jobs.iter().enumerate().zip(results) {
        let pred = analytical::predict_dtype(hw, &r.run, *m, *k, *n, &surface, dtype)?;
        total_flops += 2 * (*m as u64) * (*k as u64) * (*n as u64);
        total_sim += r.sim.total_secs;
        println!(
            "{:>4} {:>16} {:>10} {:>12.3} {:>12.3} {:>10.1} {:>10.3}",
            id,
            format!("{m}*{k}*{n}"),
            format!("({},{})", r.run.np, r.run.si),
            pred.t_overlap() * 1e3,
            r.sim.total_secs * 1e3,
            r.sim.gflops,
            r.host_latency_secs
        );
    }
    println!(
        "batch: {} jobs in {:.2} s host wall | simulated {:.3} ms total -> {:.1} GFLOPS sustained",
        jobs.len(),
        wall,
        total_sim * 1e3,
        total_flops as f64 / total_sim / 1e9
    );
    println!("{metrics_line}");
    Ok(())
}

/// The operands of a one-shared-B job file: what both the `--shared-b`
/// and `--register-weights` batch modes run.
struct SharedBWorkload {
    b: Matrix,
    many_a: Vec<Matrix>,
    run: Option<RunConfig>,
    k0: usize,
    n0: usize,
}

/// Shared prelude of the shared-B batch modes: validate that the job
/// file describes ONE B (uniform K and N) under ONE config, then
/// synthesize the deterministic operands.
fn shared_b_workload(
    mode: &str,
    jobs: &[((usize, usize, usize), Option<RunConfig>)],
) -> anyhow::Result<SharedBWorkload> {
    let ((_, k0, n0), run) = jobs[0];
    anyhow::ensure!(
        jobs.iter().all(|((_, k, n), _)| (*k, *n) == (k0, n0)),
        "{mode} needs one B: every job line must share K and N"
    );
    // These modes run under ONE config; a file mixing pins would
    // silently lose all but the first, so reject it instead.
    anyhow::ensure!(
        jobs.iter().all(|(_, r)| *r == run),
        "{mode} runs the whole batch under one config: every job \
         line must carry the same [NP SI] (or none)"
    );
    let b = Matrix::random(k0, n0, 1);
    let many_a = jobs
        .iter()
        .enumerate()
        .map(|(id, ((m, k, _), _))| Matrix::random(*m, *k, id as u64 * 2))
        .collect();
    Ok(SharedBWorkload { b, many_a, run, k0, n0 })
}

/// One `JobServer` for a batch mode, sized to admit the whole file.
fn batch_server(
    hw: &HardwareConfig,
    args: &Args,
    njobs: usize,
    label: &str,
) -> anyhow::Result<multi_array::coordinator::JobServer> {
    use multi_array::coordinator::{JobServer, ServerConfig};
    let engine = engine_from(args);
    println!("{label}: numerics backend {}", engine.name);
    let mut cfg = ServerConfig::default();
    if let Some(w) = args.get_usize("workers")? {
        cfg.workers = w;
    }
    cfg.queue_capacity = njobs.max(cfg.queue_capacity);
    JobServer::new(hw.clone(), engine, cfg)
}

/// Shared-B mode of `marr batch`: the whole job file is one batch
/// multiplying a single B, run through the `JobServer` both ways —
/// individual GEMM submissions (N private B packs) and one
/// `Submission::batched` (one shared pack) — so the pack-traffic win is
/// directly observable from the printed stats.
fn cmd_batch_shared_b(
    hw: &HardwareConfig,
    args: &Args,
    jobs: &[((usize, usize, usize), Option<RunConfig>)],
) -> anyhow::Result<()> {
    let SharedBWorkload { b, many_a, run, k0, n0 } = shared_b_workload("--shared-b", jobs)?;
    let dtype = dtype_from(args)?;

    // Baseline: the same traffic, one submission per job.
    let srv = batch_server(hw, args, jobs.len(), "individual")?;
    let t0 = std::time::Instant::now();
    let futures: Vec<_> = many_a
        .iter()
        .enumerate()
        .map(|(id, a)| {
            srv.submit_async(
                Submission::gemm(a.clone(), b.clone()).id(id as u64).run(run).dtype(dtype),
            )
        })
        .collect::<anyhow::Result<_>>()?;
    for f in futures {
        f.wait()?;
    }
    let individual_wall = t0.elapsed().as_secs_f64();
    let individual_stats = srv.stats();
    srv.shutdown();

    // Shared: one admission unit, one packed B for the whole batch.
    let srv = batch_server(hw, args, jobs.len(), "shared-B")?;
    let t0 = std::time::Instant::now();
    let results = srv.submit_blocking(Submission::batched(b, many_a).run(run).dtype(dtype))?;
    let shared_wall = t0.elapsed().as_secs_f64();
    let shared_stats = srv.stats();
    srv.shutdown();

    println!("\n{} jobs x ({k0} x {n0}) shared B at dtype {dtype}:", results.len());
    println!(
        "  individual: {individual_wall:.3} s wall | packs(a/b)={}/{} panels_shared={}",
        individual_stats.a_panel_packs,
        individual_stats.b_panel_packs,
        individual_stats.panels_shared
    );
    println!(
        "  shared-B:   {shared_wall:.3} s wall | packs(a/b)={}/{} panels_shared={} \
         ({} B packs avoided)",
        shared_stats.a_panel_packs,
        shared_stats.b_panel_packs,
        shared_stats.panels_shared,
        individual_stats.b_panel_packs.saturating_sub(shared_stats.b_panel_packs)
    );
    println!("  individual server: {individual_stats}");
    println!("  shared-B server:   {shared_stats}");
    Ok(())
}

/// Registered-weights mode of `marr batch`: the whole job file is one
/// shared-B batch run `--repeat` times through the `JobServer` both
/// ways — inline B per call (one pack per run) and through one
/// registered `WeightHandle` (one pack per *process*, later runs are
/// registry hits) — so the cross-call repack traffic the operand
/// registry eliminates is directly observable from the printed stats.
fn cmd_batch_register_weights(
    hw: &HardwareConfig,
    args: &Args,
    jobs: &[((usize, usize, usize), Option<RunConfig>)],
) -> anyhow::Result<()> {
    let SharedBWorkload { b, many_a, run, k0, n0 } =
        shared_b_workload("--register-weights", jobs)?;
    let repeat = args.get_usize("repeat")?.unwrap_or(3).max(1);
    let dtype = dtype_from(args)?;

    // Baseline: the same traffic, inline B every run (repacks per run).
    let srv = batch_server(hw, args, jobs.len(), "inline")?;
    let t0 = std::time::Instant::now();
    for _ in 0..repeat {
        srv.submit_blocking(
            Submission::batched(b.clone(), many_a.clone()).run(run).dtype(dtype),
        )?;
    }
    let inline_wall = t0.elapsed().as_secs_f64();
    let inline_stats = srv.stats();
    srv.shutdown();

    // Registered: one model-load, every run resolves the cached pack.
    let srv = batch_server(hw, args, jobs.len(), "registered")?;
    let handle = srv.register_b(b)?;
    let t0 = std::time::Instant::now();
    for _ in 0..repeat {
        srv.submit_blocking(
            Submission::batched(handle, many_a.clone()).run(run).dtype(dtype),
        )?;
    }
    let registered_wall = t0.elapsed().as_secs_f64();
    let registered_stats = srv.stats();
    srv.shutdown();

    println!(
        "\n{} jobs x ({k0} x {n0}) shared B at dtype {dtype}, {repeat} repeated runs:",
        many_a.len()
    );
    println!(
        "  inline:     {inline_wall:.3} s wall | b_panel_packs={} (one per run)",
        inline_stats.b_panel_packs
    );
    println!(
        "  registered: {registered_wall:.3} s wall | b_panel_packs={} \
         cache_hits={} ({} repacks avoided across runs)",
        registered_stats.b_panel_packs,
        registered_stats.registry_hits,
        inline_stats.b_panel_packs.saturating_sub(registered_stats.b_panel_packs)
    );
    println!("  inline server:     {inline_stats}");
    println!("  registered server: {registered_stats}");
    Ok(())
}

/// `marr serve-demo`: the multi-tenant admission front end in action.
/// `--tenants N` clients with DRR weights `1..=N` each submit a skewed
/// async stream (tenant `t` submits `(t+1) * --jobs` GEMMs up front, so
/// the queue is backlogged and fairness — not arrival order — decides
/// service) under a per-job `--deadline-ms` budget. Per-tenant service
/// counters and the deadline-miss rate come straight from `stats()`.
fn cmd_serve_demo(hw: &HardwareConfig, args: &Args) -> anyhow::Result<()> {
    use multi_array::coordinator::{JobServer, ServerConfig, TenantConfig, TenantId};

    let tenants = args.get_usize("tenants")?.unwrap_or(3).max(1);
    let per = args.get_usize("jobs")?.unwrap_or(8).max(1);
    let deadline_ms = args.get_usize("deadline-ms")?.unwrap_or(250) as u64;
    let engine = engine_from(args);
    println!(
        "serve-demo: numerics backend {} | {tenants} tenants, DRR weights 1..={tenants}",
        engine.name
    );
    let mut cfg = ServerConfig::default();
    if let Some(w) = args.get_usize("workers")? {
        cfg.workers = w;
    }
    cfg.default_run = Some(RunConfig::square(2, 16));
    let srv = JobServer::new(hw.clone(), engine, cfg)?;

    for t in 0..tenants {
        srv.configure_tenant(
            TenantId(t as u32),
            TenantConfig { weight: (t + 1) as u32, ..TenantConfig::default() },
        )?;
    }

    let mut futures = Vec::new();
    for t in 0..tenants {
        for j in 0..(t + 1) * per {
            let seed = (t * 10_000 + j) as u64;
            let a = Matrix::random(48, 32, seed * 2);
            let b = Matrix::random(32, 40, seed * 2 + 1);
            futures.push(srv.submit_async(
                Submission::gemm(a, b)
                    .id(seed)
                    .tenant(TenantId(t as u32))
                    .deadline(std::time::Duration::from_millis(deadline_ms)),
            )?);
        }
    }
    let total = futures.len();
    let t0 = std::time::Instant::now();
    for f in futures {
        f.wait()?;
    }
    let wall = t0.elapsed().as_secs_f64();

    let stats = srv.stats();
    println!("\n{total} jobs served in {wall:.3} s wall");
    println!(
        "deadlines: {}/{} missed ({deadline_ms} ms budget each)",
        stats.deadline_misses, stats.deadline_jobs
    );
    println!("{:>8} {:>8} {:>8} {:>8}", "tenant", "weight", "jobs", "misses");
    for (id, c) in &stats.tenants {
        println!(
            "{:>8} {:>8} {:>8} {:>8}",
            format!("#{}", id.0),
            id.0 + 1,
            c.jobs,
            c.deadline_misses
        );
    }
    println!("server: {stats}");
    srv.shutdown();
    Ok(())
}

/// `marr trace`: the flight recorder end to end. Runs a mixed workload
/// — per-tenant plain GEMMs under a deadline, plus one shared-B batch
/// against a registered weight so the trace carries registry hits —
/// with `trace_capacity` ring slots, then renders the per-job stage
/// breakdown (queue/plan/pack/execute/finalize), per-worker task and
/// steal provenance, and predicted-vs-measured drift. `--json` prints
/// the JSONL job traces to stdout (consumed by
/// `ci/check_trace_schema.py`); `--out PREFIX` writes `PREFIX.jsonl`
/// and `PREFIX.chrome.json` for Perfetto / `chrome://tracing`.
fn cmd_trace(hw: &HardwareConfig, args: &Args) -> anyhow::Result<()> {
    use multi_array::coordinator::trace::{stage_percentiles, STAGE_NAMES};
    use multi_array::coordinator::{JobServer, ServerConfig, TenantConfig, TenantId};

    let tenants = args.get_usize("tenants")?.unwrap_or(2).max(1);
    let per = args.get_usize("jobs")?.unwrap_or(6).max(1);
    let capacity = args.get_usize("capacity")?.unwrap_or(4096).max(1);
    let json = args.flags.contains_key("json");
    let engine = engine_from(args);

    let mut cfg = ServerConfig::default();
    if let Some(w) = args.get_usize("workers")? {
        cfg.workers = w;
    }
    cfg.default_run = Some(RunConfig::square(2, 16));
    cfg.trace_capacity = capacity;
    let srv = JobServer::new(hw.clone(), engine, cfg)?;

    for t in 0..tenants {
        srv.configure_tenant(
            TenantId(t as u32),
            TenantConfig { weight: (t + 1) as u32, ..TenantConfig::default() },
        )?;
    }

    // Plain per-tenant streams; odd tenants carry a deadline so the
    // trace exercises the deadline accounting too.
    let mut futures = Vec::new();
    for t in 0..tenants {
        for j in 0..per {
            let seed = (t * 10_000 + j) as u64;
            let a = Matrix::random(48, 32, seed * 2);
            let b = Matrix::random(32, 40, seed * 2 + 1);
            let mut sub = Submission::gemm(a, b).id(seed).tenant(TenantId(t as u32));
            if t % 2 == 1 {
                sub = sub.deadline(std::time::Duration::from_millis(250));
            }
            futures.push(srv.submit_async(sub)?);
        }
    }
    // One shared-B batch against a registered weight: the pack stage
    // resolves through the operand registry, so the trace carries
    // registry-hit events alongside the job lifecycle.
    let wb = srv.register_b(Matrix::random(32, 40, 7))?;
    let many_a: Vec<Matrix> = (0..4).map(|i| Matrix::random(48, 32, 100 + i)).collect();
    futures.push(srv.submit_async(Submission::batched(wb, many_a))?);

    for f in futures {
        f.wait()?;
    }
    srv.unregister_b(wb)?;

    let snap = srv.trace_snapshot();
    if json {
        let mut out = std::io::stdout().lock();
        snap.exporter().write_jsonl(&mut out)?;
        srv.shutdown();
        return Ok(());
    }

    let traces = snap.job_traces();
    println!(
        "trace: {} events recorded ({} overwritten), {} job traces",
        snap.recorded,
        snap.dropped,
        traces.len()
    );
    println!(
        "{:>6} {:>7} {:>10} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9} {:>7}",
        "uid", "tenant", "terminal", "queue_s", "plan_s", "pack_s", "exec_s", "final_s",
        "e2e_s", "drift"
    );
    let fmt = |v: Option<f64>| match v {
        Some(x) => format!("{x:.6}"),
        None => "-".to_string(),
    };
    for t in &traces {
        println!(
            "{:>6} {:>7} {:>10} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9} {:>7}",
            t.uid,
            t.tenant,
            t.terminal.name(),
            fmt(t.queue_secs()),
            fmt(t.plan_secs()),
            fmt(t.pack_secs()),
            fmt(t.execute_secs()),
            fmt(t.finalize_secs()),
            fmt(t.end_to_end_secs()),
            match t.drift_frac() {
                Some(d) => format!("{:+.1}%", 100.0 * d),
                None => "-".to_string(),
            },
        );
    }

    if let Some(pcts) = stage_percentiles(&traces, &[0.50, 0.95]) {
        println!("\nstage rollup (p50 / p95):");
        for (name, ps) in STAGE_NAMES.iter().zip(&pcts) {
            println!("  {name:>8}: {:.6} s / {:.6} s", ps[0], ps[1]);
        }
    }

    let mut tallies: std::collections::BTreeMap<u32, (u64, u64)> = Default::default();
    for t in &traces {
        for wt in &t.workers {
            let e = tallies.entry(wt.worker).or_default();
            e.0 += wt.tasks;
            e.1 += wt.stolen;
        }
    }
    println!("\n{:>8} {:>8} {:>8}", "worker", "tasks", "stolen");
    for (w, (tasks, stolen)) in &tallies {
        println!("{w:>8} {tasks:>8} {stolen:>8}");
    }

    let stats = srv.stats();
    if let Some(d) = &stats.drift {
        println!(
            "\ndrift over {} jobs: min {:+.3} mean {:+.3} max {:+.3} p95 {:+.3}",
            d.count, d.min, d.mean, d.max, d.p95
        );
    }
    println!("\nserver: {stats}");

    if let Some(prefix) = args.flags.get("out") {
        let mut jl = std::fs::File::create(format!("{prefix}.jsonl"))?;
        snap.exporter().write_jsonl(&mut jl)?;
        let mut ch = std::fs::File::create(format!("{prefix}.chrome.json"))?;
        snap.exporter().write_chrome(&mut ch)?;
        println!("wrote {prefix}.jsonl and {prefix}.chrome.json");
    }
    srv.shutdown();
    Ok(())
}

/// `marr attention`: one transformer attention block served `--repeat`
/// times both ways — inline (every operand repacked every run) and
/// through the symmetric operand registry (`AttentionWeights` on the B
/// side, `ActivationBatch` on the A side: after warmup, repeated runs
/// pack nothing). Outputs are checked bit-identical across the two
/// paths; `--check` additionally verifies against the scalar oracle.
fn cmd_attention(hw: &HardwareConfig, args: &Args) -> anyhow::Result<()> {
    use multi_array::attention::{
        attention_block_inline_dtype, attention_block_oracle,
        attention_block_registered_dtype, ActivationBatch, AttentionWeights,
    };

    let d_model = args.get_usize("d-model")?.unwrap_or(64);
    let seq = args.get_usize("seq")?.unwrap_or(48);
    let batch = args.get_usize("batch")?.unwrap_or(4);
    let repeat = args.get_usize("repeat")?.unwrap_or(3).max(1);
    let dtype = dtype_from(args)?;
    let run = match (args.get_usize("np")?, args.get_usize("si")?) {
        (Some(np), Some(si)) => Some(RunConfig::square(np, si)),
        (None, None) => None,
        _ => anyhow::bail!("--np and --si must be given together"),
    };
    let xs: Vec<Matrix> =
        (0..batch as u64).map(|i| Matrix::random(seq, d_model, 900 + i)).collect();
    let wq = Matrix::random(d_model, d_model, 910);
    let wk = Matrix::random(d_model, d_model, 911);
    let wv = Matrix::random(d_model, d_model, 912);
    let wo = Matrix::random(d_model, d_model, 913);

    // Baseline: every run re-packs all four weights and every
    // activation (three projections each) from scratch.
    let srv = batch_server(hw, args, batch.max(8), "inline")?;
    let t0 = std::time::Instant::now();
    let mut inline_out = Vec::new();
    for _ in 0..repeat {
        inline_out =
            attention_block_inline_dtype(&srv, &xs, &wq, &wk, &wv, &wo, run, dtype)?;
    }
    let inline_wall = t0.elapsed().as_secs_f64();
    let inline_stats = srv.stats();
    srv.shutdown();

    // Registered: one model-load + one batch-load, then every run
    // resolves both sides from the pack cache.
    let srv = batch_server(hw, args, batch.max(8), "registered")?;
    let weights =
        AttentionWeights::register(&srv, wq.clone(), wk.clone(), wv.clone(), wo.clone())?;
    let abatch = ActivationBatch::register(&srv, &xs)?;
    let t0 = std::time::Instant::now();
    let mut reg_out = Vec::new();
    for _ in 0..repeat {
        reg_out = attention_block_registered_dtype(&srv, &abatch, &weights, run, dtype)?;
    }
    let registered_wall = t0.elapsed().as_secs_f64();
    let registered_stats = srv.stats();
    abatch.unregister(&srv)?;
    weights.unregister(&srv)?;
    srv.shutdown();

    for (i, (a, b)) in inline_out.iter().zip(&reg_out).enumerate() {
        anyhow::ensure!(
            a.data == b.data,
            "member {i}: registered output differs from inline — residency changed numerics"
        );
    }

    println!(
        "\nattention block: d_model={d_model} seq={seq} batch={batch}, \
         {repeat} repeated runs at dtype {dtype}:"
    );
    // Model-predicted time for one projection GEMM (seq x d_model x
    // d_model) at the serving precision vs f32 — the throughput the
    // dtype buys on paper, next to the achieved wall times below.
    {
        let surface = BandwidthSurface::calibrate(&hw.ddr);
        let proj = dse::explore_dtype(hw, seq, d_model, d_model, &surface, dtype)?.best;
        let f32_proj = dse::explore(hw, seq, d_model, d_model, &surface)?.best;
        println!(
            "  model: projection GEMM predicted {:.3} ms at {dtype} (f32: {:.3} ms)",
            proj.prediction.t_overlap() * 1e3,
            f32_proj.prediction.t_overlap() * 1e3
        );
    }
    println!(
        "  inline:     {inline_wall:.3} s wall | packs(a/b)={}/{}",
        inline_stats.a_panel_packs, inline_stats.b_panel_packs
    );
    println!(
        "  registered: {registered_wall:.3} s wall | packs(a/b)={}/{} \
         cache hits(a/b)={}/{} ({} repacks avoided)",
        registered_stats.a_panel_packs,
        registered_stats.b_panel_packs,
        registered_stats.registry_a_hits,
        registered_stats.registry_hits,
        (inline_stats.a_panel_packs + inline_stats.b_panel_packs)
            .saturating_sub(registered_stats.a_panel_packs + registered_stats.b_panel_packs)
    );
    println!("  outputs bit-identical across both paths");
    println!("  inline server:     {inline_stats}");
    println!("  registered server: {registered_stats}");

    if args.flags.contains_key("check") {
        // Half-precision serving quantizes the packed panels, so the
        // oracle tolerance widens with the dtype's unit roundoff.
        let tol = match dtype {
            Dtype::F64 | Dtype::F32 => 1e-3,
            Dtype::F16 => 5e-2,
            Dtype::Bf16 => 3e-1,
        };
        let oracle = attention_block_oracle(&xs, &wq, &wk, &wv, &wo);
        let mut max_err = 0.0f32;
        for (i, (o, c)) in oracle.iter().zip(&reg_out).enumerate() {
            let err = o.max_abs_diff(c);
            max_err = max_err.max(err);
            anyhow::ensure!(
                o.allclose(c, tol),
                "member {i}: served block disagrees with the scalar oracle (|err| = {err:.3e})"
            );
        }
        println!("  check vs scalar oracle (tol {tol:.0e}): max |err| = {max_err:.3e} — OK");
    }
    Ok(())
}
