//! Server-resident packed-operand registry: register a weight once,
//! never repack it across calls.
//!
//! PR 4's shared-B batches made a packed B shareable *within* one
//! [`super::JobServer::submit_batched_gemm`] call; successive batches,
//! epochs, and layers that reuse the same weight still repacked it per
//! call. Inference servers solve this with an explicit model-load step
//! — weights are stationary state, activations are traffic — and the
//! related multi-array literature (Strassen Multisystolic Arrays,
//! ArrayFlex) likewise preloads stationary operands. [`OperandRegistry`]
//! is that model-load step for this serving runtime:
//!
//! * [`super::JobServer::register_b`] stores the operand once behind an
//!   `Arc<Matrix>` and returns an opaque [`WeightHandle`];
//! * submissions carry a [`BOperand`] — `Inline(Matrix)` keeps the old
//!   per-call semantics, `Registered(WeightHandle)` resolves inside the
//!   dispatcher to the cached [`PackedB`];
//! * the pack cache is keyed by `(handle, sj)`: a handle resolved under
//!   one block size reuses its pack on every later call (a *hit*),
//!   while a different `S_j` re-derives a per-shape variant once (a
//!   *miss* that packs and caches). The one-pack guarantee therefore
//!   holds **across** calls, not just within one;
//! * eviction is refcount-pinned LRU under a configurable byte budget
//!   (`ServerConfig::registry_budget_bytes`): least-recently-used packs
//!   leave first, but a pack still referenced outside the registry (an
//!   in-flight job holds its `Arc`) is pinned and survives — the
//!   registry may transiently exceed its budget rather than invalidate
//!   live work. Evicting a pack never invalidates its handle: the next
//!   resolution repacks from the retained matrix (a miss, not an error).
//!
//! Hit/miss/evict counters and the resident-bytes gauge land in
//! [`Metrics`] next to `panels_shared`, so the cross-call win is as
//! observable as PR 4's within-call sharing.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::gemm::{Matrix, PackedB};

use super::metrics::Metrics;

/// Process-unique registry ids, so a handle minted by one server can
/// never silently resolve against another server's registry.
static NEXT_REGISTRY_NONCE: AtomicU64 = AtomicU64::new(1);

/// Opaque, copyable handle to a registered B operand. Obtained from
/// [`super::JobServer::register_b`]; valid until the matching
/// `unregister_b`. Submitting an unknown, unregistered, or
/// foreign-server handle fails that job through its ticket, never the
/// server — the handle carries its registry's nonce, so crossing two
/// servers' handles is an error, not silently wrong numerics.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct WeightHandle {
    registry: u64,
    id: u64,
}

impl WeightHandle {
    /// The raw per-registry id (diagnostics / logging).
    pub fn id(&self) -> u64 {
        self.id
    }
}

impl std::fmt::Display for WeightHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "weight#{}", self.id)
    }
}

/// The B side of a submission: a one-shot inline matrix (packed per
/// call, exactly the pre-registry behavior) or a registered weight
/// resolved from the server's [`OperandRegistry`].
#[derive(Debug, Clone)]
pub enum BOperand {
    /// Caller-owned operand; packed once for this call.
    Inline(Matrix),
    /// Server-resident weight; packed at most once per `(handle, S_j)`
    /// for the whole process.
    Registered(WeightHandle),
}

impl BOperand {
    /// `(rows, cols)` when the operand is inline; `None` for a handle
    /// (its dims live in the server's registry).
    pub fn inline_dims(&self) -> Option<(usize, usize)> {
        match self {
            BOperand::Inline(m) => Some((m.rows, m.cols)),
            BOperand::Registered(_) => None,
        }
    }

    /// Borrow the inline matrix, if any.
    pub fn as_inline(&self) -> Option<&Matrix> {
        match self {
            BOperand::Inline(m) => Some(m),
            BOperand::Registered(_) => None,
        }
    }

    /// Take the inline matrix back out, if any.
    pub fn into_inline(self) -> Option<Matrix> {
        match self {
            BOperand::Inline(m) => Some(m),
            BOperand::Registered(_) => None,
        }
    }

    /// The registered handle, if any.
    pub fn handle(&self) -> Option<WeightHandle> {
        match self {
            BOperand::Inline(_) => None,
            BOperand::Registered(h) => Some(*h),
        }
    }
}

impl From<Matrix> for BOperand {
    fn from(m: Matrix) -> Self {
        BOperand::Inline(m)
    }
}

impl From<WeightHandle> for BOperand {
    fn from(h: WeightHandle) -> Self {
        BOperand::Registered(h)
    }
}

/// One cached pack variant of a registered operand.
struct PackSlot {
    pack: Arc<PackedB>,
    bytes: u64,
    /// Logical LRU timestamp; bumped on every hit.
    stamp: u64,
}

/// One registered operand: the retained matrix plus its per-`sj` pack
/// variants.
struct Entry {
    matrix: Arc<Matrix>,
    packs: HashMap<usize, PackSlot>,
}

struct State {
    entries: HashMap<u64, Entry>,
    next_handle: u64,
    /// LRU clock; bumped on every resolution.
    clock: u64,
    /// Bytes of packed data currently held by the registry (cached
    /// packs only — retained matrices and in-flight clones the registry
    /// no longer holds are not counted).
    resident_bytes: u64,
}

/// The server-resident weight cache. Owned by the `JobServer`'s shared
/// state; clients reach it through `register_b` / `unregister_b`, the
/// dispatcher through [`OperandRegistry::resolve_pack`].
pub struct OperandRegistry {
    nonce: u64,
    budget_bytes: u64,
    metrics: Arc<Metrics>,
    state: Mutex<State>,
}

impl OperandRegistry {
    pub(crate) fn new(budget_bytes: u64, metrics: Arc<Metrics>) -> Self {
        Self {
            nonce: NEXT_REGISTRY_NONCE.fetch_add(1, Ordering::Relaxed),
            budget_bytes,
            metrics,
            state: Mutex::new(State {
                entries: HashMap::new(),
                next_handle: 0,
                clock: 0,
                resident_bytes: 0,
            }),
        }
    }

    /// The entry key for `h`, or `None` for a handle minted by a
    /// different registry (another server's handle must never resolve
    /// here — it would be silently wrong numerics, not a cache miss).
    fn key(&self, h: WeightHandle) -> Option<u64> {
        (h.registry == self.nonce).then_some(h.id)
    }

    /// Register one B operand; packing is lazy (first resolution per
    /// block size), so the handle is cheap to create and never packs at
    /// a block size no job asks for.
    pub fn register(&self, b: Matrix) -> anyhow::Result<WeightHandle> {
        anyhow::ensure!(
            b.rows > 0 && b.cols > 0,
            "cannot register degenerate operand {}x{}",
            b.rows,
            b.cols
        );
        let mut st = self.state.lock().unwrap();
        let h = WeightHandle { registry: self.nonce, id: st.next_handle };
        st.next_handle += 1;
        st.entries.insert(h.id, Entry { matrix: Arc::new(b), packs: HashMap::new() });
        Ok(h)
    }

    /// Drop a registered operand and its cached packs. In-flight jobs
    /// keep their `Arc` clones, so running work is unaffected; later
    /// submissions under this handle fail through their tickets.
    pub fn unregister(&self, h: WeightHandle) -> anyhow::Result<()> {
        let key = self
            .key(h)
            .ok_or_else(|| anyhow::anyhow!("{h} belongs to a different server's registry"))?;
        let mut st = self.state.lock().unwrap();
        let entry = st
            .entries
            .remove(&key)
            .ok_or_else(|| anyhow::anyhow!("{h} is not registered (double unregister?)"))?;
        let freed: u64 = entry.packs.values().map(|s| s.bytes).sum();
        st.resident_bytes -= freed;
        self.metrics.set_registry_resident_bytes(st.resident_bytes);
        Ok(())
    }

    /// `(rows, cols)` of a registered operand; `None` once unregistered
    /// (or for another registry's handle).
    pub fn dims(&self, h: WeightHandle) -> Option<(usize, usize)> {
        let key = self.key(h)?;
        let st = self.state.lock().unwrap();
        st.entries.get(&key).map(|e| (e.matrix.rows, e.matrix.cols))
    }

    /// The retained operand matrix; `None` once unregistered (or for
    /// another registry's handle).
    pub fn matrix(&self, h: WeightHandle) -> Option<Arc<Matrix>> {
        let key = self.key(h)?;
        let st = self.state.lock().unwrap();
        st.entries.get(&key).map(|e| e.matrix.clone())
    }

    /// Resolve the packed form of `h` at block size `sj`: a cached
    /// variant is a **hit**; otherwise pack once (off the lock), cache
    /// the result, and evict LRU-unpinned packs past the byte budget.
    /// The returned `Arc` pins its pack against eviction for as long as
    /// the caller (an in-flight job) holds it.
    pub fn resolve_pack(&self, h: WeightHandle, sj: usize) -> anyhow::Result<Arc<PackedB>> {
        let key = self
            .key(h)
            .ok_or_else(|| anyhow::anyhow!("{h} belongs to a different server's registry"))?;
        let matrix = {
            let mut st = self.state.lock().unwrap();
            st.clock += 1;
            let clock = st.clock;
            let entry = st
                .entries
                .get_mut(&key)
                .ok_or_else(|| anyhow::anyhow!("{h} is not registered"))?;
            if let Some(slot) = entry.packs.get_mut(&sj) {
                slot.stamp = clock;
                self.metrics.add_registry_hits(1);
                return Ok(slot.pack.clone());
            }
            entry.matrix.clone()
        };
        // Miss: pack outside the lock (packing a large weight must not
        // stall concurrent register/stats calls), then publish. A
        // concurrent unregister simply skips the caching, and a
        // concurrent resolver that won the same-(handle, sj) race has
        // its slot replaced — with its bytes returned to the ledger, so
        // resident accounting survives the race exactly.
        self.metrics.add_registry_misses(1);
        self.metrics.add_b_panel_packs(1);
        let pack = Arc::new(PackedB::pack(matrix.view(), sj));
        let bytes = pack.packed_bytes();
        let mut st = self.state.lock().unwrap();
        st.clock += 1;
        let stamp = st.clock;
        if let Some(entry) = st.entries.get_mut(&key) {
            if let Some(old) = entry.packs.insert(sj, PackSlot { pack: pack.clone(), bytes, stamp })
            {
                st.resident_bytes -= old.bytes;
            }
            st.resident_bytes += bytes;
            self.evict_lru(&mut st);
            self.metrics.set_registry_resident_bytes(st.resident_bytes);
        }
        Ok(pack)
    }

    /// Evict least-recently-used packs until the budget holds, skipping
    /// pinned ones (`Arc` held outside the registry — an in-flight
    /// job). With everything pinned the registry overshoots its budget
    /// transiently instead of invalidating live work.
    fn evict_lru(&self, st: &mut State) {
        while st.resident_bytes > self.budget_bytes {
            let victim = st
                .entries
                .iter()
                .flat_map(|(id, e)| {
                    e.packs
                        .iter()
                        .filter(|(_, slot)| Arc::strong_count(&slot.pack) == 1)
                        .map(move |(sj, slot)| (slot.stamp, *id, *sj))
                })
                .min();
            let Some((_, id, sj)) = victim else { break };
            let slot = st
                .entries
                .get_mut(&id)
                .expect("victim entry vanished under the lock")
                .packs
                .remove(&sj)
                .expect("victim slot vanished under the lock");
            st.resident_bytes -= slot.bytes;
            self.metrics.add_registry_evictions(1);
        }
    }

    /// Registered operands currently alive.
    pub fn registered_weights(&self) -> usize {
        self.state.lock().unwrap().entries.len()
    }

    /// Bytes of packed data the registry currently holds.
    pub fn resident_bytes(&self) -> u64 {
        self.state.lock().unwrap().resident_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn registry(budget: u64) -> (OperandRegistry, Arc<Metrics>) {
        let metrics = Arc::new(Metrics::default());
        (OperandRegistry::new(budget, metrics.clone()), metrics)
    }

    #[test]
    fn register_resolve_hit_miss_counters() {
        let (reg, m) = registry(u64::MAX);
        let h = reg.register(Matrix::random(13, 29, 1)).unwrap();
        assert_eq!(reg.dims(h), Some((13, 29)));
        assert_eq!(reg.registered_weights(), 1);

        let p1 = reg.resolve_pack(h, 16).unwrap();
        assert_eq!((m.registry_hits(), m.registry_misses()), (0, 1));
        assert_eq!(m.b_panel_packs(), 1, "a miss is one whole-operand pack");
        let p2 = reg.resolve_pack(h, 16).unwrap();
        assert!(Arc::ptr_eq(&p1, &p2), "a hit returns the cached pack");
        assert_eq!((m.registry_hits(), m.registry_misses()), (1, 1));
        assert_eq!(m.b_panel_packs(), 1, "hits never repack");

        // A different block size is a per-shape variant: one more miss,
        // cached under its own (handle, sj) key.
        let p3 = reg.resolve_pack(h, 8).unwrap();
        assert!(!Arc::ptr_eq(&p1, &p3));
        assert_eq!((m.registry_hits(), m.registry_misses()), (1, 2));
        assert_eq!(m.b_panel_packs(), 2);
        assert_eq!(m.registry_resident_bytes(), reg.resident_bytes());
        assert!(reg.resident_bytes() > 0);
    }

    #[test]
    fn resolved_pack_is_bit_identical_to_private_pack() {
        let (reg, _) = registry(u64::MAX);
        let b = Matrix::random(23, 37, 7);
        let h = reg.register(b.clone()).unwrap();
        let cached = reg.resolve_pack(h, 12).unwrap();
        let private = PackedB::pack(b.view(), 12);
        assert_eq!(cached.num_panels(), private.num_panels());
        for bj in 0..private.num_panels() {
            assert_eq!(cached.panel(bj), private.panel(bj));
        }
    }

    #[test]
    fn lru_eviction_respects_budget_and_order() {
        // Budget fits exactly one of the two packs; resolving the
        // second must evict the first (older stamp), and re-resolving
        // the first is a miss again (repacked from the retained matrix,
        // never an error).
        let (reg, m) = registry(1);
        let h1 = reg.register(Matrix::random(8, 8, 1)).unwrap();
        let h2 = reg.register(Matrix::random(8, 8, 2)).unwrap();
        let p1 = reg.resolve_pack(h1, 8).unwrap();
        drop(p1); // unpin
        let p2 = reg.resolve_pack(h2, 8).unwrap();
        assert_eq!(m.registry_evictions(), 1, "older pack evicted");
        drop(p2);
        let _p1_again = reg.resolve_pack(h1, 8).unwrap();
        assert_eq!(m.registry_misses(), 3, "evicted pack resolves as a fresh miss");
        assert_eq!(m.registry_evictions(), 2);
        assert_eq!(m.registry_hits(), 0);
    }

    #[test]
    fn inflight_pack_is_pinned_against_eviction() {
        // The refcount pin: a pack whose Arc is held outside the
        // registry (an in-flight job) survives eviction even when the
        // budget is blown; the registry overshoots instead.
        let (reg, m) = registry(1);
        let h1 = reg.register(Matrix::random(8, 8, 1)).unwrap();
        let h2 = reg.register(Matrix::random(8, 8, 2)).unwrap();
        let pinned = reg.resolve_pack(h1, 8).unwrap(); // held: strong_count 2
        let bytes_one = reg.resident_bytes();
        let also_pinned = reg.resolve_pack(h2, 8).unwrap();
        assert_eq!(m.registry_evictions(), 0, "both packs pinned, none evictable");
        assert_eq!(reg.resident_bytes(), 2 * bytes_one, "budget transiently exceeded");
        // Releasing the pins makes them evictable on the next pressure.
        drop(pinned);
        drop(also_pinned);
        let h3 = reg.register(Matrix::random(8, 8, 3)).unwrap();
        let _p3 = reg.resolve_pack(h3, 8).unwrap();
        assert!(m.registry_evictions() >= 2, "released packs evicted under pressure");
        assert_eq!(reg.resident_bytes(), bytes_one, "only the fresh pinned pack remains");
    }

    #[test]
    fn unregister_frees_and_invalidates() {
        let (reg, m) = registry(u64::MAX);
        let h = reg.register(Matrix::random(8, 8, 1)).unwrap();
        let held = reg.resolve_pack(h, 8).unwrap();
        assert!(reg.resident_bytes() > 0);
        reg.unregister(h).unwrap();
        assert_eq!(reg.resident_bytes(), 0);
        assert_eq!(m.registry_resident_bytes(), 0);
        assert_eq!(reg.registered_weights(), 0);
        assert!(reg.dims(h).is_none());
        assert!(reg.matrix(h).is_none());
        assert!(reg.resolve_pack(h, 8).is_err(), "handle dead after unregister");
        assert!(reg.unregister(h).is_err(), "double unregister is an error");
        // The in-flight clone stays valid — unregistering never yanks
        // data out from under running work.
        assert!(held.num_panels() > 0);
    }

    #[test]
    fn degenerate_register_rejected() {
        let (reg, _) = registry(u64::MAX);
        assert!(reg.register(Matrix::zeros(0, 4)).is_err());
        assert!(reg.register(Matrix::zeros(4, 0)).is_err());
    }

    #[test]
    fn boperand_conversions() {
        let m = Matrix::random(3, 4, 9);
        let inline: BOperand = m.clone().into();
        assert_eq!(inline.inline_dims(), Some((3, 4)));
        assert!(inline.handle().is_none());
        assert_eq!(inline.into_inline().unwrap().data, m.data);
        let h = WeightHandle { registry: 0, id: 42 };
        let reg: BOperand = h.into();
        assert!(reg.inline_dims().is_none());
        assert!(reg.as_inline().is_none());
        assert_eq!(reg.handle(), Some(h));
        assert_eq!(h.to_string(), "weight#42");
    }

    #[test]
    fn foreign_handle_never_resolves() {
        // A handle minted by one registry must be an error — not a
        // lookup into same-numbered state — on any other registry.
        let (r1, _) = registry(u64::MAX);
        let (r2, _) = registry(u64::MAX);
        let h1 = r1.register(Matrix::random(4, 4, 1)).unwrap();
        let h2 = r2.register(Matrix::random(6, 6, 2)).unwrap();
        assert_eq!((h1.id(), h2.id()), (0, 0), "same raw id, different registries");
        assert_ne!(h1, h2, "nonce distinguishes the handles");
        assert!(r2.dims(h1).is_none());
        assert!(r2.matrix(h1).is_none());
        assert!(r2.resolve_pack(h1, 8).is_err());
        assert!(r2.unregister(h1).is_err());
        assert_eq!(r2.registered_weights(), 1, "foreign unregister must not evict");
        assert!(r1.resolve_pack(h1, 8).is_ok());
    }
}
