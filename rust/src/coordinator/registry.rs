//! Server-resident packed-operand registry: register an operand once,
//! never repack it across calls — on **either** side of the GEMM.
//!
//! PR 4's shared-B batches made a packed B shareable *within* one
//! [`super::Submission::batched`] call; successive batches,
//! epochs, and layers that reuse the same weight still repacked it per
//! call. Inference servers solve this with an explicit model-load step
//! — weights are stationary state, activations are traffic — and the
//! related multi-array literature (Strassen Multisystolic Arrays,
//! ArrayFlex) likewise preloads stationary operands. [`OperandRegistry`]
//! is that model-load step for this serving runtime. PR 6 makes it
//! symmetric: attention-style traffic multiplies one *activation* batch
//! against several weight sets (Q/K/V/O), so the A side reuses panels
//! just as heavily as B does.
//!
//! * [`super::JobServer::register_b`] stores a weight once behind an
//!   `Arc<Matrix>` and returns an opaque [`WeightHandle`];
//!   [`super::JobServer::register_a`] does the same for an activation
//!   and returns an [`ActivationHandle`];
//! * submissions carry a [`BOperand`] / [`AOperand`] —
//!   `Inline(Matrix)` keeps the old per-call semantics, `Registered(_)`
//!   resolves inside the dispatcher to the cached [`PackedB`] /
//!   [`PackedA`];
//! * the pack cache is side-tagged and keyed by `(handle, side,
//!   s_param, dtype)`: a handle resolved under one block size (`S_j`
//!   for B, `S_i` for A) and precision reuses its pack on every later
//!   call (a *hit*), while a different block size or serving dtype
//!   re-derives a per-shape/per-precision variant once (a *miss* that
//!   packs and caches). The one-pack guarantee therefore holds
//!   **across** calls, not just within one, and one registered weight
//!   serves jobs at several precisions without repacking churn;
//! * both sides share one byte budget and one refcount-pinned LRU
//!   (`ServerConfig::registry_budget_bytes`): least-recently-used packs
//!   of either side leave first, but a pack still referenced outside
//!   the registry (an in-flight job holds its `Arc`) is pinned and
//!   survives — the registry may transiently exceed its budget rather
//!   than invalidate live work. Evicting a pack never invalidates its
//!   handle: the next resolution repacks from the retained matrix (a
//!   miss, not an error).
//!
//! Hit/miss/evict counters are shared across sides (the A-side share is
//! additionally split out as `registry_a_*`), and resident-bytes gauges
//! — total and A-side — land in [`Metrics`] next to `panels_shared`,
//! so the cross-call win is as observable as PR 4's within-call
//! sharing.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::gemm::{CombineOp, Dtype, Matrix, MatrixView, PackedA, PackedB};

use super::frontend::TenantId;
use super::metrics::Metrics;
use super::trace::{EventKind, TraceRing, ACTOR_NONE};

/// Process-unique registry ids, so a handle minted by one server can
/// never silently resolve against another server's registry.
static NEXT_REGISTRY_NONCE: AtomicU64 = AtomicU64::new(1);

/// Opaque, copyable handle to a registered B operand. Obtained from
/// [`super::JobServer::register_b`]; valid until the matching
/// `unregister_b`. Submitting an unknown, unregistered, or
/// foreign-server handle fails that job through its ticket, never the
/// server — the handle carries its registry's nonce, so crossing two
/// servers' handles is an error, not silently wrong numerics.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct WeightHandle {
    registry: u64,
    id: u64,
}

impl WeightHandle {
    /// The raw per-registry id (diagnostics / logging).
    pub fn id(&self) -> u64 {
        self.id
    }
}

impl std::fmt::Display for WeightHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "weight#{}", self.id)
    }
}

/// Opaque, copyable handle to a registered A operand (an activation
/// batch member). Obtained from [`super::JobServer::register_a`]; valid
/// until the matching `unregister_a`. Same nonce discipline as
/// [`WeightHandle`]: a foreign handle is an error, never a silent
/// lookup into same-numbered state.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ActivationHandle {
    registry: u64,
    id: u64,
}

impl ActivationHandle {
    /// The raw per-registry id (diagnostics / logging).
    pub fn id(&self) -> u64 {
        self.id
    }
}

impl std::fmt::Display for ActivationHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "act#{}", self.id)
    }
}

/// One window of a shared parent matrix that a [`FusedOperand`] reads —
/// the parent is refcounted so the submission can outlive the caller's
/// stack frame (Strassen's async leaf groups hold these across `wait`).
#[derive(Debug, Clone)]
pub struct FusedSource {
    /// The matrix the window reads from.
    pub parent: Arc<Matrix>,
    /// Window origin (row, col) inside `parent`.
    pub row0: usize,
    pub col0: usize,
}

impl FusedSource {
    /// A window covering all of `parent`.
    pub fn whole(parent: Arc<Matrix>) -> Self {
        Self { parent, row0: 0, col0: 0 }
    }

    /// The `rows x cols` view at this source's origin. Caller guarantees
    /// bounds (checked by [`FusedOperand::validate`]).
    fn view(&self, rows: usize, cols: usize) -> MatrixView<'_> {
        self.parent.view().block(self.row0, self.col0, rows, cols)
    }
}

/// An operand formed *during* packing as `x op y` (or a plain window
/// `x`) over one or two [`FusedSource`] windows — never materialized as
/// its own matrix. This is how Strassen ships `A11 + A22`-style quadrant
/// combinations to the server: the combine happens inside the pack
/// pass ([`PackedA::from_sum_of_views`]), cutting one full temp
/// write + read per operand.
#[derive(Debug, Clone)]
pub struct FusedOperand {
    /// Operand shape (both windows must hold a full `rows x cols`).
    pub rows: usize,
    pub cols: usize,
    pub x: FusedSource,
    /// Second window and the op combining it with `x`; `None` packs `x`
    /// alone (a fused copy — no temp either).
    pub y: Option<(FusedSource, CombineOp)>,
}

impl FusedOperand {
    /// A single-window fused operand (`rows x cols` at `x`'s origin).
    pub fn single(rows: usize, cols: usize, x: FusedSource) -> Self {
        Self { rows, cols, x, y: None }
    }

    /// A two-window combination `x op y`.
    pub fn combine(rows: usize, cols: usize, x: FusedSource, y: FusedSource, op: CombineOp) -> Self {
        Self { rows, cols, x, y: Some((y, op)) }
    }

    /// Both windows fit their parents. Explicit because
    /// [`MatrixView::block`] clips silently — an out-of-bounds fused
    /// operand must fail the job, not shrink it.
    pub fn validate(&self) -> anyhow::Result<()> {
        let fits = |s: &FusedSource| {
            s.row0 + self.rows <= s.parent.rows && s.col0 + self.cols <= s.parent.cols
        };
        anyhow::ensure!(
            fits(&self.x),
            "fused operand window {}x{} at ({}, {}) exceeds its {}x{} parent",
            self.rows,
            self.cols,
            self.x.row0,
            self.x.col0,
            self.x.parent.rows,
            self.x.parent.cols
        );
        if let Some((y, _)) = &self.y {
            anyhow::ensure!(
                y.row0 + self.rows <= y.parent.rows && y.col0 + self.cols <= y.parent.cols,
                "fused operand window {}x{} at ({}, {}) exceeds its {}x{} parent",
                self.rows,
                self.cols,
                y.row0,
                y.col0,
                y.parent.rows,
                y.parent.cols
            );
        }
        Ok(())
    }

    /// Pack as an A operand at block size `si` — combine fused into the
    /// pack pass, bit-identical to materialize-then-pack.
    pub fn pack_a(&self, si: usize) -> PackedA {
        let y = self.y.as_ref().map(|(s, op)| (s.view(self.rows, self.cols), *op));
        PackedA::from_sum_of_views(self.x.view(self.rows, self.cols), y, si)
    }

    /// [`FusedOperand::pack_a`] at a serving precision: the combine
    /// happens in f32, the converted panels land in `dtype`'s store.
    pub fn pack_a_dtype(&self, si: usize, dtype: Dtype) -> PackedA {
        let y = self.y.as_ref().map(|(s, op)| (s.view(self.rows, self.cols), *op));
        PackedA::from_sum_of_views_dtype(self.x.view(self.rows, self.cols), y, si, dtype)
    }

    /// Pack as a B operand at block size `sj`.
    pub fn pack_b(&self, sj: usize) -> PackedB {
        let y = self.y.as_ref().map(|(s, op)| (s.view(self.rows, self.cols), *op));
        PackedB::from_sum_of_views(self.x.view(self.rows, self.cols), y, sj)
    }

    /// [`FusedOperand::pack_b`] at a serving precision.
    pub fn pack_b_dtype(&self, sj: usize, dtype: Dtype) -> PackedB {
        let y = self.y.as_ref().map(|(s, op)| (s.view(self.rows, self.cols), *op));
        PackedB::from_sum_of_views_dtype(self.x.view(self.rows, self.cols), y, sj, dtype)
    }

    /// Materialize the combined operand as its own matrix — the
    /// fallback for backends that need a contiguous operand (PJRT
    /// gather path). Same per-element expression as the fused packers.
    pub fn materialize(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        let xv = self.x.view(self.rows, self.cols);
        match &self.y {
            None => crate::gemm::ops::copy_into(xv, &mut m.view_mut()),
            Some((ys, op)) => {
                let yv = ys.view(self.rows, self.cols);
                match op {
                    CombineOp::Add => crate::gemm::ops::add_into(xv, yv, &mut m.view_mut()),
                    CombineOp::Sub => crate::gemm::ops::sub_into(xv, yv, &mut m.view_mut()),
                }
            }
        }
        m
    }
}

/// One side of a submission, generic over its handle type: a one-shot
/// inline matrix (packed per call, exactly the pre-registry behavior),
/// a registered operand resolved from the server's [`OperandRegistry`],
/// or a fused view-combination packed on the fly. The two sides are the
/// instantiations [`BOperand`] (`H = WeightHandle`, pack cached per
/// `(handle, S_j)`) and [`AOperand`] (`H = ActivationHandle`, cached
/// per `(handle, S_i)`) — one conversion path, one accessor surface,
/// no per-side duplication.
#[derive(Debug, Clone)]
pub enum Operand<H> {
    /// Caller-owned operand; packed once for this call.
    Inline(Matrix),
    /// Server-resident operand; packed at most once per
    /// `(handle, block size)` for the whole process.
    Registered(H),
    /// `x op y` over windows of shared parents, combined inside the
    /// pack pass — never materialized on the in-process path.
    Fused(FusedOperand),
}

/// The B side of a submission: inline, or a registered weight.
pub type BOperand = Operand<WeightHandle>;

/// The A side of a submission: inline, or a registered activation.
pub type AOperand = Operand<ActivationHandle>;

impl<H: Copy> Operand<H> {
    /// `(rows, cols)` when the operand is inline; `None` for a handle
    /// (its dims live in the server's registry) **and** for a fused
    /// operand — callers that demand an inline matrix
    /// (`Coordinator::plan_job`) must reject both.
    pub fn inline_dims(&self) -> Option<(usize, usize)> {
        match self {
            Operand::Inline(m) => Some((m.rows, m.cols)),
            Operand::Registered(_) | Operand::Fused(_) => None,
        }
    }

    /// Borrow the inline matrix, if any.
    pub fn as_inline(&self) -> Option<&Matrix> {
        match self {
            Operand::Inline(m) => Some(m),
            Operand::Registered(_) | Operand::Fused(_) => None,
        }
    }

    /// Take the inline matrix back out, if any.
    pub fn into_inline(self) -> Option<Matrix> {
        match self {
            Operand::Inline(m) => Some(m),
            Operand::Registered(_) | Operand::Fused(_) => None,
        }
    }

    /// The registered handle, if any.
    pub fn handle(&self) -> Option<H> {
        match self {
            Operand::Registered(h) => Some(*h),
            Operand::Inline(_) | Operand::Fused(_) => None,
        }
    }

    /// Bytes this operand charges against per-tenant byte quotas: the
    /// caller-supplied payload. Inline bills its matrix, fused bills
    /// the combined window it will pack (its parents are shared with
    /// sibling operands — billing windows rather than parents avoids
    /// multi-counting one quadrant 7x); registered operands are billed
    /// to the registry budget instead.
    pub fn quota_bytes(&self) -> usize {
        match self {
            Operand::Inline(m) => 4 * m.rows * m.cols,
            Operand::Fused(f) => 4 * f.rows * f.cols,
            Operand::Registered(_) => 0,
        }
    }
}

impl<H> From<Matrix> for Operand<H> {
    fn from(m: Matrix) -> Self {
        Operand::Inline(m)
    }
}

impl From<WeightHandle> for BOperand {
    fn from(h: WeightHandle) -> Self {
        Operand::Registered(h)
    }
}

impl From<ActivationHandle> for AOperand {
    fn from(h: ActivationHandle) -> Self {
        Operand::Registered(h)
    }
}

/// Which GEMM operand an entry (and its packs) belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Side {
    A,
    B,
}

/// One cached pack variant of a registered operand — the side tag
/// lives in the pack itself, so one LRU walks both sides.
enum AnyPack {
    A(Arc<PackedA>),
    B(Arc<PackedB>),
}

impl AnyPack {
    /// Outstanding references to the underlying pack — `1` means only
    /// the registry holds it (evictable), more means an in-flight job
    /// pins it.
    fn strong_count(&self) -> usize {
        match self {
            AnyPack::A(p) => Arc::strong_count(p),
            AnyPack::B(p) => Arc::strong_count(p),
        }
    }
}

struct PackSlot {
    pack: AnyPack,
    bytes: u64,
    /// Logical LRU timestamp; bumped on every hit.
    stamp: u64,
}

/// One registered operand: the retained matrix, its side, the tenant
/// that registered it, and its per-block-size, per-precision pack
/// variants (`(sj, dtype)` keys for B entries, `(si, dtype)` for A).
struct Entry {
    matrix: Arc<Matrix>,
    side: Side,
    /// The tenant this operand is billed to ([`TenantId::DEFAULT`] for
    /// the tenant-unaware `register_a`/`register_b` paths).
    tenant: TenantId,
    packs: HashMap<(usize, Dtype), PackSlot>,
}

struct State {
    entries: HashMap<u64, Entry>,
    /// Shared id space across sides — an A handle's id never collides
    /// with a B entry's.
    next_handle: u64,
    /// LRU clock; bumped on every resolution, shared by both sides.
    clock: u64,
    /// Bytes of packed data currently held by the registry (cached
    /// packs of both sides — retained matrices and in-flight clones the
    /// registry no longer holds are not counted).
    resident_bytes: u64,
    /// The A-side share of `resident_bytes`.
    a_resident_bytes: u64,
    /// The per-precision split of `resident_bytes`, indexed by
    /// [`Dtype::index`] — sums to `resident_bytes` across dtypes.
    dtype_resident_bytes: [u64; Dtype::ALL.len()],
}

/// One tenant's registry footprint (see
/// [`OperandRegistry::tenant_residency`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TenantResidency {
    /// Live registered operands (both sides) billed to this tenant.
    pub operands: usize,
    /// Bytes of cached packs across those operands.
    pub resident_bytes: u64,
    /// The subset of `resident_bytes` pinned by in-flight jobs.
    pub pinned_bytes: u64,
}

/// The server-resident operand cache. Owned by the `JobServer`'s shared
/// state; clients reach it through `register_a` / `register_b` (and the
/// matching unregisters), the dispatcher through
/// [`OperandRegistry::resolve_pack`] / [`OperandRegistry::resolve_pack_a`].
pub struct OperandRegistry {
    nonce: u64,
    budget_bytes: u64,
    metrics: Arc<Metrics>,
    /// Flight recorder (disabled rings record nothing); hit / miss /
    /// evict events carry the handle id, pack bytes, and side.
    trace: Arc<TraceRing>,
    state: Mutex<State>,
}

/// `TraceEvent.b` payload for registry events: the side in bit 0, the
/// pack's [`Dtype::index`] in the bits above it. F32 has index 0, so
/// f32 traffic emits exactly the pre-multi-precision payloads (0 for
/// A, 1 for B).
fn event_payload(side: Side, dtype: Dtype) -> u64 {
    let side_code = match side {
        Side::A => 0,
        Side::B => 1,
    };
    side_code | ((dtype.index() as u64) << 1)
}

impl OperandRegistry {
    pub(crate) fn new(budget_bytes: u64, metrics: Arc<Metrics>, trace: Arc<TraceRing>) -> Self {
        Self {
            nonce: NEXT_REGISTRY_NONCE.fetch_add(1, Ordering::Relaxed),
            budget_bytes,
            metrics,
            trace,
            state: Mutex::new(State {
                entries: HashMap::new(),
                next_handle: 0,
                clock: 0,
                resident_bytes: 0,
                a_resident_bytes: 0,
                dtype_resident_bytes: [0; Dtype::ALL.len()],
            }),
        }
    }

    /// The entry key for `h`, or `None` for a handle minted by a
    /// different registry (another server's handle must never resolve
    /// here — it would be silently wrong numerics, not a cache miss).
    fn key(&self, h: WeightHandle) -> Option<u64> {
        (h.registry == self.nonce).then_some(h.id)
    }

    /// [`OperandRegistry::key`], A side.
    fn key_a(&self, h: ActivationHandle) -> Option<u64> {
        (h.registry == self.nonce).then_some(h.id)
    }

    fn register_side(&self, m: Matrix, side: Side, tenant: TenantId) -> anyhow::Result<u64> {
        anyhow::ensure!(
            m.rows > 0 && m.cols > 0,
            "cannot register degenerate operand {}x{}",
            m.rows,
            m.cols
        );
        let mut st = self.state.lock().unwrap();
        let id = st.next_handle;
        st.next_handle += 1;
        st.entries
            .insert(id, Entry { matrix: Arc::new(m), side, tenant, packs: HashMap::new() });
        Ok(id)
    }

    /// Register one B operand; packing is lazy (first resolution per
    /// block size), so the handle is cheap to create and never packs at
    /// a block size no job asks for. Billed to [`TenantId::DEFAULT`];
    /// see [`OperandRegistry::register_for`].
    pub fn register(&self, b: Matrix) -> anyhow::Result<WeightHandle> {
        self.register_for(b, TenantId::DEFAULT)
    }

    /// [`OperandRegistry::register`] billed to a specific tenant, so
    /// [`OperandRegistry::tenant_residency`] can attribute resident and
    /// pinned pack bytes to whoever registered the operand.
    pub fn register_for(&self, b: Matrix, tenant: TenantId) -> anyhow::Result<WeightHandle> {
        let id = self.register_side(b, Side::B, tenant)?;
        Ok(WeightHandle { registry: self.nonce, id })
    }

    /// Register one A operand (same lazy-packing contract as
    /// [`OperandRegistry::register`], keyed by `S_i` instead of `S_j`).
    pub fn register_a(&self, a: Matrix) -> anyhow::Result<ActivationHandle> {
        self.register_a_for(a, TenantId::DEFAULT)
    }

    /// [`OperandRegistry::register_a`] billed to a specific tenant.
    pub fn register_a_for(&self, a: Matrix, tenant: TenantId) -> anyhow::Result<ActivationHandle> {
        let id = self.register_side(a, Side::A, tenant)?;
        Ok(ActivationHandle { registry: self.nonce, id })
    }

    fn unregister_key(&self, key: u64, side: Side, label: &dyn std::fmt::Display) -> anyhow::Result<()> {
        let mut st = self.state.lock().unwrap();
        match st.entries.get(&key) {
            Some(e) if e.side == side => {}
            _ => anyhow::bail!("{label} is not registered (double unregister?)"),
        }
        let entry = st.entries.remove(&key).unwrap();
        let freed: u64 = entry.packs.values().map(|s| s.bytes).sum();
        st.resident_bytes -= freed;
        if side == Side::A {
            st.a_resident_bytes -= freed;
        }
        for (&(_, dtype), slot) in &entry.packs {
            st.dtype_resident_bytes[dtype.index()] -= slot.bytes;
        }
        self.publish_gauges(&st);
        Ok(())
    }

    /// Push the resident-bytes ledger (total, A-side, per-dtype) into
    /// the metrics gauges. Called with the state lock held.
    fn publish_gauges(&self, st: &State) {
        self.metrics.set_registry_resident_bytes(st.resident_bytes);
        self.metrics.set_registry_a_resident_bytes(st.a_resident_bytes);
        for (i, &bytes) in st.dtype_resident_bytes.iter().enumerate() {
            self.metrics.set_registry_dtype_resident_bytes(i, bytes);
        }
    }

    /// Drop a registered weight and its cached packs. In-flight jobs
    /// keep their `Arc` clones, so running work is unaffected; later
    /// submissions under this handle fail through their tickets.
    pub fn unregister(&self, h: WeightHandle) -> anyhow::Result<()> {
        let key = self
            .key(h)
            .ok_or_else(|| anyhow::anyhow!("{h} belongs to a different server's registry"))?;
        self.unregister_key(key, Side::B, &h)
    }

    /// [`OperandRegistry::unregister`], A side.
    pub fn unregister_a(&self, h: ActivationHandle) -> anyhow::Result<()> {
        let key = self
            .key_a(h)
            .ok_or_else(|| anyhow::anyhow!("{h} belongs to a different server's registry"))?;
        self.unregister_key(key, Side::A, &h)
    }

    fn dims_key(&self, key: u64, side: Side) -> Option<(usize, usize)> {
        let st = self.state.lock().unwrap();
        st.entries
            .get(&key)
            .filter(|e| e.side == side)
            .map(|e| (e.matrix.rows, e.matrix.cols))
    }

    /// `(rows, cols)` of a registered weight; `None` once unregistered
    /// (or for another registry's handle).
    pub fn dims(&self, h: WeightHandle) -> Option<(usize, usize)> {
        self.dims_key(self.key(h)?, Side::B)
    }

    /// [`OperandRegistry::dims`], A side.
    pub fn dims_a(&self, h: ActivationHandle) -> Option<(usize, usize)> {
        self.dims_key(self.key_a(h)?, Side::A)
    }

    fn matrix_key(&self, key: u64, side: Side) -> Option<Arc<Matrix>> {
        let st = self.state.lock().unwrap();
        st.entries.get(&key).filter(|e| e.side == side).map(|e| e.matrix.clone())
    }

    /// The retained weight matrix; `None` once unregistered (or for
    /// another registry's handle).
    pub fn matrix(&self, h: WeightHandle) -> Option<Arc<Matrix>> {
        self.matrix_key(self.key(h)?, Side::B)
    }

    /// [`OperandRegistry::matrix`], A side.
    pub fn matrix_a(&self, h: ActivationHandle) -> Option<Arc<Matrix>> {
        self.matrix_key(self.key_a(h)?, Side::A)
    }

    /// Resolve the packed form of `h` at block size `sj` (f32, the
    /// pre-multi-precision behavior): a cached variant is a **hit**;
    /// otherwise pack once (off the lock), cache the result, and evict
    /// LRU-unpinned packs past the byte budget. The returned `Arc` pins
    /// its pack against eviction for as long as the caller (an
    /// in-flight job) holds it.
    pub fn resolve_pack(&self, h: WeightHandle, sj: usize) -> anyhow::Result<Arc<PackedB>> {
        self.resolve_pack_dtype(h, sj, Dtype::F32)
    }

    /// [`OperandRegistry::resolve_pack`] at a serving precision: the
    /// cache key is `(handle, sj, dtype)`, so one registered weight
    /// serves jobs at several precisions, each packed at most once per
    /// block size.
    pub fn resolve_pack_dtype(
        &self,
        h: WeightHandle,
        sj: usize,
        dtype: Dtype,
    ) -> anyhow::Result<Arc<PackedB>> {
        let key = self
            .key(h)
            .ok_or_else(|| anyhow::anyhow!("{h} belongs to a different server's registry"))?;
        let (matrix, tenant) = {
            let mut st = self.state.lock().unwrap();
            st.clock += 1;
            let clock = st.clock;
            let entry = st
                .entries
                .get_mut(&key)
                .filter(|e| e.side == Side::B)
                .ok_or_else(|| anyhow::anyhow!("{h} is not registered"))?;
            if let Some(slot) = entry.packs.get_mut(&(sj, dtype)) {
                slot.stamp = clock;
                self.metrics.add_registry_hits(1);
                let tenant = entry.tenant.0;
                let bytes = slot.bytes;
                match &slot.pack {
                    AnyPack::B(p) => {
                        let p = p.clone();
                        drop(st);
                        self.trace.emit(
                            EventKind::RegistryHit,
                            key,
                            tenant,
                            ACTOR_NONE,
                            bytes,
                            event_payload(Side::B, dtype),
                        );
                        return Ok(p);
                    }
                    AnyPack::A(_) => unreachable!("B entry holds an A pack"),
                }
            }
            (entry.matrix.clone(), entry.tenant.0)
        };
        // Miss: pack outside the lock (packing a large weight must not
        // stall concurrent register/stats calls), then publish. A
        // concurrent unregister simply skips the caching, and a
        // concurrent resolver that won the same-(handle, sj, dtype)
        // race has its slot replaced — with its bytes returned to the
        // ledger, so resident accounting survives the race exactly.
        self.metrics.add_registry_misses(1);
        self.metrics.add_b_panel_packs(1);
        let pack = Arc::new(PackedB::pack_dtype(matrix.view(), sj, dtype));
        let bytes = pack.packed_bytes();
        self.trace.emit(
            EventKind::RegistryMiss,
            key,
            tenant,
            ACTOR_NONE,
            bytes,
            event_payload(Side::B, dtype),
        );
        self.publish(key, (sj, dtype), AnyPack::B(pack.clone()), bytes, Side::B);
        Ok(pack)
    }

    /// [`OperandRegistry::resolve_pack`], A side: the cache key is the
    /// row block size `S_i` and the cached unit is an `Arc<PackedA>`.
    pub fn resolve_pack_a(&self, h: ActivationHandle, si: usize) -> anyhow::Result<Arc<PackedA>> {
        self.resolve_pack_a_dtype(h, si, Dtype::F32)
    }

    /// [`OperandRegistry::resolve_pack_dtype`], A side.
    pub fn resolve_pack_a_dtype(
        &self,
        h: ActivationHandle,
        si: usize,
        dtype: Dtype,
    ) -> anyhow::Result<Arc<PackedA>> {
        let key = self
            .key_a(h)
            .ok_or_else(|| anyhow::anyhow!("{h} belongs to a different server's registry"))?;
        let (matrix, tenant) = {
            let mut st = self.state.lock().unwrap();
            st.clock += 1;
            let clock = st.clock;
            let entry = st
                .entries
                .get_mut(&key)
                .filter(|e| e.side == Side::A)
                .ok_or_else(|| anyhow::anyhow!("{h} is not registered"))?;
            if let Some(slot) = entry.packs.get_mut(&(si, dtype)) {
                slot.stamp = clock;
                self.metrics.add_registry_hits(1);
                self.metrics.add_registry_a_hits(1);
                let tenant = entry.tenant.0;
                let bytes = slot.bytes;
                match &slot.pack {
                    AnyPack::A(p) => {
                        let p = p.clone();
                        drop(st);
                        self.trace.emit(
                            EventKind::RegistryHit,
                            key,
                            tenant,
                            ACTOR_NONE,
                            bytes,
                            event_payload(Side::A, dtype),
                        );
                        return Ok(p);
                    }
                    AnyPack::B(_) => unreachable!("A entry holds a B pack"),
                }
            }
            (entry.matrix.clone(), entry.tenant.0)
        };
        self.metrics.add_registry_misses(1);
        self.metrics.add_registry_a_misses(1);
        self.metrics.add_a_panel_packs(1);
        let pack = Arc::new(PackedA::pack_dtype(matrix.view(), si, dtype));
        let bytes = pack.packed_bytes();
        self.trace.emit(
            EventKind::RegistryMiss,
            key,
            tenant,
            ACTOR_NONE,
            bytes,
            event_payload(Side::A, dtype),
        );
        self.publish(key, (si, dtype), AnyPack::A(pack.clone()), bytes, Side::A);
        Ok(pack)
    }

    /// Publish a freshly packed variant into the cache, settle the byte
    /// ledger (replacement race included), and run eviction.
    fn publish(&self, key: u64, slot_key: (usize, Dtype), pack: AnyPack, bytes: u64, side: Side) {
        let mut st = self.state.lock().unwrap();
        st.clock += 1;
        let stamp = st.clock;
        let dtype = slot_key.1;
        if let Some(entry) = st.entries.get_mut(&key) {
            if let Some(old) = entry.packs.insert(slot_key, PackSlot { pack, bytes, stamp }) {
                st.resident_bytes -= old.bytes;
                st.dtype_resident_bytes[dtype.index()] -= old.bytes;
                if side == Side::A {
                    st.a_resident_bytes -= old.bytes;
                }
            }
            st.resident_bytes += bytes;
            st.dtype_resident_bytes[dtype.index()] += bytes;
            if side == Side::A {
                st.a_resident_bytes += bytes;
            }
            self.evict_lru(&mut st);
            self.publish_gauges(&st);
        }
    }

    /// Evict least-recently-used packs — of either side, one shared LRU
    /// — until the budget holds, skipping pinned ones (`Arc` held
    /// outside the registry — an in-flight job). With everything pinned
    /// the registry overshoots its budget transiently instead of
    /// invalidating live work.
    fn evict_lru(&self, st: &mut State) {
        while st.resident_bytes > self.budget_bytes {
            let victim = st
                .entries
                .iter()
                .flat_map(|(id, e)| {
                    e.packs
                        .iter()
                        .filter(|(_, slot)| slot.pack.strong_count() == 1)
                        .map(move |(slot_key, slot)| (slot.stamp, *id, *slot_key, e.side))
                })
                .min_by_key(|(stamp, id, (s_param, dtype), _)| {
                    (*stamp, *id, *s_param, dtype.index())
                });
            let Some((_, id, slot_key, side)) = victim else { break };
            let entry = st.entries.get_mut(&id).expect("victim entry vanished under the lock");
            let tenant = entry.tenant.0;
            let slot = entry.packs.remove(&slot_key).expect("victim slot vanished under the lock");
            st.resident_bytes -= slot.bytes;
            st.dtype_resident_bytes[slot_key.1.index()] -= slot.bytes;
            self.metrics.add_registry_evictions(1);
            if side == Side::A {
                st.a_resident_bytes -= slot.bytes;
                self.metrics.add_registry_a_evictions(1);
            }
            self.trace.emit(
                EventKind::RegistryEvict,
                id,
                tenant,
                ACTOR_NONE,
                slot.bytes,
                event_payload(side, slot_key.1),
            );
        }
    }

    /// The `S_j` variants of `h` currently resident at f32 (sorted).
    /// Racy by nature — a variant can be evicted between this call and
    /// the next resolution — so callers (the registry-aware planner)
    /// treat it as a hint, never a guarantee.
    pub fn resident_b_sjs(&self, h: WeightHandle) -> Vec<usize> {
        self.resident_b_sjs_dtype(h, Dtype::F32)
    }

    /// [`OperandRegistry::resident_b_sjs`] at a serving precision.
    pub fn resident_b_sjs_dtype(&self, h: WeightHandle, dtype: Dtype) -> Vec<usize> {
        let Some(key) = self.key(h) else { return Vec::new() };
        let st = self.state.lock().unwrap();
        let mut sjs: Vec<usize> = st
            .entries
            .get(&key)
            .filter(|e| e.side == Side::B)
            .map(|e| e.packs.keys().filter(|(_, d)| *d == dtype).map(|(s, _)| *s).collect())
            .unwrap_or_default();
        sjs.sort_unstable();
        sjs
    }

    /// [`OperandRegistry::resident_b_sjs`], A side: resident `S_i`
    /// variants at f32.
    pub fn resident_a_sis(&self, h: ActivationHandle) -> Vec<usize> {
        self.resident_a_sis_dtype(h, Dtype::F32)
    }

    /// [`OperandRegistry::resident_a_sis`] at a serving precision.
    pub fn resident_a_sis_dtype(&self, h: ActivationHandle, dtype: Dtype) -> Vec<usize> {
        let Some(key) = self.key_a(h) else { return Vec::new() };
        let st = self.state.lock().unwrap();
        let mut sis: Vec<usize> = st
            .entries
            .get(&key)
            .filter(|e| e.side == Side::A)
            .map(|e| e.packs.keys().filter(|(_, d)| *d == dtype).map(|(s, _)| *s).collect())
            .unwrap_or_default();
        sis.sort_unstable();
        sis
    }

    /// Registered B operands currently alive.
    pub fn registered_weights(&self) -> usize {
        let st = self.state.lock().unwrap();
        st.entries.values().filter(|e| e.side == Side::B).count()
    }

    /// Registered A operands currently alive.
    pub fn registered_activations(&self) -> usize {
        let st = self.state.lock().unwrap();
        st.entries.values().filter(|e| e.side == Side::A).count()
    }

    /// Bytes of packed data the registry currently holds (both sides).
    pub fn resident_bytes(&self) -> u64 {
        self.state.lock().unwrap().resident_bytes
    }

    /// Per-tenant residency snapshot, ordered by `TenantId`: for each
    /// tenant that has live registered operands, `(operands, resident
    /// pack bytes, pinned pack bytes)` — pinned meaning an in-flight
    /// job still holds the pack's `Arc`, so it is immune to LRU
    /// eviction. This is the registry half of multi-tenant accounting:
    /// quotas bound a tenant's in-flight traffic, this shows what it
    /// keeps resident between calls.
    pub fn tenant_residency(&self) -> Vec<(TenantId, TenantResidency)> {
        let st = self.state.lock().unwrap();
        let mut rows: std::collections::BTreeMap<TenantId, TenantResidency> =
            std::collections::BTreeMap::new();
        for e in st.entries.values() {
            let row = rows.entry(e.tenant).or_default();
            row.operands += 1;
            for slot in e.packs.values() {
                row.resident_bytes += slot.bytes;
                if slot.pack.strong_count() > 1 {
                    row.pinned_bytes += slot.bytes;
                }
            }
        }
        rows.into_iter().collect()
    }

    /// The A-side share of [`OperandRegistry::resident_bytes`].
    pub fn a_resident_bytes(&self) -> u64 {
        self.state.lock().unwrap().a_resident_bytes
    }

    /// The share of [`OperandRegistry::resident_bytes`] held in packs
    /// of one precision — the four shares sum to the total.
    pub fn dtype_resident_bytes(&self, dtype: Dtype) -> u64 {
        self.state.lock().unwrap().dtype_resident_bytes[dtype.index()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn registry(budget: u64) -> (OperandRegistry, Arc<Metrics>) {
        let metrics = Arc::new(Metrics::default());
        (OperandRegistry::new(budget, metrics.clone(), Arc::new(TraceRing::new(0))), metrics)
    }

    fn traced_registry(budget: u64) -> (OperandRegistry, Arc<TraceRing>) {
        let ring = Arc::new(TraceRing::new(64));
        (OperandRegistry::new(budget, Arc::new(Metrics::default()), ring.clone()), ring)
    }

    #[test]
    fn registry_events_land_in_the_trace() {
        let (reg, ring) = traced_registry(1);
        let hb = reg.register_for(Matrix::random(8, 8, 1), TenantId(3)).unwrap();
        let ha = reg.register_a(Matrix::random(8, 8, 2)).unwrap();

        let pb = reg.resolve_pack(hb, 8).unwrap(); // B miss
        let pb2 = reg.resolve_pack(hb, 8).unwrap(); // B hit
        drop((pb, pb2)); // unpin → evictable
        let _pa = reg.resolve_pack_a(ha, 8).unwrap(); // A miss + evicts the B pack

        let evs = ring.snapshot().events;
        let kinds: Vec<EventKind> = evs.iter().map(|e| e.kind).collect();
        assert_eq!(
            kinds,
            vec![
                EventKind::RegistryMiss,
                EventKind::RegistryHit,
                EventKind::RegistryMiss,
                EventKind::RegistryEvict,
            ]
        );
        // B-side events carry the B handle id, side code 1, the
        // registering tenant, and the pack's byte size.
        for e in &evs[..2] {
            assert_eq!(e.uid, hb.id());
            assert_eq!(e.b, 1, "B side");
            assert_eq!(e.tenant, 3);
            assert!(e.a > 0, "pack bytes recorded");
        }
        assert_eq!(evs[2].uid, ha.id());
        assert_eq!(evs[2].b, 0, "A side");
        assert_eq!(evs[2].tenant, TenantId::DEFAULT.0);
        // The eviction victim was the (unpinned) B pack.
        assert_eq!(evs[3].uid, hb.id());
        assert_eq!(evs[3].b, 1);
        assert_eq!(evs[3].a, evs[0].a, "evicted the bytes the miss published");
    }

    #[test]
    fn register_resolve_hit_miss_counters() {
        let (reg, m) = registry(u64::MAX);
        let h = reg.register(Matrix::random(13, 29, 1)).unwrap();
        assert_eq!(reg.dims(h), Some((13, 29)));
        assert_eq!(reg.registered_weights(), 1);

        let p1 = reg.resolve_pack(h, 16).unwrap();
        assert_eq!((m.registry_hits(), m.registry_misses()), (0, 1));
        assert_eq!(m.b_panel_packs(), 1, "a miss is one whole-operand pack");
        let p2 = reg.resolve_pack(h, 16).unwrap();
        assert!(Arc::ptr_eq(&p1, &p2), "a hit returns the cached pack");
        assert_eq!((m.registry_hits(), m.registry_misses()), (1, 1));
        assert_eq!(m.b_panel_packs(), 1, "hits never repack");

        // A different block size is a per-shape variant: one more miss,
        // cached under its own (handle, sj) key.
        let p3 = reg.resolve_pack(h, 8).unwrap();
        assert!(!Arc::ptr_eq(&p1, &p3));
        assert_eq!((m.registry_hits(), m.registry_misses()), (1, 2));
        assert_eq!(m.b_panel_packs(), 2);
        assert_eq!(m.registry_resident_bytes(), reg.resident_bytes());
        assert!(reg.resident_bytes() > 0);
        assert_eq!(reg.resident_b_sjs(h), vec![8, 16]);
        // Pure-B workload: the A-side split stays at zero.
        assert_eq!((m.registry_a_hits(), m.registry_a_misses()), (0, 0));
        assert_eq!(reg.a_resident_bytes(), 0);
    }

    #[test]
    fn register_a_resolve_hit_miss_counters() {
        let (reg, m) = registry(u64::MAX);
        let h = reg.register_a(Matrix::random(29, 13, 2)).unwrap();
        assert_eq!(reg.dims_a(h), Some((29, 13)));
        assert_eq!(reg.registered_activations(), 1);
        assert_eq!(reg.registered_weights(), 0, "A entries are not weights");

        let p1 = reg.resolve_pack_a(h, 16).unwrap();
        assert_eq!((m.registry_hits(), m.registry_misses()), (0, 1), "shared counters");
        assert_eq!((m.registry_a_hits(), m.registry_a_misses()), (0, 1), "A-side split");
        assert_eq!(m.a_panel_packs(), 1, "an A miss is one whole-operand A pack");
        let p2 = reg.resolve_pack_a(h, 16).unwrap();
        assert!(Arc::ptr_eq(&p1, &p2), "a hit returns the cached pack");
        assert_eq!((m.registry_a_hits(), m.registry_a_misses()), (1, 1));
        assert_eq!(m.a_panel_packs(), 1, "hits never repack");

        let p3 = reg.resolve_pack_a(h, 8).unwrap();
        assert!(!Arc::ptr_eq(&p1, &p3));
        assert_eq!((m.registry_a_hits(), m.registry_a_misses()), (1, 2));
        assert_eq!(reg.resident_a_sis(h), vec![8, 16]);
        assert_eq!(reg.a_resident_bytes(), reg.resident_bytes(), "pure-A workload");
        assert_eq!(m.registry_a_resident_bytes(), reg.a_resident_bytes());
        assert_eq!(m.b_panel_packs(), 0, "A packs never count as B packs");
    }

    #[test]
    fn dtype_variants_cache_independently_with_one_pack_each() {
        let (reg, m) = registry(u64::MAX);
        let h = reg.register(Matrix::random(13, 29, 1)).unwrap();

        // Same handle, same block size, two precisions: exactly one
        // pack per (S, dtype) variant, hits thereafter.
        let p32 = reg.resolve_pack_dtype(h, 16, Dtype::F32).unwrap();
        let pbf = reg.resolve_pack_dtype(h, 16, Dtype::Bf16).unwrap();
        assert_eq!((m.registry_hits(), m.registry_misses()), (0, 2));
        assert_eq!(m.b_panel_packs(), 2, "one pack per (S, dtype)");
        assert_eq!(p32.dtype(), Dtype::F32);
        assert_eq!(pbf.dtype(), Dtype::Bf16);

        let p32b = reg.resolve_pack(h, 16).unwrap(); // f32 delegate
        let pbfb = reg.resolve_pack_dtype(h, 16, Dtype::Bf16).unwrap();
        assert!(Arc::ptr_eq(&p32, &p32b), "f32 delegate hits the F32 variant");
        assert!(Arc::ptr_eq(&pbf, &pbfb), "bf16 resolution hits its own variant");
        assert_eq!((m.registry_hits(), m.registry_misses()), (2, 2));
        assert_eq!(m.b_panel_packs(), 2, "hits never repack");

        // Residency hints are per-dtype...
        assert_eq!(reg.resident_b_sjs(h), vec![16]);
        assert_eq!(reg.resident_b_sjs_dtype(h, Dtype::Bf16), vec![16]);
        assert!(reg.resident_b_sjs_dtype(h, Dtype::F16).is_empty());
        // ...and so is the byte ledger: the bf16 pack of the same
        // operand is exactly half the f32 bytes (same slot count, 2 vs
        // 4 bytes per element), and the shares sum to the total.
        let f32_bytes = reg.dtype_resident_bytes(Dtype::F32);
        let bf16_bytes = reg.dtype_resident_bytes(Dtype::Bf16);
        assert_eq!(f32_bytes, p32.packed_bytes());
        assert_eq!(bf16_bytes, pbf.packed_bytes());
        assert_eq!(bf16_bytes * 2, f32_bytes);
        assert_eq!(f32_bytes + bf16_bytes, reg.resident_bytes());
        assert_eq!(m.registry_dtype_resident_bytes(Dtype::Bf16.index()), bf16_bytes);
        assert_eq!(m.registry_dtype_resident_bytes(Dtype::F32.index()), f32_bytes);
    }

    #[test]
    fn mixed_dtype_lru_evicts_variants_independently() {
        // Two dtype variants of one handle are separate LRU citizens:
        // under a budget that holds nothing, the unpinned f32 variant
        // is evicted while the pinned f16 variant of the *same handle*
        // survives, and the evicted variant later resolves as a fresh
        // miss (repacked from the retained matrix, never an error).
        let (reg, m) = registry(1);
        let h = reg.register(Matrix::random(8, 8, 1)).unwrap();
        let f32_pack = reg.resolve_pack(h, 8).unwrap();
        drop(f32_pack); // unpin the f32 variant
        let pinned_f16 = reg.resolve_pack_dtype(h, 8, Dtype::F16).unwrap();
        assert_eq!(m.registry_evictions(), 1, "unpinned f32 variant evicted");
        assert_eq!(reg.dtype_resident_bytes(Dtype::F32), 0);
        assert!(reg.resident_b_sjs(h).is_empty(), "no f32 variant resident");
        assert_eq!(reg.resident_b_sjs_dtype(h, Dtype::F16), vec![8]);
        assert_eq!(reg.dtype_resident_bytes(Dtype::F16), reg.resident_bytes());

        // The evicted f32 variant is a fresh miss; the pinned f16
        // variant rides out the churn untouched.
        let f32_again = reg.resolve_pack(h, 8).unwrap();
        assert_eq!(m.registry_misses(), 3, "evicted variant repacks as a miss");
        assert_eq!(m.registry_evictions(), 1, "both variants now pinned");
        drop(f32_again);
        let f16_again = reg.resolve_pack_dtype(h, 8, Dtype::F16).unwrap();
        assert!(Arc::ptr_eq(&pinned_f16, &f16_again), "pinned f16 variant survived");
        assert_eq!(m.registry_hits(), 1, "pinned variant resolves as a hit");
    }

    #[test]
    fn registry_trace_payload_encodes_dtype_above_side_bit() {
        let (reg, ring) = traced_registry(u64::MAX);
        let hb = reg.register(Matrix::random(8, 8, 1)).unwrap();
        let ha = reg.register_a(Matrix::random(8, 8, 2)).unwrap();
        let _pb = reg.resolve_pack_dtype(hb, 8, Dtype::F16).unwrap(); // B miss
        let _pa = reg.resolve_pack_a_dtype(ha, 8, Dtype::Bf16).unwrap(); // A miss
        let evs = ring.snapshot().events;
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].b & 1, 1, "B side in bit 0");
        assert_eq!((evs[0].b >> 1) as usize, Dtype::F16.index(), "dtype code above it");
        assert_eq!(evs[1].b & 1, 0, "A side in bit 0");
        assert_eq!((evs[1].b >> 1) as usize, Dtype::Bf16.index());
    }

    #[test]
    fn resolved_pack_is_bit_identical_to_private_pack() {
        let (reg, _) = registry(u64::MAX);
        let b = Matrix::random(23, 37, 7);
        let h = reg.register(b.clone()).unwrap();
        let cached = reg.resolve_pack(h, 12).unwrap();
        let private = PackedB::pack(b.view(), 12);
        assert_eq!(cached.num_panels(), private.num_panels());
        for bj in 0..private.num_panels() {
            assert_eq!(cached.panel(bj), private.panel(bj));
        }
    }

    #[test]
    fn resolved_a_pack_is_bit_identical_to_private_pack() {
        let (reg, _) = registry(u64::MAX);
        let a = Matrix::random(37, 23, 8);
        let h = reg.register_a(a.clone()).unwrap();
        let cached = reg.resolve_pack_a(h, 12).unwrap();
        let private = PackedA::pack(a.view(), 12);
        assert_eq!(cached.num_panels(), private.num_panels());
        for bi in 0..private.num_panels() {
            assert_eq!(cached.panel(bi), private.panel(bi));
        }
    }

    #[test]
    fn lru_eviction_respects_budget_and_order() {
        // Budget fits exactly one of the two packs; resolving the
        // second must evict the first (older stamp), and re-resolving
        // the first is a miss again (repacked from the retained matrix,
        // never an error).
        let (reg, m) = registry(1);
        let h1 = reg.register(Matrix::random(8, 8, 1)).unwrap();
        let h2 = reg.register(Matrix::random(8, 8, 2)).unwrap();
        let p1 = reg.resolve_pack(h1, 8).unwrap();
        drop(p1); // unpin
        let p2 = reg.resolve_pack(h2, 8).unwrap();
        assert_eq!(m.registry_evictions(), 1, "older pack evicted");
        drop(p2);
        let _p1_again = reg.resolve_pack(h1, 8).unwrap();
        assert_eq!(m.registry_misses(), 3, "evicted pack resolves as a fresh miss");
        assert_eq!(m.registry_evictions(), 2);
        assert_eq!(m.registry_hits(), 0);
    }

    #[test]
    fn mixed_side_lru_shares_budget_and_respects_pins() {
        // The satellite eviction scenario: A and B packs in one LRU
        // under a budget that holds nothing, with refcount pins on one
        // pack of each side. The pinned packs of *either* side survive;
        // the unpinned ones (older stamps first) are evicted across
        // sides.
        let (reg, m) = registry(1);
        let ha_pin = reg.register_a(Matrix::random(8, 8, 1)).unwrap();
        let hb_pin = reg.register(Matrix::random(8, 8, 2)).unwrap();
        let ha_cold = reg.register_a(Matrix::random(8, 8, 3)).unwrap();
        let hb_cold = reg.register(Matrix::random(8, 8, 4)).unwrap();

        let pin_a = reg.resolve_pack_a(ha_pin, 8).unwrap(); // held → pinned
        let pin_b = reg.resolve_pack(hb_pin, 8).unwrap(); // held → pinned
        let bytes_each = reg.resident_bytes() / 2;
        assert_eq!(m.registry_evictions(), 0, "both resident packs are pinned");

        // Unpinned resolutions on both sides: each lands, then is the
        // only evictable pack, so the next pressure removes it — the
        // pinned A and B packs survive every round.
        let cold_a = reg.resolve_pack_a(ha_cold, 8).unwrap();
        drop(cold_a);
        let cold_b = reg.resolve_pack(hb_cold, 8).unwrap();
        assert_eq!(m.registry_evictions(), 1, "unpinned A pack evicted, pins survive");
        assert_eq!(m.registry_a_evictions(), 1, "the victim was the A-side pack");
        drop(cold_b);
        let _cold_a2 = reg.resolve_pack_a(ha_cold, 8).unwrap();
        assert_eq!(m.registry_evictions(), 2, "unpinned B pack evicted next (older stamp)");
        assert_eq!(m.registry_a_evictions(), 1, "second victim was the B-side pack");

        // Pinned packs never left: resolving them is a hit, not a miss.
        let before = m.registry_misses();
        let again_a = reg.resolve_pack_a(ha_pin, 8).unwrap();
        let again_b = reg.resolve_pack(hb_pin, 8).unwrap();
        assert!(Arc::ptr_eq(&pin_a, &again_a), "pinned A pack survived the churn");
        assert!(Arc::ptr_eq(&pin_b, &again_b), "pinned B pack survived the churn");
        assert_eq!(m.registry_misses(), before, "both were hits");
    }

    #[test]
    fn inflight_pack_is_pinned_against_eviction() {
        // The refcount pin: a pack whose Arc is held outside the
        // registry (an in-flight job) survives eviction even when the
        // budget is blown; the registry overshoots instead.
        let (reg, m) = registry(1);
        let h1 = reg.register(Matrix::random(8, 8, 1)).unwrap();
        let h2 = reg.register(Matrix::random(8, 8, 2)).unwrap();
        let pinned = reg.resolve_pack(h1, 8).unwrap(); // held: strong_count 2
        let bytes_one = reg.resident_bytes();
        let also_pinned = reg.resolve_pack(h2, 8).unwrap();
        assert_eq!(m.registry_evictions(), 0, "both packs pinned, none evictable");
        assert_eq!(reg.resident_bytes(), 2 * bytes_one, "budget transiently exceeded");
        // Releasing the pins makes them evictable on the next pressure.
        drop(pinned);
        drop(also_pinned);
        let h3 = reg.register(Matrix::random(8, 8, 3)).unwrap();
        let _p3 = reg.resolve_pack(h3, 8).unwrap();
        assert!(m.registry_evictions() >= 2, "released packs evicted under pressure");
        assert_eq!(reg.resident_bytes(), bytes_one, "only the fresh pinned pack remains");
    }

    #[test]
    fn unregister_frees_and_invalidates() {
        let (reg, m) = registry(u64::MAX);
        let h = reg.register(Matrix::random(8, 8, 1)).unwrap();
        let held = reg.resolve_pack(h, 8).unwrap();
        assert!(reg.resident_bytes() > 0);
        reg.unregister(h).unwrap();
        assert_eq!(reg.resident_bytes(), 0);
        assert_eq!(m.registry_resident_bytes(), 0);
        assert_eq!(reg.registered_weights(), 0);
        assert!(reg.dims(h).is_none());
        assert!(reg.matrix(h).is_none());
        assert!(reg.resolve_pack(h, 8).is_err(), "handle dead after unregister");
        assert!(reg.unregister(h).is_err(), "double unregister is an error");
        // The in-flight clone stays valid — unregistering never yanks
        // data out from under running work.
        assert!(held.num_panels() > 0);
    }

    #[test]
    fn unregister_a_frees_and_invalidates() {
        let (reg, m) = registry(u64::MAX);
        let h = reg.register_a(Matrix::random(8, 8, 1)).unwrap();
        let held = reg.resolve_pack_a(h, 8).unwrap();
        assert!(reg.a_resident_bytes() > 0);
        reg.unregister_a(h).unwrap();
        assert_eq!(reg.resident_bytes(), 0);
        assert_eq!(reg.a_resident_bytes(), 0);
        assert_eq!(m.registry_a_resident_bytes(), 0);
        assert_eq!(reg.registered_activations(), 0);
        assert!(reg.dims_a(h).is_none());
        assert!(reg.matrix_a(h).is_none());
        assert!(reg.resolve_pack_a(h, 8).is_err(), "handle dead after unregister");
        assert!(reg.unregister_a(h).is_err(), "double unregister is an error");
        assert!(held.num_panels() > 0);
    }

    #[test]
    fn degenerate_register_rejected() {
        let (reg, _) = registry(u64::MAX);
        assert!(reg.register(Matrix::zeros(0, 4)).is_err());
        assert!(reg.register(Matrix::zeros(4, 0)).is_err());
        assert!(reg.register_a(Matrix::zeros(0, 4)).is_err());
        assert!(reg.register_a(Matrix::zeros(4, 0)).is_err());
    }

    #[test]
    fn boperand_conversions() {
        let m = Matrix::random(3, 4, 9);
        let inline: BOperand = m.clone().into();
        assert_eq!(inline.inline_dims(), Some((3, 4)));
        assert!(inline.handle().is_none());
        assert_eq!(inline.into_inline().unwrap().data, m.data);
        let h = WeightHandle { registry: 0, id: 42 };
        let reg: BOperand = h.into();
        assert!(reg.inline_dims().is_none());
        assert!(reg.as_inline().is_none());
        assert_eq!(reg.handle(), Some(h));
        assert_eq!(h.to_string(), "weight#42");
    }

    #[test]
    fn aoperand_conversions() {
        let m = Matrix::random(3, 4, 9);
        let inline: AOperand = m.clone().into();
        assert_eq!(inline.inline_dims(), Some((3, 4)));
        assert!(inline.handle().is_none());
        assert_eq!(inline.into_inline().unwrap().data, m.data);
        let h = ActivationHandle { registry: 0, id: 7 };
        let reg: AOperand = h.into();
        assert!(reg.inline_dims().is_none());
        assert!(reg.as_inline().is_none());
        assert!(reg.into_inline().is_none());
        assert_eq!(AOperand::Registered(h).handle(), Some(h));
        assert_eq!(h.to_string(), "act#7");
    }

    #[test]
    fn fused_operand_validates_packs_and_bills() {
        let parent = Arc::new(Matrix::random(8, 8, 40));
        let x = FusedSource { parent: parent.clone(), row0: 0, col0: 0 };
        let y = FusedSource { parent: parent.clone(), row0: 4, col0: 4 };
        let f = FusedOperand::combine(4, 4, x, y, CombineOp::Add);
        f.validate().unwrap();

        // Materialized vs fused-packed: bit-identical panels.
        let mat = f.materialize();
        for r in 0..4 {
            for c in 0..4 {
                assert_eq!(mat.get(r, c), parent.get(r, c) + parent.get(4 + r, 4 + c));
            }
        }
        assert_eq!(f.pack_a(4).panel(0), PackedA::pack(mat.view(), 4).panel(0));
        assert_eq!(f.pack_b(4).panel(0), PackedB::pack(mat.view(), 4).panel(0));

        // Quota billing: the window, not the parent.
        let op: AOperand = Operand::Fused(f.clone());
        assert_eq!(op.quota_bytes(), 4 * 4 * 4);
        assert!(op.inline_dims().is_none(), "fused is not inline");
        assert!(op.as_inline().is_none());
        assert!(op.handle().is_none());
        let inline: AOperand = Matrix::zeros(3, 5).into();
        assert_eq!(inline.quota_bytes(), 4 * 15);
        let reg: BOperand = WeightHandle { registry: 0, id: 1 }.into();
        assert_eq!(reg.quota_bytes(), 0);

        // Out-of-bounds windows are an error, not a clipped view.
        let oob = FusedOperand::single(
            9,
            4,
            FusedSource::whole(parent.clone()),
        );
        assert!(oob.validate().is_err());
        let oob2 = FusedOperand::combine(
            4,
            4,
            FusedSource::whole(parent.clone()),
            FusedSource { parent, row0: 6, col0: 0 },
            CombineOp::Sub,
        );
        assert!(oob2.validate().is_err());
    }

    #[test]
    fn tenant_residency_attributes_bytes_and_pins() {
        let (reg, _) = registry(u64::MAX);
        let t1 = TenantId(1);
        let t2 = TenantId(2);
        let hb = reg.register_for(Matrix::random(8, 8, 1), t1).unwrap();
        let ha = reg.register_a_for(Matrix::random(8, 8, 2), t2).unwrap();
        let _anon = reg.register(Matrix::random(8, 8, 3)).unwrap();

        // t1's pack held by an "in-flight job" → pinned; t2's dropped.
        let pinned = reg.resolve_pack(hb, 8).unwrap();
        let released = reg.resolve_pack_a(ha, 8).unwrap();
        drop(released);

        let rows = reg.tenant_residency();
        assert_eq!(rows.len(), 3, "default tenant + t1 + t2");
        let row = |t: TenantId| rows.iter().find(|(rt, _)| *rt == t).unwrap().1;
        assert_eq!(row(TenantId::DEFAULT).operands, 1);
        assert_eq!(row(TenantId::DEFAULT).resident_bytes, 0, "never resolved, no packs");
        let r1 = row(t1);
        assert!(r1.resident_bytes > 0);
        assert_eq!(r1.pinned_bytes, r1.resident_bytes, "held Arc pins the pack");
        let r2 = row(t2);
        assert!(r2.resident_bytes > 0);
        assert_eq!(r2.pinned_bytes, 0, "released pack is unpinned");

        drop(pinned);
        let rows = reg.tenant_residency();
        let r1 = rows.iter().find(|(t, _)| *t == t1).unwrap().1;
        assert_eq!(r1.pinned_bytes, 0);
        reg.unregister(hb).unwrap();
        assert!(!reg.tenant_residency().iter().any(|(t, _)| *t == t1));
    }

    #[test]
    fn foreign_handle_never_resolves() {
        // A handle minted by one registry must be an error — not a
        // lookup into same-numbered state — on any other registry.
        let (r1, _) = registry(u64::MAX);
        let (r2, _) = registry(u64::MAX);
        let h1 = r1.register(Matrix::random(4, 4, 1)).unwrap();
        let h2 = r2.register(Matrix::random(6, 6, 2)).unwrap();
        assert_eq!((h1.id(), h2.id()), (0, 0), "same raw id, different registries");
        assert_ne!(h1, h2, "nonce distinguishes the handles");
        assert!(r2.dims(h1).is_none());
        assert!(r2.matrix(h1).is_none());
        assert!(r2.resolve_pack(h1, 8).is_err());
        assert!(r2.unregister(h1).is_err());
        assert_eq!(r2.registered_weights(), 1, "foreign unregister must not evict");
        assert!(r1.resolve_pack(h1, 8).is_ok());
    }

    #[test]
    fn foreign_activation_handle_never_resolves() {
        let (r1, _) = registry(u64::MAX);
        let (r2, _) = registry(u64::MAX);
        let h1 = r1.register_a(Matrix::random(4, 4, 1)).unwrap();
        assert!(r2.dims_a(h1).is_none());
        assert!(r2.matrix_a(h1).is_none());
        assert!(r2.resolve_pack_a(h1, 8).is_err());
        assert!(r2.unregister_a(h1).is_err());
        assert!(r2.resident_a_sis(h1).is_empty());
        assert!(r1.resolve_pack_a(h1, 8).is_ok());
    }
}
