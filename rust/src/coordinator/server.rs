//! The multi-job serving runtime: a persistent worker pool with
//! cross-job work stealing.
//!
//! [`super::Coordinator::run_job`] reproduces the paper's work stealing
//! *inside* one job: `N_p` workers spawned per job drain one
//! [`AtomicWqm`] and exit. Under serving traffic that shape wastes the
//! pool — a 128x128 request occupies one task while the other workers
//! idle, and every job pays thread spawn/join. [`JobServer`] extends the
//! paper's inter-array stealing to *inter-job* scheduling:
//!
//! * one worker pool, spawned once, serves a stream of [`GemmJob`]s;
//! * jobs enter through a **bounded admission queue**
//!   ([`JobServer::submit`] blocks when full — backpressure;
//!   [`JobServer::try_submit`] sheds load instead);
//! * a dispatcher thread plans each admitted job (pinned config,
//!   server default, or DSE), packs its operands once via the existing
//!   [`PackedPanels`] path, and publishes its tasks into a per-job
//!   [`AtomicWqm`] registered in a shared epoch-tagged
//!   [`JobRegistry`];
//! * workers drain the job they are already on first (panel locality),
//!   then **steal from the fullest queue of any live job** — so one
//!   small request can never idle the pool while a 4096x4096 job runs;
//! * sub-threshold jobs are **coalesced into one batched super-job**:
//!   their tasks share a single WQM and fan out to per-sub-job
//!   [`DisjointBlocks`] writers, so tiny GEMMs amortize scheduling and
//!   still produce bit-identical results to individually-run ones
//!   (same panels, same microkernel, same accumulation order);
//! * **shared-operand batches** ([`JobServer::submit_batched_gemm`]):
//!   N GEMMs against one B — the CNN-inference shape, where every
//!   image of a batch multiplies the same packed filter matrix — are
//!   dispatched as one super-job whose sub-jobs all hold the *same*
//!   `Arc<PackedB>`. B is packed exactly once (tracked by
//!   `Metrics::b_panel_packs`; the N-1 avoided packs land in
//!   `Metrics::panels_shared`), and because an operand's packed layout
//!   depends only on its own shape and block size, every sub-result is
//!   bit-identical to an individual submission;
//! * **registered operands** ([`JobServer::register_b`],
//!   [`JobServer::register_a`]): either side of any submission may be a
//!   handle into the server-resident [`OperandRegistry`] — the B side
//!   as a [`BOperand`]/[`WeightHandle`], the A side as an
//!   [`AOperand`]/[`ActivationHandle`]. A registered operand is packed
//!   at most once per `(handle, side, S)` for the whole process, so the
//!   one-pack guarantee extends *across* calls on both sides:
//!   successive batches reusing a filter resolve the cached
//!   `Arc<PackedB>`, and an activation batch multiplied against a whole
//!   weight set (attention's Q/K/V/O shape) resolves one cached
//!   `Arc<PackedA>` instead of repacking per weight. Both sides share
//!   one byte budget under refcount-pinned LRU
//!   (`ServerConfig::registry_budget_bytes`);
//! * **registry-aware planning**: when a submission's registered
//!   operands already hold packed variants, the planner steers the
//!   chosen `(S_i, S_j)` toward an already-resident one (turning repack
//!   misses into cache hits, counted in `Metrics::plan_residency_hits`)
//!   unless the analytical model prices every resident candidate worse
//!   than the baseline by more than `ServerConfig::plan_residency_slack`;
//! * **traffic-shaped admission** ([`super::frontend`]): every
//!   submission enters through the unified [`Submission`] builder
//!   carrying a [`TenantId`] and an optional deadline.
//!   [`JobServer::submit_async`] returns an awaitable [`JobFuture`]
//!   (poll/wait/timeout/`.await`), [`JobServer::submit_blocking`]
//!   resolves inline, and [`JobServer::try_submit`] sheds with the
//!   submission handed back. Per-tenant quotas (max in-flight
//!   jobs/bytes) are charged at admission and released per job as
//!   replies deliver; the bounded queue serves tenants by weighted
//!   deficit round robin and, within a tenant, by deadline slack
//!   (time to deadline minus the analytical model's predicted
//!   execution time). Deadline misses are counted next to the latency
//!   percentiles in [`JobServer::stats`];
//! * **sharded dispatchers**: `ServerConfig::admission_shards` threads
//!   each independently drain the front end, plan, pack, and publish
//!   into the *shared* epoch-tagged [`JobRegistry`] — admission stops
//!   being a serial bottleneck while cross-job stealing still sees one
//!   pool.
//!
//! Completion is counter-driven: the worker that finishes a job's last
//! task assembles the result, runs the timing simulation, records
//! per-job latency into the shared [`Metrics`] (server-level
//! percentiles), replies on the job's ticket channel, and retires the
//! job from the registry.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::accelerator::{Accelerator, SimOptions};
use crate::blocking::{BlockPlan, BlockTask};
use crate::config::{HardwareConfig, RunConfig};
use crate::gemm::{DisjointBlocks, Dtype, Matrix, PackedA, PackedB, PackedPanels};
use crate::wqm::{AtomicWqm, JobRegistry};

use super::engine::NumericsEngine;
use super::frontend::{
    AdmitMeta, FrontEnd, JobFuture, QuotaLedger, SubmitError, Submission, SubmissionKind,
    TenantConfig, TenantId, TenantSlot, TryPushError,
};
use super::metrics::{DriftStats, Metrics, TenantCounters};
use super::registry::{ActivationHandle, AOperand, BOperand, OperandRegistry, WeightHandle};
use super::trace::{
    stage_percentiles, EventKind, SpanKind, TraceRing, TraceSnapshot, ACTOR_NONE, STAGE_NAMES,
    TASK_CROSS_JOB, TASK_STOLEN,
};
use super::{choose_run_dims, GemmJob, JobResult};

/// Serving-runtime knobs.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Persistent worker threads (the software `N_p` of the pool).
    pub workers: usize,
    /// Bounded admission-queue capacity, in jobs. `submit` blocks and
    /// `try_submit` rejects while the queue is full. The same figure
    /// bounds *activated* jobs (`max(queue_capacity, workers)`), so the
    /// server's in-flight memory is capped regardless of arrival rate.
    pub queue_capacity: usize,
    /// A job whose block grid has at most this many tasks is "small"
    /// and eligible for batching (it cannot occupy the pool alone).
    pub batch_max_tasks: usize,
    /// Maximum small jobs coalesced into one batched super-job.
    /// `<= 1` disables batching.
    pub batch_window: usize,
    /// When `false`, workers only take tasks from the oldest live job —
    /// the per-job-pool baseline the serving bench compares against.
    pub cross_job_stealing: bool,
    /// Used for unpinned jobs instead of running the DSE per job (the
    /// serving fast path). `None` = explore per job.
    pub default_run: Option<RunConfig>,
    /// Byte budget of the operand registry's pack cache
    /// ([`JobServer::register_b`]). Least-recently-used packs are
    /// evicted past this figure unless pinned by an in-flight job;
    /// evicted packs transparently repack on next use.
    pub registry_budget_bytes: u64,
    /// Registry-aware planning slack: a block config already resident
    /// for a submission's registered operands is preferred over the
    /// planner's baseline as long as its predicted time is within
    /// `baseline * (1 + slack)` — a repack miss traded against a
    /// bounded compute penalty. Negative disables the refinement
    /// entirely (the planner ignores residency).
    pub plan_residency_slack: f64,
    /// Dispatcher (admission) shards: threads that independently drain
    /// the front-end queue, plan + pack, and publish into the shared
    /// job registry. More shards overlap planning/packing of
    /// concurrent submissions; cross-job stealing is unaffected (the
    /// workers see one pool either way). Must be >= 1; 2 by default so
    /// admission is never serial out of the box.
    pub admission_shards: usize,
    /// Flight-recorder capacity, in events ([`super::trace::TraceRing`]
    /// slots). `0` (the default) disables tracing entirely: no ring is
    /// allocated and every emission short-circuits on one atomic load.
    /// Nonzero rounds up to a power of two; when the ring fills, the
    /// oldest events are overwritten (`TraceSnapshot::dropped` counts
    /// them) — tracing never blocks the serving path.
    pub trace_capacity: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        let workers = thread::available_parallelism().map(|n| n.get().min(8)).unwrap_or(4);
        Self {
            workers,
            queue_capacity: 64,
            batch_max_tasks: 4,
            batch_window: 8,
            cross_job_stealing: true,
            default_run: None,
            registry_budget_bytes: 256 << 20,
            plan_residency_slack: 0.05,
            admission_shards: 2,
            trace_capacity: 0,
        }
    }
}

impl ServerConfig {
    /// Validate the knob set against a hardware config. Every
    /// [`JobServer`] constructor funnels through this, so `Default`,
    /// the docs, and the CLI cannot silently diverge on what a legal
    /// configuration is.
    pub fn validate(&self, hw: &HardwareConfig) -> anyhow::Result<()> {
        anyhow::ensure!(self.workers >= 1, "need at least one worker");
        anyhow::ensure!(self.queue_capacity >= 1, "need admission capacity >= 1");
        anyhow::ensure!(self.batch_window >= 1, "batch window must be >= 1");
        anyhow::ensure!(self.admission_shards >= 1, "need at least one admission shard");
        anyhow::ensure!(
            !self.plan_residency_slack.is_nan() && self.plan_residency_slack != f64::INFINITY,
            "plan residency slack must be a finite factor (negative disables)"
        );
        if let Some(run) = self.default_run {
            run.validate(hw)?;
        }
        Ok(())
    }
}

/// Handle to one in-flight job; resolves to its [`JobResult`].
#[derive(Debug)]
pub struct JobTicket {
    pub id: u64,
    rx: mpsc::Receiver<anyhow::Result<JobResult>>,
}

impl JobTicket {
    pub(crate) fn new(id: u64, rx: mpsc::Receiver<anyhow::Result<JobResult>>) -> Self {
        Self { id, rx }
    }

    /// Block until the job completes.
    pub fn wait(self) -> anyhow::Result<JobResult> {
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Err(anyhow::anyhow!("server dropped job {} without replying", self.id)),
        }
    }

    /// Non-blocking poll; `None` while the job is still in flight. A
    /// dropped reply channel (server died without answering, or the
    /// result was already consumed) surfaces as `Some(Err(..))`, never
    /// as an eternal `None`.
    pub fn try_wait(&self) -> Option<anyhow::Result<JobResult>> {
        match self.rx.try_recv() {
            Ok(r) => Some(r),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => Some(Err(anyhow::anyhow!(
                "server dropped job {} without replying",
                self.id
            ))),
        }
    }

    /// Bounded block: `Some(result)` when the job replies within
    /// `timeout`, `None` on timeout (the ticket stays valid — wait
    /// again, or poll). A dropped reply channel surfaces as
    /// `Some(Err(..))`, never as an eternal timeout.
    pub fn wait_timeout(&self, timeout: Duration) -> Option<anyhow::Result<JobResult>> {
        match self.rx.recv_timeout(timeout) {
            Ok(r) => Some(r),
            Err(mpsc::RecvTimeoutError::Timeout) => None,
            Err(mpsc::RecvTimeoutError::Disconnected) => Some(Err(anyhow::anyhow!(
                "server dropped job {} without replying",
                self.id
            ))),
        }
    }
}

/// A set of tickets submitted as one unit ([`JobServer::submit_group`])
/// that resolves jointly — the completion-join primitive the Strassen
/// planner uses for its 7-way sub-product fan-out per recursion level.
#[derive(Debug)]
pub struct JobGroup {
    tickets: Vec<JobTicket>,
}

impl JobGroup {
    pub fn len(&self) -> usize {
        self.tickets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tickets.is_empty()
    }

    /// Block until every job in the group completes, returning results
    /// in submission order. All tickets are drained even when one fails
    /// (no in-flight work is abandoned mid-group); the first failure is
    /// then returned, tagged with its job id.
    pub fn wait_all(self) -> anyhow::Result<Vec<JobResult>> {
        let mut results = Vec::with_capacity(self.tickets.len());
        let mut first_err: Option<anyhow::Error> = None;
        for t in self.tickets {
            let id = t.id;
            match t.wait() {
                Ok(r) => results.push(r),
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e.context(format!("job {id} in group failed")));
                    }
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(results),
        }
    }

    /// Take the individual tickets back (per-job polling).
    pub fn into_tickets(self) -> Vec<JobTicket> {
        self.tickets
    }
}

/// Legacy shed-path error (the pre-builder `try_submit(GemmJob)`
/// surface); carries the job back so the caller can retry, shed, or
/// route elsewhere. New code matches [`SubmitError`] from
/// [`JobServer::try_submit`] instead, which hands back the whole
/// [`Submission`].
#[derive(Debug)]
pub enum TrySubmitError {
    /// Admission queue at capacity (backpressure).
    Full(GemmJob),
    /// Server is shutting down.
    Closed(GemmJob),
}

/// Why [`JobServer::try_submit_batched_gemm`] rejected a batch; the
/// shed variants hand every operand back so the caller can retry,
/// spill, or route elsewhere — the same never-silently-drop contract as
/// [`TrySubmitError`].
#[derive(Debug)]
pub enum TrySubmitBatchedError {
    /// The batch had no A operands — nothing to run.
    Empty,
    /// Admission queue at capacity (backpressure); operands returned.
    Full { b: BOperand, many_a: Vec<Matrix> },
    /// Server is shutting down; operands returned.
    Closed { b: BOperand, many_a: Vec<Matrix> },
}

/// Server-level snapshot: throughput, tail latency, pool utilization.
#[derive(Debug, Clone)]
pub struct ServerStats {
    pub jobs: u64,
    pub jobs_failed: u64,
    pub tasks: u64,
    pub steals: u64,
    pub cross_job_steals: u64,
    pub batched_jobs: u64,
    /// Shared-B batch groups dispatched via
    /// [`JobServer::submit_batched_gemm`].
    pub shared_b_groups: u64,
    /// Operand-registry resolutions served from an already-cached pack
    /// — whole-operand packs avoided *across* calls.
    pub registry_hits: u64,
    /// Registry resolutions that packed (first use per `(handle, S_j)`,
    /// or re-use after eviction).
    pub registry_misses: u64,
    /// Cached packs evicted to hold the registry byte budget.
    pub registry_evictions: u64,
    /// A-side split of the registry figures above: resolutions of
    /// registered *activations* ([`JobServer::register_a`]) served from
    /// cache, packed fresh, and evicted. (The unsplit counters total
    /// both sides.)
    pub registry_a_hits: u64,
    pub registry_a_misses: u64,
    pub registry_a_evictions: u64,
    /// Bytes of packed data resident in the operand registry right now.
    pub registry_resident_bytes: u64,
    /// A-side share of `registry_resident_bytes`.
    pub registry_a_resident_bytes: u64,
    /// Per-precision split of `registry_resident_bytes`, indexed by
    /// [`Dtype::index`] (f32, f64, f16, bf16) — which precisions'
    /// packed variants occupy the cache right now.
    pub registry_dtype_resident_bytes: [u64; 4],
    /// Weights currently registered ([`JobServer::register_b`]).
    pub registered_weights: u64,
    /// Activations currently registered ([`JobServer::register_a`]).
    pub registered_activations: u64,
    /// Planning decisions steered to an already-resident block config
    /// instead of the cascade baseline (registry-aware planning).
    pub plan_residency_hits: u64,
    /// Individual unregister failures swallowed-but-counted by the
    /// `unregister_all*` sweeps — nonzero means handles leaked.
    pub unregister_failures: u64,
    /// Per-task operand gathers on the numerics path (0 on the packed
    /// golden path; 2/task on the channel-fed PJRT backend).
    pub panel_copies: u64,
    /// Whole-operand packs performed (A side / B side).
    pub a_panel_packs: u64,
    pub b_panel_packs: u64,
    /// Whole-operand packs *avoided* by sharing an already-packed B
    /// across a batch — the figure `submit_batched_gemm` exists to grow.
    pub panels_shared: u64,
    pub uptime_secs: f64,
    pub throughput_jobs_per_sec: f64,
    pub latency_mean_secs: f64,
    pub latency_p50_secs: f64,
    pub latency_p95_secs: f64,
    pub latency_p99_secs: f64,
    /// Completed jobs that carried a deadline, and how many of those
    /// finished past it — surfaced next to the tail latencies above: a
    /// p99 inside the deadline with a nonzero miss count means the
    /// misses live in the tail beyond p99.
    pub deadline_jobs: u64,
    pub deadline_misses: u64,
    /// Per-tenant completion counters, ascending by tenant id — one
    /// entry per tenant that completed at least one job.
    pub tenants: Vec<(TenantId, TenantCounters)>,
    /// Total worker busy time (numerics execution), seconds.
    pub worker_busy_secs: f64,
    /// `1 - busy / (workers * uptime)` — the figure cross-job stealing
    /// exists to lower.
    pub worker_idle_frac: f64,
    /// Tasks executed by each worker, indexed by worker. The sum equals
    /// `tasks`; the spread is what stealing exists to flatten.
    pub per_worker_tasks: Vec<u64>,
    /// Tasks each worker claimed from a queue other than its own
    /// (steal provenance, intra- or cross-job).
    pub per_worker_steals: Vec<u64>,
    /// `max / min` of `per_worker_tasks` — 1.0 is a perfectly balanced
    /// pool, `inf` means some worker executed nothing while others
    /// worked, 0.0 means no tasks ran at all.
    pub worker_imbalance: f64,
    /// Predicted-vs-measured model drift over completed jobs
    /// ([`Metrics::record_drift`]); `None` before the first completion.
    pub drift: Option<DriftStats>,
    /// Flight-recorder stage rollup, index-aligned with
    /// [`STAGE_NAMES`]: `(p50, p95)` seconds per stage. `None` when
    /// tracing is disabled or no job has a full breakdown yet.
    pub stage_p50_p95_secs: Option<[(f64, f64); 5]>,
    /// Events currently recorded / overwritten in the trace ring
    /// (both 0 when tracing is disabled).
    pub trace_recorded: u64,
    pub trace_dropped: u64,
}

impl std::fmt::Display for ServerStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "jobs={} (failed={}, batched={}, shared-b groups={}) tasks={} \
             steals={} (cross-job={}) packs(a/b)={}/{} panels_shared={} \
             registry(hit/miss/evict)={}/{}/{} weights={} resident={}B \
             a_panel(hit/miss/evict)={}/{}/{} activations={} a_resident={}B \
             plan_residency_hits={} panel_copies={} {:.1} jobs/s \
             lat(p50/p95/p99)={:.4}s/{:.4}s/{:.4}s deadline(miss/ddl)={}/{} \
             tenants=[{}] idle={:.1}%",
            self.jobs,
            self.jobs_failed,
            self.batched_jobs,
            self.shared_b_groups,
            self.tasks,
            self.steals,
            self.cross_job_steals,
            self.a_panel_packs,
            self.b_panel_packs,
            self.panels_shared,
            self.registry_hits,
            self.registry_misses,
            self.registry_evictions,
            self.registered_weights,
            self.registry_resident_bytes,
            self.registry_a_hits,
            self.registry_a_misses,
            self.registry_a_evictions,
            self.registered_activations,
            self.registry_a_resident_bytes,
            self.plan_residency_hits,
            self.panel_copies,
            self.throughput_jobs_per_sec,
            self.latency_p50_secs,
            self.latency_p95_secs,
            self.latency_p99_secs,
            self.deadline_misses,
            self.deadline_jobs,
            self.tenants
                .iter()
                .map(|(t, c)| format!("#{}:{}j/{}m", t.0, c.jobs, c.deadline_misses))
                .collect::<Vec<_>>()
                .join(","),
            100.0 * self.worker_idle_frac
        )?;
        let dt = &self.registry_dtype_resident_bytes;
        write!(
            f,
            " dtype_resident(f32/f64/f16/bf16)={}/{}/{}/{}B",
            dt[0], dt[1], dt[2], dt[3]
        )?;
        let max_t = self.per_worker_tasks.iter().copied().max().unwrap_or(0);
        let min_t = self.per_worker_tasks.iter().copied().min().unwrap_or(0);
        write!(
            f,
            " worker_tasks(max/min)={max_t}/{min_t} imbalance={:.2}",
            self.worker_imbalance
        )?;
        if let Some(d) = &self.drift {
            write!(
                f,
                " drift(min/mean/max/p95)={:+.3}/{:+.3}/{:+.3}/{:+.3}",
                d.min, d.mean, d.max, d.p95
            )?;
        }
        if let Some(stages) = &self.stage_p50_p95_secs {
            let body = STAGE_NAMES
                .iter()
                .zip(stages)
                .map(|(name, (p50, p95))| format!("{name}={p50:.5}s/{p95:.5}s"))
                .collect::<Vec<_>>()
                .join(" ");
            write!(f, " stages(p50/p95)=[{body}]")?;
        }
        Ok(())
    }
}

/// One queue element of a (possibly batched) job: which sub-job it
/// belongs to, and which C block it computes.
#[derive(Debug, Clone, Copy)]
struct SubTask {
    sub: u32,
    task: BlockTask,
}

/// Raw handle to a sub-job's C storage; the buffer it points into is
/// owned by [`SubJob::out`] and outlives every task of the sub-job.
#[derive(Debug, Clone, Copy)]
struct RawOut {
    ptr: *mut f32,
    rows: usize,
    cols: usize,
}

// SAFETY: the pointer targets heap storage owned by the same `SubJob`
// (kept alive in `out` until after the last task completes), and all
// writes through it go through `DisjointBlocks::write_block`'s
// disjointness contract.
unsafe impl Send for RawOut {}
unsafe impl Sync for RawOut {}

/// An activated sub-job's view of one operand: the full matrix (inline
/// and registered operands — the gather fallback reads it per task), or
/// dimensions only, for a fused operand that exists purely as packed
/// panels (its combination was formed inside the pack pass and a full
/// matrix was never materialized).
enum ExecOperand {
    Full(Arc<Matrix>),
    Packed { rows: usize, cols: usize },
}

impl ExecOperand {
    fn rows(&self) -> usize {
        match self {
            ExecOperand::Full(m) => m.rows,
            ExecOperand::Packed { rows, .. } => *rows,
        }
    }

    fn cols(&self) -> usize {
        match self {
            ExecOperand::Full(m) => m.cols,
            ExecOperand::Packed { cols, .. } => *cols,
        }
    }

    /// The full matrix, when one exists (`None` for packed-only fused
    /// operands — the engine's gather path errors on those).
    fn full(&self) -> Option<&Arc<Matrix>> {
        match self {
            ExecOperand::Full(m) => Some(m),
            ExecOperand::Packed { .. } => None,
        }
    }
}

/// One GEMM inside an active (possibly batched) job.
struct SubJob {
    id: u64,
    run: RunConfig,
    /// Refcounted on both sides: a registered operand's matrix is the
    /// registry's own `Arc` (never cloned per job), an inline one is
    /// wrapped at dispatch; a fused operand carries dims only (it lives
    /// in `panels`). The gather-fallback path reads the full matrices
    /// per task; a shared-B batch holds one B across all sub-jobs.
    a: ExecOperand,
    b: ExecOperand,
    /// Packed once at dispatch for in-process engines; `None` for the
    /// channel-fed PJRT backend (it gathers per task). The packed B
    /// half inside is an `Arc<PackedB>` — one pack feeds every sub-job
    /// of a shared-B batch.
    panels: Option<PackedPanels>,
    /// C's owned storage; taken by the finalizing worker.
    out: Mutex<Option<Matrix>>,
    raw: RawOut,
    /// Tasks not yet completed; the worker that decrements it to zero
    /// finalizes the sub-job.
    pending: AtomicUsize,
    /// First task-level error, if any (delivered at finalize).
    error: Mutex<Option<anyhow::Error>>,
    reply: Mutex<Option<Reply>>,
    accepted_at: Instant,
    batched: bool,
    tenant: TenantId,
    /// Absolute completion deadline; finishing past it counts a miss
    /// (the job is never cancelled — a late answer still answers).
    deadline: Option<Instant>,
    /// Flight-recorder identity, minted at admission: unique across
    /// every sub-job the server has ever seen, and the key that stitches
    /// this sub-job's Submit → … → Done events into one [`super::trace::JobTrace`].
    uid: u64,
    /// What the analytical model priced this sub-job at when the
    /// dispatcher planned it; compared against the measured (simulated)
    /// time at finalize — the model-drift record.
    predicted_secs: f64,
}

/// A registered job: its lock-free task queues plus execution context.
struct ActiveJob {
    wqm: AtomicWqm<SubTask>,
    subs: Vec<SubJob>,
    /// Sub-jobs not yet finalized; zero retires the job from the table.
    subs_pending: AtomicUsize,
}

/// Generation-counted wakeup gate: registration (and shutdown) bump the
/// generation; idle workers sleep until it moves past what they saw
/// before their last empty scan — no lost wakeups, no busy wait.
///
/// `current` is one atomic load (it sits on the workers' per-task fast
/// path); the mutex + condvar only serialize the sleep/notify
/// handshake. The bump increments the generation *under* the lock, so
/// it cannot land between a waiter's re-check and its `wait`.
struct WorkGate {
    gen: AtomicU64,
    lock: Mutex<()>,
    cv: Condvar,
}

impl WorkGate {
    fn new() -> Self {
        Self { gen: AtomicU64::new(0), lock: Mutex::new(()), cv: Condvar::new() }
    }

    fn current(&self) -> u64 {
        self.gen.load(Ordering::Acquire)
    }

    fn bump(&self) {
        {
            let _g = self.lock.lock().unwrap();
            self.gen.fetch_add(1, Ordering::AcqRel);
        }
        self.cv.notify_all();
    }

    fn wait_past(&self, seen: u64) {
        let mut g = self.lock.lock().unwrap();
        while self.gen.load(Ordering::Acquire) == seen {
            g = self.cv.wait(g).unwrap();
        }
        drop(g);
    }
}

/// A job's reply endpoint, carrying its per-tenant quota slot: the
/// slot releases when the `Reply` is consumed (result sent) *or*
/// dropped (planner rejection, shed hand-back, shutdown abandonment) —
/// exactly once either way, which is what makes quota accounting
/// conserve under every failure path.
struct Reply {
    tx: mpsc::Sender<anyhow::Result<JobResult>>,
    _slot: Option<TenantSlot>,
}

impl Reply {
    fn send(self, r: anyhow::Result<JobResult>) {
        // A departed client (dropped ticket) is not an error; the quota
        // slot releases regardless as `self` drops here.
        let _ = self.tx.send(r);
    }
}

/// One admitted job awaiting dispatch — the queue-side form of a
/// [`Submission`], with the tenant resolved and the deadline absolute.
struct Admitted {
    job: GemmJob,
    reply: Reply,
    accepted_at: Instant,
    tenant: TenantId,
    deadline: Option<Instant>,
    /// Flight-recorder identity (see [`SubJob::uid`]).
    uid: u64,
    /// Precision the job's panels pack (and its microkernel runs) at;
    /// carried from the [`Submission`], `F32` for plain `submit` calls.
    dtype: Dtype,
}

/// One sub-request of a shared-B batch: its own A (inline, or a
/// registered activation handle), its own reply — B lives once on the
/// enclosing [`SharedBatch`].
struct SharedSub {
    id: u64,
    a: AOperand,
    reply: Reply,
    accepted_at: Instant,
    tenant: TenantId,
    deadline: Option<Instant>,
    /// Flight-recorder identity (see [`SubJob::uid`]).
    uid: u64,
}

/// An admitted [`JobServer::submit_batched_gemm`] call: one B (inline,
/// or a registered weight handle) shared by every sub-request,
/// dispatched as a single super-job that packs B at most once — and
/// not at all when a registered handle hits the operand registry.
struct SharedBatch {
    b: BOperand,
    run: Option<RunConfig>,
    subs: Vec<SharedSub>,
    /// One precision for the whole batch — the shared B packs once per
    /// `(handle, S_j, dtype)`, so the subs cannot disagree.
    dtype: Dtype,
}

/// Admission-queue element: a lone job, an explicit group (from
/// [`Submission::group`]) the dispatcher coalesces as a unit, or a
/// shared-B batch. The bounded multi-tenant queue itself
/// ([`FrontEnd`]) lives in [`super::frontend`]; this is its payload.
enum QueueItem {
    One(Admitted),
    Group(Vec<Admitted>),
    SharedB(SharedBatch),
}

/// Rebuild the caller-facing [`Submission`] from a shed queue item:
/// operands, tenant, pin, and remaining deadline come back intact,
/// while the replies (and the quota slots riding them) drop — which is
/// exactly what releases the charge taken at admission.
fn reclaim_submission(item: QueueItem, deadline: Option<Instant>) -> Submission {
    let left = deadline.map(|d| d.saturating_duration_since(Instant::now()));
    let mut s = match item {
        QueueItem::One(adm) => {
            let tenant = adm.tenant;
            let dtype = adm.dtype;
            let GemmJob { id, a, b, run } = adm.job;
            let mut s = Submission::gemm(a, b).tenant(tenant).id(id).dtype(dtype);
            s.run = run;
            s
        }
        QueueItem::Group(subs) => {
            let tenant = subs.first().map_or(TenantId::DEFAULT, |s| s.tenant);
            let dtype = subs.first().map_or(Dtype::F32, |s| s.dtype);
            Submission::group(subs.into_iter().map(|s| s.job).collect())
                .tenant(tenant)
                .dtype(dtype)
        }
        QueueItem::SharedB(batch) => {
            let tenant = batch.subs.first().map_or(TenantId::DEFAULT, |s| s.tenant);
            let id = batch.subs.first().map_or(0, |s| s.id);
            let run = batch.run;
            let dtype = batch.dtype;
            let many_a: Vec<AOperand> = batch.subs.into_iter().map(|s| s.a).collect();
            let mut s = Submission::batched(batch.b, many_a).tenant(tenant).id(id).dtype(dtype);
            s.run = run;
            s
        }
    };
    s.deadline = left;
    s
}

/// State shared by the dispatcher and every worker.
struct Shared {
    hw: HardwareConfig,
    accelerator: Accelerator,
    engine: NumericsEngine,
    metrics: Arc<Metrics>,
    /// Server-resident packed-operand cache (registered weights).
    operands: OperandRegistry,
    registry: JobRegistry<ActiveJob>,
    gate: WorkGate,
    stop: AtomicBool,
    cfg: ServerConfig,
    /// Per-worker busy nanoseconds (numerics execution only).
    worker_busy: Vec<AtomicU64>,
    /// Per-worker tasks executed / tasks claimed from a foreign queue —
    /// the load-balance breakdown [`JobServer::stats`] surfaces.
    worker_tasks: Vec<AtomicU64>,
    worker_steals: Vec<AtomicU64>,
    /// Registered-but-unfinished jobs; shutdown drains this to zero.
    inflight: AtomicUsize,
    started: Instant,
    /// Bounded lock-free flight recorder (disabled at capacity 0: every
    /// emission is one relaxed load and out).
    trace: Arc<TraceRing>,
    /// Sub-job uid allocator; a submission of `n` jobs takes a
    /// contiguous range so even quota-rejected work has an identity.
    next_uid: AtomicU64,
}

/// A planned submission, ready to activate.
struct Planned {
    sub: Admitted,
    run: RunConfig,
    plan: BlockPlan,
    small: bool,
    /// Analytical-model price of the chosen config (0.0 when the model
    /// could not price it) — carried to the finished job's drift record.
    predicted: f64,
}

/// The serving runtime. See the module docs for the architecture.
pub struct JobServer {
    shared: Arc<Shared>,
    admission: Arc<FrontEnd<QueueItem>>,
    ledger: Arc<QuotaLedger>,
    dispatchers: Vec<thread::JoinHandle<()>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl JobServer {
    pub fn new(
        hw: HardwareConfig,
        engine: NumericsEngine,
        cfg: ServerConfig,
    ) -> anyhow::Result<Self> {
        cfg.validate(&hw)?;
        let metrics = Arc::new(Metrics::default());
        let trace = Arc::new(TraceRing::new(cfg.trace_capacity));
        let shared = Arc::new(Shared {
            accelerator: Accelerator::new(hw.clone()),
            hw,
            engine,
            operands: OperandRegistry::new(
                cfg.registry_budget_bytes,
                metrics.clone(),
                trace.clone(),
            ),
            metrics,
            registry: JobRegistry::new(),
            gate: WorkGate::new(),
            stop: AtomicBool::new(false),
            worker_busy: (0..cfg.workers).map(|_| AtomicU64::new(0)).collect(),
            worker_tasks: (0..cfg.workers).map(|_| AtomicU64::new(0)).collect(),
            worker_steals: (0..cfg.workers).map(|_| AtomicU64::new(0)).collect(),
            inflight: AtomicUsize::new(0),
            started: Instant::now(),
            trace,
            next_uid: AtomicU64::new(0),
            cfg,
        });
        let admission =
            Arc::new(FrontEnd::with_trace(shared.cfg.queue_capacity, shared.trace.clone()));
        let ledger = Arc::new(QuotaLedger::new());

        let mut workers = Vec::with_capacity(shared.cfg.workers);
        for w in 0..shared.cfg.workers {
            let shared = shared.clone();
            workers.push(
                thread::Builder::new()
                    .name(format!("marr-worker-{w}"))
                    .spawn(move || worker_loop(shared, w))?,
            );
        }
        let mut dispatchers = Vec::with_capacity(shared.cfg.admission_shards);
        for d in 0..shared.cfg.admission_shards {
            let shared = shared.clone();
            let admission = admission.clone();
            dispatchers.push(
                thread::Builder::new()
                    .name(format!("marr-dispatch-{d}"))
                    .spawn(move || dispatcher_loop(shared, admission, d))?,
            );
        }
        Ok(Self { shared, admission, ledger, dispatchers, workers })
    }

    /// A server with default knobs.
    pub fn with_defaults(hw: HardwareConfig, engine: NumericsEngine) -> anyhow::Result<Self> {
        Self::new(hw, engine, ServerConfig::default())
    }

    /// Configure a tenant's DRR weight and in-flight quotas. Takes
    /// effect for the tenant's *next* submission (weight) and next
    /// quota check (caps); in-flight work is never re-billed.
    pub fn configure_tenant(&self, tenant: TenantId, cfg: TenantConfig) -> anyhow::Result<()> {
        anyhow::ensure!(cfg.weight >= 1, "tenant weight must be >= 1");
        self.ledger.configure(tenant, cfg);
        Ok(())
    }

    /// Submit through the unified builder and get an awaitable
    /// [`JobFuture`] back. Blocks only on *admission* (tenant quota,
    /// then queue capacity — backpressure), never on execution: the
    /// future resolves via poll, wait, bounded wait, or `.await`.
    /// Errors once the server is shutting down.
    ///
    /// Accepts anything `Into<Submission>`: the builder itself, or a
    /// bare [`GemmJob`].
    pub fn submit_async(&self, s: impl Into<Submission>) -> anyhow::Result<JobFuture> {
        self.admit(s.into(), true).map_err(anyhow::Error::new)
    }

    /// [`JobServer::submit_async`] + [`JobFuture::wait`] in one call —
    /// the blocking path, now a veneer over the async one (results are
    /// bit-identical by construction: same queue, same dispatch, same
    /// workers).
    pub fn submit_blocking(&self, s: impl Into<Submission>) -> anyhow::Result<Vec<JobResult>> {
        self.submit_async(s)?.wait()
    }

    /// Non-blocking submit: rejects — with the whole [`Submission`]
    /// handed back, operands intact — when the queue is full (shed
    /// load), the tenant's in-flight quota is exhausted, or the server
    /// is closed. Never barges past blocked `submit_async` callers.
    pub fn try_submit(&self, s: impl Into<Submission>) -> Result<JobFuture, SubmitError> {
        self.admit(s.into(), false)
    }

    /// The one admission path every entry point funnels through:
    /// validate, charge the tenant's quota (all-or-nothing), mint
    /// per-job quota slots onto the replies, price the work for slack
    /// ordering, and push into the multi-tenant front end.
    fn admit(&self, s: Submission, blocking: bool) -> Result<JobFuture, SubmitError> {
        let njobs = s.jobs();
        if njobs == 0 {
            return Err(SubmitError::Invalid("empty submission".into()));
        }
        let tenant = s.tenant;
        let bytes = s.inline_bytes();
        // One uid per sub-job, minted before any outcome is known, so
        // quota-rejected and shed work still has a trace identity. The
        // emit helper walks the range; every emission is a no-op load
        // when tracing is disabled.
        let trace = &self.shared.trace;
        let base_uid = self.shared.next_uid.fetch_add(njobs as u64, Ordering::Relaxed);
        let emit_each = |kind: EventKind| {
            if trace.enabled() {
                for i in 0..njobs as u64 {
                    trace.emit(kind, base_uid + i, tenant.0, ACTOR_NONE, 0, 0);
                }
            }
        };
        emit_each(EventKind::Submit);
        // Quota before queue: a submission blocked on queue space must
        // already hold its quota, so a tenant cannot overcommit by
        // stacking blocked pushers.
        if blocking {
            if self.ledger.charge_blocking(tenant, njobs, bytes).is_err() {
                emit_each(EventKind::Shed);
                return Err(SubmitError::Closed(s));
            }
        } else if !self.ledger.try_charge(tenant, njobs, bytes) {
            emit_each(EventKind::QuotaReject);
            return Err(SubmitError::QuotaExceeded { submission: s, tenant });
        }
        let deadline = s.deadline.map(|d| Instant::now() + d);
        let meta = AdmitMeta {
            tenant,
            weight: self.ledger.weight(tenant),
            cost: njobs,
            deadline,
            predicted_secs: self.predict_submission(&s),
        };
        let (tickets, item) = self.build_item(s, deadline, base_uid);
        let fut = JobFuture::new(tickets);
        let res = if blocking {
            self.admission.push_blocking(meta, item).map_err(TryPushError::Closed)
        } else {
            self.admission.try_push(meta, item)
        };
        match res {
            Ok(()) => {
                emit_each(EventKind::Admit);
                Ok(fut)
            }
            Err(e) => {
                emit_each(EventKind::Shed);
                let (full, item) = match e {
                    TryPushError::Full(i) => (true, i),
                    TryPushError::Closed(i) => (false, i),
                };
                // Rebuilding drops the item's replies — and with them
                // the quota slots, so the charge above releases here.
                let s = reclaim_submission(item, deadline);
                Err(if full { SubmitError::Full(s) } else { SubmitError::Closed(s) })
            }
        }
    }

    /// Split one submission into its reply tickets and queue item,
    /// minting one quota slot per job. Each slot carries its job's
    /// inline bytes; a shared B is billed to the first sub (the split
    /// is an accounting detail — only the per-tenant totals matter).
    fn build_item(
        &self,
        s: Submission,
        deadline: Option<Instant>,
        base_uid: u64,
    ) -> (Vec<JobTicket>, QueueItem) {
        let now = Instant::now();
        let tenant = s.tenant;
        let slot = |bytes: usize| Some(TenantSlot::new(self.ledger.clone(), tenant, bytes));
        match s.kind {
            SubmissionKind::Gemm { a, b } => {
                let bytes = a.quota_bytes() + b.quota_bytes();
                let (tx, rx) = mpsc::channel();
                let adm = Admitted {
                    job: GemmJob { id: s.id, a, b, run: s.run },
                    reply: Reply { tx, _slot: slot(bytes) },
                    accepted_at: now,
                    tenant,
                    deadline,
                    uid: base_uid,
                    dtype: s.dtype,
                };
                (vec![JobTicket::new(s.id, rx)], QueueItem::One(adm))
            }
            SubmissionKind::Group(jobs) => {
                let mut tickets = Vec::with_capacity(jobs.len());
                let mut subs = Vec::with_capacity(jobs.len());
                for (i, j) in jobs.into_iter().enumerate() {
                    let bytes = j.a.quota_bytes() + j.b.quota_bytes();
                    let (tx, rx) = mpsc::channel();
                    tickets.push(JobTicket::new(j.id, rx));
                    subs.push(Admitted {
                        // A member without its own pin inherits the
                        // submission-level one.
                        job: GemmJob { run: j.run.or(s.run), ..j },
                        reply: Reply { tx, _slot: slot(bytes) },
                        accepted_at: now,
                        tenant,
                        deadline,
                        uid: base_uid + i as u64,
                        dtype: s.dtype,
                    });
                }
                (tickets, QueueItem::Group(subs))
            }
            SubmissionKind::SharedB { b, many_a } => {
                let b_bytes = b.quota_bytes();
                let mut tickets = Vec::with_capacity(many_a.len());
                let mut subs = Vec::with_capacity(many_a.len());
                for (i, a) in many_a.into_iter().enumerate() {
                    let bytes = a.quota_bytes() + if i == 0 { b_bytes } else { 0 };
                    let (tx, rx) = mpsc::channel();
                    let id = s.id + i as u64;
                    tickets.push(JobTicket::new(id, rx));
                    subs.push(SharedSub {
                        id,
                        a,
                        reply: Reply { tx, _slot: slot(bytes) },
                        accepted_at: now,
                        tenant,
                        deadline,
                        uid: base_uid + i as u64,
                    });
                }
                (tickets, QueueItem::SharedB(SharedBatch { b, run: s.run, subs, dtype: s.dtype }))
            }
        }
    }

    /// Modeled execution time for deadline-slack ordering: per-job
    /// [`crate::analytical::predict`] under the job-pin → submission-pin
    /// → server-default cascade. Work the model cannot price before
    /// dispatch (no config short of the DSE, unknown dims) contributes
    /// zero and sorts as pure earliest-deadline-first; submissions
    /// without a deadline skip the model walk entirely.
    fn predict_submission(&self, s: &Submission) -> f64 {
        if s.deadline.is_none() {
            return 0.0;
        }
        let shared = &self.shared;
        let dims_a = |a: &AOperand| match a {
            AOperand::Inline(m) => Some((m.rows, m.cols)),
            AOperand::Registered(h) => shared.operands.dims_a(*h),
            AOperand::Fused(f) => Some((f.rows, f.cols)),
        };
        let dims_b = |b: &BOperand| match b {
            BOperand::Inline(m) => Some((m.rows, m.cols)),
            BOperand::Registered(h) => shared.operands.dims(*h),
            BOperand::Fused(f) => Some((f.rows, f.cols)),
        };
        let predict = |run: Option<RunConfig>, m: usize, k: usize, n: usize| -> f64 {
            let Some(run) = run.or(shared.cfg.default_run) else { return 0.0 };
            crate::analytical::predict(&shared.hw, &run, m, k, n, shared.accelerator.surface())
                .map(|p| p.t_overlap())
                .unwrap_or(0.0)
        };
        match &s.kind {
            SubmissionKind::Gemm { a, b } => match (dims_a(a), dims_b(b)) {
                (Some((m, k)), Some((_, n))) => predict(s.run, m, k, n),
                _ => 0.0,
            },
            SubmissionKind::Group(jobs) => jobs
                .iter()
                .map(|j| match (dims_a(&j.a), dims_b(&j.b)) {
                    (Some((m, k)), Some((_, n))) => predict(j.run.or(s.run), m, k, n),
                    _ => 0.0,
                })
                .sum(),
            SubmissionKind::SharedB { b, many_a } => {
                let Some((_, n)) = dims_b(b) else { return 0.0 };
                many_a
                    .iter()
                    .map(|a| match dims_a(a) {
                        Some((m, k)) => predict(s.run, m, k, n),
                        None => 0.0,
                    })
                    .sum()
            }
        }
    }

    /// Submit one job; blocks while the admission queue is full
    /// (backpressure) and errors once the server is shutting down.
    #[deprecated(note = "use `submit_async(Submission::gemm(a, b))` or `submit_blocking`")]
    pub fn submit(&self, job: GemmJob) -> anyhow::Result<JobTicket> {
        let id = job.id;
        let fut = self
            .admit(job.into(), true)
            .map_err(|_| anyhow::anyhow!("server closed; job {id} rejected"))?;
        Ok(fut.into_tickets().pop().expect("one-job submission yields one ticket"))
    }

    /// Submit jobs as one admission unit: the dispatcher coalesces the
    /// sub-threshold ones into batched super-jobs deterministically
    /// (no reliance on queue-timing races). Blocks under backpressure.
    #[deprecated(note = "use `submit_async(Submission::group(jobs))`")]
    pub fn submit_batch(&self, jobs: Vec<GemmJob>) -> anyhow::Result<Vec<JobTicket>> {
        anyhow::ensure!(!jobs.is_empty(), "empty batch");
        let fut = self
            .admit(Submission::group(jobs), true)
            .map_err(|_| anyhow::anyhow!("server closed; batch rejected"))?;
        Ok(fut.into_tickets())
    }

    /// Submit jobs as one admission unit and get a joint handle back:
    /// [`JobGroup::wait_all`] resolves the whole group in submission
    /// order. Same admission semantics as [`JobServer::submit_batch`].
    #[deprecated(note = "use `submit_async(Submission::group(jobs))`")]
    pub fn submit_group(&self, jobs: Vec<GemmJob>) -> anyhow::Result<JobGroup> {
        Ok(JobGroup { tickets: self.submit_batch(jobs)? })
    }

    /// Submit a shared-operand batch: `many_a[i] x b` for every A, with
    /// B packed **at most once** and its `Arc<PackedB>` shared by all
    /// sub-jobs (CNN inference's shape: one filter matrix, a batch of
    /// im2col'd images). `b` is any [`BOperand`]: an inline `Matrix`
    /// packs once for this call; a [`WeightHandle`] resolves through
    /// the operand registry, so a repeat call under the same handle
    /// packs **zero** times (a registry hit). The whole batch is one
    /// admission unit and one dispatched super-job; every sub-job runs
    /// with the same block configuration (`run`, else the server
    /// default, else the DSE optimum for the largest sub-problem —
    /// valid for all since K and N are shared). Results come back in
    /// `many_a` order with `JobResult::id` = the A's index, and are
    /// bit-identical to submitting each pair individually: the packed
    /// layout of an operand depends only on its own shape and block
    /// size, and each C element accumulates in ascending-k order
    /// regardless of batching. Blocks under backpressure like
    /// [`JobServer::submit`].
    #[deprecated(note = "use `submit_async(Submission::batched(b, many_a))`")]
    pub fn submit_batched_gemm(
        &self,
        b: impl Into<BOperand>,
        many_a: Vec<Matrix>,
        run: Option<RunConfig>,
    ) -> anyhow::Result<JobGroup> {
        self.submit_batched_gemm_operands(
            b,
            many_a.into_iter().map(AOperand::from).collect(),
            run,
        )
    }

    /// [`JobServer::submit_batched_gemm`] generalized to [`AOperand`]s:
    /// each member of `many_a` is inline, or a registered activation
    /// handle whose cached `Arc<PackedA>` resolves at dispatch — one A
    /// pack per `(handle, S_i)` across *calls*, so a fully-registered
    /// workload (attention: one activation batch against Q/K/V/O weight
    /// handles) packs nothing at steady state. Semantics otherwise
    /// identical, including bit-identical results to inline submission:
    /// a cached pack holds the same bytes a private pack of the same
    /// matrix would.
    #[deprecated(note = "use `submit_async(Submission::batched(b, many_a))`")]
    pub fn submit_batched_gemm_operands(
        &self,
        b: impl Into<BOperand>,
        many_a: Vec<AOperand>,
        run: Option<RunConfig>,
    ) -> anyhow::Result<JobGroup> {
        anyhow::ensure!(!many_a.is_empty(), "empty shared-B batch");
        let mut s = Submission::batched(b, many_a);
        s.run = run;
        let fut = self
            .admit(s, true)
            .map_err(|_| anyhow::anyhow!("server closed; shared-B batch rejected"))?;
        Ok(JobGroup { tickets: fut.into_tickets() })
    }

    /// Non-blocking [`JobServer::submit_batched_gemm`]: rejects with
    /// **all operands handed back** when the admission queue is full
    /// (shed load) or the server is closed, so shared-B traffic
    /// respects the same backpressure contract as
    /// [`JobServer::try_submit`].
    #[deprecated(note = "use `try_submit(Submission::batched(b, many_a))`")]
    pub fn try_submit_batched_gemm(
        &self,
        b: impl Into<BOperand>,
        many_a: Vec<Matrix>,
        run: Option<RunConfig>,
    ) -> Result<JobGroup, TrySubmitBatchedError> {
        if many_a.is_empty() {
            return Err(TrySubmitBatchedError::Empty);
        }
        let mut s = Submission::batched(b, many_a);
        s.run = run;
        match self.admit(s, false) {
            Ok(fut) => Ok(JobGroup { tickets: fut.into_tickets() }),
            Err(e) => {
                let (full, s) = match e {
                    SubmitError::Full(s) => (true, s),
                    SubmitError::Closed(s) => (false, s),
                    // The default tenant runs unlimited, but map the
                    // variant anyway: quota pressure is backpressure.
                    SubmitError::QuotaExceeded { submission, .. } => (true, submission),
                    SubmitError::Invalid(msg) => {
                        unreachable!("non-empty batch rejected as invalid: {msg}")
                    }
                };
                let SubmissionKind::SharedB { b, many_a } = s.into_kind() else {
                    unreachable!("shared-B batch came back as another submission kind")
                };
                // This entry point only ever builds inline subs, so the
                // hand-back unwrap cannot miss.
                let many_a = many_a
                    .into_iter()
                    .map(|a| a.into_inline().expect("try-submit subs are inline"))
                    .collect();
                Err(if full {
                    TrySubmitBatchedError::Full { b, many_a }
                } else {
                    TrySubmitBatchedError::Closed { b, many_a }
                })
            }
        }
    }

    /// Register a B operand as server-resident weight state — the
    /// inference-server model-load step. The matrix is stored once;
    /// its packed form is built lazily, at most once per block size,
    /// and reused by every submission whose [`BOperand`] carries the
    /// returned handle. See [`OperandRegistry`] for eviction semantics.
    pub fn register_b(&self, b: Matrix) -> anyhow::Result<WeightHandle> {
        self.shared.operands.register(b)
    }

    /// [`JobServer::register_b`] billed to a specific tenant, so
    /// [`JobServer::tenant_residency`] attributes the resident packs to
    /// whoever loaded the model.
    pub fn register_b_for(&self, b: Matrix, tenant: TenantId) -> anyhow::Result<WeightHandle> {
        self.shared.operands.register_for(b, tenant)
    }

    /// Drop a registered weight and its cached packs. In-flight jobs
    /// holding the pack finish unaffected; later submissions under the
    /// handle fail through their tickets.
    pub fn unregister_b(&self, h: WeightHandle) -> anyhow::Result<()> {
        self.shared.operands.unregister(h)
    }

    /// Unregister a whole set of weights, continuing through individual
    /// failures (e.g. a handle already dropped directly) so a partial
    /// error never leaks the remaining registrations; the first error
    /// is reported after the sweep. The weight-set owners
    /// (`cnn::schedule::NetworkWeights`, `strassen::StrassenWeights`)
    /// release through this.
    pub fn unregister_all(
        &self,
        handles: impl IntoIterator<Item = WeightHandle>,
    ) -> anyhow::Result<()> {
        let mut first_err = None;
        for h in handles {
            if let Err(e) = self.unregister_b(h) {
                self.shared.metrics.add_unregister_failures(1);
                first_err.get_or_insert(e);
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Register an A operand as server-resident activation state — the
    /// symmetric twin of [`JobServer::register_b`], for traffic that
    /// reuses the *A* side (attention: one activation batch multiplied
    /// against the whole Q/K/V/O weight set). The matrix is stored
    /// once; its packed form builds lazily, at most once per
    /// `(handle, S_i)`, in the same byte-budgeted, refcount-pinned LRU
    /// cache the B side uses.
    pub fn register_a(&self, a: Matrix) -> anyhow::Result<ActivationHandle> {
        self.shared.operands.register_a(a)
    }

    /// [`JobServer::register_a`] billed to a specific tenant.
    pub fn register_a_for(&self, a: Matrix, tenant: TenantId) -> anyhow::Result<ActivationHandle> {
        self.shared.operands.register_a_for(a, tenant)
    }

    /// Per-tenant registry footprint: live operands, resident pack
    /// bytes, and the pinned share — see
    /// [`super::registry::OperandRegistry::tenant_residency`].
    pub fn tenant_residency(&self) -> Vec<(TenantId, super::registry::TenantResidency)> {
        self.shared.operands.tenant_residency()
    }

    /// Drop a registered activation and its cached packs. In-flight
    /// jobs holding a pack finish unaffected; later submissions under
    /// the handle fail through their tickets.
    pub fn unregister_a(&self, h: ActivationHandle) -> anyhow::Result<()> {
        self.shared.operands.unregister_a(h)
    }

    /// Unregister a whole set of activations with the same
    /// sweep-then-report contract as [`JobServer::unregister_all`];
    /// individual failures are counted in `Metrics::unregister_failures`.
    pub fn unregister_all_a(
        &self,
        handles: impl IntoIterator<Item = ActivationHandle>,
    ) -> anyhow::Result<()> {
        let mut first_err = None;
        for h in handles {
            if let Err(e) = self.unregister_a(h) {
                self.shared.metrics.add_unregister_failures(1);
                first_err.get_or_insert(e);
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// The server-resident operand registry (resident bytes, live
    /// weight count — the cache the dispatcher resolves handles in).
    pub fn operand_registry(&self) -> &OperandRegistry {
        &self.shared.operands
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.shared.metrics.clone()
    }

    pub fn hw(&self) -> &HardwareConfig {
        &self.shared.hw
    }

    /// The calibrated bandwidth surface of the server's accelerator —
    /// what planners (DSE, Strassen crossover) evaluate the analytical
    /// model against.
    pub fn surface(&self) -> &crate::analytical::BandwidthSurface {
        self.shared.accelerator.surface()
    }

    /// Jobs currently waiting in the admission queue.
    pub fn queue_depth(&self) -> usize {
        self.admission.len()
    }

    /// Consistent snapshot of the flight recorder: every stable event
    /// in generation order, plus the recorded/overwritten totals. Empty
    /// (and allocation-free) when `ServerConfig::trace_capacity` is 0.
    pub fn trace_snapshot(&self) -> TraceSnapshot {
        self.shared.trace.snapshot()
    }

    /// Whether the flight recorder is collecting events.
    pub fn trace_enabled(&self) -> bool {
        self.shared.trace.enabled()
    }

    /// Open a workload-level span on the trace (Strassen recursion
    /// level, CNN layer, attention phase). `detail` is the span's
    /// kind-specific payload — a level / layer / phase index. No-op
    /// when tracing is disabled; spans render as their own track in the
    /// Chrome export.
    pub fn trace_span_begin(&self, kind: SpanKind, detail: u64) {
        self.shared.trace.emit(
            EventKind::SpanBegin,
            kind as u32 as u64,
            ACTOR_NONE,
            ACTOR_NONE,
            detail,
            0,
        );
    }

    /// Close the innermost span of `kind` (see
    /// [`JobServer::trace_span_begin`]).
    pub fn trace_span_end(&self, kind: SpanKind, detail: u64) {
        self.shared.trace.emit(
            EventKind::SpanEnd,
            kind as u32 as u64,
            ACTOR_NONE,
            ACTOR_NONE,
            detail,
            0,
        );
    }

    /// Server-level snapshot (throughput, percentiles, idle fraction).
    pub fn stats(&self) -> ServerStats {
        let m = &self.shared.metrics;
        let uptime = self.shared.started.elapsed().as_secs_f64();
        let busy_secs = self
            .shared
            .worker_busy
            .iter()
            .map(|b| b.load(Ordering::Relaxed) as f64 / 1e9)
            .sum::<f64>();
        let denom = uptime * self.shared.cfg.workers as f64;
        let idle = if denom > 0.0 { (1.0 - busy_secs / denom).clamp(0.0, 1.0) } else { 0.0 };
        // One latency snapshot feeds mean and every percentile — a
        // single pass over one consistent copy of the reservoir.
        let lat = m.latency_snapshot();
        let pcts = lat.percentiles(&[0.50, 0.95, 0.99]);
        let per_worker_tasks: Vec<u64> =
            self.shared.worker_tasks.iter().map(|t| t.load(Ordering::Relaxed)).collect();
        let per_worker_steals: Vec<u64> =
            self.shared.worker_steals.iter().map(|t| t.load(Ordering::Relaxed)).collect();
        let max_t = per_worker_tasks.iter().copied().max().unwrap_or(0);
        let min_t = per_worker_tasks.iter().copied().min().unwrap_or(0);
        let worker_imbalance = match (max_t, min_t) {
            (0, _) => 0.0,
            (_, 0) => f64::INFINITY,
            (max, min) => max as f64 / min as f64,
        };
        let stage_p50_p95_secs = if self.shared.trace.enabled() {
            let traces = self.shared.trace.snapshot().job_traces();
            stage_percentiles(&traces, &[0.50, 0.95]).map(|per_stage| {
                let mut out = [(0.0, 0.0); 5];
                for (slot, ps) in out.iter_mut().zip(&per_stage) {
                    *slot = (ps[0], ps[1]);
                }
                out
            })
        } else {
            None
        };
        ServerStats {
            jobs: m.jobs(),
            jobs_failed: m.jobs_failed(),
            tasks: m.tasks(),
            steals: m.steals(),
            cross_job_steals: m.cross_job_steals(),
            batched_jobs: m.batched_jobs(),
            shared_b_groups: m.shared_b_groups(),
            registry_hits: m.registry_hits(),
            registry_misses: m.registry_misses(),
            registry_evictions: m.registry_evictions(),
            registry_a_hits: m.registry_a_hits(),
            registry_a_misses: m.registry_a_misses(),
            registry_a_evictions: m.registry_a_evictions(),
            registry_resident_bytes: m.registry_resident_bytes(),
            registry_a_resident_bytes: m.registry_a_resident_bytes(),
            registry_dtype_resident_bytes: std::array::from_fn(|i| {
                m.registry_dtype_resident_bytes(i)
            }),
            registered_weights: self.shared.operands.registered_weights() as u64,
            registered_activations: self.shared.operands.registered_activations() as u64,
            plan_residency_hits: m.plan_residency_hits(),
            unregister_failures: m.unregister_failures(),
            panel_copies: m.panel_copies(),
            a_panel_packs: m.a_panel_packs(),
            b_panel_packs: m.b_panel_packs(),
            panels_shared: m.panels_shared(),
            uptime_secs: uptime,
            throughput_jobs_per_sec: if uptime > 0.0 { m.jobs() as f64 / uptime } else { 0.0 },
            latency_mean_secs: lat.mean,
            latency_p50_secs: pcts[0],
            latency_p95_secs: pcts[1],
            latency_p99_secs: pcts[2],
            deadline_jobs: m.deadline_jobs(),
            deadline_misses: m.deadline_misses(),
            tenants: m.tenant_counters(),
            worker_busy_secs: busy_secs,
            worker_idle_frac: idle,
            per_worker_tasks,
            per_worker_steals,
            worker_imbalance,
            drift: m.drift_stats(),
            stage_p50_p95_secs,
            trace_recorded: self.shared.trace.recorded(),
            trace_dropped: self.shared.trace.dropped(),
        }
    }

    /// Graceful shutdown: stop admitting, dispatch what was admitted,
    /// finish every in-flight job (tickets still resolve), then join the
    /// pool. `Drop` does the same.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.admission.close();
        // Unblock submitters waiting on tenant quota, not just on queue
        // space — they error out instead of hanging on a closing server.
        self.ledger.close();
        for d in self.dispatchers.drain(..) {
            let _ = d.join();
        }
        // Wait for registered jobs to drain; unregister bumps the gate.
        loop {
            if self.shared.inflight.load(Ordering::Acquire) == 0 {
                break;
            }
            let seen = self.shared.gate.current();
            if self.shared.inflight.load(Ordering::Acquire) == 0 {
                break;
            }
            self.shared.gate.wait_past(seen);
        }
        self.shared.stop.store(true, Ordering::Release);
        self.shared.gate.bump();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for JobServer {
    fn drop(&mut self) {
        if !self.dispatchers.is_empty() || !self.workers.is_empty() {
            self.shutdown_inner();
        }
    }
}

/// Plan one submission: validate, choose the run config, price it with
/// the analytical model (the job's drift baseline), build the block
/// grid. On failure the submitter gets the error through its ticket and
/// `None` comes back. `shard` tags the trace events with the planning
/// dispatcher.
fn plan_one(shared: &Shared, s: Admitted, shard: usize) -> Option<Planned> {
    let planned = (|| -> anyhow::Result<(RunConfig, BlockPlan, f64)> {
        // A registered operand plans from the registry's recorded dims;
        // the pack itself resolves at activation.
        let (a_rows, a_cols) = match &s.job.a {
            AOperand::Inline(m) => (m.rows, m.cols),
            AOperand::Registered(h) => shared
                .operands
                .dims_a(*h)
                .ok_or_else(|| anyhow::anyhow!("{h} is not registered"))?,
            AOperand::Fused(f) => {
                // An out-of-window fused operand fails its job here,
                // before any panels are packed from clipped views.
                f.validate()?;
                (f.rows, f.cols)
            }
        };
        let (b_rows, b_cols) = match &s.job.b {
            BOperand::Inline(m) => (m.rows, m.cols),
            BOperand::Registered(h) => shared
                .operands
                .dims(*h)
                .ok_or_else(|| anyhow::anyhow!("{h} is not registered"))?,
            BOperand::Fused(f) => {
                f.validate()?;
                (f.rows, f.cols)
            }
        };
        anyhow::ensure!(a_cols == b_rows, "contraction mismatch");
        // BlockPlan::new panics on zero dims; in a server that would
        // take the dispatcher thread down — reject the job instead.
        anyhow::ensure!(
            a_rows > 0 && a_cols > 0 && b_cols > 0,
            "degenerate problem {a_rows}x{a_cols}x{b_cols}",
        );
        // Channel-fed backends gather f32 panels per task; reduced
        // precision exists only on the packed in-process path.
        anyhow::ensure!(
            s.dtype == Dtype::F32 || shared.engine.is_inprocess(),
            "dtype {} requires an in-process engine",
            s.dtype,
        );
        let run = choose_run_dims(
            &shared.hw,
            shared.accelerator.surface(),
            a_rows,
            a_cols,
            b_cols,
            s.job.run,
            shared.cfg.default_run,
        )?;
        let a_sis = s.job.a.handle().map(|h| shared.operands.resident_a_sis_dtype(h, s.dtype));
        let b_sjs = s.job.b.handle().map(|h| shared.operands.resident_b_sjs_dtype(h, s.dtype));
        let run = refine_run_for_residency(
            shared,
            run,
            a_sis.as_deref(),
            b_sjs.as_deref(),
            a_rows,
            a_cols,
            b_cols,
        );
        let plan = BlockPlan::new(a_rows, a_cols, b_cols, run.si, run.sj);
        let predicted = predict_run(shared, &run, a_rows, a_cols, b_cols);
        Ok((run, plan, predicted))
    })();
    match planned {
        Ok((run, plan, predicted)) => {
            shared.trace.emit(
                EventKind::Planned,
                s.uid,
                s.tenant.0,
                shard as u32,
                predicted.to_bits(),
                plan.num_tasks() as u64,
            );
            let small = plan.num_tasks() <= shared.cfg.batch_max_tasks;
            Some(Planned { sub: s, run, plan, small, predicted })
        }
        Err(e) => {
            shared.trace.emit(EventKind::PlanFail, s.uid, s.tenant.0, shard as u32, 0, 0);
            shared.metrics.job_failed();
            s.reply.send(Err(e));
            None
        }
    }
}

/// Price a `(run, m, k, n)` with the analytical model; 0.0 when the
/// model rejects the configuration (drift records then skip the job —
/// `Metrics::record_drift` guards non-positive predictions).
fn predict_run(shared: &Shared, run: &RunConfig, m: usize, k: usize, n: usize) -> f64 {
    crate::analytical::predict(&shared.hw, run, m, k, n, shared.accelerator.surface())
        .map(|p| p.t_overlap())
        .unwrap_or(0.0)
}

/// Registry-aware run refinement: when a submission's registered
/// operands already hold packed variants for some block sizes, steer
/// the planner's baseline toward an `(S_i, S_j)` that is resident —
/// turning a would-be repack miss into a cache hit — as long as the
/// analytical model prices the switch within
/// `ServerConfig::plan_residency_slack` of the baseline. A side passes
/// `None` when unregistered (its baseline parameter is kept) and its
/// resident block sizes otherwise; an empty set also keeps that side's
/// baseline parameter (nothing resident means every choice repacks
/// there, but the *other* side may still be steerable). A switch away
/// from the baseline counts in `Metrics::plan_residency_hits`.
fn refine_run_for_residency(
    shared: &Shared,
    baseline: RunConfig,
    resident_sis: Option<&[usize]>,
    resident_sjs: Option<&[usize]>,
    m: usize,
    k: usize,
    n: usize,
) -> RunConfig {
    let slack = shared.cfg.plan_residency_slack;
    if slack < 0.0 || (resident_sis.is_none() && resident_sjs.is_none()) {
        return baseline;
    }
    // A side is satisfied when unregistered, or when its resident set
    // already holds the baseline block size. Fully satisfied means the
    // baseline repacks nothing residency could save — keep it without
    // consulting the cost model.
    let si_satisfied = resident_sis.is_none_or(|v| v.contains(&baseline.si));
    let sj_satisfied = resident_sjs.is_none_or(|v| v.contains(&baseline.sj));
    if si_satisfied && sj_satisfied {
        return baseline;
    }
    let sis: Vec<usize> = match resident_sis {
        Some(v) if !v.is_empty() => v.to_vec(),
        _ => vec![baseline.si],
    };
    let sjs: Vec<usize> = match resident_sjs {
        Some(v) if !v.is_empty() => v.to_vec(),
        _ => vec![baseline.sj],
    };
    let surface = shared.accelerator.surface();
    let Ok(base_cost) =
        crate::analytical::predict(&shared.hw, &baseline, m, k, n, surface).map(|p| p.t_overlap())
    else {
        return baseline;
    };
    let mut best: Option<(f64, RunConfig)> = None;
    for &si in &sis {
        for &sj in &sjs {
            // Keep the baseline's array split when it stays feasible
            // for the candidate block sizes; fall back to the first
            // feasible split otherwise (residency is about S, not N_p).
            let candidate = std::iter::once(baseline.np)
                .chain(crate::analytical::feasible_nps(&shared.hw, si))
                .map(|np| RunConfig::new(np, si, sj))
                .find(|run| run.validate(&shared.hw).is_ok());
            let Some(run) = candidate else { continue };
            let Ok(p) = crate::analytical::predict(&shared.hw, &run, m, k, n, surface) else {
                continue;
            };
            let cost = p.t_overlap();
            if best.as_ref().is_none_or(|(c, _)| cost < *c) {
                best = Some((cost, run));
            }
        }
    }
    match best {
        Some((cost, run)) if run != baseline && cost <= base_cost * (1.0 + slack) => {
            shared.metrics.add_plan_residency_hits(1);
            run
        }
        _ => baseline,
    }
}

/// Build the active job for `planned` (one sub = a plain job, several =
/// a batched super-job), pack panels, publish the combined task set into
/// a fresh per-job WQM, and register it for the workers.
///
/// Blocks while the in-flight bound is reached, which is what makes the
/// admission queue's backpressure real: the dispatcher stops draining,
/// the queue fills, and `submit` blocks — so total server memory is
/// bounded by `queue_capacity` queued plus `max(queue_capacity,
/// workers)` active jobs, not by the arrival rate.
fn activate(shared: &Arc<Shared>, planned: Vec<Planned>, shard: usize) {
    debug_assert!(!planned.is_empty());
    wait_for_inflight_slot(shared);
    // Resolve every sub's operands first: an inline side wraps (and
    // packs) here, a registered handle resolves through the operand
    // registry — and a handle unregistered since planning fails that
    // sub alone through its ticket while the rest of the batch
    // proceeds.
    struct Build {
        id: u64,
        run: RunConfig,
        plan: BlockPlan,
        a: ExecOperand,
        packed_a: Option<Arc<PackedA>>,
        b: ExecOperand,
        packed_b: Option<Arc<PackedB>>,
        reply: Reply,
        accepted_at: Instant,
        tenant: TenantId,
        deadline: Option<Instant>,
        uid: u64,
        predicted: f64,
    }
    let inprocess = shared.engine.is_inprocess();
    let mut builds: Vec<Build> = Vec::with_capacity(planned.len());
    for p in planned {
        let Planned { sub, run, plan, predicted, .. } = p;
        let Admitted { job, reply, accepted_at, tenant, deadline, uid, dtype } = sub;
        let GemmJob { id, a, b, .. } = job;
        let resolved = (|| -> anyhow::Result<_> {
            let (a, packed_a) = resolve_a_operand(shared, a, run.si, dtype, inprocess)?;
            let (b, packed_b) = match b {
                BOperand::Inline(m) => {
                    let m = Arc::new(m);
                    let packed = if inprocess {
                        shared.metrics.add_b_panel_packs(1);
                        Some(Arc::new(PackedB::pack_dtype(m.view(), run.sj, dtype)))
                    } else {
                        None
                    };
                    (ExecOperand::Full(m), packed)
                }
                BOperand::Registered(h) => {
                    let m = shared
                        .operands
                        .matrix(h)
                        .ok_or_else(|| anyhow::anyhow!("{h} is not registered"))?;
                    let packed = if inprocess {
                        Some(shared.operands.resolve_pack_dtype(h, run.sj, dtype)?)
                    } else {
                        None
                    };
                    (ExecOperand::Full(m), packed)
                }
                BOperand::Fused(f) => {
                    if inprocess {
                        // The combine happens inside the pack pass; the
                        // operand never exists as a matrix.
                        shared.metrics.add_b_panel_packs(1);
                        shared.metrics.add_fused_packs(1);
                        let packed = Arc::new(f.pack_b_dtype(run.sj, dtype));
                        (
                            ExecOperand::Packed { rows: f.rows, cols: f.cols },
                            Some(packed),
                        )
                    } else {
                        // Channel-fed backends gather per task and need
                        // the full operand — materialize once here.
                        (ExecOperand::Full(Arc::new(f.materialize())), None)
                    }
                }
            };
            Ok((a, packed_a, b, packed_b))
        })();
        match resolved {
            Ok((a, packed_a, b, packed_b)) => builds.push(Build {
                id,
                run,
                plan,
                a,
                packed_a,
                b,
                packed_b,
                reply,
                accepted_at,
                tenant,
                deadline,
                uid,
                predicted,
            }),
            Err(e) => {
                shared.trace.emit(EventKind::Fail, uid, tenant.0, shard as u32, 0, 0);
                shared.metrics.job_failed();
                reply.send(Err(e));
            }
        }
    }
    if builds.is_empty() {
        return;
    }
    let batched = builds.len() > 1;
    if batched {
        shared.metrics.add_batched_jobs(builds.len() as u64);
    }
    let mut subs = Vec::with_capacity(builds.len());
    let mut tasks: Vec<SubTask> = Vec::new();
    for (i, build) in builds.into_iter().enumerate() {
        for task in build.plan.tasks() {
            tasks.push(SubTask { sub: i as u32, task });
        }
        let panels = match (build.packed_a, build.packed_b) {
            (Some(pa), Some(pb)) => Some(PackedPanels::from_parts(pa, pb)),
            _ => None,
        };
        subs.push(build_sub(
            build.id,
            build.run,
            build.a,
            build.b,
            panels,
            build.plan.num_tasks(),
            build.reply,
            build.accepted_at,
            batched,
            build.tenant,
            build.deadline,
            build.uid,
            build.predicted,
        ));
    }
    publish(shared, subs, tasks, shard);
}

/// Resolve one A operand for execution under block size `si`: an inline
/// matrix wraps and (on in-process engines) packs privately; a
/// registered activation borrows the registry's `Arc<Matrix>` and
/// resolves its cached `Arc<PackedA>` — a registry hit packs nothing;
/// a fused operand packs its combination straight from its parent
/// views (no materialized matrix on in-process engines).
fn resolve_a_operand(
    shared: &Shared,
    a: AOperand,
    si: usize,
    dtype: Dtype,
    inprocess: bool,
) -> anyhow::Result<(ExecOperand, Option<Arc<PackedA>>)> {
    match a {
        AOperand::Inline(m) => {
            let m = Arc::new(m);
            let packed = if inprocess {
                shared.metrics.add_a_panel_packs(1);
                Some(Arc::new(PackedA::pack_dtype(m.view(), si, dtype)))
            } else {
                None
            };
            Ok((ExecOperand::Full(m), packed))
        }
        AOperand::Registered(h) => {
            let m = shared
                .operands
                .matrix_a(h)
                .ok_or_else(|| anyhow::anyhow!("{h} is not registered"))?;
            let packed = if inprocess {
                Some(shared.operands.resolve_pack_a_dtype(h, si, dtype)?)
            } else {
                None
            };
            Ok((ExecOperand::Full(m), packed))
        }
        AOperand::Fused(f) => {
            if inprocess {
                shared.metrics.add_a_panel_packs(1);
                shared.metrics.add_fused_packs(1);
                let packed = Arc::new(f.pack_a_dtype(si, dtype));
                Ok((ExecOperand::Packed { rows: f.rows, cols: f.cols }, Some(packed)))
            } else {
                Ok((ExecOperand::Full(Arc::new(f.materialize())), None))
            }
        }
    }
}

/// Block while the in-flight bound is reached. Job retirement bumps the
/// gate; workers drain independently of the dispatcher, so this always
/// makes progress.
fn wait_for_inflight_slot(shared: &Shared) {
    let inflight_bound = shared.cfg.queue_capacity.max(shared.cfg.workers);
    loop {
        let seen = shared.gate.current();
        if shared.inflight.load(Ordering::Acquire) < inflight_bound {
            break;
        }
        shared.gate.wait_past(seen);
    }
}

/// Assemble one [`SubJob`] with its owned C storage and raw writer
/// handle (shared by the plain and shared-B activation paths).
#[allow(clippy::too_many_arguments)]
fn build_sub(
    id: u64,
    run: RunConfig,
    a: ExecOperand,
    b: ExecOperand,
    panels: Option<PackedPanels>,
    num_tasks: usize,
    reply: Reply,
    accepted_at: Instant,
    batched: bool,
    tenant: TenantId,
    deadline: Option<Instant>,
    uid: u64,
    predicted_secs: f64,
) -> SubJob {
    let mut c = Matrix::zeros(a.rows(), b.cols());
    let raw = RawOut { ptr: c.data.as_mut_ptr(), rows: c.rows, cols: c.cols };
    SubJob {
        id,
        run,
        a,
        b,
        panels,
        pending: AtomicUsize::new(num_tasks),
        out: Mutex::new(Some(c)),
        raw,
        error: Mutex::new(None),
        reply: Mutex::new(Some(reply)),
        accepted_at,
        batched,
        tenant,
        deadline,
        uid,
        predicted_secs,
    }
}

/// Register one active (super-)job: round-robin the combined task set
/// over the pool's queues — the same initial static partition a single
/// job's WQM gets — and wake the workers.
fn publish(shared: &Arc<Shared>, subs: Vec<SubJob>, tasks: Vec<SubTask>, shard: usize) {
    if shared.trace.enabled() {
        for sub in &subs {
            shared.trace.emit(
                EventKind::Published,
                sub.uid,
                sub.tenant.0,
                shard as u32,
                sub.pending.load(Ordering::Relaxed) as u64,
                subs.len() as u64,
            );
        }
    }
    let mut partition: Vec<Vec<SubTask>> = vec![Vec::new(); shared.cfg.workers];
    for (i, st) in tasks.into_iter().enumerate() {
        partition[i % shared.cfg.workers].push(st);
    }
    let subs_pending = AtomicUsize::new(subs.len());
    let job = Arc::new(ActiveJob {
        wqm: AtomicWqm::from_partition(partition),
        subs,
        subs_pending,
    });
    shared.inflight.fetch_add(1, Ordering::AcqRel);
    shared.registry.register(job);
    shared.gate.bump();
}

/// What the dispatcher carries over to its next iteration when batch
/// accumulation runs into a non-batchable item.
enum Carry {
    Fresh(QueueItem),
    Planned(Planned),
}

/// Stamp a `Pop` for every sub-job of a freshly-popped queue item:
/// the end of the queue-wait stage for each of them, tagged with the
/// dispatcher shard that took the item.
fn emit_pops(shared: &Shared, item: &QueueItem, shard: usize) {
    if !shared.trace.enabled() {
        return;
    }
    let one = |uid: u64, tenant: TenantId| {
        shared.trace.emit(EventKind::Pop, uid, tenant.0, shard as u32, 0, 0);
    };
    match item {
        QueueItem::One(s) => one(s.uid, s.tenant),
        QueueItem::Group(subs) => subs.iter().for_each(|s| one(s.uid, s.tenant)),
        QueueItem::SharedB(batch) => batch.subs.iter().for_each(|s| one(s.uid, s.tenant)),
    }
}

fn dispatcher_loop(shared: Arc<Shared>, admission: Arc<FrontEnd<QueueItem>>, shard: usize) {
    let mut carry: Option<Carry> = None;
    loop {
        let item = match carry.take() {
            Some(c) => c,
            None => match admission.pop_blocking() {
                Some(i) => {
                    emit_pops(&shared, &i, shard);
                    Carry::Fresh(i)
                }
                None => break, // closed and drained
            },
        };
        match item {
            Carry::Fresh(QueueItem::Group(group)) => dispatch_group(&shared, group, shard),
            Carry::Fresh(QueueItem::SharedB(batch)) => dispatch_shared_b(&shared, batch, shard),
            Carry::Fresh(QueueItem::One(s)) => {
                if let Some(p) = plan_one(&shared, s, shard) {
                    dispatch_single(&shared, &admission, p, &mut carry, shard);
                }
            }
            Carry::Planned(p) => dispatch_single(&shared, &admission, p, &mut carry, shard),
        }
    }
}

/// Dispatch one planned job; when it is small, opportunistically coalesce
/// the run of small jobs already waiting at the queue front (a non-small
/// job or an explicit group ends the run and is carried to the next
/// iteration — small jobs may therefore complete ahead of a larger job
/// admitted between them).
fn dispatch_single(
    shared: &Arc<Shared>,
    admission: &FrontEnd<QueueItem>,
    first: Planned,
    carry: &mut Option<Carry>,
    shard: usize,
) {
    if !first.small || shared.cfg.batch_window <= 1 {
        activate(shared, vec![first], shard);
        return;
    }
    let mut batch = vec![first];
    while batch.len() < shared.cfg.batch_window {
        match admission.try_pop() {
            Some(item) => {
                emit_pops(shared, &item, shard);
                match item {
                    QueueItem::One(s) => match plan_one(shared, s, shard) {
                        Some(p) if p.small => batch.push(p),
                        Some(p) => {
                            *carry = Some(Carry::Planned(p));
                            break;
                        }
                        None => {}
                    },
                    // An explicit group or shared-B batch ends the
                    // coalescing run; it is dispatched as its own unit
                    // next iteration.
                    other => {
                        *carry = Some(Carry::Fresh(other));
                        break;
                    }
                }
            }
            None => break,
        }
    }
    activate(shared, batch, shard);
}

/// Dispatch an explicit group: batch its small members (in windows),
/// activate the rest individually.
fn dispatch_group(shared: &Arc<Shared>, group: Vec<Admitted>, shard: usize) {
    let mut smalls: Vec<Planned> = Vec::new();
    for s in group {
        if let Some(p) = plan_one(shared, s, shard) {
            if p.small && shared.cfg.batch_window > 1 {
                smalls.push(p);
                if smalls.len() == shared.cfg.batch_window {
                    activate(shared, std::mem::take(&mut smalls), shard);
                }
            } else {
                activate(shared, vec![p], shard);
            }
        }
    }
    if !smalls.is_empty() {
        activate(shared, smalls, shard);
    }
}

/// Choose the one run configuration a shared-B batch executes under:
/// the usual pin → server-default → DSE cascade ([`choose_run_dims`],
/// the same policy individual jobs plan with), evaluated for the
/// *largest* sub-problem — every sub shares K and N, so a feasible
/// config for the largest M is feasible for all. The baseline is then
/// residency-refined: the B side by the shared handle's resident
/// variants, the A side only when *every* sub is a registered
/// activation (the batch runs under one `S_i`, so a block size is only
/// resident for the group if each member already holds it — the
/// intersection of their resident sets).
fn choose_shared_run(
    shared: &Shared,
    b: &Matrix,
    b_handle: Option<WeightHandle>,
    subs: &[(SharedSub, (usize, usize))],
    run: Option<RunConfig>,
    dtype: Dtype,
) -> anyhow::Result<RunConfig> {
    let m = subs.iter().map(|(_, (rows, _))| *rows).max().expect("non-empty batch");
    let baseline = choose_run_dims(
        &shared.hw,
        shared.accelerator.surface(),
        m,
        b.rows,
        b.cols,
        run,
        shared.cfg.default_run,
    )?;
    let all_a_handles: Option<Vec<ActivationHandle>> =
        subs.iter().map(|(s, _)| s.a.handle()).collect();
    let a_sis: Option<Vec<usize>> = all_a_handles.map(|hs| {
        let mut sets = hs.iter().map(|&h| shared.operands.resident_a_sis_dtype(h, dtype));
        let first = sets.next().unwrap_or_default();
        let rest: Vec<Vec<usize>> = sets.collect();
        first.into_iter().filter(|si| rest.iter().all(|set| set.contains(si))).collect()
    });
    let b_sjs = b_handle.map(|h| shared.operands.resident_b_sjs_dtype(h, dtype));
    Ok(refine_run_for_residency(
        shared,
        baseline,
        a_sis.as_deref(),
        b_sjs.as_deref(),
        m,
        b.rows,
        b.cols,
    ))
}

/// Dispatch a shared-B batch as one super-job: resolve the shared
/// operand (inline, or a registered handle looked up in the operand
/// registry), validate every sub against it (mismatches are rejected
/// individually through their tickets), choose one run config, obtain
/// the packed B **at most once** — an inline B packs here, a registered
/// one resolves from the cache (zero packs on a hit) — obtain each
/// surviving sub's [`PackedA`] (private pack for inline A, cached
/// registry pack for a registered activation), and publish the
/// combined task grid.
/// `Metrics::b_panel_packs` counts actual packs and
/// `Metrics::panels_shared` the within-call packs the sharing avoided.
fn dispatch_shared_b(shared: &Arc<Shared>, batch: SharedBatch, shard: usize) {
    let SharedBatch { b, run, subs, dtype } = batch;
    let reject_all = |subs: Vec<SharedSub>, msg: String| {
        for s in subs {
            shared.trace.emit(EventKind::Fail, s.uid, s.tenant.0, shard as u32, 0, 0);
            shared.metrics.job_failed();
            s.reply.send(Err(anyhow::anyhow!("shared-B batch rejected: {msg}")));
        }
    };
    // Reduced precision exists only on the packed in-process path (see
    // `plan_one`, which gates lone jobs the same way).
    if dtype != Dtype::F32 && !shared.engine.is_inprocess() {
        reject_all(subs, format!("dtype {dtype} requires an in-process engine"));
        return;
    }
    // Resolve the shared operand up front: a dead handle or a
    // degenerate inline B rejects every sub.
    let (b, handle): (Arc<Matrix>, Option<WeightHandle>) = match b {
        BOperand::Inline(m) => (Arc::new(m), None),
        BOperand::Registered(h) => match shared.operands.matrix(h) {
            Some(m) => (m, Some(h)),
            None => {
                reject_all(subs, format!("{h} is not registered"));
                return;
            }
        },
        BOperand::Fused(_) => {
            // A fused B exists only as a combination recipe; sharing it
            // across subs would re-form it per pack. Callers materialize
            // or submit per-job instead.
            reject_all(subs, "fused operands are not supported as a shared B".into());
            return;
        }
    };
    if b.rows == 0 || b.cols == 0 {
        reject_all(subs, format!("degenerate B {}x{}", b.rows, b.cols));
        return;
    }
    // Per-sub validation first (a mismatched or dead-handle A fails
    // alone, not the batch), so run selection below only ever sees
    // valid shapes. Registered activations validate against the
    // registry's recorded dims.
    let mut accepted: Vec<(SharedSub, (usize, usize))> = Vec::with_capacity(subs.len());
    for s in subs {
        let dims = match &s.a {
            AOperand::Inline(m) => Ok((m.rows, m.cols)),
            AOperand::Registered(h) => shared
                .operands
                .dims_a(*h)
                .ok_or_else(|| anyhow::anyhow!("sub-job {}: {h} is not registered", s.id)),
            AOperand::Fused(_) => Err(anyhow::anyhow!(
                "sub-job {}: fused operands are not supported in shared-B batches",
                s.id
            )),
        };
        match dims {
            Ok((rows, cols)) if cols == b.rows && rows > 0 => accepted.push((s, (rows, cols))),
            Ok((rows, cols)) => {
                shared.trace.emit(EventKind::PlanFail, s.uid, s.tenant.0, shard as u32, 0, 0);
                shared.metrics.job_failed();
                s.reply.send(Err(anyhow::anyhow!(
                    "sub-job {}: A is {}x{} against shared B {}x{}",
                    s.id,
                    rows,
                    cols,
                    b.rows,
                    b.cols
                )));
            }
            Err(e) => {
                shared.trace.emit(EventKind::PlanFail, s.uid, s.tenant.0, shard as u32, 0, 0);
                shared.metrics.job_failed();
                s.reply.send(Err(e));
            }
        }
    }
    if accepted.is_empty() {
        return;
    }
    // One config for the whole batch; failure (bad pin, DSE error)
    // rejects every surviving sub.
    let run = match choose_shared_run(shared, &b, handle, &accepted, run, dtype) {
        Ok(r) => r,
        Err(e) => {
            let msg = format!("{e:#}");
            for (s, _) in accepted {
                shared.trace.emit(EventKind::PlanFail, s.uid, s.tenant.0, shard as u32, 0, 0);
                shared.metrics.job_failed();
                s.reply.send(Err(anyhow::anyhow!("shared-B batch rejected: {msg}")));
            }
            return;
        }
    };
    // One Planned per surviving sub, each priced for its own shape
    // under the batch's single config — the drift baselines.
    if shared.trace.enabled() {
        for (s, (rows, cols)) in &accepted {
            let predicted = predict_run(shared, &run, *rows, *cols, b.cols);
            shared.trace.emit(
                EventKind::Planned,
                s.uid,
                s.tenant.0,
                shard as u32,
                predicted.to_bits(),
                0,
            );
        }
    }
    wait_for_inflight_slot(shared);

    // Obtain the shared packed half at most once: an inline B packs
    // here; a registered one resolves through the operand registry —
    // zero packs on a hit, and a handle unregistered mid-flight rejects
    // the batch instead of wedging the dispatcher. Every sub-job below
    // clones the Arc, not the panels.
    let inprocess = shared.engine.is_inprocess();
    let packed_b = if inprocess {
        let pb = match handle {
            None => {
                shared.metrics.add_b_panel_packs(1);
                Arc::new(PackedB::pack_dtype(b.view(), run.sj, dtype))
            }
            Some(h) => match shared.operands.resolve_pack_dtype(h, run.sj, dtype) {
                Ok(pb) => pb,
                Err(e) => {
                    reject_all(accepted.into_iter().map(|(s, _)| s).collect(), format!("{e:#}"));
                    return;
                }
            },
        };
        shared.metrics.add_panels_shared(accepted.len() as u64 - 1);
        Some(pb)
    } else {
        None
    };
    let batched = accepted.len() > 1;
    if batched {
        shared.metrics.add_batched_jobs(accepted.len() as u64);
    }
    shared.metrics.add_shared_b_groups(1);
    let mut subs_built = Vec::with_capacity(accepted.len());
    let mut tasks: Vec<SubTask> = Vec::new();
    for (s, (rows, cols)) in accepted {
        // Resolve this sub's A: inline packs privately, a registered
        // activation resolves its cached pack — a handle that died
        // since validation fails this sub alone.
        let (a, packed_a) = match resolve_a_operand(shared, s.a, run.si, dtype, inprocess) {
            Ok(resolved) => resolved,
            Err(e) => {
                shared.trace.emit(EventKind::Fail, s.uid, s.tenant.0, shard as u32, 0, 0);
                shared.metrics.job_failed();
                s.reply.send(Err(e));
                continue;
            }
        };
        let plan = BlockPlan::new(rows, cols, b.cols, run.si, run.sj);
        let idx = subs_built.len() as u32;
        for task in plan.tasks() {
            tasks.push(SubTask { sub: idx, task });
        }
        let panels = match (packed_a, packed_b.as_ref()) {
            (Some(pa), Some(pb)) => Some(PackedPanels::from_parts(pa, pb.clone())),
            _ => None,
        };
        let predicted = predict_run(shared, &run, rows, cols, b.cols);
        subs_built.push(build_sub(
            s.id,
            run,
            a,
            ExecOperand::Full(b.clone()),
            panels,
            plan.num_tasks(),
            s.reply,
            s.accepted_at,
            batched,
            s.tenant,
            s.deadline,
            s.uid,
            predicted,
        ));
    }
    if subs_built.is_empty() {
        return;
    }
    publish(shared, subs_built, tasks, shard);
}

fn worker_loop(shared: Arc<Shared>, w: usize) {
    let mut cache_epoch = u64::MAX;
    let mut cache: Vec<(u64, Arc<ActiveJob>)> = Vec::new();
    // The job this worker last took a task from — drained first for
    // panel locality; switching away from it is a cross-job steal.
    let mut last_job: Option<u64> = None;
    loop {
        // Read the gate generation BEFORE the stop flag: shutdown does
        // `stop.store` then `bump`, so either this iteration sees stop,
        // or the bump lands after `gate_seen` and any later `wait_past`
        // returns immediately — the stop check then fires next loop.
        // (Checking stop first would allow store+bump to slip between
        // the check and the read, putting the worker to sleep forever.)
        let gate_seen = shared.gate.current();
        if shared.stop.load(Ordering::Acquire) {
            break;
        }
        if shared.registry.epoch() != cache_epoch {
            let (epoch, snap) = shared.registry.snapshot();
            cache_epoch = epoch;
            cache = snap;
        }

        // 1) Keep draining the job we're already on. A job that retired
        //    from the table resets the affinity — adopting the next job
        //    after that is assignment, not a cross-job steal. `stolen`
        //    records intra-job provenance: the task came off a queue
        //    other than this worker's own.
        let mut claimed: Option<(u64, Arc<ActiveJob>, SubTask, bool, bool)> = None;
        if let Some(tag) = last_job {
            match cache.iter().find(|(t, _)| *t == tag) {
                Some((t, job)) => {
                    if let Some((st, src)) = job.wqm.pop_with_source(w) {
                        claimed = Some((*t, job.clone(), st, false, src != w));
                    }
                }
                None => last_job = None,
            }
        }
        // 2) Otherwise take from another live job: the fullest one
        //    (cross-job steal). With stealing disabled, the pool behaves
        //    like per-job pools instead: every worker converges on the
        //    *oldest* live job and waits for it to retire before moving
        //    on — jobs run through the pool strictly one at a time.
        if claimed.is_none() {
            let pick = if shared.cfg.cross_job_stealing {
                cache
                    .iter()
                    .map(|(t, j)| (*t, j, j.wqm.remaining()))
                    .filter(|(_, _, r)| *r > 0)
                    .max_by_key(|(_, _, r)| *r)
            } else {
                cache.iter().map(|(t, j)| (*t, j, j.wqm.remaining())).next()
            };
            if let Some((tag, job, _)) = pick {
                if let Some((st, src)) = job.wqm.pop_with_source(w) {
                    // Adopting a job when we had none is assignment, not
                    // stealing; and the no-cross-steal baseline moves to
                    // the next job sequentially, which doesn't count.
                    let switched = shared.cfg.cross_job_stealing
                        && last_job.is_some()
                        && last_job != Some(tag);
                    claimed = Some((tag, job.clone(), st, switched, src != w));
                } else if shared.cfg.cross_job_stealing {
                    // Raced with other workers; another job may still
                    // hold work — rescan immediately.
                    std::thread::yield_now();
                    continue;
                } else {
                    // Baseline: the oldest job is drained but not yet
                    // retired, and this worker may not move past it.
                    // Sleep until membership changes (retirement bumps
                    // the gate) instead of busy-polling. Drop the
                    // snapshot first so sleeping pins no retired jobs.
                    cache.clear();
                    cache_epoch = u64::MAX;
                    shared.gate.wait_past(gate_seen);
                    continue;
                }
            }
        }

        match claimed {
            Some((tag, job, st, switched, stolen)) => {
                if switched {
                    shared.metrics.add_cross_job_steals(1);
                }
                shared.worker_tasks[w].fetch_add(1, Ordering::Relaxed);
                if stolen {
                    shared.worker_steals[w].fetch_add(1, Ordering::Relaxed);
                }
                last_job = Some(tag);
                let flags =
                    (stolen as u64 * TASK_STOLEN) | (switched as u64 * TASK_CROSS_JOB);
                let t0 = Instant::now();
                execute_subtask(&shared, &job, tag, st, w, flags);
                shared.worker_busy[w]
                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            }
            None => {
                last_job = None;
                // Sleep until a registration (or shutdown) moves the
                // gate past what we saw before the empty scan. Drop the
                // snapshot first: a sleeping worker must not pin retired
                // jobs' operands/panels through an idle period.
                cache.clear();
                cache_epoch = u64::MAX;
                shared.gate.wait_past(gate_seen);
            }
        }
    }
}

fn execute_subtask(shared: &Shared, job: &ActiveJob, tag: u64, st: SubTask, w: usize, flags: u64) {
    let sub = &job.subs[st.sub as usize];
    let start_us = shared.trace.now_us();
    // SAFETY: `sub.out` keeps C's buffer alive until the final task's
    // completion below; the WQM hands each task to exactly one worker
    // and a BlockPlan's tasks tile C disjointly, so concurrent
    // write_block calls never overlap.
    let writer = unsafe { DisjointBlocks::from_raw(sub.raw.ptr, sub.raw.rows, sub.raw.cols) };
    // Contain panics from the numerics path (kernel/writer invariant
    // asserts): an unwinding worker would skip the completion
    // bookkeeping below, wedging the job's ticket and shutdown forever.
    // A panic degrades to a job error instead; no lock is held across
    // this call, so nothing gets poisoned. (AssertUnwindSafe: on panic
    // the only cross-boundary state is C's buffer, which the error path
    // discards with the job.)
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        shared
            .engine
            .task_product_into(
                sub.panels.as_ref(),
                sub.a.full().map(|a| &**a),
                sub.b.full().map(|b| &**b),
                &st.task,
                &writer,
            )
    }));
    match outcome {
        Ok(Ok(zero_copy)) => {
            if !zero_copy {
                shared.metrics.add_panel_copies(2);
            }
        }
        Ok(Err(e)) => {
            let mut g = sub.error.lock().unwrap();
            if g.is_none() {
                *g = Some(e);
            }
        }
        Err(panic) => {
            let msg = panic
                .downcast_ref::<&str>()
                .map(|s| (*s).to_string())
                .or_else(|| panic.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "non-string panic payload".to_string());
            let mut g = sub.error.lock().unwrap();
            if g.is_none() {
                *g = Some(anyhow::anyhow!("task {} panicked: {msg}", st.task.id));
            }
        }
    }
    shared.metrics.task_done();
    // Stamped before the completion bookkeeping so the last task's
    // record lands before (and its timestamp never exceeds) the job's
    // Done event emitted by `finalize_sub` below.
    shared.trace.emit(EventKind::TaskExec, sub.uid, sub.tenant.0, w as u32, start_us, flags);
    if sub.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
        finalize_sub(shared, sub);
        if job.subs_pending.fetch_sub(1, Ordering::AcqRel) == 1 {
            // Whole (super-)job done: fold its WQM stats into the server
            // metrics and retire it from the table.
            let intra: u64 = job.wqm.stats().iter().map(|s| s.stolen_in).sum();
            shared.metrics.add_steals(intra);
            shared.registry.unregister(tag);
            shared.inflight.fetch_sub(1, Ordering::AcqRel);
            shared.gate.bump();
        }
    }
}

/// Assemble and deliver one finished sub-job: take C, run the timing
/// simulation, record per-job and per-tenant metrics (a deadline job
/// that completes after its deadline counts as a miss), reply on the
/// ticket.
fn finalize_sub(shared: &Shared, sub: &SubJob) {
    let c = sub.out.lock().unwrap().take();
    let err = sub.error.lock().unwrap().take();
    let host_latency_secs = sub.accepted_at.elapsed().as_secs_f64();
    let result = match (err, c) {
        (None, Some(c)) => shared
            .accelerator
            .simulate(&sub.run, sub.a.rows(), sub.a.cols(), sub.b.cols(), &SimOptions::default())
            .map(|sim| {
                shared.metrics.job_done(host_latency_secs, sim.total_secs);
                let missed = sub.deadline.map(|d| Instant::now() > d);
                if let Some(m) = missed {
                    shared.metrics.deadline_job_done(m);
                }
                shared.metrics.tenant_job_done(
                    sub.tenant,
                    sub.deadline.is_some(),
                    missed.unwrap_or(false),
                );
                // Model drift: what planning predicted vs what the
                // simulation measured (guarded inside `record_drift`
                // when the model could not price the job).
                shared.metrics.record_drift(sub.predicted_secs, sim.total_secs);
                shared.trace.emit(
                    EventKind::Done,
                    sub.uid,
                    sub.tenant.0,
                    ACTOR_NONE,
                    sub.predicted_secs.to_bits(),
                    sim.total_secs.to_bits(),
                );
                JobResult {
                    id: sub.id,
                    c,
                    run: sub.run,
                    sim,
                    host_latency_secs,
                    batched: sub.batched,
                }
            }),
        (Some(e), _) => Err(e),
        (None, None) => Err(anyhow::anyhow!("job {} finalized twice", sub.id)),
    };
    if result.is_err() {
        shared.trace.emit(EventKind::Fail, sub.uid, sub.tenant.0, ACTOR_NONE, 0, 0);
        shared.metrics.job_failed();
    }
    if let Some(reply) = sub.reply.lock().unwrap().take() {
        reply.send(result);
    }
}

#[cfg(test)]
#[allow(deprecated)] // legacy shims are exercised on purpose
mod tests {
    use super::*;

    fn server(cfg: ServerConfig) -> JobServer {
        JobServer::new(HardwareConfig::paper(), NumericsEngine::golden(), cfg).unwrap()
    }

    fn small_cfg() -> ServerConfig {
        ServerConfig {
            workers: 4,
            queue_capacity: 16,
            batch_max_tasks: 4,
            batch_window: 4,
            cross_job_stealing: true,
            default_run: Some(RunConfig::square(2, 16)),
            ..ServerConfig::default()
        }
    }

    #[test]
    fn single_job_roundtrip() {
        let srv = server(small_cfg());
        let a = Matrix::random(48, 24, 1);
        let b = Matrix::random(24, 40, 2);
        let want = a.matmul(&b);
        let t = srv
            .submit(GemmJob { id: 7, a: a.into(), b: b.into(), run: Some(RunConfig::square(2, 16)) })
            .unwrap();
        let r = t.wait().unwrap();
        assert_eq!(r.id, 7);
        assert!(r.c.allclose(&want, 1e-4));
        assert!(r.sim.total_secs > 0.0);
        srv.shutdown();
    }

    #[test]
    fn unpinned_job_uses_default_run() {
        let srv = server(small_cfg());
        let a = Matrix::random(40, 20, 3);
        let b = Matrix::random(20, 40, 4);
        let want = a.matmul(&b);
        let r = srv.submit(GemmJob { id: 1, a: a.into(), b: b.into(), run: None }).unwrap().wait().unwrap();
        assert_eq!(r.run, RunConfig::square(2, 16));
        assert!(r.c.allclose(&want, 1e-4));
    }

    #[test]
    fn invalid_job_rejected_through_ticket() {
        let srv = server(small_cfg());
        let job = GemmJob {
            id: 2,
            a: Matrix::random(8, 8, 5).into(),
            b: Matrix::random(9, 8, 6).into(),
            run: None,
        };
        assert!(srv.submit(job).unwrap().wait().is_err());
        assert_eq!(srv.metrics().jobs_failed(), 1);
    }

    #[test]
    fn degenerate_job_rejected_without_killing_dispatcher() {
        let srv = server(small_cfg());
        let bad = GemmJob {
            id: 4,
            a: Matrix::zeros(0, 0).into(),
            b: Matrix::zeros(0, 8).into(),
            run: None,
        };
        assert!(srv.submit(bad).unwrap().wait().is_err());
        // The dispatcher must still be alive to serve the next job.
        let a = Matrix::random(16, 8, 31);
        let b = Matrix::random(8, 16, 32);
        let want = a.matmul(&b);
        let r = srv
            .submit(GemmJob { id: 5, a: a.into(), b: b.into(), run: Some(RunConfig::square(2, 16)) })
            .unwrap()
            .wait()
            .unwrap();
        assert!(r.c.allclose(&want, 1e-5));
    }

    #[test]
    fn invalid_pinned_config_rejected() {
        let srv = server(small_cfg());
        let job = GemmJob {
            id: 3,
            a: Matrix::random(8, 8, 7).into(),
            b: Matrix::random(8, 8, 8).into(),
            run: Some(RunConfig::square(4, 256)),
        };
        assert!(srv.submit(job).unwrap().wait().is_err());
    }

    #[test]
    fn batch_submit_is_bit_identical_to_packed_matmul() {
        let srv = server(small_cfg());
        let mut jobs = Vec::new();
        let mut wants = Vec::new();
        for i in 0..6u64 {
            let a = Matrix::random(20, 12, 100 + i);
            let b = Matrix::random(12, 24, 200 + i);
            wants.push(crate::gemm::packed_matmul(&a, &b, 16, 16));
            jobs.push(GemmJob { id: i, a: a.into(), b: b.into(), run: Some(RunConfig::square(2, 16)) });
        }
        let tickets = srv.submit_batch(jobs).unwrap();
        for (t, want) in tickets.into_iter().zip(&wants) {
            let r = t.wait().unwrap();
            assert!(r.batched, "small group member should be batched");
            // Bit-identical: same panels, same microkernel, same order.
            assert_eq!(r.c.data, want.data);
        }
        assert_eq!(srv.metrics().batched_jobs(), 6);
    }

    #[test]
    fn submit_group_joins_in_submission_order() {
        let srv = server(small_cfg());
        let mut jobs = Vec::new();
        let mut wants = Vec::new();
        for i in 0..7u64 {
            let a = Matrix::random(24, 16, 700 + i);
            let b = Matrix::random(16, 20, 800 + i);
            wants.push(a.matmul(&b));
            jobs.push(GemmJob { id: i, a: a.into(), b: b.into(), run: Some(RunConfig::square(2, 16)) });
        }
        let group = srv.submit_group(jobs).unwrap();
        assert_eq!(group.len(), 7);
        let results = group.wait_all().unwrap();
        assert_eq!(results.len(), 7);
        for (i, (r, want)) in results.iter().zip(&wants).enumerate() {
            assert_eq!(r.id, i as u64, "results must come back in submission order");
            assert!(r.c.allclose(want, 1e-4));
        }
    }

    #[test]
    fn submit_group_surfaces_member_failure_after_draining() {
        let srv = server(small_cfg());
        let good_a = Matrix::random(16, 8, 41);
        let good_b = Matrix::random(8, 16, 42);
        let jobs = vec![
            GemmJob {
                id: 0,
                a: good_a.into(),
                b: good_b.into(),
                run: Some(RunConfig::square(2, 16)),
            },
            // Contraction mismatch: rejected at planning.
            GemmJob {
                id: 1,
                a: Matrix::random(8, 8, 43).into(),
                b: Matrix::random(9, 8, 44).into(),
                run: None,
            },
        ];
        let err = srv.submit_group(jobs).unwrap().wait_all().unwrap_err();
        assert!(format!("{err:#}").contains("job 1"), "got: {err:#}");
        // The healthy member still ran to completion (metrics prove it).
        assert_eq!(srv.metrics().jobs(), 1);
        assert_eq!(srv.metrics().jobs_failed(), 1);
    }

    #[test]
    fn big_jobs_in_group_are_not_batched() {
        let srv = server(small_cfg());
        let a = Matrix::random(96, 16, 11);
        let b = Matrix::random(16, 96, 12);
        let want = a.matmul(&b);
        // 6x6 = 36 tasks at si=16 — far above batch_max_tasks.
        let tickets = srv
            .submit_batch(vec![GemmJob {
                id: 0,
                a: a.into(),
                b: b.into(),
                run: Some(RunConfig::square(2, 16)),
            }])
            .unwrap();
        let r = tickets.into_iter().next().unwrap().wait().unwrap();
        assert!(!r.batched);
        assert!(r.c.allclose(&want, 1e-4));
        assert_eq!(srv.metrics().batched_jobs(), 0);
    }

    #[test]
    fn mixed_sizes_with_cross_job_stealing_off_still_correct() {
        let mut cfg = small_cfg();
        cfg.cross_job_stealing = false;
        let srv = server(cfg);
        let mut pending = Vec::new();
        for i in 0..8u64 {
            let (m, n) = if i % 2 == 0 { (64, 64) } else { (20, 28) };
            let a = Matrix::random(m, 16, 300 + i);
            let b = Matrix::random(16, n, 400 + i);
            let want = a.matmul(&b);
            let t = srv
                .submit(GemmJob { id: i, a: a.into(), b: b.into(), run: Some(RunConfig::square(2, 16)) })
                .unwrap();
            pending.push((t, want));
        }
        for (t, want) in pending {
            assert!(t.wait().unwrap().c.allclose(&want, 1e-4));
        }
        assert_eq!(srv.metrics().cross_job_steals(), 0);
    }

    #[test]
    fn shutdown_resolves_outstanding_tickets() {
        let srv = server(small_cfg());
        let a = Matrix::random(64, 32, 21);
        let b = Matrix::random(32, 64, 22);
        let want = a.matmul(&b);
        let t = srv
            .submit(GemmJob { id: 9, a: a.into(), b: b.into(), run: Some(RunConfig::square(2, 16)) })
            .unwrap();
        srv.shutdown();
        assert!(t.wait().unwrap().c.allclose(&want, 1e-4));
    }

    #[test]
    fn stats_snapshot_is_sane() {
        let srv = server(small_cfg());
        for i in 0..5u64 {
            let a = Matrix::random(32, 16, i);
            let b = Matrix::random(16, 32, i + 50);
            srv.submit(GemmJob { id: i, a: a.into(), b: b.into(), run: Some(RunConfig::square(2, 16)) })
                .unwrap()
                .wait()
                .unwrap();
        }
        let s = srv.stats();
        assert_eq!(s.jobs, 5);
        assert!(s.tasks >= 5);
        assert!(s.throughput_jobs_per_sec > 0.0);
        assert!(s.latency_p50_secs <= s.latency_p95_secs);
        assert!(s.latency_p95_secs <= s.latency_p99_secs);
        assert!((0.0..=1.0).contains(&s.worker_idle_frac));
        // Per-worker breakdown: the tallies partition the task total,
        // and the imbalance ratio is well-defined once work ran.
        assert_eq!(s.per_worker_tasks.len(), 4);
        assert_eq!(s.per_worker_tasks.iter().sum::<u64>(), s.tasks);
        assert!(s.per_worker_steals.iter().sum::<u64>() <= s.tasks);
        assert!(s.worker_imbalance >= 1.0);
        assert!(s.to_string().contains("jobs=5"));
    }

    #[test]
    fn batched_gemm_shares_one_b_pack() {
        let srv = server(small_cfg());
        let b = Matrix::random(16, 24, 900);
        let many_a: Vec<Matrix> =
            (0..5u64).map(|i| Matrix::random(20, 16, 910 + i)).collect();
        let wants: Vec<Matrix> = many_a.iter().map(|a| a.matmul(&b)).collect();
        let group = srv
            .submit_batched_gemm(b, many_a, Some(RunConfig::square(2, 16)))
            .unwrap();
        let results = group.wait_all().unwrap();
        assert_eq!(results.len(), 5);
        for (i, (r, want)) in results.iter().zip(&wants).enumerate() {
            assert_eq!(r.id, i as u64, "results in many_a order");
            assert!(r.batched, "shared-B sub-jobs run as one super-job");
            assert!(r.c.allclose(want, 1e-4));
        }
        // The conservation the whole refactor exists for: one B pack,
        // four avoided, five A packs, and it is all visible in stats().
        let s = srv.stats();
        assert_eq!(s.b_panel_packs, 1, "shared B must be packed exactly once");
        assert_eq!(s.panels_shared, 4);
        assert_eq!(s.a_panel_packs, 5);
        assert_eq!(s.shared_b_groups, 1);
        assert_eq!(s.batched_jobs, 5);
        assert_eq!(s.panel_copies, 0, "golden path stays gather-free");
        assert!(s.to_string().contains("shared-b groups=1"));
    }

    #[test]
    fn batched_gemm_single_a_is_a_plain_job() {
        let srv = server(small_cfg());
        let b = Matrix::random(12, 20, 920);
        let a = Matrix::random(16, 12, 921);
        let want = a.matmul(&b);
        let results = srv
            .submit_batched_gemm(b, vec![a], Some(RunConfig::square(2, 16)))
            .unwrap()
            .wait_all()
            .unwrap();
        assert_eq!(results.len(), 1);
        assert!(!results[0].batched, "a batch of one is not a super-job");
        assert!(results[0].c.allclose(&want, 1e-4));
        let s = srv.stats();
        assert_eq!((s.b_panel_packs, s.panels_shared), (1, 0));
        assert_eq!(s.shared_b_groups, 1);
    }

    #[test]
    fn batched_gemm_rejects_mismatched_sub_alone() {
        let srv = server(small_cfg());
        let b = Matrix::random(16, 16, 930);
        let good = Matrix::random(8, 16, 931);
        let bad = Matrix::random(8, 9, 932); // contraction mismatch
        let want = good.matmul(&b);
        let group = srv
            .submit_batched_gemm(b, vec![good, bad], Some(RunConfig::square(2, 16)))
            .unwrap();
        let mut tickets = group.into_tickets().into_iter();
        let ok = tickets.next().unwrap().wait().unwrap();
        assert!(ok.c.allclose(&want, 1e-4));
        assert!(tickets.next().unwrap().wait().is_err());
        assert_eq!(srv.metrics().jobs_failed(), 1);
    }

    #[test]
    fn batched_gemm_empty_and_degenerate_rejected() {
        let srv = server(small_cfg());
        assert!(srv
            .submit_batched_gemm(Matrix::random(4, 4, 940), vec![], None)
            .is_err());
        // Degenerate B fails every sub through its ticket, and the
        // dispatcher survives.
        let group = srv
            .submit_batched_gemm(
                Matrix::zeros(0, 0),
                vec![Matrix::random(4, 4, 941)],
                None,
            )
            .unwrap();
        assert!(group.wait_all().is_err());
        let a = Matrix::random(16, 8, 942);
        let b = Matrix::random(8, 16, 943);
        let want = a.matmul(&b);
        let r = srv
            .submit(GemmJob { id: 1, a: a.into(), b: b.into(), run: Some(RunConfig::square(2, 16)) })
            .unwrap()
            .wait()
            .unwrap();
        assert!(r.c.allclose(&want, 1e-4));
    }

    #[test]
    fn batched_gemm_uses_dse_for_largest_sub_when_unpinned() {
        // No pin, no server default: the batch plans once via the DSE
        // and every sub runs under that single config.
        let cfg = ServerConfig { default_run: None, ..small_cfg() };
        let srv = server(cfg);
        let b = Matrix::random(24, 32, 950);
        let many_a: Vec<Matrix> = vec![
            Matrix::random(8, 24, 951),
            Matrix::random(64, 24, 952),
        ];
        let wants: Vec<Matrix> = many_a.iter().map(|a| a.matmul(&b)).collect();
        let results = srv.submit_batched_gemm(b, many_a, None).unwrap().wait_all().unwrap();
        assert_eq!(results[0].run, results[1].run, "one config for the whole batch");
        for (r, want) in results.iter().zip(&wants) {
            assert!(r.c.allclose(want, 1e-4));
        }
    }

    #[test]
    fn registered_handle_roundtrip_and_per_shape_variants() {
        // Residency refinement disabled: this test deliberately pins a
        // *non-resident* sj for its third job to prove per-shape
        // variants are cached independently — the refiner would
        // otherwise be free to steer that pin back to the resident one.
        let cfg = ServerConfig { plan_residency_slack: -1.0, ..small_cfg() };
        let srv = server(cfg);
        let b = Matrix::random(16, 24, 960);
        let h = srv.register_b(b.clone()).unwrap();
        let a1 = Matrix::random(20, 16, 961);
        let want1 = a1.matmul(&b);
        let r1 = srv
            .submit(GemmJob { id: 0, a: a1.into(), b: h.into(), run: Some(RunConfig::square(2, 16)) })
            .unwrap()
            .wait()
            .unwrap();
        assert!(r1.c.allclose(&want1, 1e-4));
        // Same handle, same block size: a registry hit, no new pack.
        let a2 = Matrix::random(12, 16, 962);
        let want2 = a2.matmul(&b);
        let r2 = srv
            .submit(GemmJob { id: 1, a: a2.into(), b: h.into(), run: Some(RunConfig::square(2, 16)) })
            .unwrap()
            .wait()
            .unwrap();
        assert!(r2.c.allclose(&want2, 1e-4));
        // A different block size re-derives a per-shape variant once,
        // cached under its own (handle, sj) key.
        let a3 = Matrix::random(20, 16, 963);
        let want3 = a3.matmul(&b);
        let r3 = srv
            .submit(GemmJob { id: 2, a: a3.into(), b: h.into(), run: Some(RunConfig::square(2, 32)) })
            .unwrap()
            .wait()
            .unwrap();
        assert!(r3.c.allclose(&want3, 1e-4));
        let s = srv.stats();
        assert_eq!(s.b_panel_packs, 2, "one pack per (handle, sj) variant");
        assert_eq!((s.registry_hits, s.registry_misses), (1, 2));
        assert_eq!(s.registered_weights, 1);
        assert!(s.registry_resident_bytes > 0);
        assert!(s.to_string().contains("registry(hit/miss/evict)=1/2/0"));
    }

    #[test]
    fn batched_gemm_with_handle_packs_once_across_calls() {
        // The acceptance gate for the registry: three successive
        // batched calls reusing one handle perform exactly ONE B pack
        // total — the one-pack guarantee now holds across calls.
        let srv = server(small_cfg());
        let b = Matrix::random(16, 24, 970);
        let h = srv.register_b(b.clone()).unwrap();
        let run = Some(RunConfig::square(2, 16));
        for call in 0..3u64 {
            let many_a: Vec<Matrix> =
                (0..4u64).map(|i| Matrix::random(20, 16, 971 + 10 * call + i)).collect();
            let wants: Vec<Matrix> = many_a.iter().map(|a| a.matmul(&b)).collect();
            let results =
                srv.submit_batched_gemm(h, many_a, run).unwrap().wait_all().unwrap();
            for (r, want) in results.iter().zip(&wants) {
                assert!(r.c.allclose(want, 1e-4));
            }
        }
        let s = srv.stats();
        assert_eq!(s.b_panel_packs, 1, "one pack across all three calls");
        assert_eq!((s.registry_hits, s.registry_misses), (2, 1));
        assert_eq!(s.shared_b_groups, 3);
        assert_eq!(s.panels_shared, 3 * 3, "within-call sharing still counted");
    }

    #[test]
    fn handle_after_unregister_fails_through_tickets() {
        let srv = server(small_cfg());
        let h = srv.register_b(Matrix::random(16, 16, 980)).unwrap();
        srv.unregister_b(h).unwrap();
        assert!(srv.unregister_b(h).is_err(), "double unregister rejected");
        // A lone submit and a shared batch both fail through their
        // tickets, never the dispatcher.
        let err = srv
            .submit(GemmJob { id: 0, a: Matrix::random(8, 16, 981).into(), b: h.into(), run: None })
            .unwrap()
            .wait()
            .unwrap_err();
        assert!(format!("{err:#}").contains("not registered"), "got: {err:#}");
        assert!(srv
            .submit_batched_gemm(h, vec![Matrix::random(8, 16, 982)], None)
            .unwrap()
            .wait_all()
            .is_err());
        assert_eq!(srv.metrics().jobs_failed(), 2);
        // The dispatcher survives to serve real work.
        let a = Matrix::random(16, 8, 983);
        let b = Matrix::random(8, 16, 984);
        let want = a.matmul(&b);
        let r = srv
            .submit(GemmJob {
                id: 1,
                a: a.into(),
                b: b.clone().into(),
                run: Some(RunConfig::square(2, 16)),
            })
            .unwrap()
            .wait()
            .unwrap();
        assert!(r.c.allclose(&want, 1e-4));
    }

    #[test]
    fn try_submit_batched_gemm_empty_rejected() {
        let srv = server(small_cfg());
        assert!(matches!(
            srv.try_submit_batched_gemm(Matrix::random(4, 4, 990), vec![], None),
            Err(TrySubmitBatchedError::Empty)
        ));
    }

    /// Test-only [`AdmitMeta`]: default tenant, no deadline, `cost` jobs.
    fn meta(cost: usize) -> AdmitMeta {
        AdmitMeta {
            tenant: TenantId::DEFAULT,
            weight: 1,
            cost,
            deadline: None,
            predicted_secs: 0.0,
        }
    }

    fn admitted(tx: &mpsc::Sender<anyhow::Result<JobResult>>, id: u64) -> Admitted {
        Admitted {
            job: GemmJob {
                id,
                a: Matrix::zeros(1, 1).into(),
                b: Matrix::zeros(1, 1).into(),
                run: None,
            },
            reply: Reply { tx: tx.clone(), _slot: None },
            accepted_at: Instant::now(),
            tenant: TenantId::DEFAULT,
            deadline: None,
            uid: id,
            dtype: Dtype::F32,
        }
    }

    #[test]
    fn admission_hands_back_shared_batch_intact() {
        // The recovery path try_submit builds on: a shed shared-B batch
        // comes back with every operand intact.
        let adm: FrontEnd<QueueItem> = FrontEnd::new(1);
        let (tx, _rx) = mpsc::channel::<anyhow::Result<JobResult>>();
        adm.try_push(meta(1), QueueItem::One(admitted(&tx, 0))).map_err(|_| ()).unwrap();
        let batch = QueueItem::SharedB(SharedBatch {
            b: Matrix::random(5, 7, 991).into(),
            run: None,
            subs: (0..2)
                .map(|i| SharedSub {
                    id: i,
                    a: Matrix::random(3, 5, 992 + i).into(),
                    reply: Reply { tx: tx.clone(), _slot: None },
                    accepted_at: Instant::now(),
                    tenant: TenantId::DEFAULT,
                    deadline: None,
                    uid: i,
                })
                .collect(),
            dtype: Dtype::F32,
        });
        match adm.try_push(meta(2), batch) {
            Err(TryPushError::Full(QueueItem::SharedB(SharedBatch { b, subs, .. }))) => {
                assert_eq!(b.inline_dims(), Some((5, 7)));
                assert_eq!(subs.len(), 2);
                assert!(subs.iter().all(|s| s.a.inline_dims() == Some((3, 5))));
            }
            other => panic!("expected Full(SharedB), got {:?}", other.is_ok()),
        }
    }

    #[test]
    fn admission_try_push_full_and_closed() {
        let adm: FrontEnd<QueueItem> = FrontEnd::new(1);
        let (tx, _rx) = mpsc::channel();
        assert!(adm.try_push(meta(1), QueueItem::One(admitted(&tx, 0))).is_ok());
        assert!(matches!(
            adm.try_push(meta(1), QueueItem::One(admitted(&tx, 1))),
            Err(TryPushError::Full(_))
        ));
        assert_eq!(adm.len(), 1);
        assert!(adm.try_pop().is_some());
        assert!(adm.try_push(meta(1), QueueItem::One(admitted(&tx, 2))).is_ok());
        adm.close();
        assert!(matches!(
            adm.try_push(meta(1), QueueItem::One(admitted(&tx, 3))),
            Err(TryPushError::Closed(_))
        ));
        // Closed but not drained: the dispatcher still sees the item.
        assert!(adm.pop_blocking().is_some());
        assert!(adm.pop_blocking().is_none());
    }

    #[test]
    fn admission_oversized_group_admitted_when_empty() {
        let adm: FrontEnd<QueueItem> = FrontEnd::new(2);
        let (tx, _rx) = mpsc::channel::<anyhow::Result<JobResult>>();
        let group = QueueItem::Group((0..5).map(|i| admitted(&tx, i)).collect());
        assert!(adm.try_push(meta(5), group).is_ok());
        assert_eq!(adm.len(), 5);
    }

    #[test]
    fn server_config_default_is_valid() {
        // The Default-consistency gate: every knob Default ships must
        // pass its own validation, and the sharded front is on by
        // default.
        let cfg = ServerConfig::default();
        cfg.validate(&HardwareConfig::paper()).unwrap();
        assert!(cfg.admission_shards >= 2, "sharded admission is the default");
        assert!(ServerConfig { workers: 0, ..cfg }.validate(&HardwareConfig::paper()).is_err());
        assert!(
            ServerConfig { admission_shards: 0, ..ServerConfig::default() }
                .validate(&HardwareConfig::paper())
                .is_err()
        );
        assert!(
            ServerConfig { queue_capacity: 0, ..ServerConfig::default() }
                .validate(&HardwareConfig::paper())
                .is_err()
        );
    }

    #[test]
    fn deadline_misses_counted_and_surfaced() {
        let srv = server(small_cfg());
        let a = Matrix::random(24, 16, 51);
        let b = Matrix::random(16, 24, 52);
        let want = a.matmul(&b);
        // A deadline already in the past must still complete correctly —
        // deadlines shape ordering, they never drop work — but counts as
        // a miss for its tenant.
        let t9 = TenantId(9);
        let r = srv
            .submit_blocking(
                Submission::gemm(a, b)
                    .tenant(t9)
                    .deadline(Duration::ZERO)
                    .run(RunConfig::square(2, 16)),
            )
            .unwrap();
        assert!(r[0].c.allclose(&want, 1e-4));
        let s = srv.stats();
        assert_eq!((s.deadline_jobs, s.deadline_misses), (1, 1));
        let (tid, tc) = s.tenants.iter().find(|(t, _)| *t == t9).expect("tenant row");
        assert_eq!(*tid, t9);
        assert_eq!((tc.jobs, tc.deadline_jobs, tc.deadline_misses), (1, 1, 1));
        assert!(s.to_string().contains("deadline(miss/ddl)=1/1"), "got: {s}");
    }

    #[test]
    fn registered_a_bit_identity_lone_and_repeat() {
        // Ragged prime/odd shapes (nothing divides the block size): a
        // registered activation must produce the same bits as inline
        // submission — cached pack, private pack, same bytes — and a
        // repeat under the handle must resolve as a hit, not a repack.
        let srv = server(small_cfg());
        let run = Some(RunConfig::square(2, 16));
        for (i, &(m, k, n)) in [(13usize, 7usize, 11usize), (23, 5, 9), (3, 17, 29)]
            .iter()
            .enumerate()
        {
            let a = Matrix::random(m, k, 600 + i as u64);
            let b = Matrix::random(k, n, 640 + i as u64);
            let inline = srv
                .submit(GemmJob { id: 0, a: a.clone().into(), b: b.clone().into(), run })
                .unwrap()
                .wait()
                .unwrap();
            let h = srv.register_a(a).unwrap();
            let reg = srv
                .submit(GemmJob { id: 1, a: h.into(), b: b.clone().into(), run })
                .unwrap()
                .wait()
                .unwrap();
            assert_eq!(reg.c.data, inline.c.data, "registered A must be bit-identical");
            let again = srv
                .submit(GemmJob { id: 2, a: h.into(), b: b.into(), run })
                .unwrap()
                .wait()
                .unwrap();
            assert_eq!(again.c.data, inline.c.data, "repeat hit must be bit-identical");
        }
        let s = srv.stats();
        assert_eq!((s.registry_a_hits, s.registry_a_misses), (3, 3));
        assert_eq!(s.a_panel_packs, 6, "3 inline + 3 first-use packs; repeats pack nothing");
        assert_eq!(s.registered_activations, 3);
        assert!(s.registry_a_resident_bytes > 0);
    }

    #[test]
    fn registered_a_batched_bit_identity_and_repeat_hits() {
        // submit_batched_gemm_operands with registered activations is
        // bit-identical to the inline batched call, and a second call
        // under the same handles packs nothing on the A side.
        let srv = server(small_cfg());
        let run = Some(RunConfig::square(2, 16));
        let b = Matrix::random(7, 19, 660);
        let many: Vec<Matrix> = [(13usize, 7usize), (21, 7), (5, 7)]
            .iter()
            .enumerate()
            .map(|(i, &(m, k))| Matrix::random(m, k, 670 + i as u64))
            .collect();
        let inline = srv
            .submit_batched_gemm(b.clone(), many.clone(), run)
            .unwrap()
            .wait_all()
            .unwrap();
        let handles: Vec<_> =
            many.into_iter().map(|a| srv.register_a(a).unwrap()).collect();
        for call in 0..2 {
            let ops: Vec<AOperand> = handles.iter().map(|&h| h.into()).collect();
            let reg = srv
                .submit_batched_gemm_operands(b.clone(), ops, run)
                .unwrap()
                .wait_all()
                .unwrap();
            for (r, want) in reg.iter().zip(&inline) {
                assert_eq!(r.c.data, want.c.data, "call {call}: bit-identical to inline");
            }
        }
        let s = srv.stats();
        assert_eq!((s.registry_a_hits, s.registry_a_misses), (3, 3));
        assert_eq!(s.a_panel_packs, 6, "3 inline + 3 first-call packs; the repeat packs 0");
    }

    #[test]
    fn plan_residency_steers_pinned_config_to_resident_b() {
        // Mixed-config traffic against one registered weight: the
        // second pin would have repacked at sj=32 before registry-aware
        // planning; with slack the planner steers it to the resident
        // sj=16 variant and the repack becomes a registry hit.
        let cfg = ServerConfig { plan_residency_slack: 10.0, ..small_cfg() };
        let srv = server(cfg);
        let b = Matrix::random(16, 24, 700);
        let h = srv.register_b(b.clone()).unwrap();
        let a1 = Matrix::random(20, 16, 701);
        let want1 = a1.matmul(&b);
        let r1 = srv
            .submit(GemmJob { id: 0, a: a1.into(), b: h.into(), run: Some(RunConfig::square(2, 16)) })
            .unwrap()
            .wait()
            .unwrap();
        assert!(r1.c.allclose(&want1, 1e-4));
        let a2 = Matrix::random(20, 16, 702);
        let want2 = a2.matmul(&b);
        let r2 = srv
            .submit(GemmJob { id: 1, a: a2.into(), b: h.into(), run: Some(RunConfig::square(2, 32)) })
            .unwrap()
            .wait()
            .unwrap();
        assert!(r2.c.allclose(&want2, 1e-4));
        assert_eq!(r2.run.sj, 16, "steered to the resident B variant");
        let s = srv.stats();
        assert_eq!(s.plan_residency_hits, 1);
        assert_eq!(s.b_panel_packs, 1, "the would-be repack became a hit");
        assert_eq!((s.registry_hits, s.registry_misses), (1, 1));
    }

    #[test]
    fn plan_residency_steers_pinned_config_to_resident_a() {
        // Same steering on the A side: one registered activation served
        // under mixed pins resolves one cached pack instead of two.
        let cfg = ServerConfig { plan_residency_slack: 10.0, ..small_cfg() };
        let srv = server(cfg);
        let a = Matrix::random(40, 16, 710);
        let h = srv.register_a(a.clone()).unwrap();
        let b1 = Matrix::random(16, 24, 711);
        let want1 = a.matmul(&b1);
        let r1 = srv
            .submit(GemmJob { id: 0, a: h.into(), b: b1.into(), run: Some(RunConfig::square(2, 16)) })
            .unwrap()
            .wait()
            .unwrap();
        assert!(r1.c.allclose(&want1, 1e-4));
        let b2 = Matrix::random(16, 24, 712);
        let want2 = a.matmul(&b2);
        let r2 = srv
            .submit(GemmJob { id: 1, a: h.into(), b: b2.into(), run: Some(RunConfig::square(2, 32)) })
            .unwrap()
            .wait()
            .unwrap();
        assert!(r2.c.allclose(&want2, 1e-4));
        assert_eq!(r2.run.si, 16, "steered to the resident A variant");
        let s = srv.stats();
        assert_eq!(s.plan_residency_hits, 1);
        assert_eq!((s.registry_a_hits, s.registry_a_misses), (1, 1));
        assert_eq!(s.a_panel_packs, 1, "one A pack across both pins");
        assert!(s.to_string().contains("plan_residency_hits=1"));
    }

    #[test]
    fn tight_budget_evicts_across_sides_through_server() {
        // A one-byte budget makes every published pack over-budget, so
        // each fresh variant evicts whatever unpinned packs remain — of
        // EITHER side. Results stay correct: eviction only drops cache.
        let cfg = ServerConfig {
            registry_budget_bytes: 1,
            plan_residency_slack: -1.0,
            ..small_cfg()
        };
        let srv = server(cfg);
        let a = Matrix::random(20, 16, 720);
        let b = Matrix::random(16, 24, 721);
        let ha = srv.register_a(a.clone()).unwrap();
        let hb = srv.register_b(b.clone()).unwrap();
        let want = a.matmul(&b);
        for (id, si) in [(0u64, 16usize), (1, 32)] {
            let r = srv
                .submit(GemmJob {
                    id,
                    a: ha.into(),
                    b: hb.into(),
                    run: Some(RunConfig::square(2, si)),
                })
                .unwrap()
                .wait()
                .unwrap();
            assert!(r.c.allclose(&want, 1e-4));
        }
        let s = srv.stats();
        assert_eq!((s.registry_hits, s.registry_misses), (0, 4), "every variant packed fresh");
        assert!(s.registry_evictions >= 2, "unpinned packs evicted past the budget");
        assert!(s.registry_a_evictions >= 1, "the A side participated in cross-side LRU");
    }

    #[test]
    fn f32_dtype_submission_is_bit_identical_to_default_path() {
        // The no-regression gate for the whole dtype refactor: an
        // explicit `.dtype(F32)` submission takes the exact code path a
        // plain submit does — same packs (counter-asserted), and bits
        // equal to the pinned packed_matmul reference.
        let srv = server(small_cfg());
        let a = Matrix::random(20, 12, 500);
        let b = Matrix::random(12, 24, 501);
        let want = crate::gemm::packed_matmul(&a, &b, 16, 16);
        let plain = srv
            .submit(GemmJob {
                id: 0,
                a: a.clone().into(),
                b: b.clone().into(),
                run: Some(RunConfig::square(2, 16)),
            })
            .unwrap()
            .wait()
            .unwrap();
        let explicit = srv
            .submit_blocking(
                Submission::gemm(a, b).run(RunConfig::square(2, 16)).dtype(Dtype::F32),
            )
            .unwrap();
        assert_eq!(plain.c.data, want.data, "default path matches the pinned reference");
        assert_eq!(explicit[0].c.data, want.data, "explicit F32 is bit-identical");
        let s = srv.stats();
        assert_eq!((s.a_panel_packs, s.b_panel_packs), (2, 2), "one pack per side per job");
        assert_eq!(
            s.registry_dtype_resident_bytes,
            [0, 0, 0, 0],
            "inline jobs leave nothing resident"
        );
    }

    #[test]
    fn half_dtype_jobs_match_f64_oracle_at_ragged_shapes() {
        // Reduced-precision GEMM accumulates in f32 over half-width
        // panels; against an f64 oracle the error stays within the
        // documented per-dtype bounds even at ragged prime shapes.
        let srv = server(small_cfg());
        for (dtype, tol) in [(Dtype::F16, 2e-2f32), (Dtype::Bf16, 1.5e-1)] {
            for (i, &(m, k, n)) in
                [(13usize, 7usize, 11usize), (23, 5, 9), (3, 17, 29)].iter().enumerate()
            {
                let a = Matrix::random(m, k, 520 + i as u64);
                let b = Matrix::random(k, n, 540 + i as u64);
                let oracle = a.matmul_f64(&b);
                let r = srv
                    .submit_blocking(
                        Submission::gemm(a, b).run(RunConfig::square(2, 16)).dtype(dtype),
                    )
                    .unwrap();
                assert!(
                    r[0].c.allclose(&oracle, tol),
                    "{dtype} {m}x{k}x{n} exceeded tolerance {tol}"
                );
            }
        }
        // F64 jobs ride the same plumbing (wide panels, f64 accumulate).
        let a = Matrix::random(13, 7, 580);
        let b = Matrix::random(7, 11, 581);
        let oracle = a.matmul_f64(&b);
        let r = srv
            .submit_blocking(
                Submission::gemm(a, b).run(RunConfig::square(2, 16)).dtype(Dtype::F64),
            )
            .unwrap();
        assert!(r[0].c.allclose(&oracle, 1e-6));
    }

    #[test]
    fn registered_weight_serves_two_dtypes_with_one_pack_per_variant() {
        // The multi-precision registry gate: one WeightHandle serves f32
        // and bf16 traffic with exactly one pack per (S_j, dtype)
        // variant, and the per-dtype residency split surfaces in stats.
        let srv = server(small_cfg());
        let b = Matrix::random(16, 24, 590);
        let h = srv.register_b(b.clone()).unwrap();
        let run = RunConfig::square(2, 16);
        for (id, dtype) in
            [(0u64, Dtype::F32), (1, Dtype::Bf16), (2, Dtype::F32), (3, Dtype::Bf16)]
        {
            let a = Matrix::random(20, 16, 595 + id);
            let oracle = a.matmul_f64(&b);
            let tol = if dtype == Dtype::F32 { 1e-4 } else { 1.5e-1 };
            let r = srv
                .submit_blocking(Submission::gemm(a, h).id(id).run(run).dtype(dtype))
                .unwrap();
            assert!(r[0].c.allclose(&oracle, tol), "job {id} ({dtype})");
        }
        let s = srv.stats();
        assert_eq!(s.b_panel_packs, 2, "one pack per (handle, sj, dtype) variant");
        assert_eq!((s.registry_hits, s.registry_misses), (2, 2));
        assert_eq!(s.a_panel_packs, 4, "inline A packs are per-job regardless of dtype");
        let f32_bytes = s.registry_dtype_resident_bytes[Dtype::F32.index()];
        let bf16_bytes = s.registry_dtype_resident_bytes[Dtype::Bf16.index()];
        assert!(f32_bytes > 0 && bf16_bytes > 0);
        assert_eq!(bf16_bytes * 2, f32_bytes, "half-width panels, same element count");
        assert_eq!(f32_bytes + bf16_bytes, s.registry_resident_bytes);
        assert!(s.to_string().contains("dtype_resident(f32/f64/f16/bf16)="), "got: {s}");
    }

    use super::super::trace::Terminal;

    #[test]
    fn disabled_trace_records_nothing() {
        // The overhead gate: with `trace_capacity: 0` (the default) the
        // whole pipeline runs without recording a single event — and
        // the snapshot allocates nothing.
        let srv = server(small_cfg());
        assert!(!srv.trace_enabled());
        let a = Matrix::random(32, 16, 1);
        let b = Matrix::random(16, 32, 2);
        srv.submit(GemmJob { id: 0, a: a.into(), b: b.into(), run: Some(RunConfig::square(2, 16)) })
            .unwrap()
            .wait()
            .unwrap();
        let snap = srv.trace_snapshot();
        assert_eq!(snap.recorded, 0);
        assert_eq!(snap.dropped, 0);
        assert!(snap.events.is_empty());
        assert!(snap.events.capacity() == 0, "disabled snapshot must not allocate");
        let s = srv.stats();
        assert_eq!((s.trace_recorded, s.trace_dropped), (0, 0));
        assert!(s.stage_p50_p95_secs.is_none());
    }

    #[test]
    fn traced_lifecycle_telescopes_and_surfaces_drift() {
        let cfg = ServerConfig { trace_capacity: 1024, ..small_cfg() };
        let srv = server(cfg);
        for i in 0..3u64 {
            let a = Matrix::random(48, 24, 10 + i);
            let b = Matrix::random(24, 40, 20 + i);
            srv.submit(GemmJob {
                id: i,
                a: a.into(),
                b: b.into(),
                run: Some(RunConfig::square(2, 16)),
            })
            .unwrap()
            .wait()
            .unwrap();
        }
        let snap = srv.trace_snapshot();
        let traces = snap.job_traces();
        assert_eq!(traces.len(), 3, "one JobTrace per submitted job");
        for t in &traces {
            assert_eq!(t.terminal, Terminal::Done);
            let stages = t.stage_secs().expect("full stage breakdown");
            let e2e = t.end_to_end_secs().expect("e2e span");
            let sum: f64 = stages.iter().sum();
            assert!(
                (sum - e2e).abs() < 1e-9,
                "stages must telescope to e2e: {sum} vs {e2e}"
            );
            assert!(t.tasks >= 1);
            assert_eq!(
                t.workers.iter().map(|w| w.tasks).sum::<u64>(),
                t.tasks,
                "per-worker tallies partition the task count"
            );
            assert!(t.predicted_secs.is_some(), "planned jobs carry a prediction");
            let measured = t.measured_secs.expect("done jobs carry a measurement");
            assert!(measured > 0.0);
        }
        // The drift aggregate and stage rollups surface in stats().
        let s = srv.stats();
        assert!(s.trace_recorded > 0);
        let d = s.drift.expect("3 completed jobs recorded drift");
        assert_eq!(d.count, 3);
        assert!(d.min <= d.mean && d.mean <= d.max);
        let stages = s.stage_p50_p95_secs.expect("stage rollup with tracing on");
        for (p50, p95) in stages {
            assert!(p50 <= p95);
        }
        let text = s.to_string();
        assert!(text.contains("worker_tasks(max/min)="), "got: {text}");
        assert!(text.contains("drift(min/mean/max/p95)="), "got: {text}");
        assert!(text.contains("stages(p50/p95)=[queue="), "got: {text}");
    }

    #[test]
    fn plan_failure_is_a_traced_terminal() {
        let cfg = ServerConfig { trace_capacity: 256, ..small_cfg() };
        let srv = server(cfg);
        let bad = GemmJob {
            id: 1,
            a: Matrix::random(8, 8, 5).into(),
            b: Matrix::random(9, 8, 6).into(), // contraction mismatch
            run: None,
        };
        assert!(srv.submit(bad).unwrap().wait().is_err());
        let traces = srv.trace_snapshot().job_traces();
        assert_eq!(traces.len(), 1);
        assert_eq!(traces[0].terminal, Terminal::PlanFailed);
        assert!(traces[0].done_us.is_some(), "terminal events carry a timestamp");
    }

    #[test]
    fn quota_rejection_is_a_traced_terminal() {
        let cfg = ServerConfig { trace_capacity: 1024, workers: 1, ..small_cfg() };
        let srv = server(cfg);
        let t7 = TenantId(7);
        srv.configure_tenant(
            t7,
            TenantConfig { weight: 1, max_inflight_jobs: Some(1), max_inflight_bytes: None },
        )
        .unwrap();
        // A large job holds the tenant's whole quota while in flight...
        let a = Matrix::random(512, 64, 30);
        let b = Matrix::random(64, 512, 31);
        let fut = srv
            .submit_async(Submission::gemm(a, b).tenant(t7).run(RunConfig::square(2, 16)))
            .unwrap();
        // ...so the next submission bounces at the door.
        let r = srv.try_submit(
            Submission::gemm(Matrix::random(8, 8, 32), Matrix::random(8, 8, 33)).tenant(t7),
        );
        assert!(matches!(r, Err(SubmitError::QuotaExceeded { .. })));
        fut.wait().unwrap();
        let traces = srv.trace_snapshot().job_traces();
        assert_eq!(traces.len(), 2, "rejected work still has a trace identity");
        let rejected: Vec<_> =
            traces.iter().filter(|t| t.terminal == Terminal::QuotaRejected).collect();
        assert_eq!(rejected.len(), 1, "exactly one quota rejection");
        assert_eq!(rejected[0].tenant, 7);
        assert_eq!(
            traces.iter().filter(|t| t.terminal == Terminal::Done).count(),
            1,
            "the admitted job completed"
        );
    }

    #[test]
    fn trace_conserves_every_submission_under_shedding() {
        // Conservation: every uid that entered `admit` ends with exactly
        // one terminal — Done for completions, Shed for queue-full
        // rejections — no matter how the flood races the dispatcher.
        let cfg = ServerConfig {
            trace_capacity: 8192,
            workers: 1,
            queue_capacity: 1,
            ..small_cfg()
        };
        let srv = server(cfg);
        let mut futs = Vec::new();
        let mut shed = 0u64;
        let total = 24u64;
        for i in 0..total {
            let s = Submission::gemm(Matrix::random(64, 32, i), Matrix::random(32, 64, 100 + i))
                .run(RunConfig::square(2, 16));
            match srv.try_submit(s) {
                Ok(f) => futs.push(f),
                Err(SubmitError::Full(_)) => shed += 1,
                Err(e) => panic!("unexpected admission outcome: {e:?}"),
            }
        }
        for f in futs {
            f.wait().unwrap();
        }
        let traces = srv.trace_snapshot().job_traces();
        assert_eq!(traces.len() as u64, total, "every submission traced exactly once");
        assert!(traces.iter().all(|t| t.terminal != Terminal::InFlight));
        let sheds = traces.iter().filter(|t| t.terminal == Terminal::Shed).count() as u64;
        let dones = traces.iter().filter(|t| t.terminal == Terminal::Done).count() as u64;
        assert_eq!(sheds, shed, "one Shed terminal per queue-full rejection");
        assert_eq!(dones, total - shed, "everything admitted ran to completion");
    }

    #[test]
    fn workload_spans_bracket_in_the_trace() {
        let cfg = ServerConfig { trace_capacity: 128, ..small_cfg() };
        let srv = server(cfg);
        srv.trace_span_begin(SpanKind::StrassenLevel, 2);
        srv.trace_span_end(SpanKind::StrassenLevel, 2);
        let snap = srv.trace_snapshot();
        let spans: Vec<_> = snap
            .events
            .iter()
            .filter(|e| matches!(e.kind, EventKind::SpanBegin | EventKind::SpanEnd))
            .collect();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].kind, EventKind::SpanBegin);
        assert_eq!(spans[0].uid, SpanKind::StrassenLevel as u64);
        assert_eq!(spans[0].a, 2);
        assert_eq!(spans[1].kind, EventKind::SpanEnd);
        assert!(spans[0].t_us <= spans[1].t_us);
    }
}
