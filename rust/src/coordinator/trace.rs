//! Flight recorder for the serving stack.
//!
//! The server's counters (`Metrics`, `ServerStats`) say *how much* —
//! jobs, packs, steals, percentiles — but not *where the time went* or
//! *which worker did the work*. This module adds the missing evidence
//! layer: a bounded, lock-free, multi-producer flight recorder that
//! stamps every job's lifecycle
//!
//! ```text
//! submit → quota/admit → DRR pop → plan → pack → publish
//!        → first/last task → finalize/reply
//! ```
//!
//! so each [`JobTrace`] yields
//!
//! * a **queue-wait / plan / pack / execute / finalize** breakdown whose
//!   five spans telescope exactly to the job's end-to-end latency (all
//!   spans are differences of the *same* event timestamps);
//! * **per-worker task and steal-provenance counts** — the direct
//!   observable for the paper's claim that work stealing equalizes the
//!   workload partition across arrays;
//! * a **`predicted_secs` vs `measured_secs` drift record**: the
//!   analytical model (Eqs. 3–7) prices the *chosen* config at plan
//!   time, the simulator reports measured time at finalize, and the
//!   relative drift between them is the model-calibration signal the
//!   ROADMAP's measured-backend item needs.
//!
//! ## The ring
//!
//! [`TraceRing`] is a fixed-capacity MPSC ring of compact, `Copy`
//! [`TraceEvent`]s with overwrite-oldest semantics. Each slot carries a
//! seqlock word: a writer claims generation `n` by CAS-ing the slot's
//! sequence from an even (stable) value to `2n+1`, writes the payload,
//! and publishes `2n+2`; the snapshot reader copies a slot only when it
//! observes the same even sequence before and after the copy, so a
//! snapshot can never tear an event. A writer that loses the claim race
//! (another writer lapped the ring onto the same slot) drops its event
//! and counts it — the recorder is lossy-oldest by design, never
//! blocking and never corrupting. With `capacity == 0` the ring holds
//! no slots, allocates nothing, and `emit` returns immediately — the
//! disabled recorder's cost is one branch.
//!
//! ## Export
//!
//! [`TraceSnapshot::job_traces`] folds the raw events into per-job
//! records; [`TraceExporter`] writes them as JSONL (one job per line,
//! validated by `ci/check_trace_schema.py`) and as Chrome
//! `trace_event` JSON loadable in Perfetto — one track per worker
//! (task execution with steal provenance), one per dispatcher shard
//! (plan/pack), one for registry activity, one for workload-level
//! spans, plus per-job async stage spans.

use std::cell::UnsafeCell;
use std::collections::BTreeMap;
use std::io::{self, Write};
use std::sync::atomic::{fence, AtomicU64, Ordering};
use std::time::Instant;

/// What a [`TraceEvent`] records. Job-lifecycle kinds (`Submit` through
/// `Fail`) are keyed by job uid; registry kinds carry a handle id;
/// span kinds carry a [`SpanKind`] code.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum EventKind {
    /// Job entered `admit` (one event per sub-job of the submission).
    Submit,
    /// Job passed quota and was pushed into the admission queue.
    Admit,
    /// Terminal: the tenant's quota rejected the submission.
    QuotaReject,
    /// Terminal: `try_submit` shed the job (queue full / closed).
    Shed,
    /// A dispatcher shard popped the job from the DRR queue
    /// (`actor` = shard).
    Pop,
    /// Planning chose a config; `a` = predicted seconds (f64 bits).
    Planned,
    /// Terminal: planning failed, the job replied with an error.
    PlanFail,
    /// Operands packed and tasks published to the workers.
    Published,
    /// A worker finished one task (`actor` = worker, `a` = start µs,
    /// `b` = provenance flags, see [`TASK_STOLEN`]).
    TaskExec,
    /// Terminal: finalized and replied; `a`/`b` = predicted/measured
    /// seconds (f64 bits) — the model-drift record.
    Done,
    /// Terminal: the job failed after admission (operand resolution,
    /// validation, execution error).
    Fail,
    /// Registry pack-cache hit (`uid` = handle, `a` = bytes,
    /// `b` = side in bit 0 (0 = A, 1 = B) with the pack's
    /// `Dtype::index` in the bits above it — f32 packs, index 0, emit
    /// exactly the pre-multi-precision payloads).
    RegistryHit,
    /// Registry pack-cache miss (payload as [`EventKind::RegistryHit`]).
    RegistryMiss,
    /// Registry evicted a pack (payload as [`EventKind::RegistryHit`]).
    RegistryEvict,
    /// Workload-level span opened (`uid` = [`SpanKind`] code,
    /// `a` = detail).
    SpanBegin,
    /// Workload-level span closed (payload as [`EventKind::SpanBegin`]).
    SpanEnd,
    /// The admission queue's DRR scheduler served a tenant
    /// (`a` = jobs still queued, `b` = remaining deficit).
    DrrPop,
}

/// `TaskExec.b` bit: the task was claimed from a queue other than the
/// executing worker's own (intra-job steal).
pub const TASK_STOLEN: u64 = 1;
/// `TaskExec.b` bit: the worker switched jobs to claim this task
/// (cross-job steal).
pub const TASK_CROSS_JOB: u64 = 2;

/// `actor` value for events not tied to a worker or shard.
pub const ACTOR_NONE: u32 = u32::MAX;

/// Workload-level span labels for [`EventKind::SpanBegin`] /
/// [`EventKind::SpanEnd`], emitted by the strassen / cnn / attention
/// layers around their group submissions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u32)]
pub enum SpanKind {
    /// One Strassen recursion level's 7-product fan-out (`detail` =
    /// level).
    StrassenLevel = 1,
    /// One served CNN layer (`detail` = layer index).
    CnnLayer = 2,
    /// One attention-block phase (`detail`: 0 = Q/K/V projections,
    /// 1 = QKᵀ + softmax + AV, 2 = O projection).
    AttentionPhase = 3,
    /// One Strassen node's C-quadrant recombination (`detail` = level)
    /// — the host-side add/sub work between leaf groups, so Perfetto
    /// shows combine-vs-leaf time directly.
    StrassenCombine = 4,
}

impl SpanKind {
    /// Exporter-facing name for the span track.
    pub fn name(code: u64) -> &'static str {
        match code {
            1 => "strassen-level",
            2 => "cnn-layer",
            3 => "attention-phase",
            4 => "strassen-combine",
            _ => "span",
        }
    }
}

/// One compact flight-recorder record. `Copy` and fixed-size so the
/// ring's seqlock copy is a plain memcpy.
#[derive(Clone, Copy, Debug)]
pub struct TraceEvent {
    /// Microseconds since the ring's epoch (server start).
    pub t_us: u64,
    /// Job uid for lifecycle kinds; handle id for registry kinds;
    /// span code for span kinds.
    pub uid: u64,
    /// Kind-specific payload (see [`EventKind`]).
    pub a: u64,
    /// Kind-specific payload (see [`EventKind`]).
    pub b: u64,
    /// Tenant tag (`u32::MAX` when not applicable).
    pub tenant: u32,
    /// Worker index (`TaskExec`), dispatcher shard (`Pop`/`Planned`/
    /// `Published`), or [`ACTOR_NONE`].
    pub actor: u32,
    /// What happened.
    pub kind: EventKind,
}

impl TraceEvent {
    const EMPTY: TraceEvent = TraceEvent {
        t_us: 0,
        uid: 0,
        a: 0,
        b: 0,
        tenant: 0,
        actor: ACTOR_NONE,
        kind: EventKind::Submit,
    };
}

struct Slot {
    /// Seqlock word: `0` = never written, odd `2n+1` = generation `n`
    /// being written, even `2n+2` = generation `n` stable.
    seq: AtomicU64,
    ev: UnsafeCell<TraceEvent>,
}

/// Bounded lock-free MPSC flight recorder (see module docs).
pub struct TraceRing {
    epoch: Instant,
    /// Next generation number; slot = `n % capacity`.
    next: AtomicU64,
    /// Events dropped on lap collision (writer raced a lapping writer).
    dropped: AtomicU64,
    slots: Box<[Slot]>,
}

// SAFETY: the `UnsafeCell` payload is only written by the writer that
// owns the slot's odd sequence (claimed by CAS from an even value, so
// exactly one writer at a time), and only read through the seqlock
// protocol (copy validated by an unchanged even sequence on both
// sides). Torn reads are detected and retried, never returned.
unsafe impl Sync for TraceRing {}
unsafe impl Send for TraceRing {}

impl std::fmt::Debug for TraceRing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraceRing")
            .field("capacity", &self.slots.len())
            .field("recorded", &self.recorded())
            .field("dropped", &self.dropped())
            .finish()
    }
}

impl TraceRing {
    /// A recorder with room for `capacity` events. `capacity == 0`
    /// disables recording entirely: no slots are allocated
    /// (`Vec::new().into_boxed_slice()` holds no heap block) and
    /// [`TraceRing::emit`] is a single branch.
    pub fn new(capacity: usize) -> Self {
        let slots: Vec<Slot> = (0..capacity)
            .map(|_| Slot { seq: AtomicU64::new(0), ev: UnsafeCell::new(TraceEvent::EMPTY) })
            .collect();
        Self {
            epoch: Instant::now(),
            next: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            slots: slots.into_boxed_slice(),
        }
    }

    /// Whether the recorder stores anything at all.
    pub fn enabled(&self) -> bool {
        !self.slots.is_empty()
    }

    /// Slot count.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Total emit attempts while enabled (monotonic; the ring retains
    /// the most recent `capacity` of them, minus lap drops).
    pub fn recorded(&self) -> u64 {
        if self.enabled() {
            self.next.load(Ordering::Relaxed)
        } else {
            0
        }
    }

    /// Events lost to lap collisions (not to ordinary overwrite).
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Microseconds since the ring's epoch.
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Record one event. Lock-free: one fetch-add, one CAS, one
    /// payload copy, one release store. Never blocks; on a lap
    /// collision (a writer `capacity` generations ahead already owns
    /// the slot) the event is dropped and counted.
    #[allow(clippy::too_many_arguments)]
    pub fn emit(&self, kind: EventKind, uid: u64, tenant: u32, actor: u32, a: u64, b: u64) {
        if self.slots.is_empty() {
            return;
        }
        let t_us = self.now_us();
        let n = self.next.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(n as usize) % self.slots.len()];
        let claimed = 2 * n + 1;
        let seen = slot.seq.load(Ordering::Relaxed);
        // Only claim forward: an odd `seen` means another writer is
        // mid-write here; `seen >= claimed` means a *newer* generation
        // already owns the slot (we were lapped while stalled). Either
        // way our event is the oldest thing in sight — drop it.
        if seen % 2 == 1 || seen >= claimed {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        if slot
            .seq
            .compare_exchange(seen, claimed, Ordering::Acquire, Ordering::Relaxed)
            .is_err()
        {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        // SAFETY: the successful CAS from an even value makes this
        // thread the slot's unique writer until the release store
        // below (any racing writer observes an odd sequence and drops).
        unsafe {
            std::ptr::write_volatile(
                slot.ev.get(),
                TraceEvent { t_us, uid, a, b, tenant, actor, kind },
            );
        }
        slot.seq.store(claimed + 1, Ordering::Release);
    }

    /// Tear-free copy of every stable event, oldest first.
    pub fn snapshot(&self) -> TraceSnapshot {
        let mut tagged: Vec<(u64, TraceEvent)> = Vec::with_capacity(self.slots.len());
        for slot in self.slots.iter() {
            loop {
                let s1 = slot.seq.load(Ordering::Acquire);
                if s1 == 0 || s1 % 2 == 1 {
                    // Never written, or mid-write right now — skip.
                    break;
                }
                // SAFETY: seqlock read — the copy is only kept if the
                // sequence is unchanged (still `s1`) after it, which
                // means no writer touched the payload during the copy.
                let ev = unsafe { std::ptr::read_volatile(slot.ev.get()) };
                fence(Ordering::Acquire);
                if slot.seq.load(Ordering::Relaxed) == s1 {
                    tagged.push((s1 / 2 - 1, ev));
                    break;
                }
                // Torn — a writer claimed the slot mid-copy; retry.
            }
        }
        tagged.sort_unstable_by_key(|(n, _)| *n);
        TraceSnapshot {
            events: tagged.into_iter().map(|(_, ev)| ev).collect(),
            recorded: self.recorded(),
            dropped: self.dropped(),
        }
    }
}

/// A consistent copy of the recorder's contents.
#[derive(Debug, Clone)]
pub struct TraceSnapshot {
    /// Stable events, oldest first.
    pub events: Vec<TraceEvent>,
    /// Total events ever accepted by the ring (≥ `events.len()`).
    pub recorded: u64,
    /// Events lost to writer lap collisions.
    pub dropped: u64,
}

impl TraceSnapshot {
    /// Fold raw events into per-job lifecycle records, uid-ascending.
    pub fn job_traces(&self) -> Vec<JobTrace> {
        job_traces(&self.events)
    }

    /// A [`TraceExporter`] over this snapshot.
    pub fn exporter(&self) -> TraceExporter<'_> {
        TraceExporter { snap: self }
    }
}

/// How a job's lifecycle ended (or hasn't yet).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Terminal {
    /// Finalized and replied successfully.
    Done,
    /// Rejected at the door by the tenant's quota.
    QuotaRejected,
    /// Shed by `try_submit` (queue full or closed).
    Shed,
    /// Planning failed; the job replied with an error.
    PlanFailed,
    /// Failed after admission (resolution / validation / execution).
    Failed,
    /// No terminal event recorded (still running, or its terminal
    /// event was overwritten).
    InFlight,
}

impl Terminal {
    /// JSONL-facing name.
    pub fn name(self) -> &'static str {
        match self {
            Terminal::Done => "done",
            Terminal::QuotaRejected => "quota_rejected",
            Terminal::Shed => "shed",
            Terminal::PlanFailed => "plan_failed",
            Terminal::Failed => "failed",
            Terminal::InFlight => "in_flight",
        }
    }
}

/// Per-worker execution tally within one job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WorkerTally {
    /// Worker index.
    pub worker: u32,
    /// Tasks this worker executed for the job.
    pub tasks: u64,
    /// Of those, tasks claimed from another queue (steal provenance:
    /// intra-job back-steals plus cross-job switches).
    pub stolen: u64,
}

/// One job's reconstructed lifecycle: stage timestamps, per-worker
/// provenance, and the model-drift record.
#[derive(Clone, Debug)]
pub struct JobTrace {
    /// Server-minted job uid (unique per sub-job for the process).
    pub uid: u64,
    /// Owning tenant.
    pub tenant: u32,
    /// `Submit` timestamp (µs since ring epoch).
    pub submit_us: Option<u64>,
    /// `Admit` timestamp.
    pub admit_us: Option<u64>,
    /// DRR `Pop` timestamp.
    pub pop_us: Option<u64>,
    /// `Planned` timestamp.
    pub planned_us: Option<u64>,
    /// `Published` (packed + tasks live) timestamp.
    pub published_us: Option<u64>,
    /// Earliest task start.
    pub first_task_us: Option<u64>,
    /// Latest task completion.
    pub last_task_us: Option<u64>,
    /// Terminal-event timestamp.
    pub done_us: Option<u64>,
    /// How the lifecycle ended.
    pub terminal: Terminal,
    /// Total tasks executed.
    pub tasks: u64,
    /// Tasks with steal provenance (claimed off another queue).
    pub stolen_tasks: u64,
    /// Per-worker tallies, worker-ascending.
    pub workers: Vec<WorkerTally>,
    /// `analytical::predict` for the chosen config, priced at plan
    /// time.
    pub predicted_secs: Option<f64>,
    /// Simulated execution time reported at finalize.
    pub measured_secs: Option<f64>,
}

impl JobTrace {
    fn new(uid: u64, tenant: u32) -> Self {
        Self {
            uid,
            tenant,
            submit_us: None,
            admit_us: None,
            pop_us: None,
            planned_us: None,
            published_us: None,
            first_task_us: None,
            last_task_us: None,
            done_us: None,
            terminal: Terminal::InFlight,
            tasks: 0,
            stolen_tasks: 0,
            workers: Vec::new(),
            predicted_secs: None,
            measured_secs: None,
        }
    }

    fn span_secs(a: Option<u64>, b: Option<u64>) -> Option<f64> {
        Some(b?.saturating_sub(a?) as f64 * 1e-6)
    }

    /// submit → pop: admission-queue wait.
    pub fn queue_secs(&self) -> Option<f64> {
        Self::span_secs(self.submit_us, self.pop_us)
    }

    /// pop → planned: config choice (DSE / residency refinement).
    pub fn plan_secs(&self) -> Option<f64> {
        Self::span_secs(self.pop_us, self.planned_us)
    }

    /// planned → published: operand resolve + pack + task publish.
    pub fn pack_secs(&self) -> Option<f64> {
        Self::span_secs(self.planned_us, self.published_us)
    }

    /// published → last task: worker execution.
    pub fn execute_secs(&self) -> Option<f64> {
        Self::span_secs(self.published_us, self.last_task_us)
    }

    /// last task → done: take C, simulate timing, reply.
    pub fn finalize_secs(&self) -> Option<f64> {
        Self::span_secs(self.last_task_us, self.done_us)
    }

    /// submit → done.
    pub fn end_to_end_secs(&self) -> Option<f64> {
        Self::span_secs(self.submit_us, self.done_us)
    }

    /// The five stage spans `[queue, plan, pack, execute, finalize]`.
    /// They are differences of one timestamp chain, so their sum
    /// telescopes to [`JobTrace::end_to_end_secs`] exactly (up to µs
    /// quantization).
    pub fn stage_secs(&self) -> Option<[f64; 5]> {
        Some([
            self.queue_secs()?,
            self.plan_secs()?,
            self.pack_secs()?,
            self.execute_secs()?,
            self.finalize_secs()?,
        ])
    }

    /// Relative model drift `(measured - predicted) / predicted`.
    pub fn drift_frac(&self) -> Option<f64> {
        let (p, m) = (self.predicted_secs?, self.measured_secs?);
        if p > 0.0 {
            Some((m - p) / p)
        } else {
            None
        }
    }
}

/// Stage labels, index-aligned with [`JobTrace::stage_secs`].
pub const STAGE_NAMES: [&str; 5] = ["queue", "plan", "pack", "execute", "finalize"];

/// Fold a raw event stream into per-job records (uid-ascending).
/// Registry / span / DRR events are not job-keyed and are skipped.
pub fn job_traces(events: &[TraceEvent]) -> Vec<JobTrace> {
    let mut map: BTreeMap<u64, JobTrace> = BTreeMap::new();
    for ev in events {
        match ev.kind {
            EventKind::RegistryHit
            | EventKind::RegistryMiss
            | EventKind::RegistryEvict
            | EventKind::SpanBegin
            | EventKind::SpanEnd
            | EventKind::DrrPop => continue,
            _ => {}
        }
        let jt = map.entry(ev.uid).or_insert_with(|| JobTrace::new(ev.uid, ev.tenant));
        if ev.tenant != ACTOR_NONE {
            jt.tenant = ev.tenant;
        }
        match ev.kind {
            EventKind::Submit => jt.submit_us = Some(ev.t_us),
            EventKind::Admit => jt.admit_us = Some(ev.t_us),
            EventKind::QuotaReject => {
                jt.terminal = Terminal::QuotaRejected;
                jt.done_us = Some(ev.t_us);
            }
            EventKind::Shed => {
                jt.terminal = Terminal::Shed;
                jt.done_us = Some(ev.t_us);
            }
            EventKind::Pop => jt.pop_us = Some(ev.t_us),
            EventKind::Planned => {
                jt.planned_us = Some(ev.t_us);
                jt.predicted_secs = Some(f64::from_bits(ev.a));
            }
            EventKind::PlanFail => {
                jt.terminal = Terminal::PlanFailed;
                jt.done_us = Some(ev.t_us);
            }
            EventKind::Published => jt.published_us = Some(ev.t_us),
            EventKind::TaskExec => {
                jt.tasks += 1;
                let stolen = ev.b & (TASK_STOLEN | TASK_CROSS_JOB) != 0;
                if stolen {
                    jt.stolen_tasks += 1;
                }
                jt.first_task_us =
                    Some(jt.first_task_us.map_or(ev.a, |f| f.min(ev.a)));
                jt.last_task_us =
                    Some(jt.last_task_us.map_or(ev.t_us, |l| l.max(ev.t_us)));
                match jt.workers.binary_search_by_key(&ev.actor, |w| w.worker) {
                    Ok(i) => {
                        jt.workers[i].tasks += 1;
                        if stolen {
                            jt.workers[i].stolen += 1;
                        }
                    }
                    Err(i) => jt.workers.insert(
                        i,
                        WorkerTally {
                            worker: ev.actor,
                            tasks: 1,
                            stolen: u64::from(stolen),
                        },
                    ),
                }
            }
            EventKind::Done => {
                jt.terminal = Terminal::Done;
                jt.done_us = Some(ev.t_us);
                jt.predicted_secs = Some(f64::from_bits(ev.a));
                jt.measured_secs = Some(f64::from_bits(ev.b));
            }
            EventKind::Fail => {
                jt.terminal = Terminal::Failed;
                jt.done_us = Some(ev.t_us);
            }
            _ => unreachable!("non-job kinds filtered above"),
        }
    }
    map.into_values().collect()
}

/// Nearest-rank percentiles of each stage span over completed traces:
/// `result[stage][i]` is the `ps[i]` percentile of stage `stage`
/// (index-aligned with [`STAGE_NAMES`]). `None` when no trace has a
/// full stage breakdown.
pub fn stage_percentiles(traces: &[JobTrace], ps: &[f64]) -> Option<Vec<Vec<f64>>> {
    let mut per_stage: [Vec<f64>; 5] = Default::default();
    for t in traces {
        if let Some(stages) = t.stage_secs() {
            for (acc, v) in per_stage.iter_mut().zip(stages) {
                acc.push(v);
            }
        }
    }
    if per_stage[0].is_empty() {
        return None;
    }
    Some(
        per_stage
            .iter_mut()
            .map(|vals| {
                vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
                ps.iter()
                    .map(|&p| {
                        let rank = ((p * vals.len() as f64).ceil() as usize)
                            .saturating_sub(1)
                            .min(vals.len() - 1);
                        vals[rank]
                    })
                    .collect()
            })
            .collect(),
    )
}

fn json_f64(v: Option<f64>) -> String {
    match v {
        Some(x) if x.is_finite() => format!("{x}"),
        _ => "null".to_string(),
    }
}

fn json_u64(v: Option<u64>) -> String {
    match v {
        Some(x) => format!("{x}"),
        None => "null".to_string(),
    }
}

/// Writes a [`TraceSnapshot`] in the two interchange formats.
pub struct TraceExporter<'a> {
    snap: &'a TraceSnapshot,
}

impl TraceExporter<'_> {
    /// JSONL: one JSON object per job trace, schema validated by
    /// `ci/check_trace_schema.py`. Stage spans and drift are emitted
    /// pre-computed so consumers never re-derive them.
    pub fn write_jsonl<W: Write>(&self, w: &mut W) -> io::Result<()> {
        for t in self.snap.job_traces() {
            let mut workers = String::new();
            for (i, wt) in t.workers.iter().enumerate() {
                if i > 0 {
                    workers.push(',');
                }
                workers.push_str(&format!(
                    "{{\"worker\":{},\"tasks\":{},\"stolen\":{}}}",
                    wt.worker, wt.tasks, wt.stolen
                ));
            }
            writeln!(
                w,
                "{{\"uid\":{},\"tenant\":{},\"terminal\":\"{}\",\
                 \"submit_us\":{},\"pop_us\":{},\"planned_us\":{},\
                 \"published_us\":{},\"first_task_us\":{},\"last_task_us\":{},\
                 \"done_us\":{},\"queue_secs\":{},\"plan_secs\":{},\
                 \"pack_secs\":{},\"execute_secs\":{},\"finalize_secs\":{},\
                 \"e2e_secs\":{},\"predicted_secs\":{},\"measured_secs\":{},\
                 \"drift_frac\":{},\"tasks\":{},\"stolen_tasks\":{},\
                 \"workers\":[{}]}}",
                t.uid,
                t.tenant,
                t.terminal.name(),
                json_u64(t.submit_us),
                json_u64(t.pop_us),
                json_u64(t.planned_us),
                json_u64(t.published_us),
                json_u64(t.first_task_us),
                json_u64(t.last_task_us),
                json_u64(t.done_us),
                json_f64(t.queue_secs()),
                json_f64(t.plan_secs()),
                json_f64(t.pack_secs()),
                json_f64(t.execute_secs()),
                json_f64(t.finalize_secs()),
                json_f64(t.end_to_end_secs()),
                json_f64(t.predicted_secs),
                json_f64(t.measured_secs),
                json_f64(t.drift_frac()),
                t.tasks,
                t.stolen_tasks,
                workers,
            )?;
        }
        Ok(())
    }

    /// Chrome `trace_event` JSON (Perfetto-loadable): one track per
    /// worker carrying task "X" slices with steal provenance, one per
    /// dispatcher shard carrying plan/pack slices, instant events for
    /// registry activity, "B"/"E" slices for workload spans, and
    /// per-job "b"/"e" async stage spans.
    pub fn write_chrome<W: Write>(&self, w: &mut W) -> io::Result<()> {
        const PID: u32 = 1;
        let tid_worker = |wk: u32| 1 + wk;
        let tid_shard = |sh: u32| 1001 + sh;
        const TID_REGISTRY: u32 = 900;
        const TID_SPANS: u32 = 901;

        write!(w, "[")?;
        let mut first = true;
        let mut sep = |w: &mut W| -> io::Result<()> {
            if first {
                first = false;
            } else {
                write!(w, ",")?;
            }
            writeln!(w)
        };

        // Thread-name metadata for every track that appears.
        let mut workers: Vec<u32> = Vec::new();
        let mut shards: Vec<u32> = Vec::new();
        let mut saw_registry = false;
        let mut saw_spans = false;
        for ev in &self.snap.events {
            match ev.kind {
                EventKind::TaskExec => {
                    if !workers.contains(&ev.actor) {
                        workers.push(ev.actor);
                    }
                }
                EventKind::Pop | EventKind::Planned | EventKind::Published
                    if ev.actor != ACTOR_NONE =>
                {
                    if !shards.contains(&ev.actor) {
                        shards.push(ev.actor);
                    }
                }
                EventKind::RegistryHit | EventKind::RegistryMiss | EventKind::RegistryEvict => {
                    saw_registry = true;
                }
                EventKind::SpanBegin | EventKind::SpanEnd => saw_spans = true,
                _ => {}
            }
        }
        workers.sort_unstable();
        shards.sort_unstable();
        for &wk in &workers {
            sep(w)?;
            write!(
                w,
                "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":{PID},\"tid\":{},\
                 \"args\":{{\"name\":\"worker-{wk}\"}}}}",
                tid_worker(wk)
            )?;
        }
        for &sh in &shards {
            sep(w)?;
            write!(
                w,
                "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":{PID},\"tid\":{},\
                 \"args\":{{\"name\":\"dispatch-{sh}\"}}}}",
                tid_shard(sh)
            )?;
        }
        if saw_registry {
            sep(w)?;
            write!(
                w,
                "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":{PID},\
                 \"tid\":{TID_REGISTRY},\"args\":{{\"name\":\"registry\"}}}}"
            )?;
        }
        if saw_spans {
            sep(w)?;
            write!(
                w,
                "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":{PID},\
                 \"tid\":{TID_SPANS},\"args\":{{\"name\":\"workload\"}}}}"
            )?;
        }

        // Worker / shard slices, registry instants, workload spans.
        for ev in &self.snap.events {
            match ev.kind {
                EventKind::TaskExec => {
                    sep(w)?;
                    let dur = ev.t_us.saturating_sub(ev.a).max(1);
                    let stolen = ev.b & TASK_STOLEN != 0;
                    let cross = ev.b & TASK_CROSS_JOB != 0;
                    write!(
                        w,
                        "{{\"name\":\"task\",\"cat\":\"exec\",\"ph\":\"X\",\
                         \"pid\":{PID},\"tid\":{},\"ts\":{},\"dur\":{dur},\
                         \"args\":{{\"job\":{},\"stolen\":{stolen},\
                         \"cross_job\":{cross}}}}}",
                        tid_worker(ev.actor),
                        ev.a,
                        ev.uid
                    )?;
                }
                EventKind::RegistryHit | EventKind::RegistryMiss | EventKind::RegistryEvict => {
                    sep(w)?;
                    let name = match ev.kind {
                        EventKind::RegistryHit => "hit",
                        EventKind::RegistryMiss => "miss",
                        _ => "evict",
                    };
                    let side = if ev.b & 1 == 0 { "A" } else { "B" };
                    let dtype = crate::gemm::Dtype::from_index((ev.b >> 1) as usize)
                        .map(|d| d.label())
                        .unwrap_or("?");
                    write!(
                        w,
                        "{{\"name\":\"{name}\",\"cat\":\"registry\",\"ph\":\"i\",\
                         \"s\":\"t\",\"pid\":{PID},\"tid\":{TID_REGISTRY},\
                         \"ts\":{},\"args\":{{\"handle\":{},\"bytes\":{},\
                         \"side\":\"{side}\",\"dtype\":\"{dtype}\"}}}}",
                        ev.t_us, ev.uid, ev.a
                    )?;
                }
                EventKind::SpanBegin | EventKind::SpanEnd => {
                    sep(w)?;
                    let ph = if ev.kind == EventKind::SpanBegin { "B" } else { "E" };
                    write!(
                        w,
                        "{{\"name\":\"{}-{}\",\"cat\":\"workload\",\"ph\":\"{ph}\",\
                         \"pid\":{PID},\"tid\":{TID_SPANS},\"ts\":{}}}",
                        SpanKind::name(ev.uid),
                        ev.a,
                        ev.t_us
                    )?;
                }
                _ => {}
            }
        }

        // Dispatcher slices + per-job async stage spans from the
        // folded traces (differences of the same timestamps the JSONL
        // carries, so the two exports always agree).
        for t in self.snap.job_traces() {
            // Plan + pack slices on the owning shard's track need the
            // shard id, which lives on the raw Pop event; recover it.
            let shard = self
                .snap
                .events
                .iter()
                .find(|e| e.kind == EventKind::Pop && e.uid == t.uid)
                .map(|e| e.actor)
                .filter(|&a| a != ACTOR_NONE);
            if let (Some(sh), Some(pop), Some(published)) =
                (shard, t.pop_us, t.published_us)
            {
                sep(w)?;
                write!(
                    w,
                    "{{\"name\":\"plan+pack\",\"cat\":\"dispatch\",\"ph\":\"X\",\
                     \"pid\":{PID},\"tid\":{},\"ts\":{pop},\"dur\":{},\
                     \"args\":{{\"job\":{}}}}}",
                    tid_shard(sh),
                    published.saturating_sub(pop).max(1),
                    t.uid
                )?;
            }
            let spans: [(usize, Option<u64>, Option<u64>); 5] = [
                (0, t.submit_us, t.pop_us),
                (1, t.pop_us, t.planned_us),
                (2, t.planned_us, t.published_us),
                (3, t.published_us, t.last_task_us),
                (4, t.last_task_us, t.done_us),
            ];
            for (stage, begin, end) in spans {
                if let (Some(b), Some(e)) = (begin, end) {
                    sep(w)?;
                    write!(
                        w,
                        "{{\"name\":\"{}\",\"cat\":\"job\",\"ph\":\"b\",\
                         \"id\":{},\"pid\":{PID},\"ts\":{b}}}",
                        STAGE_NAMES[stage], t.uid
                    )?;
                    sep(w)?;
                    write!(
                        w,
                        "{{\"name\":\"{}\",\"cat\":\"job\",\"ph\":\"e\",\
                         \"id\":{},\"pid\":{PID},\"ts\":{e}}}",
                        STAGE_NAMES[stage], t.uid
                    )?;
                }
            }
        }
        writeln!(w)?;
        writeln!(w, "]")?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(kind: EventKind, uid: u64, t_us: u64, a: u64, b: u64, actor: u32) -> TraceEvent {
        TraceEvent { t_us, uid, a, b, tenant: 7, actor, kind }
    }

    #[test]
    fn disabled_ring_records_nothing_and_allocates_nothing() {
        let ring = TraceRing::new(0);
        assert!(!ring.enabled());
        assert_eq!(ring.capacity(), 0);
        for i in 0..100 {
            ring.emit(EventKind::Submit, i, 0, ACTOR_NONE, 0, 0);
        }
        assert_eq!(ring.recorded(), 0);
        assert_eq!(ring.dropped(), 0);
        assert!(ring.snapshot().events.is_empty());
    }

    #[test]
    fn overwrite_drops_oldest_first() {
        let ring = TraceRing::new(4);
        for i in 0..10u64 {
            ring.emit(EventKind::Submit, i, 0, ACTOR_NONE, 0, 0);
        }
        let snap = ring.snapshot();
        assert_eq!(snap.recorded, 10);
        let uids: Vec<u64> = snap.events.iter().map(|e| e.uid).collect();
        assert_eq!(uids, vec![6, 7, 8, 9]);
    }

    #[test]
    fn snapshot_under_capacity_keeps_everything_in_order() {
        let ring = TraceRing::new(16);
        for i in 0..5u64 {
            ring.emit(EventKind::Admit, i, 3, ACTOR_NONE, i * 10, i * 100);
        }
        let snap = ring.snapshot();
        assert_eq!(snap.events.len(), 5);
        for (i, e) in snap.events.iter().enumerate() {
            assert_eq!(e.uid, i as u64);
            assert_eq!(e.a, i as u64 * 10);
            assert_eq!(e.b, i as u64 * 100);
            assert_eq!(e.tenant, 3);
        }
    }

    #[test]
    fn threaded_emit_never_tears_an_event() {
        // Writers stamp correlated payloads (a = uid * 3, b = uid * 7);
        // concurrent snapshots must never observe a mixed record.
        let ring = TraceRing::new(64);
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let ring = &ring;
                s.spawn(move || {
                    for i in 0..5_000u64 {
                        let uid = t * 1_000_000 + i;
                        ring.emit(EventKind::TaskExec, uid, t as u32, 0, uid * 3, uid * 7);
                    }
                });
            }
            let ring = &ring;
            s.spawn(move || {
                for _ in 0..200 {
                    for e in ring.snapshot().events {
                        assert_eq!(e.a, e.uid * 3, "torn event: a mismatch");
                        assert_eq!(e.b, e.uid * 7, "torn event: b mismatch");
                    }
                }
            });
        });
        // The generation counter saw every attempted emit.
        assert_eq!(ring.recorded(), 20_000);
        let snap = ring.snapshot();
        assert_eq!(snap.events.len(), 64);
    }

    #[test]
    fn job_trace_stages_sum_to_end_to_end() {
        let events = vec![
            ev(EventKind::Submit, 1, 100, 0, 0, ACTOR_NONE),
            ev(EventKind::Admit, 1, 110, 0, 0, ACTOR_NONE),
            ev(EventKind::Pop, 1, 400, 0, 0, 0),
            ev(EventKind::Planned, 1, 650, 0.004f64.to_bits(), 0, 0),
            ev(EventKind::Published, 1, 900, 0, 0, 0),
            ev(EventKind::TaskExec, 1, 1500, 950, TASK_STOLEN, 2),
            ev(EventKind::TaskExec, 1, 1800, 1000, 0, 0),
            ev(
                EventKind::Done,
                1,
                2100,
                0.004f64.to_bits(),
                0.005f64.to_bits(),
                ACTOR_NONE,
            ),
        ];
        let traces = job_traces(&events);
        assert_eq!(traces.len(), 1);
        let t = &traces[0];
        assert_eq!(t.terminal, Terminal::Done);
        assert_eq!(t.tenant, 7);
        let stages = t.stage_secs().unwrap();
        let sum: f64 = stages.iter().sum();
        let e2e = t.end_to_end_secs().unwrap();
        assert!((sum - e2e).abs() < 1e-12, "stages {sum} != e2e {e2e}");
        assert!((e2e - 2000e-6).abs() < 1e-12);
        assert_eq!(t.tasks, 2);
        assert_eq!(t.stolen_tasks, 1);
        assert_eq!(t.workers.len(), 2);
        assert_eq!(t.workers[0], WorkerTally { worker: 0, tasks: 1, stolen: 0 });
        assert_eq!(t.workers[1], WorkerTally { worker: 2, tasks: 1, stolen: 1 });
        assert_eq!(t.first_task_us, Some(950));
        assert_eq!(t.last_task_us, Some(1800));
        let drift = t.drift_frac().unwrap();
        assert!((drift - 0.25).abs() < 1e-12);
    }

    #[test]
    fn terminal_kinds_map_to_terminal_states() {
        for (kind, want) in [
            (EventKind::QuotaReject, Terminal::QuotaRejected),
            (EventKind::Shed, Terminal::Shed),
            (EventKind::PlanFail, Terminal::PlanFailed),
            (EventKind::Fail, Terminal::Failed),
        ] {
            let events = vec![
                ev(EventKind::Submit, 9, 10, 0, 0, ACTOR_NONE),
                ev(kind, 9, 20, 0, 0, ACTOR_NONE),
            ];
            let traces = job_traces(&events);
            assert_eq!(traces.len(), 1);
            assert_eq!(traces[0].terminal, want);
            assert_eq!(traces[0].done_us, Some(20));
        }
    }

    #[test]
    fn non_job_events_do_not_create_traces() {
        let events = vec![
            ev(EventKind::RegistryHit, 5, 10, 4096, 1, ACTOR_NONE),
            ev(EventKind::SpanBegin, 1, 20, 0, 0, ACTOR_NONE),
            ev(EventKind::DrrPop, 0, 30, 2, 1, ACTOR_NONE),
        ];
        assert!(job_traces(&events).is_empty());
    }

    #[test]
    fn stage_percentiles_nearest_rank() {
        let mut traces = Vec::new();
        for i in 1..=4u64 {
            let events = vec![
                ev(EventKind::Submit, i, 0, 0, 0, ACTOR_NONE),
                ev(EventKind::Pop, i, i * 100, 0, 0, 0),
                ev(EventKind::Planned, i, i * 100 + 10, 0, 0, 0),
                ev(EventKind::Published, i, i * 100 + 20, 0, 0, 0),
                ev(EventKind::TaskExec, i, i * 100 + 50, i * 100 + 20, 0, 0),
                ev(EventKind::Done, i, i * 100 + 60, 0, 0, ACTOR_NONE),
            ];
            traces.extend(job_traces(&events));
        }
        let p = stage_percentiles(&traces, &[0.50, 1.0]).unwrap();
        // queue stage: 100/200/300/400 µs → p50 = 200 µs, max = 400 µs.
        assert!((p[0][0] - 200e-6).abs() < 1e-12);
        assert!((p[0][1] - 400e-6).abs() < 1e-12);
        // plan stage is constant 10 µs.
        assert!((p[1][0] - 10e-6).abs() < 1e-12);
        assert!(stage_percentiles(&[], &[0.5]).is_none());
    }

    #[test]
    fn jsonl_export_carries_required_fields() {
        let events = vec![
            ev(EventKind::Submit, 1, 100, 0, 0, ACTOR_NONE),
            ev(EventKind::Pop, 1, 200, 0, 0, 0),
            ev(EventKind::Planned, 1, 300, 0.001f64.to_bits(), 0, 0),
            ev(EventKind::Published, 1, 400, 0, 0, 0),
            ev(EventKind::TaskExec, 1, 600, 450, 0, 1),
            ev(
                EventKind::Done,
                1,
                700,
                0.001f64.to_bits(),
                0.002f64.to_bits(),
                ACTOR_NONE,
            ),
        ];
        let snap = TraceSnapshot { events, recorded: 6, dropped: 0 };
        let mut buf = Vec::new();
        snap.exporter().write_jsonl(&mut buf).unwrap();
        let line = String::from_utf8(buf).unwrap();
        assert_eq!(line.lines().count(), 1);
        for field in [
            "\"uid\":1",
            "\"tenant\":7",
            "\"terminal\":\"done\"",
            "\"queue_secs\":",
            "\"plan_secs\":",
            "\"pack_secs\":",
            "\"execute_secs\":",
            "\"finalize_secs\":",
            "\"e2e_secs\":",
            "\"predicted_secs\":0.001",
            "\"measured_secs\":0.002",
            "\"drift_frac\":1",
            "\"workers\":[{\"worker\":1,\"tasks\":1,\"stolen\":0}]",
        ] {
            assert!(line.contains(field), "missing {field} in {line}");
        }
    }

    #[test]
    fn chrome_export_has_tracks_and_stage_spans() {
        let events = vec![
            ev(EventKind::Submit, 1, 100, 0, 0, ACTOR_NONE),
            ev(EventKind::Pop, 1, 200, 0, 0, 0),
            ev(EventKind::Planned, 1, 300, 0.001f64.to_bits(), 0, 0),
            ev(EventKind::Published, 1, 400, 0, 0, 0),
            ev(EventKind::TaskExec, 1, 600, 450, TASK_STOLEN, 2),
            ev(EventKind::RegistryMiss, 40, 350, 8192, 1, ACTOR_NONE),
            ev(EventKind::SpanBegin, 1, 90, 0, 0, ACTOR_NONE),
            ev(EventKind::SpanEnd, 1, 800, 0, 0, ACTOR_NONE),
            ev(
                EventKind::Done,
                1,
                700,
                0.001f64.to_bits(),
                0.002f64.to_bits(),
                ACTOR_NONE,
            ),
        ];
        let snap = TraceSnapshot { events, recorded: 9, dropped: 0 };
        let mut buf = Vec::new();
        snap.exporter().write_chrome(&mut buf).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.trim_start().starts_with('['));
        assert!(text.trim_end().ends_with(']'));
        for needle in [
            "\"name\":\"worker-2\"",
            "\"name\":\"dispatch-0\"",
            "\"name\":\"registry\"",
            "\"name\":\"workload\"",
            "\"ph\":\"X\"",
            "\"stolen\":true",
            "\"name\":\"queue\"",
            "\"name\":\"finalize\"",
            "\"ph\":\"b\"",
            "\"ph\":\"e\"",
            "\"name\":\"miss\"",
            "\"side\":\"B\",\"dtype\":\"f32\"",
            "\"name\":\"strassen-level-0\"",
        ] {
            assert!(text.contains(needle), "missing {needle}");
        }
    }
}
