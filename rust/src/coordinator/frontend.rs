//! Traffic-shaped admission front end for the [`JobServer`]: the
//! unified [`Submission`] builder, awaitable [`JobFuture`]s, per-tenant
//! quotas and weighted deficit-round-robin fairness, and the
//! deadline-slack ordering the sharded dispatchers drain by.
//!
//! The serving runtime below the job boundary (per-job WQMs, cross-job
//! stealing) already equalizes *work*; this module shapes *traffic*:
//!
//! * **One submission surface.** [`Submission::gemm`],
//!   [`Submission::group`] and [`Submission::batched`] (or
//!   `Submission::gemm(a, b).shared_b(more)`) replace the historical
//!   seven-way `submit`/`submit_batch`/`submit_group`/
//!   `submit_batched_gemm`/... sprawl. Every submission carries a
//!   [`TenantId`], an optional deadline, and an optional pinned
//!   [`RunConfig`]; it enters through `submit_async` (awaitable,
//!   blocks on backpressure), `submit_blocking` (await inline) or
//!   `try_submit` (sheds, hands the submission back).
//! * **Per-tenant quotas.** [`TenantConfig`] bounds a tenant's
//!   in-flight jobs and in-flight inline operand bytes; the internal
//!   `QuotaLedger` charges at admission and releases exactly once per
//!   job when its reply is delivered (or abandoned), via a
//!   `TenantSlot` drop guard riding the reply channel.
//! * **Weighted deficit round robin.** Each tenant owns a FIFO of
//!   admitted submissions; dispatch serves the tenant ring with a
//!   deficit counter recharged to the tenant's weight at the ring
//!   head, so a tenant with weight `w` gets `w` submissions per round
//!   while backlogged and an idle tenant's unused quantum never
//!   accumulates — one heavy tenant cannot starve the rest.
//! * **Deadline-slack ordering.** Within the tenant the round picked,
//!   the submission with the least *slack* — time to deadline minus
//!   the analytical model's predicted execution time — dispatches
//!   first (earliest-deadline-first, cost-adjusted); submissions
//!   without a deadline have infinite slack and fall back to FIFO
//!   among themselves. Misses are counted in
//!   `Metrics::deadline_misses` and surfaced by `stats()`.
//!
//! The queue (`FrontEnd<T>`) keeps the old admission contract intact:
//! capacity is bounded in *jobs*, blocked pushers are admitted strictly
//! in arrival order (no barging), an oversized submission is admitted
//! once the queue is empty, and `try_push` never barges past blocked
//! FIFO pushers.
//!
//! [`JobServer`]: super::JobServer

use std::collections::{BTreeMap, VecDeque};
use std::future::Future;
use std::pin::Pin;
use std::sync::{Arc, Condvar, Mutex};
use std::task::{Context, Poll};
use std::time::{Duration, Instant};

use crate::config::RunConfig;
use crate::gemm::Dtype;

use super::registry::{AOperand, BOperand};
use super::server::JobTicket;
use super::trace::{EventKind, TraceRing, ACTOR_NONE};
use super::{GemmJob, JobResult};

/// A client identity every submission carries. Tenants are cheap: the
/// server tracks only those that submit or are explicitly configured
/// (`JobServer::configure_tenant`); an unconfigured tenant runs with
/// weight 1 and unlimited quotas.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TenantId(pub u32);

impl TenantId {
    /// The tenant submissions run under when none is set.
    pub const DEFAULT: TenantId = TenantId(0);
}

impl std::fmt::Display for TenantId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "tenant#{}", self.0)
    }
}

/// Per-tenant admission policy: DRR weight plus in-flight quotas.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TenantConfig {
    /// Deficit-round-robin weight: submissions served per ring round
    /// while the tenant is backlogged. Must be >= 1.
    pub weight: u32,
    /// Maximum jobs the tenant may have in flight (admitted but not yet
    /// replied to). `None` = unlimited. A submission from a tenant with
    /// *nothing* in flight is admitted even when it alone exceeds the
    /// cap, so an oversized batch makes progress instead of deadlocking.
    pub max_inflight_jobs: Option<usize>,
    /// Maximum inline operand bytes in flight (registered operands are
    /// server-resident and billed to the registry budget, not here).
    /// Same idle-tenant oversize rule as `max_inflight_jobs`.
    pub max_inflight_bytes: Option<usize>,
}

impl Default for TenantConfig {
    fn default() -> Self {
        Self { weight: 1, max_inflight_jobs: None, max_inflight_bytes: None }
    }
}

/// What one [`Submission`] asks the server to run.
#[derive(Debug)]
pub enum SubmissionKind {
    /// One GEMM: `a x b`.
    Gemm { a: AOperand, b: BOperand },
    /// Jobs admitted as one unit; the dispatcher coalesces the
    /// sub-threshold members into batched super-jobs deterministically.
    Group(Vec<GemmJob>),
    /// `many_a[i] x b` with B packed at most once for the whole batch.
    SharedB { b: BOperand, many_a: Vec<AOperand> },
}

/// The unified submission builder: what to run, as which tenant, by
/// when. Construct with [`Submission::gemm`], [`Submission::group`] or
/// [`Submission::batched`], refine with the chained setters, then hand
/// to `JobServer::submit_async` / `submit_blocking` / `try_submit`.
///
/// ```ignore
/// let fut = srv.submit_async(
///     Submission::gemm(a, b)
///         .tenant(TenantId(3))
///         .deadline(Duration::from_millis(50)),
/// )?;
/// let results = fut.wait()?;
/// ```
#[derive(Debug)]
pub struct Submission {
    pub(crate) kind: SubmissionKind,
    pub(crate) tenant: TenantId,
    /// Relative deadline, resolved to an `Instant` at admission.
    pub(crate) deadline: Option<Duration>,
    /// Run-config pin applied to every job that has none of its own.
    pub(crate) run: Option<RunConfig>,
    /// Storage precision for every job's packed panels (default `F32`,
    /// which reproduces pre-multi-precision behavior bit for bit).
    pub(crate) dtype: Dtype,
    /// Base job id (`JobResult::id`); shared-B members get `id + index`.
    pub(crate) id: u64,
}

impl Submission {
    /// One GEMM `a x b`; either side inline or registered.
    pub fn gemm(a: impl Into<AOperand>, b: impl Into<BOperand>) -> Self {
        Self::with_kind(SubmissionKind::Gemm { a: a.into(), b: b.into() })
    }

    /// Jobs admitted as one unit (the old `submit_batch`/`submit_group`
    /// shape); each keeps its own id and optional run pin.
    pub fn group(jobs: Vec<GemmJob>) -> Self {
        Self::with_kind(SubmissionKind::Group(jobs))
    }

    /// A shared-B batch: `many_a[i] x b` with one packed B (the old
    /// `submit_batched_gemm` shape). Also reachable as
    /// `Submission::gemm(a, b).shared_b(more_as)`.
    pub fn batched<B, A>(b: B, many_a: impl IntoIterator<Item = A>) -> Self
    where
        B: Into<BOperand>,
        A: Into<AOperand>,
    {
        Self::with_kind(SubmissionKind::SharedB {
            b: b.into(),
            many_a: many_a.into_iter().map(Into::into).collect(),
        })
    }

    fn with_kind(kind: SubmissionKind) -> Self {
        Self {
            kind,
            tenant: TenantId::DEFAULT,
            deadline: None,
            run: None,
            dtype: Dtype::F32,
            id: 0,
        }
    }

    /// Submit as `tenant` (default [`TenantId::DEFAULT`]).
    pub fn tenant(mut self, tenant: TenantId) -> Self {
        self.tenant = tenant;
        self
    }

    /// Ask for completion within `deadline` of admission. The
    /// dispatcher orders eligible work by slack (deadline minus
    /// predicted execution time); a miss is counted, never cancelled —
    /// the job still runs to completion.
    pub fn deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Pin the run configuration for every job in the submission that
    /// does not pin its own. Accepts a bare `RunConfig` or an
    /// `Option<RunConfig>` (callers threading an optional pin through).
    pub fn run(mut self, run: impl Into<Option<RunConfig>>) -> Self {
        self.run = run.into();
        self
    }

    /// Base id reported back in [`JobResult::id`].
    pub fn id(mut self, id: u64) -> Self {
        self.id = id;
        self
    }

    /// Storage precision for every job in the submission: operands are
    /// converted into `dtype` at pack time and the microkernel runs the
    /// matching per-dtype variant (accumulating in f32 for the half
    /// types, natively in f64 for `F64`); results are always f32.
    /// Default [`Dtype::F32`] — the legacy path, bit for bit. Non-f32
    /// dtypes require an in-process numerics engine (the out-of-process
    /// gather fallback is f32-only) and are rejected at planning
    /// otherwise.
    pub fn dtype(mut self, dtype: Dtype) -> Self {
        self.dtype = dtype;
        self
    }

    /// Widen a single GEMM into a shared-B batch over the same B: the
    /// original A becomes the first member, `more_a` the rest. On a
    /// submission that is already a batch, appends to it; on a group,
    /// this is a no-op (a group has no shared operand).
    pub fn shared_b<A: Into<AOperand>>(mut self, more_a: impl IntoIterator<Item = A>) -> Self {
        self.kind = match self.kind {
            SubmissionKind::Gemm { a, b } => {
                let mut many_a = vec![a];
                many_a.extend(more_a.into_iter().map(Into::into));
                SubmissionKind::SharedB { b, many_a }
            }
            SubmissionKind::SharedB { b, mut many_a } => {
                many_a.extend(more_a.into_iter().map(Into::into));
                SubmissionKind::SharedB { b, many_a }
            }
            other => other,
        };
        self
    }

    /// Jobs this submission admits (what admission capacity and
    /// per-tenant job quotas are counted in).
    pub fn jobs(&self) -> usize {
        match &self.kind {
            SubmissionKind::Gemm { .. } => 1,
            SubmissionKind::Group(g) => g.len(),
            SubmissionKind::SharedB { many_a, .. } => many_a.len(),
        }
    }

    /// Caller-supplied operand bytes (what per-tenant byte quotas are
    /// counted in): inline matrices plus fused windows; registered
    /// operands are billed to the registry budget.
    pub fn inline_bytes(&self) -> usize {
        match &self.kind {
            SubmissionKind::Gemm { a, b } => a.quota_bytes() + b.quota_bytes(),
            SubmissionKind::Group(g) => {
                g.iter().map(|j| j.a.quota_bytes() + j.b.quota_bytes()).sum()
            }
            SubmissionKind::SharedB { b, many_a } => {
                b.quota_bytes() + many_a.iter().map(|a| a.quota_bytes()).sum::<usize>()
            }
        }
    }

    /// The payload back out — what a shed submission's owner uses to
    /// recover operands for retry or spill.
    pub fn into_kind(self) -> SubmissionKind {
        self.kind
    }
}

/// A lone job is a one-GEMM submission with its id and pin preserved.
impl From<GemmJob> for Submission {
    fn from(job: GemmJob) -> Self {
        let GemmJob { id, a, b, run } = job;
        let mut s = Submission::gemm(a, b).id(id);
        s.run = run;
        s
    }
}

/// Why `try_submit` rejected; the shed variants hand the whole
/// [`Submission`] back (operands intact) so the caller can retry,
/// spill, or route elsewhere — the never-silently-drop contract.
#[derive(Debug)]
pub enum SubmitError {
    /// Admission queue at capacity (backpressure).
    Full(Submission),
    /// The tenant's in-flight quota would be exceeded.
    QuotaExceeded { submission: Submission, tenant: TenantId },
    /// Server is shutting down.
    Closed(Submission),
    /// Malformed submission (e.g. an empty group); nothing to hand back
    /// beyond the message.
    Invalid(String),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Full(_) => write!(f, "admission queue full; submission handed back"),
            SubmitError::QuotaExceeded { tenant, .. } => {
                write!(f, "{tenant} in-flight quota exceeded; submission handed back")
            }
            SubmitError::Closed(_) => write!(f, "server closed; submission handed back"),
            SubmitError::Invalid(msg) => write!(f, "invalid submission: {msg}"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Awaitable handle to one submission: resolves to its [`JobResult`]s
/// in submission order. Poll it ([`JobFuture::poll`]), block on it
/// ([`JobFuture::wait`]), bound the block ([`JobFuture::wait_timeout`]),
/// or `.await` it — the [`Future`] impl self-wakes, so it works under
/// any executor (including a trivial block-on) without a reactor.
#[derive(Debug)]
pub struct JobFuture {
    slots: Vec<Slot>,
}

#[derive(Debug)]
enum Slot {
    Pending(JobTicket),
    Ready(Box<anyhow::Result<JobResult>>),
    Taken,
}

impl JobFuture {
    pub(crate) fn new(tickets: Vec<JobTicket>) -> Self {
        Self { slots: tickets.into_iter().map(Slot::Pending).collect() }
    }

    /// Jobs this future resolves to.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Non-blocking: `Some(results)` once every job has replied, `None`
    /// while any is still in flight. Results already received are
    /// buffered across calls, so polling is incremental. A future
    /// yields its results once; after that it is spent.
    pub fn poll(&mut self) -> Option<anyhow::Result<Vec<JobResult>>> {
        for slot in &mut self.slots {
            if let Slot::Pending(t) = slot {
                match t.try_wait() {
                    Some(r) => *slot = Slot::Ready(Box::new(r)),
                    None => return None,
                }
            }
        }
        Some(self.take_ready())
    }

    /// Block until every job replies; results in submission order. All
    /// replies are drained even when one fails (no in-flight work is
    /// abandoned); the first failure is returned, tagged with its job.
    pub fn wait(mut self) -> anyhow::Result<Vec<JobResult>> {
        for slot in &mut self.slots {
            if let Slot::Pending(t) = slot {
                let id = t.id;
                let r = std::mem::replace(slot, Slot::Taken);
                let Slot::Pending(t) = r else { unreachable!() };
                *slot =
                    Slot::Ready(Box::new(t.wait().map_err(|e| e.context(format!("job {id} failed")))));
            }
        }
        self.take_ready()
    }

    /// Like [`JobFuture::wait`] for a single-job submission.
    pub fn wait_one(self) -> anyhow::Result<JobResult> {
        anyhow::ensure!(self.slots.len() == 1, "wait_one on a {}-job future", self.slots.len());
        let mut results = self.wait()?;
        Ok(results.pop().expect("one result"))
    }

    /// Block for at most `timeout`: `Ok(Some(results))` when everything
    /// replied in time, `Ok(None)` on timeout (replies received so far
    /// stay buffered — call again, or `wait`, to finish), `Err` when a
    /// job failed.
    pub fn wait_timeout(&mut self, timeout: Duration) -> anyhow::Result<Option<Vec<JobResult>>> {
        let deadline = Instant::now() + timeout;
        for slot in &mut self.slots {
            if let Slot::Pending(t) = slot {
                let left = deadline.saturating_duration_since(Instant::now());
                match t.wait_timeout(left) {
                    Some(r) => *slot = Slot::Ready(Box::new(r)),
                    None => return Ok(None),
                }
            }
        }
        self.take_ready().map(Some)
    }

    /// Drain the buffered results (every slot must be `Ready`/`Taken`).
    fn take_ready(&mut self) -> anyhow::Result<Vec<JobResult>> {
        let mut results = Vec::with_capacity(self.slots.len());
        let mut first_err: Option<anyhow::Error> = None;
        for slot in &mut self.slots {
            match std::mem::replace(slot, Slot::Taken) {
                Slot::Ready(r) => match *r {
                    Ok(r) => results.push(r),
                    Err(e) => first_err.get_or_insert(e).ignore(),
                },
                Slot::Pending(_) => unreachable!("take_ready with a pending slot"),
                Slot::Taken => {}
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(results),
        }
    }

    /// The underlying per-job tickets (all must still be pending —
    /// i.e. the future was not polled); used by the deprecated
    /// single-ticket shims.
    pub fn into_tickets(self) -> Vec<JobTicket> {
        self.slots
            .into_iter()
            .filter_map(|s| match s {
                Slot::Pending(t) => Some(t),
                _ => None,
            })
            .collect()
    }
}

/// `get_or_insert(..)` returns `&mut E`; this makes the discard explicit
/// without a clippy-baiting `let _ =`.
trait Ignore {
    fn ignore(&self) {}
}
impl<T> Ignore for T {}

impl Future for JobFuture {
    type Output = anyhow::Result<Vec<JobResult>>;

    /// Self-waking poll: when still pending, the waker is rescheduled
    /// immediately, so simple executors spin-poll to completion without
    /// a reactor to register the mpsc replies with.
    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<Self::Output> {
        match self.get_mut().poll() {
            Some(r) => Poll::Ready(r),
            None => {
                cx.waker().wake_by_ref();
                Poll::Pending
            }
        }
    }
}

// ---------------------------------------------------------------------
// Quota ledger
// ---------------------------------------------------------------------

#[derive(Default)]
struct TenantLedger {
    cfg: TenantConfig,
    inflight_jobs: usize,
    inflight_bytes: usize,
}

/// Per-tenant in-flight accounting. Charged (all-or-nothing per
/// submission) before the queue push; released one job at a time by the
/// [`TenantSlot`] drop guard riding each job's reply — exactly once,
/// whether the job completed, failed at planning, or was abandoned at
/// shutdown.
pub(crate) struct QuotaLedger {
    st: Mutex<BTreeMap<TenantId, TenantLedger>>,
    space: Condvar,
    closed: Mutex<bool>,
}

impl QuotaLedger {
    pub(crate) fn new() -> Self {
        Self { st: Mutex::new(BTreeMap::new()), space: Condvar::new(), closed: Mutex::new(false) }
    }

    pub(crate) fn configure(&self, tenant: TenantId, cfg: TenantConfig) {
        self.st.lock().unwrap().entry(tenant).or_default().cfg = cfg;
        // A raised quota may unblock waiters.
        self.space.notify_all();
    }

    /// The tenant's DRR weight (1 when unconfigured).
    pub(crate) fn weight(&self, tenant: TenantId) -> u32 {
        self.st.lock().unwrap().get(&tenant).map_or(1, |t| t.cfg.weight.max(1))
    }

    /// Charge `jobs`/`bytes` against the tenant's quota, all or
    /// nothing. An idle tenant (nothing in flight) is always admitted —
    /// the oversize rule that keeps a lone batch larger than the quota
    /// from deadlocking.
    pub(crate) fn try_charge(&self, tenant: TenantId, jobs: usize, bytes: usize) -> bool {
        let mut st = self.st.lock().unwrap();
        let t = st.entry(tenant).or_default();
        let idle = t.inflight_jobs == 0 && t.inflight_bytes == 0;
        let jobs_ok =
            t.cfg.max_inflight_jobs.is_none_or(|cap| t.inflight_jobs + jobs <= cap);
        let bytes_ok =
            t.cfg.max_inflight_bytes.is_none_or(|cap| t.inflight_bytes + bytes <= cap);
        if idle || (jobs_ok && bytes_ok) {
            t.inflight_jobs += jobs;
            t.inflight_bytes += bytes;
            true
        } else {
            false
        }
    }

    /// Blocking [`QuotaLedger::try_charge`]: waits for in-flight work
    /// to release quota; errors once the server closes.
    pub(crate) fn charge_blocking(
        &self,
        tenant: TenantId,
        jobs: usize,
        bytes: usize,
    ) -> anyhow::Result<()> {
        loop {
            if self.try_charge(tenant, jobs, bytes) {
                return Ok(());
            }
            let closed = self.closed.lock().unwrap();
            if *closed {
                anyhow::bail!("server closed while waiting for {tenant} quota");
            }
            // Re-check under the closed lock: a release between the
            // failed try and this wait would notify `space` first, so
            // wait on `closed`'s mutex with a timeout-free condvar is
            // unsafe — instead wait on `space` via the ledger mutex.
            drop(closed);
            let st = self.st.lock().unwrap();
            let closed_now = *self.closed.lock().unwrap();
            if closed_now {
                anyhow::bail!("server closed while waiting for {tenant} quota");
            }
            let _unused = self.space.wait_timeout(st, Duration::from_millis(50)).unwrap();
        }
    }

    fn release(&self, tenant: TenantId, jobs: usize, bytes: usize) {
        let mut st = self.st.lock().unwrap();
        if let Some(t) = st.get_mut(&tenant) {
            t.inflight_jobs = t.inflight_jobs.saturating_sub(jobs);
            t.inflight_bytes = t.inflight_bytes.saturating_sub(bytes);
        }
        drop(st);
        self.space.notify_all();
    }

    pub(crate) fn close(&self) {
        *self.closed.lock().unwrap() = true;
        self.space.notify_all();
    }

    /// `(inflight_jobs, inflight_bytes)` for one tenant.
    #[cfg(test)]
    fn inflight(&self, tenant: TenantId) -> (usize, usize) {
        self.st
            .lock()
            .unwrap()
            .get(&tenant)
            .map_or((0, 0), |t| (t.inflight_jobs, t.inflight_bytes))
    }
}

/// Drop guard releasing one job's share of its tenant's quota. Lives in
/// the job's reply wrapper, so delivery, planner rejection, and
/// shutdown abandonment all release exactly once.
pub(crate) struct TenantSlot {
    ledger: Arc<QuotaLedger>,
    tenant: TenantId,
    bytes: usize,
}

impl TenantSlot {
    pub(crate) fn new(ledger: Arc<QuotaLedger>, tenant: TenantId, bytes: usize) -> Self {
        Self { ledger, tenant, bytes }
    }
}

impl std::fmt::Debug for TenantSlot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "TenantSlot({}, {}B)", self.tenant, self.bytes)
    }
}

impl Drop for TenantSlot {
    fn drop(&mut self) {
        self.ledger.release(self.tenant, 1, self.bytes);
    }
}

// ---------------------------------------------------------------------
// The DRR + slack admission queue
// ---------------------------------------------------------------------

/// Admission metadata the queue orders by.
pub(crate) struct AdmitMeta {
    pub(crate) tenant: TenantId,
    /// DRR weight snapshot (read from the ledger at push; a weight
    /// change applies from the tenant's next submission).
    pub(crate) weight: u32,
    /// Jobs (what capacity is counted in). Always >= 1.
    pub(crate) cost: usize,
    /// Absolute completion deadline, if any.
    pub(crate) deadline: Option<Instant>,
    /// Modeled execution time ([`crate::analytical::predict`]) used for
    /// slack; 0 when no estimate was available.
    pub(crate) predicted_secs: f64,
}

struct QueuedItem<T> {
    item: T,
    cost: usize,
    seq: u64,
    deadline: Option<Instant>,
    predicted_secs: f64,
}

impl<T> QueuedItem<T> {
    /// Slack = time-to-deadline minus predicted execution time; +inf
    /// without a deadline (deadline traffic always outranks it).
    fn slack(&self, now: Instant) -> f64 {
        match self.deadline {
            Some(d) => {
                let to_deadline = if d >= now {
                    d.duration_since(now).as_secs_f64()
                } else {
                    -now.duration_since(d).as_secs_f64()
                };
                to_deadline - self.predicted_secs
            }
            None => f64::INFINITY,
        }
    }
}

struct TenantQueue<T> {
    weight: u32,
    deficit: u32,
    items: VecDeque<QueuedItem<T>>,
}

struct FrontState<T> {
    tenants: BTreeMap<TenantId, TenantQueue<T>>,
    /// Backlogged tenants in round order. Invariant: a tenant is in the
    /// ring iff its queue is non-empty.
    ring: VecDeque<TenantId>,
    /// Jobs (not submissions) currently queued — what capacity bounds.
    queued_jobs: usize,
    closed: bool,
    seq: u64,
    /// FIFO tickets for blocking pushers: each `push_blocking` takes
    /// `next_ticket` and may only admit when it becomes `serving`, so a
    /// large submission waiting for space cannot be starved by a stream
    /// of later submitters barging into the freed capacity.
    next_ticket: u64,
    serving: u64,
}

pub(crate) enum TryPushError<T> {
    Full(T),
    Closed(T),
}

/// Bounded multi-tenant admission queue: weighted deficit round robin
/// across tenants, deadline-slack (then FIFO) within a tenant, blocking
/// and load-shedding entry points, shared by N dispatcher shards.
pub(crate) struct FrontEnd<T> {
    capacity: usize,
    /// Flight recorder; every DRR pop stamps the tenant served, its
    /// remaining backlog, and the quantum left (disabled rings record
    /// nothing).
    trace: Arc<TraceRing>,
    st: Mutex<FrontState<T>>,
    not_full: Condvar,
    not_empty: Condvar,
}

impl<T> FrontEnd<T> {
    pub(crate) fn new(capacity: usize) -> Self {
        Self::with_trace(capacity, Arc::new(TraceRing::new(0)))
    }

    pub(crate) fn with_trace(capacity: usize, trace: Arc<TraceRing>) -> Self {
        Self {
            capacity,
            trace,
            st: Mutex::new(FrontState {
                tenants: BTreeMap::new(),
                ring: VecDeque::new(),
                queued_jobs: 0,
                closed: false,
                seq: 0,
                next_ticket: 0,
                serving: 0,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
        }
    }

    fn enqueue_locked(st: &mut FrontState<T>, meta: &AdmitMeta, item: T) {
        let seq = st.seq;
        st.seq += 1;
        let tq = st.tenants.entry(meta.tenant).or_insert_with(|| TenantQueue {
            weight: meta.weight.max(1),
            deficit: 0,
            items: VecDeque::new(),
        });
        tq.weight = meta.weight.max(1);
        let was_empty = tq.items.is_empty();
        tq.items.push_back(QueuedItem {
            item,
            cost: meta.cost,
            seq,
            deadline: meta.deadline,
            predicted_secs: meta.predicted_secs,
        });
        if was_empty {
            st.ring.push_back(meta.tenant);
        }
        st.queued_jobs += meta.cost;
    }

    /// Block until the submission fits (backpressure), admitting
    /// blocked pushers strictly in arrival (ticket) order. A submission
    /// larger than the whole capacity is admitted once the queue is
    /// empty, so oversized batches make progress instead of
    /// deadlocking.
    pub(crate) fn push_blocking(&self, meta: AdmitMeta, item: T) -> Result<(), T> {
        let n = meta.cost;
        let mut st = self.st.lock().unwrap();
        let ticket = st.next_ticket;
        st.next_ticket += 1;
        loop {
            if st.closed {
                // Every waiter sees `closed` and exits; `serving` need
                // not advance past abandoned tickets.
                return Err(item);
            }
            if st.serving == ticket && (st.queued_jobs + n <= self.capacity || st.queued_jobs == 0)
            {
                st.serving += 1;
                Self::enqueue_locked(&mut st, &meta, item);
                self.not_empty.notify_one();
                // Hand the turn to the next ticket holder, if any.
                self.not_full.notify_all();
                return Ok(());
            }
            st = self.not_full.wait(st).unwrap();
        }
    }

    pub(crate) fn try_push(&self, meta: AdmitMeta, item: T) -> Result<(), TryPushError<T>> {
        let n = meta.cost;
        let mut st = self.st.lock().unwrap();
        if st.closed {
            return Err(TryPushError::Closed(item));
        }
        // Never barge past blocked FIFO pushers (serving < next_ticket
        // means someone is waiting for space).
        if st.serving != st.next_ticket
            || (st.queued_jobs + n > self.capacity && st.queued_jobs > 0)
        {
            return Err(TryPushError::Full(item));
        }
        st.next_ticket += 1;
        st.serving += 1;
        Self::enqueue_locked(&mut st, &meta, item);
        self.not_empty.notify_one();
        Ok(())
    }

    /// One DRR step: pick the ring-head tenant (recharging its deficit
    /// to its weight when spent), then that tenant's least-slack
    /// submission. Maintains the ring invariant and rotates the head
    /// out when its deficit is exhausted. Each serve stamps a
    /// [`EventKind::DrrPop`] trace event, making the round-robin
    /// schedule itself observable.
    fn pop_locked(&self, st: &mut FrontState<T>) -> Option<T> {
        let now = Instant::now();
        loop {
            let tenant = *st.ring.front()?;
            let tq = st.tenants.get_mut(&tenant).expect("ring tenant has a queue");
            if tq.items.is_empty() {
                // Belt and braces; the invariant should prevent this.
                st.ring.pop_front();
                tq.deficit = 0;
                continue;
            }
            if tq.deficit == 0 {
                tq.deficit = tq.weight.max(1);
            }
            let mut best = 0usize;
            let mut best_key = (tq.items[0].slack(now), tq.items[0].seq);
            for (i, q) in tq.items.iter().enumerate().skip(1) {
                let key = (q.slack(now), q.seq);
                if key.0 < best_key.0 || (key.0 == best_key.0 && key.1 < best_key.1) {
                    best = i;
                    best_key = key;
                }
            }
            let q = tq.items.remove(best).expect("best index in range");
            tq.deficit -= 1;
            st.queued_jobs -= q.cost;
            let (backlog, deficit) = (tq.items.len() as u64, tq.deficit as u64);
            if tq.items.is_empty() {
                // Leaving the ring resets the deficit: an idle tenant
                // does not bank unused quantum.
                tq.deficit = 0;
                st.ring.pop_front();
            } else if tq.deficit == 0 {
                let t = st.ring.pop_front().expect("ring head");
                st.ring.push_back(t);
            }
            self.trace.emit(EventKind::DrrPop, 0, tenant.0, ACTOR_NONE, backlog, deficit);
            return Some(q.item);
        }
    }

    /// Dispatcher side: next submission, or `None` once closed *and*
    /// drained. Safe to call from several shards concurrently.
    pub(crate) fn pop_blocking(&self) -> Option<T> {
        let mut st = self.st.lock().unwrap();
        loop {
            if let Some(item) = self.pop_locked(&mut st) {
                self.not_full.notify_all();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.not_empty.wait(st).unwrap();
        }
    }

    pub(crate) fn try_pop(&self) -> Option<T> {
        let mut st = self.st.lock().unwrap();
        let item = self.pop_locked(&mut st)?;
        self.not_full.notify_all();
        Some(item)
    }

    pub(crate) fn close(&self) {
        let mut st = self.st.lock().unwrap();
        st.closed = true;
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }

    /// Jobs currently queued.
    pub(crate) fn len(&self) -> usize {
        self.st.lock().unwrap().queued_jobs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::Matrix;

    fn meta(tenant: u32, weight: u32) -> AdmitMeta {
        AdmitMeta {
            tenant: TenantId(tenant),
            weight,
            cost: 1,
            deadline: None,
            predicted_secs: 0.0,
        }
    }

    #[test]
    fn drr_serves_weights_exactly_while_backlogged() {
        let q: FrontEnd<&'static str> = FrontEnd::new(64);
        for _ in 0..8 {
            q.try_push(meta(1, 3), "a").map_err(|_| ()).unwrap();
        }
        for _ in 0..8 {
            q.try_push(meta(2, 1), "b").map_err(|_| ()).unwrap();
        }
        let order: String = std::iter::from_fn(|| q.try_pop()).collect();
        // Weight 3:1 — three a's per b while both are backlogged; the
        // a-queue empties mid-quantum and b drains the tail alone.
        assert_eq!(order, "aaabaaabaabbbbbb");
    }

    #[test]
    fn drr_pops_are_traced_with_backlog_and_deficit() {
        let ring = Arc::new(TraceRing::new(32));
        let q: FrontEnd<&'static str> = FrontEnd::with_trace(64, ring.clone());
        q.try_push(meta(5, 2), "a").map_err(|_| ()).unwrap();
        q.try_push(meta(5, 2), "b").map_err(|_| ()).unwrap();
        assert_eq!(q.try_pop(), Some("a"));
        assert_eq!(q.try_pop(), Some("b"));
        let evs = ring.snapshot().events;
        assert_eq!(evs.len(), 2);
        for e in &evs {
            assert_eq!(e.kind, EventKind::DrrPop);
            assert_eq!(e.tenant, 5);
        }
        // First serve: one job left, one quantum left. Second: drained.
        assert_eq!((evs[0].a, evs[0].b), (1, 1));
        assert_eq!((evs[1].a, evs[1].b), (0, 0));
    }

    #[test]
    fn drr_idle_tenant_banks_no_quantum() {
        let q: FrontEnd<&'static str> = FrontEnd::new(64);
        // Tenant 1 (weight 3) drains completely, THEN tenant 2 arrives:
        // tenant 1's unused quantum must not defer tenant 2.
        q.try_push(meta(1, 3), "a").map_err(|_| ()).unwrap();
        assert_eq!(q.try_pop(), Some("a"));
        q.try_push(meta(2, 1), "b").map_err(|_| ()).unwrap();
        q.try_push(meta(1, 3), "a").map_err(|_| ()).unwrap();
        // Tenant 2 re-entered the ring first; it serves before tenant 1
        // despite the lower weight.
        assert_eq!(q.try_pop(), Some("b"));
        assert_eq!(q.try_pop(), Some("a"));
    }

    #[test]
    fn within_tenant_least_slack_first_then_fifo() {
        let q: FrontEnd<u32> = FrontEnd::new(64);
        let now = Instant::now();
        let push = |deadline: Option<Duration>, predicted: f64, tag: u32| {
            q.try_push(
                AdmitMeta {
                    tenant: TenantId(1),
                    weight: 1,
                    cost: 1,
                    deadline: deadline.map(|d| now + d),
                    predicted_secs: predicted,
                },
                tag,
            )
            .map_err(|_| ())
            .unwrap();
        };
        push(None, 0.0, 10); // no deadline: infinite slack, FIFO tail
        push(Some(Duration::from_secs(100)), 0.0, 11); // slack ~100
        push(Some(Duration::from_secs(100)), 95.0, 12); // slack ~5: first
        push(None, 0.0, 13); // infinite slack, after tag 10 (FIFO)
        assert_eq!(
            std::iter::from_fn(|| q.try_pop()).collect::<Vec<_>>(),
            vec![12, 11, 10, 13]
        );
    }

    #[test]
    fn capacity_counts_jobs_and_oversize_admits_when_empty() {
        let q: FrontEnd<u32> = FrontEnd::new(2);
        let big = AdmitMeta { cost: 5, ..meta(1, 1) };
        // Oversized but empty: admitted.
        q.try_push(big, 1).map_err(|_| ()).unwrap();
        assert_eq!(q.len(), 5);
        // Non-empty and over capacity: shed.
        assert!(matches!(q.try_push(meta(1, 1), 2), Err(TryPushError::Full(2))));
        assert_eq!(q.try_pop(), Some(1));
        q.try_push(meta(1, 1), 3).map_err(|_| ()).unwrap();
        q.try_push(meta(1, 1), 4).map_err(|_| ()).unwrap();
        assert!(matches!(q.try_push(meta(1, 1), 5), Err(TryPushError::Full(5))));
    }

    #[test]
    fn close_rejects_then_drains() {
        let q: FrontEnd<u32> = FrontEnd::new(4);
        q.try_push(meta(1, 1), 1).map_err(|_| ()).unwrap();
        q.close();
        assert!(matches!(q.try_push(meta(1, 1), 2), Err(TryPushError::Closed(2))));
        assert_eq!(q.pop_blocking(), Some(1));
        assert_eq!(q.pop_blocking(), None);
    }

    #[test]
    fn blocked_pusher_not_barged_past() {
        let q: Arc<FrontEnd<u32>> = Arc::new(FrontEnd::new(1));
        q.try_push(meta(1, 1), 1).map_err(|_| ()).unwrap();
        let q2 = q.clone();
        let blocked = std::thread::spawn(move || q2.push_blocking(meta(1, 1), 2));
        // Give the pusher time to take its ticket and block.
        std::thread::sleep(Duration::from_millis(30));
        // A try_push may not steal the capacity the blocked pusher is
        // waiting for.
        assert!(matches!(q.try_push(meta(1, 1), 3), Err(TryPushError::Full(3))));
        assert_eq!(q.try_pop(), Some(1));
        blocked.join().unwrap().map_err(|_| ()).unwrap();
        assert_eq!(q.try_pop(), Some(2));
    }

    #[test]
    fn quota_ledger_charges_and_releases() {
        let ledger = Arc::new(QuotaLedger::new());
        let t = TenantId(7);
        ledger.configure(
            t,
            TenantConfig { weight: 1, max_inflight_jobs: Some(2), max_inflight_bytes: None },
        );
        assert!(ledger.try_charge(t, 1, 10));
        assert!(ledger.try_charge(t, 1, 10));
        assert!(!ledger.try_charge(t, 1, 10), "third job over the cap");
        assert_eq!(ledger.inflight(t), (2, 20));
        drop(TenantSlot::new(ledger.clone(), t, 10));
        assert_eq!(ledger.inflight(t), (1, 10));
        assert!(ledger.try_charge(t, 1, 10));
    }

    #[test]
    fn quota_idle_tenant_oversize_admitted() {
        let ledger = QuotaLedger::new();
        let t = TenantId(8);
        ledger.configure(
            t,
            TenantConfig {
                weight: 1,
                max_inflight_jobs: Some(2),
                max_inflight_bytes: Some(100),
            },
        );
        // Nothing in flight: a 5-job, 1000-byte batch is admitted.
        assert!(ledger.try_charge(t, 5, 1000));
        // But nothing more until it drains.
        assert!(!ledger.try_charge(t, 1, 0));
    }

    #[test]
    fn quota_byte_cap_enforced() {
        let ledger = QuotaLedger::new();
        let t = TenantId(9);
        ledger.configure(
            t,
            TenantConfig { weight: 1, max_inflight_jobs: None, max_inflight_bytes: Some(64) },
        );
        assert!(ledger.try_charge(t, 1, 40));
        assert!(!ledger.try_charge(t, 1, 40), "over the byte cap");
        assert!(ledger.try_charge(t, 1, 24));
    }

    #[test]
    fn submission_builder_counts_and_conversions() {
        let a = Matrix::random(4, 3, 1);
        let b = Matrix::random(3, 5, 2);
        let s = Submission::gemm(a.clone(), b.clone());
        assert_eq!(s.jobs(), 1);
        assert_eq!(s.inline_bytes(), 4 * (4 * 3 + 3 * 5));
        // gemm(..).shared_b(more) widens into a batch with the original
        // A as member 0.
        let s = Submission::gemm(a.clone(), b.clone())
            .shared_b(vec![Matrix::random(2, 3, 3)])
            .tenant(TenantId(4))
            .deadline(Duration::from_millis(5))
            .id(40);
        assert_eq!(s.jobs(), 2);
        assert_eq!(s.tenant, TenantId(4));
        assert!(s.deadline.is_some());
        match s.into_kind() {
            SubmissionKind::SharedB { many_a, .. } => {
                assert_eq!(many_a[0].as_inline().map(|m| m.rows), Some(4));
                assert_eq!(many_a[1].as_inline().map(|m| m.rows), Some(2));
            }
            _ => panic!("expected a shared-B batch"),
        }
        let job = GemmJob { id: 9, a: a.into(), b: b.into(), run: None };
        let s: Submission = job.into();
        assert_eq!((s.jobs(), s.id), (1, 9));
        // Dtype defaults to F32 everywhere (including the GemmJob
        // conversion) and threads through the chained setter; inline
        // byte billing stays element-count based regardless of dtype.
        assert_eq!(s.dtype, Dtype::F32);
        let a = Matrix::random(4, 3, 5);
        let b = Matrix::random(3, 5, 6);
        let bytes = Submission::gemm(a.clone(), b.clone()).inline_bytes();
        let s = Submission::gemm(a, b).dtype(Dtype::Bf16);
        assert_eq!(s.dtype, Dtype::Bf16);
        assert_eq!(s.inline_bytes(), bytes);
    }

    #[test]
    fn job_future_poll_wait_and_timeout() {
        use std::sync::mpsc;
        let mk = |id: u64| {
            let (tx, rx) = mpsc::channel();
            (tx, JobTicket::new(id, rx))
        };
        let (tx0, t0) = mk(0);
        let (tx1, t1) = mk(1);
        let mut fut = JobFuture::new(vec![t0, t1]);
        assert_eq!(fut.len(), 2);
        assert!(fut.poll().is_none(), "nothing replied yet");
        let result = |id: u64| JobResult {
            id,
            c: Matrix::zeros(1, 1),
            run: RunConfig::square(1, 16),
            sim: crate::accelerator::SimReport {
                run: RunConfig::square(1, 16),
                m: 1,
                k: 1,
                n: 1,
                total_secs: 0.0,
                gflops: 0.0,
                arrays: Vec::new(),
                total_tasks: 0,
                total_steals: 0,
                memory_bound_frac: 0.0,
                trace: Vec::new(),
            },
            host_latency_secs: 0.0,
            batched: false,
        };
        tx0.send(Ok(result(0))).unwrap();
        assert!(fut.poll().is_none(), "one of two replied");
        assert_eq!(
            fut.wait_timeout(Duration::from_millis(10)).unwrap(),
            None,
            "job 1 still pending"
        );
        tx1.send(Ok(result(1))).unwrap();
        let results = fut.wait_timeout(Duration::from_secs(5)).unwrap().unwrap();
        assert_eq!(results.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);

        // wait() surfaces a dropped server as an error, tagged by job.
        let (_tx2, t2) = mk(2);
        drop(_tx2);
        let err = JobFuture::new(vec![t2]).wait().unwrap_err();
        assert!(format!("{err:#}").contains("job 2"), "got: {err:#}");
    }
}
