//! L3 coordinator: the serving face of the accelerator.
//!
//! GEMM jobs come in; the coordinator picks the optimal `⟨N_p, S_i⟩` via
//! the DSE (unless pinned), partitions the problem into sub-block tasks,
//! and drives `N_p` worker threads — the software twin of the paper's
//! hardware WQM + MAC pipeline. The numerics hot path is lock-free and
//! zero-copy end to end:
//!
//! * both operand panel sets are packed **once per job** into
//!   refcounted halves ([`crate::gemm::PackedA`] /
//!   [`crate::gemm::PackedB`], composed as
//!   [`crate::gemm::PackedPanels`]; A panels transposed, the MAC's
//!   layout fix) instead of once per task — and at most once per
//!   *batch*: a shared-B workload
//!   ([`frontend::Submission::batched`]) packs B once and
//!   shares the `Arc<PackedB>` across every sub-job — and at most once
//!   per *process* for operands registered in the server's
//!   [`registry::OperandRegistry`] ([`server::JobServer::register_b`]
//!   for weights, [`server::JobServer::register_a`] for activations):
//!   submissions whose [`BOperand`] / [`AOperand`] carries a
//!   [`WeightHandle`] / [`ActivationHandle`] resolve to the cached
//!   pack, so successive batches, epochs, and layers reusing either
//!   operand never repack it (one refcount-pinned LRU across both
//!   sides, under a shared byte budget, keeps residency bounded), and
//!   the server's planner steers unpinned jobs toward `(S_i, S_j)`
//!   variants already resident — within a predicted-cost slack — so
//!   mixed-shape traffic turns repacks into cache hits;
//! * workers pop/steal from a shared [`crate::wqm::AtomicWqm`] — one CAS
//!   per claim on a packed `head|tail` word, no `Mutex<Wqm>`;
//! * each worker runs the register-blocked microkernel over the packed
//!   panels and streams its finished `C_ij` straight into the result
//!   matrix through a shared [`crate::gemm::DisjointBlocks`] writer — no
//!   `Mutex<Matrix>`. Writes are race-free because a
//!   [`BlockPlan`]'s tasks tile C exactly and the WQM hands each task to
//!   exactly one worker (disjoint ownership by construction).
//!
//! Numerics execute on the [`engine::NumericsEngine`]: the in-process
//! golden/packed backend, or a dedicated thread owning the PJRT runtime
//! (XLA handles are not `Send`) fed over channels. Timing comes from the
//! cycle-level simulator, so every job returns both a real result matrix
//! and the FPGA-time report.
//!
//! Two serving shapes share that job-scoped pipeline:
//!
//! * [`Coordinator`] — one job at a time; spawns `N_p` workers per job
//!   and joins them before returning (the shape of the paper's single
//!   measured run). Simple, deterministic, good for tests and the CLI.
//! * [`server::JobServer`] — the production shape: a persistent worker
//!   pool fed by a traffic-shaped admission front end
//!   ([`frontend`]: one typed [`Submission`] builder,
//!   awaitable [`JobFuture`]s, per-tenant quotas + weighted
//!   deficit-round-robin fairness, deadline-slack ordering, N
//!   dispatcher shards), per-job `AtomicWqm`s in an epoch-tagged job
//!   table ([`crate::wqm::JobRegistry`]), **cross-job** work stealing
//!   so small requests can't idle the pool behind a large one, and
//!   batching of sub-threshold jobs into shared super-jobs. Use this
//!   when jobs arrive as traffic rather than as one call.
//!
//! Both report into the same [`Metrics`] shape; the server additionally
//! exposes throughput and latency percentiles via
//! [`server::JobServer::stats`], and — when
//! [`server::ServerConfig::trace_capacity`] is set — a lock-free
//! flight recorder ([`trace`]) that stamps every job's lifecycle for
//! per-stage latency breakdowns, per-worker steal provenance, and
//! predicted-vs-measured model-drift records, exportable as JSONL or
//! Chrome `trace_event` JSON via
//! [`server::JobServer::trace_snapshot`].

pub mod engine;
pub mod frontend;
pub mod metrics;
pub mod registry;
pub mod server;
pub mod trace;

pub use engine::NumericsEngine;
pub use frontend::{
    JobFuture, SubmitError, Submission, SubmissionKind, TenantConfig, TenantId,
};
pub use metrics::{DriftStats, LatencySnapshot, Metrics, TenantCounters};
pub use registry::{
    ActivationHandle, AOperand, BOperand, FusedOperand, FusedSource, Operand, OperandRegistry,
    TenantResidency, WeightHandle,
};
pub use server::{
    JobGroup, JobServer, JobTicket, ServerConfig, ServerStats, TrySubmitBatchedError,
    TrySubmitError,
};
pub use trace::{
    JobTrace, SpanKind, Terminal, TraceEvent, TraceExporter, TraceRing, TraceSnapshot,
    WorkerTally,
};

use std::sync::{mpsc, Arc};
use std::time::Instant;

use crate::accelerator::{Accelerator, SimOptions, SimReport};
use crate::blocking::BlockPlan;
use crate::config::{HardwareConfig, RunConfig};
use crate::dse;
use crate::gemm::{DisjointBlocks, Matrix, PackedPanels};
use crate::wqm::AtomicWqm;

/// One GEMM request. Each side is an operand enum — [`AOperand`] for
/// A, [`BOperand`] for B: an inline matrix (packed per job, the classic
/// shape) or a handle registered with a [`JobServer`]'s operand
/// registry ([`ActivationHandle`] / [`WeightHandle`]), resolved at
/// dispatch to the server-resident cached pack so repeated submissions
/// never repack. `Matrix` converts into either operand via `.into()`.
#[derive(Debug, Clone)]
pub struct GemmJob {
    pub id: u64,
    pub a: AOperand,
    pub b: BOperand,
    /// Pin a config, or let the DSE choose.
    pub run: Option<RunConfig>,
}

/// What the coordinator returns per job.
#[derive(Debug)]
pub struct JobResult {
    pub id: u64,
    pub c: Matrix,
    /// The configuration actually used.
    pub run: RunConfig,
    /// Simulated FPGA-side execution report.
    pub sim: SimReport,
    /// Wall-clock host latency of the numerics execution (for served
    /// jobs: admission to completion, queueing included).
    pub host_latency_secs: f64,
    /// Whether the job was coalesced into a batched super-job by the
    /// serving runtime. Always `false` from [`Coordinator::run_job`].
    pub batched: bool,
}

/// The single copy of the pin → default → DSE planning cascade: a job's
/// pinned config wins, then the caller's default (the server's serving
/// fast path), then the DSE optimum. Dims-based so callers whose B is a
/// registered handle (resolved in the server's registry) plan the same
/// way as inline jobs.
pub(crate) fn choose_run_dims(
    hw: &HardwareConfig,
    surface: &crate::analytical::BandwidthSurface,
    m: usize,
    k: usize,
    n: usize,
    pinned: Option<RunConfig>,
    default_run: Option<RunConfig>,
) -> anyhow::Result<RunConfig> {
    if let Some(run) = pinned {
        run.validate(hw)?;
        return Ok(run);
    }
    if let Some(run) = default_run {
        run.validate(hw)?;
        return Ok(run);
    }
    let e = dse::explore(hw, m, k, n, surface)?;
    Ok(e.best.run)
}

/// The coordinator.
pub struct Coordinator {
    pub hw: HardwareConfig,
    accelerator: Accelerator,
    engine: NumericsEngine,
    metrics: Arc<Metrics>,
}

impl Coordinator {
    pub fn new(hw: HardwareConfig, engine: NumericsEngine) -> Self {
        Self {
            accelerator: Accelerator::new(hw.clone()),
            hw,
            engine,
            metrics: Arc::new(Metrics::default()),
        }
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    pub fn accelerator(&self) -> &Accelerator {
        &self.accelerator
    }

    /// Choose the run config for a job: pinned, or DSE-optimal. The
    /// one-shot coordinator has no operand registry, so both of the
    /// job's operands must be inline ([`JobServer`] submissions resolve
    /// handles).
    pub fn plan_job(&self, job: &GemmJob) -> anyhow::Result<RunConfig> {
        let (a_rows, a_cols) = job.a.inline_dims().ok_or_else(|| {
            anyhow::anyhow!(
                "registered and fused operands resolve inside a JobServer; \
                 Coordinator jobs need an inline A"
            )
        })?;
        let (_, b_cols) = job.b.inline_dims().ok_or_else(|| {
            anyhow::anyhow!(
                "registered and fused operands resolve inside a JobServer; \
                 Coordinator jobs need an inline B"
            )
        })?;
        choose_run_dims(
            &self.hw,
            self.accelerator.surface(),
            a_rows,
            a_cols,
            b_cols,
            job.run,
            None,
        )
    }

    /// Execute one job: numerics through `N_p` work-stealing workers on
    /// the engine, timing through the simulator.
    ///
    /// Hot-path structure: pack panels once, spawn `N_p` workers that
    /// claim tasks lock-free from the [`AtomicWqm`] and write disjoint C
    /// blocks through a shared [`DisjointBlocks`] writer — no global
    /// lock is taken between the first pop and the last write-back.
    pub fn run_job(&self, job: GemmJob) -> anyhow::Result<JobResult> {
        let run = self.plan_job(&job)?;
        let GemmJob { id, a, b, .. } = job;
        let a = a.into_inline().expect("plan_job already required an inline A");
        let b = b.into_inline().expect("plan_job already required an inline B");
        anyhow::ensure!(a.cols == b.rows, "contraction mismatch");
        let start = Instant::now();

        let a = &a;
        let b = &b;
        let plan = BlockPlan::new(a.rows, a.cols, b.cols, run.si, run.sj);
        let wqm = AtomicWqm::from_partition(plan.partition(run.np));
        // In-process backends consume the packed panels zero-copy; the
        // channel-fed PJRT backend gathers per task instead, so skip the
        // pack there.
        let packed = if self.engine.is_inprocess() {
            self.metrics.add_a_panel_packs(1);
            self.metrics.add_b_panel_packs(1);
            Some(PackedPanels::pack(a.view(), b.view(), &plan))
        } else {
            None
        };
        let mut c = Matrix::zeros(a.rows, b.cols);
        {
            // The writer holds C's unique borrow for the worker scope;
            // per-block writes are disjoint because the plan's tasks
            // tile C and the WQM pops each task exactly once.
            let writer = DisjointBlocks::new(c.view_mut());
            std::thread::scope(|s| -> anyhow::Result<()> {
                let mut handles = Vec::with_capacity(run.np);
                for w in 0..run.np {
                    let wqm = &wqm;
                    let writer = &writer;
                    let packed = packed.as_ref();
                    let engine = &self.engine;
                    let metrics = &self.metrics;
                    handles.push(s.spawn(move || -> anyhow::Result<()> {
                        while let Some(task) = wqm.pop(w) {
                            let zero_copy = engine
                                .task_product_into(packed, Some(a), Some(b), &task, writer)?;
                            if !zero_copy {
                                metrics.add_panel_copies(2);
                            }
                            metrics.task_done();
                        }
                        Ok(())
                    }));
                }
                for h in handles {
                    h.join().map_err(|_| anyhow::anyhow!("worker panicked"))??;
                }
                Ok(())
            })?;
        }

        let steals: u64 = wqm.stats().iter().map(|s| s.stolen_in).sum();
        self.metrics.add_steals(steals);

        let sim = self.accelerator.simulate(
            &run,
            a.rows,
            a.cols,
            b.cols,
            &SimOptions::default(),
        )?;
        let host_latency_secs = start.elapsed().as_secs_f64();
        self.metrics.job_done(host_latency_secs, sim.total_secs);

        Ok(JobResult { id, c, run, sim, host_latency_secs, batched: false })
    }

    /// Serve a stream of jobs, replying on per-job channels. Jobs run
    /// sequentially (the accelerator is a single shared device); the
    /// queue is the batching point. Returns when the sender hangs up.
    ///
    /// This is the minimal serving loop; for concurrent traffic use
    /// [`JobServer`], which keeps one persistent pool busy across jobs
    /// (cross-job stealing) instead of processing them one at a time.
    pub fn serve(
        &self,
        jobs: mpsc::Receiver<(GemmJob, mpsc::Sender<anyhow::Result<JobResult>>)>,
    ) {
        while let Ok((job, reply)) = jobs.recv() {
            let result = self.run_job(job);
            let _ = reply.send(result);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn coordinator() -> Coordinator {
        Coordinator::new(HardwareConfig::paper(), NumericsEngine::golden())
    }

    #[test]
    fn job_produces_correct_result() {
        let co = coordinator();
        let a = Matrix::random(100, 50, 1);
        let b = Matrix::random(50, 80, 2);
        let want = a.matmul(&b);
        let job = GemmJob { id: 1, a: a.into(), b: b.into(), run: Some(RunConfig::square(2, 32)) };
        let r = co.run_job(job).unwrap();
        assert!(r.c.allclose(&want, 1e-4));
        assert_eq!(r.run, RunConfig::square(2, 32));
        assert!(r.sim.total_secs > 0.0);
    }

    #[test]
    fn dse_chooses_config_when_unpinned() {
        let co = coordinator();
        let a = Matrix::random(128, 64, 3);
        let b = Matrix::random(64, 128, 4);
        let want = a.matmul(&b);
        let r = co.run_job(GemmJob { id: 2, a: a.into(), b: b.into(), run: None }).unwrap();
        assert!(r.c.allclose(&want, 1e-4));
        assert!(r.run.validate(&co.hw).is_ok());
    }

    #[test]
    fn invalid_pinned_config_rejected() {
        let co = coordinator();
        let a = Matrix::random(8, 8, 5);
        let b = Matrix::random(8, 8, 6);
        let job = GemmJob { id: 3, a: a.into(), b: b.into(), run: Some(RunConfig::square(4, 256)) };
        assert!(co.run_job(job).is_err());
    }

    #[test]
    fn mismatched_operands_rejected() {
        let co = coordinator();
        let job = GemmJob {
            id: 4,
            a: Matrix::random(8, 8, 7).into(),
            b: Matrix::random(9, 8, 8).into(),
            run: None,
        };
        assert!(co.run_job(job).is_err());
    }

    #[test]
    fn metrics_accumulate() {
        let co = coordinator();
        let a = Matrix::random(64, 32, 9);
        let b = Matrix::random(32, 64, 10);
        let job = GemmJob { id: 5, a: a.into(), b: b.into(), run: Some(RunConfig::square(4, 16)) };
        co.run_job(job).unwrap();
        let m = co.metrics();
        assert_eq!(m.jobs(), 1);
        assert!(m.tasks() >= 16); // 4x4 block grid
    }

    #[test]
    fn golden_hot_path_makes_no_panel_copies() {
        // The zero-copy acceptance gate: a golden job must not gather
        // any per-task operand panels.
        let co = coordinator();
        let a = Matrix::random(100, 40, 21);
        let b = Matrix::random(40, 90, 22);
        let want = a.matmul(&b);
        let job = GemmJob { id: 9, a: a.into(), b: b.into(), run: Some(RunConfig::square(4, 16)) };
        let r = co.run_job(job).unwrap();
        assert!(r.c.allclose(&want, 1e-4));
        assert_eq!(co.metrics().panel_copies(), 0);
        assert!(co.metrics().tasks() > 0);
    }

    #[test]
    fn more_workers_than_tasks() {
        // np = 4 but the problem is one block: three workers find the
        // WQM empty immediately; the result is still correct.
        let co = coordinator();
        let a = Matrix::random(10, 8, 23);
        let b = Matrix::random(8, 12, 24);
        let want = a.matmul(&b);
        let job = GemmJob { id: 10, a: a.into(), b: b.into(), run: Some(RunConfig::square(4, 16)) };
        let r = co.run_job(job).unwrap();
        assert!(r.c.allclose(&want, 1e-5));
        assert_eq!(co.metrics().tasks(), 1);
    }

    #[test]
    fn serve_loop_replies() {
        let co = coordinator();
        let (tx, rx) = mpsc::channel();
        let (rtx, rrx) = mpsc::channel();
        let a = Matrix::random(32, 16, 11);
        let b = Matrix::random(16, 32, 12);
        let want = a.matmul(&b);
        tx.send((GemmJob { id: 6, a: a.into(), b: b.into(), run: Some(RunConfig::square(2, 16)) }, rtx))
            .unwrap();
        drop(tx);
        co.serve(rx);
        let r = rrx.recv().unwrap().unwrap();
        assert!(r.c.allclose(&want, 1e-4));
    }

    #[test]
    fn concurrent_jobs_from_multiple_clients() {
        // The engine + coordinator are shared across threads.
        let co = coordinator();
        std::thread::scope(|s| {
            for t in 0u64..3 {
                let co = &co;
                s.spawn(move || {
                    let a = Matrix::random(40, 20, t);
                    let b = Matrix::random(20, 40, t + 50);
                    let want = a.matmul(&b);
                    let r = co
                        .run_job(GemmJob {
                            id: t,
                            a: a.into(),
                            b: b.into(),
                            run: Some(RunConfig::square(2, 16)),
                        })
                        .unwrap();
                    assert!(r.c.allclose(&want, 1e-4));
                });
            }
        });
        assert_eq!(co.metrics().jobs(), 3);
    }
}
