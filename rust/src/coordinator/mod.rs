//! L3 coordinator: the serving face of the accelerator.
//!
//! GEMM jobs come in; the coordinator picks the optimal `⟨N_p, S_i⟩` via
//! the DSE (unless pinned), partitions the problem into sub-block tasks,
//! and drives `N_p` worker threads that pop tasks from a shared
//! work-stealing WQM — the software twin of the paper's hardware WQM.
//! Numerics execute on the [`engine::NumericsEngine`]: a dedicated thread
//! owning the PJRT runtime (XLA handles are not `Send`), fed over
//! channels, or a pure-rust golden engine for environments without
//! artifacts. Timing comes from the cycle-level simulator, so every job
//! returns both a real result matrix and the FPGA-time report.

pub mod engine;
pub mod metrics;

pub use engine::NumericsEngine;
pub use metrics::Metrics;

use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use crate::accelerator::{Accelerator, SimOptions, SimReport};
use crate::blocking::BlockPlan;
use crate::config::{HardwareConfig, RunConfig};
use crate::dse;
use crate::gemm::Matrix;
use crate::wqm::Wqm;

/// One GEMM request.
#[derive(Debug, Clone)]
pub struct GemmJob {
    pub id: u64,
    pub a: Matrix,
    pub b: Matrix,
    /// Pin a config, or let the DSE choose.
    pub run: Option<RunConfig>,
}

/// What the coordinator returns per job.
#[derive(Debug)]
pub struct JobResult {
    pub id: u64,
    pub c: Matrix,
    /// The configuration actually used.
    pub run: RunConfig,
    /// Simulated FPGA-side execution report.
    pub sim: SimReport,
    /// Wall-clock host latency of the numerics execution.
    pub host_latency_secs: f64,
}

/// The coordinator.
pub struct Coordinator {
    pub hw: HardwareConfig,
    accelerator: Accelerator,
    engine: NumericsEngine,
    metrics: Arc<Metrics>,
}

impl Coordinator {
    pub fn new(hw: HardwareConfig, engine: NumericsEngine) -> Self {
        Self {
            accelerator: Accelerator::new(hw.clone()),
            hw,
            engine,
            metrics: Arc::new(Metrics::default()),
        }
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    pub fn accelerator(&self) -> &Accelerator {
        &self.accelerator
    }

    /// Choose the run config for a job: pinned, or DSE-optimal.
    pub fn plan_job(&self, job: &GemmJob) -> anyhow::Result<RunConfig> {
        if let Some(run) = job.run {
            run.validate(&self.hw)?;
            return Ok(run);
        }
        let e = dse::explore(
            &self.hw,
            job.a.rows,
            job.a.cols,
            job.b.cols,
            self.accelerator.surface(),
        )?;
        Ok(e.best.run)
    }

    /// Execute one job: numerics through `N_p` work-stealing workers on
    /// the engine, timing through the simulator.
    pub fn run_job(&self, job: GemmJob) -> anyhow::Result<JobResult> {
        anyhow::ensure!(job.a.cols == job.b.rows, "contraction mismatch");
        let run = self.plan_job(&job)?;
        let start = Instant::now();

        let plan = BlockPlan::new(job.a.rows, job.a.cols, job.b.cols, run.si, run.sj);
        let mut wqm = Wqm::from_partition(plan.partition(run.np));
        wqm.set_stealing(true);
        let wqm = Mutex::new(wqm);
        let a = &job.a;
        let b = &job.b;
        let c = Mutex::new(Matrix::zeros(a.rows, b.cols));

        std::thread::scope(|s| -> anyhow::Result<()> {
            let mut handles = Vec::with_capacity(run.np);
            for w in 0..run.np {
                let wqm = &wqm;
                let c = &c;
                let engine = &self.engine;
                let metrics = &self.metrics;
                handles.push(s.spawn(move || -> anyhow::Result<()> {
                    loop {
                        // Pop (with stealing) under the WQM lock — the
                        // hardware controller's atomic counter compare.
                        let task = { wqm.lock().unwrap().pop(w) };
                        let Some(task) = task else { break };
                        let sa = a.block(task.row0, 0, task.si, a.cols);
                        let sb = b.block(0, task.col0, b.rows, task.sj);
                        let block = engine.block_product(sa, sb)?;
                        c.lock().unwrap().set_block(task.row0, task.col0, &block);
                        metrics.task_done();
                    }
                    Ok(())
                }));
            }
            for h in handles {
                h.join().map_err(|_| anyhow::anyhow!("worker panicked"))??;
            }
            Ok(())
        })?;

        let steals: u64 = {
            let w = wqm.lock().unwrap();
            w.stats().iter().map(|s| s.stolen_in).sum()
        };
        self.metrics.add_steals(steals);

        let sim = self.accelerator.simulate(
            &run,
            a.rows,
            a.cols,
            b.cols,
            &SimOptions::default(),
        )?;
        let host_latency_secs = start.elapsed().as_secs_f64();
        self.metrics.job_done(host_latency_secs, sim.total_secs);

        Ok(JobResult {
            id: job.id,
            c: c.into_inner().unwrap(),
            run,
            sim,
            host_latency_secs,
        })
    }

    /// Serve a stream of jobs, replying on per-job channels. Jobs run
    /// sequentially (the accelerator is a single shared device); the
    /// queue is the batching point. Returns when the sender hangs up.
    pub fn serve(
        &self,
        jobs: mpsc::Receiver<(GemmJob, mpsc::Sender<anyhow::Result<JobResult>>)>,
    ) {
        while let Ok((job, reply)) = jobs.recv() {
            let result = self.run_job(job);
            let _ = reply.send(result);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn coordinator() -> Coordinator {
        Coordinator::new(HardwareConfig::paper(), NumericsEngine::golden())
    }

    #[test]
    fn job_produces_correct_result() {
        let co = coordinator();
        let a = Matrix::random(100, 50, 1);
        let b = Matrix::random(50, 80, 2);
        let want = a.matmul(&b);
        let job = GemmJob { id: 1, a, b, run: Some(RunConfig::square(2, 32)) };
        let r = co.run_job(job).unwrap();
        assert!(r.c.allclose(&want, 1e-4));
        assert_eq!(r.run, RunConfig::square(2, 32));
        assert!(r.sim.total_secs > 0.0);
    }

    #[test]
    fn dse_chooses_config_when_unpinned() {
        let co = coordinator();
        let a = Matrix::random(128, 64, 3);
        let b = Matrix::random(64, 128, 4);
        let want = a.matmul(&b);
        let r = co.run_job(GemmJob { id: 2, a, b, run: None }).unwrap();
        assert!(r.c.allclose(&want, 1e-4));
        assert!(r.run.validate(&co.hw).is_ok());
    }

    #[test]
    fn invalid_pinned_config_rejected() {
        let co = coordinator();
        let a = Matrix::random(8, 8, 5);
        let b = Matrix::random(8, 8, 6);
        let job = GemmJob { id: 3, a, b, run: Some(RunConfig::square(4, 256)) };
        assert!(co.run_job(job).is_err());
    }

    #[test]
    fn mismatched_operands_rejected() {
        let co = coordinator();
        let job = GemmJob {
            id: 4,
            a: Matrix::random(8, 8, 7),
            b: Matrix::random(9, 8, 8),
            run: None,
        };
        assert!(co.run_job(job).is_err());
    }

    #[test]
    fn metrics_accumulate() {
        let co = coordinator();
        let a = Matrix::random(64, 32, 9);
        let b = Matrix::random(32, 64, 10);
        let job = GemmJob { id: 5, a, b, run: Some(RunConfig::square(4, 16)) };
        co.run_job(job).unwrap();
        let m = co.metrics();
        assert_eq!(m.jobs(), 1);
        assert!(m.tasks() >= 16); // 4x4 block grid
    }

    #[test]
    fn serve_loop_replies() {
        let co = coordinator();
        let (tx, rx) = mpsc::channel();
        let (rtx, rrx) = mpsc::channel();
        let a = Matrix::random(32, 16, 11);
        let b = Matrix::random(16, 32, 12);
        let want = a.matmul(&b);
        tx.send((GemmJob { id: 6, a, b, run: Some(RunConfig::square(2, 16)) }, rtx))
            .unwrap();
        drop(tx);
        co.serve(rx);
        let r = rrx.recv().unwrap().unwrap();
        assert!(r.c.allclose(&want, 1e-4));
    }

    #[test]
    fn concurrent_jobs_from_multiple_clients() {
        // The engine + coordinator are shared across threads.
        let co = coordinator();
        std::thread::scope(|s| {
            for t in 0u64..3 {
                let co = &co;
                s.spawn(move || {
                    let a = Matrix::random(40, 20, t);
                    let b = Matrix::random(20, 40, t + 50);
                    let want = a.matmul(&b);
                    let r = co
                        .run_job(GemmJob {
                            id: t,
                            a,
                            b,
                            run: Some(RunConfig::square(2, 16)),
                        })
                        .unwrap();
                    assert!(r.c.allclose(&want, 1e-4));
                });
            }
        });
        assert_eq!(co.metrics().jobs(), 3);
    }
}
