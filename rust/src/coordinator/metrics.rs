//! Coordinator metrics: atomic counters + latency aggregates, cheap
//! enough to update from every worker without contention concerns.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

#[derive(Debug, Default)]
pub struct Metrics {
    jobs: AtomicU64,
    tasks: AtomicU64,
    steals: AtomicU64,
    /// Per-task operand-panel copies made on the numerics path. The
    /// packed zero-copy pipeline keeps this at 0; the PJRT channel
    /// backend pays 2 per task (SA and SB gathers). The hotpath tests
    /// assert on it.
    panel_copies: AtomicU64,
    latencies: Mutex<LatencyAgg>,
}

#[derive(Debug, Default, Clone, Copy)]
struct LatencyAgg {
    count: u64,
    host_sum: f64,
    host_max: f64,
    sim_sum: f64,
}

impl Metrics {
    pub fn task_done(&self) {
        self.tasks.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add_steals(&self, n: u64) {
        self.steals.fetch_add(n, Ordering::Relaxed);
    }

    pub fn add_panel_copies(&self, n: u64) {
        self.panel_copies.fetch_add(n, Ordering::Relaxed);
    }

    pub fn job_done(&self, host_secs: f64, sim_secs: f64) {
        self.jobs.fetch_add(1, Ordering::Relaxed);
        let mut l = self.latencies.lock().unwrap();
        l.count += 1;
        l.host_sum += host_secs;
        l.host_max = l.host_max.max(host_secs);
        l.sim_sum += sim_secs;
    }

    pub fn jobs(&self) -> u64 {
        self.jobs.load(Ordering::Relaxed)
    }

    pub fn tasks(&self) -> u64 {
        self.tasks.load(Ordering::Relaxed)
    }

    pub fn steals(&self) -> u64 {
        self.steals.load(Ordering::Relaxed)
    }

    pub fn panel_copies(&self) -> u64 {
        self.panel_copies.load(Ordering::Relaxed)
    }

    /// (mean, max) host latency in seconds.
    pub fn host_latency(&self) -> (f64, f64) {
        let l = self.latencies.lock().unwrap();
        if l.count == 0 {
            (0.0, 0.0)
        } else {
            (l.host_sum / l.count as f64, l.host_max)
        }
    }

    /// Mean simulated FPGA time per job, seconds.
    pub fn mean_sim_secs(&self) -> f64 {
        let l = self.latencies.lock().unwrap();
        if l.count == 0 {
            0.0
        } else {
            l.sim_sum / l.count as f64
        }
    }

    pub fn summary(&self) -> String {
        let (mean, max) = self.host_latency();
        format!(
            "jobs={} tasks={} steals={} panel_copies={} host_lat(mean/max)={:.3}s/{:.3}s sim(mean)={:.6}s",
            self.jobs(),
            self.tasks(),
            self.steals(),
            self.panel_copies(),
            mean,
            max,
            self.mean_sim_secs()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::default();
        m.task_done();
        m.task_done();
        m.add_steals(3);
        m.add_panel_copies(2);
        m.job_done(0.5, 0.001);
        m.job_done(1.5, 0.003);
        assert_eq!(m.tasks(), 2);
        assert_eq!(m.steals(), 3);
        assert_eq!(m.panel_copies(), 2);
        assert_eq!(m.jobs(), 2);
        let (mean, max) = m.host_latency();
        assert!((mean - 1.0).abs() < 1e-12);
        assert!((max - 1.5).abs() < 1e-12);
        assert!((m.mean_sim_secs() - 0.002).abs() < 1e-12);
    }

    #[test]
    fn empty_latency_is_zero() {
        let m = Metrics::default();
        assert_eq!(m.host_latency(), (0.0, 0.0));
        assert_eq!(m.mean_sim_secs(), 0.0);
    }

    #[test]
    fn summary_formats() {
        let m = Metrics::default();
        m.job_done(0.1, 0.01);
        assert!(m.summary().contains("jobs=1"));
    }
}
