//! Coordinator metrics: atomic counters + latency aggregates, cheap
//! enough to update from every worker without contention concerns.
//!
//! The serving layer ([`crate::coordinator::JobServer`]) shares this
//! struct: per-job latencies are recorded individually so server-level
//! percentiles (p50/p95/p99) come from the true distribution, not from
//! a mean — tail latency is the serving metric that matters.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::frontend::TenantId;
use crate::util::rng::Rng;

/// Latency samples kept for percentile queries. Exact up to this many
/// jobs; beyond it, Algorithm-R reservoir sampling keeps a uniform
/// subsample so a long-lived server's memory stays bounded.
const LATENCY_RESERVOIR: usize = 4096;

#[derive(Debug, Default)]
pub struct Metrics {
    jobs: AtomicU64,
    jobs_failed: AtomicU64,
    tasks: AtomicU64,
    steals: AtomicU64,
    /// Pops a serving worker made from a different job than its previous
    /// one — the inter-job extension of the paper's inter-array steal.
    cross_job_steals: AtomicU64,
    /// Sub-threshold jobs that were coalesced into a batched super-job.
    batched_jobs: AtomicU64,
    /// Per-task operand-panel copies made on the numerics path. The
    /// packed zero-copy pipeline keeps this at 0; the PJRT channel
    /// backend pays 2 per task (SA and SB gathers). The hotpath tests
    /// assert on it.
    panel_copies: AtomicU64,
    /// Whole-operand A pack operations performed ([`crate::gemm::PackedA`]
    /// built). One per sub-job on the in-process path.
    a_panel_packs: AtomicU64,
    /// Whole-operand B pack operations performed ([`crate::gemm::PackedB`]
    /// built). A shared-B batch performs exactly one regardless of its
    /// sub-job count — the conservation the batched tests assert.
    b_panel_packs: AtomicU64,
    /// Sub-jobs served from an *already-packed* shared operand instead
    /// of packing their own — each increment is one whole-operand pack
    /// avoided (the sharing win `Submission::batched` exists for).
    panels_shared: AtomicU64,
    /// Operands whose combine was fused into the pack pass (a
    /// `FusedOperand` packed via `from_sum_of_views`) — each increment
    /// is one materialized temp write + read the Strassen fused path
    /// avoided.
    fused_packs: AtomicU64,
    /// Shared-B batch groups dispatched (one per
    /// `Submission::batched` call that reached activation).
    shared_b_groups: AtomicU64,
    /// Operand-registry resolutions served from an already-cached pack
    /// — each hit is one whole-operand pack avoided *across* calls,
    /// the cross-call extension of `panels_shared`. Shared by both
    /// registry sides; the A-side share is split out below.
    registry_hits: AtomicU64,
    /// Registry resolutions that had to pack (first use of a
    /// `(handle, side, s_param)` key, or re-use after eviction). Both
    /// sides.
    registry_misses: AtomicU64,
    /// Cached packs of either side evicted by the registry's
    /// refcount-pinned LRU to hold its shared byte budget.
    registry_evictions: AtomicU64,
    /// A-side (activation) share of `registry_hits`.
    registry_a_hits: AtomicU64,
    /// A-side share of `registry_misses`.
    registry_a_misses: AtomicU64,
    /// A-side share of `registry_evictions`.
    registry_a_evictions: AtomicU64,
    /// Gauge: bytes of packed data currently resident in the operand
    /// registry, both sides (set, not accumulated).
    registry_resident_bytes: AtomicU64,
    /// Gauge: the A-side (activation-panel) share of
    /// `registry_resident_bytes`.
    registry_a_resident_bytes: AtomicU64,
    /// Gauge: the per-precision split of `registry_resident_bytes`,
    /// indexed by `Dtype::index` — the four shares sum to the total.
    registry_dtype_resident_bytes: [AtomicU64; 4],
    /// Planner selections steered to an already-resident `(S_i, S_j)`
    /// variant instead of the config the pre-residency cascade would
    /// have chosen — each one is a repack turned into a cache hit.
    plan_residency_hits: AtomicU64,
    /// Registry unregister calls that failed (dead or foreign handle) —
    /// nonzero means a handle leak or a double-free somewhere upstream.
    unregister_failures: AtomicU64,
    /// Completed jobs that carried a deadline.
    deadline_jobs: AtomicU64,
    /// Deadline jobs that completed *after* their deadline. Deadlines
    /// shape dispatch order; a miss is a served-late job, never a
    /// dropped one — which is why this sits next to p99 in `stats()`.
    deadline_misses: AtomicU64,
    /// Per-tenant served/deadline/miss counts, keyed by `TenantId`.
    tenants: Mutex<BTreeMap<TenantId, TenantCounters>>,
    latencies: Mutex<LatencyAgg>,
    /// Relative model-drift records `(measured - predicted) / predicted`,
    /// one per finalized job whose config was priced at plan time.
    drift: Mutex<DriftAgg>,
}

/// Per-tenant serving counters, surfaced through
/// [`crate::coordinator::ServerStats::tenants`] — the observability half
/// of the fairness story: weights shape *dispatch order*, these prove
/// who actually got served.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TenantCounters {
    /// Jobs completed successfully for this tenant.
    pub jobs: u64,
    /// The subset of `jobs` that carried a deadline.
    pub deadline_jobs: u64,
    /// The subset of `deadline_jobs` that finished late.
    pub deadline_misses: u64,
}

#[derive(Debug)]
struct LatencyAgg {
    count: u64,
    host_sum: f64,
    host_max: f64,
    sim_sum: f64,
    /// Host-latency reservoir for percentile queries (exact below
    /// [`LATENCY_RESERVOIR`] jobs, uniform subsample above).
    host_all: Vec<f64>,
    /// Drives the reservoir's replacement choices; deterministic seed —
    /// the sampling, not the stream, is what needs to be unbiased.
    rng: Rng,
}

impl Default for LatencyAgg {
    fn default() -> Self {
        Self {
            count: 0,
            host_sum: 0.0,
            host_max: 0.0,
            sim_sum: 0.0,
            host_all: Vec::new(),
            rng: Rng::new(0x7A11_1A7E),
        }
    }
}

/// One-lock copy of the latency aggregate: every derived figure
/// (mean, max, any set of percentiles, mean sim time) comes from the
/// *same* consistent snapshot, and the percentile sort happens off the
/// lock so finalizing workers never wait behind a stats poll.
#[derive(Debug, Clone)]
pub struct LatencySnapshot {
    /// Jobs recorded.
    pub count: u64,
    /// Mean host latency, seconds (0 with no jobs).
    pub mean: f64,
    /// Max host latency, seconds.
    pub max: f64,
    /// Mean simulated FPGA time per job, seconds.
    pub mean_sim: f64,
    sorted: Vec<f64>,
}

impl LatencySnapshot {
    /// Nearest-rank percentile for `p` in `[0, 1]`, seconds; 0 with no
    /// recorded jobs.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.sorted.is_empty() {
            return 0.0;
        }
        let idx = ((p.clamp(0.0, 1.0) * self.sorted.len() as f64).ceil() as usize)
            .saturating_sub(1)
            .min(self.sorted.len() - 1);
        self.sorted[idx]
    }

    /// [`LatencySnapshot::percentile`] for each `p`, in order.
    pub fn percentiles(&self, ps: &[f64]) -> Vec<f64> {
        ps.iter().map(|&p| self.percentile(p)).collect()
    }
}

#[derive(Debug)]
struct DriftAgg {
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
    /// Reservoir for the drift p95 (same scheme as `LatencyAgg`).
    all: Vec<f64>,
    rng: Rng,
}

impl Default for DriftAgg {
    fn default() -> Self {
        Self {
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            all: Vec::new(),
            rng: Rng::new(0x0D21_F7A0),
        }
    }
}

/// Rollup of the model-drift distribution: how far the simulator's
/// measured time ran from `analytical::predict`'s plan-time price,
/// as a fraction of the prediction (positive = slower than predicted).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DriftStats {
    /// Jobs with a drift record.
    pub count: u64,
    /// Smallest relative drift.
    pub min: f64,
    /// Mean relative drift.
    pub mean: f64,
    /// Largest relative drift.
    pub max: f64,
    /// Nearest-rank p95 of relative drift.
    pub p95: f64,
}

impl Metrics {
    pub fn task_done(&self) {
        self.tasks.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add_steals(&self, n: u64) {
        self.steals.fetch_add(n, Ordering::Relaxed);
    }

    pub fn add_cross_job_steals(&self, n: u64) {
        self.cross_job_steals.fetch_add(n, Ordering::Relaxed);
    }

    pub fn add_batched_jobs(&self, n: u64) {
        self.batched_jobs.fetch_add(n, Ordering::Relaxed);
    }

    pub fn add_panel_copies(&self, n: u64) {
        self.panel_copies.fetch_add(n, Ordering::Relaxed);
    }

    pub fn add_a_panel_packs(&self, n: u64) {
        self.a_panel_packs.fetch_add(n, Ordering::Relaxed);
    }

    pub fn add_b_panel_packs(&self, n: u64) {
        self.b_panel_packs.fetch_add(n, Ordering::Relaxed);
    }

    pub fn add_panels_shared(&self, n: u64) {
        self.panels_shared.fetch_add(n, Ordering::Relaxed);
    }

    pub fn add_fused_packs(&self, n: u64) {
        self.fused_packs.fetch_add(n, Ordering::Relaxed);
    }

    pub fn add_shared_b_groups(&self, n: u64) {
        self.shared_b_groups.fetch_add(n, Ordering::Relaxed);
    }

    pub fn add_registry_hits(&self, n: u64) {
        self.registry_hits.fetch_add(n, Ordering::Relaxed);
    }

    pub fn add_registry_misses(&self, n: u64) {
        self.registry_misses.fetch_add(n, Ordering::Relaxed);
    }

    pub fn add_registry_evictions(&self, n: u64) {
        self.registry_evictions.fetch_add(n, Ordering::Relaxed);
    }

    pub fn add_registry_a_hits(&self, n: u64) {
        self.registry_a_hits.fetch_add(n, Ordering::Relaxed);
    }

    pub fn add_registry_a_misses(&self, n: u64) {
        self.registry_a_misses.fetch_add(n, Ordering::Relaxed);
    }

    pub fn add_registry_a_evictions(&self, n: u64) {
        self.registry_a_evictions.fetch_add(n, Ordering::Relaxed);
    }

    pub fn set_registry_resident_bytes(&self, bytes: u64) {
        self.registry_resident_bytes.store(bytes, Ordering::Relaxed);
    }

    pub fn set_registry_a_resident_bytes(&self, bytes: u64) {
        self.registry_a_resident_bytes.store(bytes, Ordering::Relaxed);
    }

    /// Set one precision's share of the registry resident-bytes gauge
    /// (`dtype_index` is `Dtype::index`; out-of-range indices are
    /// ignored rather than panicking a metrics path).
    pub fn set_registry_dtype_resident_bytes(&self, dtype_index: usize, bytes: u64) {
        if let Some(g) = self.registry_dtype_resident_bytes.get(dtype_index) {
            g.store(bytes, Ordering::Relaxed);
        }
    }

    pub fn add_plan_residency_hits(&self, n: u64) {
        self.plan_residency_hits.fetch_add(n, Ordering::Relaxed);
    }

    pub fn add_unregister_failures(&self, n: u64) {
        self.unregister_failures.fetch_add(n, Ordering::Relaxed);
    }

    pub fn job_done(&self, host_secs: f64, sim_secs: f64) {
        self.jobs.fetch_add(1, Ordering::Relaxed);
        let mut l = self.latencies.lock().unwrap();
        l.count += 1;
        l.host_sum += host_secs;
        l.host_max = l.host_max.max(host_secs);
        l.sim_sum += sim_secs;
        // Algorithm R: keep the first RESERVOIR samples, then replace a
        // uniformly-chosen slot with probability RESERVOIR / count.
        if l.host_all.len() < LATENCY_RESERVOIR {
            l.host_all.push(host_secs);
        } else {
            let j = (l.rng.next_u64() % l.count) as usize;
            if j < LATENCY_RESERVOIR {
                l.host_all[j] = host_secs;
            }
        }
    }

    pub fn job_failed(&self) {
        self.jobs_failed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one model-drift observation: the analytical prediction
    /// priced at plan time vs the simulator's measured time at
    /// finalize. Stored as relative drift `(measured - predicted) /
    /// predicted`; non-positive predictions are ignored.
    pub fn record_drift(&self, predicted_secs: f64, measured_secs: f64) {
        if !predicted_secs.is_finite()
            || !measured_secs.is_finite()
            || predicted_secs <= 0.0
        {
            return;
        }
        let frac = (measured_secs - predicted_secs) / predicted_secs;
        let mut d = self.drift.lock().unwrap();
        d.count += 1;
        d.sum += frac;
        d.min = d.min.min(frac);
        d.max = d.max.max(frac);
        if d.all.len() < LATENCY_RESERVOIR {
            d.all.push(frac);
        } else {
            let j = (d.rng.next_u64() % d.count) as usize;
            if j < LATENCY_RESERVOIR {
                d.all[j] = frac;
            }
        }
    }

    /// Rollup of the recorded model drift; `None` before the first
    /// record.
    pub fn drift_stats(&self) -> Option<DriftStats> {
        let (count, sum, min, max, mut all) = {
            let d = self.drift.lock().unwrap();
            if d.count == 0 {
                return None;
            }
            (d.count, d.sum, d.min, d.max, d.all.clone())
        };
        all.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((0.95 * all.len() as f64).ceil() as usize)
            .saturating_sub(1)
            .min(all.len() - 1);
        Some(DriftStats { count, min, mean: sum / count as f64, max, p95: all[idx] })
    }

    /// Record a completed deadline-carrying job; `missed` when it
    /// finished past its deadline.
    pub fn deadline_job_done(&self, missed: bool) {
        self.deadline_jobs.fetch_add(1, Ordering::Relaxed);
        if missed {
            self.deadline_misses.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record a completed job against its tenant's counters.
    pub fn tenant_job_done(&self, tenant: TenantId, has_deadline: bool, missed: bool) {
        let mut t = self.tenants.lock().unwrap();
        let c = t.entry(tenant).or_default();
        c.jobs += 1;
        if has_deadline {
            c.deadline_jobs += 1;
        }
        if missed {
            c.deadline_misses += 1;
        }
    }

    pub fn jobs(&self) -> u64 {
        self.jobs.load(Ordering::Relaxed)
    }

    pub fn jobs_failed(&self) -> u64 {
        self.jobs_failed.load(Ordering::Relaxed)
    }

    pub fn tasks(&self) -> u64 {
        self.tasks.load(Ordering::Relaxed)
    }

    pub fn steals(&self) -> u64 {
        self.steals.load(Ordering::Relaxed)
    }

    pub fn cross_job_steals(&self) -> u64 {
        self.cross_job_steals.load(Ordering::Relaxed)
    }

    pub fn batched_jobs(&self) -> u64 {
        self.batched_jobs.load(Ordering::Relaxed)
    }

    pub fn panel_copies(&self) -> u64 {
        self.panel_copies.load(Ordering::Relaxed)
    }

    pub fn a_panel_packs(&self) -> u64 {
        self.a_panel_packs.load(Ordering::Relaxed)
    }

    pub fn b_panel_packs(&self) -> u64 {
        self.b_panel_packs.load(Ordering::Relaxed)
    }

    pub fn panels_shared(&self) -> u64 {
        self.panels_shared.load(Ordering::Relaxed)
    }

    pub fn fused_packs(&self) -> u64 {
        self.fused_packs.load(Ordering::Relaxed)
    }

    pub fn shared_b_groups(&self) -> u64 {
        self.shared_b_groups.load(Ordering::Relaxed)
    }

    pub fn registry_hits(&self) -> u64 {
        self.registry_hits.load(Ordering::Relaxed)
    }

    pub fn registry_misses(&self) -> u64 {
        self.registry_misses.load(Ordering::Relaxed)
    }

    pub fn registry_evictions(&self) -> u64 {
        self.registry_evictions.load(Ordering::Relaxed)
    }

    pub fn registry_a_hits(&self) -> u64 {
        self.registry_a_hits.load(Ordering::Relaxed)
    }

    pub fn registry_a_misses(&self) -> u64 {
        self.registry_a_misses.load(Ordering::Relaxed)
    }

    pub fn registry_a_evictions(&self) -> u64 {
        self.registry_a_evictions.load(Ordering::Relaxed)
    }

    pub fn registry_resident_bytes(&self) -> u64 {
        self.registry_resident_bytes.load(Ordering::Relaxed)
    }

    pub fn registry_a_resident_bytes(&self) -> u64 {
        self.registry_a_resident_bytes.load(Ordering::Relaxed)
    }

    /// One precision's share of the registry resident-bytes gauge
    /// (zero for out-of-range indices).
    pub fn registry_dtype_resident_bytes(&self, dtype_index: usize) -> u64 {
        self.registry_dtype_resident_bytes
            .get(dtype_index)
            .map(|g| g.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    pub fn plan_residency_hits(&self) -> u64 {
        self.plan_residency_hits.load(Ordering::Relaxed)
    }

    pub fn unregister_failures(&self) -> u64 {
        self.unregister_failures.load(Ordering::Relaxed)
    }

    pub fn deadline_jobs(&self) -> u64 {
        self.deadline_jobs.load(Ordering::Relaxed)
    }

    pub fn deadline_misses(&self) -> u64 {
        self.deadline_misses.load(Ordering::Relaxed)
    }

    /// Per-tenant counter snapshot, ordered by `TenantId`.
    pub fn tenant_counters(&self) -> Vec<(TenantId, TenantCounters)> {
        self.tenants.lock().unwrap().iter().map(|(&t, &c)| (t, c)).collect()
    }

    /// (mean, max) host latency in seconds.
    pub fn host_latency(&self) -> (f64, f64) {
        let l = self.latencies.lock().unwrap();
        if l.count == 0 {
            (0.0, 0.0)
        } else {
            (l.host_sum / l.count as f64, l.host_max)
        }
    }

    /// One consistent copy of the whole latency aggregate under a
    /// single lock acquisition — mean, max, sim mean, and the
    /// percentile reservoir together. Use this instead of separate
    /// [`Self::host_latency`] / [`Self::host_latency_percentile`] /
    /// [`Self::mean_sim_secs`] calls when deriving several figures at
    /// once: three separate locks can interleave with `job_done` and
    /// report a mean and a p95 from *different* job populations.
    pub fn latency_snapshot(&self) -> LatencySnapshot {
        let (count, mean, max, mean_sim, mut sorted) = {
            let l = self.latencies.lock().unwrap();
            let mean = if l.count == 0 { 0.0 } else { l.host_sum / l.count as f64 };
            let mean_sim = if l.count == 0 { 0.0 } else { l.sim_sum / l.count as f64 };
            (l.count, mean, l.host_max, mean_sim, l.host_all.clone())
        };
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        LatencySnapshot { count, mean, max, mean_sim, sorted }
    }

    /// Host-latency percentiles (nearest-rank) for each `p` in `[0, 1]`,
    /// seconds; zeros with no recorded jobs. One snapshot + one sort for
    /// the whole batch, with the sort done off the lock so finalizing
    /// workers never wait behind a stats poll.
    pub fn host_latency_percentiles(&self, ps: &[f64]) -> Vec<f64> {
        let mut sorted = {
            let l = self.latencies.lock().unwrap();
            l.host_all.clone()
        };
        if sorted.is_empty() {
            return vec![0.0; ps.len()];
        }
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        ps.iter()
            .map(|p| {
                let idx = ((p.clamp(0.0, 1.0) * sorted.len() as f64).ceil() as usize)
                    .saturating_sub(1)
                    .min(sorted.len() - 1);
                sorted[idx]
            })
            .collect()
    }

    /// Single-percentile convenience over [`Self::host_latency_percentiles`].
    pub fn host_latency_percentile(&self, p: f64) -> f64 {
        self.host_latency_percentiles(&[p])[0]
    }

    /// Mean simulated FPGA time per job, seconds.
    pub fn mean_sim_secs(&self) -> f64 {
        let l = self.latencies.lock().unwrap();
        if l.count == 0 {
            0.0
        } else {
            l.sim_sum / l.count as f64
        }
    }

    pub fn summary(&self) -> String {
        // One lock acquisition for every latency-derived figure: a
        // mean, percentiles, and sim mean read under separate locks can
        // interleave with `job_done` and describe different job
        // populations in one line.
        let lat = self.latency_snapshot();
        let ps = lat.percentiles(&[0.50, 0.95, 0.99]);
        let mut s = format!(
            "jobs={} (failed={}, batched={}) tasks={} steals={} (cross-job={}) \
             panel_copies={} packs(a/b)={}/{} panels_shared={} fused_packs={} \
             registry(hit/miss/evict)={}/{}/{} \
             a_panel(hit/miss/evict)={}/{}/{} plan_residency_hits={} \
             deadline(miss/ddl)={}/{} \
             host_lat(mean/p50/p95/p99/max)={:.3}s/{:.3}s/{:.3}s/{:.3}s/{:.3}s \
             sim(mean)={:.6}s",
            self.jobs(),
            self.jobs_failed(),
            self.batched_jobs(),
            self.tasks(),
            self.steals(),
            self.cross_job_steals(),
            self.panel_copies(),
            self.a_panel_packs(),
            self.b_panel_packs(),
            self.panels_shared(),
            self.fused_packs(),
            self.registry_hits(),
            self.registry_misses(),
            self.registry_evictions(),
            self.registry_a_hits(),
            self.registry_a_misses(),
            self.registry_a_evictions(),
            self.plan_residency_hits(),
            self.deadline_misses(),
            self.deadline_jobs(),
            lat.mean,
            ps[0],
            ps[1],
            ps[2],
            lat.max,
            lat.mean_sim
        );
        if let Some(d) = self.drift_stats() {
            s.push_str(&format!(
                " drift(min/mean/max/p95)={:+.3}/{:+.3}/{:+.3}/{:+.3}",
                d.min, d.mean, d.max, d.p95
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::default();
        m.task_done();
        m.task_done();
        m.add_steals(3);
        m.add_cross_job_steals(2);
        m.add_batched_jobs(4);
        m.add_panel_copies(2);
        m.add_a_panel_packs(5);
        m.add_b_panel_packs(1);
        m.add_panels_shared(4);
        m.add_fused_packs(6);
        m.add_shared_b_groups(1);
        m.add_registry_hits(3);
        m.add_registry_misses(2);
        m.add_registry_evictions(1);
        m.add_registry_a_hits(2);
        m.add_registry_a_misses(1);
        m.add_registry_a_evictions(1);
        m.add_plan_residency_hits(1);
        m.add_unregister_failures(1);
        m.set_registry_resident_bytes(4096);
        m.set_registry_resident_bytes(2048); // gauge: set, not summed
        m.set_registry_a_resident_bytes(512);
        m.set_registry_a_resident_bytes(256);
        m.set_registry_dtype_resident_bytes(0, 2048);
        m.set_registry_dtype_resident_bytes(3, 128);
        m.set_registry_dtype_resident_bytes(3, 64); // gauge: set, not summed
        m.set_registry_dtype_resident_bytes(99, 7); // out of range: ignored
        m.job_done(0.5, 0.001);
        m.job_done(1.5, 0.003);
        m.job_failed();
        assert_eq!(m.tasks(), 2);
        assert_eq!(m.steals(), 3);
        assert_eq!(m.cross_job_steals(), 2);
        assert_eq!(m.batched_jobs(), 4);
        assert_eq!(m.panel_copies(), 2);
        assert_eq!(m.a_panel_packs(), 5);
        assert_eq!(m.b_panel_packs(), 1);
        assert_eq!(m.panels_shared(), 4);
        assert_eq!(m.fused_packs(), 6);
        assert_eq!(m.shared_b_groups(), 1);
        assert_eq!(m.registry_hits(), 3);
        assert_eq!(m.registry_misses(), 2);
        assert_eq!(m.registry_evictions(), 1);
        assert_eq!(m.registry_a_hits(), 2);
        assert_eq!(m.registry_a_misses(), 1);
        assert_eq!(m.registry_a_evictions(), 1);
        assert_eq!(m.plan_residency_hits(), 1);
        assert_eq!(m.unregister_failures(), 1);
        assert_eq!(m.registry_resident_bytes(), 2048);
        assert_eq!(m.registry_a_resident_bytes(), 256);
        assert_eq!(m.registry_dtype_resident_bytes(0), 2048);
        assert_eq!(m.registry_dtype_resident_bytes(3), 64);
        assert_eq!(m.registry_dtype_resident_bytes(1), 0);
        assert_eq!(m.registry_dtype_resident_bytes(99), 0);
        assert_eq!(m.jobs(), 2);
        assert_eq!(m.jobs_failed(), 1);
        let (mean, max) = m.host_latency();
        assert!((mean - 1.0).abs() < 1e-12);
        assert!((max - 1.5).abs() < 1e-12);
        assert!((m.mean_sim_secs() - 0.002).abs() < 1e-12);
    }

    #[test]
    fn empty_latency_is_zero() {
        let m = Metrics::default();
        assert_eq!(m.host_latency(), (0.0, 0.0));
        assert_eq!(m.mean_sim_secs(), 0.0);
        assert_eq!(m.host_latency_percentile(0.99), 0.0);
    }

    #[test]
    fn percentiles_nearest_rank() {
        let m = Metrics::default();
        for v in 1..=100 {
            m.job_done(v as f64, 0.0);
        }
        assert_eq!(m.host_latency_percentile(0.50), 50.0);
        assert_eq!(m.host_latency_percentile(0.95), 95.0);
        assert_eq!(m.host_latency_percentile(0.99), 99.0);
        assert_eq!(m.host_latency_percentile(1.0), 100.0);
        assert_eq!(m.host_latency_percentile(0.0), 1.0);
    }

    #[test]
    fn reservoir_keeps_percentiles_representative() {
        // Push far more jobs than the reservoir holds: aggregates stay
        // exact, percentiles stay statistically representative.
        let m = Metrics::default();
        for v in 1..=10_000 {
            m.job_done(v as f64, 0.0);
        }
        let (mean, max) = m.host_latency();
        assert_eq!(max, 10_000.0); // max is exact, not sampled
        assert!((mean - 5000.5).abs() < 1e-9); // sum/count exact too
        let ps = m.host_latency_percentiles(&[0.50, 0.95]);
        assert!((4000.0..=6000.0).contains(&ps[0]), "p50 {}", ps[0]);
        assert!((9000.0..=10_000.0).contains(&ps[1]), "p95 {}", ps[1]);
        assert!(ps[0] <= ps[1]);
    }

    #[test]
    fn percentile_single_sample() {
        let m = Metrics::default();
        m.job_done(0.25, 0.0);
        assert_eq!(m.host_latency_percentile(0.5), 0.25);
        assert_eq!(m.host_latency_percentile(0.99), 0.25);
    }

    #[test]
    fn summary_formats() {
        let m = Metrics::default();
        m.job_done(0.1, 0.01);
        assert!(m.summary().contains("jobs=1"));
        assert!(m.summary().contains("cross-job=0"));
        assert!(m.summary().contains("a_panel(hit/miss/evict)=0/0/0"));
        assert!(m.summary().contains("plan_residency_hits=0"));
        assert!(m.summary().contains("deadline(miss/ddl)=0/0"));
        assert!(m.summary().contains("host_lat(mean/p50/p95/p99/max)"));
        // No drift recorded → no drift segment.
        assert!(!m.summary().contains("drift("));
        m.record_drift(0.010, 0.012);
        assert!(m.summary().contains("drift(min/mean/max/p95)="));
    }

    #[test]
    fn latency_snapshot_is_one_consistent_copy() {
        let m = Metrics::default();
        for v in 1..=100 {
            m.job_done(v as f64, (v as f64) * 1e-3);
        }
        let s = m.latency_snapshot();
        assert_eq!(s.count, 100);
        assert!((s.mean - 50.5).abs() < 1e-12);
        assert_eq!(s.max, 100.0);
        assert!((s.mean_sim - 0.0505).abs() < 1e-12);
        // Percentiles agree with the multi-lock path on a quiescent
        // metrics object.
        assert_eq!(s.percentile(0.50), m.host_latency_percentile(0.50));
        assert_eq!(s.percentile(0.95), 95.0);
        assert_eq!(s.percentile(0.99), 99.0);
        assert_eq!(s.percentiles(&[0.5, 0.99]), vec![50.0, 99.0]);
        let empty = Metrics::default().latency_snapshot();
        assert_eq!(empty.percentile(0.99), 0.0);
        assert_eq!((empty.count, empty.mean, empty.max), (0, 0.0, 0.0));
    }

    #[test]
    fn drift_stats_roll_up() {
        let m = Metrics::default();
        assert!(m.drift_stats().is_none());
        // predicted 1.0 vs measured 0.9 / 1.0 / 1.5 → drift -0.1, 0, +0.5.
        m.record_drift(1.0, 0.9);
        m.record_drift(1.0, 1.0);
        m.record_drift(1.0, 1.5);
        let d = m.drift_stats().unwrap();
        assert_eq!(d.count, 3);
        assert!((d.min - -0.1).abs() < 1e-12);
        assert!((d.max - 0.5).abs() < 1e-12);
        assert!((d.mean - (0.4 / 3.0)).abs() < 1e-12);
        assert!((d.p95 - 0.5).abs() < 1e-12);
        // Degenerate inputs are ignored, not recorded.
        m.record_drift(0.0, 1.0);
        m.record_drift(-1.0, 1.0);
        m.record_drift(f64::NAN, 1.0);
        m.record_drift(1.0, f64::INFINITY);
        assert_eq!(m.drift_stats().unwrap().count, 3);
    }

    #[test]
    fn deadline_and_tenant_counters() {
        let m = Metrics::default();
        m.deadline_job_done(false);
        m.deadline_job_done(true);
        assert_eq!((m.deadline_jobs(), m.deadline_misses()), (2, 1));
        let (a, b) = (TenantId(1), TenantId(2));
        m.tenant_job_done(a, true, false);
        m.tenant_job_done(a, true, true);
        m.tenant_job_done(b, false, false);
        let rows = m.tenant_counters();
        assert_eq!(rows.len(), 2);
        assert_eq!(
            rows[0],
            (a, TenantCounters { jobs: 2, deadline_jobs: 2, deadline_misses: 1 })
        );
        assert_eq!(
            rows[1],
            (b, TenantCounters { jobs: 1, deadline_jobs: 0, deadline_misses: 0 })
        );
    }
}
