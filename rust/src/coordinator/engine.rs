//! Numerics engine: where block products actually get computed.
//!
//! PJRT handles (`xla::PjRtLoadedExecutable`) wrap raw C pointers and are
//! not `Send`, so the PJRT backend runs on one dedicated OS thread that
//! owns the [`crate::runtime::Runtime`]; coordinator workers talk to it
//! over channels. The golden backend computes in-process with the oracle
//! GEMM — used in tests and when `artifacts/` is absent.

use std::sync::mpsc;

use crate::gemm::{self, Matrix};
use crate::runtime::Runtime;

struct Request {
    sa: Matrix,
    sb: Matrix,
    reply: mpsc::Sender<anyhow::Result<Matrix>>,
}

enum Backend {
    Golden,
    Pjrt { tx: mpsc::Sender<Request> },
}

/// Thread-safe block-product executor shared by the coordinator workers.
pub struct NumericsEngine {
    backend: Backend,
    /// Human-readable backend name for logs/metrics.
    pub name: &'static str,
}

impl NumericsEngine {
    /// Pure-rust oracle backend.
    pub fn golden() -> Self {
        Self { backend: Backend::Golden, name: "golden" }
    }

    /// PJRT backend: spawns the runtime thread and loads + compiles all
    /// artifacts before returning (so failures surface here, not on the
    /// first job).
    pub fn pjrt(artifacts_dir: impl Into<std::path::PathBuf>) -> anyhow::Result<Self> {
        let dir = artifacts_dir.into();
        let (tx, rx) = mpsc::channel::<Request>();
        let (init_tx, init_rx) = mpsc::channel::<anyhow::Result<()>>();
        std::thread::Builder::new()
            .name("pjrt-numerics".into())
            .spawn(move || {
                let runtime = match Runtime::load(&dir) {
                    Ok(rt) => {
                        let _ = init_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = init_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    let _ = req.reply.send(runtime.block_product(&req.sa, &req.sb));
                }
            })?;
        init_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("pjrt thread died during init"))??;
        Ok(Self { backend: Backend::Pjrt { tx }, name: "pjrt" })
    }

    /// PJRT if artifacts are present, golden otherwise.
    pub fn auto(artifacts_dir: impl Into<std::path::PathBuf>) -> Self {
        let dir = artifacts_dir.into();
        match Self::pjrt(&dir) {
            Ok(e) => e,
            Err(_) => Self::golden(),
        }
    }

    /// `SA (rows x k) x SB (k x cols)` — one WQM task's numerics.
    /// Blocking call; safe from any worker thread.
    pub fn block_product(&self, sa: Matrix, sb: Matrix) -> anyhow::Result<Matrix> {
        match &self.backend {
            Backend::Golden => {
                Ok(gemm::block_task(&sa, &sb, 0, 0, sa.rows, sb.cols))
            }
            Backend::Pjrt { tx } => {
                let (reply, rx) = mpsc::channel();
                tx.send(Request { sa, sb, reply })
                    .map_err(|_| anyhow::anyhow!("pjrt thread gone"))?;
                rx.recv()
                    .map_err(|_| anyhow::anyhow!("pjrt thread dropped reply"))?
            }
        }
    }
}

// The PJRT variant only holds a channel Sender (Send + !Sync by default
// is false: mpsc::Sender is Send + !Sync in old std, Send + Sync since
// 1.72). Workers clone nothing — they share &NumericsEngine.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_block_product() {
        let e = NumericsEngine::golden();
        let a = Matrix::random(10, 6, 1);
        let b = Matrix::random(6, 12, 2);
        let c = e.block_product(a.clone(), b.clone()).unwrap();
        assert!(c.allclose(&a.matmul(&b), 1e-5));
    }

    #[test]
    fn pjrt_missing_artifacts_fails_fast() {
        assert!(NumericsEngine::pjrt("/nonexistent").is_err());
    }

    #[test]
    fn auto_falls_back_to_golden() {
        let e = NumericsEngine::auto("/nonexistent");
        assert_eq!(e.name, "golden");
        let a = Matrix::random(4, 4, 3);
        let b = Matrix::random(4, 4, 4);
        let c = e.block_product(a.clone(), b.clone()).unwrap();
        assert!(c.allclose(&a.matmul(&b), 1e-5));
    }

    #[test]
    fn engine_usable_from_threads() {
        let e = NumericsEngine::golden();
        std::thread::scope(|s| {
            for t in 0..4 {
                let e = &e;
                s.spawn(move || {
                    let a = Matrix::random(8, 8, t);
                    let b = Matrix::random(8, 8, t + 10);
                    let c = e.block_product(a.clone(), b.clone()).unwrap();
                    assert!(c.allclose(&a.matmul(&b), 1e-5));
                });
            }
        });
    }
}
