//! Numerics engine: where block products actually get computed.
//!
//! Two backends behind one handle:
//!
//! * **golden** — in-process, allocation-free on the hot path: tasks are
//!   computed by the register-blocked microkernel straight out of the
//!   job's [`PackedPanels`] and streamed into C through the shared
//!   [`DisjointBlocks`] writer. Used in tests and whenever `artifacts/`
//!   is absent.
//! * **pjrt** — PJRT handles (`xla::PjRtLoadedExecutable`) wrap raw C
//!   pointers and are not `Send`, so this backend runs on one dedicated
//!   OS thread that owns the [`crate::runtime::Runtime`]; workers talk
//!   to it over channels. Crossing the channel inherently copies the
//!   task's panels (counted by the coordinator's `panel_copies` metric).
//!
//! Both take operands by reference — the engine never consumes a job's
//! matrices.

use std::sync::mpsc;

use crate::blocking::BlockTask;
use crate::gemm::{self, DisjointBlocks, Matrix, PackedPanels};
use crate::runtime::Runtime;

struct Request {
    sa: Matrix,
    sb: Matrix,
    reply: mpsc::Sender<anyhow::Result<Matrix>>,
}

enum Backend {
    Golden,
    Pjrt { tx: mpsc::Sender<Request> },
}

/// Thread-safe block-product executor shared by the coordinator workers.
pub struct NumericsEngine {
    backend: Backend,
    /// Human-readable backend name for logs/metrics.
    pub name: &'static str,
}

impl NumericsEngine {
    /// Pure-rust in-process backend (microkernel fast path, oracle
    /// `block_task` as its cross-check in tests).
    pub fn golden() -> Self {
        Self { backend: Backend::Golden, name: "golden" }
    }

    /// PJRT backend: spawns the runtime thread and loads + compiles all
    /// artifacts before returning (so failures surface here, not on the
    /// first job).
    pub fn pjrt(artifacts_dir: impl Into<std::path::PathBuf>) -> anyhow::Result<Self> {
        let dir = artifacts_dir.into();
        let (tx, rx) = mpsc::channel::<Request>();
        let (init_tx, init_rx) = mpsc::channel::<anyhow::Result<()>>();
        std::thread::Builder::new()
            .name("pjrt-numerics".into())
            .spawn(move || {
                let runtime = match Runtime::load(&dir) {
                    Ok(rt) => {
                        let _ = init_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = init_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    let _ = req.reply.send(runtime.block_product(&req.sa, &req.sb));
                }
            })?;
        init_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("pjrt thread died during init"))??;
        Ok(Self { backend: Backend::Pjrt { tx }, name: "pjrt" })
    }

    /// PJRT if artifacts are present, golden otherwise.
    pub fn auto(artifacts_dir: impl Into<std::path::PathBuf>) -> Self {
        let dir = artifacts_dir.into();
        match Self::pjrt(&dir) {
            Ok(e) => e,
            Err(_) => Self::golden(),
        }
    }

    /// Does this backend compute in the worker's own thread (and can it
    /// therefore consume packed panels zero-copy)?
    pub fn is_inprocess(&self) -> bool {
        matches!(self.backend, Backend::Golden)
    }

    /// `SA (rows x k) x SB (k x cols)` — one block product, borrowed
    /// operands. Blocking call; safe from any worker thread. The PJRT
    /// backend clones the operands to cross the runtime-thread channel;
    /// callers that already own their operands should use
    /// [`Self::block_product_owned`] to skip that clone.
    pub fn block_product(&self, sa: &Matrix, sb: &Matrix) -> anyhow::Result<Matrix> {
        match &self.backend {
            Backend::Golden => Ok(gemm::block_task(sa, sb, 0, 0, sa.rows, sb.cols)),
            Backend::Pjrt { .. } => self.block_product_owned(sa.clone(), sb.clone()),
        }
    }

    /// Owned-operand variant of [`Self::block_product`]: the PJRT
    /// backend moves the operands into the runtime-thread channel
    /// without an extra copy.
    pub fn block_product_owned(&self, sa: Matrix, sb: Matrix) -> anyhow::Result<Matrix> {
        match &self.backend {
            Backend::Golden => Ok(gemm::block_task(&sa, &sb, 0, 0, sa.rows, sb.cols)),
            Backend::Pjrt { tx } => {
                let (reply, rx) = mpsc::channel();
                tx.send(Request { sa, sb, reply })
                    .map_err(|_| anyhow::anyhow!("pjrt thread gone"))?;
                rx.recv()
                    .map_err(|_| anyhow::anyhow!("pjrt thread dropped reply"))?
            }
        }
    }

    /// Execute one WQM task and write its `C_ij` block through the
    /// shared writer. Returns `true` when the zero-copy path ran (no
    /// per-task panel copies were made).
    ///
    /// * golden + packed panels: microkernel over `panels`, written in
    ///   place — no allocation, no copy;
    /// * pjrt (or no panels): gather the task's `SA_i` / `SB_j` slices
    ///   from the borrowed operands and run [`Self::block_product`].
    ///
    /// `task` must come from the same [`crate::blocking::BlockPlan`]
    /// that built `panels` and sized `out`, and each task must be
    /// executed at most once per writer — the disjointness contract of
    /// [`DisjointBlocks::write_block`].
    ///
    /// The full operands are optional because a fused sub-job exists
    /// *only* in packed form (its combination was formed inside the pack
    /// pass); the gather fallback needs both full matrices and errors
    /// without them.
    pub fn task_product_into(
        &self,
        panels: Option<&PackedPanels>,
        a: Option<&Matrix>,
        b: Option<&Matrix>,
        task: &BlockTask,
        out: &DisjointBlocks<'_>,
    ) -> anyhow::Result<bool> {
        if self.is_inprocess() {
            if let Some(panels) = panels {
                // SAFETY: the caller (coordinator / tests) executes each
                // task exactly once per writer, and a BlockPlan's tasks
                // tile C disjointly, so this block has a single writer.
                unsafe { gemm::task_product_into(panels, task, out) };
                return Ok(true);
            }
        }
        let (Some(a), Some(b)) = (a, b) else {
            anyhow::bail!("packed-only (fused) operands need an in-process engine")
        };
        // One gather copy per operand; the owned variant moves them into
        // the channel, so `panel_copies` (+2/task) is the true count.
        let sa = a.block(task.row0, 0, task.si, a.cols);
        let sb = b.block(0, task.col0, b.rows, task.sj);
        let block = self.block_product_owned(sa, sb)?;
        anyhow::ensure!(
            (block.rows, block.cols) == (task.rows, task.cols),
            "backend returned a {}x{} block for a {}x{} task",
            block.rows,
            block.cols,
            task.rows,
            task.cols
        );
        // SAFETY: same single-writer-per-task argument as above.
        unsafe {
            out.write_block(task.row0, task.col0, &block.data, block.cols, block.rows, block.cols)
        };
        Ok(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocking::BlockPlan;

    #[test]
    fn golden_block_product() {
        let e = NumericsEngine::golden();
        let a = Matrix::random(10, 6, 1);
        let b = Matrix::random(6, 12, 2);
        let c = e.block_product(&a, &b).unwrap();
        assert!(c.allclose(&a.matmul(&b), 1e-5));
    }

    #[test]
    fn pjrt_missing_artifacts_fails_fast() {
        assert!(NumericsEngine::pjrt("/nonexistent").is_err());
    }

    #[test]
    fn auto_falls_back_to_golden() {
        let e = NumericsEngine::auto("/nonexistent");
        assert_eq!(e.name, "golden");
        assert!(e.is_inprocess());
        let a = Matrix::random(4, 4, 3);
        let b = Matrix::random(4, 4, 4);
        let c = e.block_product(&a, &b).unwrap();
        assert!(c.allclose(&a.matmul(&b), 1e-5));
    }

    #[test]
    fn engine_usable_from_threads() {
        let e = NumericsEngine::golden();
        std::thread::scope(|s| {
            for t in 0..4 {
                let e = &e;
                s.spawn(move || {
                    let a = Matrix::random(8, 8, t);
                    let b = Matrix::random(8, 8, t + 10);
                    let c = e.block_product(&a, &b).unwrap();
                    assert!(c.allclose(&a.matmul(&b), 1e-5));
                });
            }
        });
    }

    #[test]
    fn task_product_into_zero_copy_matches_oracle() {
        let e = NumericsEngine::golden();
        let a = Matrix::random(40, 22, 5);
        let b = Matrix::random(22, 33, 6);
        let plan = BlockPlan::new(40, 22, 33, 16, 16);
        let panels = PackedPanels::pack(a.view(), b.view(), &plan);
        let mut c = Matrix::zeros(40, 33);
        {
            let w = DisjointBlocks::new(c.view_mut());
            for task in plan.tasks() {
                let zero_copy = e
                    .task_product_into(Some(&panels), Some(&a), Some(&b), &task, &w)
                    .unwrap();
                assert!(zero_copy);
            }
        }
        assert!(c.allclose(&a.matmul(&b), 1e-4));
    }

    #[test]
    fn task_product_into_gather_fallback_matches_oracle() {
        // Without panels the in-process engine falls back to the gather
        // path (what the pjrt backend does), flagging the copy.
        let e = NumericsEngine::golden();
        let a = Matrix::random(25, 14, 7);
        let b = Matrix::random(14, 19, 8);
        let plan = BlockPlan::new(25, 14, 19, 8, 8);
        let mut c = Matrix::zeros(25, 19);
        {
            let w = DisjointBlocks::new(c.view_mut());
            for task in plan.tasks() {
                let zero_copy =
                    e.task_product_into(None, Some(&a), Some(&b), &task, &w).unwrap();
                assert!(!zero_copy);
            }
        }
        assert!(c.allclose(&a.matmul(&b), 1e-4));
    }
}
