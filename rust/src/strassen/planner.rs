//! The recursive Strassen planner: quadrant split, 7-way sub-product
//! fan-out through the [`JobServer`], combine from the scratch arena.
//!
//! Two 7-multiplication schedules are table-driven behind
//! [`StrassenAlgo`]. The default is the Winograd form, which reaches the
//! same 7 products with 15 combine operations per node instead of the
//! classic form's 18 (4 A-side + 4 B-side + 7 C-side vs 5 + 5 + 8):
//!
//! ```text
//! S1 = A21 + A22   S5 = B12 - B11    M1 = S2*S6   M5 = S1*S5
//! S2 = S1  - A11   S6 = B22 - S5     M2 = A11*B11 M6 = S4*B22
//! S3 = A11 - A21   S7 = B22 - B12    M3 = A12*B21 M7 = A22*S8
//! S4 = A12 - S2    S8 = S6  - B21    M4 = S3*S7
//!
//! t1 = M1 + M2     C11 = M2 + M3     C21 = t2 - M7
//! t2 = t1 + M4     C12 = t1 + M5 + M6    C22 = t2 + M5
//! ```
//!
//! At the leaf level the 7 operand pairs are not materialized at all:
//! each is handed to the server as a fused operand
//! ([`FusedOperand`]), so the packer streams `X op Y` straight from the
//! parent quadrants into panel layout — one read of each source, no
//! intermediate write/read round trip. Only schedule steps that later
//! steps depend on (S1/S2 and S5/S6 under Winograd, nothing under
//! classic) are materialized. All 7 leaf jobs go down as one job group,
//! so the pool's cross-job stealing load-balances the fan-out.
//!
//! Above the leaf level the planner recurses; with
//! [`StrassenConfig::parallel`] (the default) the 7 sibling sub-trees
//! walk concurrently on scoped threads, each with a private
//! [`ScratchArena`] the parent absorbs at the join — the server sees
//! the whole tree's leaf groups in flight instead of one sub-tree at a
//! time. The walk is bit-identical to the sequential one: join order is
//! fixed, arena buffers are zeroed, and job IDs carry no numerics.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::analytical::{strassen_crossover_with, CrossoverPlan, StrassenAlgo};
use crate::config::RunConfig;
use crate::coordinator::{
    ActivationHandle, AOperand, BOperand, FusedOperand, FusedSource, GemmJob, JobServer,
    SpanKind, Submission, WeightHandle,
};
use crate::gemm::{ops, CombineOp, Dtype, Matrix, MatrixView};

use super::arena::{ArenaStats, ScratchArena};

/// Children a *direct* quadrant split would spawn per node — the figure
/// Strassen's 7 is measured against.
pub const DIRECT_SPLIT_FANOUT: u64 = 8;

/// How the recursion depth is chosen.
#[derive(Debug, Clone, Copy)]
pub enum Cutoff {
    /// Ask the analytical crossover model: recurse while it says
    /// `7·T(n/2) + combine` beats the direct multi-array time.
    Model,
    /// Force exactly this many levels (clamped so no padded leaf
    /// dimension collapses below 1 — tests use this to exercise
    /// multi-level recombination on small problems).
    Depth(usize),
}

/// Planner knobs.
#[derive(Debug, Clone, Copy)]
pub struct StrassenConfig {
    pub cutoff: Cutoff,
    /// Pinned run config for the leaf GEMMs; `None` lets the server
    /// plan each leaf (server default or per-job DSE).
    pub run: Option<RunConfig>,
    /// Which 7-product schedule to run (Winograd by default: 15 combine
    /// ops per node vs classic's 18).
    pub algo: StrassenAlgo,
    /// Walk sibling sub-trees above the leaf level on concurrent
    /// threads (bit-identical to the sequential walk).
    pub parallel: bool,
    /// Precision the leaf GEMMs submit at ([`Dtype::F32`] by default —
    /// the legacy path, bit for bit). The combine phase always runs in
    /// f32: leaves accumulate in f32 and stream f32 C blocks, so
    /// quadrant folds see full-width partials regardless of the leaf
    /// dtype.
    pub dtype: Dtype,
}

impl Default for StrassenConfig {
    fn default() -> Self {
        Self {
            cutoff: Cutoff::Model,
            run: None,
            algo: StrassenAlgo::default(),
            parallel: true,
            dtype: Dtype::F32,
        }
    }
}

/// Combine-phase accounting, the numbers behind the Winograd form's
/// ~20% operand-traffic cut: how many add/sub/copy passes ran and how
/// many temporaries were (and were not) written to memory.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CombineStats {
    /// Recursion nodes that contributed to these counters.
    pub nodes: u64,
    /// Logical combine operations executed: operand-side add/subs
    /// (whether materialized or fused into the packer) plus C-side
    /// quadrant folds. 15 per node under Winograd, 18 under classic.
    pub combine_ops: u64,
    /// Temporaries actually written: materialized schedule steps,
    /// quadrant copies, and C-side `t1`/`t2` under Winograd.
    pub temps_materialized: u64,
    /// Leaf operand temporaries *avoided* by fusing formation into the
    /// packer (out of the 14 a fully-materialized node would write).
    pub temps_avoided: u64,
}

impl CombineStats {
    pub fn merge(&mut self, o: CombineStats) {
        self.nodes += o.nodes;
        self.combine_ops += o.combine_ops;
        self.temps_materialized += o.temps_materialized;
        self.temps_avoided += o.temps_avoided;
    }

    /// Average combine operations per recursion node — 15.0 for a pure
    /// Winograd run, 18.0 for classic.
    pub fn ops_per_node(&self) -> f64 {
        if self.nodes == 0 {
            return 0.0;
        }
        self.combine_ops as f64 / self.nodes as f64
    }
}

/// What a Strassen run reports besides the product itself.
#[derive(Debug)]
pub struct StrassenReport {
    pub c: Matrix,
    /// Recursion levels actually executed (0 = ran direct).
    pub depth: usize,
    /// The schedule that ran.
    pub algo: StrassenAlgo,
    /// GEMMs submitted to the server (`7^depth`).
    pub leaf_gemms: u64,
    /// Recursion nodes per level (`level_nodes[i]` = nodes at level i).
    pub level_nodes: Vec<u64>,
    /// Sub-multiplies spawned per level, measured by counting at each
    /// node (not assumed).
    pub level_spawns: Vec<u64>,
    /// Combine-phase operation and temporary counts across the run.
    pub combine: CombineStats,
    /// Operand shapes after top-level padding to a multiple of
    /// `2^depth` (equals the input shape when depth = 0).
    pub padded: (usize, usize, usize),
    /// The analytical model's verdict, present only when the cutoff was
    /// [`Cutoff::Model`] (forced-depth runs skip the sweep).
    pub model: Option<CrossoverPlan>,
    pub arena: ArenaStats,
}

impl StrassenReport {
    /// Measured sub-multiplies per node at `level` — 7.0 on every
    /// executed Strassen level (vs [`DIRECT_SPLIT_FANOUT`]).
    pub fn fanout(&self, level: usize) -> f64 {
        match self.level_nodes.get(level) {
            Some(&nodes) if nodes > 0 => self.level_spawns[level] as f64 / nodes as f64,
            _ => 0.0,
        }
    }
}

/// Deepest recursion the shape admits: each level halves every padded
/// dimension, so `2^depth` may not exceed any of them.
fn depth_cap(m: usize, k: usize, n: usize) -> usize {
    (m.ilog2().min(k.ilog2()).min(n.ilog2())) as usize
}

/// One term of a side schedule: a parent quadrant or an earlier step's
/// result.
#[derive(Debug, Clone, Copy)]
enum Term {
    /// Quadrant `q`: row `q / 2`, column `q % 2` of the parent.
    Q(usize),
    /// The result of schedule step `i`.
    S(usize),
}

/// One schedule step: `x` alone (a copy) or `x op y`.
#[derive(Debug, Clone, Copy)]
struct Step {
    x: Term,
    op: Option<(CombineOp, Term)>,
}

/// One operand side (A or B) of a 7-product schedule: the temporaries
/// in dependency order, then the 7 sub-product operands M1..M7 as
/// terms over quadrants and steps.
struct SideSchedule {
    steps: &'static [Step],
    operands: [Term; 7],
}

use CombineOp::{Add, Sub};
use Term::{Q, S};

/// Classic Strassen, A side: each operand is its own step, nothing is
/// shared between steps.
static CLASSIC_A: SideSchedule = SideSchedule {
    steps: &[
        Step { x: Q(0), op: Some((Add, Q(3))) }, // A11 + A22
        Step { x: Q(2), op: Some((Add, Q(3))) }, // A21 + A22
        Step { x: Q(0), op: None },              // A11
        Step { x: Q(3), op: None },              // A22
        Step { x: Q(0), op: Some((Add, Q(1))) }, // A11 + A12
        Step { x: Q(2), op: Some((Sub, Q(0))) }, // A21 - A11
        Step { x: Q(1), op: Some((Sub, Q(3))) }, // A12 - A22
    ],
    operands: [S(0), S(1), S(2), S(3), S(4), S(5), S(6)],
};

/// Classic Strassen, B side.
static CLASSIC_B: SideSchedule = SideSchedule {
    steps: &[
        Step { x: Q(0), op: Some((Add, Q(3))) }, // B11 + B22
        Step { x: Q(0), op: None },              // B11
        Step { x: Q(1), op: Some((Sub, Q(3))) }, // B12 - B22
        Step { x: Q(2), op: Some((Sub, Q(0))) }, // B21 - B11
        Step { x: Q(3), op: None },              // B22
        Step { x: Q(0), op: Some((Add, Q(1))) }, // B11 + B12
        Step { x: Q(2), op: Some((Add, Q(3))) }, // B21 + B22
    ],
    operands: [S(0), S(1), S(2), S(3), S(4), S(5), S(6)],
};

/// Winograd form, A side: 4 chained sums serve all 7 operands (steps 0
/// and 1 feed later steps, so leaves materialize only those two).
static WINOGRAD_A: SideSchedule = SideSchedule {
    steps: &[
        Step { x: Q(2), op: Some((Add, Q(3))) }, // S1 = A21 + A22
        Step { x: S(0), op: Some((Sub, Q(0))) }, // S2 = S1 - A11
        Step { x: Q(0), op: Some((Sub, Q(2))) }, // S3 = A11 - A21
        Step { x: Q(1), op: Some((Sub, S(1))) }, // S4 = A12 - S2
    ],
    operands: [S(1), Q(0), Q(1), S(2), S(0), S(3), Q(3)],
};

/// Winograd form, B side (dual of the A side).
static WINOGRAD_B: SideSchedule = SideSchedule {
    steps: &[
        Step { x: Q(1), op: Some((Sub, Q(0))) }, // S5 = B12 - B11
        Step { x: Q(3), op: Some((Sub, S(0))) }, // S6 = B22 - S5
        Step { x: Q(3), op: Some((Sub, Q(1))) }, // S7 = B22 - B12
        Step { x: S(1), op: Some((Sub, Q(2))) }, // S8 = S6 - B21
    ],
    operands: [S(1), Q(0), Q(2), S(2), S(0), Q(3), S(3)],
};

fn a_schedule(algo: StrassenAlgo) -> &'static SideSchedule {
    match algo {
        StrassenAlgo::Classic => &CLASSIC_A,
        StrassenAlgo::Winograd => &WINOGRAD_A,
    }
}

fn b_schedule(algo: StrassenAlgo) -> &'static SideSchedule {
    match algo {
        StrassenAlgo::Classic => &CLASSIC_B,
        StrassenAlgo::Winograd => &WINOGRAD_B,
    }
}

/// Quadrant `q` of `parent` as a view (`r2 x c2` halves).
fn quad_view(parent: &Matrix, q: usize, r2: usize, c2: usize) -> MatrixView<'_> {
    parent.view().block((q / 2) * r2, (q % 2) * c2, r2, c2)
}

/// Resolve a schedule term against the parent and the materialized
/// steps so far.
fn term_view<'p>(
    parent: &'p Matrix,
    steps: &'p [Matrix],
    t: Term,
    r2: usize,
    c2: usize,
) -> MatrixView<'p> {
    match t {
        Term::Q(q) => quad_view(parent, q, r2, c2),
        Term::S(i) => steps[i].view(),
    }
}

/// Materialize one side of a schedule: every step written to an arena
/// buffer, the 7 operands returned in M1..M7 order (quadrant operands
/// are copied so each sub-product owns its matrix). Used above the leaf
/// level and at registration time, where operands must outlive the
/// parent.
fn form_side(
    sched: &SideSchedule,
    parent: &Matrix,
    arena: &mut ScratchArena,
    combine: &mut CombineStats,
) -> Vec<Matrix> {
    debug_assert!(parent.rows % 2 == 0 && parent.cols % 2 == 0, "side dims must be even");
    let (r2, c2) = (parent.rows / 2, parent.cols / 2);
    let mut steps: Vec<Matrix> = Vec::with_capacity(sched.steps.len());
    for step in sched.steps {
        let mut out = arena.take(r2, c2);
        {
            let x = term_view(parent, &steps, step.x, r2, c2);
            let mut ov = out.view_mut();
            match step.op {
                None => ops::copy_into(x, &mut ov),
                Some((op, y)) => {
                    let yv = term_view(parent, &steps, y, r2, c2);
                    match op {
                        CombineOp::Add => ops::add_into(x, yv, &mut ov),
                        CombineOp::Sub => ops::sub_into(x, yv, &mut ov),
                    }
                    combine.combine_ops += 1;
                }
            }
        }
        combine.temps_materialized += 1;
        steps.push(out);
    }
    let mut parked: Vec<Option<Matrix>> = steps.into_iter().map(Some).collect();
    let mut out = Vec::with_capacity(7);
    for &t in &sched.operands {
        match t {
            Term::S(i) => {
                out.push(parked[i].take().expect("schedule reuses a step as two operands"))
            }
            Term::Q(q) => {
                let mut m = arena.take(r2, c2);
                ops::copy_into(quad_view(parent, q, r2, c2), &mut m.view_mut());
                combine.temps_materialized += 1;
                out.push(m);
            }
        }
    }
    for leftover in parked.into_iter().flatten() {
        arena.put(leftover);
    }
    out
}

/// Form one side of a schedule for a *leaf* node: only steps that later
/// steps read are materialized; every operand becomes a
/// [`FusedOperand`] the packer resolves directly from the parent
/// quadrants (or a materialized step), so the add/sub happens inside
/// the pack pass. Returns the 7 operands in M1..M7 order plus the Arcs
/// holding the materialized steps (reclaim them after the jobs finish).
fn form_side_fused(
    sched: &SideSchedule,
    parent: &Arc<Matrix>,
    arena: &mut ScratchArena,
    combine: &mut CombineStats,
) -> (Vec<FusedOperand>, Vec<Arc<Matrix>>) {
    debug_assert!(parent.rows % 2 == 0 && parent.cols % 2 == 0, "side dims must be even");
    let (r2, c2) = (parent.rows / 2, parent.cols / 2);
    // A step must hit memory only if a later step's recipe reads it;
    // operand references expand into fused packs instead.
    let mut needed = [false; 7];
    for step in sched.steps {
        if let Term::S(i) = step.x {
            needed[i] = true;
        }
        if let Some((_, Term::S(i))) = step.op {
            needed[i] = true;
        }
    }
    let mut mats: Vec<Option<Arc<Matrix>>> = Vec::with_capacity(sched.steps.len());
    let mut materialized = 0u64;
    for (i, step) in sched.steps.iter().enumerate() {
        if needed[i] {
            let mut out = arena.take(r2, c2);
            {
                let x = fused_term_view(parent, &mats, step.x, r2, c2);
                let mut ov = out.view_mut();
                match step.op {
                    None => ops::copy_into(x, &mut ov),
                    Some((op, y)) => {
                        let yv = fused_term_view(parent, &mats, y, r2, c2);
                        match op {
                            CombineOp::Add => ops::add_into(x, yv, &mut ov),
                            CombineOp::Sub => ops::sub_into(x, yv, &mut ov),
                        }
                    }
                }
            }
            combine.temps_materialized += 1;
            materialized += 1;
            mats.push(Some(Arc::new(out)));
        } else {
            mats.push(None);
        }
        if step.op.is_some() {
            combine.combine_ops += 1;
        }
    }
    // A fully-materialized side writes one temp per operand.
    combine.temps_avoided += 7 - materialized;

    let src = |t: Term| -> FusedSource {
        match t {
            Term::Q(q) => FusedSource {
                parent: parent.clone(),
                row0: (q / 2) * r2,
                col0: (q % 2) * c2,
            },
            Term::S(i) => FusedSource::whole(
                mats[i].as_ref().expect("referenced step was materialized").clone(),
            ),
        }
    };
    let mut out = Vec::with_capacity(7);
    for &t in &sched.operands {
        let f = match t {
            Term::S(i) if mats[i].is_none() => {
                // Un-materialized step: hand its recipe to the packer.
                let step = &sched.steps[i];
                match step.op {
                    None => FusedOperand::single(r2, c2, src(step.x)),
                    Some((op, y)) => FusedOperand::combine(r2, c2, src(step.x), src(y), op),
                }
            }
            _ => FusedOperand::single(r2, c2, src(t)),
        };
        out.push(f);
    }
    let arcs = mats.into_iter().flatten().collect();
    (out, arcs)
}

/// Resolve a schedule term at a fused leaf (materialized steps live in
/// Arcs).
fn fused_term_view<'p>(
    parent: &'p Matrix,
    mats: &'p [Option<Arc<Matrix>>],
    t: Term,
    r2: usize,
    c2: usize,
) -> MatrixView<'p> {
    match t {
        Term::Q(q) => quad_view(parent, q, r2, c2),
        Term::S(i) => mats[i].as_ref().expect("referenced step was materialized").view(),
    }
}

/// Fold the 7 sub-products `ms` (M1..M7) into `c`'s quadrants under
/// `algo` — the single combine kernel every recursion variant shares,
/// so batched, registered and parallel runs recombine bit-identically.
fn combine_quadrants(
    algo: StrassenAlgo,
    arena: &mut ScratchArena,
    combine: &mut CombineStats,
    ms: [&Matrix; 7],
    c: &mut Matrix,
) {
    let (m2, n2) = (c.rows / 2, c.cols / 2);
    match algo {
        StrassenAlgo::Classic => {
            let mut cv = c.view_mut();
            {
                let mut c11 = cv.block_mut(0, 0, m2, n2);
                ops::add_into(ms[0].view(), ms[3].view(), &mut c11);
                ops::acc_sub(&mut c11, ms[4].view());
                ops::acc_add(&mut c11, ms[6].view());
            }
            {
                let mut c12 = cv.block_mut(0, n2, m2, n2);
                ops::add_into(ms[2].view(), ms[4].view(), &mut c12);
            }
            {
                let mut c21 = cv.block_mut(m2, 0, m2, n2);
                ops::add_into(ms[1].view(), ms[3].view(), &mut c21);
            }
            {
                let mut c22 = cv.block_mut(m2, n2, m2, n2);
                ops::sub_into(ms[0].view(), ms[1].view(), &mut c22);
                ops::acc_add(&mut c22, ms[2].view());
                ops::acc_add(&mut c22, ms[5].view());
            }
            combine.combine_ops += 8;
        }
        StrassenAlgo::Winograd => {
            // t1 = M1 + M2, t2 = t1 + M4 feed three quadrants; the two
            // temps are the Winograd C-side's whole working set.
            let mut t1 = arena.take(m2, n2);
            ops::add_into(ms[0].view(), ms[1].view(), &mut t1.view_mut());
            let mut t2 = arena.take(m2, n2);
            ops::add_into(t1.view(), ms[3].view(), &mut t2.view_mut());
            {
                let mut cv = c.view_mut();
                {
                    let mut c11 = cv.block_mut(0, 0, m2, n2);
                    ops::add_into(ms[1].view(), ms[2].view(), &mut c11);
                }
                {
                    let mut c12 = cv.block_mut(0, n2, m2, n2);
                    ops::add_into(t1.view(), ms[4].view(), &mut c12);
                    ops::acc_add(&mut c12, ms[5].view());
                }
                {
                    let mut c21 = cv.block_mut(m2, 0, m2, n2);
                    ops::sub_into(t2.view(), ms[6].view(), &mut c21);
                }
                {
                    let mut c22 = cv.block_mut(m2, n2, m2, n2);
                    ops::add_into(t2.view(), ms[4].view(), &mut c22);
                }
            }
            arena.put(t1);
            arena.put(t2);
            combine.combine_ops += 7;
            combine.temps_materialized += 2;
        }
    }
}

/// Read-only run state shared across the (possibly parallel) tree walk.
struct Shared<'s> {
    server: &'s JobServer,
    run: Option<RunConfig>,
    algo: StrassenAlgo,
    parallel: bool,
    depth: usize,
    dtype: Dtype,
    next_id: AtomicU64,
}

impl Shared<'_> {
    fn fresh_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }
}

/// Per-sub-tree counters; parallel siblings each fill their own and the
/// parent merges at the join.
struct NodeStats {
    leaf_gemms: u64,
    level_nodes: Vec<u64>,
    level_spawns: Vec<u64>,
    combine: CombineStats,
}

impl NodeStats {
    fn new(depth: usize) -> Self {
        Self {
            leaf_gemms: 0,
            level_nodes: vec![0; depth],
            level_spawns: vec![0; depth],
            combine: CombineStats::default(),
        }
    }

    fn merge(&mut self, o: NodeStats) {
        self.leaf_gemms += o.leaf_gemms;
        for (mine, theirs) in self.level_nodes.iter_mut().zip(o.level_nodes) {
            *mine += theirs;
        }
        for (mine, theirs) in self.level_spawns.iter_mut().zip(o.level_spawns) {
            *mine += theirs;
        }
        self.combine.merge(o.combine);
    }
}

/// Compute `C = A x B` through the Strassen planner on `server`.
///
/// The recursion depth is `cfg.cutoff` (model-chosen by default, under
/// `cfg.algo`'s combine pricing), clamped by the shape; `depth = 0`
/// degrades to one direct server job, the model's own verdict for
/// sub-crossover problems.
pub fn multiply(
    server: &JobServer,
    a: &Matrix,
    b: &Matrix,
    cfg: &StrassenConfig,
) -> anyhow::Result<StrassenReport> {
    anyhow::ensure!(a.cols == b.rows, "contraction mismatch");
    anyhow::ensure!(
        a.rows > 0 && a.cols > 0 && b.cols > 0,
        "degenerate problem {}x{}x{}",
        a.rows,
        a.cols,
        b.cols
    );
    if let Some(run) = cfg.run {
        run.validate(server.hw())?;
    }
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let (model, requested) = match cfg.cutoff {
        Cutoff::Model => {
            let plan = strassen_crossover_with(server.hw(), m, k, n, server.surface(), cfg.algo)?;
            let depth = plan.depth;
            (Some(plan), depth)
        }
        Cutoff::Depth(d) => (None, d),
    };
    let depth = requested.min(depth_cap(m, k, n));

    let mut arena = ScratchArena::new();
    // Fresh here, but pins the contract: report counters describe this
    // run even if an arena is ever carried across runs.
    arena.reset_stats();
    let mut stats = NodeStats::new(depth);
    let sh = Shared {
        server,
        run: cfg.run,
        algo: cfg.algo,
        parallel: cfg.parallel,
        depth,
        dtype: cfg.dtype,
        next_id: AtomicU64::new(0),
    };

    let (c, padded) = if depth == 0 {
        let job =
            GemmJob { id: sh.fresh_id(), a: a.clone().into(), b: b.clone().into(), run: cfg.run };
        let r = server.submit_async(Submission::from(job).dtype(cfg.dtype))?.wait_one()?;
        stats.leaf_gemms = 1;
        (r.c, (m, k, n))
    } else {
        // Section-IV zero padding, once, up to a multiple of 2^depth:
        // every level then halves exactly and leaves stay unragged.
        let align = 1usize << depth;
        let (mp, kp, np) =
            (m.next_multiple_of(align), k.next_multiple_of(align), n.next_multiple_of(align));
        let ap = a.pad_to(mp, kp);
        let bp = b.pad_to(kp, np);
        let cp = node(&sh, 0, ap, bp, &mut arena, &mut stats)?;
        // Padded columns of A meet padded rows of B as exact zero
        // terms, so the real product is the top-left block.
        let c = cp.block(0, 0, m, n);
        arena.put(cp);
        (c, (mp, kp, np))
    };

    Ok(StrassenReport {
        c,
        depth,
        algo: cfg.algo,
        leaf_gemms: stats.leaf_gemms,
        level_nodes: stats.level_nodes,
        level_spawns: stats.level_spawns,
        combine: stats.combine,
        padded,
        model,
        arena: arena.stats(),
    })
}

/// One recursion node (`level < sh.depth`; all dims even).
fn node(
    sh: &Shared<'_>,
    level: usize,
    a: Matrix,
    b: Matrix,
    arena: &mut ScratchArena,
    stats: &mut NodeStats,
) -> anyhow::Result<Matrix> {
    let (m, k, n) = (a.rows, a.cols, b.cols);
    debug_assert!(m % 2 == 0 && k % 2 == 0 && n % 2 == 0, "node dims must be even");
    let (m2, n2) = (m / 2, n / 2);
    let _ = k;
    let depth_left = sh.depth - level;
    stats.level_nodes[level] += 1;
    stats.level_spawns[level] += 7;
    stats.combine.nodes += 1;

    let ms: Vec<Matrix> = if depth_left == 1 {
        // Leaf level: operand formation is fused into the packer. The
        // parents (and the few chained schedule steps) go down wrapped
        // in Arcs; the server packs `X op Y` straight from them.
        let a = Arc::new(a);
        let b = Arc::new(b);
        let (fas, a_arcs) = form_side_fused(a_schedule(sh.algo), &a, arena, &mut stats.combine);
        let (fbs, b_arcs) = form_side_fused(b_schedule(sh.algo), &b, arena, &mut stats.combine);
        let jobs: Vec<GemmJob> = fas
            .into_iter()
            .zip(fbs)
            .map(|(fa, fb)| GemmJob {
                id: sh.fresh_id(),
                a: AOperand::Fused(fa),
                b: BOperand::Fused(fb),
                run: sh.run,
            })
            .collect();
        sh.server.trace_span_begin(SpanKind::StrassenLevel, level as u64);
        let results =
            sh.server.submit_async(Submission::group(jobs).dtype(sh.dtype))?.wait()?;
        sh.server.trace_span_end(SpanKind::StrassenLevel, level as u64);
        stats.leaf_gemms += 7;
        // Reclaim whatever the server has let go of; a worker cache may
        // briefly pin an Arc, in which case the buffer just drops.
        for arc in a_arcs.into_iter().chain(b_arcs).chain([a, b]) {
            if let Ok(freed) = Arc::try_unwrap(arc) {
                arena.put(freed);
            }
        }
        let mut ms = Vec::with_capacity(7);
        for r in results {
            anyhow::ensure!(
                (r.c.rows, r.c.cols) == (m2, n2),
                "leaf {} returned {}x{}, expected {m2}x{n2}",
                r.id,
                r.c.rows,
                r.c.cols
            );
            ms.push(r.c);
        }
        ms
    } else {
        let tas = form_side(a_schedule(sh.algo), &a, arena, &mut stats.combine);
        let tbs = form_side(b_schedule(sh.algo), &b, arena, &mut stats.combine);
        arena.put(a);
        arena.put(b);
        let pairs: Vec<(Matrix, Matrix)> = tas.into_iter().zip(tbs).collect();
        if sh.parallel {
            // Walk the 7 sibling sub-trees concurrently: each thread
            // owns a private arena and counters the parent absorbs at
            // the fixed-order join, so results and stats are identical
            // to the sequential walk while the server sees every
            // sub-tree's leaf groups in flight at once. submit_async
            // blocks on backpressure, so a full admission queue throttles
            // the walkers instead of failing them.
            let subs = std::thread::scope(|scope| {
                let handles: Vec<_> = pairs
                    .into_iter()
                    .map(|(ta, tb)| {
                        scope.spawn(move || -> anyhow::Result<(Matrix, ScratchArena, NodeStats)> {
                            let mut sub_arena = ScratchArena::new();
                            let mut sub_stats = NodeStats::new(sh.depth);
                            let c = node(sh, level + 1, ta, tb, &mut sub_arena, &mut sub_stats)?;
                            Ok((c, sub_arena, sub_stats))
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("strassen sub-tree thread panicked"))
                    .collect::<Vec<_>>()
            });
            let mut ms = Vec::with_capacity(7);
            for sub in subs {
                let (c, sub_arena, sub_stats) = sub?;
                arena.absorb(sub_arena);
                stats.merge(sub_stats);
                ms.push(c);
            }
            ms
        } else {
            let mut ms = Vec::with_capacity(7);
            for (ta, tb) in pairs {
                ms.push(node(sh, level + 1, ta, tb, arena, stats)?);
            }
            ms
        }
    };

    sh.server.trace_span_begin(SpanKind::StrassenCombine, level as u64);
    let mut c = arena.take(m, n);
    combine_quadrants(
        sh.algo,
        arena,
        &mut stats.combine,
        std::array::from_fn(|j| &ms[j]),
        &mut c,
    );
    sh.server.trace_span_end(SpanKind::StrassenCombine, level as u64);
    for mi in ms {
        arena.put(mi);
    }
    Ok(c)
}

/// What a batched Strassen run reports besides the per-member products.
#[derive(Debug)]
pub struct BatchedStrassenReport {
    /// `cs[i] = a_list[i] x b`, in input order.
    pub cs: Vec<Matrix>,
    /// Recursion levels actually executed (0 = one direct shared-B
    /// group).
    pub depth: usize,
    /// The schedule that ran (must match the registered sides).
    pub algo: StrassenAlgo,
    /// Shared-B groups submitted (`7^depth`, or 1 at depth 0) — each
    /// packed its B combination exactly once for the whole batch.
    pub leaf_groups: u64,
    /// Leaf GEMMs executed (`batch · 7^depth`).
    pub leaf_gemms: u64,
    /// Recursion nodes per level (as in [`StrassenReport`]).
    pub level_nodes: Vec<u64>,
    /// Sub-multiplies spawned per level, counted at each node.
    pub level_spawns: Vec<u64>,
    /// Combine-phase counters for the recursion-time work (per-member
    /// A forming and C recombination; registered-side forming happens
    /// at registration, not here).
    pub combine: CombineStats,
    /// Operand shapes after top-level padding (input shape at depth 0).
    pub padded: (usize, usize, usize),
    /// Present only under [`Cutoff::Model`].
    pub model: Option<CrossoverPlan>,
    pub arena: ArenaStats,
}

/// Recursion state for the batched (shared-B) variants, which stay
/// sequential: their leaves already batch whole member sets per
/// submission, so the admission queue sees wide groups without a
/// parallel tree walk.
struct Ctx<'s> {
    server: &'s JobServer,
    arena: ScratchArena,
    run: Option<RunConfig>,
    algo: StrassenAlgo,
    leaf_gemms: u64,
    /// Shared-B leaf groups submitted (each packs its B combination
    /// exactly once for the whole batch).
    leaf_groups: u64,
    level_nodes: Vec<u64>,
    level_spawns: Vec<u64>,
    combine: CombineStats,
}

/// The B side of a batched Strassen recursion registered as
/// server-resident weights: every **leaf-level B quadrant combination**
/// (`7^depth` of them, in the recursion's visit order) lives in the
/// server's operand registry under a [`WeightHandle`]. Build once with
/// [`register_weights`], run any number of batched recursions with
/// [`multiply_batched_registered`] — repeated inference over the same
/// weight matrix resolves every combination from the cache (registry
/// hits) instead of re-forming and repacking `7^depth` operands per
/// call.
pub struct StrassenWeights {
    /// Leaf combinations in recursion (pre-order, M1..M7 per node)
    /// visit order.
    handles: Vec<WeightHandle>,
    depth: usize,
    /// The schedule the combinations were formed under.
    algo: StrassenAlgo,
    /// Original B dims.
    k: usize,
    n: usize,
    /// B dims after top-level padding to a multiple of `2^depth`.
    padded_k: usize,
    padded_n: usize,
}

impl StrassenWeights {
    /// The recursion depth the combinations were registered for.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// The schedule the combinations were formed under.
    pub fn algo(&self) -> StrassenAlgo {
        self.algo
    }

    /// The registered leaf-combination handles (`7^depth`, or 1 at
    /// depth 0), in recursion visit order.
    pub fn leaf_handles(&self) -> &[WeightHandle] {
        &self.handles
    }

    /// Drop every registered combination (cached packs freed; in-flight
    /// work is unaffected). Sweeps the whole list even when one handle
    /// fails, so a partial failure never leaks the remainder.
    pub fn unregister(self, server: &JobServer) -> anyhow::Result<()> {
        server.unregister_all(self.handles)
    }
}

/// [`register_weights_with`] under the default schedule.
pub fn register_weights(
    server: &JobServer,
    b: &Matrix,
    depth: usize,
) -> anyhow::Result<StrassenWeights> {
    register_weights_with(server, b, depth, StrassenAlgo::default())
}

/// Form and register the B-side combination tree of `b` at `depth`
/// under `algo` — the Strassen model-load step. The combinations are
/// built with the same row-streamed add/sub kernels the recursion uses,
/// so a registered run is bit-identical to an inline one. `depth = 0`
/// registers `b` itself as a single shared operand.
pub fn register_weights_with(
    server: &JobServer,
    b: &Matrix,
    depth: usize,
    algo: StrassenAlgo,
) -> anyhow::Result<StrassenWeights> {
    let (k, n) = (b.rows, b.cols);
    anyhow::ensure!(k > 0 && n > 0, "degenerate B {k}x{n}");
    anyhow::ensure!(
        depth <= (k.ilog2().min(n.ilog2())) as usize,
        "depth {depth} too deep for a {k}x{n} B (each level halves both dims)"
    );
    let mut handles = Vec::new();
    let (padded_k, padded_n) = if depth == 0 {
        handles.push(server.register_b(b.clone())?);
        (k, n)
    } else {
        let align = 1usize << depth;
        let (kp, np) = (k.next_multiple_of(align), n.next_multiple_of(align));
        let bp = b.pad_to(kp, np);
        collect_b_combos(server, &bp, depth, algo, &mut handles)?;
        (kp, np)
    };
    Ok(StrassenWeights { handles, depth, algo, k, n, padded_k, padded_n })
}

/// Register the `7^depth_left` leaf combinations under `b`, pre-order
/// (combination j's subtree fully before combination j+1's) — exactly
/// the order [`node_batched_registered`] consumes them in.
fn collect_b_combos(
    server: &JobServer,
    b: &Matrix,
    depth_left: usize,
    algo: StrassenAlgo,
    handles: &mut Vec<WeightHandle>,
) -> anyhow::Result<()> {
    // Registration runs outside any recursion arena; a throwaway
    // arena/stats pair keeps the forming kernels identical.
    let combos =
        form_side(b_schedule(algo), b, &mut ScratchArena::new(), &mut CombineStats::default());
    for combo in combos {
        if depth_left == 1 {
            handles.push(server.register_b(combo)?);
        } else {
            collect_b_combos(server, &combo, depth_left - 1, algo, handles)?;
        }
    }
    Ok(())
}

/// Batched Strassen over a **shared B**: `cs[i] = a_list[i] x b` for a
/// whole batch, reusing the B-side quadrant combinations across it.
///
/// The 7-product fan-out repeats every B combination once per batch
/// member — a per-member recursion would rematerialize and repack each
/// combination `batch` times; here the combinations are **registered
/// with the server's operand registry** ([`register_weights_with`],
/// under `cfg.algo`) and every leaf pairing streams through a
/// [`Submission::batched`] under its [`WeightHandle`] — one shared-B
/// group per combination, the packed combo built exactly once however
/// large the batch is (`Metrics::b_panel_packs` = `7^depth` total,
/// `Metrics::panels_shared` = `(batch-1) · 7^depth`). This convenience
/// wrapper registers, runs once, and unregisters; repeated recursions
/// over the same `b` should hold a [`StrassenWeights`] and call
/// [`multiply_batched_registered`] per batch so later runs hit the
/// cache instead of re-forming `7^depth` packs.
///
/// Every member must have the same shape (a batch of identical GEMMs —
/// the im2col inference stream). Results are bit-identical to running
/// [`multiply`] per member with the same `cfg`: identical combine
/// kernels and identical leaf accumulation order, over operands whose
/// packed layout does not depend on sharing or on fused formation.
pub fn multiply_batched(
    server: &JobServer,
    a_list: &[Matrix],
    b: &Matrix,
    cfg: &StrassenConfig,
) -> anyhow::Result<BatchedStrassenReport> {
    anyhow::ensure!(!a_list.is_empty(), "empty batch");
    let (m, k) = (a_list[0].rows, a_list[0].cols);
    anyhow::ensure!(
        a_list.iter().all(|a| (a.rows, a.cols) == (m, k)),
        "batch members must share one shape"
    );
    anyhow::ensure!(k == b.rows, "contraction mismatch");
    anyhow::ensure!(
        m > 0 && k > 0 && b.cols > 0,
        "degenerate problem {m}x{k}x{}",
        b.cols
    );
    if let Some(run) = cfg.run {
        run.validate(server.hw())?;
    }
    let n = b.cols;
    let (model, requested) = match cfg.cutoff {
        Cutoff::Model => {
            let plan = strassen_crossover_with(server.hw(), m, k, n, server.surface(), cfg.algo)?;
            let depth = plan.depth;
            (Some(plan), depth)
        }
        Cutoff::Depth(d) => (None, d),
    };
    let depth = requested.min(depth_cap(m, k, n));

    if depth == 0 {
        // One direct shared-B group; nothing worth registering.
        let results =
            server.submit_blocking(Submission::batched(b.clone(), a_list.to_vec()).run(cfg.run))?;
        let cs = results.into_iter().map(|r| r.c).collect();
        return Ok(BatchedStrassenReport {
            cs,
            depth: 0,
            algo: cfg.algo,
            leaf_groups: 1,
            leaf_gemms: a_list.len() as u64,
            level_nodes: Vec::new(),
            level_spawns: Vec::new(),
            combine: CombineStats::default(),
            padded: (m, k, n),
            model,
            arena: ScratchArena::new().stats(),
        });
    }
    let weights = register_weights_with(server, b, depth, cfg.algo)?;
    // Unregister before surfacing any run failure: a failed recursion
    // must not leak 7^depth registrations into a long-lived server.
    let result = multiply_batched_registered(server, a_list, &weights, cfg.run);
    let unregistered = weights.unregister(server);
    let mut report = result?;
    unregistered?;
    report.model = model;
    Ok(report)
}

/// Batched Strassen against **pre-registered** B-side combinations: the
/// recursion carries only the A side — every leaf submits its shared-B
/// group by [`WeightHandle`], so a run over weights already resolved
/// once performs **zero** B-side forming or packing (pure registry
/// hits). The recursion depth and schedule are the weights'; the
/// report's `model` is `None` (register at the model's depth to combine
/// both).
pub fn multiply_batched_registered(
    server: &JobServer,
    a_list: &[Matrix],
    weights: &StrassenWeights,
    run: Option<RunConfig>,
) -> anyhow::Result<BatchedStrassenReport> {
    anyhow::ensure!(!a_list.is_empty(), "empty batch");
    let (m, k) = (a_list[0].rows, a_list[0].cols);
    anyhow::ensure!(
        a_list.iter().all(|a| (a.rows, a.cols) == (m, k)),
        "batch members must share one shape"
    );
    anyhow::ensure!(
        k == weights.k,
        "contraction mismatch: batch K = {k}, registered B K = {}",
        weights.k
    );
    anyhow::ensure!(m > 0 && k > 0, "degenerate problem {m}x{k}x{}", weights.n);
    if let Some(run) = run {
        run.validate(server.hw())?;
    }
    let depth = weights.depth;
    anyhow::ensure!(
        depth <= depth_cap(m, k, weights.n),
        "registered depth {depth} too deep for batch M = {m}; \
         register shallower weights for this problem"
    );

    let mut ctx = Ctx {
        server,
        arena: ScratchArena::new(),
        run,
        algo: weights.algo,
        leaf_gemms: 0,
        leaf_groups: 0,
        level_nodes: vec![0; depth],
        level_spawns: vec![0; depth],
        combine: CombineStats::default(),
    };
    ctx.arena.reset_stats();

    let (cs, padded) = if depth == 0 {
        let results = server
            .submit_blocking(Submission::batched(weights.handles[0], a_list.to_vec()).run(run))?;
        ctx.leaf_groups = 1;
        ctx.leaf_gemms = a_list.len() as u64;
        let cs = results.into_iter().map(|r| r.c).collect();
        (cs, (m, k, weights.n))
    } else {
        let align = 1usize << depth;
        let mp = m.next_multiple_of(align);
        let (kp, np) = (weights.padded_k, weights.padded_n);
        let aps: Vec<Matrix> = a_list.iter().map(|a| a.pad_to(mp, kp)).collect();
        let mut cursor = 0usize;
        let cps = node_batched_registered(&mut ctx, aps, np, depth, 0, weights, &mut cursor)?;
        debug_assert_eq!(cursor, weights.handles.len(), "every leaf combo consumed");
        let cs = cps
            .into_iter()
            .map(|cp| {
                let c = cp.block(0, 0, m, weights.n);
                ctx.arena.put(cp);
                c
            })
            .collect();
        (cs, (mp, kp, np))
    };

    Ok(BatchedStrassenReport {
        cs,
        depth,
        algo: weights.algo,
        leaf_groups: ctx.leaf_groups,
        leaf_gemms: ctx.leaf_gemms,
        level_nodes: ctx.level_nodes,
        level_spawns: ctx.level_spawns,
        combine: ctx.combine,
        padded,
        model: None,
        arena: ctx.arena.stats(),
    })
}

/// One batched recursion node against registered B combinations
/// (`depth_left >= 1`; all dims even, `n` = this node's B columns).
/// Forms the 7 A combinations per member under the registered schedule;
/// the B side is consumed as handles from `weights` in registration
/// (pre-)order via `cursor`.
fn node_batched_registered(
    ctx: &mut Ctx<'_>,
    a_list: Vec<Matrix>,
    n: usize,
    depth_left: usize,
    level: usize,
    weights: &StrassenWeights,
    cursor: &mut usize,
) -> anyhow::Result<Vec<Matrix>> {
    let batch = a_list.len();
    let (m, k) = (a_list[0].rows, a_list[0].cols);
    debug_assert!(m % 2 == 0 && k % 2 == 0 && n % 2 == 0, "node dims must be even");
    let (m2, n2) = (m / 2, n / 2);

    // Per-member A combinations: a_combos[j] holds combination j of
    // every member, in batch order.
    let mut a_combos: Vec<Vec<Matrix>> = (0..7).map(|_| Vec::with_capacity(batch)).collect();
    for a in a_list {
        let combos = form_side(a_schedule(ctx.algo), &a, &mut ctx.arena, &mut ctx.combine);
        for (j, combo) in combos.into_iter().enumerate() {
            a_combos[j].push(combo);
        }
        ctx.arena.put(a);
    }
    ctx.level_nodes[level] += 1;
    ctx.level_spawns[level] += 7;
    ctx.combine.nodes += 1;

    // ms[j][member] = combination j's product for that member.
    let ms: Vec<Vec<Matrix>> = if depth_left == 1 {
        // Submit all 7 shared-B groups before waiting on any, so the
        // pool sees the node's whole fan-out at once. Each group's B is
        // a registered handle: resolved from the cache, never re-formed.
        let mut groups = Vec::with_capacity(7);
        for acs in a_combos {
            let h = weights.handles[*cursor];
            *cursor += 1;
            groups.push(ctx.server.submit_async(Submission::batched(h, acs).run(ctx.run))?);
        }
        ctx.leaf_groups += 7;
        ctx.leaf_gemms += 7 * batch as u64;
        let mut ms = Vec::with_capacity(7);
        for g in groups {
            let results = g.wait()?;
            let mut per_member = Vec::with_capacity(batch);
            for r in results {
                anyhow::ensure!(
                    (r.c.rows, r.c.cols) == (m2, n2),
                    "leaf {} returned {}x{}, expected {m2}x{n2}",
                    r.id,
                    r.c.rows,
                    r.c.cols
                );
                per_member.push(r.c);
            }
            ms.push(per_member);
        }
        ms
    } else {
        let mut ms = Vec::with_capacity(7);
        for acs in a_combos {
            ms.push(node_batched_registered(
                ctx,
                acs,
                n2,
                depth_left - 1,
                level + 1,
                weights,
                cursor,
            )?);
        }
        ms
    };

    Ok(combine_members(ctx, ms, batch, m, n))
}

/// The per-member Strassen combine for one batched node: fold each
/// member's 7 sub-products `ms[j][member]` into its `m x n` C through
/// the shared [`combine_quadrants`] kernel, recycling the sub-products
/// through the arena. Shared by every batched recursion variant so
/// registered and inline runs combine bit-identically.
fn combine_members(
    ctx: &mut Ctx<'_>,
    ms: Vec<Vec<Matrix>>,
    batch: usize,
    m: usize,
    n: usize,
) -> Vec<Matrix> {
    let mut cs = Vec::with_capacity(batch);
    for member in 0..batch {
        let mut c = ctx.arena.take(m, n);
        combine_quadrants(
            ctx.algo,
            &mut ctx.arena,
            &mut ctx.combine,
            std::array::from_fn(|j| &ms[j][member]),
            &mut c,
        );
        cs.push(c);
    }
    for per_combo in ms {
        for mi in per_combo {
            ctx.arena.put(mi);
        }
    }
    cs
}

/// The A side of a batched Strassen recursion registered as
/// server-resident activations: every **leaf-level A quadrant
/// combination of every batch member** (`7^depth` combinations x
/// `batch` members, in the recursion's visit order) lives in the
/// server's operand registry under an [`ActivationHandle`]. The
/// dual of [`StrassenWeights`] for serving loops that re-run the same
/// activation batch against one or more weight sets — build once with
/// [`register_activations`], then [`multiply_batched_bi_registered`]
/// resolves *both* sides of every leaf GEMM from the pack cache.
pub struct StrassenActivations {
    /// `handles[leaf][member]`: leaf combinations in recursion
    /// (pre-order, M1..M7 per node) visit order — the same order
    /// [`StrassenWeights`] registers the B side in, so one cursor
    /// walks both.
    handles: Vec<Vec<ActivationHandle>>,
    depth: usize,
    /// The schedule the combinations were formed under.
    algo: StrassenAlgo,
    batch: usize,
    /// Original per-member A dims.
    m: usize,
    k: usize,
    /// A dims after top-level padding to a multiple of `2^depth`.
    padded_m: usize,
    padded_k: usize,
}

impl StrassenActivations {
    /// The recursion depth the combinations were registered for.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// The schedule the combinations were formed under.
    pub fn algo(&self) -> StrassenAlgo {
        self.algo
    }

    /// Batch members per leaf combination.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// The registered leaf combinations (`7^depth` groups of `batch`
    /// handles, or 1 group at depth 0), in recursion visit order.
    pub fn leaf_handles(&self) -> &[Vec<ActivationHandle>] {
        &self.handles
    }

    /// Drop every registered combination (cached packs freed; in-flight
    /// work is unaffected). Sweeps the whole list even when one handle
    /// fails, so a partial failure never leaks the remainder.
    pub fn unregister(self, server: &JobServer) -> anyhow::Result<()> {
        server.unregister_all_a(self.handles.into_iter().flatten())
    }
}

/// [`register_activations_with`] under the default schedule.
pub fn register_activations(
    server: &JobServer,
    a_list: &[Matrix],
    depth: usize,
) -> anyhow::Result<StrassenActivations> {
    register_activations_with(server, a_list, depth, StrassenAlgo::default())
}

/// Form and register the A-side combination tree of a whole batch at
/// `depth` under `algo` — the Strassen activation-load step, dual to
/// [`register_weights_with`]. The combinations are built with the same
/// row-streamed add/sub kernels the recursion uses, so a registered run
/// is bit-identical to an inline one. `depth = 0` registers each member
/// itself.
pub fn register_activations_with(
    server: &JobServer,
    a_list: &[Matrix],
    depth: usize,
    algo: StrassenAlgo,
) -> anyhow::Result<StrassenActivations> {
    anyhow::ensure!(!a_list.is_empty(), "empty batch");
    let (m, k) = (a_list[0].rows, a_list[0].cols);
    anyhow::ensure!(
        a_list.iter().all(|a| (a.rows, a.cols) == (m, k)),
        "batch members must share one shape"
    );
    anyhow::ensure!(m > 0 && k > 0, "degenerate A {m}x{k}");
    anyhow::ensure!(
        depth <= (m.ilog2().min(k.ilog2())) as usize,
        "depth {depth} too deep for a {m}x{k} A (each level halves both dims)"
    );
    let mut handles = Vec::new();
    let (padded_m, padded_k) = if depth == 0 {
        let group = a_list
            .iter()
            .map(|a| server.register_a(a.clone()))
            .collect::<anyhow::Result<Vec<_>>>()?;
        handles.push(group);
        (m, k)
    } else {
        let align = 1usize << depth;
        let (mp, kp) = (m.next_multiple_of(align), k.next_multiple_of(align));
        let aps: Vec<Matrix> = a_list.iter().map(|a| a.pad_to(mp, kp)).collect();
        collect_a_combos(server, &aps, depth, algo, &mut handles)?;
        (mp, kp)
    };
    Ok(StrassenActivations {
        handles,
        depth,
        algo,
        batch: a_list.len(),
        m,
        k,
        padded_m,
        padded_k,
    })
}

/// Register the `7^depth_left` leaf combinations of every member under
/// `a_list`, pre-order (combination j's subtree fully before
/// combination j+1's) — exactly the order [`collect_b_combos`] uses, so
/// [`node_bi_registered`] walks both lists with one cursor.
fn collect_a_combos(
    server: &JobServer,
    a_list: &[Matrix],
    depth_left: usize,
    algo: StrassenAlgo,
    handles: &mut Vec<Vec<ActivationHandle>>,
) -> anyhow::Result<()> {
    let batch = a_list.len();
    let mut combos: Vec<Vec<Matrix>> = (0..7).map(|_| Vec::with_capacity(batch)).collect();
    let mut scratch = ScratchArena::new();
    let mut stats = CombineStats::default();
    for a in a_list {
        for (j, combo) in form_side(a_schedule(algo), a, &mut scratch, &mut stats)
            .into_iter()
            .enumerate()
        {
            combos[j].push(combo);
        }
    }
    for group in combos {
        if depth_left == 1 {
            let hs = group
                .into_iter()
                .map(|g| server.register_a(g))
                .collect::<anyhow::Result<Vec<_>>>()?;
            handles.push(hs);
        } else {
            collect_a_combos(server, &group, depth_left - 1, algo, handles)?;
        }
    }
    Ok(())
}

/// Batched Strassen with **both sides pre-registered**: every leaf GEMM
/// pairs a registered A combination ([`StrassenActivations`]) with its
/// registered B combination ([`StrassenWeights`]) — the recursion forms
/// no operands and, once each `(handle, S)` variant is warm, packs
/// nothing on either side. This is the cache-hot serving shape for
/// re-running one activation batch (an attention block's token batch,
/// an im2col window set) against resident weights.
///
/// Both sides must have been registered under the same depth **and the
/// same schedule** — a Winograd A-side combination paired with a classic
/// B handle would compute garbage, so the mismatch is rejected up
/// front. Results are bit-identical to [`multiply_batched_registered`]
/// over the same `a_list`: the registered combinations were built by
/// the same forming kernels, and packed layout does not depend on
/// residency.
pub fn multiply_batched_bi_registered(
    server: &JobServer,
    acts: &StrassenActivations,
    weights: &StrassenWeights,
    run: Option<RunConfig>,
) -> anyhow::Result<BatchedStrassenReport> {
    anyhow::ensure!(
        acts.depth == weights.depth,
        "depth mismatch: activations registered at {}, weights at {}",
        acts.depth,
        weights.depth
    );
    anyhow::ensure!(
        acts.algo == weights.algo,
        "schedule mismatch: activations formed under {}, weights under {}",
        acts.algo.name(),
        weights.algo.name()
    );
    anyhow::ensure!(
        acts.k == weights.k,
        "contraction mismatch: registered A K = {}, registered B K = {}",
        acts.k,
        weights.k
    );
    if let Some(run) = run {
        run.validate(server.hw())?;
    }
    let depth = acts.depth;

    let mut ctx = Ctx {
        server,
        arena: ScratchArena::new(),
        run,
        algo: weights.algo,
        leaf_gemms: 0,
        leaf_groups: 0,
        level_nodes: vec![0; depth],
        level_spawns: vec![0; depth],
        combine: CombineStats::default(),
    };
    ctx.arena.reset_stats();

    let (cs, padded) = if depth == 0 {
        let many_a: Vec<AOperand> =
            acts.handles[0].iter().map(|&h| AOperand::from(h)).collect();
        let results = server
            .submit_blocking(Submission::batched(weights.handles[0], many_a).run(run))?;
        ctx.leaf_groups = 1;
        ctx.leaf_gemms = acts.batch as u64;
        let cs = results.into_iter().map(|r| r.c).collect();
        (cs, (acts.m, acts.k, weights.n))
    } else {
        let (mp, kp, np) = (acts.padded_m, acts.padded_k, weights.padded_n);
        debug_assert_eq!(kp, weights.padded_k, "equal K and depth pad identically");
        let mut cursor = 0usize;
        let cps = node_bi_registered(&mut ctx, mp, np, depth, 0, acts, weights, &mut cursor)?;
        debug_assert_eq!(cursor, weights.handles.len(), "every leaf combo consumed");
        let cs = cps
            .into_iter()
            .map(|cp| {
                let c = cp.block(0, 0, acts.m, weights.n);
                ctx.arena.put(cp);
                c
            })
            .collect();
        (cs, (mp, kp, np))
    };

    Ok(BatchedStrassenReport {
        cs,
        depth,
        algo: weights.algo,
        leaf_groups: ctx.leaf_groups,
        leaf_gemms: ctx.leaf_gemms,
        level_nodes: ctx.level_nodes,
        level_spawns: ctx.level_spawns,
        combine: ctx.combine,
        padded,
        model: None,
        arena: ctx.arena.stats(),
    })
}

/// One batched recursion node with both sides registered
/// (`depth_left >= 1`; `m`/`n` = this node's C dims, both even). The
/// node carries no operand data at all — both sides are consumed as
/// handles in registration (pre-)order via the shared `cursor`.
#[allow(clippy::too_many_arguments)]
fn node_bi_registered(
    ctx: &mut Ctx<'_>,
    m: usize,
    n: usize,
    depth_left: usize,
    level: usize,
    acts: &StrassenActivations,
    weights: &StrassenWeights,
    cursor: &mut usize,
) -> anyhow::Result<Vec<Matrix>> {
    let batch = acts.batch;
    debug_assert!(m % 2 == 0 && n % 2 == 0, "node dims must be even");
    let (m2, n2) = (m / 2, n / 2);
    ctx.level_nodes[level] += 1;
    ctx.level_spawns[level] += 7;
    ctx.combine.nodes += 1;

    // ms[j][member] = combination j's product for that member.
    let ms: Vec<Vec<Matrix>> = if depth_left == 1 {
        // Submit all 7 fully-registered groups before waiting on any.
        let mut groups = Vec::with_capacity(7);
        for _ in 0..7 {
            let wh = weights.handles[*cursor];
            let many_a: Vec<AOperand> =
                acts.handles[*cursor].iter().map(|&h| AOperand::from(h)).collect();
            *cursor += 1;
            groups.push(ctx.server.submit_async(Submission::batched(wh, many_a).run(ctx.run))?);
        }
        ctx.leaf_groups += 7;
        ctx.leaf_gemms += 7 * batch as u64;
        let mut ms = Vec::with_capacity(7);
        for g in groups {
            let results = g.wait()?;
            let mut per_member = Vec::with_capacity(batch);
            for r in results {
                anyhow::ensure!(
                    (r.c.rows, r.c.cols) == (m2, n2),
                    "leaf {} returned {}x{}, expected {m2}x{n2}",
                    r.id,
                    r.c.rows,
                    r.c.cols
                );
                per_member.push(r.c);
            }
            ms.push(per_member);
        }
        ms
    } else {
        let mut ms = Vec::with_capacity(7);
        for _ in 0..7 {
            ms.push(node_bi_registered(
                ctx,
                m2,
                n2,
                depth_left - 1,
                level + 1,
                acts,
                weights,
                cursor,
            )?);
        }
        ms
    };

    Ok(combine_members(ctx, ms, batch, m, n))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareConfig;
    use crate::coordinator::{NumericsEngine, ServerConfig};

    fn server() -> JobServer {
        let cfg = ServerConfig {
            workers: 4,
            queue_capacity: 16,
            batch_max_tasks: 4,
            batch_window: 4,
            cross_job_stealing: true,
            default_run: Some(RunConfig::square(2, 16)),
            ..ServerConfig::default()
        };
        JobServer::new(HardwareConfig::paper(), NumericsEngine::golden(), cfg).unwrap()
    }

    fn cfg_depth(d: usize) -> StrassenConfig {
        StrassenConfig {
            cutoff: Cutoff::Depth(d),
            run: Some(RunConfig::square(2, 16)),
            ..StrassenConfig::default()
        }
    }

    #[test]
    fn half_precision_leaves_track_oracle_and_f32_is_default() {
        let srv = server();
        let a = Matrix::random(32, 24, 60);
        let b = Matrix::random(24, 40, 61);
        let base = multiply(&srv, &a, &b, &cfg_depth(1)).unwrap();
        let f32v = multiply(
            &srv,
            &a,
            &b,
            &StrassenConfig { dtype: Dtype::F32, ..cfg_depth(1) },
        )
        .unwrap();
        assert_eq!(base.c.data, f32v.c.data, "explicit F32 must be the default path");
        // Half-precision leaves: the fused packer quantizes `X ± Y` at
        // the leaf dtype, leaves accumulate in f32, and the combine
        // phase folds full-width partials — the recursion stays within
        // a few units of the per-leaf bound of the oracle.
        let oracle = a.matmul(&b);
        for (dtype, tol) in [(Dtype::F16, 2e-2), (Dtype::Bf16, 1.5e-1)] {
            let r = multiply(
                &srv,
                &a,
                &b,
                &StrassenConfig { dtype, ..cfg_depth(1) },
            )
            .unwrap();
            assert_eq!(r.leaf_gemms, 7);
            assert!(oracle.allclose(&r.c, tol), "{dtype} recursion must track the oracle");
        }
    }

    #[test]
    fn one_level_matches_oracle_even_dims() {
        let srv = server();
        let a = Matrix::random(32, 24, 1);
        let b = Matrix::random(24, 40, 2);
        let r = multiply(&srv, &a, &b, &cfg_depth(1)).unwrap();
        assert_eq!(r.depth, 1);
        assert_eq!(r.algo, StrassenAlgo::Winograd);
        assert_eq!(r.leaf_gemms, 7);
        assert_eq!(r.level_nodes, vec![1]);
        assert!((r.fanout(0) - 7.0).abs() < 1e-12);
        assert!(r.model.is_none(), "forced depth must not pay for the model sweep");
        // Winograd node: 4 + 4 operand ops + 7 C-side ops; only S1/S2,
        // S5/S6 and t1/t2 hit memory, 10 of 14 operand temps fused away.
        assert_eq!(r.combine.nodes, 1);
        assert_eq!(r.combine.combine_ops, 15);
        assert_eq!(r.combine.temps_materialized, 6);
        assert_eq!(r.combine.temps_avoided, 10);
        assert!(r.c.allclose(&a.matmul(&b), 1e-4));
    }

    #[test]
    fn classic_depth1_counts_and_matches_winograd() {
        let srv = server();
        let a = Matrix::random(32, 24, 21);
        let b = Matrix::random(24, 40, 22);
        let classic = StrassenConfig { algo: StrassenAlgo::Classic, ..cfg_depth(1) };
        let rc = multiply(&srv, &a, &b, &classic).unwrap();
        assert_eq!(rc.algo, StrassenAlgo::Classic);
        // Classic node: 5 + 5 operand ops + 8 C-side ops; at a fused
        // leaf no schedule step feeds another, so nothing hits memory.
        assert_eq!(rc.combine.combine_ops, 18);
        assert_eq!(rc.combine.temps_materialized, 0);
        assert_eq!(rc.combine.temps_avoided, 14);
        let rw = multiply(&srv, &a, &b, &cfg_depth(1)).unwrap();
        assert_eq!(rw.combine.combine_ops, 15);
        assert!(
            rw.combine.combine_ops < rc.combine.combine_ops,
            "Winograd must save combine ops"
        );
        let oracle = a.matmul(&b);
        assert!(rc.c.allclose(&oracle, 1e-3));
        assert!(rw.c.allclose(&oracle, 1e-3));
        assert!(rc.c.allclose(&rw.c, 1e-3), "the two schedules agree within tolerance");
    }

    #[test]
    fn parallel_matches_sequential_bit_for_bit() {
        let srv = server();
        let a = Matrix::random(40, 36, 31);
        let b = Matrix::random(36, 44, 32);
        let seq =
            multiply(&srv, &a, &b, &StrassenConfig { parallel: false, ..cfg_depth(2) }).unwrap();
        let par = multiply(&srv, &a, &b, &cfg_depth(2)).unwrap();
        assert_eq!(par.c.data, seq.c.data, "parallel walk must be bit-identical");
        assert_eq!(par.level_nodes, seq.level_nodes);
        assert_eq!(par.level_spawns, seq.level_spawns);
        assert_eq!(par.combine, seq.combine, "merged counters match the serial walk");
        let again = multiply(&srv, &a, &b, &cfg_depth(2)).unwrap();
        assert_eq!(par.c.data, again.c.data, "parallel runs must be deterministic");
    }

    #[test]
    fn fused_leaves_count_fused_packs() {
        let srv = server();
        let a = Matrix::random(32, 24, 41);
        let b = Matrix::random(24, 40, 42);
        let r = multiply(&srv, &a, &b, &cfg_depth(1)).unwrap();
        assert!(r.c.allclose(&a.matmul(&b), 1e-4));
        assert_eq!(srv.metrics().fused_packs(), 14, "7 leaf jobs x 2 fused sides");
    }

    #[test]
    fn odd_dims_are_padded_even() {
        let srv = server();
        let a = Matrix::random(33, 17, 3);
        let b = Matrix::random(17, 29, 4);
        let r = multiply(&srv, &a, &b, &cfg_depth(1)).unwrap();
        assert_eq!(r.padded, (34, 18, 30));
        assert_eq!((r.c.rows, r.c.cols), (33, 29));
        assert!(r.c.allclose(&a.matmul(&b), 1e-4));
    }

    #[test]
    fn depth_zero_is_one_direct_job() {
        let srv = server();
        let a = Matrix::random(20, 12, 5);
        let b = Matrix::random(12, 16, 6);
        let r = multiply(&srv, &a, &b, &cfg_depth(0)).unwrap();
        assert_eq!((r.depth, r.leaf_gemms), (0, 1));
        assert_eq!(r.padded, (20, 12, 16));
        assert_eq!(r.combine, CombineStats::default(), "no recursion, no combines");
        assert!(r.c.allclose(&a.matmul(&b), 1e-4));
    }

    #[test]
    fn forced_depth_clamped_by_shape() {
        let srv = server();
        let a = Matrix::random(3, 5, 7);
        let b = Matrix::random(5, 2, 8);
        // ilog2(2) = 1 caps the recursion regardless of the request.
        let r = multiply(&srv, &a, &b, &cfg_depth(6)).unwrap();
        assert_eq!(r.depth, 1);
        assert!(r.c.allclose(&a.matmul(&b), 1e-4));
        // A 1-dim shape cannot recurse at all.
        let a1 = Matrix::random(1, 4, 9);
        let b1 = Matrix::random(4, 4, 10);
        let r1 = multiply(&srv, &a1, &b1, &cfg_depth(3)).unwrap();
        assert_eq!(r1.depth, 0);
        assert!(r1.c.allclose(&a1.matmul(&b1), 1e-4));
    }

    #[test]
    fn model_cutoff_runs_small_problems_direct() {
        let srv = server();
        let a = Matrix::random(64, 64, 11);
        let b = Matrix::random(64, 64, 12);
        let cfg = StrassenConfig {
            cutoff: Cutoff::Model,
            run: Some(RunConfig::square(2, 16)),
            ..StrassenConfig::default()
        };
        let r = multiply(&srv, &a, &b, &cfg).unwrap();
        assert_eq!(r.depth, 0, "64^3 is far below the modeled crossover");
        assert_eq!(r.model.as_ref().unwrap().depth, 0);
        assert_eq!(r.model.as_ref().unwrap().algo, StrassenAlgo::Winograd);
        assert!(r.c.allclose(&a.matmul(&b), 1e-4));
    }

    #[test]
    fn two_levels_recombine_and_reuse_the_arena() {
        let srv = server();
        let a = Matrix::random(40, 36, 13);
        let b = Matrix::random(36, 44, 14);
        let r = multiply(&srv, &a, &b, &cfg_depth(2)).unwrap();
        assert_eq!(r.depth, 2);
        assert_eq!(r.leaf_gemms, 49);
        assert_eq!(r.level_nodes, vec![1, 7]);
        assert_eq!(r.level_spawns, vec![7, 49]);
        // 8 Winograd nodes at 15 ops each; the interior node writes 16
        // temps (4 steps + 3 quadrant copies per side, plus t1/t2) and
        // each of the 7 fused leaves writes 6.
        assert_eq!(r.combine.nodes, 8);
        assert_eq!(r.combine.combine_ops, 120);
        assert!((r.combine.ops_per_node() - 15.0).abs() < 1e-12);
        assert_eq!(r.combine.temps_materialized, 16 + 7 * 6);
        assert_eq!(r.combine.temps_avoided, 70);
        assert!(r.c.allclose(&a.matmul(&b), 1e-3));
        assert!(r.arena.reuses > 0, "deep recursion must recycle buffers");
    }

    #[test]
    fn mismatched_operands_rejected() {
        let srv = server();
        let a = Matrix::random(8, 8, 15);
        let b = Matrix::random(9, 8, 16);
        assert!(multiply(&srv, &a, &b, &cfg_depth(1)).is_err());
    }

    #[test]
    fn batched_depth1_packs_each_b_combo_once() {
        let srv = server();
        let b = Matrix::random(24, 40, 100);
        let a_list: Vec<Matrix> = (0..3u64).map(|i| Matrix::random(32, 24, 101 + i)).collect();
        let r = multiply_batched(&srv, &a_list, &b, &cfg_depth(1)).unwrap();
        assert_eq!(r.depth, 1);
        assert_eq!(r.algo, StrassenAlgo::Winograd);
        assert_eq!(r.leaf_groups, 7, "one shared-B group per combination");
        assert_eq!(r.leaf_gemms, 21);
        assert_eq!(r.level_nodes, vec![1]);
        for (a, c) in a_list.iter().zip(&r.cs) {
            assert!(c.allclose(&a.matmul(&b), 1e-4));
        }
        // The reuse the batched recursion exists for: each of the 7 B
        // combinations packed once, (batch-1) packs avoided apiece.
        let m = srv.metrics();
        assert_eq!(m.b_panel_packs(), 7);
        assert_eq!(m.panels_shared(), 7 * (3 - 1));
        assert_eq!(m.a_panel_packs(), 21);
        assert_eq!(m.shared_b_groups(), 7);
    }

    #[test]
    fn batched_matches_single_member_multiply_bit_for_bit() {
        // Same schedule, same combine kernels, same leaf accumulation
        // order: the shared-B recursion must agree with the per-member
        // planner exactly, not just approximately — even though the
        // batched side materializes operands the fused leaves stream.
        let srv = server();
        let b = Matrix::random(36, 44, 110);
        let a_list: Vec<Matrix> = (0..2u64).map(|i| Matrix::random(40, 36, 111 + i)).collect();
        let batched = multiply_batched(&srv, &a_list, &b, &cfg_depth(2)).unwrap();
        assert_eq!(batched.depth, 2);
        assert_eq!(batched.leaf_groups, 49);
        assert_eq!(batched.level_nodes, vec![1, 7]);
        assert_eq!(batched.level_spawns, vec![7, 49]);
        for (a, c) in a_list.iter().zip(&batched.cs) {
            let single = multiply(&srv, a, &b, &cfg_depth(2)).unwrap();
            assert_eq!(c.data, single.c.data, "batched member diverged from single run");
        }
        assert!(batched.arena.reuses > 0);
    }

    #[test]
    fn batched_depth0_is_one_shared_group() {
        let srv = server();
        let b = Matrix::random(12, 16, 120);
        let a_list: Vec<Matrix> = (0..4u64).map(|i| Matrix::random(20, 12, 121 + i)).collect();
        let r = multiply_batched(&srv, &a_list, &b, &cfg_depth(0)).unwrap();
        assert_eq!((r.depth, r.leaf_groups, r.leaf_gemms), (0, 1, 4));
        assert_eq!(r.padded, (20, 12, 16));
        for (a, c) in a_list.iter().zip(&r.cs) {
            assert!(c.allclose(&a.matmul(&b), 1e-4));
        }
        assert_eq!(srv.metrics().b_panel_packs(), 1);
        assert_eq!(srv.metrics().panels_shared(), 3);
    }

    #[test]
    fn batched_odd_dims_padded_and_clipped() {
        let srv = server();
        let b = Matrix::random(17, 29, 130);
        let a_list: Vec<Matrix> = (0..2u64).map(|i| Matrix::random(33, 17, 131 + i)).collect();
        let r = multiply_batched(&srv, &a_list, &b, &cfg_depth(1)).unwrap();
        assert_eq!(r.padded, (34, 18, 30));
        for (a, c) in a_list.iter().zip(&r.cs) {
            assert_eq!((c.rows, c.cols), (33, 29));
            assert!(c.allclose(&a.matmul(&b), 1e-4));
        }
    }

    #[test]
    fn registered_weights_reused_across_recursions() {
        // Repeated batched recursions over one registered B: the 7
        // combos pack once on the first run and are pure cache hits on
        // every later one — and repeat results stay bit-identical.
        let srv = server();
        let b = Matrix::random(24, 40, 150);
        let a_list: Vec<Matrix> =
            (0..2u64).map(|i| Matrix::random(32, 24, 151 + i)).collect();
        let weights = register_weights(&srv, &b, 1).unwrap();
        assert_eq!(weights.depth(), 1);
        assert_eq!(weights.algo(), StrassenAlgo::Winograd);
        assert_eq!(weights.leaf_handles().len(), 7);
        let run = Some(RunConfig::square(2, 16));
        let first = multiply_batched_registered(&srv, &a_list, &weights, run).unwrap();
        assert!(first.model.is_none());
        assert_eq!((first.depth, first.leaf_groups, first.leaf_gemms), (1, 7, 14));
        assert_eq!(first.algo, StrassenAlgo::Winograd);
        let second = multiply_batched_registered(&srv, &a_list, &weights, run).unwrap();
        for ((a, c1), c2) in a_list.iter().zip(&first.cs).zip(&second.cs) {
            assert!(c1.allclose(&a.matmul(&b), 1e-4));
            assert_eq!(c1.data, c2.data, "repeat run must be bit-identical");
        }
        let m = srv.metrics();
        assert_eq!(m.b_panel_packs(), 7, "7 combos packed once across both runs");
        assert_eq!(m.registry_misses(), 7);
        assert_eq!(m.registry_hits(), 7, "second run is pure cache hits");
        weights.unregister(&srv).unwrap();
        assert_eq!(srv.stats().registered_weights, 0);
        // Depth guard: weights registered at depth 1 reject a batch
        // whose M cannot halve.
        let tiny = vec![Matrix::random(1, 24, 160)];
        let w1 = register_weights(&srv, &b, 1).unwrap();
        assert!(multiply_batched_registered(&srv, &tiny, &w1, None).is_err());
        w1.unregister(&srv).unwrap();
        // And registration itself rejects depths B cannot halve to.
        assert!(register_weights(&srv, &Matrix::random(2, 2, 161), 2).is_err());
    }

    #[test]
    fn registered_algos_must_agree_across_sides() {
        let srv = server();
        let b = Matrix::random(24, 40, 200);
        let a_list: Vec<Matrix> =
            (0..2u64).map(|i| Matrix::random(32, 24, 201 + i)).collect();
        // Classic weights drive a classic recursion end to end...
        let wc = register_weights_with(&srv, &b, 1, StrassenAlgo::Classic).unwrap();
        assert_eq!(wc.algo(), StrassenAlgo::Classic);
        let run = Some(RunConfig::square(2, 16));
        let r = multiply_batched_registered(&srv, &a_list, &wc, run).unwrap();
        assert_eq!(r.algo, StrassenAlgo::Classic);
        assert_eq!(r.combine.combine_ops, 2 * (5 + 8), "2 members x (5 A-side + 8 C-side ops)");
        for (a, c) in a_list.iter().zip(&r.cs) {
            assert!(c.allclose(&a.matmul(&b), 1e-4));
        }
        // ...and a bi-registered run rejects mixed schedules up front.
        let aw = register_activations_with(&srv, &a_list, 1, StrassenAlgo::Winograd).unwrap();
        assert!(multiply_batched_bi_registered(&srv, &aw, &wc, run).is_err());
        aw.unregister(&srv).unwrap();
        wc.unregister(&srv).unwrap();
    }

    #[test]
    fn bi_registered_leaves_reuse_activation_packs() {
        // Registering the A side too: the 7 x batch activation combos
        // pack once on the first bi-registered run, and a repeat run
        // packs nothing on either side — bit-identical throughout.
        let srv = server();
        let b = Matrix::random(24, 40, 170);
        let a_list: Vec<Matrix> =
            (0..2u64).map(|i| Matrix::random(32, 24, 171 + i)).collect();
        let weights = register_weights(&srv, &b, 1).unwrap();
        let run = Some(RunConfig::square(2, 16));
        let inline = multiply_batched_registered(&srv, &a_list, &weights, run).unwrap();
        let acts = register_activations(&srv, &a_list, 1).unwrap();
        assert_eq!((acts.depth(), acts.batch()), (1, 2));
        assert_eq!(acts.algo(), StrassenAlgo::Winograd);
        assert_eq!(acts.leaf_handles().len(), 7);
        let m = srv.metrics();
        let packs_before = m.a_panel_packs();
        assert_eq!(packs_before, 14, "inline run packed A privately per leaf GEMM");
        let first = multiply_batched_bi_registered(&srv, &acts, &weights, run).unwrap();
        assert_eq!((first.depth, first.leaf_groups, first.leaf_gemms), (1, 7, 14));
        for (c1, c2) in inline.cs.iter().zip(&first.cs) {
            assert_eq!(c1.data, c2.data, "registered-A leaves must be bit-identical");
        }
        assert_eq!(m.a_panel_packs() - packs_before, 14, "7 combos x 2 members, packed once");
        assert_eq!(m.registry_a_misses(), 14);
        let second = multiply_batched_bi_registered(&srv, &acts, &weights, run).unwrap();
        for (c1, c2) in first.cs.iter().zip(&second.cs) {
            assert_eq!(c1.data, c2.data, "repeat run must be bit-identical");
        }
        assert_eq!(m.a_panel_packs() - packs_before, 14, "repeat run packed nothing");
        assert_eq!(m.registry_a_hits(), 14, "second run is pure A-side cache hits");
        acts.unregister(&srv).unwrap();
        weights.unregister(&srv).unwrap();
        let stats = srv.stats();
        assert_eq!((stats.registered_activations, stats.registered_weights), (0, 0));
        // Depth mismatch between the two sides is rejected up front.
        let w0 = register_weights(&srv, &b, 0).unwrap();
        let a1 = register_activations(&srv, &a_list, 1).unwrap();
        assert!(multiply_batched_bi_registered(&srv, &a1, &w0, run).is_err());
        a1.unregister(&srv).unwrap();
        w0.unregister(&srv).unwrap();
    }

    #[test]
    fn bi_registered_depth_zero_and_validation() {
        let srv = server();
        let b = Matrix::random(12, 16, 180);
        let a_list: Vec<Matrix> = (0..3u64).map(|i| Matrix::random(20, 12, 181 + i)).collect();
        let weights = register_weights(&srv, &b, 0).unwrap();
        let acts = register_activations(&srv, &a_list, 0).unwrap();
        assert_eq!(acts.leaf_handles().len(), 1);
        assert_eq!(acts.leaf_handles()[0].len(), 3);
        let r = multiply_batched_bi_registered(&srv, &acts, &weights, None).unwrap();
        assert_eq!((r.depth, r.leaf_groups, r.leaf_gemms), (0, 1, 3));
        for (a, c) in a_list.iter().zip(&r.cs) {
            assert!(c.allclose(&a.matmul(&b), 1e-4));
        }
        acts.unregister(&srv).unwrap();
        weights.unregister(&srv).unwrap();
        // Registration validation: ragged batches, empty batches, and
        // over-deep requests are rejected.
        assert!(register_activations(&srv, &[], 0).is_err());
        let ragged = vec![Matrix::random(4, 4, 190), Matrix::random(4, 6, 191)];
        assert!(register_activations(&srv, &ragged, 0).is_err());
        assert!(register_activations(&srv, &[Matrix::random(2, 2, 192)], 2).is_err());
        // Contraction mismatch across registered sides.
        let w = register_weights(&srv, &Matrix::random(8, 8, 193), 0).unwrap();
        let a = register_activations(&srv, &[Matrix::random(4, 6, 194)], 0).unwrap();
        assert!(multiply_batched_bi_registered(&srv, &a, &w, None).is_err());
        a.unregister(&srv).unwrap();
        w.unregister(&srv).unwrap();
    }

    #[test]
    fn batched_rejects_ragged_batches_and_mismatches() {
        let srv = server();
        let b = Matrix::random(8, 8, 140);
        assert!(multiply_batched(&srv, &[], &b, &cfg_depth(1)).is_err());
        let ragged = vec![Matrix::random(8, 8, 141), Matrix::random(10, 8, 142)];
        assert!(multiply_batched(&srv, &ragged, &b, &cfg_depth(1)).is_err());
        let mismatched = vec![Matrix::random(8, 9, 143)];
        assert!(multiply_batched(&srv, &mismatched, &b, &cfg_depth(1)).is_err());
    }

    #[test]
    fn invalid_pinned_run_rejected_before_any_submit() {
        let srv = server();
        let a = Matrix::random(8, 8, 17);
        let b = Matrix::random(8, 8, 18);
        let cfg = StrassenConfig {
            cutoff: Cutoff::Depth(1),
            run: Some(RunConfig::square(4, 256)),
            ..StrassenConfig::default()
        };
        assert!(multiply(&srv, &a, &b, &cfg).is_err());
    }
}
