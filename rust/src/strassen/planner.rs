//! The recursive Strassen planner: quadrant split, 7-way sub-product
//! fan-out through the [`JobServer`], combine from the scratch arena.
//!
//! One recursion node computes `C = A x B` (all dimensions even, kept
//! divisible by `2^depth` by the top-level padding) as:
//!
//! ```text
//! M1 = (A11 + A22)(B11 + B22)    C11 = M1 + M4 - M5 + M7
//! M2 = (A21 + A22) B11           C12 = M3 + M5
//! M3 =  A11 (B12 - B22)          C21 = M2 + M4
//! M4 =  A22 (B21 - B11)          C22 = M1 - M2 + M3 + M6
//! M5 = (A11 + A12) B22
//! M6 = (A21 - A11)(B11 + B12)
//! M7 = (A12 - A22)(B21 + B22)
//! ```
//!
//! 7 sub-products per node instead of the direct split's 8. At the leaf
//! level all 7 are submitted to the server as one job group, so the
//! pool's cross-job stealing load-balances the fan-out; above the leaf
//! the planner recurses depth-first. Temporaries and results cycle
//! through the node-local [`ScratchArena`].

use crate::analytical::{strassen_crossover, CrossoverPlan};
use crate::config::RunConfig;
use crate::coordinator::{
    ActivationHandle, AOperand, GemmJob, JobServer, SpanKind, Submission, WeightHandle,
};
use crate::gemm::{ops, Matrix, MatrixView};

use super::arena::{ArenaStats, ScratchArena};

/// Children a *direct* quadrant split would spawn per node — the figure
/// Strassen's 7 is measured against.
pub const DIRECT_SPLIT_FANOUT: u64 = 8;

/// How the recursion depth is chosen.
#[derive(Debug, Clone, Copy)]
pub enum Cutoff {
    /// Ask [`strassen_crossover`]: recurse while the model says
    /// `7·T(n/2) + combine` beats the direct multi-array time.
    Model,
    /// Force exactly this many levels (clamped so no padded leaf
    /// dimension collapses below 1 — tests use this to exercise
    /// multi-level recombination on small problems).
    Depth(usize),
}

/// Planner knobs.
#[derive(Debug, Clone, Copy)]
pub struct StrassenConfig {
    pub cutoff: Cutoff,
    /// Pinned run config for the leaf GEMMs; `None` lets the server
    /// plan each leaf (server default or per-job DSE).
    pub run: Option<RunConfig>,
}

impl Default for StrassenConfig {
    fn default() -> Self {
        Self { cutoff: Cutoff::Model, run: None }
    }
}

/// What a Strassen run reports besides the product itself.
#[derive(Debug)]
pub struct StrassenReport {
    pub c: Matrix,
    /// Recursion levels actually executed (0 = ran direct).
    pub depth: usize,
    /// GEMMs submitted to the server (`7^depth`).
    pub leaf_gemms: u64,
    /// Recursion nodes per level (`level_nodes[i]` = nodes at level i).
    pub level_nodes: Vec<u64>,
    /// Sub-multiplies spawned per level, measured by counting at each
    /// node (not assumed).
    pub level_spawns: Vec<u64>,
    /// Operand shapes after top-level padding to a multiple of
    /// `2^depth` (equals the input shape when depth = 0).
    pub padded: (usize, usize, usize),
    /// The analytical model's verdict, present only when the cutoff was
    /// [`Cutoff::Model`] (forced-depth runs skip the sweep; call
    /// [`strassen_crossover`] directly to compare against a forced run).
    pub model: Option<CrossoverPlan>,
    pub arena: ArenaStats,
}

impl StrassenReport {
    /// Measured sub-multiplies per node at `level` — 7.0 on every
    /// executed Strassen level (vs [`DIRECT_SPLIT_FANOUT`]).
    pub fn fanout(&self, level: usize) -> f64 {
        match self.level_nodes.get(level) {
            Some(&nodes) if nodes > 0 => self.level_spawns[level] as f64 / nodes as f64,
            _ => 0.0,
        }
    }
}

/// Deepest recursion the shape admits: each level halves every padded
/// dimension, so `2^depth` may not exceed any of them.
fn depth_cap(m: usize, k: usize, n: usize) -> usize {
    (m.ilog2().min(k.ilog2()).min(n.ilog2())) as usize
}

struct Ctx<'s> {
    server: &'s JobServer,
    arena: ScratchArena,
    run: Option<RunConfig>,
    next_id: u64,
    leaf_gemms: u64,
    /// Shared-B leaf groups submitted (batched recursion only; each
    /// packs its B combination exactly once for the whole batch).
    leaf_groups: u64,
    level_nodes: Vec<u64>,
    level_spawns: Vec<u64>,
}

impl Ctx<'_> {
    fn fresh_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }
}

/// One operand combination to materialize from quadrant views.
#[derive(Clone, Copy)]
enum Combo<'v> {
    Copy(MatrixView<'v>),
    Add(MatrixView<'v>, MatrixView<'v>),
    Sub(MatrixView<'v>, MatrixView<'v>),
}

/// Stream one operand combination into `ov` — the single copy of the
/// `Combo` → add/sub/copy kernel dispatch (the in-recursion
/// [`materialize`] and the registration-time [`collect_b_combos`] must
/// form bit-identical values, so they share it).
fn fill_combo(ov: &mut crate::gemm::MatrixViewMut<'_>, combo: Combo<'_>) {
    match combo {
        Combo::Copy(x) => ops::copy_into(x, ov),
        Combo::Add(x, y) => ops::add_into(x, y, ov),
        Combo::Sub(x, y) => ops::sub_into(x, y, ov),
    }
}

fn materialize(arena: &mut ScratchArena, rows: usize, cols: usize, combo: Combo<'_>) -> Matrix {
    let mut out = arena.take(rows, cols);
    fill_combo(&mut out.view_mut(), combo);
    out
}

/// Compute `C = A x B` through the Strassen planner on `server`.
///
/// The recursion depth is `cfg.cutoff` (model-chosen by default),
/// clamped by the shape; `depth = 0` degrades to one direct server job,
/// the model's own verdict for sub-crossover problems.
pub fn multiply(
    server: &JobServer,
    a: &Matrix,
    b: &Matrix,
    cfg: &StrassenConfig,
) -> anyhow::Result<StrassenReport> {
    anyhow::ensure!(a.cols == b.rows, "contraction mismatch");
    anyhow::ensure!(
        a.rows > 0 && a.cols > 0 && b.cols > 0,
        "degenerate problem {}x{}x{}",
        a.rows,
        a.cols,
        b.cols
    );
    if let Some(run) = cfg.run {
        run.validate(server.hw())?;
    }
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let (model, requested) = match cfg.cutoff {
        Cutoff::Model => {
            let plan = strassen_crossover(server.hw(), m, k, n, server.surface())?;
            let depth = plan.depth;
            (Some(plan), depth)
        }
        Cutoff::Depth(d) => (None, d),
    };
    let depth = requested.min(depth_cap(m, k, n));

    let mut ctx = Ctx {
        server,
        arena: ScratchArena::new(),
        run: cfg.run,
        next_id: 0,
        leaf_gemms: 0,
        leaf_groups: 0,
        level_nodes: vec![0; depth],
        level_spawns: vec![0; depth],
    };

    let (c, padded) = if depth == 0 {
        let job =
            GemmJob { id: ctx.fresh_id(), a: a.clone().into(), b: b.clone().into(), run: cfg.run };
        let r = server.submit_async(job)?.wait_one()?;
        ctx.leaf_gemms = 1;
        (r.c, (m, k, n))
    } else {
        // Section-IV zero padding, once, up to a multiple of 2^depth:
        // every level then halves exactly and leaves stay unragged.
        let align = 1usize << depth;
        let (mp, kp, np) =
            (m.next_multiple_of(align), k.next_multiple_of(align), n.next_multiple_of(align));
        let ap = a.pad_to(mp, kp);
        let bp = b.pad_to(kp, np);
        let cp = node(&mut ctx, ap, bp, depth, 0)?;
        // Padded columns of A meet padded rows of B as exact zero
        // terms, so the real product is the top-left block.
        let c = cp.block(0, 0, m, n);
        ctx.arena.put(cp);
        (c, (mp, kp, np))
    };

    Ok(StrassenReport {
        c,
        depth,
        leaf_gemms: ctx.leaf_gemms,
        level_nodes: ctx.level_nodes,
        level_spawns: ctx.level_spawns,
        padded,
        model,
        arena: ctx.arena.stats(),
    })
}

/// One recursion node (`depth_left >= 1`; all dims even).
fn node(
    ctx: &mut Ctx<'_>,
    a: Matrix,
    b: Matrix,
    depth_left: usize,
    level: usize,
) -> anyhow::Result<Matrix> {
    let (m, k, n) = (a.rows, a.cols, b.cols);
    debug_assert!(m % 2 == 0 && k % 2 == 0 && n % 2 == 0, "node dims must be even");
    let (m2, k2, n2) = (m / 2, k / 2, n / 2);

    let mut pairs: Vec<(Matrix, Matrix)> = Vec::with_capacity(7);
    {
        let av = a.view();
        let bv = b.view();
        let a11 = av.block(0, 0, m2, k2);
        let a12 = av.block(0, k2, m2, k2);
        let a21 = av.block(m2, 0, m2, k2);
        let a22 = av.block(m2, k2, m2, k2);
        let b11 = bv.block(0, 0, k2, n2);
        let b12 = bv.block(0, n2, k2, n2);
        let b21 = bv.block(k2, 0, k2, n2);
        let b22 = bv.block(k2, n2, k2, n2);
        let specs: [(Combo<'_>, Combo<'_>); 7] = [
            (Combo::Add(a11, a22), Combo::Add(b11, b22)), // M1
            (Combo::Add(a21, a22), Combo::Copy(b11)),     // M2
            (Combo::Copy(a11), Combo::Sub(b12, b22)),     // M3
            (Combo::Copy(a22), Combo::Sub(b21, b11)),     // M4
            (Combo::Add(a11, a12), Combo::Copy(b22)),     // M5
            (Combo::Sub(a21, a11), Combo::Add(b11, b12)), // M6
            (Combo::Sub(a12, a22), Combo::Add(b21, b22)), // M7
        ];
        for (ca, cb) in specs {
            let ta = materialize(&mut ctx.arena, m2, k2, ca);
            let tb = materialize(&mut ctx.arena, k2, n2, cb);
            pairs.push((ta, tb));
        }
    }
    // Operands are fully captured in the combos; recycle them before
    // the sub-products run so children draw from the same pool.
    ctx.arena.put(a);
    ctx.arena.put(b);
    ctx.level_nodes[level] += 1;
    ctx.level_spawns[level] += 7;

    let ms: Vec<Matrix> = if depth_left == 1 {
        // Leaf level: one job group of 7 — the admission queue keeps
        // them together and cross-job stealing spreads them over the
        // pool.
        let jobs: Vec<GemmJob> = pairs
            .into_iter()
            .map(|(ta, tb)| GemmJob { id: ctx.fresh_id(), a: ta.into(), b: tb.into(), run: ctx.run })
            .collect();
        ctx.server.trace_span_begin(SpanKind::StrassenLevel, level as u64);
        let results = ctx.server.submit_blocking(Submission::group(jobs))?;
        ctx.server.trace_span_end(SpanKind::StrassenLevel, level as u64);
        ctx.leaf_gemms += 7;
        let mut ms = Vec::with_capacity(7);
        for r in results {
            anyhow::ensure!(
                (r.c.rows, r.c.cols) == (m2, n2),
                "leaf {} returned {}x{}, expected {m2}x{n2}",
                r.id,
                r.c.rows,
                r.c.cols
            );
            ms.push(r.c);
        }
        ms
    } else {
        let mut ms = Vec::with_capacity(7);
        for (ta, tb) in pairs {
            ms.push(node(ctx, ta, tb, depth_left - 1, level + 1)?);
        }
        ms
    };

    let mut c = ctx.arena.take(m, n);
    {
        let mut cv = c.view_mut();
        {
            let mut c11 = cv.block_mut(0, 0, m2, n2);
            ops::add_into(ms[0].view(), ms[3].view(), &mut c11);
            ops::acc_sub(&mut c11, ms[4].view());
            ops::acc_add(&mut c11, ms[6].view());
        }
        {
            let mut c12 = cv.block_mut(0, n2, m2, n2);
            ops::add_into(ms[2].view(), ms[4].view(), &mut c12);
        }
        {
            let mut c21 = cv.block_mut(m2, 0, m2, n2);
            ops::add_into(ms[1].view(), ms[3].view(), &mut c21);
        }
        {
            let mut c22 = cv.block_mut(m2, n2, m2, n2);
            ops::sub_into(ms[0].view(), ms[1].view(), &mut c22);
            ops::acc_add(&mut c22, ms[2].view());
            ops::acc_add(&mut c22, ms[5].view());
        }
    }
    for mi in ms {
        ctx.arena.put(mi);
    }
    Ok(c)
}

/// What a batched Strassen run reports besides the per-member products.
#[derive(Debug)]
pub struct BatchedStrassenReport {
    /// `cs[i] = a_list[i] x b`, in input order.
    pub cs: Vec<Matrix>,
    /// Recursion levels actually executed (0 = one direct shared-B
    /// group).
    pub depth: usize,
    /// Shared-B groups submitted (`7^depth`, or 1 at depth 0) — each
    /// packed its B combination exactly once for the whole batch.
    pub leaf_groups: u64,
    /// Leaf GEMMs executed (`batch · 7^depth`).
    pub leaf_gemms: u64,
    /// Recursion nodes per level (as in [`StrassenReport`]).
    pub level_nodes: Vec<u64>,
    /// Sub-multiplies spawned per level, counted at each node.
    pub level_spawns: Vec<u64>,
    /// Operand shapes after top-level padding (input shape at depth 0).
    pub padded: (usize, usize, usize),
    /// Present only under [`Cutoff::Model`].
    pub model: Option<CrossoverPlan>,
    pub arena: ArenaStats,
}

/// The B side of a batched Strassen recursion registered as
/// server-resident weights: every **leaf-level B quadrant combination**
/// (`7^depth` of them, in the recursion's visit order) lives in the
/// server's operand registry under a [`WeightHandle`]. Build once with
/// [`register_weights`], run any number of batched recursions with
/// [`multiply_batched_registered`] — repeated inference over the same
/// weight matrix resolves every combination from the cache (registry
/// hits) instead of re-forming and repacking `7^depth` operands per
/// call.
pub struct StrassenWeights {
    /// Leaf combinations in recursion (pre-order, M1..M7 per node)
    /// visit order.
    handles: Vec<WeightHandle>,
    depth: usize,
    /// Original B dims.
    k: usize,
    n: usize,
    /// B dims after top-level padding to a multiple of `2^depth`.
    padded_k: usize,
    padded_n: usize,
}

impl StrassenWeights {
    /// The recursion depth the combinations were registered for.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// The registered leaf-combination handles (`7^depth`, or 1 at
    /// depth 0), in recursion visit order.
    pub fn leaf_handles(&self) -> &[WeightHandle] {
        &self.handles
    }

    /// Drop every registered combination (cached packs freed; in-flight
    /// work is unaffected). Sweeps the whole list even when one handle
    /// fails, so a partial failure never leaks the remainder.
    pub fn unregister(self, server: &JobServer) -> anyhow::Result<()> {
        server.unregister_all(self.handles)
    }
}

/// Form and register the B-side quadrant-combination tree of `b` at
/// `depth` — the Strassen model-load step. The combinations are built
/// with the same row-streamed add/sub kernels the recursion uses, so a
/// registered run is bit-identical to an inline one. `depth = 0`
/// registers `b` itself as a single shared operand.
pub fn register_weights(
    server: &JobServer,
    b: &Matrix,
    depth: usize,
) -> anyhow::Result<StrassenWeights> {
    let (k, n) = (b.rows, b.cols);
    anyhow::ensure!(k > 0 && n > 0, "degenerate B {k}x{n}");
    anyhow::ensure!(
        depth <= (k.ilog2().min(n.ilog2())) as usize,
        "depth {depth} too deep for a {k}x{n} B (each level halves both dims)"
    );
    let mut handles = Vec::new();
    let (padded_k, padded_n) = if depth == 0 {
        handles.push(server.register_b(b.clone())?);
        (k, n)
    } else {
        let align = 1usize << depth;
        let (kp, np) = (k.next_multiple_of(align), n.next_multiple_of(align));
        let bp = b.pad_to(kp, np);
        collect_b_combos(server, &bp, depth, &mut handles)?;
        (kp, np)
    };
    Ok(StrassenWeights { handles, depth, k, n, padded_k, padded_n })
}

/// Register the `7^depth_left` leaf combinations under `b`, pre-order
/// (combination j's subtree fully before combination j+1's) — exactly
/// the order [`node_batched_registered`] consumes them in.
fn collect_b_combos(
    server: &JobServer,
    b: &Matrix,
    depth_left: usize,
    handles: &mut Vec<WeightHandle>,
) -> anyhow::Result<()> {
    let (k, n) = (b.rows, b.cols);
    debug_assert!(k % 2 == 0 && n % 2 == 0, "combo dims must be even");
    let (k2, n2) = (k / 2, n / 2);
    let mut combos: Vec<Matrix> = Vec::with_capacity(7);
    {
        let bv = b.view();
        let b11 = bv.block(0, 0, k2, n2);
        let b12 = bv.block(0, n2, k2, n2);
        let b21 = bv.block(k2, 0, k2, n2);
        let b22 = bv.block(k2, n2, k2, n2);
        let specs: [Combo<'_>; 7] = [
            Combo::Add(b11, b22), // M1
            Combo::Copy(b11),     // M2
            Combo::Sub(b12, b22), // M3
            Combo::Sub(b21, b11), // M4
            Combo::Copy(b22),     // M5
            Combo::Add(b11, b12), // M6
            Combo::Add(b21, b22), // M7
        ];
        for cb in specs {
            let mut combo = Matrix::zeros(k2, n2);
            fill_combo(&mut combo.view_mut(), cb);
            combos.push(combo);
        }
    }
    for combo in combos {
        if depth_left == 1 {
            handles.push(server.register_b(combo)?);
        } else {
            collect_b_combos(server, &combo, depth_left - 1, handles)?;
        }
    }
    Ok(())
}

/// Batched Strassen over a **shared B**: `cs[i] = a_list[i] x b` for a
/// whole batch, reusing the B-side quadrant combinations across it.
///
/// The 7-product fan-out repeats every B combination once per batch
/// member — M2 of every member multiplies the *same* `B11`, M1 the same
/// `B11 + B22`, and so on. A per-member recursion would rematerialize
/// and repack each combination `batch` times; here the combinations are
/// **registered with the server's operand registry**
/// ([`register_weights`]) and every leaf pairing streams through a
/// [`Submission::batched`] under its [`WeightHandle`] — one
/// shared-B group per combination, the packed combo built exactly once
/// however large the batch is (`Metrics::b_panel_packs` = `7^depth`
/// total, `Metrics::panels_shared` = `(batch-1) · 7^depth`). This
/// convenience wrapper registers, runs once, and unregisters; repeated
/// recursions over the same `b` should hold a [`StrassenWeights`] and
/// call [`multiply_batched_registered`] per batch so later runs hit
/// the cache instead of re-forming `7^depth` packs.
///
/// Every member must have the same shape (a batch of identical GEMMs —
/// the im2col inference stream). Results are bit-identical to running
/// [`multiply`] per member with the same `cfg`: identical combine
/// kernels and identical leaf accumulation order, over operands whose
/// packed layout does not depend on sharing.
pub fn multiply_batched(
    server: &JobServer,
    a_list: &[Matrix],
    b: &Matrix,
    cfg: &StrassenConfig,
) -> anyhow::Result<BatchedStrassenReport> {
    anyhow::ensure!(!a_list.is_empty(), "empty batch");
    let (m, k) = (a_list[0].rows, a_list[0].cols);
    anyhow::ensure!(
        a_list.iter().all(|a| (a.rows, a.cols) == (m, k)),
        "batch members must share one shape"
    );
    anyhow::ensure!(k == b.rows, "contraction mismatch");
    anyhow::ensure!(
        m > 0 && k > 0 && b.cols > 0,
        "degenerate problem {m}x{k}x{}",
        b.cols
    );
    if let Some(run) = cfg.run {
        run.validate(server.hw())?;
    }
    let n = b.cols;
    let (model, requested) = match cfg.cutoff {
        Cutoff::Model => {
            let plan = strassen_crossover(server.hw(), m, k, n, server.surface())?;
            let depth = plan.depth;
            (Some(plan), depth)
        }
        Cutoff::Depth(d) => (None, d),
    };
    let depth = requested.min(depth_cap(m, k, n));

    if depth == 0 {
        // One direct shared-B group; nothing worth registering.
        let results =
            server.submit_blocking(Submission::batched(b.clone(), a_list.to_vec()).run(cfg.run))?;
        let cs = results.into_iter().map(|r| r.c).collect();
        return Ok(BatchedStrassenReport {
            cs,
            depth: 0,
            leaf_groups: 1,
            leaf_gemms: a_list.len() as u64,
            level_nodes: Vec::new(),
            level_spawns: Vec::new(),
            padded: (m, k, n),
            model,
            arena: ScratchArena::new().stats(),
        });
    }
    let weights = register_weights(server, b, depth)?;
    // Unregister before surfacing any run failure: a failed recursion
    // must not leak 7^depth registrations into a long-lived server.
    let result = multiply_batched_registered(server, a_list, &weights, cfg.run);
    let unregistered = weights.unregister(server);
    let mut report = result?;
    unregistered?;
    report.model = model;
    Ok(report)
}

/// Batched Strassen against **pre-registered** B-side combinations: the
/// recursion carries only the A side — every leaf submits its shared-B
/// group by [`WeightHandle`], so a run over weights already resolved
/// once performs **zero** B-side forming or packing (pure registry
/// hits). The recursion depth is `weights.depth()`; the report's
/// `model` is `None` (register at the model's depth to combine both).
pub fn multiply_batched_registered(
    server: &JobServer,
    a_list: &[Matrix],
    weights: &StrassenWeights,
    run: Option<RunConfig>,
) -> anyhow::Result<BatchedStrassenReport> {
    anyhow::ensure!(!a_list.is_empty(), "empty batch");
    let (m, k) = (a_list[0].rows, a_list[0].cols);
    anyhow::ensure!(
        a_list.iter().all(|a| (a.rows, a.cols) == (m, k)),
        "batch members must share one shape"
    );
    anyhow::ensure!(
        k == weights.k,
        "contraction mismatch: batch K = {k}, registered B K = {}",
        weights.k
    );
    anyhow::ensure!(m > 0 && k > 0, "degenerate problem {m}x{k}x{}", weights.n);
    if let Some(run) = run {
        run.validate(server.hw())?;
    }
    let depth = weights.depth;
    anyhow::ensure!(
        depth <= depth_cap(m, k, weights.n),
        "registered depth {depth} too deep for batch M = {m}; \
         register shallower weights for this problem"
    );

    let mut ctx = Ctx {
        server,
        arena: ScratchArena::new(),
        run,
        next_id: 0,
        leaf_gemms: 0,
        leaf_groups: 0,
        level_nodes: vec![0; depth],
        level_spawns: vec![0; depth],
    };

    let (cs, padded) = if depth == 0 {
        let results = server
            .submit_blocking(Submission::batched(weights.handles[0], a_list.to_vec()).run(run))?;
        ctx.leaf_groups = 1;
        ctx.leaf_gemms = a_list.len() as u64;
        let cs = results.into_iter().map(|r| r.c).collect();
        (cs, (m, k, weights.n))
    } else {
        let align = 1usize << depth;
        let mp = m.next_multiple_of(align);
        let (kp, np) = (weights.padded_k, weights.padded_n);
        let aps: Vec<Matrix> = a_list.iter().map(|a| a.pad_to(mp, kp)).collect();
        let mut cursor = 0usize;
        let cps = node_batched_registered(&mut ctx, aps, np, depth, 0, weights, &mut cursor)?;
        debug_assert_eq!(cursor, weights.handles.len(), "every leaf combo consumed");
        let cs = cps
            .into_iter()
            .map(|cp| {
                let c = cp.block(0, 0, m, weights.n);
                ctx.arena.put(cp);
                c
            })
            .collect();
        (cs, (mp, kp, np))
    };

    Ok(BatchedStrassenReport {
        cs,
        depth,
        leaf_groups: ctx.leaf_groups,
        leaf_gemms: ctx.leaf_gemms,
        level_nodes: ctx.level_nodes,
        level_spawns: ctx.level_spawns,
        padded,
        model: None,
        arena: ctx.arena.stats(),
    })
}

/// One batched recursion node against registered B combinations
/// (`depth_left >= 1`; all dims even, `n` = this node's B columns).
/// Forms the 7 A combinations per member; the B side is consumed as
/// handles from `weights` in registration (pre-)order via `cursor`.
fn node_batched_registered(
    ctx: &mut Ctx<'_>,
    a_list: Vec<Matrix>,
    n: usize,
    depth_left: usize,
    level: usize,
    weights: &StrassenWeights,
    cursor: &mut usize,
) -> anyhow::Result<Vec<Matrix>> {
    let batch = a_list.len();
    let (m, k) = (a_list[0].rows, a_list[0].cols);
    debug_assert!(m % 2 == 0 && k % 2 == 0 && n % 2 == 0, "node dims must be even");
    let (m2, k2, n2) = (m / 2, k / 2, n / 2);

    // Per-member A combinations: a_combos[j] holds combination j of
    // every member, in batch order.
    let mut a_combos: Vec<Vec<Matrix>> =
        (0..7).map(|_| Vec::with_capacity(batch)).collect();
    for a in a_list {
        {
            let av = a.view();
            let a11 = av.block(0, 0, m2, k2);
            let a12 = av.block(0, k2, m2, k2);
            let a21 = av.block(m2, 0, m2, k2);
            let a22 = av.block(m2, k2, m2, k2);
            let specs: [Combo<'_>; 7] = [
                Combo::Add(a11, a22), // M1
                Combo::Add(a21, a22), // M2
                Combo::Copy(a11),     // M3
                Combo::Copy(a22),     // M4
                Combo::Add(a11, a12), // M5
                Combo::Sub(a21, a11), // M6
                Combo::Sub(a12, a22), // M7
            ];
            for (j, ca) in specs.into_iter().enumerate() {
                a_combos[j].push(materialize(&mut ctx.arena, m2, k2, ca));
            }
        }
        ctx.arena.put(a);
    }
    ctx.level_nodes[level] += 1;
    ctx.level_spawns[level] += 7;

    // ms[j][member] = combination j's product for that member.
    let ms: Vec<Vec<Matrix>> = if depth_left == 1 {
        // Submit all 7 shared-B groups before waiting on any, so the
        // pool sees the node's whole fan-out at once. Each group's B is
        // a registered handle: resolved from the cache, never re-formed.
        let mut groups = Vec::with_capacity(7);
        for acs in a_combos {
            let h = weights.handles[*cursor];
            *cursor += 1;
            groups.push(ctx.server.submit_async(Submission::batched(h, acs).run(ctx.run))?);
        }
        ctx.leaf_groups += 7;
        ctx.leaf_gemms += 7 * batch as u64;
        let mut ms = Vec::with_capacity(7);
        for g in groups {
            let results = g.wait()?;
            let mut per_member = Vec::with_capacity(batch);
            for r in results {
                anyhow::ensure!(
                    (r.c.rows, r.c.cols) == (m2, n2),
                    "leaf {} returned {}x{}, expected {m2}x{n2}",
                    r.id,
                    r.c.rows,
                    r.c.cols
                );
                per_member.push(r.c);
            }
            ms.push(per_member);
        }
        ms
    } else {
        let mut ms = Vec::with_capacity(7);
        for acs in a_combos {
            ms.push(node_batched_registered(
                ctx,
                acs,
                n2,
                depth_left - 1,
                level + 1,
                weights,
                cursor,
            )?);
        }
        ms
    };

    Ok(combine_members(ctx, ms, batch, m, n))
}

/// The per-member Strassen combine for one batched node: fold each
/// member's 7 sub-products `ms[j][member]` into its `m x n` C, recycling
/// the sub-products through the arena. Shared by every batched recursion
/// variant so registered and inline runs combine bit-identically.
fn combine_members(
    ctx: &mut Ctx<'_>,
    ms: Vec<Vec<Matrix>>,
    batch: usize,
    m: usize,
    n: usize,
) -> Vec<Matrix> {
    let (m2, n2) = (m / 2, n / 2);
    let mut cs = Vec::with_capacity(batch);
    for member in 0..batch {
        let mut c = ctx.arena.take(m, n);
        {
            let mut cv = c.view_mut();
            {
                let mut c11 = cv.block_mut(0, 0, m2, n2);
                ops::add_into(ms[0][member].view(), ms[3][member].view(), &mut c11);
                ops::acc_sub(&mut c11, ms[4][member].view());
                ops::acc_add(&mut c11, ms[6][member].view());
            }
            {
                let mut c12 = cv.block_mut(0, n2, m2, n2);
                ops::add_into(ms[2][member].view(), ms[4][member].view(), &mut c12);
            }
            {
                let mut c21 = cv.block_mut(m2, 0, m2, n2);
                ops::add_into(ms[1][member].view(), ms[3][member].view(), &mut c21);
            }
            {
                let mut c22 = cv.block_mut(m2, n2, m2, n2);
                ops::sub_into(ms[0][member].view(), ms[1][member].view(), &mut c22);
                ops::acc_add(&mut c22, ms[2][member].view());
                ops::acc_add(&mut c22, ms[5][member].view());
            }
        }
        cs.push(c);
    }
    for per_combo in ms {
        for mi in per_combo {
            ctx.arena.put(mi);
        }
    }
    cs
}

/// The A side of a batched Strassen recursion registered as
/// server-resident activations: every **leaf-level A quadrant
/// combination of every batch member** (`7^depth` combinations x
/// `batch` members, in the recursion's visit order) lives in the
/// server's operand registry under an [`ActivationHandle`]. The
/// dual of [`StrassenWeights`] for serving loops that re-run the same
/// activation batch against one or more weight sets — build once with
/// [`register_activations`], then [`multiply_batched_bi_registered`]
/// resolves *both* sides of every leaf GEMM from the pack cache.
pub struct StrassenActivations {
    /// `handles[leaf][member]`: leaf combinations in recursion
    /// (pre-order, M1..M7 per node) visit order — the same order
    /// [`StrassenWeights`] registers the B side in, so one cursor
    /// walks both.
    handles: Vec<Vec<ActivationHandle>>,
    depth: usize,
    batch: usize,
    /// Original per-member A dims.
    m: usize,
    k: usize,
    /// A dims after top-level padding to a multiple of `2^depth`.
    padded_m: usize,
    padded_k: usize,
}

impl StrassenActivations {
    /// The recursion depth the combinations were registered for.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Batch members per leaf combination.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// The registered leaf combinations (`7^depth` groups of `batch`
    /// handles, or 1 group at depth 0), in recursion visit order.
    pub fn leaf_handles(&self) -> &[Vec<ActivationHandle>] {
        &self.handles
    }

    /// Drop every registered combination (cached packs freed; in-flight
    /// work is unaffected). Sweeps the whole list even when one handle
    /// fails, so a partial failure never leaks the remainder.
    pub fn unregister(self, server: &JobServer) -> anyhow::Result<()> {
        server.unregister_all_a(self.handles.into_iter().flatten())
    }
}

/// Form and register the A-side quadrant-combination tree of a whole
/// batch at `depth` — the Strassen activation-load step, dual to
/// [`register_weights`]. The combinations are built with the same
/// row-streamed add/sub kernels the recursion uses, so a registered run
/// is bit-identical to an inline one. `depth = 0` registers each member
/// itself.
pub fn register_activations(
    server: &JobServer,
    a_list: &[Matrix],
    depth: usize,
) -> anyhow::Result<StrassenActivations> {
    anyhow::ensure!(!a_list.is_empty(), "empty batch");
    let (m, k) = (a_list[0].rows, a_list[0].cols);
    anyhow::ensure!(
        a_list.iter().all(|a| (a.rows, a.cols) == (m, k)),
        "batch members must share one shape"
    );
    anyhow::ensure!(m > 0 && k > 0, "degenerate A {m}x{k}");
    anyhow::ensure!(
        depth <= (m.ilog2().min(k.ilog2())) as usize,
        "depth {depth} too deep for a {m}x{k} A (each level halves both dims)"
    );
    let mut handles = Vec::new();
    let (padded_m, padded_k) = if depth == 0 {
        let group = a_list
            .iter()
            .map(|a| server.register_a(a.clone()))
            .collect::<anyhow::Result<Vec<_>>>()?;
        handles.push(group);
        (m, k)
    } else {
        let align = 1usize << depth;
        let (mp, kp) = (m.next_multiple_of(align), k.next_multiple_of(align));
        let aps: Vec<Matrix> = a_list.iter().map(|a| a.pad_to(mp, kp)).collect();
        collect_a_combos(server, &aps, depth, &mut handles)?;
        (mp, kp)
    };
    Ok(StrassenActivations {
        handles,
        depth,
        batch: a_list.len(),
        m,
        k,
        padded_m,
        padded_k,
    })
}

/// Register the `7^depth_left` leaf combinations of every member under
/// `a_list`, pre-order (combination j's subtree fully before
/// combination j+1's) — exactly the order [`collect_b_combos`] uses, so
/// [`node_bi_registered`] walks both lists with one cursor.
fn collect_a_combos(
    server: &JobServer,
    a_list: &[Matrix],
    depth_left: usize,
    handles: &mut Vec<Vec<ActivationHandle>>,
) -> anyhow::Result<()> {
    let (m, k) = (a_list[0].rows, a_list[0].cols);
    debug_assert!(m % 2 == 0 && k % 2 == 0, "combo dims must be even");
    let (m2, k2) = (m / 2, k / 2);
    let mut combos: Vec<Vec<Matrix>> = (0..7).map(|_| Vec::with_capacity(a_list.len())).collect();
    for a in a_list {
        let av = a.view();
        let a11 = av.block(0, 0, m2, k2);
        let a12 = av.block(0, k2, m2, k2);
        let a21 = av.block(m2, 0, m2, k2);
        let a22 = av.block(m2, k2, m2, k2);
        let specs: [Combo<'_>; 7] = [
            Combo::Add(a11, a22), // M1
            Combo::Add(a21, a22), // M2
            Combo::Copy(a11),     // M3
            Combo::Copy(a22),     // M4
            Combo::Add(a11, a12), // M5
            Combo::Sub(a21, a11), // M6
            Combo::Sub(a12, a22), // M7
        ];
        for (j, ca) in specs.into_iter().enumerate() {
            let mut combo = Matrix::zeros(m2, k2);
            fill_combo(&mut combo.view_mut(), ca);
            combos[j].push(combo);
        }
    }
    for group in combos {
        if depth_left == 1 {
            let hs = group
                .into_iter()
                .map(|g| server.register_a(g))
                .collect::<anyhow::Result<Vec<_>>>()?;
            handles.push(hs);
        } else {
            collect_a_combos(server, &group, depth_left - 1, handles)?;
        }
    }
    Ok(())
}

/// Batched Strassen with **both sides pre-registered**: every leaf GEMM
/// pairs a registered A combination ([`StrassenActivations`]) with its
/// registered B combination ([`StrassenWeights`]) — the recursion forms
/// no operands and, once each `(handle, S)` variant is warm, packs
/// nothing on either side. This is the cache-hot serving shape for
/// re-running one activation batch (an attention block's token batch,
/// an im2col window set) against resident weights.
///
/// Results are bit-identical to [`multiply_batched_registered`] over the
/// same `a_list`: the registered combinations were built by the same
/// combine kernels, and packed layout does not depend on residency.
pub fn multiply_batched_bi_registered(
    server: &JobServer,
    acts: &StrassenActivations,
    weights: &StrassenWeights,
    run: Option<RunConfig>,
) -> anyhow::Result<BatchedStrassenReport> {
    anyhow::ensure!(
        acts.depth == weights.depth,
        "depth mismatch: activations registered at {}, weights at {}",
        acts.depth,
        weights.depth
    );
    anyhow::ensure!(
        acts.k == weights.k,
        "contraction mismatch: registered A K = {}, registered B K = {}",
        acts.k,
        weights.k
    );
    if let Some(run) = run {
        run.validate(server.hw())?;
    }
    let depth = acts.depth;

    let mut ctx = Ctx {
        server,
        arena: ScratchArena::new(),
        run,
        next_id: 0,
        leaf_gemms: 0,
        leaf_groups: 0,
        level_nodes: vec![0; depth],
        level_spawns: vec![0; depth],
    };

    let (cs, padded) = if depth == 0 {
        let many_a: Vec<AOperand> =
            acts.handles[0].iter().map(|&h| AOperand::from(h)).collect();
        let results = server
            .submit_blocking(Submission::batched(weights.handles[0], many_a).run(run))?;
        ctx.leaf_groups = 1;
        ctx.leaf_gemms = acts.batch as u64;
        let cs = results.into_iter().map(|r| r.c).collect();
        (cs, (acts.m, acts.k, weights.n))
    } else {
        let (mp, kp, np) = (acts.padded_m, acts.padded_k, weights.padded_n);
        debug_assert_eq!(kp, weights.padded_k, "equal K and depth pad identically");
        let mut cursor = 0usize;
        let cps = node_bi_registered(&mut ctx, mp, np, depth, 0, acts, weights, &mut cursor)?;
        debug_assert_eq!(cursor, weights.handles.len(), "every leaf combo consumed");
        let cs = cps
            .into_iter()
            .map(|cp| {
                let c = cp.block(0, 0, acts.m, weights.n);
                ctx.arena.put(cp);
                c
            })
            .collect();
        (cs, (mp, kp, np))
    };

    Ok(BatchedStrassenReport {
        cs,
        depth,
        leaf_groups: ctx.leaf_groups,
        leaf_gemms: ctx.leaf_gemms,
        level_nodes: ctx.level_nodes,
        level_spawns: ctx.level_spawns,
        padded,
        model: None,
        arena: ctx.arena.stats(),
    })
}

/// One batched recursion node with both sides registered
/// (`depth_left >= 1`; `m`/`n` = this node's C dims, both even). The
/// node carries no operand data at all — both sides are consumed as
/// handles in registration (pre-)order via the shared `cursor`.
#[allow(clippy::too_many_arguments)]
fn node_bi_registered(
    ctx: &mut Ctx<'_>,
    m: usize,
    n: usize,
    depth_left: usize,
    level: usize,
    acts: &StrassenActivations,
    weights: &StrassenWeights,
    cursor: &mut usize,
) -> anyhow::Result<Vec<Matrix>> {
    let batch = acts.batch;
    debug_assert!(m % 2 == 0 && n % 2 == 0, "node dims must be even");
    let (m2, n2) = (m / 2, n / 2);
    ctx.level_nodes[level] += 1;
    ctx.level_spawns[level] += 7;

    // ms[j][member] = combination j's product for that member.
    let ms: Vec<Vec<Matrix>> = if depth_left == 1 {
        // Submit all 7 fully-registered groups before waiting on any.
        let mut groups = Vec::with_capacity(7);
        for _ in 0..7 {
            let wh = weights.handles[*cursor];
            let many_a: Vec<AOperand> =
                acts.handles[*cursor].iter().map(|&h| AOperand::from(h)).collect();
            *cursor += 1;
            groups.push(ctx.server.submit_async(Submission::batched(wh, many_a).run(ctx.run))?);
        }
        ctx.leaf_groups += 7;
        ctx.leaf_gemms += 7 * batch as u64;
        let mut ms = Vec::with_capacity(7);
        for g in groups {
            let results = g.wait()?;
            let mut per_member = Vec::with_capacity(batch);
            for r in results {
                anyhow::ensure!(
                    (r.c.rows, r.c.cols) == (m2, n2),
                    "leaf {} returned {}x{}, expected {m2}x{n2}",
                    r.id,
                    r.c.rows,
                    r.c.cols
                );
                per_member.push(r.c);
            }
            ms.push(per_member);
        }
        ms
    } else {
        let mut ms = Vec::with_capacity(7);
        for _ in 0..7 {
            ms.push(node_bi_registered(
                ctx,
                m2,
                n2,
                depth_left - 1,
                level + 1,
                acts,
                weights,
                cursor,
            )?);
        }
        ms
    };

    Ok(combine_members(ctx, ms, batch, m, n))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareConfig;
    use crate::coordinator::{NumericsEngine, ServerConfig};

    fn server() -> JobServer {
        let cfg = ServerConfig {
            workers: 4,
            queue_capacity: 16,
            batch_max_tasks: 4,
            batch_window: 4,
            cross_job_stealing: true,
            default_run: Some(RunConfig::square(2, 16)),
            ..ServerConfig::default()
        };
        JobServer::new(HardwareConfig::paper(), NumericsEngine::golden(), cfg).unwrap()
    }

    fn cfg_depth(d: usize) -> StrassenConfig {
        StrassenConfig { cutoff: Cutoff::Depth(d), run: Some(RunConfig::square(2, 16)) }
    }

    #[test]
    fn one_level_matches_oracle_even_dims() {
        let srv = server();
        let a = Matrix::random(32, 24, 1);
        let b = Matrix::random(24, 40, 2);
        let r = multiply(&srv, &a, &b, &cfg_depth(1)).unwrap();
        assert_eq!(r.depth, 1);
        assert_eq!(r.leaf_gemms, 7);
        assert_eq!(r.level_nodes, vec![1]);
        assert!((r.fanout(0) - 7.0).abs() < 1e-12);
        assert!(r.model.is_none(), "forced depth must not pay for the model sweep");
        assert!(r.c.allclose(&a.matmul(&b), 1e-4));
    }

    #[test]
    fn odd_dims_are_padded_even() {
        let srv = server();
        let a = Matrix::random(33, 17, 3);
        let b = Matrix::random(17, 29, 4);
        let r = multiply(&srv, &a, &b, &cfg_depth(1)).unwrap();
        assert_eq!(r.padded, (34, 18, 30));
        assert_eq!((r.c.rows, r.c.cols), (33, 29));
        assert!(r.c.allclose(&a.matmul(&b), 1e-4));
    }

    #[test]
    fn depth_zero_is_one_direct_job() {
        let srv = server();
        let a = Matrix::random(20, 12, 5);
        let b = Matrix::random(12, 16, 6);
        let r = multiply(&srv, &a, &b, &cfg_depth(0)).unwrap();
        assert_eq!((r.depth, r.leaf_gemms), (0, 1));
        assert_eq!(r.padded, (20, 12, 16));
        assert!(r.c.allclose(&a.matmul(&b), 1e-4));
    }

    #[test]
    fn forced_depth_clamped_by_shape() {
        let srv = server();
        let a = Matrix::random(3, 5, 7);
        let b = Matrix::random(5, 2, 8);
        // ilog2(2) = 1 caps the recursion regardless of the request.
        let r = multiply(&srv, &a, &b, &cfg_depth(6)).unwrap();
        assert_eq!(r.depth, 1);
        assert!(r.c.allclose(&a.matmul(&b), 1e-4));
        // A 1-dim shape cannot recurse at all.
        let a1 = Matrix::random(1, 4, 9);
        let b1 = Matrix::random(4, 4, 10);
        let r1 = multiply(&srv, &a1, &b1, &cfg_depth(3)).unwrap();
        assert_eq!(r1.depth, 0);
        assert!(r1.c.allclose(&a1.matmul(&b1), 1e-4));
    }

    #[test]
    fn model_cutoff_runs_small_problems_direct() {
        let srv = server();
        let a = Matrix::random(64, 64, 11);
        let b = Matrix::random(64, 64, 12);
        let cfg = StrassenConfig { cutoff: Cutoff::Model, run: Some(RunConfig::square(2, 16)) };
        let r = multiply(&srv, &a, &b, &cfg).unwrap();
        assert_eq!(r.depth, 0, "64^3 is far below the modeled crossover");
        assert_eq!(r.model.as_ref().unwrap().depth, 0);
        assert!(r.c.allclose(&a.matmul(&b), 1e-4));
    }

    #[test]
    fn two_levels_recombine_and_reuse_the_arena() {
        let srv = server();
        let a = Matrix::random(40, 36, 13);
        let b = Matrix::random(36, 44, 14);
        let r = multiply(&srv, &a, &b, &cfg_depth(2)).unwrap();
        assert_eq!(r.depth, 2);
        assert_eq!(r.leaf_gemms, 49);
        assert_eq!(r.level_nodes, vec![1, 7]);
        assert_eq!(r.level_spawns, vec![7, 49]);
        assert!(r.c.allclose(&a.matmul(&b), 1e-3));
        assert!(r.arena.reuses > 0, "deep recursion must recycle buffers");
    }

    #[test]
    fn mismatched_operands_rejected() {
        let srv = server();
        let a = Matrix::random(8, 8, 15);
        let b = Matrix::random(9, 8, 16);
        assert!(multiply(&srv, &a, &b, &cfg_depth(1)).is_err());
    }

    #[test]
    fn batched_depth1_packs_each_b_combo_once() {
        let srv = server();
        let b = Matrix::random(24, 40, 100);
        let a_list: Vec<Matrix> = (0..3u64).map(|i| Matrix::random(32, 24, 101 + i)).collect();
        let r = multiply_batched(&srv, &a_list, &b, &cfg_depth(1)).unwrap();
        assert_eq!(r.depth, 1);
        assert_eq!(r.leaf_groups, 7, "one shared-B group per combination");
        assert_eq!(r.leaf_gemms, 21);
        assert_eq!(r.level_nodes, vec![1]);
        for (a, c) in a_list.iter().zip(&r.cs) {
            assert!(c.allclose(&a.matmul(&b), 1e-4));
        }
        // The reuse the batched recursion exists for: each of the 7 B
        // combinations packed once, (batch-1) packs avoided apiece.
        let m = srv.metrics();
        assert_eq!(m.b_panel_packs(), 7);
        assert_eq!(m.panels_shared(), 7 * (3 - 1));
        assert_eq!(m.a_panel_packs(), 21);
        assert_eq!(m.shared_b_groups(), 7);
    }

    #[test]
    fn batched_matches_single_member_multiply_bit_for_bit() {
        // Same combos, same combine kernels, same leaf accumulation
        // order: the shared-B recursion must agree with the per-member
        // planner exactly, not just approximately.
        let srv = server();
        let b = Matrix::random(36, 44, 110);
        let a_list: Vec<Matrix> = (0..2u64).map(|i| Matrix::random(40, 36, 111 + i)).collect();
        let batched = multiply_batched(&srv, &a_list, &b, &cfg_depth(2)).unwrap();
        assert_eq!(batched.depth, 2);
        assert_eq!(batched.leaf_groups, 49);
        assert_eq!(batched.level_nodes, vec![1, 7]);
        assert_eq!(batched.level_spawns, vec![7, 49]);
        for (a, c) in a_list.iter().zip(&batched.cs) {
            let single = multiply(&srv, a, &b, &cfg_depth(2)).unwrap();
            assert_eq!(c.data, single.c.data, "batched member diverged from single run");
        }
        assert!(batched.arena.reuses > 0);
    }

    #[test]
    fn batched_depth0_is_one_shared_group() {
        let srv = server();
        let b = Matrix::random(12, 16, 120);
        let a_list: Vec<Matrix> = (0..4u64).map(|i| Matrix::random(20, 12, 121 + i)).collect();
        let r = multiply_batched(&srv, &a_list, &b, &cfg_depth(0)).unwrap();
        assert_eq!((r.depth, r.leaf_groups, r.leaf_gemms), (0, 1, 4));
        assert_eq!(r.padded, (20, 12, 16));
        for (a, c) in a_list.iter().zip(&r.cs) {
            assert!(c.allclose(&a.matmul(&b), 1e-4));
        }
        assert_eq!(srv.metrics().b_panel_packs(), 1);
        assert_eq!(srv.metrics().panels_shared(), 3);
    }

    #[test]
    fn batched_odd_dims_padded_and_clipped() {
        let srv = server();
        let b = Matrix::random(17, 29, 130);
        let a_list: Vec<Matrix> = (0..2u64).map(|i| Matrix::random(33, 17, 131 + i)).collect();
        let r = multiply_batched(&srv, &a_list, &b, &cfg_depth(1)).unwrap();
        assert_eq!(r.padded, (34, 18, 30));
        for (a, c) in a_list.iter().zip(&r.cs) {
            assert_eq!((c.rows, c.cols), (33, 29));
            assert!(c.allclose(&a.matmul(&b), 1e-4));
        }
    }

    #[test]
    fn registered_weights_reused_across_recursions() {
        // Repeated batched recursions over one registered B: the 7
        // combos pack once on the first run and are pure cache hits on
        // every later one — and repeat results stay bit-identical.
        let srv = server();
        let b = Matrix::random(24, 40, 150);
        let a_list: Vec<Matrix> =
            (0..2u64).map(|i| Matrix::random(32, 24, 151 + i)).collect();
        let weights = register_weights(&srv, &b, 1).unwrap();
        assert_eq!(weights.depth(), 1);
        assert_eq!(weights.leaf_handles().len(), 7);
        let run = Some(RunConfig::square(2, 16));
        let first = multiply_batched_registered(&srv, &a_list, &weights, run).unwrap();
        assert!(first.model.is_none());
        assert_eq!((first.depth, first.leaf_groups, first.leaf_gemms), (1, 7, 14));
        let second = multiply_batched_registered(&srv, &a_list, &weights, run).unwrap();
        for ((a, c1), c2) in a_list.iter().zip(&first.cs).zip(&second.cs) {
            assert!(c1.allclose(&a.matmul(&b), 1e-4));
            assert_eq!(c1.data, c2.data, "repeat run must be bit-identical");
        }
        let m = srv.metrics();
        assert_eq!(m.b_panel_packs(), 7, "7 combos packed once across both runs");
        assert_eq!(m.registry_misses(), 7);
        assert_eq!(m.registry_hits(), 7, "second run is pure cache hits");
        weights.unregister(&srv).unwrap();
        assert_eq!(srv.stats().registered_weights, 0);
        // Depth guard: weights registered at depth 1 reject a batch
        // whose M cannot halve.
        let tiny = vec![Matrix::random(1, 24, 160)];
        let w1 = register_weights(&srv, &b, 1).unwrap();
        assert!(multiply_batched_registered(&srv, &tiny, &w1, None).is_err());
        w1.unregister(&srv).unwrap();
        // And registration itself rejects depths B cannot halve to.
        assert!(register_weights(&srv, &Matrix::random(2, 2, 161), 2).is_err());
    }

    #[test]
    fn bi_registered_leaves_reuse_activation_packs() {
        // Registering the A side too: the 7 x batch activation combos
        // pack once on the first bi-registered run, and a repeat run
        // packs nothing on either side — bit-identical throughout.
        let srv = server();
        let b = Matrix::random(24, 40, 170);
        let a_list: Vec<Matrix> =
            (0..2u64).map(|i| Matrix::random(32, 24, 171 + i)).collect();
        let weights = register_weights(&srv, &b, 1).unwrap();
        let run = Some(RunConfig::square(2, 16));
        let inline = multiply_batched_registered(&srv, &a_list, &weights, run).unwrap();
        let acts = register_activations(&srv, &a_list, 1).unwrap();
        assert_eq!((acts.depth(), acts.batch()), (1, 2));
        assert_eq!(acts.leaf_handles().len(), 7);
        let m = srv.metrics();
        let packs_before = m.a_panel_packs();
        assert_eq!(packs_before, 14, "inline run packed A privately per leaf GEMM");
        let first = multiply_batched_bi_registered(&srv, &acts, &weights, run).unwrap();
        assert_eq!((first.depth, first.leaf_groups, first.leaf_gemms), (1, 7, 14));
        for (c1, c2) in inline.cs.iter().zip(&first.cs) {
            assert_eq!(c1.data, c2.data, "registered-A leaves must be bit-identical");
        }
        assert_eq!(m.a_panel_packs() - packs_before, 14, "7 combos x 2 members, packed once");
        assert_eq!(m.registry_a_misses(), 14);
        let second = multiply_batched_bi_registered(&srv, &acts, &weights, run).unwrap();
        for (c1, c2) in first.cs.iter().zip(&second.cs) {
            assert_eq!(c1.data, c2.data, "repeat run must be bit-identical");
        }
        assert_eq!(m.a_panel_packs() - packs_before, 14, "repeat run packed nothing");
        assert_eq!(m.registry_a_hits(), 14, "second run is pure A-side cache hits");
        acts.unregister(&srv).unwrap();
        weights.unregister(&srv).unwrap();
        let stats = srv.stats();
        assert_eq!((stats.registered_activations, stats.registered_weights), (0, 0));
        // Depth mismatch between the two sides is rejected up front.
        let w0 = register_weights(&srv, &b, 0).unwrap();
        let a1 = register_activations(&srv, &a_list, 1).unwrap();
        assert!(multiply_batched_bi_registered(&srv, &a1, &w0, run).is_err());
        a1.unregister(&srv).unwrap();
        w0.unregister(&srv).unwrap();
    }

    #[test]
    fn bi_registered_depth_zero_and_validation() {
        let srv = server();
        let b = Matrix::random(12, 16, 180);
        let a_list: Vec<Matrix> = (0..3u64).map(|i| Matrix::random(20, 12, 181 + i)).collect();
        let weights = register_weights(&srv, &b, 0).unwrap();
        let acts = register_activations(&srv, &a_list, 0).unwrap();
        assert_eq!(acts.leaf_handles().len(), 1);
        assert_eq!(acts.leaf_handles()[0].len(), 3);
        let r = multiply_batched_bi_registered(&srv, &acts, &weights, None).unwrap();
        assert_eq!((r.depth, r.leaf_groups, r.leaf_gemms), (0, 1, 3));
        for (a, c) in a_list.iter().zip(&r.cs) {
            assert!(c.allclose(&a.matmul(&b), 1e-4));
        }
        acts.unregister(&srv).unwrap();
        weights.unregister(&srv).unwrap();
        // Registration validation: ragged batches, empty batches, and
        // over-deep requests are rejected.
        assert!(register_activations(&srv, &[], 0).is_err());
        let ragged = vec![Matrix::random(4, 4, 190), Matrix::random(4, 6, 191)];
        assert!(register_activations(&srv, &ragged, 0).is_err());
        assert!(register_activations(&srv, &[Matrix::random(2, 2, 192)], 2).is_err());
        // Contraction mismatch across registered sides.
        let w = register_weights(&srv, &Matrix::random(8, 8, 193), 0).unwrap();
        let a = register_activations(&srv, &[Matrix::random(4, 6, 194)], 0).unwrap();
        assert!(multiply_batched_bi_registered(&srv, &a, &w, None).is_err());
        a.unregister(&srv).unwrap();
        w.unregister(&srv).unwrap();
    }

    #[test]
    fn batched_rejects_ragged_batches_and_mismatches() {
        let srv = server();
        let b = Matrix::random(8, 8, 140);
        assert!(multiply_batched(&srv, &[], &b, &cfg_depth(1)).is_err());
        let ragged = vec![Matrix::random(8, 8, 141), Matrix::random(10, 8, 142)];
        assert!(multiply_batched(&srv, &ragged, &b, &cfg_depth(1)).is_err());
        let mismatched = vec![Matrix::random(8, 9, 143)];
        assert!(multiply_batched(&srv, &mismatched, &b, &cfg_depth(1)).is_err());
    }

    #[test]
    fn invalid_pinned_run_rejected_before_any_submit() {
        let srv = server();
        let a = Matrix::random(8, 8, 17);
        let b = Matrix::random(8, 8, 18);
        let cfg = StrassenConfig { cutoff: Cutoff::Depth(1), run: Some(RunConfig::square(4, 256)) };
        assert!(multiply(&srv, &a, &b, &cfg).is_err());
    }
}
