//! Reusable scratch buffers for the Strassen recursion.
//!
//! Every recursion node needs 14 operand temporaries (two per
//! sub-product), 7 sub-product results, and one combined output.
//! Allocating each fresh would scale peak memory with the node count;
//! the arena instead parks finished buffers on a free list and hands
//! them back best-fit, so a deep recursion cycles through a small,
//! bounded working set. Buffers handed to the [`crate::coordinator`]
//! as job operands leave the arena for good (the server owns and drops
//! them), but the server's result matrices flow *into* the arena after
//! combining, which keeps the pool balanced across levels.
//!
//! Buffers come back zero-filled, so a taken matrix is always a valid
//! zero matrix (the same contract as [`Matrix::zeros`]); the zeroing
//! cost is linear and vanishes next to the O(n³) products.

use crate::gemm::Matrix;

/// Allocation statistics — the numbers that show the reuse working.
#[derive(Debug, Clone, Copy, Default)]
pub struct ArenaStats {
    /// Buffers allocated fresh (free list could not serve the request).
    pub fresh_allocs: u64,
    /// Requests served by recycling a parked buffer.
    pub reuses: u64,
    /// Total bytes of fresh allocations — the arena's memory footprint
    /// bound (reused buffers add nothing here).
    pub fresh_bytes: u64,
    /// Bytes currently parked on the free list.
    pub freelist_bytes: u64,
}

/// Best-fit free list of FP32 buffers, single-owner (the planner
/// threads recursion through one `&mut` arena).
#[derive(Debug, Default)]
pub struct ScratchArena {
    free: Vec<Vec<f32>>,
    stats: ArenaStats,
}

impl ScratchArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// A zeroed `rows x cols` matrix, recycled when a parked buffer's
    /// capacity fits (best fit: the smallest sufficient one), fresh
    /// otherwise.
    pub fn take(&mut self, rows: usize, cols: usize) -> Matrix {
        let need = rows * cols;
        let candidate = self
            .free
            .iter()
            .enumerate()
            .filter(|(_, buf)| buf.capacity() >= need)
            .min_by_key(|(_, buf)| buf.capacity())
            .map(|(i, _)| i);
        let data = match candidate {
            Some(i) => {
                let mut buf = self.free.swap_remove(i);
                self.stats.freelist_bytes -= 4 * buf.capacity() as u64;
                self.stats.reuses += 1;
                buf.clear();
                buf.resize(need, 0.0);
                buf
            }
            None => {
                self.stats.fresh_allocs += 1;
                self.stats.fresh_bytes += 4 * need as u64;
                vec![0.0; need]
            }
        };
        Matrix::from_vec(rows, cols, data)
    }

    /// Park a finished matrix's buffer for reuse.
    pub fn put(&mut self, m: Matrix) {
        self.stats.freelist_bytes += 4 * m.data.capacity() as u64;
        self.free.push(m.data);
    }

    pub fn stats(&self) -> ArenaStats {
        self.stats
    }

    /// Zero the per-run counters while keeping the parked buffers (and
    /// the `freelist_bytes` gauge that describes them). A long-lived
    /// arena carried across `multiply` calls otherwise reports the sum
    /// of every run it ever served instead of the run at hand.
    pub fn reset_stats(&mut self) {
        let parked = self.stats.freelist_bytes;
        self.stats = ArenaStats { freelist_bytes: parked, ..ArenaStats::default() };
    }

    /// Merge another arena into this one: its parked buffers join the
    /// free list and its counters fold into ours. Used by the parallel
    /// recursion walk, where each sub-tree runs on a private arena that
    /// the parent absorbs at the join.
    pub fn absorb(&mut self, other: ScratchArena) {
        self.stats.fresh_allocs += other.stats.fresh_allocs;
        self.stats.reuses += other.stats.reuses;
        self.stats.fresh_bytes += other.stats.fresh_bytes;
        self.stats.freelist_bytes += other.stats.freelist_bytes;
        self.free.extend(other.free);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_returns_zeroed_matrix() {
        let mut arena = ScratchArena::new();
        let mut m = arena.take(3, 4);
        assert_eq!(m, Matrix::zeros(3, 4));
        m.data.fill(7.0);
        arena.put(m);
        // Recycled buffer must come back clean.
        let again = arena.take(2, 5);
        assert_eq!(again, Matrix::zeros(2, 5));
        assert_eq!(arena.stats().reuses, 1);
    }

    #[test]
    fn best_fit_prefers_smallest_sufficient_buffer() {
        let mut arena = ScratchArena::new();
        let big = arena.take(10, 10);
        let small = arena.take(3, 3);
        arena.put(big);
        arena.put(small);
        // A 3x3 request must take the 9-slot buffer, not the 100-slot.
        let got = arena.take(3, 3);
        assert_eq!(got.data.capacity(), 9);
        assert_eq!(arena.stats().fresh_allocs, 2);
        assert_eq!(arena.stats().reuses, 1);
    }

    #[test]
    fn fresh_bytes_bound_under_reuse() {
        let mut arena = ScratchArena::new();
        // Serial take/put of equal sizes must allocate exactly once.
        for _ in 0..50 {
            let m = arena.take(8, 8);
            arena.put(m);
        }
        let s = arena.stats();
        assert_eq!(s.fresh_allocs, 1);
        assert_eq!(s.reuses, 49);
        assert_eq!(s.fresh_bytes, 4 * 64);
        assert_eq!(s.freelist_bytes, 4 * 64);
    }

    #[test]
    fn reset_stats_keeps_freelist_and_its_gauge() {
        let mut arena = ScratchArena::new();
        let m = arena.take(4, 4);
        arena.put(m);
        arena.reset_stats();
        let s = arena.stats();
        assert_eq!(s.fresh_allocs, 0);
        assert_eq!(s.reuses, 0);
        assert_eq!(s.fresh_bytes, 0);
        assert_eq!(s.freelist_bytes, 4 * 16, "parked buffers survive the reset");
        // The parked buffer still serves the next request.
        let again = arena.take(4, 4);
        assert_eq!(again, Matrix::zeros(4, 4));
        assert_eq!(arena.stats().reuses, 1);
        assert_eq!(arena.stats().fresh_allocs, 0);
    }

    #[test]
    fn absorb_merges_freelist_and_counters() {
        let mut parent = ScratchArena::new();
        let pm = parent.take(2, 3);
        parent.put(pm);
        let mut child = ScratchArena::new();
        let cm = child.take(5, 5);
        child.put(cm);
        parent.absorb(child);
        let s = parent.stats();
        assert_eq!(s.fresh_allocs, 2);
        assert_eq!(s.fresh_bytes, 4 * (6 + 25));
        assert_eq!(s.freelist_bytes, 4 * (6 + 25));
        // The absorbed buffer is reusable from the parent.
        let got = parent.take(5, 5);
        assert_eq!(got.data.capacity(), 25);
        assert_eq!(parent.stats().reuses, 1);
    }

    #[test]
    fn too_small_parked_buffers_are_skipped() {
        let mut arena = ScratchArena::new();
        let tiny = arena.take(2, 2);
        arena.put(tiny);
        let big = arena.take(20, 20);
        assert_eq!(big.data.len(), 400);
        assert_eq!(arena.stats().fresh_allocs, 2, "tiny buffer cannot serve 400 elems");
    }
}
