//! Strassen decomposition on top of the serving runtime — the
//! algorithmic lever above the paper's architectural ones.
//!
//! The paper scales GEMM by multiplying PE arrays and balancing them
//! with work stealing; Strassen changes the FLOP count itself: a
//! quadrant split needs only 7 sub-products instead of 8, at the price
//! of O(n²) element-wise combine traffic. This module composes the two:
//!
//! * the **planner** ([`multiply`]) recursively splits `C = A x B` into
//!   quadrants, padding odd dimensions once up front with the Section-IV
//!   zero-pad machinery ([`crate::gemm::Matrix::pad_to`] to a multiple
//!   of `2^depth`, so every level halves exactly);
//! * two table-driven 7-product schedules sit behind
//!   [`StrassenAlgo`]: the classic form (18 combine operations per
//!   node) and the default **Winograd form** (15 — 4 chained sums per
//!   operand side plus a 7-op C-side fold through two shared temps),
//!   which cuts the O(n²) combine traffic by roughly 20%;
//! * above the leaf level operand combinations are formed by the
//!   row-streamed add/sub kernels of [`crate::gemm::ops`] reading
//!   quadrants through borrowed [`crate::gemm::MatrixView`]s; **at the
//!   leaf level they are not materialized at all** — each goes down as
//!   a [`crate::coordinator::FusedOperand`] and the packer streams
//!   `X op Y` straight from the parent quadrants into panel layout;
//! * the 7 sub-products of a leaf are submitted to the
//!   [`crate::coordinator::JobServer`] as **one group**
//!   ([`crate::coordinator::Submission::group`]) — cross-job work
//!   stealing spreads the 7-way fan-out over the persistent pool, the
//!   serving-runtime twin of the paper's inter-array WQM balancing;
//! * above the leaf the 7 sibling sub-trees walk **in parallel** on
//!   scoped threads by default ([`StrassenConfig::parallel`]), each
//!   with a private arena the parent absorbs at the join —
//!   bit-identical to the sequential walk, but the server sees the
//!   whole tree's leaf groups in flight at once;
//! * recursion depth comes from the analytical model:
//!   [`crate::analytical::strassen_crossover_with`] recurses only while
//!   `7·T(n/2) + combine` (priced per schedule and per fusion mode)
//!   beats the best direct multi-array time (override with
//!   [`Cutoff::Depth`] to force levels);
//! * per-level temporaries cycle through a reusable [`ScratchArena`],
//!   so peak allocation stays bounded across recursion levels instead
//!   of growing with every node.
//!
//! [`multiply`] returns a [`StrassenReport`]: the result matrix plus
//! the executed depth and schedule, the measured per-level fan-out (7,
//! vs 8 for a direct quadrant split), leaf-GEMM count, the
//! [`CombineStats`] counters behind the Winograd/fusion savings
//! (combine ops per node, temporaries materialized and avoided), the
//! model's crossover trace (on model-cutoff runs), and arena
//! statistics.
//!
//! [`multiply_batched`] extends the planner to the shared-operand
//! workload (one B, many A — the im2col inference stream): the 7-way
//! fan-out repeats every B-side quadrant combination once per batch
//! member, so the combinations are **registered with the server's
//! operand registry** ([`register_weights`] → [`StrassenWeights`],
//! `7^depth` handles in recursion order) and each leaf pairing streams
//! through [`crate::coordinator::Submission::batched`] under
//! its handle — every B combination packed exactly once for the whole
//! batch. Repeated inference over the same weights should hold the
//! [`StrassenWeights`] and call [`multiply_batched_registered`] per
//! batch: later recursions resolve every combination from the cache
//! (registry hits) instead of re-forming `7^depth` packs per call.
//!
//! The A side has the symmetric lever for serving loops that re-run
//! one **activation batch**: [`register_activations`] →
//! [`StrassenActivations`] registers every leaf A combination of every
//! member, and [`multiply_batched_bi_registered`] runs the recursion
//! with **both** sides resolved from the registry — once warm, a
//! repeat run forms and packs nothing on either side.

mod arena;
mod planner;

pub use crate::analytical::StrassenAlgo;
pub use arena::{ArenaStats, ScratchArena};
pub use planner::{
    multiply, multiply_batched, multiply_batched_bi_registered, multiply_batched_registered,
    register_activations, register_activations_with, register_weights, register_weights_with,
    BatchedStrassenReport, CombineStats, Cutoff, StrassenActivations, StrassenConfig,
    StrassenReport, StrassenWeights, DIRECT_SPLIT_FANOUT,
};
