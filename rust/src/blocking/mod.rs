//! Workload partitioning: split `C = A x B` into the sub-block tasks the
//! WQM distributes over the PE arrays (Section II's blocked algorithm).
//!
//! A is split into `ceil(M/S_i)` row blocks `SA_i`, B into `ceil(N/S_j)`
//! column blocks `SB_j`; every pair `(i, j)` is one task producing the
//! `S_i x S_j` block `C_ij`. Ragged edges are padded with zeros in memory
//! (Section IV) but the task remembers its *effective* extent so the
//! functional model writes only real elements back.


/// One sub-block task `C_ij = SA_i x SB_j` — the WQM's queue element and
/// the unit of work stealing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockTask {
    /// Sequential task id (row-major over the (i, j) grid).
    pub id: usize,
    /// Block row index `i`.
    pub bi: usize,
    /// Block column index `j`.
    pub bj: usize,
    /// Element offset of the block in C (top-left corner).
    pub row0: usize,
    pub col0: usize,
    /// Nominal (padded) block shape = (S_i, S_j).
    pub si: usize,
    pub sj: usize,
    /// Effective extent before the matrix edge (<= si, <= sj).
    pub rows: usize,
    pub cols: usize,
    /// Shared contraction depth K.
    pub k: usize,
}

impl BlockTask {
    /// FLOPs of the padded task (what the PE array actually executes:
    /// zero-padded lanes still occupy pipeline slots).
    pub fn padded_flops(&self) -> u64 {
        2 * self.si as u64 * self.sj as u64 * self.k as u64
    }

    /// FLOPs that contribute to the un-padded result.
    pub fn effective_flops(&self) -> u64 {
        2 * self.rows as u64 * self.cols as u64 * self.k as u64
    }

    /// Bytes moved per Eq. 4: load SA_i + SB_j, write back C_ij (FP32).
    pub fn bytes_moved(&self) -> u64 {
        4 * (self.si as u64 * self.k as u64
            + self.sj as u64 * self.k as u64
            + self.si as u64 * self.sj as u64)
    }
}

/// The full task grid for one GEMM problem.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BlockPlan {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub si: usize,
    pub sj: usize,
}

impl BlockPlan {
    pub fn new(m: usize, k: usize, n: usize, si: usize, sj: usize) -> Self {
        assert!(m > 0 && k > 0 && n > 0, "degenerate problem");
        assert!(si > 0 && sj > 0, "degenerate block");
        Self { m, k, n, si, sj }
    }

    /// `ceil(M / S_i)` — row blocks of A.
    pub fn blocks_i(&self) -> usize {
        self.m.div_ceil(self.si)
    }

    /// `ceil(N / S_j)` — column blocks of B.
    pub fn blocks_j(&self) -> usize {
        self.n.div_ceil(self.sj)
    }

    /// Total task count `ceil(M/S_i) * ceil(N/S_j)`.
    pub fn num_tasks(&self) -> usize {
        self.blocks_i() * self.blocks_j()
    }

    /// Average tasks per array, Eq. 3.
    pub fn n_work(&self, np: usize) -> usize {
        self.num_tasks().div_ceil(np)
    }

    pub fn task(&self, id: usize) -> BlockTask {
        assert!(id < self.num_tasks(), "task id out of range");
        let bj_count = self.blocks_j();
        let bi = id / bj_count;
        let bj = id % bj_count;
        let row0 = bi * self.si;
        let col0 = bj * self.sj;
        BlockTask {
            id,
            bi,
            bj,
            row0,
            col0,
            si: self.si,
            sj: self.sj,
            rows: self.si.min(self.m - row0),
            cols: self.sj.min(self.n - col0),
            k: self.k,
        }
    }

    pub fn tasks(&self) -> impl Iterator<Item = BlockTask> + '_ {
        (0..self.num_tasks()).map(|id| self.task(id))
    }

    /// Initial static partition: round-robin tasks over `np` queues (the
    /// WQM's starting state before any stealing happens).
    pub fn partition(&self, np: usize) -> Vec<Vec<BlockTask>> {
        let mut queues = vec![Vec::new(); np];
        for t in self.tasks() {
            queues[t.id % np].push(t);
        }
        queues
    }

    /// Total bytes moved over the whole problem (all tasks, Eq. 4/5).
    pub fn total_bytes(&self) -> u64 {
        self.tasks().map(|t| t.bytes_moved()).sum()
    }

    /// Effective (un-padded) FLOPs of the whole problem: 2 M K N.
    pub fn effective_flops(&self) -> u64 {
        2 * self.m as u64 * self.k as u64 * self.n as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check;
    use std::collections::HashSet;

    #[test]
    fn exact_grid() {
        let p = BlockPlan::new(256, 100, 512, 64, 128);
        assert_eq!(p.blocks_i(), 4);
        assert_eq!(p.blocks_j(), 4);
        assert_eq!(p.num_tasks(), 16);
    }

    #[test]
    fn ragged_grid_rounds_up() {
        let p = BlockPlan::new(100, 10, 100, 64, 64);
        assert_eq!(p.blocks_i(), 2);
        assert_eq!(p.blocks_j(), 2);
        let t = p.task(3);
        assert_eq!((t.rows, t.cols), (36, 36));
        assert_eq!((t.si, t.sj), (64, 64));
    }

    #[test]
    fn n_work_eq3() {
        // Paper example: conv-2 (M=128, N=729) at Si=Sj=128:
        // ceil(128/128) * ceil(729/128) = 1 * 6 = 6 tasks; Np=2 -> 3 each.
        let p = BlockPlan::new(128, 1200, 729, 128, 128);
        assert_eq!(p.num_tasks(), 6);
        assert_eq!(p.n_work(2), 3);
        assert_eq!(p.n_work(4), 2);
    }

    #[test]
    fn task_bytes_eq4() {
        let p = BlockPlan::new(128, 1200, 729, 128, 128);
        let t = p.task(0);
        // 4 * (Si*K + Sj*K + Si*Sj)
        assert_eq!(t.bytes_moved(), 4 * (128 * 1200 + 128 * 1200 + 128 * 128));
    }

    #[test]
    fn partition_is_balanced_and_complete() {
        let p = BlockPlan::new(300, 50, 300, 64, 64);
        let queues = p.partition(4);
        let total: usize = queues.iter().map(Vec::len).sum();
        assert_eq!(total, p.num_tasks());
        let (min, max) = (
            queues.iter().map(Vec::len).min().unwrap(),
            queues.iter().map(Vec::len).max().unwrap(),
        );
        assert!(max - min <= 1);
    }

    #[test]
    fn prop_tasks_tile_c_exactly() {
        // Every element of C belongs to exactly one task.
        check::cases(64, |rng| {
            let (m, n) = (rng.range(1, 200), rng.range(1, 200));
            let (si, sj) = (rng.range(1, 70), rng.range(1, 70));
            let p = BlockPlan::new(m, 7, n, si, sj);
            let mut covered = vec![0u8; m * n];
            for t in p.tasks() {
                for r in t.row0..t.row0 + t.rows {
                    for c in t.col0..t.col0 + t.cols {
                        covered[r * n + c] += 1;
                    }
                }
            }
            assert!(covered.iter().all(|&v| v == 1));
        });
    }

    #[test]
    fn prop_ids_unique_and_dense() {
        check::cases(64, |rng| {
            let (m, n) = (rng.range(1, 150), rng.range(1, 150));
            let (si, sj) = (rng.range(1, 64), rng.range(1, 64));
            let p = BlockPlan::new(m, 3, n, si, sj);
            let ids: HashSet<usize> = p.tasks().map(|t| t.id).collect();
            assert_eq!(ids.len(), p.num_tasks());
            assert!(ids.iter().all(|&id| id < p.num_tasks()));
        });
    }

    #[test]
    fn prop_partition_conserves_tasks() {
        check::cases(64, |rng| {
            let (m, n) = (rng.range(1, 150), rng.range(1, 150));
            let (si, sj) = (rng.range(1, 64), rng.range(1, 64));
            let np = rng.range(1, 8);
            let p = BlockPlan::new(m, 5, n, si, sj);
            let queues = p.partition(np);
            let mut ids: Vec<usize> =
                queues.iter().flatten().map(|t| t.id).collect();
            ids.sort_unstable();
            let want: Vec<usize> = (0..p.num_tasks()).collect();
            assert_eq!(ids, want);
        });
    }

    #[test]
    fn prop_effective_flops_bounded_by_padded() {
        check::cases(64, |rng| {
            let (m, k, n) = (rng.range(1, 100), rng.range(1, 50), rng.range(1, 100));
            let (si, sj) = (rng.range(1, 40), rng.range(1, 40));
            let p = BlockPlan::new(m, k, n, si, sj);
            for t in p.tasks() {
                assert!(t.effective_flops() <= t.padded_flops());
            }
            let eff: u64 = p.tasks().map(|t| t.effective_flops()).sum();
            assert_eq!(eff, p.effective_flops());
        });
    }

    #[test]
    fn prop_n_work_covers_all() {
        check::cases(64, |rng| {
            let (m, n) = (rng.range(1, 200), rng.range(1, 200));
            let si = rng.range(1, 64);
            let np = rng.range(1, 5);
            let p = BlockPlan::new(m, 3, n, si, si);
            assert!(p.n_work(np) * np >= p.num_tasks());
        });
    }
}
