//! Strassen recursion-cutoff predictor, built on the Eqs. 3–9 model.
//!
//! Strassen trades the 8 sub-multiplies of a quadrant split for 7 plus
//! O(n²) element-wise combine traffic. Whether that trade wins on the
//! multi-array accelerator is exactly the kind of question the paper's
//! analytical model answers: the direct time of a `(M, K, N)` problem is
//! the best `⟨N_p, S_i⟩` design point's overlap estimate (Eqs. 3–7 over
//! the Eq. 9-feasible space), and one recursion level replaces it with
//! `7 · T(M/2, K/2, N/2) + T_combine`, where the combine term streams
//! the add/sub traffic at the Fig. 3 bandwidth of a single fully-chained
//! master (`BW(1, S_max)` — sequential bursts, the surface's sweet
//! spot).
//!
//! The combine term is priced per [`StrassenAlgo`] — the classic 7
//! products use 5+5 operand add/subs and 8 C-side ops, the Winograd
//! schedule 4+4 and 7 — and knows about **fused combine-packing**: at a
//! level whose children run direct, the planner forms each operand
//! combination *inside* the pack pass instead of materializing it, so
//! only the extra operand read is billed, not a round trip through a
//! temporary.
//!
//! [`strassen_crossover`] evaluates that recurrence level by level and
//! stops at the first level where recursing no longer pays (or where a
//! half falls below one `S_i = 16` granule). The result is a
//! [`CrossoverPlan`]: the model-chosen depth plus the full per-level
//! decision trace, which [`crate::dse::explore_strassen`] surfaces as a
//! first-class DSE output and `strassen::multiply` uses as its default
//! cutoff policy.

use crate::config::{HardwareConfig, RunConfig};
use crate::gemm::Dtype;

use super::bandwidth::{BandwidthSurface, SI_GRID};
use super::{feasible_nps, predict_dtype};

/// Recursion is only considered while both halves keep at least one
/// full `S_i = 16` block granule per dimension.
pub const MIN_HALF: usize = 16;

/// Which 7-product schedule the Strassen recursion runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum StrassenAlgo {
    /// Strassen's original 1969 schedule: 5 operand add/subs and 2
    /// copies per side, 8 C-side ops — 18 two-term combines per node.
    Classic,
    /// Winograd's rearrangement of the same 7 products: 4 operand
    /// add/subs per side and 7 C-side ops (two of them shared partial
    /// sums) — 15 two-term combines per node, the known minimum.
    #[default]
    Winograd,
}

impl StrassenAlgo {
    pub fn name(self) -> &'static str {
        match self {
            StrassenAlgo::Classic => "classic",
            StrassenAlgo::Winograd => "winograd",
        }
    }
}

/// One level of the crossover recurrence: the problem size seen at that
/// level and the model's two options for it.
#[derive(Debug, Clone, Copy)]
pub struct LevelDecision {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// Best direct multi-array time (Eq. 3–7 optimum), seconds.
    pub t_direct: f64,
    /// `7 · T(child) + combine`, seconds; infinite when recursion is
    /// infeasible (a half below [`MIN_HALF`]).
    pub t_strassen: f64,
    /// The combine term alone, seconds (0 when infeasible). Priced with
    /// the fused constants when this level's children run direct.
    pub combine_secs: f64,
    /// Did the model choose to recurse at this level?
    pub recurse: bool,
}

/// The model's verdict for a problem: chosen depth plus the per-level
/// decision trace (level 0 is the full problem; the last level is the
/// one executed directly).
#[derive(Debug, Clone)]
pub struct CrossoverPlan {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// Schedule the plan was priced for.
    pub algo: StrassenAlgo,
    /// Recursion levels the model recommends (0 = run direct).
    pub depth: usize,
    /// Decision at each level, outermost first; `levels.len() == depth + 1`.
    pub levels: Vec<LevelDecision>,
    /// Direct time of the full problem, seconds.
    pub t_direct: f64,
    /// Total time of the chosen plan (equals `t_direct` when depth = 0).
    pub t_chosen: f64,
}

/// Bytes per element of combine traffic on one operand side.
///
/// Materialized (interior nodes): an add/sub streams 12 bytes per
/// element (two reads + one write), a copy 8. Fused (leaf-parents, where
/// the combination forms inside the pack pass): a two-view combination
/// only adds the second operand read, 4 bytes, and a pass-through view
/// adds nothing — the pack itself would have read one operand anyway.
fn side_bytes_per_elem(algo: StrassenAlgo, fused: bool) -> f64 {
    match (algo, fused) {
        // 5 add/subs + 2 copies.
        (StrassenAlgo::Classic, false) => 5.0 * 12.0 + 2.0 * 8.0,
        // All 7 operands fuse: 5 two-view combos, 2 pass-throughs.
        (StrassenAlgo::Classic, true) => 5.0 * 4.0 + 2.0 * 0.0,
        // 4 chained add/subs; the all-materialized form also copies the
        // 3 quadrants that feed products directly (A11, A12, A22 /
        // B11, B21, B22).
        (StrassenAlgo::Winograd, false) => 4.0 * 12.0 + 3.0 * 8.0,
        // The chain heads (S1/S2, S5/S6) must materialize because later
        // steps read them; the other 2 steps and every pass-through
        // operand fuse into the packs.
        (StrassenAlgo::Winograd, true) => 2.0 * 12.0 + 2.0 * 4.0,
    }
}

/// C-side two-term ops per node: classic recombines with 8, Winograd
/// with 7 (two shared partial sums `t1`, `t2` included).
fn c_side_ops(algo: StrassenAlgo) -> f64 {
    match algo {
        StrassenAlgo::Classic => 8.0,
        StrassenAlgo::Winograd => 7.0,
    }
}

/// Seconds to form the 7 operand combinations and recombine the 7
/// sub-products, for quadrants `m2 x k2` (A side), `k2 x n2` (B side)
/// and `m2 x n2` (C side), streaming at `bw` bytes/s. `fused` selects
/// the leaf-parent pricing where operand formation rides inside the
/// pack pass.
pub fn combine_secs(
    algo: StrassenAlgo,
    fused: bool,
    m2: usize,
    k2: usize,
    n2: usize,
    bw: f64,
) -> f64 {
    let per_side = side_bytes_per_elem(algo, fused);
    let a_bytes = (m2 * k2) as f64 * per_side;
    let b_bytes = (k2 * n2) as f64 * per_side;
    let c_bytes = (m2 * n2) as f64 * (c_side_ops(algo) * 12.0);
    (a_bytes + b_bytes + c_bytes) / bw
}

/// [`combine_secs`] at a leaf precision: the combine constants above are
/// f32 (4-byte) element traffic; at a narrower or wider leaf dtype the
/// same element counts move proportionally fewer or more bytes. Exactly
/// [`combine_secs`] at `F32` (the scale factor is 1.0).
#[allow(clippy::too_many_arguments)]
pub fn combine_secs_dtype(
    algo: StrassenAlgo,
    fused: bool,
    m2: usize,
    k2: usize,
    n2: usize,
    bw: f64,
    dtype: Dtype,
) -> f64 {
    combine_secs(algo, fused, m2, k2, n2, bw) * (dtype.bytes() as f64 / 4.0)
}

/// Best direct time for `(m, k, n)`: minimum overlap estimate over the
/// Eq. 9-feasible `(N_p, S_i)` space — the same
/// [`crate::dse::candidate_sis`] sweep [`crate::dse::explore`] ranks,
/// so the two agree by construction (`dse` has a test pinning it).
pub fn best_direct_secs(
    hw: &HardwareConfig,
    m: usize,
    k: usize,
    n: usize,
    surface: &BandwidthSurface,
) -> anyhow::Result<f64> {
    best_direct_secs_dtype(hw, m, k, n, surface, Dtype::F32)
}

/// [`best_direct_secs`] priced at `dtype` via
/// [`predict_dtype`](super::predict_dtype) — identical at `F32`.
pub fn best_direct_secs_dtype(
    hw: &HardwareConfig,
    m: usize,
    k: usize,
    n: usize,
    surface: &BandwidthSurface,
    dtype: Dtype,
) -> anyhow::Result<f64> {
    let mut best: Option<f64> = None;
    for si in crate::dse::candidate_sis(hw, m) {
        for np in feasible_nps(hw, si) {
            let p = predict_dtype(hw, &RunConfig::square(np, si), m, k, n, surface, dtype)?;
            let t = p.t_overlap();
            if best.map(|b| t < b).unwrap_or(true) {
                best = Some(t);
            }
        }
    }
    best.ok_or_else(|| anyhow::anyhow!("no feasible direct design point for {m}x{k}x{n}"))
}

/// [`strassen_crossover_with`] under the default schedule
/// ([`StrassenAlgo::Winograd`]).
pub fn strassen_crossover(
    hw: &HardwareConfig,
    m: usize,
    k: usize,
    n: usize,
    surface: &BandwidthSurface,
) -> anyhow::Result<CrossoverPlan> {
    strassen_crossover_with(hw, m, k, n, surface, StrassenAlgo::default())
}

/// Evaluate the Strassen recurrence for `(m, k, n)` under `algo` and
/// return the model-chosen recursion depth with its full decision
/// trace. Child sizes are `ceil(dim / 2)` — the even-padded halves the
/// planner actually executes.
pub fn strassen_crossover_with(
    hw: &HardwareConfig,
    m: usize,
    k: usize,
    n: usize,
    surface: &BandwidthSurface,
    algo: StrassenAlgo,
) -> anyhow::Result<CrossoverPlan> {
    strassen_crossover_dtype(hw, m, k, n, surface, algo, Dtype::F32)
}

/// [`strassen_crossover_with`] priced at a leaf precision: leaf products
/// cost [`best_direct_secs_dtype`] and combine traffic scales with the
/// element width ([`combine_secs_dtype`]). Identical at `F32` — the
/// base functions delegate here.
pub fn strassen_crossover_dtype(
    hw: &HardwareConfig,
    m: usize,
    k: usize,
    n: usize,
    surface: &BandwidthSurface,
    algo: StrassenAlgo,
    dtype: Dtype,
) -> anyhow::Result<CrossoverPlan> {
    // Combine traffic streams sequentially through one master; use the
    // surface's best single-master point (largest calibrated burst).
    let combine_bw = surface.bw(1, SI_GRID[SI_GRID.len() - 1]);
    let (levels, t_chosen) = eval_level(hw, m, k, n, surface, combine_bw, algo, dtype)?;
    let depth = levels.len() - 1;
    Ok(CrossoverPlan { m, k, n, algo, depth, t_direct: levels[0].t_direct, levels, t_chosen })
}

/// Recursive core: returns the decision chain from this level down
/// (ending at the first non-recursing level) and the chosen total time.
#[allow(clippy::too_many_arguments)]
fn eval_level(
    hw: &HardwareConfig,
    m: usize,
    k: usize,
    n: usize,
    surface: &BandwidthSurface,
    combine_bw: f64,
    algo: StrassenAlgo,
    dtype: Dtype,
) -> anyhow::Result<(Vec<LevelDecision>, f64)> {
    let t_direct = best_direct_secs_dtype(hw, m, k, n, surface, dtype)?;
    let (m2, k2, n2) = (m.div_ceil(2), k.div_ceil(2), n.div_ceil(2));
    if m2 < MIN_HALF || k2 < MIN_HALF || n2 < MIN_HALF {
        let leaf = LevelDecision {
            m,
            k,
            n,
            t_direct,
            t_strassen: f64::INFINITY,
            combine_secs: 0.0,
            recurse: false,
        };
        return Ok((vec![leaf], t_direct));
    }
    let (child_levels, t_child) = eval_level(hw, m2, k2, n2, surface, combine_bw, algo, dtype)?;
    // Children that run direct are leaves: their parent fuses operand
    // formation into the pack pass instead of materializing temps.
    let fused = child_levels.len() == 1;
    let combine = combine_secs_dtype(algo, fused, m2, k2, n2, combine_bw, dtype);
    let t_strassen = 7.0 * t_child + combine;
    let recurse = t_strassen < t_direct;
    let here = LevelDecision { m, k, n, t_direct, t_strassen, combine_secs: combine, recurse };
    if recurse {
        let mut levels = vec![here];
        levels.extend(child_levels);
        Ok((levels, t_strassen))
    } else {
        Ok((vec![here], t_direct))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ddr::DdrConfig;

    fn setup() -> (HardwareConfig, BandwidthSurface) {
        let hw = HardwareConfig::paper();
        let s = BandwidthSurface::calibrate(&DdrConfig::vc709());
        (hw, s)
    }

    #[test]
    fn small_problems_run_direct() {
        let (hw, s) = setup();
        let plan = strassen_crossover(&hw, 128, 128, 128, &s).unwrap();
        assert_eq!(plan.depth, 0);
        assert_eq!(plan.levels.len(), 1);
        assert!(!plan.levels[0].recurse);
        assert_eq!(plan.t_chosen, plan.t_direct);
        assert_eq!(plan.algo, StrassenAlgo::Winograd, "default schedule");
    }

    #[test]
    fn huge_problems_recurse() {
        // At serving scale one level of Strassen must beat 8 direct
        // sub-multiplies: the saved eighth of compute dwarfs the O(n²)
        // combine traffic.
        let (hw, s) = setup();
        for algo in [StrassenAlgo::Classic, StrassenAlgo::Winograd] {
            let plan = strassen_crossover_with(&hw, 8192, 8192, 8192, &s, algo).unwrap();
            assert!(plan.depth >= 1, "depth {} at 8192^3 ({})", plan.depth, algo.name());
            assert!(plan.t_chosen < plan.t_direct);
            assert!(plan.levels[0].recurse);
        }
    }

    #[test]
    fn depth_is_monotone_in_problem_size() {
        let (hw, s) = setup();
        let mut last = 0;
        for dim in [256usize, 1024, 4096, 16384] {
            let plan = strassen_crossover(&hw, dim, dim, dim, &s).unwrap();
            assert!(plan.depth >= last, "depth shrank from {last} to {} at {dim}^3", plan.depth);
            last = plan.depth;
        }
    }

    #[test]
    fn levels_chain_halves_and_terminates() {
        let (hw, s) = setup();
        let plan = strassen_crossover(&hw, 10_000, 9_000, 11_000, &s).unwrap();
        assert_eq!(plan.levels.len(), plan.depth + 1);
        for w in plan.levels.windows(2) {
            assert!(w[0].recurse);
            assert_eq!(w[1].m, w[0].m.div_ceil(2));
            assert_eq!(w[1].k, w[0].k.div_ceil(2));
            assert_eq!(w[1].n, w[0].n.div_ceil(2));
        }
        assert!(!plan.levels.last().unwrap().recurse);
    }

    #[test]
    fn chosen_time_matches_recurrence() {
        let (hw, s) = setup();
        for algo in [StrassenAlgo::Classic, StrassenAlgo::Winograd] {
            let plan = strassen_crossover_with(&hw, 8192, 8192, 8192, &s, algo).unwrap();
            // Reconstruct the total from the trace: fold leaf-up.
            let mut t = plan.levels.last().unwrap().t_direct;
            for lvl in plan.levels.iter().rev().skip(1) {
                t = 7.0 * t + lvl.combine_secs;
            }
            assert!((t - plan.t_chosen).abs() <= 1e-12 * t.max(1.0));
        }
    }

    #[test]
    fn combine_constants_per_algo_and_fusion() {
        let area = 100.0 * 100.0;
        let at = |algo, fused| combine_secs(algo, fused, 100, 100, 100, 1e9) * 1e9;
        // Materialized: classic 5·12+2·8 = 76 per side, 8·12 = 96 on C;
        // Winograd 4·12+3·8 = 72 per side, 7·12 = 84 on C.
        assert!((at(StrassenAlgo::Classic, false) - area * (76.0 + 76.0 + 96.0)).abs() < 1e-6);
        assert!((at(StrassenAlgo::Winograd, false) - area * (72.0 + 72.0 + 84.0)).abs() < 1e-6);
        // Fused: classic 5·4 = 20 per side; Winograd 2·12+2·4 = 32.
        assert!((at(StrassenAlgo::Classic, true) - area * (20.0 + 20.0 + 96.0)).abs() < 1e-6);
        assert!((at(StrassenAlgo::Winograd, true) - area * (32.0 + 32.0 + 84.0)).abs() < 1e-6);
        // Winograd wins where temps materialize (interior nodes);
        // classic's copy-heavy schedule fuses better at leaf-parents.
        assert!(at(StrassenAlgo::Winograd, false) < at(StrassenAlgo::Classic, false));
        assert!(at(StrassenAlgo::Classic, true) < at(StrassenAlgo::Winograd, true));
    }

    #[test]
    fn dtype_crossover_f32_is_the_base_model() {
        let (hw, s) = setup();
        let base = strassen_crossover_with(&hw, 8192, 8192, 8192, &s, StrassenAlgo::Winograd)
            .unwrap();
        let f32d = strassen_crossover_dtype(
            &hw, 8192, 8192, 8192, &s, StrassenAlgo::Winograd, Dtype::F32,
        )
        .unwrap();
        assert_eq!(base.depth, f32d.depth);
        assert_eq!(base.t_chosen.to_bits(), f32d.t_chosen.to_bits());
        assert_eq!(base.t_direct.to_bits(), f32d.t_direct.to_bits());
        // Narrower leaves move less combine traffic and compute cheaper
        // MACs: the bf16 plan can only be as fast or faster.
        let bf16 = strassen_crossover_dtype(
            &hw, 8192, 8192, 8192, &s, StrassenAlgo::Winograd, Dtype::Bf16,
        )
        .unwrap();
        assert!(bf16.t_chosen <= f32d.t_chosen);
        assert!(bf16.t_direct < f32d.t_direct);
    }

    #[test]
    fn combine_grows_linearly_with_area() {
        let t1 = combine_secs(StrassenAlgo::Winograd, false, 100, 100, 100, 1e9);
        let t4 = combine_secs(StrassenAlgo::Winograd, false, 200, 200, 200, 1e9);
        assert!((t4 / t1 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn min_half_floor_respected() {
        let (hw, s) = setup();
        // Halves of 31 fall to 16 >= MIN_HALF; halves of 30 fall to 15.
        let p31 = strassen_crossover(&hw, 31, 31, 31, &s).unwrap();
        assert!(p31.levels[0].t_strassen.is_finite() || p31.depth == 0);
        let p30 = strassen_crossover(&hw, 30, 30, 30, &s).unwrap();
        assert_eq!(p30.depth, 0);
        assert!(p30.levels[0].t_strassen.is_infinite());
    }
}
